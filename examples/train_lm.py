"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on the synthetic pipeline, with checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Deliverable (b): the loss must visibly decrease; a second invocation
resumes from the latest checkpoint.
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: olmo-1b family scaled to 8 layers x 768
    cfg = dataclasses.replace(
        get_config("olmo-1b"),
        name="olmo-100m",
        n_layers=8,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab=32768,
        dtype="float32",
    )
    model = build_model(cfg)
    n = sum(int(v.size) for v in jax.tree.leaves(model.abstract()))
    print(f"model: {cfg.name} ({n/1e6:.1f}M params)")

    trainer = Trainer(
        model,
        AdamWConfig(lr_peak=3e-4, warmup_steps=50, decay_steps=args.steps),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch),
        TrainerConfig(
            steps=args.steps,
            log_every=20,
            checkpoint_every=100,
            checkpoint_dir=args.ckpt,
        ),
    )
    trainer.run()
    first, last = trainer.history[0]["loss"], trainer.history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
