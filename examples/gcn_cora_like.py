"""GCN + GraphSAGE inference on a Cora-like power-law graph over HBP.

    PYTHONPATH=src python examples/gcn_cora_like.py

The GNN workload end to end on the serving stack: build a synthetic
citation-network-shaped graph (2708 nodes, the Cora node count, power-law
degrees), admit its adjacencies to a MatrixRegistry — content-hashed,
autotune-cached in ``.hbp_autotune/`` — and run

* a 2-layer GCN over the symmetric-normalized self-loop adjacency
  (sum aggregation == one HBP SpMM per layer), and
* a 2-layer GraphSAGE with max aggregation over the raw adjacency
  (the max-monoid kernel path), aggregating 256-wide input features —
  wider than one 128-lane tile, so the kernel's lane-tiled k loop carries
  the layer.

Both forwards are checked against a numpy oracle; repeated calls reuse
the resident device tiles (the admit-once / infer-many asymmetry).
"""
import time

import numpy as np

import jax

from repro.graph import (
    add_self_loops,
    degrees,
    gcn_forward,
    init_gcn,
    init_sage,
    normalize_adjacency,
    plan_aggregator,
    power_law_graph,
    sage_forward,
)
from repro.serving import MatrixRegistry

N_NODES = 2708  # Cora's node count
N_FEATURES = 256  # > one 128-lane tile: the lane-tiled k loop engages
N_CLASSES = 7


def sum_oracle(csr, X):
    rows = np.repeat(np.arange(csr.n_rows), csr.row_nnz())
    out = np.zeros((csr.n_rows, X.shape[1]))
    np.add.at(out, rows, csr.data[:, None] * X[csr.indices])
    return out


def max_oracle(csr, X):
    rows = np.repeat(np.arange(csr.n_rows), csr.row_nnz())
    out = np.full((csr.n_rows, X.shape[1]), -np.inf, np.float32)
    np.maximum.at(out, rows, (csr.data[:, None] * X[csr.indices]).astype(np.float32))
    out[np.isneginf(out)] = 0.0
    return out


def main() -> None:
    print("== GCN / GraphSAGE on HBP message passing ==")
    G = power_law_graph(N_NODES, 8.0, seed=0)
    deg = degrees(G)
    print(
        f"graph: {G.shape[0]:,} nodes, {G.nnz:,} edges, "
        f"max degree {int(deg.max())}, median {int(np.median(deg))}"
    )

    # admit both adjacency views once; layers reuse the resident plans
    reg = MatrixRegistry(search=False)  # nnz-profile heuristic, disk-cached
    t0 = time.perf_counter()
    gcn_plan = reg.admit(normalize_adjacency(add_self_loops(G), "sym"), "cora/gcn")
    raw_plan = reg.admit(G, "cora/raw")
    print(f"admitted 2 adjacencies in {time.perf_counter() - t0:.2f}s "
          f"(lane={gcn_plan.cfg.lane}, tiles={gcn_plan.tiles.n_tiles})")

    rng = np.random.default_rng(1)
    X = rng.standard_normal((N_NODES, N_FEATURES)).astype(np.float32)

    # --- GCN ---------------------------------------------------------------
    params = init_gcn(jax.random.PRNGKey(0), [N_FEATURES, 64, N_CLASSES])
    agg = plan_aggregator(gcn_plan)
    fwd = jax.jit(lambda p, x: gcn_forward(agg, p, x))
    logits = np.asarray(fwd(params, X))  # compile + run
    t0 = time.perf_counter()
    for _ in range(5):
        fwd(params, X).block_until_ready()
    gcn_ms = (time.perf_counter() - t0) / 5 * 1e3

    csr_hat = normalize_adjacency(add_self_loops(G), "sym")
    h = np.maximum(sum_oracle(csr_hat, X @ np.asarray(params[0].W)) + np.asarray(params[0].b), 0)
    want = sum_oracle(csr_hat, h @ np.asarray(params[1].W)) + np.asarray(params[1].b)
    err = np.abs(logits - want).max() / (np.abs(want).max() + 1e-12)
    print(f"GCN     [{N_FEATURES} -> 64 -> {N_CLASSES}]: {gcn_ms:6.1f} ms/forward, "
          f"rel err vs oracle {err:.2e}")
    assert err < 1e-5

    # --- GraphSAGE (max aggregation: the max-monoid kernel path) -----------
    sparams = init_sage(jax.random.PRNGKey(1), [N_FEATURES, 64, N_CLASSES])
    sagg = plan_aggregator(raw_plan, op="max")
    sfwd = jax.jit(lambda p, x: sage_forward(sagg, p, x))
    slogits = np.asarray(sfwd(sparams, X))
    t0 = time.perf_counter()
    for _ in range(5):
        sfwd(sparams, X).block_until_ready()
    sage_ms = (time.perf_counter() - t0) / 5 * 1e3

    hs = np.maximum(
        X @ np.asarray(sparams[0].W_self)
        + max_oracle(G, X) @ np.asarray(sparams[0].W_neigh)
        + np.asarray(sparams[0].b),
        0,
    ).astype(np.float32)
    wants = (
        hs @ np.asarray(sparams[1].W_self)
        + max_oracle(G, hs) @ np.asarray(sparams[1].W_neigh)
        + np.asarray(sparams[1].b)
    )
    serr = np.abs(slogits - wants).max() / (np.abs(wants).max() + 1e-12)
    print(f"SAGEmax [{N_FEATURES} -> 64 -> {N_CLASSES}]: {sage_ms:6.1f} ms/forward, "
          f"rel err vs oracle {serr:.2e}  (k=256 lane-tiled)")
    assert serr < 1e-5

    stats = reg.stats()["cora/gcn"]
    print(f"plan reuse: admissions={stats['admissions']}, "
          f"preprocess {stats['preprocess_s']:.2f}s amortized over every layer call")
    print("OK")


if __name__ == "__main__":
    main()
