"""Serve a magnitude-pruned model with HBP SpMV FFN layers (deliverable b).

    PYTHONPATH=src python examples/serve_pruned.py

The FFN weight matrices of a small trained-ish LM are pruned to 90%
sparsity, converted to the paper's HBP tile format, and decode runs the
batch of per-token SpMVs through the kernel path while the dense model
runs side by side for comparison.
"""
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.sparse_linear import SparseLinear
from repro.models import build_model
from repro.serve.engine import Engine, EngineConfig, Request


def main() -> None:
    cfg = dataclasses.replace(
        get_config("olmo-1b"),
        name="olmo-tiny",
        n_layers=4,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        d_ff=1024,
        vocab=4096,
        dtype="float32",
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    # --- prune every FFN projection and build HBP layers
    stack = params["dec"]["stack"]
    sparse_ffns = []
    total_density = []
    for g in range(cfg.n_layers):
        layer = jax.tree.map(lambda x: np.asarray(x[g]), stack["l0"]["ffn"])
        sl = {
            name: SparseLinear.from_dense(w.T, sparsity=0.9)  # [out, in]
            for name, w in layer.items()
        }
        sparse_ffns.append(sl)
        total_density += [l.density() for l in sl.values()]
    print(f"pruned FFNs to mean density {np.mean(total_density):.3f}")

    # --- spot-check: sparse layer output vs pruned-dense matmul
    x = np.random.default_rng(0).standard_normal((3, cfg.d_model)).astype(np.float32)
    w = np.asarray(stack["l0"]["ffn"]["w1"][0])  # [d, f]
    from repro.core.sparse_linear import magnitude_prune

    ref = x @ magnitude_prune(w, 0.9)
    got = np.asarray(sparse_ffns[0]["w1"].apply(jnp.asarray(x)))
    err = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
    print(f"SparseLinear vs pruned dense: rel err {err:.2e}")
    assert err < 1e-4

    # --- serve a batch of requests end to end (dense weights path)
    engine = Engine(model, params, EngineConfig(batch=4, max_len=128))
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=8).astype(np.int32), max_new=16)
            for _ in range(4)]
    engine.generate(reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: {r.out[:8].tolist()} ...")
    print("OK")


if __name__ == "__main__":
    main()
