"""Distributed SpMV across a device mesh (deliverable b, cluster scale).

    PYTHONPATH=src python examples/spmv_cluster.py

Maps the paper's fixed/competitive block scheduling onto a (small, CPU)
device mesh via shard_map: "grid" placement = locality-first (x segments
never move), "balanced" = LPT competitive replay.  On the 512-chip
production mesh the same code path shards over the full "data" axis.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

import jax

from repro.core import PartitionConfig
from repro.core.distributed import build_sharded_spmv
from repro.core.matrices import rmat


def main() -> None:
    mesh = jax.make_mesh((8,), ("data",))
    A = rmat(1 << 13, 200_000, seed=0)
    x = np.random.default_rng(0).standard_normal(A.n_cols).astype(np.float32)
    y_ref = A.matvec(x)

    for mode in ("balanced", "grid"):
        sh = build_sharded_spmv(
            A, mesh, cfg=PartitionConfig(row_block=256, col_block=1024), mode=mode
        )
        y = np.asarray(sh.matvec(jax.numpy.asarray(x)))
        err = np.abs(y - y_ref).max() / (np.abs(y_ref).max() + 1e-12)
        imbalance = sh.loads.max() / max(sh.loads.mean(), 1e-9)
        print(
            f"mode={mode:9s} rel_err={err:.2e} tiles/worker imbalance="
            f"{imbalance:.2f} (loads {sh.loads.astype(int).tolist()})"
        )
        assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
