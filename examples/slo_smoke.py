"""SLO smoke: induced deadline misses MUST trip the always-on telemetry.

Nightly-CI guard for the flight-recorder + SLO + request-trace path:
serve a small matrix on a virtual clock, stall it long enough that every
pending request misses its deadline, then assert the failure left the
evidence a real outage would need —

* a ``flight_deadline_miss_*.json`` post-mortem dump (Perfetto-loadable)
  containing the offending ``serve.flush`` span, whose trigger event
  names the **trace ids** of the late requests;
* a burning ``slo.burn_rate`` gauge and a paging
  :meth:`ServingEngine.health` view;
* the same late trace ids as **exemplars** on the ``serving.latency_s``
  histogram scraped live from the OpenMetrics endpoint
  (``repro.obs.export.serve``) — the dump and the scrape join on the id.

Exits nonzero when any of it is missing, so a regression that silently
disables the always-on path fails the nightly job::

    PYTHONPATH=src REPRO_FLIGHT_DIR=flight_dumps python examples/slo_smoke.py
"""
import json
import sys
import tempfile
import urllib.request

import numpy as np

from repro.core.matrices import circuit
from repro.obs import export
from repro.obs.flight import FlightRecorder
from repro.obs.requesttrace import RequestLog
from repro.serving import MatrixRegistry, ServingEngine


def main() -> int:
    with tempfile.TemporaryDirectory() as cache_dir:
        reg = MatrixRegistry(cache_dir=cache_dir, search=False)
        A = circuit(200, seed=7)
        reg.admit(A, "smoke")

        flight = FlightRecorder(capacity=512)  # dumps to $REPRO_FLIGHT_DIR
        vclock = [0.0]
        eng = ServingEngine(
            reg, max_wait_s=0.001, max_batch=8, clock=lambda: vclock[0],
            flight=flight, request_log=RequestLog(),
        )
        rng = np.random.default_rng(0)
        for i in range(16):
            vclock[0] = 1e-5 * i
            eng.submit("smoke", rng.standard_normal(A.shape[1]).astype(np.float32))
        vclock[0] = 1.0  # every pending request is now far past its deadline
        eng.poll()
        eng.flush()

    failures = []

    # the request log knows exactly which requests burned their deadline
    late_ids = {
        c.trace_id for c in eng.request_log.contexts() if c.deadline_hit is False
    }
    if not late_ids:
        failures.append("request log recorded no deadline-missing requests")

    dumps = flight.stats()["dumps"]
    miss_dumps = [p for p in dumps if "deadline_miss" in p]
    if not miss_dumps:
        failures.append(f"no deadline_miss flight dump was written (dumps: {dumps})")
    else:
        with open(miss_dumps[0]) as f:
            artifact = json.load(f)
        events = artifact.get("traceEvents", [])
        if artifact.get("otherData", {}).get("reason") != "deadline_miss":
            failures.append(f"dump {miss_dumps[0]} has the wrong trigger reason")
        if not any(e["name"] == "serve.flush" for e in events):
            failures.append("the dump does not contain the offending flush span")
        # the trigger event must name the offending requests by trace id
        triggers = [e for e in events if e["name"] == "flight.trigger"]
        dump_ids = set()
        for e in triggers:
            dump_ids.update(e.get("args", {}).get("trace_ids") or [])
        if not dump_ids:
            failures.append("the trigger event carries no trace_ids")
        elif not dump_ids <= late_ids:
            failures.append(
                f"dump trace_ids {sorted(dump_ids)} are not the late requests "
                f"{sorted(late_ids)}"
            )
        else:
            print(
                f"flight dump ok: {miss_dumps[0]} ({len(events)} ring events, "
                f"{len(dump_ids)} late trace ids named)"
            )

    # the same trace ids must be scrapable as histogram exemplars
    srv = export.serve(port=0, registries=[eng.metrics])
    try:
        with urllib.request.urlopen(srv.url, timeout=10) as resp:
            text = resp.read().decode("utf-8")
        families = export.parse_openmetrics(text)
        lat = families.get("serving_latency_s")
        if lat is None:
            failures.append("scrape has no serving_latency_s family")
        else:
            scraped_ids = {
                s["exemplar"]["labels"].get("trace_id")
                for s in lat["samples"]
                if s.get("exemplar")
            }
            if not scraped_ids & late_ids:
                failures.append(
                    f"no late trace id appears as a scraped exemplar "
                    f"(scraped {sorted(scraped_ids)}, late {sorted(late_ids)})"
                )
            else:
                print(
                    f"scrape ok: {srv.url} exposes "
                    f"{len(scraped_ids & late_ids)} late trace ids as exemplars"
                )
    finally:
        srv.close()

    health = eng.health(now=vclock[0])
    status = health["matrices"].get("smoke", {}).get("status")
    if status != "page":
        failures.append(f"health status is {status!r}, expected 'page'")
    else:
        print(f"health ok: smoke pages (overall {health['status']})")

    burn = eng.metrics.value(
        "slo.burn_rate", matrix="smoke", slo="deadline", window="60s"
    )
    if burn <= 1.0:
        failures.append(f"slo.burn_rate gauge is {burn}, expected a real burn")
    else:
        print(f"burn-rate gauge ok: {burn:.1f}x the sustainable pace")

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print("slo smoke: induced deadline misses tripped dump + gauges as required")
    return 0


if __name__ == "__main__":
    sys.exit(main())
