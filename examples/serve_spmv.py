"""Serving SpMV traffic end to end: registry -> micro-batcher -> kernel.

    PYTHONPATH=src python examples/serve_spmv.py

Admits two matrices into a MatrixRegistry (content-hashed, partition
config autotuned with an on-disk cache under .hbp_autotune/), replays a
burst of mixed requests through the micro-batching ServingEngine, checks
every answer bitwise against a sequential per-request SpMV, and prints the
engine's instrumentation — including how far the traffic has amortized the
one-time HBP preprocessing cost.

With observability on::

    REPRO_OBS=1 PYTHONPATH=src python examples/serve_spmv.py

it additionally writes ``serve_trace.json`` (Chrome-trace JSON — open at
https://ui.perfetto.dev to see the nested admit/flush spans),
``serve_obs.json`` (the full metrics snapshot, re-renderable with
``python -m repro.analysis.report --obs serve_obs.json``), and prints the
text dashboard: registry hit/miss counters, batch-width histograms, and
the per-matrix amortized-preprocess ledger.
"""
import numpy as np

from repro import obs
from repro.core import spmv
from repro.core.matrices import banded_fem, circuit
from repro.core.partition import enumerate_configs
from repro.serving import MatrixRegistry, ServingEngine


def main() -> None:
    print("== HBP SpMV serving ==")
    A = circuit(6_000, seed=0)
    B = banded_fem(4_000, seed=3)
    # a compact measured search keeps the demo's first run quick; the cache
    # makes every later run (and CI re-run) skip it entirely
    candidates = enumerate_configs(
        A.shape, row_blocks=(256, 512), col_blocks=(2048, 4096), lanes=(16, 64)
    )

    for attempt in ("cold (or cached from a previous run)", "warm"):
        registry = MatrixRegistry(cache_dir=".hbp_autotune", candidates=candidates)
        plan_a = registry.admit(A, "circuit")
        plan_b = registry.admit(B, "fem")
        print(f"[{attempt}] admit circuit: cache_hit={plan_a.autotune_cache_hit} "
              f"searched={plan_a.autotune_searched} cfg=({plan_a.cfg.row_block},"
              f"{plan_a.cfg.col_block},{plan_a.cfg.group},{plan_a.cfg.lane}) "
              f"preprocess={plan_a.preprocess_s:.2f}s")

    # identical content re-admitted into the live registry is a pure hit —
    # no tiles rebuilt, just the hit/admission counters moving
    assert registry.admit(A, "circuit") is plan_a
    assert plan_a.admissions == 2

    engine = ServingEngine(registry, max_wait_s=0.002)
    rng = np.random.default_rng(0)
    requests = []
    for i in range(40):  # mixed traffic, ~2:1 across the two matrices
        key = "circuit" if i % 3 != 2 else "fem"
        n = (A if key == "circuit" else B).n_cols
        x = rng.standard_normal(n).astype(np.float32)
        requests.append((key, x, engine.submit(key, x)))
    engine.flush()

    worst = 0.0
    for key, x, ticket in requests:
        plan = registry.get(key)
        assert np.array_equal(ticket.result(), np.asarray(plan.matvec(x))), (
            "batched result must be bitwise identical to the sequential call"
        )
        y_ref = spmv(A if key == "circuit" else B, x.astype(np.float64))
        worst = max(worst, float(np.abs(ticket.result() - y_ref).max() / (np.abs(y_ref).max() + 1e-12)))
    print(f"40 requests served; bitwise == sequential; max rel err vs CSR: {worst:.2e}")

    def ms(v):
        # percentiles/amortization are None for a matrix with no completed
        # requests yet — print "n/a", never crash on the empty window
        return "n/a" if v is None else f"{1e3 * v:.1f}ms"

    for key, s in sorted(engine.stats().items()):
        print(
            f"stats[{key}]: requests={s['requests']} batches={s['batches']} "
            f"mean_batch_k={s['mean_batch_k']:.1f} occupancy={s['occupancy']:.2f} "
            f"pad_fraction={s['pad_fraction']:.2f} "
            f"p50={ms(s['latency_p50_s'])} p99={ms(s['latency_p99_s'])} "
            f"amortized_preprocess={ms(s['amortized_preprocess_s'])}/req"
        )

    if obs.enabled():
        obs.write_trace("serve_trace.json")
        snap = obs.dump("serve_obs.json")
        print(
            f"\n[obs] wrote serve_trace.json ({snap['n_events']} span events, "
            "open at https://ui.perfetto.dev) and serve_obs.json"
        )
        print(obs.report())
        # the per-matrix explain report: partition quality, autotune
        # provenance, modeled-vs-measured bandwidth, imbalance verdict —
        # the same text `python -m repro.analysis.report --explain circuit`
        # re-renders from serve_obs.json
        from repro.obs.planview import explain_report

        print(explain_report(snap, "circuit"))
    print("ok")


if __name__ == "__main__":
    main()
