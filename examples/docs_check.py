"""Docs executability gate: every fenced snippet in the docs must run.

Checks ``README.md`` and every page under ``docs/`` by default.

Documentation rots the moment an API drifts under it.  This check keeps
the docs honest the same way tests keep the code honest:

* every ```` ```python ```` block is executed, blocks within one file
  sharing a namespace in document order (so a page can build state
  incrementally, exactly as a reader pasting it into a REPL would);
* every ```` ```bash ```` block is syntax-checked with ``bash -n`` (the
  commands themselves may need artifacts or long runtimes CI should not
  pay — the gate catches typos and quoting rot, not semantics);
* all other fence languages (``yaml``, ``text``, bare fences for sample
  output) are ignored.

Python blocks run inside a throwaway working directory so snippet
side-effect files (autotune caches, flight dumps, ``metrics.prom``)
never land in the repo checkout.  Exits nonzero on the first failing
snippet, naming the file and the line the fence opened on::

    PYTHONPATH=src python examples/docs_check.py            # all of docs/
    PYTHONPATH=src python examples/docs_check.py docs/serving.md
"""
import argparse
import os
import re
import subprocess
import sys
import tempfile
from pathlib import Path

_FENCE_RE = re.compile(r"^```(\w*)\s*$")


def extract_blocks(text: str):
    """Yield ``(language, start_line, source)`` for each fenced block."""
    lang, start, lines = None, 0, []
    for i, line in enumerate(text.splitlines(), start=1):
        m = _FENCE_RE.match(line.strip())
        if m and lang is None:
            lang, start, lines = m.group(1).lower(), i, []
        elif line.strip() == "```" and lang is not None:
            yield lang, start, "\n".join(lines) + "\n"
            lang = None
        elif lang is not None:
            lines.append(line)


def check_python(path: Path, blocks) -> int:
    """Execute the file's python blocks in one shared namespace."""
    failures = 0
    namespace = {"__name__": f"docs_check:{path.name}"}
    for lang, start, src in blocks:
        if lang != "python":
            continue
        try:
            code = compile(src, f"{path}:{start}", "exec")
            exec(code, namespace)  # noqa: S102 - executing our own docs is the point
        except Exception as e:  # noqa: BLE001 - report and keep the gate's exit code
            print(f"FAIL {path}:{start} [python] {type(e).__name__}: {e}")
            failures += 1
        else:
            print(f"ok   {path}:{start} [python]")
    return failures


def check_bash(path: Path, blocks) -> int:
    """Syntax-check the file's bash blocks with ``bash -n``."""
    failures = 0
    for lang, start, src in blocks:
        if lang not in ("bash", "sh", "shell"):
            continue
        proc = subprocess.run(
            ["bash", "-n"], input=src, capture_output=True, text=True
        )
        if proc.returncode != 0:
            print(f"FAIL {path}:{start} [bash] {proc.stderr.strip()}")
            failures += 1
        else:
            print(f"ok   {path}:{start} [bash]")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "files",
        nargs="*",
        help="markdown files to check (default: README.md + docs/*.md)",
    )
    args = ap.parse_args(argv)
    repo = Path(__file__).resolve().parent.parent
    files = (
        [Path(f).resolve() for f in args.files]
        if args.files
        else [repo / "README.md", *sorted((repo / "docs").glob("*.md"))]
    )
    if not files:
        print("no docs to check", file=sys.stderr)
        return 1
    failures = 0
    cwd = os.getcwd()
    for path in files:
        blocks = list(extract_blocks(path.read_text()))
        with tempfile.TemporaryDirectory(prefix="docs_check_") as tmp:
            os.chdir(tmp)  # snippet side-effect files stay out of the checkout
            try:
                failures += check_python(path, blocks)
                failures += check_bash(path, blocks)
            finally:
                os.chdir(cwd)
    if failures:
        print(f"\n{failures} snippet(s) failed")
        return 1
    print(f"\nall snippets green across {len(files)} file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
