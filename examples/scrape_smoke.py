"""Scrape smoke: the OpenMetrics endpoint MUST serve parseable telemetry.

CI guard for the exporter path: serve a little real traffic through a
ServingEngine, start the scrape endpoint (``repro.obs.export.serve``),
fetch ``/metrics`` the way a Prometheus would (``curl`` when available,
urllib otherwise), then assert the exposition —

* parses as OpenMetrics (:func:`repro.obs.export.parse_openmetrics` is
  strict about TYPE families, suffixes, cumulative buckets and ``# EOF``);
* carries the ``slo.burn_rate`` gauge family the burn-rate monitors
  maintain;
* carries at least one histogram **exemplar** linking a latency bucket to
  a request trace id.

``--out metrics.prom`` additionally writes the scraped text to a file so
the CI job can upload it as an artifact next to ``TRACE_ci.json``.  Exits
nonzero when anything is missing::

    PYTHONPATH=src python examples/scrape_smoke.py --out metrics.prom
"""
import argparse
import shutil
import subprocess
import sys
import tempfile
import urllib.request

import numpy as np

from repro.core.matrices import circuit
from repro.obs import export
from repro.serving import MatrixRegistry, ServingEngine


def scrape(url: str) -> str:
    """GET the endpoint like a real scraper: curl if present, else urllib."""
    curl = shutil.which("curl")
    if curl:
        out = subprocess.run(
            [curl, "-sSf", "--max-time", "10", url],
            check=True,
            capture_output=True,
        )
        return out.stdout.decode("utf-8")
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.read().decode("utf-8")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--out", default=None, metavar="PATH",
        help="also write the scraped exposition to PATH (CI artifact)",
    )
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as cache_dir:
        reg = MatrixRegistry(cache_dir=cache_dir, search=False)
        A = circuit(200, seed=11)
        reg.admit(A, "scrape")
        eng = ServingEngine(reg, max_batch=4)
        rng = np.random.default_rng(1)
        for _ in range(12):
            eng.submit("scrape", rng.standard_normal(A.shape[1]).astype(np.float32))
        eng.flush()
        eng.health()  # populate the slo.* gauges the scrape must expose

        failures = []
        srv = export.serve(port=0, registries=[eng.metrics])
        try:
            text = scrape(srv.url)
        finally:
            srv.close()

        try:
            families = export.parse_openmetrics(text)
            print(f"scrape ok: {len(text)} bytes, {len(families)} families parse")
        except ValueError as e:
            print(f"FAIL: exposition does not parse: {e}", file=sys.stderr)
            return 1

        if "slo_burn_rate" not in families:
            failures.append(
                f"slo_burn_rate family missing (got {sorted(families)})"
            )
        else:
            print("slo.burn_rate gauges present")

        exemplars = [
            s["exemplar"]
            for f in families.values()
            for s in f["samples"]
            if s.get("exemplar")
        ]
        if not any(e["labels"].get("trace_id") for e in exemplars):
            failures.append("no trace_id exemplar anywhere in the exposition")
        else:
            print(f"{len(exemplars)} bucket exemplars carry trace ids")

        if args.out:
            with open(args.out, "w") as f:
                f.write(text)
            print(f"wrote {args.out}")

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print("scrape smoke: endpoint serves parseable OpenMetrics with exemplars")
    return 0


if __name__ == "__main__":
    sys.exit(main())
