"""Quickstart: the paper's pipeline end to end on one matrix.

    PYTHONPATH=src python examples/quickstart.py

Builds a circuit-simulation matrix (ASIC_* family), converts it to the
HBP format (2D partition -> nonlinear hash reorder -> TPU tiles), runs the
Pallas SpMV kernel (interpret mode on CPU) and compares against CSR.
"""
import numpy as np

from repro.core import (
    PartitionConfig,
    build_tiles,
    group_stddev,
    padding_waste,
    spmv,
)
from repro.core.hash import sample_params
from repro.core.matrices import circuit
from repro.core.reorder import hash_reorder_block


def main() -> None:
    print("== HBP quickstart ==")
    A = circuit(20_000, seed=0)
    print(f"matrix: {A.shape[0]}x{A.shape[1]}, nnz={A.nnz:,}")

    # 1. the nonlinear hash on one row block (paper Fig. 3/4)
    nnz = A.row_nnz()[:512]
    params = sample_params(nnz, table_size=512)
    perm = hash_reorder_block(nnz, params)
    print(f"hash params: a={params.a} c={params.c} b={params.b} d={params.d}")
    print(
        f"warp stddev: {group_stddev(nnz, np.arange(512)).mean():.2f} -> "
        f"{group_stddev(nnz, perm).mean():.2f}"
    )
    print(
        f"tile padding waste: {padding_waste(nnz, np.arange(512)):.3f} -> "
        f"{padding_waste(nnz, perm):.3f}"
    )

    # 2. full format conversion + SpMV (Pallas kernel, interpret on CPU)
    cfg = PartitionConfig(row_block=512, col_block=4096)
    tiles = build_tiles(A, cfg, method="hash")
    print(f"tiles: {tiles.n_tiles}, utilization={tiles.nnz_utilization():.2f}")
    x = np.random.default_rng(0).standard_normal(A.n_cols).astype(np.float32)
    y_hbp = np.asarray(spmv(tiles, x, backend="jnp"))
    y_csr = spmv(A, x)  # CSR reference (Algorithm 1)
    err = np.abs(y_hbp - y_csr).max() / (np.abs(y_csr).max() + 1e-12)
    print(f"HBP vs CSR relative error: {err:.2e}")
    assert err < 1e-5
    print("OK")


if __name__ == "__main__":
    main()
