"""Quickstart: PageRank on an R-MAT graph through the HBP pipeline.

    PYTHONPATH=src python examples/pagerank_rmat.py

The workload the paper motivates: an iterative algorithm whose inner loop
is one sparse product, run entirely on the HBP tile format.  Builds a
power-law (kron_g500-family) graph, converts the column-stochastic
transition matrix to HBP tiles, and ranks with the jit-compiled power
iteration — once with the uniform vector (SpMV per step) and once with
four personalization vectors in a single run (the multi-RHS SpMM kernel:
one tile-stream pass per iteration for all four rankings).
"""
import numpy as np

from repro.core import PartitionConfig, build_tiles
from repro.core.matrices import rmat
from repro.solvers import aslinearoperator, pagerank, transition_matrix


def main() -> None:
    print("== PageRank on HBP quickstart ==")
    G = rmat(1 << 13, 80_000, seed=4, symmetric=False)
    print(f"graph: {G.n_rows:,} nodes, {G.nnz:,} edges")

    # host-side preprocessing: normalize + transpose, then the HBP build
    M, dangling = transition_matrix(G)
    tiles = build_tiles(M, PartitionConfig())
    print(f"tiles: {tiles.n_tiles}, utilization={tiles.nnz_utilization():.2f}, "
          f"dangling nodes: {int(dangling.sum())}")
    # jnp oracle of the Pallas kernel on CPU; on TPU drop strategy for the
    # fused Pallas path
    op = aslinearoperator(tiles, strategy="reference")

    # 1. classic PageRank (one SpMV launch per iteration)
    res = pagerank(op, damping=0.85, dangling=dangling, tol=1e-10, maxiter=200)
    p = np.asarray(res.x)
    print(f"converged={bool(res.converged)} in {int(res.iterations)} iterations, "
          f"sum={p.sum():.6f}")
    print("top-5 nodes:", np.argsort(p)[::-1][:5].tolist())

    # 2. four personalized rankings in ONE run (multi-RHS SpMM per step)
    rng = np.random.default_rng(0)
    P = (rng.random((G.n_rows, 4)) + 0.01).astype(np.float32)
    multi = pagerank(op, damping=0.85, personalization=P, dangling=dangling,
                     tol=1e-10, maxiter=200)
    pm = np.asarray(multi.x)
    print(f"personalized block: shape={pm.shape}, "
          f"column sums={np.round(pm.sum(axis=0), 6).tolist()}")

    # cross-check column 0 against an independent single-vector run
    single = pagerank(op, damping=0.85, personalization=P[:, 0],
                      dangling=dangling, tol=1e-10, maxiter=200)
    err = np.abs(pm[:, 0] - np.asarray(single.x)).max()
    print(f"SpMM column vs independent SpMV run: max abs diff = {err:.2e}")
    assert err < 1e-6
    print("OK")


if __name__ == "__main__":
    main()
