"""Train a GCN node classifier end to end on the differentiable HBP path.

    PYTHONPATH=src python examples/train_gcn_node_classification.py

The GNN *training* workload on the serving stack: every forward
aggregation is an HBP SpMM over the registry-resident normalized
adjacency, and every backward is an HBP SpMM over its linked transpose
plan (``x̄ = Âᵀ ȳ`` — for GCN's symmetric Â the two plans are literally
the same residency, linked to itself by content hash).  The script

1. builds a synthetic *homophilous* power-law citation graph — nodes
   carry class labels and edges prefer same-class endpoints, so the graph
   structure (not just the features) is informative;
2. trains a 2-layer GCN for 20 full-graph steps with AdamW and asserts
   the cross-entropy decreases on average (the CI gate);
3. runs GraphSAGE neighbor-sampled mini-batches over the same registry
   for two epochs, showing the second epoch re-admits every sampled
   subgraph for free (content-hash cache hits).

Autotune state persists in ``.hbp_autotune/`` between runs.
"""
import numpy as np

from repro.graph import graph_from_edges
from repro.graph.train import NodeClassifierTrainer
from repro.serving import MatrixRegistry

N_NODES = 600
N_CLASSES = 5
N_FEATURES = 32
HOMOPHILY = 0.85  # fraction of edges drawn within a class
AVG_DEGREE = 8.0
STEPS = 20


def homophilous_graph(rng):
    """Power-law-ish graph whose edges prefer same-class endpoints."""
    labels = rng.integers(0, N_CLASSES, N_NODES)
    m = int(N_NODES * AVG_DEGREE / 2)
    # Zipf-like popularity so degrees stay skewed (the HBP-relevant shape)
    p = (1.0 + np.arange(N_NODES)) ** -1.1
    p /= p.sum()
    pop = rng.permutation(N_NODES)
    src = pop[rng.choice(N_NODES, size=m, p=p)]
    dst = pop[rng.choice(N_NODES, size=m, p=p)]
    # rewire a HOMOPHILY fraction of destinations to the source's class
    same = rng.random(m) < HOMOPHILY
    by_class = [np.flatnonzero(labels == c) for c in range(N_CLASSES)]
    dst = np.where(
        same,
        np.array([rng.choice(by_class[labels[s]]) for s in src]),
        dst,
    )
    keep = src != dst
    adj = graph_from_edges(src[keep], dst[keep], n_nodes=N_NODES, symmetric=True)
    return adj, labels


def main() -> None:
    print("== GCN node-classification training over differentiable HBP ==")
    rng = np.random.default_rng(0)
    adj, labels = homophilous_graph(rng)
    # weakly informative features: class signal well below the noise floor,
    # so the aggregation over same-class neighborhoods has to do the work
    proj = rng.standard_normal((N_CLASSES, N_FEATURES))
    X = (0.5 * np.eye(N_CLASSES)[labels] @ proj
         + rng.standard_normal((N_NODES, N_FEATURES))).astype(np.float32)
    deg = adj.row_nnz()
    print(f"graph: {N_NODES} nodes, {adj.nnz} edges, max degree {int(deg.max())}, "
          f"{N_CLASSES} classes, homophily {HOMOPHILY:.0%}")

    registry = MatrixRegistry(search=False)  # .hbp_autotune/ persists runs
    trainer = NodeClassifierTrainer(
        [N_FEATURES, 32, N_CLASSES], model="gcn", registry=registry
    )

    # --- full-graph GCN ----------------------------------------------------
    state, history = trainer.fit(adj, X, labels, steps=STEPS, key=0)
    losses = [h["loss"] for h in history]
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    for h in history[:: max(1, STEPS // 5)]:
        print(f"  step {h['step']:>3}: loss {h['loss']:.4f}  "
              f"acc {h['accuracy']:.3f}  |grad| {h['grad_norm']:.3f}")
    print(f"GCN: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(first-5 mean {first:.4f}, last-5 mean {last:.4f}), "
          f"train acc {history[-1]['accuracy']:.3f}")
    assert last < first, "training loss did not decrease on average"
    assert history[-1]["accuracy"] > 1.5 / N_CLASSES, "no better than chance"

    # --- GraphSAGE mini-batches over the same registry ---------------------
    sage = NodeClassifierTrainer(
        [N_FEATURES, 32, N_CLASSES], model="sage", op="mean", registry=registry
    )
    batch_size = 150
    epoch_batches = -(-N_NODES // batch_size)
    state_s, hist_s = sage.fit_sampled(
        adj, X, labels, steps=2 * epoch_batches, batch_size=batch_size,
        fanouts=(8, 4), key=1, seed=42,
    )
    sl = [h["loss"] for h in hist_s]
    print(f"SAGE mini-batch: loss {sl[0]:.4f} -> {sl[-1]:.4f} over "
          f"{len(sl)} steps ({epoch_batches} batches x 2 epochs, "
          f"~{int(np.mean([h['batch_nodes'] for h in hist_s]))} nodes/batch)")
    batch_plans = [
        s for name, s in registry.stats().items() if s["shape"][0] < N_NODES
    ]
    readmitted = sum(1 for s in batch_plans if s["admissions"] > 1)
    print(f"registry: {len(registry)} resident plans; "
          f"{readmitted}/{len(batch_plans)} sampled subgraphs re-admitted free "
          "(content-hash hits on epoch 2)")
    assert readmitted == len(batch_plans), "epoch-2 batches should all be cache hits"
    print("OK")


if __name__ == "__main__":
    main()
