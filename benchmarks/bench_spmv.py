"""Figs. 8/10: SpMV throughput — HBP vs CSR vs plain 2D-partitioning.

Two views are reported per matrix:

* **measured** — wall time of the jitted XLA implementations on the host
  CPU (HBP tiles run the jnp oracle of the Pallas kernel; interpret-mode
  Pallas timing is meaningless).  GFLOPS = 2·nnz / t, the paper's metric.
* **projected v5e** — analytic HBM-traffic model of each format divided by
  819 GB/s: the bandwidth-bound throughput the format's byte footprint
  permits on the target hardware (SpMV is memory-bound, so bytes/nnz is
  the controlling quantity; padding waste shows up directly here).
  CSR's per-nnz random x read is charged one 64 B transaction — the
  effect the paper's Table II measures directly (0.15% mem-busy,
  2.85 GB/s effective CSR throughput vs 145 GB/s for HBP's staged
  streams).  HBP staging is modelled for BOTH kernel strategies (fused
  combine re-stages x per row-group/col-block run; the paper-faithful
  partials stages x once per column block but pays the combine pass) and
  the better one is reported — the system picks the strategy per matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PartitionConfig, build_tiles, csr_spmv_jnp, tuned_partition_config
from repro.kernels import device_tiles
from repro.kernels.ops import blocked_vector
from repro.kernels.ref import hbp_spmv_hashed_ref, unpermute

from .common import emit, load_suite, timeit

HBM_BW = 819e9  # v5e B/s


def _projected_tpu_gflops(nnz: int, bytes_moved: float) -> float:
    t = bytes_moved / HBM_BW
    return 2 * nnz / t / 1e9


def main(full: bool = False) -> None:
    cfg = PartitionConfig()  # the paper's 512 x 4096
    for name, csr in load_suite(full).items():
        x = np.random.default_rng(1).standard_normal(csr.n_cols).astype(np.float32)
        xj = jnp.asarray(x)
        nnz = csr.nnz

        # --- CSR baseline (Algorithm 1 as segment-sum)
        indptr = jnp.asarray(csr.indptr)
        indices = jnp.asarray(csr.indices)
        data = jnp.asarray(csr.data.astype(np.float32))
        csr_fn = jax.jit(lambda v: csr_spmv_jnp(indptr, indices, data, v, csr.n_rows))
        t_csr = timeit(lambda: csr_fn(xj).block_until_ready())

        # --- HBP (hash), plain 2D (no reordering), tuned-geometry HBP
        results = {}
        tuned_cfg = tuned_partition_config(csr)
        for method, label, tcfg in (
            ("hash", "hbp", cfg),
            ("none", "2d", cfg),
            ("hash", "hbp-tuned", tuned_cfg),
        ):
            tiles = build_tiles(csr, tcfg, method=method)
            dt = device_tiles(tiles)
            xb = blocked_vector(xj, cfg.col_block)
            nrg, nrows = tiles.n_rowgroups, csr.n_rows

            def run(dt=dt, xb=xb, nrg=nrg, nrows=nrows):
                y = hbp_spmv_hashed_ref(dt.rowgroup, dt.colblock, dt.data, dt.cols, xb, n_rowgroups=nrg)
                return unpermute(y, dt.perm, nrows)

            jrun = jax.jit(run)
            t = timeit(lambda: jrun().block_until_ready())
            # v5e traffic model: tiles stream (data f32 + cols i32); x
            # staging and combine depend on the kernel strategy — take the
            # better of fused (x per colblock run) vs partials (x once per
            # colblock + partial vectors written and re-read)
            tile_bytes = tiles.n_tiles * tcfg.group * tcfg.lane * 8
            switches = int(np.count_nonzero(np.diff(tiles.colblock)) + 1)
            n_cb = -(-csr.n_cols // tcfg.col_block)
            y_bytes = tiles.padded_rows() * 4
            fused = tile_bytes + switches * tcfg.col_block * 4 + y_bytes
            partials = (tile_bytes + n_cb * tcfg.col_block * 4
                        + tiles.n_tiles * tcfg.group * 8 + y_bytes)
            results[label] = (t, min(fused, partials))

        # data+col streams + one 64B transaction per random x read + ptr+y
        csr_bytes = nnz * 12 + nnz * 64 + csr.n_rows * 12

        def g(t):
            return 2 * nnz / t / 1e9
        t_hbp, hbp_bytes = results["hbp"]
        t_2d, d2_bytes = results["2d"]
        t_tuned, tuned_bytes = results["hbp-tuned"]
        emit(
            f"spmv/{name}",
            t_hbp,
            f"measured GFLOPS csr={g(t_csr):.2f} 2d={g(t_2d):.2f} hbp={g(t_hbp):.2f} "
            f"tuned={g(t_tuned):.2f} "
            f"speedup_vs_csr={t_csr/t_hbp:.2f}x speedup_vs_2d={t_2d/t_hbp:.2f}x | "
            f"projected-v5e GFLOPS csr={_projected_tpu_gflops(nnz, csr_bytes):.1f} "
            f"2d={_projected_tpu_gflops(nnz, d2_bytes):.1f} "
            f"hbp={_projected_tpu_gflops(nnz, hbp_bytes):.1f} "
            f"tuned={_projected_tpu_gflops(nnz, tuned_bytes):.1f} (beyond-paper)",
        )


if __name__ == "__main__":
    main()
