"""Serving-traffic benchmark: micro-batched engine vs sequential SpMV.

Drives the `repro.serving` stack with a synthetic open-loop arrival trace
(Poisson arrivals on a virtual clock, independent of service progress —
the standard serving-benchmark methodology) and reports

* ``seq_req_per_s``     — one SpMV launch per request, the unbatched
  baseline every request would pay on its own;
* ``batched_req_per_s`` — the engine's throughput: requests coalesced into
  k-bucketed SpMM launches (one tile-stream pass per batch);
* ``speedup``           — the ratio, the amortization the ROADMAP promised
  from the multi-RHS kernel (~5x at k=8 in bench_solvers);
* ``mean_batch_k`` / ``occupancy`` / ``pad_fraction`` — how full the
  coalescing window ran, from the engine's own instrumentation.

Timing uses the registry's default strategy — off-TPU that is the
batch-width-invariant jnp path (the Pallas kernels would execute in
interpret mode, whose timings are meaningless).  Both sides of the
comparison run the same strategy, so the ratio is the batching effect
alone.
"""
from __future__ import annotations

import time

import numpy as np

from repro.serving import MatrixRegistry, ServingEngine

from .common import emit, load_suite


def open_loop_trace(n_req: int, rate_per_s: float, seed: int = 0) -> np.ndarray:
    """Arrival times of a Poisson process with the given rate (virtual s)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_per_s, size=n_req))


def drive(engine: ServingEngine, key: str, xs, arrivals, vclock) -> float:
    """Replay the trace against the engine; returns compute seconds."""
    t0 = time.perf_counter()
    for x, t_arr in zip(xs, arrivals):
        vclock[0] = t_arr
        engine.submit(key, x)
        engine.poll()
    vclock[0] = arrivals[-1] + engine.batcher.max_wait_s
    engine.poll()
    engine.flush()
    return time.perf_counter() - t0


def main(full: bool = False) -> None:
    n_req = 512 if full else 128
    for name, csr in load_suite(full).items():
        reg = MatrixRegistry(search=False, cache_dir=".hbp_autotune")
        plan = reg.admit(csr, name)
        rng = np.random.default_rng(1)
        xs = [rng.standard_normal(csr.n_cols).astype(np.float32) for _ in range(n_req)]

        # sequential baseline: every request pays its own SpMV launch
        plan.matvec(xs[0]).block_until_ready()  # compile
        t0 = time.perf_counter()
        for x in xs:
            plan.matvec(x).block_until_ready()
        t_seq = time.perf_counter() - t0

        # batched engine on an open-loop trace: the arrival rate is set so
        # ~2 full windows of requests land per max_wait, i.e. the engine
        # runs at high occupancy — the regime batching is built for
        vclock = [0.0]
        eng = ServingEngine(reg, max_wait_s=0.002, clock=lambda: vclock[0])
        rate = 2 * eng.batcher.max_batch / eng.batcher.max_wait_s
        arrivals = open_loop_trace(n_req, rate)
        # warm the per-bucket compiles outside the clock
        for k in (1, 2, 4, 8, 16):
            plan.matmat(np.zeros((csr.n_cols, k), np.float32)).block_until_ready()
        t_batched = drive(eng, name, xs, arrivals, vclock)

        s = eng.stats()[name]
        assert s["requests"] == n_req
        emit(
            f"traffic/{name}",
            t_batched / n_req,
            f"seq_req_per_s={n_req / t_seq:.1f} "
            f"batched_req_per_s={n_req / t_batched:.1f} "
            f"speedup={t_seq / t_batched:.2f}x "
            f"mean_batch_k={s['mean_batch_k']:.1f} "
            f"occupancy={s['occupancy']:.2f} pad_fraction={s['pad_fraction']:.2f} "
            f"p99_wait_ms={1e3 * s['latency_p99_s']:.2f}(virtual)",
        )


if __name__ == "__main__":
    main()
