"""Serving-traffic benchmark: micro-batched engine vs sequential SpMV.

Drives the `repro.serving` stack with a synthetic open-loop arrival trace
(Poisson arrivals on a virtual clock, independent of service progress —
the standard serving-benchmark methodology) and reports

* ``seq_req_per_s``     — one SpMV launch per request, the unbatched
  baseline every request would pay on its own;
* ``batched_req_per_s`` — the engine's throughput: requests coalesced into
  k-bucketed SpMM launches (one tile-stream pass per batch);
* ``speedup``           — the ratio, the amortization the ROADMAP promised
  from the multi-RHS kernel (~5x at k=8 in bench_solvers);
* ``mean_batch_k`` / ``occupancy`` / ``pad_fraction`` — how full the
  coalescing window ran, from the engine's own instrumentation.

Timing uses the registry's default strategy — off-TPU that is the
batch-width-invariant jnp path (the Pallas kernels would execute in
interpret mode, whose timings are meaningless).  Both sides of the
comparison run the same strategy, so the ratio is the batching effect
alone.

The **multi-tenant overload** section drives two tenants (a weight-4
``gold`` class and a shed-eligible ``best_effort`` class) plus a cold
third matrix through one engine under open-loop load beyond service
capacity, comparing async-overlap dispatch against the synchronous
baseline.  Reported per mode: per-tenant p99 and goodput, best-effort
sheds (typed :class:`~repro.serving.qos.BackpressureError`, never a
silent drop), the flight-recorder dump the first shed triggered, the
``evict.*`` restage counters the HBM budget forced, and the scrapeable
``qos.*``/``evict.*`` OpenMetrics families.
"""
from __future__ import annotations

import time

import numpy as np

from repro.obs.export import render_openmetrics
from repro.obs.flight import FlightRecorder
from repro.serving import (
    BackpressureError,
    MatrixRegistry,
    QoSClass,
    ServingEngine,
    plan_device_bytes,
)

from .common import emit, load_suite


def open_loop_trace(n_req: int, rate_per_s: float, seed: int = 0) -> np.ndarray:
    """Arrival times of a Poisson process with the given rate (virtual s)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_per_s, size=n_req))


def drive(engine: ServingEngine, key: str, xs, arrivals, vclock) -> float:
    """Replay the trace against the engine; returns compute seconds."""
    t0 = time.perf_counter()
    for x, t_arr in zip(xs, arrivals):
        vclock[0] = t_arr
        engine.submit(key, x)
        engine.poll()
    vclock[0] = arrivals[-1] + engine.batcher.max_wait_s
    engine.poll()
    engine.flush()
    return time.perf_counter() - t0


def main(full: bool = False) -> None:
    n_req = 512 if full else 128
    for name, csr in load_suite(full).items():
        reg = MatrixRegistry(search=False, cache_dir=".hbp_autotune")
        plan = reg.admit(csr, name)
        rng = np.random.default_rng(1)
        xs = [rng.standard_normal(csr.n_cols).astype(np.float32) for _ in range(n_req)]

        # sequential baseline: every request pays its own SpMV launch
        plan.matvec(xs[0]).block_until_ready()  # compile
        t0 = time.perf_counter()
        for x in xs:
            plan.matvec(x).block_until_ready()
        t_seq = time.perf_counter() - t0

        # batched engine on an open-loop trace: the arrival rate is set so
        # ~2 full windows of requests land per max_wait, i.e. the engine
        # runs at high occupancy — the regime batching is built for
        vclock = [0.0]
        eng = ServingEngine(reg, max_wait_s=0.002, clock=lambda: vclock[0])
        rate = 2 * eng.batcher.max_batch / eng.batcher.max_wait_s
        arrivals = open_loop_trace(n_req, rate)
        # warm the per-bucket compiles outside the clock
        for k in (1, 2, 4, 8, 16):
            plan.matmat(np.zeros((csr.n_cols, k), np.float32)).block_until_ready()
        t_batched = drive(eng, name, xs, arrivals, vclock)

        s = eng.stats()[name]
        assert s["requests"] == n_req
        emit(
            f"traffic/{name}",
            t_batched / n_req,
            f"seq_req_per_s={n_req / t_seq:.1f} "
            f"batched_req_per_s={n_req / t_batched:.1f} "
            f"speedup={t_seq / t_batched:.2f}x "
            f"mean_batch_k={s['mean_batch_k']:.1f} "
            f"occupancy={s['occupancy']:.2f} pad_fraction={s['pad_fraction']:.2f} "
            f"p99_wait_ms={1e3 * s['latency_p99_s']:.2f}(virtual)",
        )


def _synth_csr(n: int, m: int, density: float, seed: int):
    """Distinct-content random CSR (its own tenant under content hashing)."""
    from repro.core.formats import csr_from_dense

    rng = np.random.default_rng(seed)
    dense = (rng.random((n, m)) < density) * rng.standard_normal((n, m))
    return csr_from_dense(dense.astype(np.float32))


def _drive_overload(overlap: bool, n_rounds: int, dump_dir: str) -> dict:
    """One overload run (fresh registry + engine); returns the report row.

    Open-loop on the real clock: every round submits one gold request and
    a ten-deep best-effort burst back-to-back without waiting for service.
    The burst exceeds the best-effort ``max_queue`` (8), so its tail sheds
    every round regardless of service speed — offered best-effort load is
    beyond admitted capacity by construction, the admission-control
    regime — while the gold tenant (no queue cap, weight 4) rides through
    untouched.
    """
    n, m = 256, 256
    gold_csr = _synth_csr(n, m, 0.05, seed=11)
    be_csr = _synth_csr(n, m, 0.05, seed=22)
    cold_csr = _synth_csr(n, m, 0.05, seed=33)

    reg = MatrixRegistry(search=False, cache_dir=".hbp_autotune")
    gold_plan = reg.admit(gold_csr, "gold_tenant")
    # budget fits the two serving tenants but not the cold third: admitting
    # it mid-run unstages the LRU tenant, and the next request transparently
    # re-stages it — the evict.* counters the report surfaces
    budget = int(2.25 * plan_device_bytes(gold_plan.tiles))
    reg2 = MatrixRegistry(
        search=False, cache_dir=".hbp_autotune", hbm_budget_bytes=budget
    )
    reg2.admit(gold_csr, "gold_tenant")
    reg2.admit(be_csr, "be_tenant")
    # the cold third tenant overflows the budget at admission and unstages
    # the LRU serving tenant — the first request against that tenant inside
    # the measured loop transparently re-stages it (evict.restages), keeping
    # the expensive preprocessing OUT of the latency-measured window
    reg2.admit(cold_csr, "cold_tenant")

    flight = FlightRecorder(dump_dir=dump_dir)
    eng = ServingEngine(
        reg2,
        max_wait_s=0.0005,
        overlap=overlap,
        flight=flight,
        qos={
            "gold_tenant": QoSClass("gold", deadline_s=0.05, weight=4.0),
            "be_tenant": QoSClass(
                "best_effort", deadline_s=0.5, weight=0.25, max_queue=8
            ),
        },
    )
    rng = np.random.default_rng(2)
    xs = [rng.standard_normal(m).astype(np.float32) for _ in range(4)]
    # warm the bucket compiles outside the measured window
    for k in (1, 2, 4, 8):
        gold_plan.matmat(np.zeros((m, k), np.float32)).block_until_ready()

    import glob
    import os

    t_start = time.time()
    shed = 0
    submitted = {"gold_tenant": 0, "be_tenant": 0}
    t0 = time.perf_counter()
    for i in range(n_rounds):
        for key, count in (("gold_tenant", 1), ("be_tenant", 10)):
            for j in range(count):
                try:
                    eng.submit(key, xs[(i + j) % len(xs)])
                    submitted[key] += 1
                except BackpressureError:
                    shed += 1
        eng.poll()
    eng.flush()
    wall = time.perf_counter() - t0
    # the trigger inside the first shedding submit wrote the post-mortem;
    # surface this run's artifact (mtime-filtered: reruns overwrite the
    # same flight_load_shed_0.json path, so a path diff would miss it)
    new_dumps = sorted(
        p
        for p in glob.glob(os.path.join(dump_dir, "flight_load_shed_*.json"))
        if os.path.getmtime(p) >= t_start
    )
    first_dump = new_dumps[0] if new_dumps else None

    stats = eng.stats()
    m2 = reg2.metrics
    restages = sum(
        m2.value("evict.restages", matrix=k)
        for k in ("gold_tenant", "be_tenant", "cold_tenant")
    )
    completed = sum(submitted.values())
    return {
        "mode": "overlap" if overlap else "sync",
        "wall_s": wall,
        "goodput_req_per_s": completed / wall,
        "gold_p99_s": stats["gold_tenant"]["latency_p99_s"],
        "be_p99_s": stats["be_tenant"]["latency_p99_s"],
        "gold_deadline_s": stats["gold_tenant"]["deadline_s"],
        "shed": shed,
        "shed_counter": int(
            m2.value("qos.shed", matrix="be_tenant", qos="best_effort")
        ),
        "restages": int(restages),
        "first_shed_dump": first_dump,
        "metrics_registry": m2,
    }


def multi_tenant_overload(full: bool = False) -> None:
    """Overload comparison: async-overlap dispatch vs synchronous baseline."""
    n_rounds = 400 if full else 120
    # one small untimed pass first: tile-build helpers, bucket compiles and
    # admission caches all warm up here, so the measured sync-vs-overlap
    # comparison is not confounded by whichever mode happens to run first
    _drive_overload(False, max(n_rounds // 8, 16), dump_dir=".flight_dumps/warmup")
    # median of three interleaved repetitions per mode: single CPU-backend
    # runs swing tens of percent under host contention, and a one-shot
    # comparison would report that noise as a mode effect
    reps = 3
    rows = []
    for overlap in (False, True):
        runs = [
            _drive_overload(
                overlap,
                n_rounds,
                # per-mode dirs: each run's fresh recorder restarts its dump
                # sequence, so a shared dir would collide on the filename
                dump_dir=f".flight_dumps/overload_{'overlap' if overlap else 'sync'}",
            )
            for _ in range(reps)
        ]
        rows.append(sorted(runs, key=lambda r: r["wall_s"])[reps // 2])
    for r in rows:
        emit(
            f"traffic/overload_{r['mode']}",
            r["wall_s"] / n_rounds,
            f"goodput={r['goodput_req_per_s']:.1f}req/s "
            f"gold_p99_ms={1e3 * r['gold_p99_s']:.2f} "
            f"(deadline {1e3 * r['gold_deadline_s']:.0f}ms) "
            f"be_p99_ms={1e3 * r['be_p99_s']:.2f} "
            f"shed={r['shed']} (counter {r['shed_counter']}) "
            f"restages={r['restages']} "
            f"first_shed_dump={r['first_shed_dump']}",
        )
    sync, ov = rows
    emit(
        "traffic/overlap_vs_sync",
        ov["wall_s"] / max(sync["wall_s"], 1e-12),
        f"goodput_ratio={ov['goodput_req_per_s'] / sync['goodput_req_per_s']:.2f}x "
        "(overlap/sync)",
    )
    # the scrapeable families the OpenMetrics endpoint would serve — proof
    # the new scheduler state rides the ordinary exporter path
    text = render_openmetrics([ov["metrics_registry"]])
    families = sorted(
        {
            line.split()[2]
            for line in text.splitlines()
            if line.startswith("# TYPE") and line.split()[2].startswith(("qos_", "evict_"))
        }
    )
    print(f"openmetrics qos/evict families: {', '.join(families)}")


if __name__ == "__main__":
    main()
    multi_tenant_overload()
