"""Observability overhead: what does instrumentation cost when it's on/off?

The obs layer promises "off by default, free when off".  This bench holds
it to that with three measurements:

* ``obs/serve_disabled`` vs ``obs/serve_enabled`` — the serving hot loop
  (submit → poll → flush over an admitted suite matrix) timed with the
  gated instrumentation compiled out vs fully live (spans + counters +
  gauges + histograms).  ``overhead`` in the derived column is the
  enabled/disabled median ratio; the CI smoke gate runs the *disabled*
  configuration against ``baseline.json``, so any cost on the default
  path fails the existing regression pipeline.
* ``obs/counter`` / ``obs/span`` — per-op microcosts of one labelled
  counter increment and one empty span, enabled and disabled, so a
  regression in the primitives is visible before it shows up in the
  engine numbers.
* ``obs/flight_record`` — per-op cost of one always-on flight-recorder
  ring write: unlike the gated primitives this path has no off state, so
  its microcost IS the serving hot loop's telemetry floor.
* ``obs/request_context`` — per-op cost of the always-on request-trace
  path (mint a context, stamp its lifecycle, complete it into the
  bounded log): the per-request tax every submit pays, gated by the same
  regression pipeline as the flight ring.
* ``obs/openmetrics_render`` — one full OpenMetrics exposition render of
  a populated registry: the per-scrape cost a Prometheus endpoint pays
  (off the serving hot path, but a runaway here would starve a scraper).
* ``obs/plan_quality`` — per-admission cost of the partition-quality
  introspection (:func:`repro.obs.planview.partition_quality`: occupancy
  stats, LPT competitive-ratio replay, hash-group cohesion vs a random
  baseline).  Unlike the ratio-gated benches this one carries a pinned
  absolute budget: exceeding it raises, failing the whole bench run.

All timings restore the obs enable state they found, and the registries
are reset afterwards so a ``--trace`` run's artifact is not polluted by
benchmark-loop spans.

Set ``REPRO_OBS_DUMP=PATH`` to write the full obs snapshot (including the
serving engines' ``attr.*`` bandwidth-attribution counters) before the
benchmark's registries go out of scope — the input
``python -m repro.analysis.report --attribution PATH`` renders.
"""
from __future__ import annotations

import os

import numpy as np

from repro import obs
from repro.serving import MatrixRegistry, ServingEngine

from .common import emit, load_suite, timeit

_MICRO_OPS = 10_000

# per-admission ceiling for the partition-quality introspection bench:
# far above the measured cost (single-digit ms on the smoke suite) but low
# enough that an accidental Python-loop rewrite of the metrics trips it
_PLAN_QUALITY_BUDGET_MS = 250.0


def _serve_cycle(engine: ServingEngine, key: str, xs, vclock) -> None:
    """One hot-loop pass: every request submitted, coalesced, flushed."""
    for i, x in enumerate(xs):
        vclock[0] = 1e-5 * i
        engine.submit(key, x)
        engine.poll()
    vclock[0] = 1e-5 * len(xs) + engine.batcher.max_wait_s
    engine.poll()
    engine.flush()


def _time_serving(csr, name: str, n_req: int, repeats: int, keep: list):
    reg = MatrixRegistry(search=False, cache_dir=".hbp_autotune")
    plan = reg.admit(csr, name)
    rng = np.random.default_rng(2)
    xs = [rng.standard_normal(csr.n_cols).astype(np.float32) for _ in range(n_req)]
    # warm per-bucket compiles outside the timed region
    for k in (1, 2, 4, 8, 16):
        plan.matmat(np.zeros((csr.n_cols, k), np.float32)).block_until_ready()
    vclock = [0.0]
    eng = ServingEngine(reg, max_wait_s=0.002, clock=lambda: vclock[0])
    # metric registries are weakly aggregated — keep the MatrixRegistry
    # alive so a REPRO_OBS_DUMP snapshot still sees its attr.* counters
    keep.append(reg)
    return timeit(lambda: _serve_cycle(eng, name, xs, vclock), repeats=repeats)


def _with_obs(flag: bool, fn):
    was = obs.enabled()
    (obs.enable if flag else obs.disable)()
    try:
        return fn()
    finally:
        (obs.enable if was else obs.disable)()


def _micro_counter() -> None:
    c = obs.counter("bench.obs_micro", site="counter")
    for _ in range(_MICRO_OPS):
        c.inc()


def _micro_span() -> None:
    for _ in range(_MICRO_OPS):
        with obs.span("bench.obs_micro_span"):
            pass


def _micro_flight() -> None:
    fl = obs.flight()
    for _ in range(_MICRO_OPS):
        fl.record("bench.flight_micro")


def _micro_request_context() -> None:
    from repro.obs.requesttrace import RequestLog, new_context

    log = RequestLog()
    for i in range(_MICRO_OPS):
        ctx = new_context("bench", 0.0)  # the one per-request allocation
        ctx.t_enqueue = 1e-6
        ctx.t_flush_start = 2e-6
        ctx.t_dispatch = 3e-6
        ctx.t_complete = 4e-6
        ctx.compute_s = 1e-6
        ctx.batch_share = 0.125
        ctx.batch_k = 8
        ctx.flush_reason = "size"
        ctx.deadline_hit = True
        log.complete(ctx)


def _render_registry():
    """A populated standalone registry sized like a busy serving ledger."""
    from repro.obs.metrics import MetricRegistry

    reg = MetricRegistry(name="bench-render")
    rng = np.random.default_rng(3)
    for m in ("A", "B", "C", "D"):
        reg.counter("serving.requests", matrix=m).inc(1000)
        reg.gauge("serving.queue_depth", matrix=m).set(4)
        h = reg.histogram("serving.latency_s", matrix=m)
        for i, v in enumerate(rng.uniform(1e-5, 1e-2, 256)):
            h.observe(float(v), exemplar=f"rb-{i:x}")
    return reg


def main(full: bool = False) -> None:
    n_req = 256 if full else 64
    repeats = 7 if full else 5
    name, csr = next(iter(load_suite(False).items()))  # smallest suite matrix

    keep: list = []
    t_off = _with_obs(False, lambda: _time_serving(csr, name, n_req, repeats, keep))
    t_on = _with_obs(True, lambda: _time_serving(csr, name, n_req, repeats, keep))
    overhead = t_on.stats["median_us"] / t_off.stats["median_us"]
    emit(
        f"obs/serve_disabled/{name}",
        t_off,
        f"req_per_s={n_req / float(t_off):.1f}",
        config={"n_req": n_req},
    )
    emit(
        f"obs/serve_enabled/{name}",
        t_on,
        f"req_per_s={n_req / float(t_on):.1f} overhead={overhead:.3f}x",
        config={"n_req": n_req},
    )

    for site, fn in (("counter", _micro_counter), ("span", _micro_span)):
        for flag in (False, True):
            t = _with_obs(flag, lambda: timeit(fn, repeats=repeats))
            state = "enabled" if flag else "disabled"
            emit(
                f"obs/{site}_{state}",
                float(t) / _MICRO_OPS,
                f"ns_per_op={1e9 * float(t) / _MICRO_OPS:.0f}",
                config={"ops": _MICRO_OPS},
            )

    # the flight recorder has no disabled state — one bench, always on
    t = timeit(_micro_flight, repeats=repeats)
    emit(
        "obs/flight_record",
        float(t) / _MICRO_OPS,
        f"ns_per_op={1e9 * float(t) / _MICRO_OPS:.0f}",
        config={"ops": _MICRO_OPS},
    )

    # the request-trace path is always on too: mint + stamp + complete
    t = timeit(_micro_request_context, repeats=repeats)
    emit(
        "obs/request_context",
        float(t) / _MICRO_OPS,
        f"ns_per_op={1e9 * float(t) / _MICRO_OPS:.0f}",
        config={"ops": _MICRO_OPS},
    )

    # per-scrape cost of the OpenMetrics exposition render
    from repro.obs.export import render_openmetrics

    render_reg = _render_registry()
    t = timeit(lambda: render_openmetrics([render_reg]), repeats=repeats)
    emit(
        "obs/openmetrics_render",
        t,
        f"us_per_scrape={t.stats['median_us']:.0f}",
        config={"matrices": 4, "hist_samples": 256},
    )
    del render_reg

    # admission-time introspection: partition_quality (occupancy stats +
    # LPT competitive-ratio replay + hash-group cohesion vs the random
    # baseline) runs once per admit, so its cost IS the explain feature's
    # overhead.  Pinned to a generous absolute budget: blowing it means
    # the introspection stopped being vectorised, and admission latency
    # regressed for every caller — fail the bench run outright.
    from repro.obs.planview import partition_quality

    plan = keep[0].get(name)
    t = timeit(lambda: partition_quality(plan.tiles, csr), repeats=repeats)
    emit(
        f"obs/plan_quality/{name}",
        t,
        f"ms_per_admission={1e3 * float(t):.2f} tiles={plan.tiles.n_tiles}",
        config={"tiles": plan.tiles.n_tiles, "budget_ms": _PLAN_QUALITY_BUDGET_MS},
    )
    if t.stats["median_us"] > _PLAN_QUALITY_BUDGET_MS * 1e3:
        raise RuntimeError(
            f"partition_quality took {t.stats['median_us'] / 1e3:.1f}ms per "
            f"admission on {name} — over the {_PLAN_QUALITY_BUDGET_MS:.0f}ms "
            "budget; the admission-introspection path must stay vectorised"
        )

    # snapshot before the registries in `keep` go out of scope (their
    # MetricRegistry instances are weakly aggregated into the dump)
    dump_path = os.environ.get("REPRO_OBS_DUMP")
    if dump_path:
        obs.dump(dump_path)
        print(f"# obs snapshot -> {dump_path}")
    del keep

    # don't leak benchmark-loop metrics/spans into a --trace artifact
    if not obs.enabled():
        obs.reset()


if __name__ == "__main__":
    main()
