"""§Roofline: tabulate the dry-run artifacts (experiments/dryrun/*.json).

This benchmark does not lower anything itself — it renders the roofline
table (three terms, dominant bottleneck, MODEL_FLOPS ratio) from the
recorded dry-run sweep, so ``python -m benchmarks.run`` stays fast.
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit

DRYRUN_DIR = Path("experiments/dryrun")


def main(full: bool = False) -> None:
    if not DRYRUN_DIR.exists():
        emit("roofline/none", 0.0, "no dry-run artifacts; run repro.launch.dryrun --all")
        return
    for path in sorted(DRYRUN_DIR.glob("*__single.json")):
        rec = json.loads(path.read_text())
        cell = f"{rec['arch']}×{rec['shape']}"
        if rec.get("status") == "skipped":
            emit(f"roofline/{cell}", 0.0, "skipped: " + rec["reason"][:80])
            continue
        if rec.get("status") != "ok" or "roofline" not in rec:
            emit(f"roofline/{cell}", 0.0, f"status={rec.get('status')}")
            continue
        r = rec["roofline"]
        t_bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(
            f"roofline/{cell}",
            t_bound,
            f"compute={r['t_compute_s']:.3f}s memory={r['t_memory_s']:.3f}s "
            f"collective={r['t_collective_s']:.3f}s bottleneck={r['bottleneck']} "
            f"useful_flops_ratio={rec.get('model_flops_ratio', 0) or 0:.2f} "
            f"fits={rec.get('fits_hbm')}",
        )


if __name__ == "__main__":
    main()
