"""§III-C: block-level load balance — contiguous vs mixed (fixed+competitive)
vs pure LPT, on the suite's real per-block tile counts."""
from __future__ import annotations

import numpy as np

from repro.core import Partition2D, PartitionConfig, contiguous_schedule, lpt_schedule, mixed_schedule

from .common import emit, load_suite, timeit


def main(full: bool = False) -> None:
    cfg = PartitionConfig()
    for name, csr in load_suite(full).items():
        part = Partition2D.build(csr, cfg)
        costs = part.block_nnz().reshape(-1).astype(np.float64)
        costs = costs[costs > 0]
        n_workers = 256  # one matrix block per core slot
        t = timeit(lambda: mixed_schedule(costs, n_workers, n_cols=part.grid[1]), repeats=3)
        r_cont = contiguous_schedule(costs, n_workers).makespan_ratio
        r_mix = mixed_schedule(costs, n_workers, n_cols=part.grid[1]).makespan_ratio
        r_lpt = lpt_schedule(costs, n_workers).makespan_ratio
        emit(
            f"schedule/{name}",
            t,
            f"makespan_ratio contiguous={r_cont:.2f} mixed={r_mix:.2f} lpt={r_lpt:.2f} "
            f"blocks={costs.size}",
        )


if __name__ == "__main__":
    main()
