"""Benchmark regression gate: compare a --json run against a baseline.

CI runs the smoke benches (``benchmarks.run --only preprocess,spmm --json
BENCH_ci.json``) and gates merges on

    python -m benchmarks.compare BENCH_ci.json \
        --baseline benchmarks/baseline.json --threshold 0.25

A record regresses when its gate metric exceeds the baseline's by more
than ``threshold`` (fractional).  The gate metric is ``min_us`` (the
min-of-N floor, robust to machine-load noise) when both sides carry it,
else ``median_us``.  Records present on only one side are
reported but never fail the gate — new benches enter the baseline on the
next refresh (see README "Benchmarking & regression gates"), and retired
ones leave it.  Exit status: 0 clean, 1 regression(s).

Improvements beyond the threshold are reported (``IMPROVE`` lines, never
failing) — a baseline that is >25% slower than reality masks an equally
large later regression, so the gate nags until someone refreshes it:

    python -m benchmarks.compare BENCH_ci.json \
        --baseline benchmarks/baseline.json --update

``--update`` rewrites the baseline from the current run (gated prefixes
only, when ``--prefix`` is given); records present only in the old
baseline are kept, so a partial run never silently drops gate coverage.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_records(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    records = payload["benches"] if isinstance(payload, dict) else payload
    out = {}
    for rec in records:
        name = rec["name"]
        if name in out:
            # a bench emitted the same name twice — never silently drop a
            # sample from the gate: keep the slower record (conservative)
            # and say so
            prev = out[name]
            metric = "min_us" if ("min_us" in prev and "min_us" in rec) else "median_us"
            keep = rec if rec[metric] >= prev[metric] else prev
            print(f"WARN {path}: duplicate record {name!r}; keeping the slower one")
            out[name] = keep
        else:
            out[name] = rec
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="JSON written by benchmarks.run --json")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="allowed fractional slowdown vs baseline (default 0.25 = +25%%)",
    )
    ap.add_argument(
        "--prefix",
        default=None,
        help="only gate records whose name starts with one of these "
        "comma-separated prefixes (default: every shared record)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baseline from the current run instead of gating "
        "(gated prefixes only; baseline-only records are kept)",
    )
    ap.add_argument(
        "--diff-out",
        default=None,
        metavar="PATH",
        help="on gate failure, write a markdown culprit report (ranked "
        "per-record deltas + per-phase rollup) naming the regressed phase",
    )
    args = ap.parse_args(argv)

    cur = load_records(args.current)
    base = load_records(args.baseline)
    prefixes = (
        tuple(p.strip() for p in args.prefix.split(",") if p.strip())
        if args.prefix
        else None
    )

    def gated(name: str) -> bool:
        return prefixes is None or name.startswith(prefixes)

    if args.update:
        merged = dict(base)  # baseline-only records survive a partial run
        refreshed = 0
        for name, rec in cur.items():
            if not gated(name):
                continue
            merged[name] = rec
            refreshed += 1
        payload = {
            "schema": 1,
            "benches": [merged[name] for name in sorted(merged)],
        }
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        kept = len(merged) - refreshed
        print(
            f"baseline {args.baseline} updated: {refreshed} record(s) "
            f"refreshed from {args.current}, {kept} kept from the old baseline"
        )
        return 0

    regressions, improved, refresh_worthy, skipped = [], [], [], []
    for name in sorted(set(cur) | set(base)):
        if not gated(name):
            continue
        if name not in base:
            skipped.append((name, "not in baseline (new bench?)"))
            continue
        if name not in cur:
            skipped.append((name, "not in current run"))
            continue
        rb, rc = base[name], cur[name]
        metric = "min_us" if ("min_us" in rb and "min_us" in rc) else "median_us"
        b, c = rb[metric], rc[metric]
        if b <= 0:  # analytic/zero-cost rows carry no timing signal
            skipped.append((name, "baseline has no timing"))
            continue
        ratio = c / b
        line = f"{name}: {b:.1f}us -> {c:.1f}us ({ratio:.2f}x {metric})"
        if ratio > 1.0 + args.threshold:
            regressions.append(line)
        elif ratio < 1.0 - args.threshold:
            refresh_worthy.append(line)
        elif ratio < 1.0:
            improved.append(line)

    for name, why in skipped:
        print(f"SKIP {name}: {why}")
    for line in improved:
        print(f"OK   {line}")
    if refresh_worthy:
        # never a failure — but a stale-slow baseline masks an equally
        # large later regression, so say so until someone refreshes it
        for line in refresh_worthy:
            print(f"IMPROVE {line}")
        print(
            f"\n{len(refresh_worthy)} record(s) improved past the "
            f"{args.threshold:.0%} threshold — the baseline is stale; "
            "refresh it with --update so the gate keeps its teeth"
        )
    if regressions:
        print(
            f"\n{len(regressions)} regression(s) past the "
            f"+{args.threshold:.0%} gate:",
            file=sys.stderr,
        )
        for line in regressions:
            print(f"FAIL {line}", file=sys.stderr)
        if args.diff_out:
            _write_diff_report(args.diff_out, base, cur, gated)
        return 1
    print(f"\ngate clean (threshold +{args.threshold:.0%})")
    return 0


def _write_diff_report(path: str, base: dict, cur: dict, gated) -> None:
    """Leave the ranked culprit report next to the failed gate (CI uploads
    it alongside BENCH_ci.json so the failure names the regressed phase
    without a local repro)."""
    import os

    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    )
    from repro.analysis.diff import _phase_table, _rank, diff_bench_records, render_markdown

    rows = _rank(
        [r for r in diff_bench_records(base, cur) if gated(r["name"])]
    )
    culprit = next((r for r in rows if (r["excess"] or 0) > 0 and r["a"]), None)
    result = {
        "kind": "bench",
        "unit": "us",
        "rows": rows,
        "phases": _phase_table(rows),
        "culprit": culprit,
    }
    with open(path, "w") as f:
        f.write(render_markdown(result, title="Bench gate failure: baseline vs current"))
    print(f"culprit report written to {path}", file=sys.stderr)


if __name__ == "__main__":
    sys.exit(main())
