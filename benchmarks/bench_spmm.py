"""One-pass kernel grid smoke: 2D k-tiled SpMM + paired-payload argmax.

The two numbers the CI regression gate watches (with bench_preprocess):

* ``spmm/serve_k256`` — a wide-feature SpMM launch on the serving path
  (``strategy="stable"``), the steady-state cost every GNN layer and
  coalesced request block pays;
* ``spmm/argmax_onepass`` — the max-aggregation forward with winner
  tracking.  Its derived column also reports the structural tile-stream
  traversal count of the one-pass paired-payload recovery vs the legacy
  three-monoid-pass oracle (1 vs 3, counted by ``ref.count_traversals``)
  and asserts the one-pass form stays ≤ 1.

``k_tiling="grid"`` vs ``"loop"`` is compared on the ``"reference"``
strategy, where the two contracts genuinely differ off-TPU (one
full-width traversal vs ceil(k/128) chunked ones); ``--full`` sweeps all
four kernel strategies (the Pallas pair in interpret mode, on a smaller
matrix — interpret timings are correctness smoke, not performance).
"""
from __future__ import annotations

import numpy as np

from repro.core import PartitionConfig, build_tiles
from repro.core.matrices import rmat
from repro.kernels import ops, ref

from .common import emit, timeit

K_WIDE = 256


def _setup(n: int, nnz_target: int, cfg: PartitionConfig, k: int, seed: int = 0):
    csr = rmat(n, nnz_target, seed=seed)
    tiles = build_tiles(csr, cfg)
    dt = ops.device_tiles(tiles)
    x = np.random.default_rng(seed).standard_normal((csr.n_cols, k)).astype(np.float32)
    meta = dict(
        n_rowgroups=tiles.n_rowgroups,
        n_rows=tiles.shape[0],
        col_block=cfg.col_block,
    )
    return csr, tiles, dt, x, meta


def _traversal_counts(dt, x, col_block, n_rowgroups):
    """Structural tile-stream traversals of each argmax form (eager refs)."""
    import jax.numpy as jnp

    xb = ops.blocked_matrix(jnp.asarray(x[:, :8]), col_block)
    with ref.count_traversals() as one:
        ref.hbp_spmm_hashed_argmax_onepass(
            dt.rowgroup, dt.colblock, dt.data, dt.cols, xb, n_rowgroups=n_rowgroups
        )
    with ref.count_traversals() as three:
        ref.hbp_spmm_hashed_argmax(
            dt.rowgroup, dt.colblock, dt.data, dt.cols, xb, n_rowgroups=n_rowgroups
        )
    return one[0], three[0]


def main(full: bool = False) -> None:
    cfg = PartitionConfig(row_block=256, col_block=512, group=8, lane=16)
    csr, tiles, dt, x, meta = _setup(1 << 11, 16_000, cfg, K_WIDE)
    nnz = csr.nnz

    # --- the serving-path SpMM number the regression gate tracks
    t = timeit(
        lambda: ops.hbp_spmm(dt, x, strategy="stable", **meta),
        repeats=9, warmup=2,
    )
    emit(
        "spmm/serve_k256",
        t,
        f"stable k={K_WIDE} {2 * nnz * K_WIDE / t / 1e9:.2f}Gmul/s",
        config={"n": csr.n_rows, "nnz": nnz, "k": K_WIDE, "strategy": "stable"},
    )

    # --- one-pass 2D-grid contract vs the legacy chunk loop (jnp oracle)
    for k_tiling in ops.K_TILINGS:
        t = timeit(
            lambda kt=k_tiling: ops.hbp_spmm(
                dt, x, strategy="reference", k_tiling=kt, **meta
            ),
            repeats=9, warmup=2,
        )
        emit(
            f"spmm/reference_k256_{k_tiling}",
            t,
            f"tile stream read {'once' if k_tiling == 'grid' else 'per 128-chunk'}",
            config={"n": csr.n_rows, "nnz": nnz, "k": K_WIDE, "k_tiling": k_tiling},
        )

    # --- paired-payload argmax vs the three-pass oracle
    one, three = _traversal_counts(dt, x, cfg.col_block, tiles.n_rowgroups)
    assert one <= 1, f"one-pass argmax traversed the tile stream {one}x"
    k_arg = 8
    for passes, label in ((1, "onepass"), (3, "threepass")):
        t = timeit(
            lambda p=passes: ops.hbp_spmm_argmax(dt, x[:, :k_arg], passes=p, **meta),
            repeats=9, warmup=2,
        )
        emit(
            f"spmm/argmax_{label}",
            t,
            f"traversals={one if passes == 1 else three} "
            f"(one-pass {one} vs three-pass {three})",
            config={"n": csr.n_rows, "nnz": nnz, "k": k_arg, "passes": passes},
        )

    if full:
        # all four kernel strategies on a small matrix (Pallas pair in
        # interpret mode: correctness smoke, timings not comparable)
        cfg_s = PartitionConfig(row_block=64, col_block=128, group=8, lane=16)
        csr_s, tiles_s, dt_s, x_s, meta_s = _setup(1 << 8, 2_000, cfg_s, K_WIDE, seed=1)
        for strategy in ("fused", "partials", "reference", "stable"):
            interpret = strategy in ("fused", "partials")
            t = timeit(
                lambda s=strategy: ops.hbp_spmm(
                    dt_s, x_s, strategy=s, interpret=True, **meta_s
                ),
                repeats=3, warmup=1,
            )
            emit(
                f"spmm/strategy_{strategy}_k256",
                t,
                "interpret-mode smoke" if interpret else "",
                config={"n": csr_s.n_rows, "k": K_WIDE, "strategy": strategy},
            )


if __name__ == "__main__":
    main()
