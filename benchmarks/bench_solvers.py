"""Workload-level numbers: iterative solvers on top of the HBP kernels.

Raw SpMV microbenchmarks (bench_spmv) measure the format; this module
measures what the paper motivates the format WITH — iterative algorithms
whose inner loop is the sparse product.  Per solver we report

* ``iters_per_s`` — solver iterations per second (each iteration is one or
  two operator applications), the steady-state throughput number;
* ``time_to_tol`` — wall seconds until the convergence test fires, the
  end-to-end latency number a user of the workload sees.

As in bench_spmv, HBP runs the jnp oracle of the Pallas kernel on the host
CPU (interpret-mode timing is meaningless); the multi-RHS rows show the
SpMM kernel's one-pass-over-tiles advantage at the workload level.
"""
from __future__ import annotations

import numpy as np

from repro.core import PartitionConfig, build_tiles
from repro.core.formats import COOMatrix, CSRMatrix, csr_from_coo
from repro.core.matrices import rmat
from repro.solvers import aslinearoperator, bicgstab, cg, chebyshev, pagerank, transition_matrix

from .common import emit, timeit


def poisson2d(g: int) -> CSRMatrix:
    """5-point Laplacian on a g x g grid — the canonical SPD CG system."""
    n = g * g
    i = np.arange(n)
    ix, iy = i // g, i % g
    rows = [i]
    cols = [i]
    vals = [np.full(n, 4.0)]
    for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
        ok = (0 <= ix + dx) & (ix + dx < g) & (0 <= iy + dy) & (iy + dy < g)
        rows.append(i[ok])
        cols.append((ix[ok] + dx) * g + iy[ok] + dy)
        vals.append(np.full(ok.sum(), -1.0))
    return csr_from_coo(
        COOMatrix(np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), (n, n))
    )


def shifted(csr: CSRMatrix, sigma: float) -> CSRMatrix:
    """A + sigma I (diagonal shift to make circuit matrices solvable)."""
    coo = csr.to_coo()
    n = csr.n_rows
    return csr_from_coo(
        COOMatrix(
            np.concatenate([coo.row, np.arange(n)]),
            np.concatenate([coo.col, np.arange(n)]),
            np.concatenate([coo.data, np.full(n, sigma)]),
            csr.shape,
        )
    )


def _solver_row(name: str, run, n_iters_of) -> None:
    t = timeit(run, repeats=3, warmup=1)
    res = run()
    iters = int(n_iters_of(res))
    emit(
        f"solvers/{name}",
        t,
        f"iters={iters} iters_per_s={iters / t:.1f} time_to_tol_s={t:.4f} "
        f"converged={bool(res.converged)}",
    )


def main(full: bool = False) -> None:
    cfg = PartitionConfig()
    rng = np.random.default_rng(0)

    # --- CG + Chebyshev on the 2D Poisson system (SPD) ---
    g = 128 if full else 64
    A = poisson2d(g)
    tiles = build_tiles(A, cfg)
    op = aslinearoperator(tiles, strategy="reference")
    b = rng.standard_normal(A.n_rows).astype(np.float32)

    def run_cg(b=b):
        r = cg(op, b, tol=1e-5, maxiter=1000)
        r.x.block_until_ready()
        return r

    _solver_row(f"cg/poisson{g}x{g}", run_cg, lambda r: r.iterations)

    # blocked RHS: k systems, one SpMM launch per iteration
    k = 8
    B = rng.standard_normal((A.n_rows, k)).astype(np.float32)

    def run_cg_block(B=B):
        r = cg(op, B, tol=1e-5, maxiter=1000)
        r.x.block_until_ready()
        return r

    tk = timeit(run_cg_block, repeats=3, warmup=1)
    t1 = timeit(run_cg, repeats=3, warmup=1)
    emit(
        f"solvers/cg-block{k}/poisson{g}x{g}",
        tk,
        f"multi_rhs_speedup_vs_{k}_solves={k * t1 / tk:.2f}x",
    )

    # Chebyshev smoothing: fixed 40-iteration pass, the multigrid kernel
    lam_max = 8.0  # Gershgorin bound of the 5-point stencil
    def run_cheb(b=b):
        r = chebyshev(op, b, lam_min=lam_max / 30, lam_max=lam_max, tol=0.0, maxiter=40)
        r.x.block_until_ready()
        return r

    _solver_row(f"chebyshev40/poisson{g}x{g}", run_cheb, lambda r: r.iterations)

    # --- BiCGSTAB on a diagonally-shifted circuit matrix (nonsymmetric) ---
    from repro.core.matrices import circuit

    C = circuit(12_000 if full else 6_000, seed=1)
    sigma = 1.5 * float(np.abs(C.data).max())
    N = shifted(C, sigma)
    ntiles = build_tiles(N, cfg)
    nop = aslinearoperator(ntiles, strategy="reference")
    bn = rng.standard_normal(N.n_rows).astype(np.float32)

    def run_bicg(bn=bn):
        r = bicgstab(nop, bn, tol=1e-6, maxiter=500)
        r.x.block_until_ready()
        return r

    _solver_row("bicgstab/circuit-shifted", run_bicg, lambda r: r.iterations)

    # --- PageRank on an R-MAT graph: single vs multi-personalization ---
    Gr = rmat(1 << (15 if full else 13), 300_000 if full else 80_000, seed=4)
    M, dang = transition_matrix(Gr)
    mtiles = build_tiles(M, cfg)
    mop = aslinearoperator(mtiles, strategy="reference")
    n = Gr.n_rows
    P = (rng.random((n, k)) + 0.01).astype(np.float32)

    def run_pr():
        r = pagerank(mop, dangling=dang, tol=1e-8, maxiter=200)
        r.x.block_until_ready()
        return r

    _solver_row("pagerank/rmat", run_pr, lambda r: r.iterations)

    def run_pr_multi(P=P):
        r = pagerank(mop, personalization=P, dangling=dang, tol=1e-8, maxiter=200)
        r.x.block_until_ready()
        return r

    tm = timeit(run_pr_multi, repeats=3, warmup=1)
    ts = timeit(run_pr, repeats=3, warmup=1)
    emit(
        f"solvers/pagerank-multi{k}/rmat",
        tm,
        f"multi_rhs_speedup_vs_{k}_runs={k * ts / tm:.2f}x (SpMM kernel, "
        f"one tile-stream pass per iteration for all {k} rankings)",
    )


if __name__ == "__main__":
    main()
