"""Table II analogue: memory-traffic character of CSR vs HBP.

The paper measures Mem-Busy / throughput with Nsight; without hardware
counters we report the analytic byte footprint and access pattern of each
format: bytes moved per nonzero, contiguity (fraction of bytes in
streaming reads), and the x-vector reuse factor from 2D partitioning.
"""
from __future__ import annotations

import numpy as np

from repro.core import PartitionConfig, build_tiles, tuned_partition_config

from .common import emit, load_suite


def main(full: bool = False) -> None:
    cfg = PartitionConfig()
    for name, csr in load_suite(full).items():
        nnz = csr.nnz
        # CSR: data+col per nnz (stream) + one random x read per nnz
        # (charged a 64B DRAM transaction — the paper's Table II effect)
        csr_stream = nnz * 12 + csr.n_rows * 12
        csr_random = nnz * 64
        def fmt(tiles):
            tile_stream = tiles.n_tiles * tiles.cfg.group * tiles.cfg.lane * 8
            switches = int(np.count_nonzero(np.diff(tiles.colblock)) + 1)
            n_cb = -(-csr.n_cols // tiles.cfg.col_block)
            y_bytes = tiles.padded_rows() * 4
            fused = tile_stream + switches * tiles.cfg.col_block * 4 + y_bytes
            partials = (tile_stream + n_cb * tiles.cfg.col_block * 4
                        + tiles.n_tiles * tiles.cfg.group * 8 + y_bytes)
            return min(fused, partials), tiles.nnz_utilization()

        hbp_total, util = fmt(build_tiles(csr, cfg, method="hash"))
        tuned_total, tuned_util = fmt(
            build_tiles(csr, tuned_partition_config(csr), method="hash")
        )
        csr_total = csr_stream + csr_random
        emit(
            f"memtraffic/{name}",
            0.0,
            f"csr_bytes/nnz={csr_total/nnz:.1f} (random_frac={csr_random/csr_total:.2f}) "
            f"hbp_bytes/nnz={hbp_total/nnz:.1f} (util={util:.2f}) "
            f"hbp-tuned_bytes/nnz={tuned_total/nnz:.1f} (util={tuned_util:.2f}, beyond-paper)",
        )


if __name__ == "__main__":
    main()
