"""Shared benchmark utilities: timing, the scaled Table-I suite, CSV."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable

import numpy as np

from repro.core.formats import CSRMatrix
from repro.core.matrices import SUITE_SPECS

# benchmark subset: one matrix per structural family keeps the default run
# fast; --full sweeps the whole scaled Table-I analogue suite.
DEFAULT_SUITE = ["m1_asic320k", "m4_kron16", "m8_mip1", "m10_ohne2", "m14_rajat30"]


def load_suite(full: bool = False, seed: int = 0) -> Dict[str, CSRMatrix]:
    names = list(SUITE_SPECS) if full else DEFAULT_SUITE
    return {n: SUITE_SPECS[n](seed) for n in names}


def timeit(fn: Callable, *, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time in seconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "") -> None:
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")
