"""Shared benchmark utilities: timing, the scaled Table-I suite, CSV/JSON.

Every timed sample is **device-synchronized**: :func:`timeit` calls
``jax.block_until_ready`` on whatever the benchmarked callable returns
(arbitrary pytrees are fine, non-array leaves are ignored), so wall-clock
numbers never measure async dispatch instead of compute.  Callables must
therefore *return* the values they produce; already-blocking callables
pay one no-op re-block.

:func:`emit` prints the historical ``name,us_per_call,derived`` CSV row
AND appends a structured record (name, config, median/p50/p99 in
microseconds) to :data:`RESULTS`, which ``benchmarks.run --json PATH``
dumps for the CI regression gate (``benchmarks.compare``).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.formats import CSRMatrix
from repro.core.matrices import SUITE_SPECS

# benchmark subset: one matrix per structural family keeps the default run
# fast; --full sweeps the whole scaled Table-I analogue suite.
DEFAULT_SUITE = ["m1_asic320k", "m4_kron16", "m8_mip1", "m10_ohne2", "m14_rajat30"]

# structured records of the current run, dumped by ``run.py --json``
RESULTS: List[dict] = []


def load_suite(full: bool = False, seed: int = 0) -> Dict[str, CSRMatrix]:
    names = list(SUITE_SPECS) if full else DEFAULT_SUITE
    return {n: SUITE_SPECS[n](seed) for n in names}


class Timing(float):
    """Median wall seconds that also carries the repeat distribution.

    A plain ``float`` subclass: every existing arithmetic call site keeps
    working, while :func:`emit` lifts the attached ``stats`` dict
    (median/p50/p99 microseconds, repeat count) into the JSON record.
    """

    stats: dict

    @classmethod
    def from_samples(cls, ts) -> "Timing":
        ts = np.asarray(ts, dtype=np.float64)
        t = cls(float(np.median(ts)))
        t.stats = {
            "repeats": int(ts.size),
            "median_us": float(np.median(ts) * 1e6),
            # min-of-N: the noise-robust point estimate the regression
            # gate compares (medians swing with machine load; the floor
            # tracks the actual cost of the code)
            "min_us": float(ts.min() * 1e6),
            "p50_us": float(np.percentile(ts, 50) * 1e6),
            "p99_us": float(np.percentile(ts, 99) * 1e6),
        }
        return t


def timeit(fn: Callable, *, repeats: int = 5, warmup: int = 2) -> Timing:
    """Median wall time in seconds, device-synchronized.

    The returned value of ``fn`` is blocked on before the clock stops
    (``jax.block_until_ready`` walks any pytree and ignores non-arrays),
    so async-dispatched jax work is always inside the measurement.
    """
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return Timing.from_samples(ts)


def emit(
    name: str,
    seconds: float,
    derived: str = "",
    config: Optional[dict] = None,
) -> None:
    """CSV row ``name,us_per_call,derived`` + structured JSON record."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")
    us = float(seconds) * 1e6
    record = {
        "name": name,
        "config": config or {},
        "median_us": us,
        "p50_us": us,
        "p99_us": us,
        "derived": derived,
    }
    if isinstance(seconds, Timing):
        record.update(seconds.stats)
    RESULTS.append(record)
