"""Fig. 9 + Discussion: SpMV part vs combine part as matrices grow, and the
fused-combine kernel (beyond-paper, enabled by the TPU's sequential grid)
against the faithful two-phase split."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PartitionConfig, build_tiles
from repro.core.matrices import rmat
from repro.kernels import device_tiles
from repro.kernels.ops import blocked_vector
from repro.kernels.ref import tile_contrib_ref

from .common import emit, timeit


def main(full: bool = False) -> None:
    cfg = PartitionConfig()
    scales = [13, 14, 15, 16] if not full else [13, 14, 15, 16, 17]
    for scale in scales:
        n = 1 << scale
        csr = rmat(n, 20 * n, seed=scale)
        tiles = build_tiles(csr, cfg, method="hash")
        dt = device_tiles(tiles)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(csr.n_cols), jnp.float32)
        xb = blocked_vector(x, cfg.col_block)

        spmv_part = jax.jit(
            lambda xb: tile_contrib_ref(dt.colblock, dt.data, dt.cols, xb)
        )
        contrib = spmv_part(xb).block_until_ready()
        combine_part = jax.jit(
            lambda c: jax.ops.segment_sum(c, dt.rowgroup, num_segments=tiles.n_rowgroups)
        )

        t_spmv = timeit(lambda: spmv_part(xb).block_until_ready())
        t_comb = timeit(lambda: combine_part(contrib).block_until_ready())
        frac = t_comb / (t_comb + t_spmv)
        emit(
            f"combine/kron2^{scale}",
            t_spmv + t_comb,
            f"spmv={t_spmv*1e3:.2f}ms combine={t_comb*1e3:.2f}ms "
            f"combine_frac={frac:.2%} nnz={csr.nnz}",
        )


if __name__ == "__main__":
    main()
