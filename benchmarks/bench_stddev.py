"""Fig. 6: per-group nnz standard deviation before/after the nonlinear hash.

Also reports the TPU-relevant twin metric: 8-row tile padding waste.
The paper reports 42%/79%/67%/78%/5% stddev reductions on
kron_g500-logn18 / ASIC_680k / nxp1 / ohne2 / rajat30.
"""
from __future__ import annotations

import numpy as np

from repro.core import group_stddev, padding_waste
from repro.core.hash import sample_params
from repro.core.partition import PartitionConfig, count_block_nnz
from repro.core.reorder import hash_reorder_block

from .common import emit, load_suite


def analyze(csr, row_block=512, group=32):
    cfg = PartitionConfig(row_block=row_block)
    counts = count_block_nnz(csr, cfg)  # [rows, nbc]
    nbr = -(-csr.n_rows // row_block)
    sd0, sdh, pw0, pwh = [], [], [], []
    for bi in range(nbr):
        lo, hi = bi * row_block, min((bi + 1) * row_block, csr.n_rows)
        for bj in range(counts.shape[1]):
            nnz = counts[lo:hi, bj]
            if nnz.sum() == 0:
                continue
            params = sample_params(nnz, table_size=nnz.size)
            perm = hash_reorder_block(nnz, params)
            ident = np.arange(nnz.size)
            sd0.append(group_stddev(nnz, ident, group=group).mean())
            sdh.append(group_stddev(nnz, perm, group=group).mean())
            pw0.append(padding_waste(nnz, ident, group=8))
            pwh.append(padding_waste(nnz, perm, group=8))
    return map(lambda a: float(np.mean(a)), (sd0, sdh, pw0, pwh))


def main(full: bool = False) -> None:
    for name, csr in load_suite(full).items():
        sd0, sdh, pw0, pwh = analyze(csr)
        red = 100 * (1 - sdh / sd0) if sd0 > 0 else 0.0
        emit(
            f"stddev/{name}",
            0.0,
            f"stddev {sd0:.2f}->{sdh:.2f} (-{red:.0f}%); "
            f"pad_waste {pw0:.3f}->{pwh:.3f}",
        )


if __name__ == "__main__":
    main()
