"""GNN training-step cost: differentiable HBP forward+backward vs dense.

One row per (model/op, feature width): the full training step — forward
aggregation, cross-entropy, backward (for sum/mean an SpMM against the
transpose tiles; for max the argmax-routed scatter), AdamW update — on a
power-law graph.  The derived column reports edge-multiplies per second
counting forward + backward traffic (2 tile-stream passes for the linear
ops), and a dense-adjacency training step anchors the sparse-vs-dense
tradeoff at the same width.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.graph import rmat_graph
from repro.graph.train import NodeClassifierTrainer

from .common import emit, timeit

K_SWEEP = (32, 128)
N_CLASSES = 8
DENSE_MAX_NODES = 1 << 12


def _dense_step(D, X, labels, W):
    def loss(w):
        logits = jax.nn.relu(D @ (X @ w[0])) @ w[1]
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()

    g = jax.grad(loss)(W)
    return [w - 1e-2 * gw for w, gw in zip(W, g)]


def main(full: bool = False) -> None:
    n = 1 << (13 if full else 12)
    G = rmat_graph(n, 16.0, seed=7)
    rng = np.random.default_rng(0)
    labels = rng.integers(0, N_CLASSES, n)

    for k in K_SWEEP:
        X = rng.standard_normal((n, k)).astype(np.float32)
        # fwd + bwd each stream the tiles once per layer; 2 layers
        edge_mults = 2 * 2 * G.nnz * k
        for model, op in (("gcn", "sum"), ("sage", "mean"), ("sage", "max")):
            tr = NodeClassifierTrainer([k, 32, N_CLASSES], model=model, op=op)
            agg = tr.aggregator(tr.prepare_adjacency(G))
            state = tr.init(0)
            Xj = jnp.asarray(X)

            def step():
                # returning the new state lets timeit block on it: the
                # update is async-dispatched, and an unsynchronized clock
                # would time dispatch instead of the training step
                nonlocal state
                state, _ = tr.step(state, agg, Xj, labels)
                return state

            t = timeit(step, repeats=3, warmup=1)
            emit(f"gnn_train_{model}_{op}_k{k}", t, f"{edge_mults / t / 1e9:.2f}Gmul/s")
        if n <= DENSE_MAX_NODES:
            D = jnp.asarray(G.to_dense(), jnp.float32)
            Xj = jnp.asarray(X)
            lj = jnp.asarray(labels)
            W = [
                jnp.asarray(rng.standard_normal((k, 32)).astype(np.float32)),
                jnp.asarray(rng.standard_normal((32, N_CLASSES)).astype(np.float32)),
            ]
            t_dense = timeit(
                lambda: jax.block_until_ready(_dense_step(D, Xj, lj, W)),
                repeats=3, warmup=1,
            )
            emit(f"gnn_train_dense_k{k}", t_dense, "dense 2-layer step")


if __name__ == "__main__":
    main()
