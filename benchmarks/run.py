"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` sweeps the whole
scaled Table-I suite (slower); the default subset covers every structural
family.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, help="comma list: stddev,preprocess,spmv,combine,memtraffic,schedule,roofline,solvers,traffic,gnn,gnn_train")
    args = ap.parse_args()

    from . import (
        bench_combine,
        bench_gnn,
        bench_gnn_train,
        bench_memtraffic,
        bench_preprocess,
        bench_roofline,
        bench_schedule,
        bench_solvers,
        bench_spmv,
        bench_stddev,
        bench_traffic,
    )

    benches = {
        "stddev": bench_stddev.main,        # Fig. 6
        "preprocess": bench_preprocess.main,  # Fig. 7
        "spmv": bench_spmv.main,            # Figs. 8/10
        "combine": bench_combine.main,      # Fig. 9
        "memtraffic": bench_memtraffic.main,  # Table II
        "schedule": bench_schedule.main,    # §III-C
        "roofline": bench_roofline.main,    # EXPERIMENTS §Roofline
        "solvers": bench_solvers.main,      # workload level (beyond-paper)
        "traffic": bench_traffic.main,      # serving engine (beyond-paper)
        "gnn": bench_gnn.main,              # graph aggregation (beyond-paper)
        "gnn_train": bench_gnn_train.main,  # differentiable fwd+bwd step
    }
    selected = args.only.split(",") if args.only else list(benches)
    print("name,us_per_call,derived")
    ok = True
    for name in selected:
        try:
            benches[name](full=args.full)
        except Exception:
            ok = False
            print(f"{name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
