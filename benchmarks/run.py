"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` sweeps the whole
scaled Table-I suite (slower); the default subset covers every structural
family.  ``--only`` takes a comma-separated subset of bench names;
``--json PATH`` additionally writes the structured per-bench records
(name, config, median/p50/p99 µs) that ``benchmarks.compare`` gates CI
regressions against.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--only",
        default=None,
        help="comma list: stddev,preprocess,spmv,spmm,combine,memtraffic,"
        "schedule,roofline,solvers,traffic,gnn,gnn_train,obs",
    )
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="write structured per-bench records (median/p50/p99 µs) to PATH",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="PATH",
        help="enable repro.obs for the run and write a Chrome-trace JSON "
        "(load in Perfetto / chrome://tracing) of the benchmark's spans",
    )
    args = ap.parse_args()

    from repro import obs

    from . import (
        bench_combine,
        bench_gnn,
        bench_gnn_train,
        bench_memtraffic,
        bench_obs,
        bench_preprocess,
        bench_roofline,
        bench_schedule,
        bench_solvers,
        bench_spmm,
        bench_spmv,
        bench_stddev,
        bench_traffic,
        common,
    )

    benches = {
        "stddev": bench_stddev.main,        # Fig. 6
        "preprocess": bench_preprocess.main,  # Fig. 7
        "spmv": bench_spmv.main,            # Figs. 8/10
        "spmm": bench_spmm.main,            # one-pass kernel grid (beyond-paper)
        "combine": bench_combine.main,      # Fig. 9
        "memtraffic": bench_memtraffic.main,  # Table II
        "schedule": bench_schedule.main,    # §III-C
        "roofline": bench_roofline.main,    # EXPERIMENTS §Roofline
        "solvers": bench_solvers.main,      # workload level (beyond-paper)
        "traffic": bench_traffic.main,      # serving engine (beyond-paper)
        "gnn": bench_gnn.main,              # graph aggregation (beyond-paper)
        "gnn_train": bench_gnn_train.main,  # differentiable fwd+bwd step
        "obs": bench_obs.main,              # instrumentation overhead guard
    }
    if args.only:
        selected = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in selected if s not in benches]
        if unknown:
            ap.error(
                f"unknown bench name(s) {', '.join(unknown)} — "
                f"choose from: {', '.join(benches)}"
            )
    else:
        selected = list(benches)
    if args.trace:
        obs.enable()
    print("name,us_per_call,derived")
    ok = True
    for name in selected:
        try:
            benches[name](full=args.full)
        except Exception:
            ok = False
            print(f"{name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if args.trace:
        obs.write_trace(args.trace)
        print(f"wrote Chrome trace to {args.trace}", file=sys.stderr)
    if args.json:
        payload = {
            "schema": 1,
            "full": args.full,
            "selected": selected,
            "benches": common.RESULTS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {len(common.RESULTS)} records to {args.json}", file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
