"""Fig. 7: preprocessing (reordering) cost — nonlinear hash vs sort2D vs
DP2D.  The paper reports hash 3.53x faster than sort2D and 3.67x faster
than DP2D on average.

Why the hash wins, on any hardware: the aggregation maps each row's nnz
(unbounded integer keys) into 9 buckets in O(1)/row, so *placement*
degrades from a full-width sort to a single-byte counting sort.  On the
paper's GPU that manifests as parallel O(1) table insertion vs a sort; on
this CPU host the equivalent is a uint8-key radix pass (numpy's stable
argsort on uint8 IS histogram+prefix+scatter — a vectorised counting
sort) vs full-width key sorting.  Same algorithmic content, measured
like-for-like: both methods are one vectorised placement call over all
(row-block × col-block) problems; the shared Algorithm-2 counting pass is
excluded from both timings.  Reordering *quality* (stddev/padding) is the
separate Fig. 6 benchmark, which runs the full 3-stage hash.

DP2D additionally pays an O(n·G) dynamic program per block after its sort
(Regu2D) — the cost the paper's Fig. 7 normalises against.
"""
from __future__ import annotations

import numpy as np

from repro.core.hash import sample_params
from repro.core.partition import PartitionConfig, count_block_nnz
from repro.core.reorder import dp_reorder

from .common import emit, load_suite, timeit

ROW_BLOCK = 512


def _slab(csr):
    """All per-(row, col-block) nnz counts as one [512, nbr*nbc] slab —
    every column is an independent (row-block, col-block) reordering
    problem, so one axis-0 placement call covers the whole matrix (the
    maximally parallel formulation, for both methods alike)."""
    cfg = PartitionConfig(row_block=ROW_BLOCK)
    counts = count_block_nnz(csr, cfg)
    nbr = -(-csr.n_rows // ROW_BLOCK)
    pad = nbr * ROW_BLOCK - csr.n_rows
    if pad:
        counts = np.pad(counts, ((0, pad), (0, 0)))
    nbc = counts.shape[1]
    return counts.reshape(nbr, ROW_BLOCK, nbc).transpose(1, 0, 2).reshape(ROW_BLOCK, nbr * nbc)


def main(full: bool = False) -> None:
    for name, csr in load_suite(full).items():
        slab = _slab(csr)
        sample = slab[:, :: max(slab.shape[1] // 64, 1)].reshape(-1)

        def run_hash():
            # a, c sampled once per matrix ("sampled during program
            # execution"), then O(1)/row aggregation + counting-sort
            # placement on single-byte keys
            params = sample_params(sample, table_size=ROW_BLOCK)
            clipped = np.minimum(slab, (1 << 15) - 1).astype(np.int16)
            bucket = np.minimum(clipped >> params.a, params.n_buckets - 1).astype(np.uint8)
            np.argsort(bucket, axis=0, kind="stable")

        def run_sort():
            np.argsort(slab, axis=0, kind="stable")

        dp_blocks = [slab[:, j] for j in range(min(slab.shape[1], 40))]
        dp_scale = slab.shape[1] / max(len(dp_blocks), 1)

        def run_dp():
            for nnz in dp_blocks:
                dp_reorder(nnz, group=32)

        t_hash = timeit(run_hash, repeats=3, warmup=1)
        t_sort = timeit(run_sort, repeats=3, warmup=1)
        t_dp = timeit(run_dp, repeats=2, warmup=0) * dp_scale
        emit(
            f"preprocess/{name}",
            t_hash,
            f"hash={t_hash*1e3:.1f}ms sort2d={t_sort*1e3:.1f}ms "
            f"dp2d={t_dp*1e3:.1f}ms speedup_sort={t_sort/t_hash:.2f}x "
            f"speedup_dp={t_dp/t_hash:.2f}x problems={slab.shape[1]}",
        )


if __name__ == "__main__":
    main()
