"""GNN aggregation throughput: HBP SpMM vs the CSR oracle vs dense.

One row per (aggregation op, feature width): neighborhood aggregation over
a power-law graph at k in {16, 64, 128, 256} — k = 256 exercises the
lane-tiled k loop (two sequential 128-lane passes over the tile stream).
The derived column reports edge throughput (stored-entry multiplies per
second at that width); the dense row anchors the sparse-vs-dense tradeoff
on the same launch.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.spmv import csr_spmm_jnp
from repro.core.tile import build_tiles, tuned_partition_config
from repro.graph import degrees, make_aggregator, rmat_graph

from .common import emit, timeit

K_SWEEP = (16, 64, 128, 256)
DENSE_MAX_NODES = 1 << 12  # the dense adjacency anchor stops paying past this


def main(full: bool = False) -> None:
    n = 1 << (13 if full else 12)
    G = rmat_graph(n, 16.0, seed=7)
    deg = degrees(G)
    tiles = build_tiles(G, tuned_partition_config(G))  # built once, shared

    indptr = jnp.asarray(G.indptr)
    indices = jnp.asarray(G.indices)
    data = jnp.asarray(G.data, jnp.float32)
    dense = jnp.asarray(G.to_dense(), jnp.float32) if n <= DENSE_MAX_NODES else None

    rng = np.random.default_rng(0)
    for k in K_SWEEP:
        X = jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))
        edge_mults = G.nnz * k
        for op in ("sum", "mean", "max"):
            agg = make_aggregator(tiles, op=op, degree=deg)
            t = timeit(lambda: agg(X).block_until_ready())
            emit(f"gnn_hbp_{op}_k{k}", t, f"{edge_mults / t / 1e9:.2f}Gmul/s")
        t_csr = timeit(
            lambda: csr_spmm_jnp(indptr, indices, data, X, n).block_until_ready()
        )
        emit(f"gnn_csr_sum_k{k}", t_csr, f"{edge_mults / t_csr / 1e9:.2f}Gmul/s")
        if dense is not None:
            t_dense = timeit(lambda: (dense @ X).block_until_ready())
            emit(f"gnn_dense_k{k}", t_dense, f"dense_vs_csr={t_dense / t_csr:.2f}x")


if __name__ == "__main__":
    main()
