"""Reordering quality: the paper's Fig. 6 metric and its TPU analogue."""
import numpy as np

from repro.core import group_stddev, padding_waste
from repro.core.hash import sample_params
from repro.core.matrices import circuit, rmat
from repro.core.reorder import REORDER_METHODS, dp_reorder, hash_reorder_block, sort_reorder


def test_hash_reduces_stddev_on_circuit():
    A = circuit(20_000, seed=1)
    nnz = A.row_nnz()[:512]
    params = sample_params(nnz, 512)
    base = group_stddev(nnz, np.arange(nnz.size), group=32).mean()
    hashed = group_stddev(nnz, hash_reorder_block(nnz, params), group=32).mean()
    assert hashed < base  # Fig. 6: 42-79% reductions on circuit matrices


def test_hash_reduces_padding_on_powerlaw():
    A = rmat(1 << 14, 300_000, seed=2)
    nnz = A.row_nnz()[:512]
    params = sample_params(nnz, 512)
    base = padding_waste(nnz, np.arange(nnz.size), group=8)
    hashed = padding_waste(nnz, hash_reorder_block(nnz, params), group=8)
    assert hashed <= base


def test_sort_is_lower_bound_on_stddev(rng):
    """Full sort is the quality ceiling; hash should land between identity
    and sort."""
    nnz = rng.integers(0, 400, size=512)
    params = sample_params(nnz, 512)
    s_id = group_stddev(nnz, np.arange(512), group=32).mean()
    s_hash = group_stddev(nnz, hash_reorder_block(nnz, params), group=32).mean()
    s_sort = group_stddev(nnz, sort_reorder(nnz), group=32).mean()
    assert s_sort <= s_hash + 1e-9
    assert s_hash <= s_id + 1e-9


def test_dp_reorder_is_sorted_permutation(rng):
    nnz = rng.integers(0, 100, size=128)
    perm = dp_reorder(nnz, group=16)
    assert sorted(perm.tolist()) == list(range(128))
    assert (np.diff(nnz[perm]) >= 0).all()


def test_all_methods_are_permutations(rng):
    nnz = rng.integers(0, 50, size=64)
    for name, method in REORDER_METHODS.items():
        perm = method(nnz)
        assert sorted(perm.tolist()) == list(range(64)), name
