"""Differentiable HBP aggregation: VJPs vs the dense oracle.

The backward of sum/mean aggregation must be an SpMM against the
transpose tiles (checked to reverse-mode order 2 with ``check_grads``),
max must route cotangents to the argmax neighbor saved by the forward's
index-SpMM — including the empty-row (no gradient) and tied-max (lowest
winning column takes all) conventions.  Acceptance: ``jax.grad`` through
a 2-layer GCN on the 10k-node power-law graph matches oracle gradients.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.test_util import check_grads

from repro.core import PartitionConfig
from repro.core.formats import CSRMatrix, csr_from_dense
from repro.graph import (
    add_self_loops,
    degrees,
    gcn_forward,
    graph_from_edges,
    init_gcn,
    make_diff_aggregator,
    normalize_adjacency,
    plan_diff_aggregator,
    power_law_graph,
)
from repro.kernels import autodiff, ops

CHECK = dict(atol=5e-2, rtol=5e-2, eps=1e-2)  # fp32 numerical-diff tolerances


# --- oracles: pure-jnp CSR closures JAX can differentiate natively ---------


def jnp_oracle(csr: CSRMatrix, op: str, clamp_deg=None):
    rows = jnp.asarray(np.repeat(np.arange(csr.n_rows), csr.row_nnz()))
    cols = jnp.asarray(csr.indices)
    data = jnp.asarray(csr.data, jnp.float32)
    n = csr.n_rows

    def f(x):
        prod = data[:, None] * x[cols]
        if op == "max":
            masked = jnp.where(data[:, None] != 0, prod, -jnp.inf)
            m = jax.ops.segment_max(masked, rows, num_segments=n)
            return jnp.where(jnp.isneginf(m), 0.0, m)
        y = jax.ops.segment_sum(prod, rows, num_segments=n)
        if op == "mean":
            return y / jnp.maximum(jnp.asarray(clamp_deg, jnp.float32), 1.0)[:, None]
        return y

    return f


@pytest.fixture(scope="module")
def small():
    rng = np.random.default_rng(0)
    dense = (rng.standard_normal((37, 29)) * (rng.random((37, 29)) < 0.25)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=16, col_block=16, group=4, lane=4)
    return csr, autodiff.hbp_transpose(csr, cfg, cfg)


def _x(csr, k=5, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((csr.n_cols, k)).astype(np.float32))


# --- transpose pairing -----------------------------------------------------


def test_hbp_transpose_pair_matches_dense(small):
    csr, pair = small
    x = _x(csr)
    g = jnp.asarray(
        np.random.default_rng(2).standard_normal((csr.n_rows, 5)).astype(np.float32)
    )
    y = ops.hbp_spmm(pair.tiles, x, strategy="stable")
    yt = ops.hbp_spmm(pair.tiles_T, g, strategy="stable")
    D = csr.to_dense()
    np.testing.assert_allclose(np.asarray(y), D @ np.asarray(x), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(yt), D.T @ np.asarray(g), rtol=1e-4, atol=1e-5)


def test_hbp_transpose_tunes_each_side_independently():
    # tall-thin: row profile and column profile differ, so the tuned
    # geometries may — and the pair must carry each side's own config
    rng = np.random.default_rng(3)
    dense = (rng.random((200, 40)) < 0.4).astype(np.float32)
    csr = csr_from_dense(dense)
    pair = autodiff.hbp_transpose(csr)
    assert pair.tiles.shape == (200, 40)
    assert pair.tiles_T.shape == (40, 200)


# --- check_grads: fwd+rev, order 1-2 ---------------------------------------


@pytest.mark.parametrize("op", ["sum", "mean"])
def test_linear_vjp_mode_rev_order2(small, op):
    """The training path: backward IS the transpose-tiles SpMM; rev-mode
    composes to order 2 (grad-of-grad alternates the A and At launches)."""
    csr, pair = small
    deg = degrees(csr) if op == "mean" else None
    f = autodiff.diff_aggregator(pair, op=op, degree=deg, mode="vjp")
    check_grads(f, (_x(csr),), order=2, modes=["rev"], **CHECK)


@pytest.mark.parametrize("op", ["sum", "mean"])
def test_linear_jvp_mode_fwd_and_rev_order2(small, op):
    """The jvp flavor: tangent = a second SpMM launch; forward-mode is
    first-class and reverse-mode transposes the tangent launch."""
    csr, pair = small
    deg = degrees(csr) if op == "mean" else None
    f = autodiff.diff_aggregator(pair, op=op, degree=deg, mode="jvp")
    check_grads(f, (_x(csr),), order=2, modes=["fwd", "rev"], **CHECK)


def _distinct_int_x(n_cols: int, k: int, seed: int) -> jnp.ndarray:
    """Per-column distinct integers (zero-centred): against a binary
    adjacency every argmax margin is >= 1, so finite-difference probes in
    ``check_grads`` never flip a winner (max is only piecewise linear —
    at a near-tie the numerical derivative and the subgradient disagree,
    which would be a property of the probe, not a bug in the VJP)."""
    rng = np.random.default_rng(seed)
    cols = [rng.permutation(n_cols) - n_cols // 2 for _ in range(k)]
    return jnp.asarray(np.stack(cols, axis=1).astype(np.float32))


def test_max_fwd_and_rev_order2(small):
    """Argmax routing supports both modes: the tangent gathers through the
    saved winner indices, and its transpose is the cotangent scatter."""
    csr, _ = small
    binary = csr_from_dense((csr.to_dense() != 0).astype(np.float32))
    cfg = PartitionConfig(row_block=16, col_block=16, group=4, lane=4)
    pair = autodiff.hbp_transpose(binary, cfg, cfg)
    f = autodiff.diff_aggregator(pair, op="max")
    x = _distinct_int_x(binary.n_cols, 5, seed=1)
    check_grads(f, (x,), order=2, modes=["fwd", "rev"], **CHECK)


# --- gradients vs the dense/jnp oracle -------------------------------------


@pytest.mark.parametrize("op", ["sum", "mean", "max"])
@pytest.mark.parametrize("mode", ["vjp", "jvp"])
def test_grad_matches_oracle(small, op, mode):
    csr, pair = small
    if op == "max" and mode == "jvp":
        pytest.skip("max has a single (custom_jvp) implementation")
    deg = degrees(csr) if op == "mean" else None
    f = autodiff.diff_aggregator(pair, op=op, degree=deg, mode=mode)
    oracle = jnp_oracle(csr, op, clamp_deg=deg)
    x = _x(csr)
    w = jnp.asarray(
        np.random.default_rng(5).standard_normal((csr.n_rows, x.shape[1])).astype(np.float32)
    )
    g = jax.grad(lambda v: jnp.sum(f(v) * w))(x)
    g_oracle = jax.grad(lambda v: jnp.sum(oracle(v) * w))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_oracle), rtol=1e-4, atol=1e-5)


# --- conventions: empty rows, tied max -------------------------------------


@pytest.fixture()
def iso_graph():
    # nodes 3 and 5 have no in-neighbors (rows are empty)
    G = graph_from_edges([0, 1, 2, 4], [1, 2, 0, 0], n_nodes=6)
    cfg = PartitionConfig(row_block=8, col_block=8, group=4, lane=4)
    return G, autodiff.hbp_transpose(G, cfg, cfg)


@pytest.mark.parametrize("op", ["sum", "mean", "max"])
def test_empty_rows_pass_no_gradient(iso_graph, op):
    """Cotangents landing on empty output rows must vanish, not NaN: the
    row aggregates nothing, so it can influence nothing."""
    G, pair = iso_graph
    deg = degrees(G) if op == "mean" else None
    f = autodiff.diff_aggregator(pair, op=op, degree=deg)
    x = _x(G, k=3)
    # weight ONLY the empty rows: the whole loss is insensitive to x
    w = np.zeros((6, 3), np.float32)
    w[[3, 5]] = 7.0
    g = jax.grad(lambda v: jnp.sum(f(v) * jnp.asarray(w)))(x)
    assert np.isfinite(np.asarray(g)).all()
    np.testing.assert_array_equal(np.asarray(g), 0.0)
    check_grads(f, (x,), order=1, modes=["rev"], **CHECK)


def test_tied_max_routes_to_lowest_column():
    """Two neighbors with identical products: the winner (and the whole
    cotangent) is the lowest column id, deterministically."""
    D = np.zeros((3, 3), np.float32)
    D[0, 1] = D[0, 2] = 2.0  # row 0 aggregates cols 1 and 2 equally
    csr = csr_from_dense(D)
    cfg = PartitionConfig(row_block=4, col_block=4, group=2, lane=2)
    pair = autodiff.hbp_transpose(csr, cfg, cfg)
    f = autodiff.diff_aggregator(pair, op="max")
    x = jnp.asarray(np.full((3, 2), 3.0, np.float32))  # cols 1, 2 tie at 6.0
    y, idx, coeff = ops.hbp_spmm_argmax(pair.tiles, x)
    np.testing.assert_array_equal(np.asarray(y)[0], 6.0)
    np.testing.assert_array_equal(np.asarray(idx)[0], 1)  # lowest wins
    np.testing.assert_array_equal(np.asarray(coeff)[0], 2.0)
    g = jax.grad(lambda v: f(v)[0, 0])(x)
    expect = np.zeros((3, 2), np.float32)
    expect[1, 0] = 2.0  # full cotangent * coeff to column 1, none to column 2
    np.testing.assert_array_equal(np.asarray(g), expect)


def test_argmax_empty_rows_report_no_winner(iso_graph):
    G, pair = iso_graph
    y, idx, coeff = ops.hbp_spmm_argmax(pair.tiles, _x(G, k=2))
    assert (np.asarray(idx)[[3, 5]] == -1).all()
    assert (np.asarray(coeff)[[3, 5]] == 0).all()
    assert (np.asarray(y)[[3, 5]] == 0).all()


# --- serving-plan path -----------------------------------------------------


def test_plan_diff_aggregator_and_link_errors(tmp_path):
    from repro.serving import MatrixRegistry

    G = power_law_graph(90, 4.0, seed=8, symmetric=False)
    reg = MatrixRegistry(cache_dir=tmp_path / "c", search=False)
    lone = reg.admit(G, "lone")
    with pytest.raises(ValueError, match="admit_pair"):
        lone.diff_aggregator(op="sum")
    reg2 = MatrixRegistry(cache_dir=tmp_path / "c2", search=False)
    plan = reg2.admit_pair(G, "g")
    assert reg2.transpose_of(plan).name == "g::T"
    x = _x(G, k=4)
    w = jnp.ones((90, 4), jnp.float32)
    for op in ("sum", "mean", "max"):
        f = plan_diff_aggregator(plan, op=op)
        oracle = jnp_oracle(G, op, clamp_deg=degrees(G))
        g = jax.grad(lambda v: jnp.sum(f(v) * w))(x)
        go = jax.grad(lambda v: jnp.sum(oracle(v) * w))(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(go), rtol=1e-4, atol=1e-5)
    # max needs no transpose link
    f = lone.diff_aggregator(op="max")
    jax.grad(lambda v: jnp.sum(f(v)))(x)


def test_mode_and_op_validation(small):
    csr, pair = small
    with pytest.raises(ValueError, match="unknown mode"):
        autodiff.diff_aggregator(pair, op="sum", mode="hvp")
    with pytest.raises(ValueError, match="unknown aggregation"):
        autodiff.diff_aggregator(pair, op="median")
    with pytest.raises(ValueError, match="degree"):
        autodiff.diff_aggregator(pair, op="mean")
    with pytest.raises(ValueError, match="transpose tiles"):
        autodiff.device_diff_aggregator(
            ops.device_tiles(pair.tiles), None,
            dict(n_rowgroups=pair.tiles.n_rowgroups, n_rows=csr.n_rows,
                 col_block=pair.tiles.cfg.col_block, strategy="stable",
                 interpret=None),
            None, op="sum",
        )


# --- acceptance: 10k-node power-law graph ----------------------------------


@pytest.fixture(scope="module")
def big_graph():
    return power_law_graph(10_000, 6.0, seed=42)


@pytest.mark.parametrize("op", ["sum", "mean", "max"])
def test_check_grads_10k(big_graph, op):
    """check_grads passes for every aggregation op at acceptance scale.

    For max the features are per-column distinct integers: the graph is
    binary, so every argmax margin is >= 1 and the finite-difference
    probes stay inside one linear region (see ``_distinct_int_x``) —
    ``eps`` is raised accordingly to dominate fp32 roundoff at the
    ~1e4 value scale."""
    deg = degrees(big_graph) if op == "mean" else None
    f = make_diff_aggregator(big_graph, op=op, degree=deg)
    if op == "max":
        x = _distinct_int_x(big_graph.n_cols, 4, seed=11)
        check_grads(f, (x,), order=1, modes=["fwd", "rev"],
                    atol=5e-2, rtol=5e-2, eps=0.2)
    else:
        x = _x(big_graph, k=4, seed=11)
        check_grads(f, (x,), order=1, modes=["rev"], **CHECK)


def test_grad_through_2layer_gcn_10k_matches_oracle(big_graph):
    """jax.grad of a 2-layer GCN loss wrt features AND params, HBP path vs
    the jnp CSR oracle closure."""
    A_hat = normalize_adjacency(add_self_loops(big_graph), "sym")
    agg = make_diff_aggregator(A_hat, op="sum")
    oracle = jnp_oracle(A_hat, "sum")
    params = init_gcn(jax.random.PRNGKey(0), [8, 8, 3])
    x = _x(A_hat, k=8, seed=13)

    def loss(p, v, a):
        return jnp.mean(gcn_forward(a, p, v) ** 2)

    gp, gx = jax.grad(lambda p, v: loss(p, v, agg), argnums=(0, 1))(params, x)
    gp_o, gx_o = jax.grad(lambda p, v: loss(p, v, oracle), argnums=(0, 1))(params, x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_o), rtol=1e-3, atol=1e-5)
    for got, want in zip(jax.tree.leaves(gp), jax.tree.leaves(gp_o)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-5)
