import os

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="session")
def _flight_dumps_tmpdir(tmp_path_factory):
    """Route flight-recorder trigger dumps into a session tmp dir.

    The recorder is always on, and tests that legitimately induce
    deadline misses (virtual-clock serving tests) would otherwise litter
    the repo root with flight_*.json artifacts.
    """
    d = tmp_path_factory.mktemp("flight_dumps")
    old = os.environ.get("REPRO_FLIGHT_DIR")
    os.environ["REPRO_FLIGHT_DIR"] = str(d)
    yield
    if old is None:
        os.environ.pop("REPRO_FLIGHT_DIR", None)
    else:
        os.environ["REPRO_FLIGHT_DIR"] = old


def hypothesis_or_shim():
    """(given, settings, st) — real hypothesis, or decorators that skip.

    Lets a module keep its deterministic unit tests runnable when
    hypothesis is absent, with only the ``@given`` property tests
    skipping.  Usage::

        from conftest import hypothesis_or_shim
        given, settings, st = hypothesis_or_shim()
    """
    try:
        from hypothesis import given, settings, strategies as st

        return given, settings, st
    except ImportError:
        class _NoHypStrategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def settings(*a, **k):
            return lambda f: f

        def given(*a, **k):
            return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

        return given, settings, _NoHypStrategies()
