import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def hypothesis_or_shim():
    """(given, settings, st) — real hypothesis, or decorators that skip.

    Lets a module keep its deterministic unit tests runnable when
    hypothesis is absent, with only the ``@given`` property tests
    skipping.  Usage::

        from conftest import hypothesis_or_shim
        given, settings, st = hypothesis_or_shim()
    """
    try:
        from hypothesis import given, settings, strategies as st

        return given, settings, st
    except ImportError:
        class _NoHypStrategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def settings(*a, **k):
            return lambda f: f

        def given(*a, **k):
            return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

        return given, settings, _NoHypStrategies()
