"""Partition-quality introspection: metrics, gauges, provenance, explain.

The acceptance properties: a skewed matrix (one dense row block) must
raise the rowgroup-imbalance gauge AND the LPT competitive ratio well
above a uniform random matrix (which stays near 1.0); hash-group cohesion
must be measurably higher for a banded matrix than for the same matrix
with its rows shuffled; the ``plan.*`` gauges must appear in a live
OpenMetrics scrape of the owning registry's metrics; and the explain
report must round-trip through a real ``obs.collect()`` snapshot while
staying n/a-safe and deterministic on empty/partial dumps.
"""
import numpy as np
import pytest

from repro import obs
from repro.core.formats import COOMatrix, csr_from_coo
from repro.core.matrices import banded_fem, uniform_random
from repro.core.partition import PartitionConfig
from repro.core.tile import build_tiles
from repro.obs.export import parse_openmetrics, render_openmetrics
from repro.obs.planview import (
    explain_report,
    partition_quality,
    plan_metrics_from_snapshot,
    register_plan_metrics,
)
from repro.serving import MatrixRegistry

# small blocks keep several column blocks in play (cohesion needs a
# footprint wider than one block) and the builds in the milliseconds
CFG = PartitionConfig(row_block=256, col_block=256, group=8, lane=32)

# acceptance thresholds: the skewed matrix must blow these, the uniform
# one must stay under them
SKEWED_RATIO_MIN = 1.5
UNIFORM_RATIO_MAX = 1.2


def _skewed(n: int = 1024):
    """One fully dense 256x256 block + a sparse background diagonal: a
    single partition block dominates, so no 2-worker schedule can balance
    it (the other worker gets everything else and still idles)."""
    d = 256
    rows = np.repeat(np.arange(d), d)
    cols = np.tile(np.arange(d), d)
    diag = np.arange(d, n, 4)  # every 4th row: background stays light
    rows = np.concatenate([rows, diag])
    cols = np.concatenate([cols, diag])
    data = np.ones(rows.size)
    return csr_from_coo(COOMatrix(rows, cols, data, (n, n)))


def _shuffle_rows(csr, seed: int = 0):
    """The same nonzeros with the rows globally permuted."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(csr.shape[0])
    rows = perm[np.repeat(np.arange(csr.shape[0]), csr.row_nnz())]
    return csr_from_coo(COOMatrix(rows, csr.indices.copy(), csr.data.copy(), csr.shape))


def _quality(csr):
    return partition_quality(build_tiles(csr, CFG), csr)


# --- imbalance / competitive ratio ------------------------------------------


def test_skewed_matrix_blows_the_imbalance_and_competitive_gauges():
    q = _quality(_skewed())
    assert q["competitive_ratio"] > SKEWED_RATIO_MIN
    assert q["rowgroup_imbalance"] > SKEWED_RATIO_MIN


def test_uniform_random_stays_near_balanced():
    q = _quality(uniform_random(1024, 0.01, seed=1))
    assert q["competitive_ratio"] < UNIFORM_RATIO_MAX
    # and the skew really is the discriminator, not a constant offset
    assert q["competitive_ratio"] < _quality(_skewed())["competitive_ratio"]


# --- cohesion ----------------------------------------------------------------


def test_cohesion_banded_beats_shuffled_rows():
    banded = banded_fem(2000, band=4, seed=0)
    qb = _quality(banded)
    qs = _quality(_shuffle_rows(banded))
    assert qb["cohesion"] is not None and qs["cohesion"] is not None
    # banded rows grouped together share column blocks; shuffled rows
    # scatter their footprints across the whole band range
    assert qb["cohesion"] > qs["cohesion"] + 0.2


def test_cohesion_is_deterministic_and_none_without_csr():
    csr = banded_fem(1000, band=3, seed=2)
    tiles = build_tiles(csr, CFG)
    q1 = partition_quality(tiles, csr)
    q2 = partition_quality(tiles, csr)
    assert q1["cohesion"] == q2["cohesion"]
    assert q1["cohesion_random"] == q2["cohesion_random"]
    q0 = partition_quality(tiles)  # no matrix -> no pattern information
    assert q0["cohesion"] is None and q0["cohesion_score"] is None
    assert q0["competitive_ratio"] >= 1.0  # still computed from the tiles


# --- gauges + scrape ---------------------------------------------------------


def test_plan_gauges_land_in_live_openmetrics_scrape():
    reg = MatrixRegistry(search=False, strategy="stable")
    reg.admit(_skewed(), "skewed", cfg=CFG)
    text = render_openmetrics([reg.metrics])
    fam = parse_openmetrics(text)
    for family in (
        "plan_competitive_ratio",
        "plan_rowgroup_imbalance",
        "plan_cohesion_score",
        "plan_tile_occupancy",
        "plan_autotune_searched",
    ):
        assert family in fam, f"{family} missing from the scrape"
    (s,) = fam["plan_competitive_ratio"]["samples"]
    assert s["labels"]["matrix"] == "skewed"
    assert s["value"] > SKEWED_RATIO_MIN


def test_register_plan_metrics_skips_missing_values():
    from repro.obs.metrics import MetricRegistry

    m = MetricRegistry(name="t-planview")
    register_plan_metrics(m, "empty", {"tiles": 0.0, "cohesion": None})
    assert m.value("plan.tiles", matrix="empty") == 0.0
    assert m.get("plan.cohesion", matrix="empty") is None


# --- provenance --------------------------------------------------------------


def test_admission_records_autotune_provenance(tmp_path):
    import json

    candidates = [
        PartitionConfig(row_block=64, col_block=128, group=8, lane=8),
        PartitionConfig(row_block=128, col_block=128, group=8, lane=16),
    ]
    reg = MatrixRegistry(
        cache_dir=tmp_path / "cache", candidates=candidates, strategy="stable"
    )
    csr = banded_fem(400, band=3, seed=1)
    plan = reg.admit(csr, "tuned")
    prov = plan.provenance
    assert prov["searched"] and not prov["cache_hit"] and not prov["pinned"]
    assert len(prov["trials"]) == len(candidates)
    # fastest first, and the winner is the served config
    objs = [t["objective_us"] for t in prov["trials"]]
    assert objs == sorted(objs)
    import dataclasses

    assert prov["trials"][0]["config"] == dataclasses.asdict(plan.cfg)
    # ... persisted into the on-disk cache entry too
    (entry,) = list((tmp_path / "cache").glob("*.json"))
    cached = json.loads(entry.read_text())
    assert len(cached["trials"]) == len(candidates)
    # a second registry over the same cache explains from the cached trials
    reg2 = MatrixRegistry(
        cache_dir=tmp_path / "cache", candidates=candidates, strategy="stable"
    )
    plan2 = reg2.admit(csr, "tuned")
    assert plan2.provenance["cache_hit"]
    assert plan2.provenance["trials"] == prov["trials"]
    # provenance describes the plan but never leaks into kernel kwargs
    assert "trials" not in plan._meta() and "provenance" not in plan._meta()


def test_pinned_admission_has_empty_provenance():
    reg = MatrixRegistry(search=False, strategy="stable")
    plan = reg.admit(banded_fem(300, band=2, seed=3), "pinned", cfg=CFG)
    prov = plan.provenance
    assert prov["pinned"] and not prov["searched"] and prov["trials"] == []
    stats = reg.stats()["pinned"]
    assert stats["provenance"]["pinned"]
    assert "occupancy_sample" not in stats["quality"]
    assert stats["quality"]["competitive_ratio"] >= 1.0


# --- explain -----------------------------------------------------------------


def test_explain_round_trips_from_a_real_dump(tmp_path):
    import json

    reg = MatrixRegistry(search=False, strategy="stable")
    reg.admit(_skewed(), "skewed", cfg=CFG)
    path = tmp_path / "obs.json"
    obs.dump(path)
    snapshot = json.loads(path.read_text())
    report = explain_report(snapshot, "skewed")
    assert "== explain: skewed ==" in report
    assert "competitive ratio" in report and "cohesion" in report
    assert "IMBALANCED" in report  # the skew must reach the verdict line
    pm = plan_metrics_from_snapshot(snapshot, "skewed")
    assert pm["competitive_ratio"] > SKEWED_RATIO_MIN
    # deterministic: same snapshot, same text
    assert explain_report(snapshot, "skewed") == report


def test_explain_is_na_safe_on_empty_and_partial_dumps():
    empty = {"schema": 1, "registries": [], "spans": [], "requests": []}
    report = explain_report(empty, "ghost")
    assert "n/a" in report and "ghost" in report
    assert explain_report(empty, "ghost") == report  # deterministic
    partial = {
        "registries": [
            {
                "registry": "r",
                "metrics": [
                    {
                        "name": "plan.competitive_ratio",
                        "labels": {"matrix": "p"},
                        "type": "gauge",
                        "value": 1.01,
                    }
                ],
            }
        ]
    }
    rep = explain_report(partial, "p")
    assert "balanced" in rep  # verdict renders from the one gauge present
    assert "n/a" in rep  # everything else degrades, nothing raises


# --- flight-recorder default dump dir ---------------------------------------


def test_flight_default_dump_dir_is_not_cwd(tmp_path, monkeypatch):
    from repro.obs.flight import DEFAULT_DUMP_DIR, FlightRecorder

    monkeypatch.delenv("REPRO_FLIGHT_DIR", raising=False)
    monkeypatch.chdir(tmp_path)
    fl = FlightRecorder(capacity=8)
    fl.record("t")
    path = fl.trigger("unit_test")
    assert path is not None
    # the artifact landed under the dedicated (gitignored) subdirectory,
    # never loose in the working directory
    assert (tmp_path / DEFAULT_DUMP_DIR).is_dir()
    assert not list(tmp_path.glob("flight_*.json"))
    assert DEFAULT_DUMP_DIR in path


def test_flight_env_override_still_wins(tmp_path, monkeypatch):
    from repro.obs.flight import FlightRecorder

    target = tmp_path / "elsewhere"
    monkeypatch.setenv("REPRO_FLIGHT_DIR", str(target))
    fl = FlightRecorder(capacity=8)
    assert fl.trigger("unit_test").startswith(str(target))


@pytest.fixture(autouse=True)
def _quiet_flight_budget():
    """Keep admissions in this module from exhausting the global flight
    recorder's dump budget for later tests."""
    yield
    from repro.obs.flight import get_flight

    get_flight().reset()
