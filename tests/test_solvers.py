"""Iterative solvers vs dense numpy references.

Covers the acceptance criteria: CG and power iteration on every scaled
Table-I structural family through the HBP Pallas path (``interpret=True``
on CPU), matching ``np.linalg.solve`` / ``np.linalg.eigvalsh`` to 1e-5,
with multi-RHS solves validated against per-column runs.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import PartitionConfig, build_tiles, csr_from_dense
from repro.core.matrices import banded_fem, circuit, dense_block, rmat
from repro.solvers import (
    LinearOperator,
    aslinearoperator,
    bicgstab,
    block_jacobi,
    cg,
    chebyshev,
    estimate_spectrum,
    hash_group_blocks,
    jacobi,
    pagerank,
    power_iteration,
    transition_matrix,
)

CFG = PartitionConfig(row_block=64, col_block=128, group=8, lane=16)

# SPD analogues of the suite's structural families: S = A A^T / n + I keeps
# each family's sparsity signature while guaranteeing a well-conditioned
# symmetric positive definite system with a dense-solve reference.
FAMILIES = {
    "rmat": lambda: rmat(1 << 7, 900, seed=4),
    "circuit": lambda: circuit(128, seed=1, n_dense_rows=2, dense_row_frac=0.05),
    "banded_fem": lambda: banded_fem(128, seed=3, band=4, fill=0.9),
    "dense_block": lambda: dense_block(128, seed=8, block=24, n_blocks=2, background=3.0),
}


def spd_family(name):
    A = FAMILIES[name]().to_dense().astype(np.float64)
    n = A.shape[0]
    return (A @ A.T / n + np.eye(n)).astype(np.float32)


@pytest.fixture(scope="module")
def spd64():
    rng = np.random.default_rng(0)
    G = rng.standard_normal((64, 64)).astype(np.float32) * (rng.random((64, 64)) < 0.3)
    return (G @ G.T / 64 + 2 * np.eye(64, dtype=np.float32)).astype(np.float32)


# --- operator abstraction -------------------------------------------------


def test_operator_adapts_every_container(spd64, rng):
    x = rng.standard_normal(64).astype(np.float32)
    X = rng.standard_normal((64, 3)).astype(np.float32)
    csr = csr_from_dense(spd64)
    tiles = build_tiles(csr, PartitionConfig(row_block=32, col_block=32, group=8, lane=8))
    y_ref = spd64 @ x
    Y_ref = spd64 @ X
    for container in (spd64, csr, tiles):
        op = aslinearoperator(container, interpret=True)
        np.testing.assert_allclose(np.asarray(op(x)), y_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(op(X)), Y_ref, rtol=1e-4, atol=1e-4)
    # matvec-only operators synthesize matmat column by column
    op = LinearOperator(spd64.shape, matvec=lambda v: jnp.asarray(spd64) @ v)
    np.testing.assert_allclose(np.asarray(op.matmat(jnp.asarray(X))), Y_ref, rtol=1e-4, atol=1e-4)


def test_operator_rejects_unknown():
    with pytest.raises(TypeError):
        aslinearoperator("not a matrix")
    with pytest.raises(ValueError):
        aslinearoperator(np.ones(3, np.float32))


# --- CG -------------------------------------------------------------------


def test_cg_dense_matches_np_solve(spd64, rng):
    b = rng.standard_normal(64).astype(np.float32)
    res = cg(spd64, b, tol=1e-7, maxiter=500)
    x_ref = np.linalg.solve(spd64.astype(np.float64), b)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), x_ref, rtol=1e-5, atol=1e-5)
    # history: finite prefix ends at the final residual, NaN beyond
    hist = np.asarray(res.history)
    k = int(res.iterations)
    assert np.isfinite(hist[: k + 1]).all()
    assert np.isnan(hist[k + 1 :]).all()
    np.testing.assert_allclose(hist[k], float(res.residual), rtol=1e-6)


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_cg_converges_on_suite_families_hbp(family, rng):
    """Acceptance: CG through the HBP Pallas path on every family."""
    S = spd_family(family)
    tiles = build_tiles(csr_from_dense(S), CFG)
    b = rng.standard_normal(S.shape[0]).astype(np.float32)
    res = cg(tiles, b, tol=1e-7, maxiter=800)
    x_ref = np.linalg.solve(S.astype(np.float64), b)
    assert bool(res.converged)
    err = np.abs(np.asarray(res.x) - x_ref).max() / np.abs(x_ref).max()
    assert err < 1e-5


def test_cg_multirhs_matches_columnwise(spd64, rng):
    """Blocked-RHS CG (one SpMM per iteration) == k independent solves."""
    tiles = build_tiles(csr_from_dense(spd64), PartitionConfig(row_block=32, col_block=32, group=8, lane=8))
    B = rng.standard_normal((64, 4)).astype(np.float32)
    res = cg(tiles, B, tol=1e-7, maxiter=500)
    assert bool(res.converged)
    X_ref = np.linalg.solve(spd64.astype(np.float64), B)
    np.testing.assert_allclose(np.asarray(res.x), X_ref, rtol=1e-4, atol=1e-5)
    for j in range(4):
        single = cg(tiles, B[:, j], tol=1e-7, maxiter=500)
        np.testing.assert_allclose(np.asarray(res.x)[:, j], np.asarray(single.x), atol=1e-5)


def test_cg_is_jittable(spd64, rng):
    op = aslinearoperator(spd64)
    solve = jax.jit(lambda b: cg(op, b, tol=1e-7, maxiter=500).x)
    b = rng.standard_normal(64).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(solve(b)), np.linalg.solve(spd64.astype(np.float64), b), atol=1e-5
    )


# --- BiCGSTAB -------------------------------------------------------------


def test_bicgstab_nonsymmetric_matches_np_solve(rng):
    n = 64
    G = rng.standard_normal((n, n)).astype(np.float32) * (rng.random((n, n)) < 0.3)
    N = (G + 8 * np.eye(n, dtype=np.float32)).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    res = bicgstab(N, b, tol=1e-8, maxiter=1000)
    assert bool(res.converged)
    np.testing.assert_allclose(
        np.asarray(res.x), np.linalg.solve(N.astype(np.float64), b), rtol=1e-4, atol=1e-5
    )


def test_bicgstab_hbp_path_multirhs(rng):
    n = 128
    A = circuit(n, seed=2, n_dense_rows=2, dense_row_frac=0.05).to_dense().astype(np.float32)
    N = (A + (np.abs(A).sum(axis=1).max() + 1) * np.eye(n, dtype=np.float32)).astype(np.float32)
    tiles = build_tiles(csr_from_dense(N), CFG)
    B = rng.standard_normal((n, 3)).astype(np.float32)
    res = bicgstab(tiles, B, tol=1e-7, maxiter=1000)
    assert bool(res.converged)
    X_ref = np.linalg.solve(N.astype(np.float64), B)
    err = np.abs(np.asarray(res.x) - X_ref).max() / np.abs(X_ref).max()
    assert err < 1e-5


# --- Jacobi preconditioning -----------------------------------------------


def badly_scaled_spd(n, rng):
    """SPD with a diagonal spanning 4 decades: S A S for A ~ I."""
    R = rng.standard_normal((n, n)) * 0.02
    A = np.eye(n) + R @ R.T
    s = 10.0 ** rng.uniform(-2, 2, n)
    S = (A * s).T * s
    return ((S + S.T) / 2).astype(np.float32)


def test_csr_diagonal_sums_duplicates():
    """diagonal() must match matvec semantics: duplicate entries sum."""
    from repro.core import COOMatrix, csr_from_coo

    coo = COOMatrix([0, 0, 1], [0, 0, 2], [1.0, 2.0, 5.0], (3, 3))
    csr = csr_from_coo(coo, sum_duplicates=False)
    e0 = np.zeros(3)
    e0[0] = 1.0
    assert csr.matvec(e0)[0] == 3.0
    np.testing.assert_allclose(csr.diagonal(), [3.0, 0.0, 0.0])
    # rectangular: diagonal length is min(shape)
    wide = csr_from_coo(COOMatrix([0, 1], [0, 1], [4.0, 6.0], (2, 5)))
    np.testing.assert_allclose(wide.diagonal(), [4.0, 6.0])


def test_jacobi_accepts_csr_dense_and_diag(rng):
    A = badly_scaled_spd(32, rng)
    x = rng.standard_normal(32).astype(np.float32)
    want = (x / np.diagonal(A)).astype(np.float32)
    for M in (jacobi(csr_from_dense(A)), jacobi(A), jacobi(np.diagonal(A))):
        np.testing.assert_allclose(np.asarray(M(x)), want, rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(M(np.stack([x, 2 * x], axis=1)))[:, 1], 2 * want, rtol=1e-6
        )
    # zero diagonal entries fall back to identity scale
    M0 = jacobi(np.array([2.0, 0.0, 4.0], np.float32))
    np.testing.assert_allclose(
        np.asarray(M0(np.ones(3, np.float32))), [0.5, 1.0, 0.25], rtol=1e-6
    )
    with pytest.raises(ValueError):
        jacobi(np.ones((2, 2, 2), np.float32))


def test_jacobi_cg_converges_in_fewer_iterations(rng):
    """The ROADMAP acceptance: Jacobi-preconditioned CG needs fewer
    iterations than plain CG on a badly diagonal-scaled SPD system."""
    A = badly_scaled_spd(128, rng)
    csr = csr_from_dense(A)
    b = rng.standard_normal(128).astype(np.float32)
    plain = cg(csr, b, tol=1e-6, maxiter=600)
    pcg = cg(csr, b, tol=1e-6, maxiter=600, M=jacobi(csr))
    assert bool(pcg.converged)
    assert int(pcg.iterations) < int(plain.iterations)
    x_ref = np.linalg.solve(A.astype(np.float64), b)
    err = np.abs(np.asarray(pcg.x) - x_ref).max() / np.abs(x_ref).max()
    assert err < 1e-4


def test_jacobi_cg_through_hbp_plan_diagonal(rng):
    """Preconditioned CG with the diagonal captured at tile-build time —
    the serving-registry composition (plan.diag -> jacobi -> M=)."""
    A = badly_scaled_spd(96, rng)
    csr = csr_from_dense(A)
    tiles = build_tiles(csr, CFG)
    b = rng.standard_normal(96).astype(np.float32)
    res = cg(tiles, b, tol=1e-6, maxiter=600, M=jacobi(csr.diagonal()))
    assert bool(res.converged)
    x_ref = np.linalg.solve(A.astype(np.float64), b)
    assert np.abs(np.asarray(res.x) - x_ref).max() / np.abs(x_ref).max() < 1e-4


def block_diag_dominant_spd(n, bs, rng, coupling=0.05):
    """SPD matrix with strong [bs, bs] diagonal blocks + weak off-block
    coupling — the regime where block-Jacobi beats point Jacobi."""
    A = np.zeros((n, n))
    for lo in range(0, n, bs):
        B = rng.standard_normal((bs, bs))
        A[lo : lo + bs, lo : lo + bs] = B @ B.T + bs * np.eye(bs)
    R = rng.standard_normal((n, n)) * coupling
    return (A + R @ R.T).astype(np.float32)


def test_block_jacobi_exact_on_block_diagonal(rng):
    """On a purely block-diagonal matrix the preconditioner IS the inverse."""
    n, bs = 64, 8
    A = block_diag_dominant_spd(n, bs, rng, coupling=0.0)
    M = block_jacobi(csr_from_dense(A), block_size=bs)
    x = rng.standard_normal(n).astype(np.float32)
    want = np.linalg.solve(A.astype(np.float64), x)
    np.testing.assert_allclose(np.asarray(M(x)), want, rtol=1e-4, atol=1e-5)
    # blocked RHS goes through the batched einsum path
    X = rng.standard_normal((n, 3)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(M(X)), np.linalg.solve(A.astype(np.float64), X), rtol=1e-4, atol=1e-5
    )


def test_block_jacobi_cg_beats_point_jacobi(rng):
    """The ROADMAP acceptance: on a block-diagonal-dominant system,
    block-Jacobi PCG needs fewer iterations than point-Jacobi PCG."""
    n, bs = 128, 8
    A = block_diag_dominant_spd(n, bs, rng)
    csr = csr_from_dense(A)
    b = rng.standard_normal(n).astype(np.float32)
    point = cg(csr, b, tol=1e-8, maxiter=400, M=jacobi(csr))
    block = cg(csr, b, tol=1e-8, maxiter=400, M=block_jacobi(csr, block_size=bs))
    assert bool(block.converged)
    assert int(block.iterations) < int(point.iterations)
    x_ref = np.linalg.solve(A.astype(np.float64), b)
    assert np.abs(np.asarray(block.x) - x_ref).max() / np.abs(x_ref).max() < 1e-4


def test_block_jacobi_hash_group_partition(rng):
    """The tile-format composition: one dense [group, group] inverse per
    hash group, partition straight from HBPTiles."""
    n = 128
    A = block_diag_dominant_spd(n, 8, rng)
    csr = csr_from_dense(A)
    tiles = build_tiles(csr, CFG)
    blocks = hash_group_blocks(tiles)
    # a true partition of the row space at hash-group granularity
    flat = np.concatenate(blocks)
    assert np.array_equal(np.sort(flat), np.arange(n))
    assert all(len(b) <= tiles.cfg.group for b in blocks)
    res = cg(tiles, rng.standard_normal(n).astype(np.float32), tol=1e-8,
             maxiter=400, M=block_jacobi(csr, blocks=blocks))
    assert bool(res.converged)


def test_block_jacobi_partial_cover_and_validation(rng):
    n = 32
    A = block_diag_dominant_spd(n, 8, rng, coupling=0.0)
    csr = csr_from_dense(A)
    # rows outside the listed blocks fall back to point Jacobi
    M = block_jacobi(csr, blocks=[np.arange(0, 8), np.arange(16, 24)])
    x = np.ones(n, np.float32)
    y = np.asarray(M(x))
    np.testing.assert_allclose(
        y[:8], np.linalg.solve(A[:8, :8].astype(np.float64), x[:8]), rtol=1e-4
    )
    np.testing.assert_allclose(y[8:16], x[8:16] / np.diagonal(A)[8:16], rtol=1e-5)
    with pytest.raises(ValueError, match="disjoint"):
        block_jacobi(csr, blocks=[np.arange(0, 8), np.arange(4, 12)])
    with pytest.raises(ValueError, match="outside"):
        block_jacobi(csr, blocks=[np.array([40])])
    with pytest.raises(TypeError, match="CSR"):
        block_jacobi(build_tiles(csr, CFG))


def test_jacobi_bicgstab_converges_in_fewer_iterations(rng):
    n = 128
    G = np.eye(n) + rng.standard_normal((n, n)) * 0.01
    s = 10.0 ** rng.uniform(-2, 2, n)
    N = ((G * s).T * s).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    plain = bicgstab(csr_from_dense(N), b, tol=1e-6, maxiter=800)
    pre = bicgstab(csr_from_dense(N), b, tol=1e-6, maxiter=800, M=jacobi(csr_from_dense(N)))
    assert bool(pre.converged)
    assert int(pre.iterations) < int(plain.iterations)
    x_ref = np.linalg.solve(N.astype(np.float64), b)
    assert np.abs(np.asarray(pre.x) - x_ref).max() / np.abs(x_ref).max() < 1e-4


# --- Chebyshev ------------------------------------------------------------


def test_chebyshev_with_exact_bounds(spd64, rng):
    ev = np.linalg.eigvalsh(spd64.astype(np.float64))
    b = rng.standard_normal(64).astype(np.float32)
    res = chebyshev(spd64, b, lam_min=float(ev[0]), lam_max=float(ev[-1]), tol=1e-7, maxiter=3000)
    assert bool(res.converged)
    np.testing.assert_allclose(
        np.asarray(res.x), np.linalg.solve(spd64.astype(np.float64), b), rtol=1e-4, atol=1e-5
    )


def test_chebyshev_estimated_bounds_smooths(spd64, rng):
    """With power-iteration bounds the residual must strictly decrease —
    the smoothing-pass contract (fixed degree, tol=0)."""
    lam_min, lam_max = estimate_spectrum(spd64, maxiter=50)
    b = rng.standard_normal(64).astype(np.float32)
    res = chebyshev(spd64, b, lam_min=lam_min, lam_max=lam_max, tol=0.0, maxiter=30)
    hist = np.asarray(res.history)
    assert int(res.iterations) == 30
    assert hist[30] < 1e-2 * hist[0]


def test_chebyshev_rejects_bad_bounds(spd64):
    with pytest.raises(ValueError):
        chebyshev(spd64, np.ones(64, np.float32), lam_min=2.0, lam_max=1.0)


# --- power iteration / PageRank ------------------------------------------


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_power_iteration_on_suite_families_hbp(family):
    """Acceptance: power iteration through the HBP Pallas path matches the
    dense dominant eigenvalue to 1e-5 on every family."""
    S = spd_family(family)
    tiles = build_tiles(csr_from_dense(S), CFG)
    res = power_iteration(tiles, tol=1e-6, maxiter=3000)
    lam_ref = float(np.linalg.eigvalsh(S.astype(np.float64))[-1])
    assert bool(res.converged)
    assert abs(float(res.eigenvalue) - lam_ref) / lam_ref < 1e-5
    # eigenvector residual: ||S v - lam v|| small relative to lam
    v = np.asarray(res.eigenvector)
    assert np.linalg.norm(S @ v - float(res.eigenvalue) * v) < 1e-4 * lam_ref


def test_pagerank_matches_dense_reference(rng):
    n = 96
    A = (rng.random((n, n)) < 0.08).astype(np.float32)
    np.fill_diagonal(A, 0)
    M, dang = transition_matrix(csr_from_dense(A))
    res = pagerank(M, damping=0.85, dangling=dang, tol=1e-10, maxiter=500)
    p = np.asarray(res.x)
    assert bool(res.converged)
    np.testing.assert_allclose(p.sum(), 1.0, atol=1e-5)
    Md = M.to_dense().astype(np.float64)
    v = np.full(n, 1.0 / n)
    q = v.copy()
    for _ in range(2000):
        q_new = 0.85 * (Md @ q + (dang.astype(np.float64) @ q) * v) + 0.15 * v
        done = np.abs(q_new - q).sum() < 1e-14 * n
        q = q_new
        if done:
            break
    np.testing.assert_allclose(p, q, atol=1e-6)


def test_pagerank_multi_personalization_spmm(rng):
    """k personalization vectors in one run (SpMM path) == k single runs."""
    adj = rmat(1 << 7, 600, seed=9, symmetric=False)
    M, dang = transition_matrix(adj)
    tiles = build_tiles(M, CFG)
    n = adj.n_rows
    P = rng.random((n, 3)).astype(np.float32) + 0.01
    multi = pagerank(tiles, personalization=P, dangling=dang, tol=1e-10, maxiter=300)
    assert bool(multi.converged)
    pm = np.asarray(multi.x)
    np.testing.assert_allclose(pm.sum(axis=0), np.ones(3), atol=1e-5)
    for j in range(3):
        single = pagerank(tiles, personalization=P[:, j], dangling=dang, tol=1e-10, maxiter=300)
        np.testing.assert_allclose(pm[:, j], np.asarray(single.x), atol=1e-6)


# --- convergence telemetry (record_history) --------------------------------


@pytest.mark.parametrize("solver_kwargs", [
    (cg, {}),
    (bicgstab, {}),
], ids=["cg", "bicgstab"])
def test_record_history_false_single_slot_same_solution(solver_kwargs, spd64, rng):
    solver, kw = solver_kwargs
    b = rng.standard_normal(64).astype(np.float32)
    full = solver(spd64, b, tol=1e-7, maxiter=500, **kw)
    lean = solver(spd64, b, tol=1e-7, maxiter=500, record_history=False, **kw)
    assert np.asarray(lean.history).shape == (1,)  # initial norm only
    assert np.asarray(full.history).shape == (501,)
    # the iteration itself is untouched: same trajectory, same exit
    assert int(lean.iterations) == int(full.iterations)
    np.testing.assert_array_equal(np.asarray(lean.x), np.asarray(full.x))
    np.testing.assert_allclose(
        np.asarray(lean.history)[0], np.asarray(full.history)[0], rtol=1e-6
    )


def test_chebyshev_record_history_false(spd64, rng):
    lo, hi = estimate_spectrum(spd64)
    b = rng.standard_normal(64).astype(np.float32)
    full = chebyshev(spd64, b, lam_min=lo, lam_max=hi, tol=0.0, maxiter=30)
    lean = chebyshev(
        spd64, b, lam_min=lo, lam_max=hi, tol=0.0, maxiter=30, record_history=False
    )
    assert np.asarray(lean.history).shape == (1,)
    np.testing.assert_array_equal(np.asarray(lean.x), np.asarray(full.x))


def test_cg_history_is_monotone_ish(spd64, rng):
    """The recorded residual stream behaves like CG on an SPD system:
    overall decay by orders of magnitude, no sustained growth.  (CG's
    2-norm residual is not strictly monotone, so assert a loose envelope:
    each residual stays under 10x the running minimum.)"""
    b = rng.standard_normal(64).astype(np.float32)
    res = cg(spd64, b, tol=1e-8, maxiter=500)
    hist = np.asarray(res.history)[: int(res.iterations) + 1]
    assert hist[-1] < 1e-6 * hist[0]  # decayed hard
    running_min = np.minimum.accumulate(hist)
    assert np.all(hist <= 10.0 * np.maximum(running_min, 1e-30))


def test_record_history_streams_to_obs(spd64, rng):
    """With obs enabled the carried history surfaces as a metric stream;
    record_history=False keeps the stream silent."""
    from repro import obs

    b = rng.standard_normal(64).astype(np.float32)
    obs.reset()
    obs.enable()
    try:
        res = cg(spd64, b, tol=1e-7, maxiter=500)
        cg(spd64, b, tol=1e-7, maxiter=500, record_history=False)
        streams = obs.registry().find("solver.cg.residual")
        assert len(streams) == 1  # only the recording run emitted
        (s,) = streams
        assert len(s.points) == int(res.iterations) + 1
        vals = np.asarray(s.values)
        assert vals[-1] < vals[0]
    finally:
        obs.disable()
        obs.reset()
