"""Autotuned partition configs: search space, content hash, on-disk cache.

The acceptance property lives here: the first admission of a matrix runs
the measured search (or the heuristic, when search is disabled) and writes
the winner to the cache; every later admission of the same content — same
registry, fresh registry, fresh process — skips the search and reuses the
cached config.
"""
import json

import pytest

from repro.core import PartitionConfig, enumerate_configs
from repro.core.matrices import circuit
from repro.core.tile import tuned_partition_config
from repro.serving import (
    AutotuneCache,
    MatrixRegistry,
    Probe,
    autotune_partition,
    cg_probe,
    matrix_hash,
    spmm_probe,
)

# tiny geometries keep each measured build/launch in the milliseconds
CANDIDATES = [
    PartitionConfig(row_block=64, col_block=128, group=8, lane=8),
    PartitionConfig(row_block=64, col_block=256, group=8, lane=16),
    PartitionConfig(row_block=128, col_block=128, group=8, lane=32),
]


@pytest.fixture()
def csr():
    return circuit(400, seed=2)


# --- search space ---------------------------------------------------------


def test_enumerate_configs_clips_and_dedups():
    cfgs = enumerate_configs((100, 200))
    assert cfgs, "search space must be non-empty"
    for cfg in cfgs:
        assert cfg.row_block <= 128  # next_pow2(100)
        assert cfg.col_block <= 256  # next_pow2(200)
        assert cfg.row_block % cfg.group == 0
    assert len({(c.row_block, c.col_block, c.group, c.lane) for c in cfgs}) == len(cfgs)
    # a big matrix keeps the nominal grid
    big = enumerate_configs((100_000, 100_000))
    assert any(c.row_block == 512 and c.col_block == 4096 for c in big)
    # group that divides no row_block -> empty, not an error
    assert enumerate_configs((64, 64), row_blocks=(64,), groups=(48,)) == []


# --- content hash ---------------------------------------------------------


def test_matrix_hash_is_content_addressed(csr):
    import copy

    assert matrix_hash(csr) == matrix_hash(copy.deepcopy(csr))
    other = circuit(400, seed=3)
    assert matrix_hash(csr) != matrix_hash(other)
    # value changes rehash, not just structure
    changed = copy.deepcopy(csr)
    changed.data = changed.data.copy()
    changed.data[0] += 1.0
    assert matrix_hash(csr) != matrix_hash(changed)


# --- measured search + cache ----------------------------------------------


def test_search_then_cache_round_trip(tmp_path, csr):
    cache = AutotuneCache(tmp_path / "cache")
    first = autotune_partition(csr, cache=cache, candidates=CANDIDATES, repeats=1)
    assert first.searched and not first.cache_hit
    assert first.evaluations == len(CANDIDATES)
    assert first.objective_us is not None and first.objective_us > 0
    assert first.cfg in CANDIDATES

    second = autotune_partition(csr, cache=cache, candidates=CANDIDATES, repeats=1)
    assert second.cache_hit and not second.searched
    assert second.evaluations == 0
    assert second.cfg == first.cfg
    # the persisted entry is plain JSON, keyed by the content hash
    entry = json.loads((tmp_path / "cache" / f"{matrix_hash(csr)}.json").read_text())
    assert PartitionConfig(**entry["config"]) == first.cfg


def test_search_disabled_falls_back_to_heuristic(tmp_path, csr):
    cache = AutotuneCache(tmp_path / "cache")
    res = autotune_partition(csr, cache=cache, search=False)
    assert not res.searched and not res.cache_hit and res.evaluations == 0
    assert res.cfg == tuned_partition_config(csr)
    # the heuristic result is cached like a searched one
    again = autotune_partition(csr, cache=cache, search=False)
    assert again.cache_hit and again.cfg == res.cfg


def test_search_upgrades_heuristic_cache_entry(tmp_path, csr):
    """A heuristic entry must not permanently satisfy search=True callers:
    the first measured admission upgrades it, after which both modes hit."""
    cache = AutotuneCache(tmp_path / "cache")
    heur = autotune_partition(csr, cache=cache, search=False)
    upgraded = autotune_partition(csr, cache=cache, candidates=CANDIDATES, repeats=1)
    assert upgraded.searched and not upgraded.cache_hit
    assert upgraded.evaluations == len(CANDIDATES)
    assert autotune_partition(csr, cache=cache, candidates=CANDIDATES).cache_hit
    # and the searched entry satisfies heuristic callers too
    res = autotune_partition(csr, cache=cache, search=False)
    assert res.cache_hit and res.cfg == upgraded.cfg
    del heur


def test_searched_entry_is_keyed_by_candidate_space(tmp_path, csr):
    """A search over a narrow candidate space must not satisfy a later
    admission searching a different space — it re-searches and overwrites."""
    cache = AutotuneCache(tmp_path / "cache")
    narrow = autotune_partition(csr, cache=cache, candidates=CANDIDATES[:1], repeats=1)
    assert narrow.searched
    full = autotune_partition(csr, cache=cache, candidates=CANDIDATES, repeats=1)
    assert full.searched and not full.cache_hit
    assert full.evaluations == len(CANDIDATES)
    # the full-space result now owns the entry
    assert autotune_partition(csr, cache=cache, candidates=CANDIDATES).cache_hit
    # zero-traffic matrices still hit for heuristic callers
    assert autotune_partition(csr, cache=cache, search=False).cache_hit


def test_corrupt_cache_entry_is_a_miss(tmp_path, csr):
    cache = AutotuneCache(tmp_path / "cache")
    autotune_partition(csr, cache=cache, search=False)
    entry = tmp_path / "cache" / f"{matrix_hash(csr)}.json"
    entry.write_text("{not json")
    res = autotune_partition(csr, cache=cache, search=False)
    assert not res.cache_hit  # recomputed, rewritten
    assert autotune_partition(csr, cache=cache, search=False).cache_hit


def test_empty_candidates_uses_heuristic(tmp_path, csr):
    res = autotune_partition(
        csr, cache=AutotuneCache(tmp_path / "c"), candidates=[], repeats=1
    )
    assert not res.searched
    assert res.cfg == tuned_partition_config(csr)


# --- probe hook: solver-objective search -----------------------------------


def test_cg_probe_searches_and_caches(tmp_path, csr):
    """Time-to-tolerance ranking: a fixed-iteration CG run per candidate,
    cached like any measured search."""
    cache = AutotuneCache(tmp_path / "cache")
    probe = cg_probe(iters=3)
    res = autotune_partition(
        csr, cache=cache, candidates=CANDIDATES, repeats=1, probe=probe
    )
    assert res.searched and res.evaluations == len(CANDIDATES)
    assert res.objective_us is not None and res.objective_us > 0
    again = autotune_partition(
        csr, cache=cache, candidates=CANDIDATES, repeats=1, probe=probe
    )
    assert again.cache_hit and again.cfg == res.cfg


def test_probe_kind_fingerprints_cache_entries(tmp_path, csr):
    """Satellite acceptance: an entry searched under one objective must not
    satisfy an admission searching under another — the probe kind is part
    of the cache fingerprint."""
    cache = AutotuneCache(tmp_path / "cache")
    spmm_res = autotune_partition(csr, cache=cache, candidates=CANDIDATES, repeats=1)
    assert spmm_res.searched
    solver = autotune_partition(
        csr, cache=cache, candidates=CANDIDATES, repeats=1, probe=cg_probe(iters=3)
    )
    assert solver.searched and not solver.cache_hit  # spmm entry did not satisfy
    # the solver entry now owns the cache: solver callers hit, spmm re-search
    assert autotune_partition(
        csr, cache=cache, candidates=CANDIDATES, repeats=1, probe=cg_probe(iters=3)
    ).cache_hit
    assert autotune_partition(
        csr, cache=cache, candidates=CANDIDATES, repeats=1
    ).searched
    # distinct solver objectives are distinct kinds too
    assert cg_probe(iters=3).kind != cg_probe(iters=10).kind


def test_default_probe_keeps_historical_fingerprint(tmp_path, csr):
    """probe=None and probe=spmm_probe(...) with matching parameters are
    the same search — pre-probe cache entries stay warm."""
    cache = AutotuneCache(tmp_path / "cache")
    autotune_partition(csr, cache=cache, candidates=CANDIDATES, repeats=1)
    res = autotune_partition(
        csr, cache=cache, candidates=CANDIDATES, repeats=1,
        probe=spmm_probe(k=8, strategy="stable"),
    )
    assert res.cache_hit


def test_spmm_probe_params_fingerprint_cache_entries(tmp_path, csr):
    """An explicit spmm_probe with non-default k/strategy is a different
    objective from the default admission — its entry must not satisfy (or
    be satisfied by) a default-probe search."""
    cache = AutotuneCache(tmp_path / "cache")
    wide = autotune_partition(
        csr, cache=cache, candidates=CANDIDATES, repeats=1,
        probe=spmm_probe(k=16, strategy="reference"),
    )
    assert wide.searched
    default = autotune_partition(csr, cache=cache, candidates=CANDIDATES, repeats=1)
    assert default.searched and not default.cache_hit
    # and the default entry now hits only for the default objective
    assert autotune_partition(
        csr, cache=cache, candidates=CANDIDATES, repeats=1
    ).cache_hit
    assert autotune_partition(
        csr, cache=cache, candidates=CANDIDATES, repeats=1,
        probe=spmm_probe(k=16, strategy="reference"),
    ).searched


def test_custom_probe_object(tmp_path, csr):
    """Any (kind, measure) pair drives the search; the winner is whatever
    the objective says."""
    calls = []

    def measure(csr_, cfg, repeats):
        calls.append(cfg)
        return 1.0 if cfg is CANDIDATES[1] else 100.0

    res = autotune_partition(
        csr, cache=AutotuneCache(tmp_path / "c"), candidates=CANDIDATES,
        repeats=1, probe=Probe(kind="synthetic", measure=measure),
    )
    assert len(calls) == len(CANDIDATES)
    assert res.cfg == CANDIDATES[1]


def test_registry_passes_probe_through(tmp_path, csr):
    reg = MatrixRegistry(
        cache_dir=tmp_path / "cache", candidates=CANDIDATES,
        probe=cg_probe(iters=2),
    )
    plan = reg.admit(csr, "A")
    assert plan.autotune_searched
    # fresh registry with the same probe hits the same entry
    reg2 = MatrixRegistry(
        cache_dir=tmp_path / "cache", candidates=CANDIDATES,
        probe=cg_probe(iters=2),
    )
    assert reg2.admit(csr, "A").autotune_cache_hit


# --- registry integration (the acceptance criterion) ----------------------


def test_second_admit_skips_search_and_reuses_config(tmp_path, csr):
    cache_dir = tmp_path / "cache"
    reg1 = MatrixRegistry(cache_dir=cache_dir, candidates=CANDIDATES)
    plan1 = reg1.admit(csr, "A")
    assert plan1.autotune_searched and not plan1.autotune_cache_hit

    # same registry, same content: resident plan, nothing recomputed
    assert reg1.admit(csr) is plan1
    assert plan1.admissions == 2

    # fresh registry (fresh process in production), same cache dir: the
    # on-disk entry supplies the config, no measured search runs
    reg2 = MatrixRegistry(cache_dir=cache_dir, candidates=CANDIDATES)
    plan2 = reg2.admit(csr, "A")
    assert plan2.autotune_cache_hit and not plan2.autotune_searched
    assert plan2.cfg == plan1.cfg
    stats = reg2.stats()["A"]
    assert stats["autotune_cache_hit"] is True


def test_pinned_config_bypasses_autotune(tmp_path, csr):
    reg = MatrixRegistry(cache_dir=tmp_path / "cache", candidates=CANDIDATES)
    plan = reg.admit(csr, "A", cfg=CANDIDATES[0])
    assert plan.cfg == CANDIDATES[0]
    assert not plan.autotune_searched and not plan.autotune_cache_hit
    assert not (tmp_path / "cache").exists()  # nothing was written
    # re-admitting resident content with the same pin is fine...
    assert reg.admit(csr, cfg=CANDIDATES[0]) is plan
    # ...but a conflicting pin must not be silently ignored
    with pytest.raises(ValueError, match="already resident"):
        reg.admit(csr, cfg=CANDIDATES[1])
