"""Pallas HBP SpMV kernels vs the jnp oracle and the dense matmul.

Sweeps shapes/dtypes per the deliverable: every strategy (fused beyond-paper,
partials paper-faithful, reference) must agree with ``ref.py`` and with the
dense oracle in interpret mode.
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st


from repro.core import PartitionConfig, build_tiles, csr_from_dense
from repro.kernels import hbp_spmv


CASES = [
    (64, 64, 0.3, "hash"),
    (100, 120, 0.1, "hash"),
    (300, 500, 0.03, "hash"),
    (257, 130, 0.02, "none"),
    (64, 300, 0.15, "sort2d"),
]


@pytest.mark.parametrize("m,k,density,method", CASES)
@pytest.mark.parametrize("strategy", ["fused", "partials", "reference"])
def test_hbp_spmv_strategies_match_dense(m, k, density, method, strategy, rng):
    dense = (rng.standard_normal((m, k)) * (rng.random((m, k)) < density)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=64, col_block=128, group=8, lane=32)
    tiles = build_tiles(csr, cfg, method=method)
    x = rng.standard_normal(k).astype(np.float32)
    y = np.asarray(hbp_spmv(tiles, x, strategy=strategy, interpret=True))
    y_ref = dense @ x
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


@given(
    st.integers(8, 120),
    st.integers(8, 200),
    st.floats(0.01, 0.4),
    st.integers(0, 10),
    st.sampled_from([(4, 8), (8, 16), (8, 128)]),
)
@settings(max_examples=20, deadline=None)
def test_hbp_fused_property(m, k, density, seed, geom):
    rng = np.random.default_rng(seed)
    dense = (rng.standard_normal((m, k)) * (rng.random((m, k)) < density)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    group, lane = geom
    cfg = PartitionConfig(row_block=4 * group, col_block=2 * lane, group=group, lane=lane)
    tiles = build_tiles(csr, cfg, method="hash")
    x = rng.standard_normal(k).astype(np.float32)
    y = np.asarray(hbp_spmv(tiles, x, strategy="fused", interpret=True))
    np.testing.assert_allclose(y, dense @ x, rtol=1e-4, atol=1e-4)


def test_fused_equals_partials_bitwise_structure(rng):
    """Fused (combine-in-kernel) and partials (explicit combine) are the
    same computation reassociated — results agree to fp tolerance."""
    dense = (rng.standard_normal((200, 300)) * (rng.random((200, 300)) < 0.08)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=32, col_block=64, group=8, lane=16)
    tiles = build_tiles(csr, cfg)
    x = rng.standard_normal(300).astype(np.float32)
    yf = np.asarray(hbp_spmv(tiles, x, strategy="fused", interpret=True))
    yp = np.asarray(hbp_spmv(tiles, x, strategy="partials", interpret=True))
    np.testing.assert_allclose(yf, yp, rtol=1e-5, atol=1e-5)


def test_tile_format_invariants(rng):
    dense = (rng.standard_normal((96, 160) ) * (rng.random((96, 160)) < 0.1)).astype(np.float32)
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=32, col_block=32, group=8, lane=8)
    tiles = build_tiles(csr, cfg)
    # grid order: rowgroups non-decreasing; first flags mark run starts
    assert (np.diff(tiles.rowgroup) >= 0).all()
    starts = np.flatnonzero(tiles.first)
    assert starts[0] == 0
    assert np.array_equal(np.unique(tiles.rowgroup[starts]), np.unique(tiles.rowgroup))
    # perm is a permutation of padded rows
    assert sorted(tiles.perm.tolist()) == list(range(tiles.perm.size))
    # every nonzero is represented exactly once
    assert np.count_nonzero(tiles.data) == csr.nnz


def test_tuned_geometry_matches_and_reduces_bytes(rng):
    """Beyond-paper adaptive tile geometry: same results, fewer tile bytes
    on sparse-row matrices (EXPERIMENTS.md §Perf phase 2)."""
    from repro.core import tuned_partition_config
    from repro.core.matrices import circuit

    A = circuit(6000, seed=5)
    x = rng.standard_normal(A.n_cols).astype(np.float32)
    y_ref = A.matvec(x)
    base = build_tiles(A, PartitionConfig())
    tuned = build_tiles(A, tuned_partition_config(A))
    for tiles in (base, tuned):
        y = np.asarray(hbp_spmv(tiles, x, strategy="fused", interpret=True))
        np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    assert tuned.data.size < base.data.size  # less padding streamed
    assert tuned.nnz_utilization() > base.nnz_utilization()


def test_empty_matrix():
    dense = np.zeros((32, 32), np.float32)
    csr = csr_from_dense(dense)
    tiles = build_tiles(csr, PartitionConfig(row_block=16, col_block=16, group=4, lane=4))
    y = np.asarray(hbp_spmv(tiles, np.ones(32, np.float32), strategy="fused", interpret=True))
    assert y.shape == (32,) and (y == 0).all()
