"""Loss formulation and logical sharding rules."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model
from repro.models.params import DECODE_RULES, TRAIN_RULES, logical_spec
from repro.train.steps import loss_fn


def test_masked_sum_ce_equals_gather_ce():
    """The GSPMD-friendly masked-sum CE must equal take_along_axis CE."""
    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 24), 0, cfg.vocab)
    (loss, parts) = loss_fn(model, params, {"tokens": tokens})[0], None
    logits, _, _ = model.forward(params, {"tokens": tokens})
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
    ll = jnp.take_along_axis(logp, tokens[:, 1:][..., None], -1)[..., 0]
    expect = -float(ll.mean())
    assert abs(float(loss) - expect) < 1e-5


def test_logical_spec_divisibility_fallback():
    import jax as _jax

    mesh = _jax.make_mesh((1, 1), ("data", "model"))

    # 56 heads on a 16-wide axis would not divide on the real mesh; with a
    # 1-wide test mesh everything divides — exercise the rule application.
    spec = logical_spec((4096, 56, 128), ("embed", "heads", None), TRAIN_RULES, mesh)
    assert spec == P("data", "model", None)
    # duplicate axis: second use of "model" must drop
    spec = logical_spec((64, 64), ("vocab", "heads"), TRAIN_RULES, mesh)
    assert spec == P("model", None)
    # missing axis name in mesh ("pod" on single-pod) degrades to subset
    spec = logical_spec((64, 64), ("batch", None), TRAIN_RULES, mesh)
    assert spec == P("data", None)


def test_rules_tables_complete():
    logical_names = [
        "vocab", "embed", "heads", "kv_heads", "mlp", "experts",
        "ssm_inner", "ssm_heads", "ssm_conv_ch", "batch", "kv_embed",
        "cache_batch", "head_dim",
    ]
    for rules in (TRAIN_RULES, DECODE_RULES):
        for name in logical_names:
            assert name in rules.table, (rules.name, name)
