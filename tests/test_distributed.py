"""Distributed SpMV + dry-run machinery (multi-device via subprocess: the
device count must be set before jax initialises)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=timeout,
    )


def test_sharded_spmv_matches_reference():
    code = """
import numpy as np, jax
from repro.core import PartitionConfig
from repro.core.distributed import build_sharded_spmv
from repro.core.matrices import circuit

mesh = jax.make_mesh((8,), ("data",))
A = circuit(4000, seed=2)
x = np.random.default_rng(0).standard_normal(A.n_cols).astype(np.float32)
y_ref = A.matvec(x)
for mode in ("balanced", "grid"):
    sh = build_sharded_spmv(A, mesh, cfg=PartitionConfig(row_block=128, col_block=512), mode=mode)
    y = np.asarray(sh.matvec(jax.numpy.asarray(x)))
    err = np.abs(y - y_ref).max() / np.abs(y_ref).max()
    assert err < 1e-4, (mode, err)
print("SHARDED-OK")
"""
    r = _run(code)
    assert "SHARDED-OK" in r.stdout, r.stdout + r.stderr


def test_balanced_beats_grid_makespan():
    code = """
import numpy as np, jax
from repro.core import PartitionConfig
from repro.core.distributed import build_sharded_spmv
from repro.core.matrices import rmat

mesh = jax.make_mesh((8,), ("data",))
A = rmat(1 << 12, 120_000, seed=1)
cfg = PartitionConfig(row_block=128, col_block=512)
bal = build_sharded_spmv(A, mesh, cfg=cfg, mode="balanced")
grid = build_sharded_spmv(A, mesh, cfg=cfg, mode="grid")
r_b = bal.loads.max() / bal.loads.mean()
r_g = grid.loads.max() / grid.loads.mean()
assert r_b <= r_g + 1e-9, (r_b, r_g)
print("BALANCE-OK", round(r_g, 2), "->", round(r_b, 2))
"""
    r = _run(code)
    assert "BALANCE-OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_dryrun_cell_compiles_on_production_mesh():
    """One full dry-run cell on the 512-device mesh (the sweep's machinery)."""
    out = ROOT / "tests" / "_dryrun_tmp"
    out.mkdir(exist_ok=True)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo-1b",
         "--shape", "train_4k", "--mesh", "single", "--no-roofline",
         "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=1200, cwd=ROOT,
    )
    rec = json.loads((out / "olmo-1b__train_4k__single.json").read_text())
    assert rec["status"] == "ok", r.stdout + r.stderr
    assert rec["fits_hbm"], rec["memory"]
