"""CSR/COO containers and the 2D partition (paper §III-A)."""
import numpy as np

from conftest import hypothesis_or_shim

given, settings, st = hypothesis_or_shim()

from repro.core import CSRMatrix, csr_from_dense, Partition2D, PartitionConfig
from repro.core.formats import COOMatrix, csr_from_coo
from repro.core.partition import count_block_nnz


@given(st.integers(2, 40), st.integers(2, 40), st.floats(0.0, 0.6), st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_csr_dense_roundtrip(m, k, density, seed):
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal((m, k)) * (rng.random((m, k)) < density)
    csr = csr_from_dense(dense)
    assert np.allclose(csr.to_dense(), dense)
    x = rng.standard_normal(k)
    assert np.allclose(csr.matvec(x), dense @ x, atol=1e-10)


def test_coo_duplicate_sum():
    coo = COOMatrix([0, 0, 1], [1, 1, 0], [1.0, 2.0, 3.0], (2, 2))
    csr = csr_from_coo(coo)
    assert np.allclose(csr.to_dense(), [[0.0, 3.0], [3.0, 0.0]])


@given(st.integers(5, 60), st.integers(5, 80), st.integers(0, 4))
@settings(max_examples=30, deadline=None)
def test_block_counts_match_bruteforce(m, k, seed):
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal((m, k)) * (rng.random((m, k)) < 0.2)
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=16, col_block=16, group=4, lane=8)
    counts = count_block_nnz(csr, cfg)
    nbc = -(-k // 16)
    for r in range(m):
        for bj in range(nbc):
            expect = np.count_nonzero(dense[r, bj * 16 : (bj + 1) * 16])
            assert counts[r, bj] == expect


def test_partition_block_entries_cover_all(rng):
    dense = rng.standard_normal((100, 150)) * (rng.random((100, 150)) < 0.1)
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=32, col_block=64, group=8, lane=16)
    part = Partition2D.build(csr, cfg)
    nbr, nbc = part.grid
    total = 0
    recon = np.zeros_like(dense)
    for bi in range(nbr):
        for bj in range(nbc):
            rows, cols, data = part.block_entries(bi, bj)
            total += data.size
            recon[rows + bi * 32, cols + bj * 64] += data
    assert total == csr.nnz
    assert np.allclose(recon, dense)


# --- transpose -------------------------------------------------------------


@given(st.integers(2, 40), st.integers(2, 40), st.floats(0.0, 0.6), st.integers(0, 6))
@settings(max_examples=40, deadline=None)
def test_transpose_dense_equivalence_and_roundtrip(m, k, density, seed):
    """A.T matches the dense transpose; transposing twice reproduces the
    original CSR arrays bit for bit (index-sorted, no reordering)."""
    rng = np.random.default_rng(seed)
    dense = (rng.standard_normal((m, k)) * (rng.random((m, k)) < density)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    t = csr.transpose()
    assert t.shape == (k, m)
    np.testing.assert_array_equal(t.to_dense(), dense.T)
    # indices sorted within every row of the transpose
    for i in range(k):
        cols, _ = t.row_slice(i)
        assert (np.diff(cols) > 0).all()
    back = t.transpose()
    np.testing.assert_array_equal(back.indptr, csr.indptr)
    np.testing.assert_array_equal(back.indices, csr.indices)
    np.testing.assert_array_equal(back.data, csr.data)


def test_transpose_unit_sorted_and_roundtrip(rng):
    """Deterministic twin of the property test (runs without hypothesis):
    dense equivalence, per-row sorted indices, bit-exact double transpose."""
    dense = (rng.standard_normal((23, 31)) * (rng.random((23, 31)) < 0.3)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    t = csr.transpose()
    assert t.shape == (31, 23)
    np.testing.assert_array_equal(t.to_dense(), dense.T)
    for i in range(t.n_rows):
        cols, _ = t.row_slice(i)
        assert (np.diff(cols) > 0).all()
    back = t.transpose()
    np.testing.assert_array_equal(back.indptr, csr.indptr)
    np.testing.assert_array_equal(back.indices, csr.indices)
    np.testing.assert_array_equal(back.data, csr.data)


def test_transpose_empty_and_empty_rows():
    csr = CSRMatrix(np.zeros(4, np.int64), np.zeros(0, np.int64), np.zeros(0), (3, 5))
    t = csr.transpose()
    assert t.shape == (5, 3) and t.nnz == 0
    # a matrix whose only entries leave empty transpose rows
    d = np.zeros((3, 4), np.float32)
    d[1, 2] = 5.0
    t2 = csr_from_dense(d).transpose()
    np.testing.assert_array_equal(t2.to_dense(), d.T)


def test_transpose_matvec_is_rmatvec(rng):
    dense = (rng.standard_normal((30, 18)) * (rng.random((30, 18)) < 0.3)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    y = rng.standard_normal(30).astype(np.float32)
    np.testing.assert_allclose(csr.transpose().matvec(y), dense.T @ y, rtol=1e-5)
