"""CSR/COO containers and the 2D partition (paper §III-A)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import CSRMatrix, csr_from_dense, Partition2D, PartitionConfig
from repro.core.formats import COOMatrix, csr_from_coo
from repro.core.partition import count_block_nnz


@given(st.integers(2, 40), st.integers(2, 40), st.floats(0.0, 0.6), st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_csr_dense_roundtrip(m, k, density, seed):
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal((m, k)) * (rng.random((m, k)) < density)
    csr = csr_from_dense(dense)
    assert np.allclose(csr.to_dense(), dense)
    x = rng.standard_normal(k)
    assert np.allclose(csr.matvec(x), dense @ x, atol=1e-10)


def test_coo_duplicate_sum():
    coo = COOMatrix([0, 0, 1], [1, 1, 0], [1.0, 2.0, 3.0], (2, 2))
    csr = csr_from_coo(coo)
    assert np.allclose(csr.to_dense(), [[0.0, 3.0], [3.0, 0.0]])


@given(st.integers(5, 60), st.integers(5, 80), st.integers(0, 4))
@settings(max_examples=30, deadline=None)
def test_block_counts_match_bruteforce(m, k, seed):
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal((m, k)) * (rng.random((m, k)) < 0.2)
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=16, col_block=16, group=4, lane=8)
    counts = count_block_nnz(csr, cfg)
    nbc = -(-k // 16)
    for r in range(m):
        for bj in range(nbc):
            expect = np.count_nonzero(dense[r, bj * 16 : (bj + 1) * 16])
            assert counts[r, bj] == expect


def test_partition_block_entries_cover_all(rng):
    dense = rng.standard_normal((100, 150)) * (rng.random((100, 150)) < 0.1)
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=32, col_block=64, group=8, lane=16)
    part = Partition2D.build(csr, cfg)
    nbr, nbc = part.grid
    total = 0
    recon = np.zeros_like(dense)
    for bi in range(nbr):
        for bj in range(nbc):
            rows, cols, data = part.block_entries(bi, bj)
            total += data.size
            recon[rows + bi * 32, cols + bj * 64] += data
    assert total == csr.nnz
    assert np.allclose(recon, dense)
