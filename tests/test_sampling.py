"""Neighbor-sampled mini-batching: subgraph extraction + fan-out sampler.

Property coverage (hypothesis): induced subgraphs preserve edge weights
bit-exactly and their local degrees equal the count of in-set parent
neighbors, for arbitrary graphs and node subsets.  The sampler is checked
for seed ordering, fan-out bounds, determinism, and the content-hash
reuse the registry's mini-batch path relies on.
"""
import numpy as np
import pytest

from conftest import hypothesis_or_shim

given, settings, st = hypothesis_or_shim()

from repro.core.formats import csr_from_dense
from repro.graph import graph_from_edges, power_law_graph
from repro.graph.train import sample_neighbors, subgraph


def _random_graph(n, density, seed):
    rng = np.random.default_rng(seed)
    dense = (rng.standard_normal((n, n)) * (rng.random((n, n)) < density)).astype(
        np.float32
    )
    return csr_from_dense(dense), dense


# --- subgraph: hypothesis properties ---------------------------------------


@given(
    st.integers(3, 28),
    st.floats(0.05, 0.6),
    st.integers(0, 10),
    st.integers(0, 10),
)
@settings(max_examples=40, deadline=None)
def test_subgraph_preserves_weights_and_degrees(n, density, gseed, sseed):
    csr, dense = _random_graph(n, density, gseed)
    rng = np.random.default_rng(sseed)
    m = int(rng.integers(1, n + 1))
    nodes = rng.choice(n, size=m, replace=False)
    sub = subgraph(csr, nodes)
    assert sub.shape == (m, m)
    # weights: the induced block of the parent, bit for bit
    np.testing.assert_array_equal(sub.to_dense(), dense[np.ix_(nodes, nodes)])
    # degrees: per local node, the number of its parent in-neighbors that
    # made it into the node set
    want_deg = (dense[np.ix_(nodes, nodes)] != 0).sum(axis=1)
    np.testing.assert_array_equal(sub.row_nnz(), want_deg)


@given(st.integers(3, 20), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_subgraph_full_set_roundtrip(n, gseed):
    """The induced subgraph over ALL nodes (identity order) is the graph."""
    csr, dense = _random_graph(n, 0.3, gseed)
    sub = subgraph(csr, np.arange(n))
    np.testing.assert_array_equal(sub.indptr, csr.indptr)
    np.testing.assert_array_equal(sub.indices, csr.indices)
    np.testing.assert_array_equal(sub.data, csr.data)


def test_subgraph_preserves_weights_and_degrees_deterministic():
    """Hypothesis-free twin of the property above (always runs)."""
    for gseed, sseed in [(0, 1), (3, 4), (7, 2)]:
        csr, dense = _random_graph(17, 0.3, gseed)
        rng = np.random.default_rng(sseed)
        nodes = rng.choice(17, size=9, replace=False)
        sub = subgraph(csr, nodes)
        np.testing.assert_array_equal(sub.to_dense(), dense[np.ix_(nodes, nodes)])
        np.testing.assert_array_equal(
            sub.row_nnz(), (dense[np.ix_(nodes, nodes)] != 0).sum(axis=1)
        )


def test_subgraph_order_and_dedup():
    csr, dense = _random_graph(8, 0.5, 1)
    sub = subgraph(csr, [5, 2, 5, 7, 2])  # duplicates keep first occurrence
    np.testing.assert_array_equal(sub.to_dense(), dense[np.ix_([5, 2, 7], [5, 2, 7])])


def test_subgraph_validation():
    csr, _ = _random_graph(6, 0.3, 0)
    with pytest.raises(ValueError, match="outside"):
        subgraph(csr, [0, 9])
    rect = csr_from_dense(np.ones((3, 5), np.float32))
    with pytest.raises(ValueError, match="square"):
        subgraph(rect, [0])


# --- fan-out sampler -------------------------------------------------------


@pytest.fixture(scope="module")
def graph():
    return power_law_graph(300, 6.0, seed=4)


def test_sampler_seeds_first_and_bounded(graph):
    seeds = [7, 50, 123]
    batch = sample_neighbors(graph, seeds, fanouts=(4, 2), seed=0)
    np.testing.assert_array_equal(batch.nodes[:3], seeds)
    assert batch.n_seeds == 3
    # |nodes| <= seeds * (1 + f1 + f1*f2)
    assert batch.nodes.size <= 3 * (1 + 4 + 4 * 2)
    assert len(set(batch.nodes.tolist())) == batch.nodes.size
    mask = batch.seed_mask()
    assert mask.sum() == 3 and (mask[3:] == 0).all()


def test_sampler_fanout_bounds_per_hop(graph):
    """Hop 1 alone: at most fanout sampled in-neighbors per seed, all of
    them real in-neighbors of that seed."""
    seeds = [0, 1, 2]
    batch = sample_neighbors(graph, seeds, fanouts=(3,), seed=5)
    extras = batch.nodes[batch.n_seeds :]
    allowed = set()
    for s in seeds:
        nbrs, _ = graph.row_slice(s)
        allowed.update(int(v) for v in nbrs)
    assert all(int(v) in allowed for v in extras)
    assert extras.size <= 3 * 3


def test_sampler_deterministic_and_content_hash_reuse(graph, tmp_path):
    from repro.serving import MatrixRegistry
    from repro.serving.autotune import matrix_hash

    # seed from the two highest-degree hubs so the fan-out has real choices
    hubs = np.argsort(graph.row_nnz())[-2:].tolist()
    a = sample_neighbors(graph, hubs, fanouts=(6, 3), seed=11)
    b = sample_neighbors(graph, hubs, fanouts=(6, 3), seed=11)
    np.testing.assert_array_equal(a.nodes, b.nodes)
    assert matrix_hash(a.adj) == matrix_hash(b.adj)
    assert a.adj.nnz > 0
    c = sample_neighbors(graph, hubs, fanouts=(6, 3), seed=12)
    # different draw, same seeds: almost surely a different neighborhood
    assert (c.nodes.size != a.nodes.size) or (matrix_hash(c.adj) != matrix_hash(a.adj))

    reg = MatrixRegistry(cache_dir=tmp_path / "cache", search=False)
    plan_a = reg.admit_pair(a.adj)
    plan_b = reg.admit_pair(b.adj)
    assert plan_b is plan_a  # epoch-2 batch: free re-admission
    assert plan_a.admissions >= 2


def test_sampler_subgraph_is_induced(graph):
    """The batch adjacency equals subgraph(parent, nodes) — every in-set
    edge present, weights intact."""
    batch = sample_neighbors(graph, [3, 77], fanouts=(5,), seed=2)
    ref = subgraph(graph, batch.nodes)
    np.testing.assert_array_equal(batch.adj.to_dense(), ref.to_dense())


def test_sampler_validation(graph):
    with pytest.raises(ValueError, match="seed"):
        sample_neighbors(graph, [], fanouts=(2,))


def test_sampler_isolated_seed():
    G = graph_from_edges([0, 1], [1, 2], n_nodes=5)  # nodes 3, 4 isolated
    batch = sample_neighbors(G, [3], fanouts=(4, 4), seed=0)
    np.testing.assert_array_equal(batch.nodes, [3])
    assert batch.adj.nnz == 0
