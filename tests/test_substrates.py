"""Substrate integration: data determinism, checkpoint restart, trainer,
serving engine, sparse-linear pruned layers."""
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.sparse_linear import SparseLinear, magnitude_prune
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import Engine, EngineConfig, Request
from repro.train.trainer import Trainer, TrainerConfig


def small_cfg():
    return dataclasses.replace(
        get_config("olmo-1b").smoke(), n_layers=2, vocab=128
    )


def test_data_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=3)
    s1, s2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1 = s1.batch_at(17)
    b2 = s2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 32)
    # different steps differ
    assert not np.array_equal(b1["tokens"], s1.batch_at(18)["tokens"])


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ck.save(5, tree, blocking=True)
    ck.save(9, tree, blocking=True)
    restored, step = ck.restore(tree)
    assert step == 9
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10.0))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_trainer_restart_exact(tmp_path):
    cfg = small_cfg()
    model = build_model(cfg)
    ocfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2, decay_steps=50)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=1)

    # continuous run to 8 steps
    t1 = Trainer(model, ocfg, dcfg, TrainerConfig(steps=8, log_every=100))
    s_full = t1.run()

    # interrupted run: 5 steps + checkpoint, then resume to 8
    tc = TrainerConfig(steps=5, log_every=100, checkpoint_every=100, checkpoint_dir=str(tmp_path))
    t2 = Trainer(model, ocfg, dcfg, tc)
    s_mid = t2.run()  # saves final blocking checkpoint at step 4
    tc3 = dataclasses.replace(tc, steps=8)
    t3 = Trainer(model, ocfg, dcfg, tc3)
    s_resumed = t3.run()  # restores step 4, runs 5..7

    for a, b in zip(jax.tree.leaves(s_full["params"]), jax.tree.leaves(s_resumed["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_engine_greedy_deterministic():
    cfg = small_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, EngineConfig(batch=2, max_len=64))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 5).astype(np.int32) for _ in range(2)]
    mk = lambda: [Request(prompt=p.copy(), max_new=8) for p in prompts]
    r1, r2 = mk(), mk()
    eng.generate(r1)
    eng.generate(r2)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.out, b.out)


def test_magnitude_prune_and_sparse_linear(rng):
    w = rng.standard_normal((96, 160)).astype(np.float32)
    pruned = magnitude_prune(w, 0.8)
    assert abs((pruned == 0).mean() - 0.8) < 0.02
    sl = SparseLinear.from_dense(w, sparsity=0.8)
    x = rng.standard_normal((4, 160)).astype(np.float32)
    got = np.asarray(sl.apply(jnp.asarray(x)))
    ref = x @ pruned.T  # SparseLinear computes W_sparse @ x with W [out, in]
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
