"""Multi-tenant serving policy: QoS classes, backpressure, fairness, eviction.

The overload contracts under test:

* admission control sheds with a **typed** ``BackpressureError`` before
  the request holds a queue slot — never a silent drop (every submit
  either completes or raises, and the ledger's ``qos.shed`` counter
  accounts each rejection);
* weighted-fair flushing changes *which tenant* is served next, never the
  order **within** a tenant (per-tenant FIFO is preserved);
* HBM-budget eviction is transparent: an unstaged plan re-stages on the
  next ``get`` with bitwise-identical results, and a fully evicted matrix
  re-admits under the same content hash via the autotune disk cache;
* overlap dispatch is a scheduling change, not a numerics change: results
  are bitwise equal to the synchronous engine's.
"""
import numpy as np
import pytest

from repro.core import csr_from_dense
from repro.core.matrices import banded_fem, circuit
from repro.serving import (
    BackpressureError,
    LRUEvictor,
    MatrixRegistry,
    QoSClass,
    ServingEngine,
    WeightedFairScheduler,
    matrix_hash,
    plan_device_bytes,
)


@pytest.fixture()
def registry(tmp_path):
    return MatrixRegistry(cache_dir=tmp_path / "cache", search=False)


@pytest.fixture()
def two_matrices():
    A = circuit(150, seed=1, n_dense_rows=2, dense_row_frac=0.05)
    B = banded_fem(130, seed=3, band=4, fill=0.9)
    return A, B


def _xs(n_cols, count, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(n_cols).astype(np.float32) for _ in range(count)]


# --- QoS classes ----------------------------------------------------------


def test_qos_class_validation():
    with pytest.raises(ValueError):
        QoSClass("bad", deadline_s=0.0)
    with pytest.raises(ValueError):
        QoSClass("bad", deadline_s=0.01, weight=0.0)
    with pytest.raises(ValueError):
        QoSClass("bad", deadline_s=0.01, max_queue=0)
    with pytest.raises(ValueError):
        QoSClass("bad", deadline_s=0.01, max_wait_s=0.0)


def test_qos_max_wait_overrides_batching_window(registry, two_matrices):
    A, _ = two_matrices
    registry.admit(A, "a")
    vt = [0.0]
    eng = ServingEngine(
        registry,
        max_wait_s=0.010,
        clock=lambda: vt[0],
        qos={"a": QoSClass("tight", deadline_s=0.05, max_wait_s=0.001)},
    )
    eng.submit("a", _xs(A.shape[1], 1)[0])
    vt[0] = 0.002  # past the class window, well inside the engine default
    assert eng.poll() == 1


# --- admission control ----------------------------------------------------


def test_backpressure_is_typed_and_never_silent(registry, two_matrices):
    A, _ = two_matrices
    registry.admit(A, "a")
    vt = [0.0]
    eng = ServingEngine(
        registry,
        clock=lambda: vt[0],
        qos={"a": QoSClass("capped", deadline_s=0.05, max_queue=2)},
    )
    xs = _xs(A.shape[1], 3)
    t1 = eng.submit("a", xs[0])
    t2 = eng.submit("a", xs[1])
    with pytest.raises(BackpressureError) as exc:
        eng.submit("a", xs[2])
    # the error carries the evidence, the ledger counts the shed, and the
    # shed request holds no queue slot (the two admitted ones still do)
    assert exc.value.key == "a"
    assert exc.value.qos == "capped"
    assert exc.value.depth == 2 and exc.value.limit == 2
    assert eng.metrics.value("qos.shed", matrix="a", qos="capped") == 1
    assert eng.batcher.pending("a") == 2
    # the admitted requests are unaffected: both complete with results
    vt[0] = 1.0
    assert eng.poll() == 2
    assert t1.done() and t2.done()


def test_default_class_never_sheds(registry, two_matrices):
    A, _ = two_matrices
    registry.admit(A, "a")
    vt = [0.0]
    eng = ServingEngine(registry, clock=lambda: vt[0], queue_limit=10**6)
    for x in _xs(A.shape[1], 40):
        eng.submit("a", x)  # far past any default: must not raise
    assert eng.batcher.pending("a") == 40


def test_shed_triggers_flight_dump(registry, two_matrices, tmp_path):
    from repro.obs.flight import FlightRecorder

    A, _ = two_matrices
    registry.admit(A, "a")
    flight = FlightRecorder(dump_dir=tmp_path / "dumps")
    eng = ServingEngine(
        registry,
        flight=flight,
        qos={"a": QoSClass("capped", deadline_s=0.05, max_queue=1)},
    )
    eng.submit("a", _xs(A.shape[1], 1)[0])
    with pytest.raises(BackpressureError):
        eng.submit("a", _xs(A.shape[1], 1)[0])
    dumps = list((tmp_path / "dumps").glob("flight_load_shed_*.json"))
    assert len(dumps) == 1
    eng.flush()


# --- weighted-fair scheduling ---------------------------------------------


def test_scheduler_orders_by_virtual_work():
    sched = WeightedFairScheduler({"a": 4.0, "b": 1.0}.__getitem__)
    assert sched.vwork("a") == sched.vwork("b") == 0.0  # both join at zero
    # equal columns served: the weight-1 tenant accumulates 4x the vwork
    sched.charge("a", 8)
    sched.charge("b", 8)
    assert sched.vwork("a") == 2.0 and sched.vwork("b") == 8.0
    assert sched.order(["b", "a"]) == ["a", "b"]


def test_scheduler_status_boost_and_tiebreaks():
    sched = WeightedFairScheduler(lambda k: 1.0)
    sched.charge("a", 1)  # a has MORE vwork than b
    # a paging tenant flushes first regardless of accumulated vwork
    assert sched.order(["a", "b"], status={"a": "page"}) == ["a", "b"]
    # equal vwork: longer head-of-line wait wins
    sched2 = WeightedFairScheduler(lambda k: 1.0)
    waits = {"x": 0.001, "y": 0.005}
    assert sched2.order(["x", "y"], head_wait=waits.__getitem__) == ["y", "x"]


def test_scheduler_late_joiner_gets_no_retroactive_credit():
    sched = WeightedFairScheduler(lambda k: 1.0)
    sched.charge("a", 100)  # a: 0 -> 100
    sched.charge("b", 50)  # b joins at the live min (100) -> 150
    # "c" joins at the live minimum (100), not zero — a late joiner cannot
    # starve incumbents by replaying history it never participated in
    assert sched.vwork("b") == 150.0
    assert sched.vwork("c") == 100.0


def test_weighted_fair_preserves_per_tenant_fifo(registry, two_matrices):
    A, B = two_matrices
    registry.admit(A, "a")
    registry.admit(B, "b")
    vt = [0.0]
    eng = ServingEngine(
        registry,
        max_wait_s=0.001,
        clock=lambda: vt[0],
        qos={
            "a": QoSClass("gold", deadline_s=0.1, weight=4.0),
            "b": QoSClass("be", deadline_s=0.1, weight=0.25),
        },
    )
    tickets = {"a": [], "b": []}
    for i in range(6):
        vt[0] = i * 1e-5
        tickets["a"].append(eng.submit("a", _xs(A.shape[1], 1, seed=i)[0]))
        tickets["b"].append(eng.submit("b", _xs(B.shape[1], 1, seed=100 + i)[0]))
    vt[0] = 1.0
    eng.poll()
    for key in ("a", "b"):
        done = [t.context.t_complete for t in tickets[key]]
        assert all(t is not None for t in done)
        ids = [t.req_id for t in tickets[key]]
        # completion order within a tenant follows submission order
        assert ids == sorted(ids)
        assert done == sorted(done)


# --- LRU eviction policy (pure) -------------------------------------------


def test_lru_evicts_oldest_first():
    ev = LRUEvictor(100)
    assert ev.admit("a", 40) == []
    assert ev.admit("b", 40) == []
    assert ev.admit("c", 40) == ["a"]  # over budget: LRU goes
    ev.touch("b")  # b is now most recent
    assert ev.admit("d", 40) == ["c"]
    assert ev.resident() == ["b", "d"]


def test_lru_pair_evicted_as_unit():
    ev = LRUEvictor(100)
    ev.admit("f", 30)
    ev.admit("f::T", 30)
    ev.link("f", "f::T")
    assert set(ev.admit("g", 60)) == {"f", "f::T"}
    assert ev.resident() == ["g"]


def test_lru_single_oversized_unit_overshoots():
    ev = LRUEvictor(10)
    assert ev.admit("huge", 50) == []  # nothing else to evict: stays
    assert ev.over_budget() == 40
    assert ev.admit("next", 5) == ["huge"]


# --- registry eviction integration ----------------------------------------


def test_budget_eviction_restages_bitwise_equal(tmp_path, two_matrices):
    A, B = two_matrices
    probe = MatrixRegistry(cache_dir=tmp_path / "cache", search=False)
    nbytes = plan_device_bytes(probe.admit(A, "probe").tiles)
    reg = MatrixRegistry(
        cache_dir=tmp_path / "cache", search=False, hbm_budget_bytes=int(nbytes * 1.5)
    )
    plan_a = reg.admit(A, "a")
    x = _xs(A.shape[1], 1)[0]
    y_before = np.asarray(plan_a.matvec(x))
    reg.admit(B, "b")  # overflows the budget: "a" is unstaged
    assert reg._plans["a"].device is None
    assert reg.metrics.value("evict.unstaged", matrix="a") == 1
    # get() transparently re-stages; no re-preprocessing, same tiles
    plan_again = reg.get("a")
    assert plan_again is plan_a and plan_a.device is not None
    assert reg.metrics.value("evict.restages", matrix="a") == 1
    np.testing.assert_array_equal(np.asarray(plan_again.matvec(x)), y_before)


def test_budget_eviction_is_transparent_to_engine(tmp_path, two_matrices):
    A, B = two_matrices
    probe = MatrixRegistry(cache_dir=tmp_path / "cache", search=False)
    nbytes = plan_device_bytes(probe.admit(A, "probe").tiles)
    reg = MatrixRegistry(
        cache_dir=tmp_path / "cache", search=False, hbm_budget_bytes=int(nbytes * 1.5)
    )
    reg.admit(A, "a")
    reg.admit(B, "b")  # "a" unstaged before any traffic
    vt = [0.0]
    eng = ServingEngine(reg, clock=lambda: vt[0])
    t = eng.submit("a", _xs(A.shape[1], 1)[0])  # submit's get() re-stages
    y = t.result()
    assert y.shape == (A.shape[0],)
    assert reg.metrics.value("evict.restages", matrix="a") == 1


def test_full_evict_readmits_same_hash_via_disk_cache(tmp_path, two_matrices):
    A, _ = two_matrices
    reg = MatrixRegistry(cache_dir=tmp_path / "cache", search=True)
    plan1 = reg.admit(A, "a")
    h1, cfg1 = plan1.matrix_hash, plan1.cfg
    assert plan1.autotune_searched  # cold cache: the search ran
    x = _xs(A.shape[1], 1)[0]
    y1 = np.asarray(plan1.matvec(x))
    reg.evict("a")
    assert "a" not in reg
    plan2 = reg.admit(A, "a")  # same content: same hash, cached geometry
    assert plan2.matrix_hash == h1 == matrix_hash(A)
    assert plan2.cfg == cfg1
    assert plan2.autotune_cache_hit and not plan2.autotune_searched
    np.testing.assert_array_equal(np.asarray(plan2.matvec(x)), y1)


def test_pair_restaged_as_unit(tmp_path):
    A = circuit(90, seed=5)
    C = banded_fem(120, seed=7, band=5, fill=0.9)
    probe = MatrixRegistry(cache_dir=tmp_path / "cache", search=False)
    pp = probe.admit_pair(A, "p")
    pair_bytes = plan_device_bytes(pp.tiles) + plan_device_bytes(
        probe.transpose_of(pp).tiles
    )
    reg = MatrixRegistry(
        cache_dir=tmp_path / "cache",
        search=False,
        hbm_budget_bytes=int(pair_bytes * 1.2),
    )
    plan = reg.admit_pair(A, "p")
    plan_T = reg.transpose_of(plan)
    reg.admit(C, "c")  # evicts the pair as one unit
    assert plan.device is None and plan_T.device is None
    got = reg.get("p")  # restages BOTH sides together
    assert got.device is not None
    assert reg.transpose_of(got).device is not None


# --- overlap dispatch ------------------------------------------------------


def test_overlap_results_bitwise_equal_to_sync(registry, two_matrices):
    A, B = two_matrices
    registry.admit(A, "a")
    registry.admit(B, "b")
    vt = [0.0]
    eng_sync = ServingEngine(registry, max_wait_s=0.001, clock=lambda: vt[0])
    eng_over = ServingEngine(
        registry, max_wait_s=0.001, clock=lambda: vt[0], overlap=True
    )
    xs_a = _xs(A.shape[1], 5, seed=1)
    xs_b = _xs(B.shape[1], 5, seed=2)
    ts, to = [], []
    for xa, xb in zip(xs_a, xs_b):
        ts += [eng_sync.submit("a", xa), eng_sync.submit("b", xb)]
        to += [eng_over.submit("a", xa), eng_over.submit("b", xb)]
    vt[0] = 1.0
    eng_sync.poll()
    eng_over.poll()
    for t_s, t_o in zip(ts, to):
        np.testing.assert_array_equal(t_s.result(), t_o.result())
    assert eng_over.inflight() == 0  # everything harvested after result()


def test_overlap_ticket_result_is_the_blocking_edge(registry, two_matrices):
    A, _ = two_matrices
    registry.admit(A, "a")
    vt = [0.0]
    eng = ServingEngine(registry, max_wait_s=0.001, clock=lambda: vt[0], overlap=True)
    t = eng.submit("a", _xs(A.shape[1], 1)[0])
    # nothing due yet: poll dispatches nothing, completes nothing
    assert eng.poll() == 0 and not t.done()
    y = t.result()  # drains + harvests regardless of clock
    assert t.done() and y.shape == (A.shape[0],)
    assert eng.inflight() == 0


def test_overlap_completion_accounting_matches_sync(registry, two_matrices):
    A, _ = two_matrices
    registry.admit(A, "a")
    vt = [0.0]
    eng = ServingEngine(registry, max_wait_s=0.001, clock=lambda: vt[0], overlap=True)
    n = 7
    tickets = [eng.submit("a", x) for x in _xs(A.shape[1], n)]
    vt[0] = 1.0
    served = eng.poll()
    assert served == n  # dispatched AND harvested within the poll
    s = eng.stats()["a"]
    assert s["requests"] == n and s["batches"] == 1
    assert all(t.done() for t in tickets)
    # per-request lifecycle stamps are filled exactly as in sync mode
    ctx = tickets[0].context
    assert ctx.t_dispatch is not None and ctx.t_complete is not None
    assert ctx.compute_s is not None and ctx.batch_k == n
