"""One-pass kernel grid acceptance: 2D k-tiled SpMM + paired-payload argmax.

Two contracts are pinned here:

* the ``k_tiling="grid"`` launch geometry (2D (tile, k-tile) Pallas grid /
  single-traversal jnp paths) agrees with the legacy ``"loop"`` chunked
  launches and the dense oracle at every k-bucket boundary —
  k ∈ {1, 127, 128, 129, 256} — on all four strategies, *bitwise* on
  ``"stable"``;
* the one-pass paired-payload argmax returns triples identical to the
  legacy three-monoid-pass recovery and the dense oracle, including the
  tie-to-lowest-column and empty-row (idx = -1, coeff = 0, y = 0, no
  gradient) conventions, while traversing the tile stream once instead of
  three times.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import PartitionConfig, build_tiles, csr_from_dense
from repro.kernels import autodiff, ops, ref
from repro.kernels import hbp_spmm, hbp_spmv

K_BOUNDARIES = [1, 127, 128, 129, 256]
STRATEGIES = ["fused", "partials", "reference", "stable"]
CFG = PartitionConfig(row_block=32, col_block=64, group=8, lane=8)


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(11)
    dense = (rng.standard_normal((70, 90)) * (rng.random((70, 90)) < 0.12)).astype(
        np.float32
    )
    dense[5] = 0.0  # empty rows inside occupied groups
    dense[13] = 0.0
    dense[64] = 0.0
    csr = csr_from_dense(dense)
    return dense, csr, build_tiles(csr, CFG)


def _tied_problem():
    """A matrix + features engineered to produce many tied maxima."""
    rng = np.random.default_rng(3)
    dense = np.zeros((40, 48), np.float32)
    mask = rng.random((40, 48)) < 0.3
    dense[mask] = 1.0  # every stored entry identical -> ties everywhere
    dense[::7] = 0.0  # plus empty rows
    X = np.repeat(rng.standard_normal((48 // 4, 3)).astype(np.float32), 4, axis=0)
    csr = csr_from_dense(dense)
    return dense, csr, build_tiles(csr, CFG), X


def _argmax_oracle(dense, X):
    """Dense (y, idx, coeff) with ties to the lowest column, empty -> -1/0."""
    n, k = dense.shape[0], X.shape[1]
    y = np.zeros((n, k), np.float32)
    idx = np.full((n, k), -1, np.int32)
    coeff = np.zeros((n, k), np.float32)
    for i in range(n):
        nz = np.nonzero(dense[i])[0]
        if not nz.size:
            continue
        prod = dense[i, nz, None] * X[nz]  # [nnz_i, k]
        best = prod.max(axis=0)
        y[i] = best
        for c in range(k):
            winners = nz[prod[:, c] == best[c]]
            idx[i, c] = winners.min()
            coeff[i, c] = dense[i, idx[i, c]]
    return y, idx, coeff


# --- 2D-grid SpMM vs chunk loop vs dense, at every k-bucket boundary -------


@pytest.mark.parametrize("k", K_BOUNDARIES)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_grid_matches_loop_and_dense_at_k_boundaries(problem, k, strategy, rng):
    dense, csr, tiles = problem
    X = rng.standard_normal((90, k)).astype(np.float32)
    Yg = np.asarray(hbp_spmm(tiles, X, strategy=strategy, interpret=True, k_tiling="grid"))
    Yl = np.asarray(hbp_spmm(tiles, X, strategy=strategy, interpret=True, k_tiling="loop"))
    np.testing.assert_allclose(Yg, dense @ X, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(Yl, dense @ X, rtol=1e-4, atol=1e-4)
    if strategy == "stable":
        # the serving contract: bits never depend on the launch geometry
        assert np.array_equal(Yg, Yl)
    else:
        np.testing.assert_allclose(Yg, Yl, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("k", K_BOUNDARIES)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_grid_matches_loop_max_combine_bitwise(problem, k, strategy, rng):
    """max is reassociation-free: grid and loop agree bitwise on EVERY
    strategy, and empty rows stay exactly 0."""
    dense, csr, tiles = problem
    X = rng.standard_normal((90, k)).astype(np.float32)
    Yg = np.asarray(
        hbp_spmm(tiles, X, strategy=strategy, combine="max", interpret=True, k_tiling="grid")
    )
    Yl = np.asarray(
        hbp_spmm(tiles, X, strategy=strategy, combine="max", interpret=True, k_tiling="loop")
    )
    assert np.array_equal(Yg, Yl)
    empty = np.asarray(csr.row_nnz() == 0)
    assert (Yg[empty] == 0).all()


def test_stable_bits_invariant_across_k_tiling_and_width(problem, rng):
    """A column's bits must match the width-1 launch under both tilings at
    every serving-visible width: the engine pads requests to bucket widths
    (``hbp_spmm_bucketed``), so that entry is where the bitwise guarantee
    lives — including k boundaries 127/129 that pad across a chunk edge."""
    dense, csr, tiles = problem
    X = rng.standard_normal((90, 256)).astype(np.float32)
    singles = {
        j: np.asarray(hbp_spmv(tiles, X[:, j], strategy="stable"))
        for j in (0, 126, 127, 128, 129, 255)
    }
    for k_tiling in ops.K_TILINGS:
        for width in (127, 128, 129, 256):
            Y = np.asarray(
                ops.hbp_spmm_bucketed(
                    tiles, X[:, :width], strategy="stable", k_tiling=k_tiling
                )
            )
            assert Y.shape[1] == width
            for j, yj in singles.items():
                if j < width:
                    assert np.array_equal(Y[:, j], yj), (k_tiling, width, j)


def test_unknown_k_tiling_rejected(problem):
    _, _, tiles = problem
    with pytest.raises(ValueError, match="k_tiling"):
        hbp_spmm(tiles, np.ones((90, 2), np.float32), k_tiling="diagonal")
    with pytest.raises(ValueError, match="k_tiling"):
        hbp_spmv(tiles, np.ones(90, np.float32), k_tiling="diagonal")


# --- one-pass argmax vs three-pass vs dense oracle -------------------------


@pytest.mark.parametrize("k", [1, 5, 127, 129])
def test_argmax_onepass_equals_threepass_and_oracle(problem, k, rng):
    dense, csr, tiles = problem
    X = rng.standard_normal((90, k)).astype(np.float32)
    one = tuple(np.asarray(a) for a in ops.hbp_spmm_argmax(tiles, X, passes=1))
    three = tuple(np.asarray(a) for a in ops.hbp_spmm_argmax(tiles, X, passes=3))
    want = _argmax_oracle(dense, X)
    for got1, got3, w in zip(one, three, want):
        assert np.array_equal(got1, got3)
        np.testing.assert_array_equal(got1, w)
    # y must also match the max-combine SpMM bitwise, on every strategy
    for strategy in STRATEGIES:
        Ym = np.asarray(
            hbp_spmm(tiles, X, strategy=strategy, combine="max", interpret=True)
        )
        np.testing.assert_array_equal(one[0], Ym)


def test_argmax_tie_breaks_to_lowest_column_onepass():
    dense, csr, tiles, X = _tied_problem()
    y1, i1, c1 = (np.asarray(a) for a in ops.hbp_spmm_argmax(tiles, X, passes=1))
    y3, i3, c3 = (np.asarray(a) for a in ops.hbp_spmm_argmax(tiles, X, passes=3))
    yo, io, co = _argmax_oracle(dense, X)
    np.testing.assert_array_equal(i1, i3)
    np.testing.assert_array_equal(i1, io)  # ties -> lowest column, always
    np.testing.assert_array_equal(y1, yo)
    np.testing.assert_array_equal(c1, co)


def test_argmax_empty_rows_convention(problem, rng):
    dense, csr, tiles = problem
    X = rng.standard_normal((90, 4)).astype(np.float32)
    empty = np.asarray(csr.row_nnz() == 0)
    assert empty.any()
    for passes in (1, 3):
        y, idx, coeff = (
            np.asarray(a) for a in ops.hbp_spmm_argmax(tiles, X, passes=passes)
        )
        assert (y[empty] == 0).all()
        assert (idx[empty] == -1).all()
        assert (coeff[empty] == 0).all()


def test_argmax_rejects_bad_passes(problem):
    _, _, tiles = problem
    with pytest.raises(ValueError, match="passes"):
        ops.hbp_spmm_argmax(tiles, np.ones((90, 2), np.float32), passes=2)


def test_onepass_traverses_tile_stream_once(problem, rng):
    """The point of the redesign: <= 1 traversal, vs 3 for the legacy path."""
    _, _, tiles = problem
    dt = ops.device_tiles(tiles)
    xb = ops.blocked_matrix(jnp.asarray(rng.standard_normal((90, 4)), jnp.float32), 64)
    with ref.count_traversals() as one:
        ref.hbp_spmm_hashed_argmax_onepass(
            dt.rowgroup, dt.colblock, dt.data, dt.cols, xb,
            n_rowgroups=tiles.n_rowgroups,
        )
    with ref.count_traversals() as three:
        ref.hbp_spmm_hashed_argmax(
            dt.rowgroup, dt.colblock, dt.data, dt.cols, xb,
            n_rowgroups=tiles.n_rowgroups,
        )
    assert one[0] <= 1
    assert three[0] == 3


def test_argmax_diff_gradients_match_across_passes(problem, rng):
    """The max-aggregation VJP routes identical gradients under either
    forward (same winners, same coefficients)."""
    _, csr, tiles = problem
    dt = ops.device_tiles(tiles)
    meta = dict(n_rowgroups=tiles.n_rowgroups, n_rows=tiles.shape[0], col_block=64)
    x = jnp.asarray(rng.standard_normal((90, 3)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((70, 3)), jnp.float32)
    grads = {}
    for passes in (1, 3):
        f = autodiff.argmax_spmm_diff(dt, passes=passes, **meta)
        y, vjp = jax.vjp(f, x)
        grads[passes] = (np.asarray(y), np.asarray(vjp(g)[0]))
    np.testing.assert_array_equal(grads[1][0], grads[3][0])
    np.testing.assert_array_equal(grads[1][1], grads[3][1])


# --- serving plumbing: plans carry and serve the picked k_tiling -----------


def test_registry_plan_carries_k_tiling(tmp_path, rng):
    from repro.serving import MatrixRegistry

    dense = (rng.standard_normal((40, 40)) * (rng.random((40, 40)) < 0.2)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    X = rng.standard_normal((40, 160)).astype(np.float32)
    results = {}
    for k_tiling in ("grid", "loop", "auto"):
        reg = MatrixRegistry(
            search=False, cache_dir=tmp_path / k_tiling, k_tiling=k_tiling
        )
        plan = reg.admit(csr, f"m_{k_tiling}")
        if k_tiling == "auto":
            assert plan.k_tiling in ("grid", "loop")  # measured pick
        else:
            assert plan.k_tiling == k_tiling
        assert plan._meta()["k_tiling"] == plan.k_tiling
        assert reg.stats()[f"m_{k_tiling}"]["k_tiling"] == plan.k_tiling
        results[k_tiling] = np.asarray(plan.matmat(X, bucketed=False))
        np.testing.assert_allclose(results[k_tiling], dense @ X, rtol=1e-4, atol=1e-4)
    # the default off-TPU strategy is "stable": bits identical either way
    assert np.array_equal(results["grid"], results["loop"])


def test_registry_rejects_unknown_k_tiling():
    from repro.serving import MatrixRegistry

    with pytest.raises(ValueError, match="k_tiling"):
        MatrixRegistry(k_tiling="spiral")
