"""Multi-RHS SpMM kernel vs the dense oracle, k independent SpMV calls,
and the end-to-end cross-implementation equivalence sweep.

The equivalence sweep runs every structural family of the scaled Table-I
suite through all three implementation layers — the faithful GPU-semantics
reference (Algorithm 3), the XLA CSR baseline (Algorithm 1), and the
Pallas tile path in ``interpret=True`` — and requires them to agree.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    PartitionConfig,
    build_hbp,
    build_tiles,
    csr_from_dense,
    csr_spmv_jnp,
    hbp_spmv_reference,
    spmm,
    spmv,
)
from repro.core.matrices import banded_fem, circuit, dense_block, rmat, uniform_random
from repro.kernels import hbp_spmm, hbp_spmv


CASES = [
    (64, 64, 0.3, 1),
    (100, 120, 0.1, 4),
    (300, 500, 0.03, 8),
    (257, 130, 0.02, 3),
]


@pytest.mark.parametrize("m,k,density,nrhs", CASES)
@pytest.mark.parametrize("strategy", ["fused", "partials", "reference"])
def test_hbp_spmm_strategies_match_dense(m, k, density, nrhs, strategy, rng):
    dense = (rng.standard_normal((m, k)) * (rng.random((m, k)) < density)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=64, col_block=128, group=8, lane=32)
    tiles = build_tiles(csr, cfg)
    X = rng.standard_normal((k, nrhs)).astype(np.float32)
    Y = np.asarray(hbp_spmm(tiles, X, strategy=strategy, interpret=True))
    np.testing.assert_allclose(Y, dense @ X, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("strategy", ["fused", "partials"])
def test_spmm_equals_k_spmv_calls(strategy, rng):
    """The acceptance property: one SpMM launch == k independent SpMV
    launches, column for column."""
    dense = (rng.standard_normal((150, 220)) * (rng.random((150, 220)) < 0.07)).astype(
        np.float32
    )
    tiles = build_tiles(
        csr_from_dense(dense), PartitionConfig(row_block=64, col_block=64, group=8, lane=16)
    )
    X = rng.standard_normal((220, 6)).astype(np.float32)
    Y = np.asarray(hbp_spmm(tiles, X, strategy=strategy, interpret=True))
    for j in range(X.shape[1]):
        yj = np.asarray(hbp_spmv(tiles, X[:, j], strategy=strategy, interpret=True))
        np.testing.assert_allclose(Y[:, j], yj, rtol=1e-5, atol=1e-5)


def test_spmv_routes_2d_rhs_to_spmm(rng):
    dense = (rng.standard_normal((80, 90)) * (rng.random((80, 90)) < 0.15)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    tiles = build_tiles(csr, PartitionConfig(row_block=32, col_block=32, group=8, lane=8))
    X = rng.standard_normal((90, 5)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(spmv(tiles, X, backend="jnp")), dense @ X, rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(spmm(csr, X, backend="jnp")), dense @ X, rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(spmm(csr, X), dense @ X, rtol=1e-4, atol=1e-4)


def test_spmm_k1_column_vector_keeps_shape(rng):
    """Regression: an [n, 1] RHS takes the SpMM path on every container and
    comes back as [n, 1] — never silently squeezed to [n]."""
    dense = (rng.standard_normal((60, 70)) * (rng.random((60, 70)) < 0.15)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    tiles = build_tiles(csr, PartitionConfig(row_block=32, col_block=32, group=8, lane=8))
    hbp = build_hbp(csr, PartitionConfig(row_block=32, col_block=32, group=8, lane=8), warp=8)
    x = rng.standard_normal((70, 1)).astype(np.float32)
    want = dense @ x
    for A in (csr, tiles, hbp):
        for fn in (spmv, spmm):
            y = np.asarray(fn(A, x))
            assert y.shape == (60, 1), f"{type(A).__name__}/{fn.__name__}: {y.shape}"
            np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)
    # jnp backends too
    assert np.asarray(spmm(csr, x, backend="jnp")).shape == (60, 1)
    assert np.asarray(spmv(tiles, x, backend="jnp")).shape == (60, 1)


def test_spmv_dispatches_nested_list_by_true_rank(rng):
    """A 2-D input without an .ndim attribute (nested list) must still
    route to the SpMM path instead of falling through to 1-D spmv."""
    dense = (rng.standard_normal((40, 30)) * (rng.random((40, 30)) < 0.2)).astype(
        np.float32
    )
    csr = csr_from_dense(dense)
    x = rng.standard_normal((30, 1)).astype(np.float32)
    y = np.asarray(spmv(csr, x.tolist()))
    assert y.shape == (40, 1)
    np.testing.assert_allclose(y, dense @ x, rtol=1e-4, atol=1e-4)


def test_spmm_spmv_reject_wrong_rank(rng):
    csr = csr_from_dense(np.eye(8, dtype=np.float32))
    with pytest.raises(ValueError, match="spmm expects"):
        spmm(csr, np.ones(8, np.float32))
    with pytest.raises(ValueError, match="spmv expects"):
        spmv(csr, np.ones((8, 1, 1), np.float32))


def test_spmm_empty_matrix():
    tiles = build_tiles(
        csr_from_dense(np.zeros((32, 32), np.float32)),
        PartitionConfig(row_block=16, col_block=16, group=4, lane=4),
    )
    Y = np.asarray(hbp_spmm(tiles, np.ones((32, 3), np.float32), interpret=True))
    assert Y.shape == (32, 3) and (Y == 0).all()


# --- lane-tiled k loop: feature widths beyond one 128-lane tile -----------


def _max_oracle(dense: np.ndarray, X: np.ndarray) -> np.ndarray:
    """max_j(a_ij * x_jk) over stored entries; empty rows -> 0."""
    out = np.zeros((dense.shape[0], X.shape[1]), np.float32)
    for i in range(dense.shape[0]):
        nz = np.nonzero(dense[i])[0]
        if nz.size:
            out[i] = (dense[i, nz, None] * X[nz]).max(axis=0)
    return out


@pytest.mark.parametrize("k", [130, 256])
@pytest.mark.parametrize("strategy", ["fused", "partials", "reference", "stable"])
def test_lane_tiled_wide_k_matches_dense(k, strategy, rng):
    """k > LANE_TILE tiles over sequential <=128-lane chunks inside
    _hbp_spmm_device instead of spilling the lane dimension."""
    from repro.kernels.ops import LANE_TILE

    assert k > LANE_TILE
    dense = (rng.standard_normal((70, 90)) * (rng.random((70, 90)) < 0.12)).astype(
        np.float32
    )
    tiles = build_tiles(
        csr_from_dense(dense), PartitionConfig(row_block=32, col_block=64, group=8, lane=8)
    )
    X = rng.standard_normal((90, k)).astype(np.float32)
    Y = np.asarray(hbp_spmm(tiles, X, strategy=strategy, interpret=True))
    np.testing.assert_allclose(Y, dense @ X, rtol=1e-4, atol=1e-4)


def test_stable_strategy_invariant_across_lane_tiles(rng):
    """A column's bits must not depend on the launch width even when the
    width crosses the LANE_TILE boundary — the serving guarantee extended
    to GNN feature blocks."""
    dense = (rng.standard_normal((60, 80)) * (rng.random((60, 80)) < 0.15)).astype(
        np.float32
    )
    tiles = build_tiles(
        csr_from_dense(dense), PartitionConfig(row_block=32, col_block=32, group=8, lane=8)
    )
    X = rng.standard_normal((80, 200)).astype(np.float32)
    Y_wide = np.asarray(hbp_spmm(tiles, X, strategy="stable"))
    for j in (0, 127, 128, 199):  # columns straddling the chunk boundary
        yj = np.asarray(hbp_spmv(tiles, X[:, j], strategy="stable"))
        assert np.array_equal(Y_wide[:, j], yj), f"column {j}"
    Y_narrow = np.asarray(hbp_spmm(tiles, X[:, :130], strategy="stable"))
    assert np.array_equal(Y_narrow, Y_wide[:, :130])


# --- max-monoid combine (GNN max aggregation) ------------------------------


@pytest.mark.parametrize("k", [1, 5, 16, 256])
@pytest.mark.parametrize("strategy", ["fused", "partials", "reference", "stable"])
def test_hbp_spmm_max_matches_oracle(k, strategy, rng):
    dense = (rng.standard_normal((60, 70)) * (rng.random((60, 70)) < 0.12)).astype(
        np.float32
    )
    dense[7] = 0.0  # empty rows inside occupied groups
    dense[31] = 0.0
    tiles = build_tiles(
        csr_from_dense(dense), PartitionConfig(row_block=32, col_block=32, group=8, lane=8)
    )
    X = rng.standard_normal((70, k)).astype(np.float32)
    Y = np.asarray(
        hbp_spmm(tiles, X, strategy=strategy, combine="max", interpret=True)
    )
    # max is exact arithmetic (no reassociation error): exact equality
    np.testing.assert_array_equal(Y, _max_oracle(dense, X))


@pytest.mark.parametrize("strategy", ["fused", "partials", "stable"])
def test_max_identity_never_leaks_on_empty_rows(strategy, rng):
    """Satellite acceptance: with all-negative features, empty rows must
    yield exactly 0 — the -inf identity of the max monoid (and the 0 of a
    padded slot's product) must never surface."""
    dense = np.zeros((48, 50), np.float32)
    keep = rng.random((48, 50)) < 0.1
    keep[::5] = False  # every 5th row fully empty
    # positive weights: every stored product of a negative feature is
    # negative, so a leaked 0 (padded slot) or -inf (identity) would show
    dense[keep] = (0.1 + rng.random(int(keep.sum()))).astype(np.float32)
    csr = csr_from_dense(dense)
    tiles = build_tiles(csr, PartitionConfig(row_block=16, col_block=32, group=4, lane=4))
    X = -1.0 - rng.random((50, 6)).astype(np.float32)  # strictly negative
    Y = np.asarray(hbp_spmm(tiles, X, strategy=strategy, combine="max", interpret=True))
    assert np.isfinite(Y).all()
    empty = np.asarray(csr.row_nnz() == 0)
    assert (Y[empty] == 0).all(), "empty rows must be exactly 0"
    # non-empty rows of an all-negative product really are negative — the
    # padded slots' 0 product did not win the max
    np.testing.assert_array_equal(Y, _max_oracle(dense, X))
    assert (Y[~empty] < 0).all()


def test_max_combine_empty_matrix_is_zero():
    tiles = build_tiles(
        csr_from_dense(np.zeros((16, 16), np.float32)),
        PartitionConfig(row_block=8, col_block=8, group=4, lane=4),
    )
    Y = np.asarray(
        hbp_spmm(tiles, np.ones((16, 3), np.float32), combine="max", interpret=True)
    )
    assert Y.shape == (16, 3) and (Y == 0).all()


def test_unknown_combine_rejected(rng):
    tiles = build_tiles(
        csr_from_dense(np.eye(8, dtype=np.float32)),
        PartitionConfig(row_block=8, col_block=8, group=4, lane=4),
    )
    with pytest.raises(ValueError, match="combine"):
        hbp_spmm(tiles, np.ones((8, 2), np.float32), combine="min", interpret=True)


# --- end-to-end equivalence across the scaled Table-I structural families ---

FAMILIES = {
    "rmat": lambda: rmat(1 << 9, 3000, seed=4),
    "circuit": lambda: circuit(700, seed=1, n_dense_rows=3, dense_row_frac=0.02),
    "banded_fem": lambda: banded_fem(600, seed=3, band=4, fill=0.9),
    "dense_block": lambda: dense_block(512, seed=8, block=48, n_blocks=3, background=4.0),
    "uniform": lambda: uniform_random(400, 0.01, seed=0),
}


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_end_to_end_equivalence(family):
    """hbp_spmv_reference (Algorithm 3) vs csr_spmv_jnp (Algorithm 1) vs
    the Pallas interpret path, on every suite generator family."""
    csr = FAMILIES[family]()
    x = np.random.default_rng(7).standard_normal(csr.n_cols).astype(np.float32)

    y_csr_np = csr.matvec(x)
    y_csr_jnp = np.asarray(
        csr_spmv_jnp(
            jnp.asarray(csr.indptr),
            jnp.asarray(csr.indices),
            jnp.asarray(csr.data.astype(np.float32)),
            jnp.asarray(x),
            csr.n_rows,
        )
    )
    cfg = PartitionConfig(row_block=128, col_block=256, group=8, lane=16)
    hbp = build_hbp(csr, cfg, warp=8, method="hash")
    y_hbp_ref = hbp_spmv_reference(hbp, x.astype(np.float64))
    tiles = build_tiles(csr, cfg, method="hash")
    y_pallas = np.asarray(spmv(tiles, x, backend="pallas", interpret=True))

    scale = np.abs(y_csr_np).max() + 1e-12
    np.testing.assert_allclose(y_csr_jnp / scale, y_csr_np / scale, atol=2e-6)
    np.testing.assert_allclose(y_hbp_ref / scale, y_csr_np / scale, atol=2e-6)
    np.testing.assert_allclose(y_pallas / scale, y_csr_np / scale, atol=2e-6)
