"""Optimizer: int8 moment quantization, schedules, clipping, training."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.optim.adamw import (
    AdamWConfig,
    _dequantize,
    _quantize,
    adamw_update,
    init_opt_state,
    lr_schedule,
)
from repro.train.steps import init_train_state, make_train_step


def test_quantize_roundtrip_error(rng):
    x = jnp.asarray(rng.standard_normal((16, 100)).astype(np.float32))
    qt = _quantize(x)
    x2 = _dequantize(qt)
    # absmax int8 per row: error bounded by half a quantization step
    err = np.abs(np.asarray(x2) - np.asarray(x))
    bound = np.asarray(qt.scale).max() * 0.51
    assert err.max() <= bound
    assert qt.q.shape == x.shape and qt.scale.shape == (16, 1)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, decay_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 120, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9  # peak after warmup
    assert lrs[-1] < lrs[1]  # decays


@pytest.mark.parametrize("state_dtype", ["float32", "int8"])
def test_loss_decreases(state_dtype):
    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    ocfg = AdamWConfig(
        lr_peak=3e-3, warmup_steps=5, decay_steps=100, state_dtype=state_dtype
    )
    state = init_train_state(model, jax.random.key(0), ocfg)
    step = jax.jit(make_train_step(model, ocfg, n_microbatch=1, remat=False))
    tokens = jax.random.randint(jax.random.key(7), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tokens}
    losses = []
    for _ in range(40):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::8]


def test_grad_accumulation_matches_full_batch():
    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    ocfg = AdamWConfig(lr_peak=1e-3, warmup_steps=1, decay_steps=10)
    tokens = jax.random.randint(jax.random.key(3), (8, 16), 0, cfg.vocab)
    batch = {"tokens": tokens}
    s1 = init_train_state(model, jax.random.key(0), ocfg)
    s2 = jax.tree.map(lambda x: x, s1)
    step1 = jax.jit(make_train_step(model, ocfg, n_microbatch=1, remat=False))
    step4 = jax.jit(make_train_step(model, ocfg, n_microbatch=4, remat=False))
    s1, m1 = step1(s1, batch)
    s2, m4 = step4(s2, batch)
    # same data, same update (microbatch mean == full mean for equal sizes)
    p1 = jax.tree.leaves(s1["params"])
    p2 = jax.tree.leaves(s2["params"])
    for a, b in zip(p1, p2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(grad_clip=1e-6, lr_peak=1.0, warmup_steps=0, decay_steps=1)
    params = {"w": jnp.ones(4)}
    grads = {"w": jnp.full(4, 100.0)}
    opt = init_opt_state(params, cfg)
    new_p, _, m = adamw_update(params, grads, opt, cfg)
    # clipped grad norm -> tiny moment -> bounded first update
    assert float(m["grad_norm"]) > 1.0
    assert np.isfinite(np.asarray(new_p["w"])).all()


def test_topk_compression_error_feedback():
    """Compressed SGD with error feedback converges to the dense direction:
    the residual guarantees every coordinate is eventually transmitted."""
    from repro.optim.compression import TopKCompressor

    comp = TopKCompressor(ratio=0.25, min_k=1)
    g = {"w": jnp.asarray(np.linspace(-1, 1, 64).astype(np.float32))}
    state = comp.init(g)
    total = jax.tree.map(jnp.zeros_like, g)
    for _ in range(8):
        out, state = comp.round_trip(g, state)
        total = jax.tree.map(lambda a, b: a + b, total, out)
    # after n rounds, sum of transmitted grads ~ n * g (residual bounded)
    err = np.abs(np.asarray(total["w"]) / 8 - np.asarray(g["w"]))
    assert err.max() < 0.3  # bounded staleness
    # wire savings at a deployment ratio (val+idx = 8 B/coord vs 2 B dense:
    # breakeven is ratio 1/4; production ratios are 1e-2..1e-3)
    big = {"w": jnp.zeros(100_000, jnp.float32)}
    full, wire = TopKCompressor(ratio=0.01).wire_bytes(big)
    assert wire < full / 10


def test_topk_compression_exact_at_ratio_1():
    from repro.optim.compression import TopKCompressor

    comp = TopKCompressor(ratio=1.0)
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)).astype(np.float32))}
    state = comp.init(g)
    out, state = comp.round_trip(g, state)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]), atol=1e-6)
    assert float(jnp.abs(state["w"]).max()) == 0.0
