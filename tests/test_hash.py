"""Nonlinear hash (paper §III-B): unit + property tests."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.hash import (
    HashParams,
    hash_insert_probe,
    hash_insert_ranked,
    hash_reorder,
    hash_slot,
    sample_params,
)


@given(
    st.lists(st.integers(min_value=0, max_value=10_000), min_size=1, max_size=512),
    st.integers(min_value=0, max_value=8),
)
@settings(max_examples=60, deadline=None)
def test_hash_reorder_is_permutation(nnz, a):
    nnz = np.asarray(nnz)
    p = HashParams(a=a, c=max(1, nnz.size // 9), b=nnz.size, d=max(1, nnz.size // 9))
    perm = hash_reorder(nnz, p)
    assert sorted(perm.tolist()) == list(range(nnz.size))


@given(st.lists(st.integers(min_value=0, max_value=100_000), min_size=1, max_size=300))
@settings(max_examples=40, deadline=None)
def test_sampled_params_bucket_range(nnz):
    nnz = np.asarray(nnz)
    p = sample_params(nnz, table_size=max(nnz.size, 16))
    buckets = np.minimum(nnz >> p.a, p.n_buckets - 1)
    # the 99th-percentile row lands inside the clipped bucket range
    q = np.quantile(nnz[nnz > 0], 0.99) if (nnz > 0).any() else 0
    assert (int(q) >> p.a) <= p.n_buckets - 1


def test_probe_and_ranked_group_identically(rng):
    """Probing and the vectorised rank insertion must produce the same
    bucket-contiguous ordering (same rows grouped, same bucket order)."""
    nnz = rng.integers(0, 600, size=512)
    p = sample_params(nnz, table_size=512)
    slot0 = hash_slot(nnz, p)
    perm_probe = hash_reorder(nnz, p, method="probe")
    perm_rank = hash_reorder(nnz, p, method="ranked")
    # same multiset of initial slots in execution order
    assert np.array_equal(np.sort(slot0[perm_probe]), np.sort(slot0[perm_rank]))
    # ranked execution order is sorted by initial slot (bucket-contiguous)
    s = slot0[perm_rank]
    assert (np.diff(s) >= 0).all()


def test_aggregation_groups_similar_rows():
    """Rows with nnz in [k·2^a, (k+1)·2^a) share a bucket (Fig. 4)."""
    p = HashParams(a=2, c=10, b=90, d=10)
    nnz = np.arange(0, 36)
    buckets = np.minimum(nnz >> p.a, 8)
    slots = hash_slot(nnz, p)
    for k in range(8):
        rows = np.where(buckets == k)[0]
        assert (slots[rows] // p.c == k).all()


def test_probe_collision_resolution():
    slot0 = np.zeros(16, dtype=np.int64)  # everyone collides at 0
    slots = hash_insert_probe(slot0, 16)
    assert sorted(slots.tolist()) == list(range(16))


def test_ranked_rejects_overfull():
    with pytest.raises(ValueError):
        hash_insert_ranked(np.zeros(10, dtype=np.int64), 5)
