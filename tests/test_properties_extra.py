"""Additional system-invariant property tests (hypothesis)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (
    PartitionConfig,
    build_tiles,
    csr_from_dense,
    lpt_schedule,
    mixed_schedule,
    spmv,
    tuned_partition_config,
)
from repro.data.pipeline import DataConfig, SyntheticLM


@given(
    st.integers(10, 150),
    st.integers(10, 200),
    st.floats(0.01, 0.5),
    st.integers(0, 8),
)
@settings(max_examples=15, deadline=None)
def test_tuned_geometry_never_loses_nnz(m, k, density, seed):
    """Every nonzero is represented exactly once for any tuned geometry."""
    rng = np.random.default_rng(seed)
    dense = (rng.standard_normal((m, k)) * (rng.random((m, k)) < density)).astype(np.float32)
    csr = csr_from_dense(dense)
    cfg = tuned_partition_config(csr, row_block=64, col_block=64)
    tiles = build_tiles(csr, cfg)
    assert np.count_nonzero(tiles.data) == csr.nnz
    x = rng.standard_normal(k).astype(np.float32)
    y = np.asarray(spmv(tiles, x, backend="jnp"))
    np.testing.assert_allclose(y, dense @ x, rtol=1e-4, atol=1e-4)


@given(st.lists(st.floats(0.01, 50.0), min_size=2, max_size=300), st.integers(2, 24))
@settings(max_examples=30, deadline=None)
def test_lpt_never_worse_than_one_block(costs, workers):
    """LPT makespan is bounded by max(single block, 2x mean) — the classic
    list-scheduling guarantee."""
    costs = np.asarray(costs)
    sched = lpt_schedule(costs, workers)
    bound = max(costs.max(), costs.sum() / workers * 2)
    assert sched.loads.max() <= bound + 1e-9


@given(st.integers(0, 10_000), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_data_pipeline_steps_independent(step_a, step_b):
    cfg = DataConfig(vocab=512, seq_len=16, global_batch=4, seed=9)
    s = SyntheticLM(cfg)
    a = s.batch_at(step_a)["tokens"]
    b = s.batch_at(step_b)["tokens"]
    if step_a == step_b:
        np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < cfg.vocab


def test_host_slice_consistent_with_global():
    """Host slices [lo, hi) must be reproducible independent of the host
    count — the multi-host data-loading invariant."""
    cfg = DataConfig(vocab=1000, seq_len=8, global_batch=16, seed=2)
    s = SyntheticLM(cfg)
    full = s.batch_at(5)["tokens"]
    lo, hi = 4, 12
    part = s.batch_at(5, lo=lo, hi=hi)["tokens"]
    # slices are drawn from independent streams keyed by (lo, hi): the
    # invariant is determinism per (step, lo, hi), not sub-slicing of the
    # full batch (documented in data/pipeline.py)
    part2 = s.batch_at(5, lo=lo, hi=hi)["tokens"]
    np.testing.assert_array_equal(part, part2)
    assert part.shape == (hi - lo, cfg.seq_len)
