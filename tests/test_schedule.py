"""Mixed execution allocation (paper §III-C) — static competitive replay."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import contiguous_schedule, lpt_schedule, mixed_schedule


@given(
    st.lists(st.floats(0.01, 100.0), min_size=1, max_size=400),
    st.integers(1, 32),
)
@settings(max_examples=50, deadline=None)
def test_schedules_cover_all_blocks(costs, workers):
    costs = np.asarray(costs)
    for sched in (
        contiguous_schedule(costs, workers),
        lpt_schedule(costs, workers),
        mixed_schedule(costs, workers, n_cols=7),
    ):
        got = sorted(b for a in sched.assignment for b in a)
        assert got == list(range(costs.size))


def test_lpt_beats_contiguous_on_skew(rng):
    costs = rng.pareto(1.2, size=512) + 0.05
    c = contiguous_schedule(costs, 16).makespan_ratio
    l = lpt_schedule(costs, 16).makespan_ratio
    assert l <= c + 1e-9


def test_lpt_within_4_3_of_optimal(rng):
    """Graham's bound: LPT makespan <= (4/3 - 1/3m) * OPT."""
    for _ in range(10):
        costs = rng.random(64) + 0.01
        m = 8
        sched = lpt_schedule(costs, m)
        opt_lb = max(costs.max(), costs.sum() / m)  # lower bound on OPT
        assert sched.loads.max() <= (4 / 3) * opt_lb + 1e-9


def test_mixed_fixed_part_prefers_column_runs(rng):
    """Fixed-phase blocks on one worker should show same-column runs
    (vector segment reuse, paper Fig. 5)."""
    nbr, nbc = 16, 8
    costs = np.ones(nbr * nbc)
    sched = mixed_schedule(costs, 4, n_cols=nbc, fixed_fraction=1.0)
    for w, blocks in enumerate(sched.assignment):
        cols = [b % nbc for b in blocks]
        # runs of equal column ids: number of transitions far below random
        transitions = sum(1 for a, b in zip(cols, cols[1:]) if a != b)
        assert transitions <= len(cols) / 4


def test_padded_schedule_dense(rng):
    costs = rng.random(37)
    sched = mixed_schedule(costs, 8, n_cols=5)
    padded = sched.padded()
    assert padded.shape[0] == 8
    valid = padded[padded >= 0]
    assert sorted(valid.tolist()) == list(range(37))
