"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, output shapes + finiteness (deliverable f)."""
import numpy as np
import pytest

import jax

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state, make_train_step


def _batch(cfg, B=2, S=16, seed=1):
    k = jax.random.key(seed)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.key(2), (B, cfg.frontend_tokens, cfg.d_model)
        )
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(jax.random.key(3), (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_smoke(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, _, aux = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ["olmo-1b", "granite-moe-1b-a400m", "mamba2-370m",
                                  "jamba-1.5-large-398b", "seamless-m4t-large-v2"])
def test_train_step_smoke(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    ocfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2, decay_steps=10)
    state = init_train_state(model, jax.random.key(0), ocfg)
    step = jax.jit(make_train_step(model, ocfg, n_microbatch=1, remat=False))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert int(metrics["step"]) == 1


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b", "mamba2-370m", "jamba-1.5-large-398b"])
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S, P = 2, 16, 12
    batch = _batch(cfg, B, S)
    toks = batch["tokens"]
    logits_full, _, _ = model.forward(params, batch)
    pre = {k: (v[:, :P] if k == "tokens" else v) for k, v in batch.items()}
    cache = model.init_cache(B, S + 4, cross_len=S)
    logits_pre, cache, _ = model.forward(params, pre, cache=cache, pos0=0)
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits_full[:, :P]), atol=2e-4, rtol=1e-3
    )
    for t in range(P, S):
        step = {"tokens": toks[:, t : t + 1]}
        logits_d, cache, _ = model.forward(params, step, cache=cache, pos0=t)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(logits_full[:, t]), atol=2e-4, rtol=1e-3
        )
