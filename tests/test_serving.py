"""The SpMV serving subsystem: registry, micro-batcher, engine.

The load-bearing property is coalescing invariance: a request's result
must be bitwise independent of whatever traffic it was batched with —
mixed-k batches, padded bucket slots, two matrices interleaved — and
bitwise identical to serving it alone (a sequential spmv call through the
same plan).  The registry side covers content-addressed admission and the
flush policies of the batcher are exercised on a virtual clock.
"""
import numpy as np
import pytest

from repro.core import PartitionConfig, build_tiles, csr_from_dense, spmv
from repro.core.matrices import banded_fem, circuit
from repro.kernels import ops
from repro.serving import MatrixRegistry, MicroBatcher, ServingEngine, SpMVRequest

CFG = PartitionConfig(row_block=64, col_block=128, group=8, lane=16)


@pytest.fixture()
def two_matrices():
    A = circuit(150, seed=1, n_dense_rows=2, dense_row_frac=0.05)
    B = banded_fem(130, seed=3, band=4, fill=0.9)
    return A, B


@pytest.fixture()
def registry(tmp_path):
    # pinned config: admission cost stays trivial; autotune has its own tests
    reg = MatrixRegistry(cache_dir=tmp_path / "cache", search=False)
    return reg


# --- bucket arithmetic ----------------------------------------------------


def test_bucket_k():
    assert [ops.bucket_k(k) for k in (1, 2, 3, 4, 5, 8, 9, 16)] == [
        1, 2, 4, 4, 8, 8, 16, 16,
    ]
    assert ops.bucket_k(17) == 32 and ops.bucket_k(100) == 128  # in-bucket
    with pytest.raises(ValueError):
        ops.bucket_k(0)
    with pytest.raises(ValueError):
        ops.bucket_k(4, buckets=())


def test_bucket_k_above_top_bucket_tiles_never_clamps():
    """Regression: k beyond max(K_BUCKETS) must round UP to top-bucket
    multiples (lane tiles), never clamp down to the top bucket."""
    top = ops.K_BUCKETS[-1]
    assert ops.bucket_k(top + 1) == 2 * top
    assert ops.bucket_k(300) == -(-300 // top) * top
    assert ops.bucket_k(4 * top) == 4 * top
    # and the bucketed SpMM entry really serves such a width correctly
    rng = np.random.default_rng(11)
    dense = (rng.standard_normal((40, 50)) * (rng.random((40, 50)) < 0.2)).astype(
        np.float32
    )
    tiles = build_tiles(csr_from_dense(dense), CFG)
    k = top + 7
    X = rng.standard_normal((50, k)).astype(np.float32)
    Y = np.asarray(ops.hbp_spmm_bucketed(tiles, X, strategy="stable"))
    assert Y.shape == (40, k)
    np.testing.assert_allclose(Y, dense @ X, rtol=1e-4, atol=1e-4)


def test_hbp_spmm_bucketed_matches_unpadded(rng):
    dense = (rng.standard_normal((60, 80)) * (rng.random((60, 80)) < 0.1)).astype(
        np.float32
    )
    tiles = build_tiles(csr_from_dense(dense), CFG)
    X = rng.standard_normal((80, 5)).astype(np.float32)
    Y = np.asarray(ops.hbp_spmm_bucketed(tiles, X, strategy="stable"))
    assert Y.shape == (60, 5)
    np.testing.assert_allclose(Y, dense @ X, rtol=1e-4, atol=1e-4)


def test_stable_strategy_is_batch_width_invariant(rng):
    """The kernel-level guarantee the engine's bitwise contract rests on:
    a column's bits do not depend on the launch width or slot position."""
    dense = (rng.standard_normal((120, 150)) * (rng.random((120, 150)) < 0.1)).astype(
        np.float32
    )
    tiles = build_tiles(csr_from_dense(dense), CFG)
    X = rng.standard_normal((150, 16)).astype(np.float32)
    Y16 = np.asarray(ops.hbp_spmm(tiles, X, strategy="stable"))
    for k in (1, 2, 3, 5, 8):
        Yk = np.asarray(ops.hbp_spmm(tiles, X[:, :k], strategy="stable"))
        assert np.array_equal(Yk, Y16[:, :k])
    # single-vector spmv == any column of any launch
    for j in (0, 7, 15):
        yj = np.asarray(ops.hbp_spmv(tiles, X[:, j], strategy="stable"))
        assert np.array_equal(yj, Y16[:, j])


# --- micro-batcher policy (pure queueing, virtual time) -------------------


def _req(key, n, i, t):
    return SpMVRequest(key=key, x=np.zeros(n, np.float32), req_id=i, t_submit=t)


def test_batcher_flushes_on_size():
    b = MicroBatcher(max_batch=4, max_wait_s=10.0)
    for i in range(3):
        b.add(_req("A", 8, i, t=0.0))
    assert b.due(now=0.001) == []  # neither full nor overdue
    b.add(_req("A", 8, 3, t=0.0))
    assert b.due(now=0.001) == ["A"]
    batch = b.take("A")
    assert [r.req_id for r in batch] == [0, 1, 2, 3]  # FIFO
    assert b.pending("A") == 0


def test_batcher_flushes_on_deadline():
    b = MicroBatcher(max_batch=16, max_wait_s=0.5)
    b.add(_req("A", 8, 0, t=1.0))
    b.add(_req("B", 8, 1, t=1.2))
    assert b.due(now=1.4) == []
    assert b.due(now=1.5) == ["A"]  # A's oldest hit the deadline, B not yet
    assert sorted(b.due(now=1.8)) == ["A", "B"]


def test_batcher_keeps_matrices_separate():
    b = MicroBatcher(max_batch=2, max_wait_s=10.0)
    b.add(_req("A", 8, 0, t=0.0))
    b.add(_req("B", 8, 1, t=0.0))
    b.add(_req("A", 8, 2, t=0.0))
    assert b.due(now=0.0) == ["A"]  # A full; B alone stays queued
    assert {r.key for r in b.take("A")} == {"A"}
    assert b.pending("B") == 1


# --- engine: correctness, bitwise coalescing invariance -------------------


def test_mixed_k_two_matrices_bitwise_vs_sequential(two_matrices, registry, rng):
    """Acceptance: mixed-k concurrent requests against two registered
    matrices == sequential per-request spmv, bitwise, padded slots and
    all."""
    A, B = two_matrices
    pa = registry.admit(A, "A")
    pb = registry.admit(B, "B")
    eng = ServingEngine(registry, max_wait_s=1e9, max_batch=8)

    xs = {"A": [], "B": []}
    tickets = []
    rngs = np.random.default_rng(7)
    # interleaved submits with deliberately awkward totals: A gets 11
    # (batches of 8 + 3 -> buckets 8 and 4, one padded slot each), B gets 5
    # (bucket 8, three padded slots)
    for i in range(16):
        key = "A" if i % 3 != 2 else "B"
        n_cols = (pa if key == "A" else pb).shape[1]
        x = rngs.standard_normal(n_cols).astype(np.float32)
        xs[key].append(x)
        tickets.append((key, x, eng.submit(key, x)))
    assert len(xs["A"]) == 11 and len(xs["B"]) == 5

    served = eng.flush()
    assert served == 16
    for key, x, ticket in tickets:
        plan = pa if key == "A" else pb
        y_seq = np.asarray(plan.matvec(x))  # sequential spmv, same plan
        assert np.array_equal(np.asarray(ticket.result()), y_seq)
        # and numerically right against the CSR reference
        csr = A if key == "A" else B
        np.testing.assert_allclose(
            ticket.result(), spmv(csr, x.astype(np.float64)), rtol=1e-4, atol=1e-4
        )

    stats = eng.stats()
    assert stats["A"]["requests"] == 11 and stats["A"]["batches"] == 2
    assert stats["B"]["requests"] == 5 and stats["B"]["batches"] == 1
    assert stats["B"]["pad_fraction"] == pytest.approx(3 / 8)
    assert stats["A"]["latency_p99_s"] is not None
    assert stats["A"]["amortized_preprocess_s"] == pytest.approx(
        stats["A"]["preprocess_s"] / 11
    )


def test_engine_deadline_flush_on_virtual_clock(two_matrices, registry):
    A, _ = two_matrices
    plan = registry.admit(A, "A")
    now = [0.0]
    eng = ServingEngine(registry, max_wait_s=0.010, max_batch=8, clock=lambda: now[0])
    t1 = eng.submit("A", np.ones(plan.shape[1], np.float32))
    assert eng.poll() == 0  # deadline not reached
    now[0] = 0.005
    assert eng.poll() == 0
    now[0] = 0.011
    assert eng.poll() == 1  # deadline flush, batch of one
    assert t1.done()
    assert t1.latency_s() == pytest.approx(0.011)


def test_engine_burst_drains_multiple_full_batches(two_matrices, registry):
    A, _ = two_matrices
    plan = registry.admit(A, "A")
    now = [0.0]
    eng = ServingEngine(registry, max_wait_s=0.010, max_batch=4, clock=lambda: now[0])
    tickets = [
        eng.submit("A", np.ones(plan.shape[1], np.float32)) for _ in range(10)
    ]
    assert eng.poll() == 8  # two full batches fire immediately; 2 left waiting
    assert eng.stats()["A"]["pending"] == 2
    now[0] = 0.02
    assert eng.poll() == 2  # remainder goes out on deadline
    assert all(t.done() for t in tickets)
    assert eng.stats()["A"]["batches"] == 3


def test_engine_health_reflects_slo_burn(two_matrices, registry):
    """health() is the QoS-facing view: clean traffic reads ok, a stalled
    engine pages, and custom SLOs ride the same event stream."""
    from repro.obs.slo import SLO

    A, _ = two_matrices
    plan = registry.admit(A, "A")
    now = [0.0]
    eng = ServingEngine(
        registry,
        max_wait_s=0.010,
        max_batch=4,
        clock=lambda: now[0],
        slos=(
            SLO("deadline", "deadline_hit_ratio", 0.99),
            SLO("p99", "latency_p99", 5.0),  # generous: never violated here
        ),
    )
    for _ in range(4):
        eng.submit("A", np.ones(plan.shape[1], np.float32))
    eng.poll()  # full batch flushes immediately: every deadline hit
    h = eng.health(now=now[0])
    assert h["status"] == "ok"
    assert h["matrices"]["A"]["status"] == "ok"
    assert set(h["matrices"]["A"]["slos"]) == {"deadline", "p99"}
    # stall the next batch far past its deadline: the engine must page
    for _ in range(4):
        eng.submit("A", np.ones(plan.shape[1], np.float32))
    now[0] = 1.0
    eng.flush()
    h = eng.health(now=now[0])
    assert h["matrices"]["A"]["status"] == "page"
    assert h["status"] == "page"
    burn = h["matrices"]["A"]["slos"]["deadline"]["windows"]["60s"]["burn_rate"]
    assert burn > 14  # half the traffic missed vs a 1% budget
    # the latency SLO with its generous bound stays clean throughout
    assert h["matrices"]["A"]["slos"]["p99"]["status"] == "ok"


def test_ticket_result_forces_flush(two_matrices, registry):
    A, _ = two_matrices
    plan = registry.admit(A, "A")
    eng = ServingEngine(registry, max_wait_s=1e9)
    x = np.arange(plan.shape[1], dtype=np.float32)
    t = eng.submit("A", x)
    assert not t.done()
    y = t.result()  # demand-driven drain
    assert t.done()
    assert np.array_equal(y, np.asarray(plan.matvec(x)))


def test_engine_custom_buckets_reach_the_kernel(two_matrices, registry):
    """The engine's buckets must drive both the kernel padding and the
    accounting: with a single 8-wide bucket, a batch of 5 pads 3 slots."""
    A, _ = two_matrices
    plan = registry.admit(A, "A")
    eng = ServingEngine(registry, max_wait_s=1e9, max_batch=8, buckets=(8,))
    xs = [np.full(plan.shape[1], i + 1.0, np.float32) for i in range(5)]
    tickets = [eng.submit("A", x) for x in xs]
    eng.flush()
    for x, t in zip(xs, tickets):
        assert np.array_equal(np.asarray(t.result()), np.asarray(plan.matvec(x)))
    assert eng.stats()["A"]["pad_fraction"] == pytest.approx(3 / 8)


def test_engine_rejects_bad_submissions(two_matrices, registry):
    A, _ = two_matrices
    registry.admit(A, "A")
    eng = ServingEngine(registry)
    with pytest.raises(KeyError):
        eng.submit("unknown", np.ones(4, np.float32))
    with pytest.raises(ValueError, match="expects"):
        eng.submit("A", np.ones(3, np.float32))
    with pytest.raises(ValueError, match="k-bucket"):
        ServingEngine(registry, max_batch=2 * ops.K_BUCKETS[-1])


# --- registry -------------------------------------------------------------


def test_registry_content_addressing(two_matrices, registry):
    A, B = two_matrices
    plan = registry.admit(A, "A")
    again = registry.admit(A, "A-alias")  # same content: alias is ignored
    assert again is plan
    assert plan.admissions == 2
    assert len(registry) == 1
    registry.admit(B, "B")
    assert sorted(registry.names()) == ["A", "B"]
    with pytest.raises(ValueError, match="already bound"):
        registry.admit(circuit(80, seed=9), "A")
    registry.evict("A")
    assert "A" not in registry and len(registry) == 2 - 1


def test_registry_plan_composes_with_solvers(registry, rng):
    """plan.operator()/plan.jacobi(): the serving plan is solver-ready."""
    from repro.solvers import cg

    n = 96
    R = rng.standard_normal((n, n)) * 0.02
    S = (np.eye(n) + R @ R.T).astype(np.float32)
    plan = registry.admit(csr_from_dense(S), "spd")
    np.testing.assert_allclose(np.asarray(plan.diag), np.diagonal(S), rtol=1e-6)
    b = rng.standard_normal(n).astype(np.float32)
    res = cg(plan.operator(), b, tol=1e-6, maxiter=300, M=plan.jacobi())
    assert bool(res.converged)
    x_ref = np.linalg.solve(S.astype(np.float64), b)
    assert np.abs(np.asarray(res.x) - x_ref).max() / np.abs(x_ref).max() < 1e-4


def test_engine_and_registry_stats_share_one_ledger(two_matrices, registry, rng):
    """Regression for the stats() double-bookkeeping: both reports are
    views over the registry's shared MetricRegistry, so admission counts
    (and the preprocess cost the amortization divides) cannot drift."""
    A, B = two_matrices
    registry.admit(A, "A")
    registry.admit(A, "A-again")  # content hit
    registry.admit(B, "B")
    eng = ServingEngine(registry, max_wait_s=1e9, max_batch=8)
    for _ in range(5):
        eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
    eng.flush()

    reg_stats = registry.stats()
    eng_stats = eng.stats()
    for key in ("A", "B"):
        assert eng_stats[key]["admissions"] == reg_stats[key]["admissions"]
        assert eng_stats[key]["preprocess_s"] == reg_stats[key]["preprocess_s"]
    assert reg_stats["A"]["admissions"] == 2
    # both views read the same backing store
    m = registry.metrics
    assert eng.metrics is m
    assert m.value("registry.admissions", matrix="A") == 2
    assert m.value("registry.hits", matrix="A") == 1
    assert m.value("registry.misses", matrix="A") == 1
    assert m.value("serving.requests", matrix="A") == 5
    assert eng_stats["A"]["requests"] == 5
    assert eng_stats["A"]["amortized_preprocess_s"] == pytest.approx(
        reg_stats["A"]["preprocess_s"] / 5
    )
    # a second engine over the same registry reports from the same ledger
    eng2 = ServingEngine(registry, max_wait_s=1e9, max_batch=8)
    assert eng2.stats()["A"]["requests"] == 5
