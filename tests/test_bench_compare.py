"""The benchmark regression gate: thresholds, improvements, --update.

The gate is load-bearing CI (a stale baseline silently masks later
regressions), so its semantics are pinned here: regressions past the
threshold fail, improvements past the threshold nag (never fail), and
``--update`` rewrites the baseline without dropping records a partial run
did not cover.
"""
import json

import pytest

from benchmarks.compare import load_records, main


def _write(path, records):
    path.write_text(json.dumps({"schema": 1, "benches": records}))
    return str(path)


def _rec(name, min_us):
    return {"name": name, "min_us": min_us, "median_us": min_us * 1.1}


@pytest.fixture()
def paths(tmp_path):
    base = _write(
        tmp_path / "baseline.json",
        [_rec("spmm/a", 100.0), _rec("spmm/b", 100.0), _rec("preprocess/x", 50.0)],
    )
    return tmp_path, base


def test_gate_fails_on_regression(paths, capsys):
    tmp_path, base = paths
    cur = _write(tmp_path / "cur.json", [_rec("spmm/a", 130.0), _rec("spmm/b", 99.0)])
    assert main([cur, "--baseline", base]) == 1
    err = capsys.readouterr().err
    assert "FAIL spmm/a" in err


def test_gate_reports_improvements_without_failing(paths, capsys):
    tmp_path, base = paths
    # spmm/a improved 2x (past the 25% threshold), spmm/b only slightly
    cur = _write(tmp_path / "cur.json", [_rec("spmm/a", 50.0), _rec("spmm/b", 95.0)])
    assert main([cur, "--baseline", base]) == 0
    out = capsys.readouterr().out
    assert "IMPROVE spmm/a" in out
    assert "OK   spmm/b" in out
    assert "refresh it with --update" in out
    assert "gate clean" in out


def test_update_rewrites_baseline_keeping_uncovered_records(paths, capsys):
    tmp_path, base = paths
    cur = _write(
        tmp_path / "cur.json", [_rec("spmm/a", 50.0), _rec("spmm/new", 10.0)]
    )
    assert main([cur, "--baseline", base, "--update"]) == 0
    refreshed = load_records(base)
    assert refreshed["spmm/a"]["min_us"] == 50.0  # refreshed from the run
    assert "spmm/new" in refreshed  # new bench enters the baseline
    assert refreshed["spmm/b"]["min_us"] == 100.0  # partial run keeps coverage
    assert refreshed["preprocess/x"]["min_us"] == 50.0
    payload = json.load(open(base))
    names = [r["name"] for r in payload["benches"]]
    assert names == sorted(names)  # deterministic artifact
    # the refreshed baseline now gates the same run cleanly, no IMPROVE nag
    assert main([cur, "--baseline", base]) == 0
    assert "IMPROVE" not in capsys.readouterr().out


def test_update_respects_prefix_filter(paths):
    tmp_path, base = paths
    cur = _write(
        tmp_path / "cur.json", [_rec("spmm/a", 50.0), _rec("preprocess/x", 1.0)]
    )
    assert main([cur, "--baseline", base, "--update", "--prefix", "spmm"]) == 0
    refreshed = load_records(base)
    assert refreshed["spmm/a"]["min_us"] == 50.0
    assert refreshed["preprocess/x"]["min_us"] == 50.0  # outside prefix: kept
