"""Differential performance attribution: the diff tool + the CI wiring.

Pinned behaviors: a synthetic 2x slowdown in one phase must surface as
the top-ranked culprit (for both artifact kinds — obs dumps and bench
JSONs); the output is deterministic; mixed artifact kinds are rejected;
and ``benchmarks.compare --diff-out`` leaves the markdown culprit report
exactly when the gate fails.
"""
import json

import pytest

from benchmarks.compare import main as compare_main
from repro.analysis.diff import (
    artifact_kind,
    diff_artifacts,
    main as diff_main,
    render_markdown,
    render_text,
)


def _obs_dump(scale_serve: float = 1.0) -> dict:
    """A fabricated obs snapshot with admit/kernels/serve phases, request
    aggregates and attr counters; ``scale_serve`` multiplies the serve
    phase (the synthetic regression)."""
    return {
        "schema": 1,
        "registries": [
            {
                "registry": "serving",
                "metrics": [
                    {
                        "name": "attr.compute_s",
                        "labels": {"matrix": "A", "strategy": "stable", "k_tiling": "grid"},
                        "type": "counter",
                        "value": 0.010 * scale_serve,
                    },
                    {
                        "name": "attr.launches",
                        "labels": {"matrix": "A", "strategy": "stable", "k_tiling": "grid"},
                        "type": "counter",
                        "value": 5.0,
                    },
                ],
            }
        ],
        "spans": [
            {"name": "admit.hash", "count": 2, "total_ms": 8.0, "mean_ms": 4.0, "max_ms": 5.0},
            {
                "name": "kernels.launch",
                "count": 10,
                "total_ms": 20.0,
                "mean_ms": 2.0,
                "max_ms": 3.0,
            },
            {
                "name": "serve.flush",
                "count": 4,
                "total_ms": 40.0 * scale_serve,
                "mean_ms": 10.0 * scale_serve,
                "max_ms": 12.0,
            },
        ],
        "requests": [
            {
                "key": "A",
                "queue_wait_s": 0.002,
                "compute_share_s": 0.001 * scale_serve,
                "latency_s": 0.004,
            }
        ],
    }


def _bench(scale_spmm: float = 1.0) -> dict:
    return {
        "schema": 1,
        "benches": [
            {"name": "preprocess/hash", "min_us": 100.0, "median_us": 110.0},
            {"name": "spmm/grid", "min_us": 200.0 * scale_spmm, "median_us": 220.0 * scale_spmm},
        ],
    }


# --- detection ---------------------------------------------------------------


def test_obs_diff_ranks_the_2x_phase_as_top_culprit():
    result = diff_artifacts(_obs_dump(), _obs_dump(scale_serve=2.0))
    assert result["kind"] == "obs"
    top = result["rows"][0]
    assert top["name"] == "serve.flush" and top["phase"] == "serve"
    assert top["ratio"] == pytest.approx(2.0)
    assert result["culprit"]["name"] == "serve.flush"
    # the phase rollup agrees
    assert result["phases"][0]["phase"] == "serve"
    assert result["phases"][0]["ratio"] == pytest.approx(2.0)
    # untouched phases sit at 1.0
    by_phase = {p["phase"]: p for p in result["phases"]}
    assert by_phase["admit"]["ratio"] == pytest.approx(1.0)
    assert by_phase["kernels"]["ratio"] == pytest.approx(1.0)


def test_bench_diff_ranks_the_2x_record_as_top_culprit():
    result = diff_artifacts(_bench(), _bench(scale_spmm=2.0))
    assert result["kind"] == "bench"
    top = result["rows"][0]
    assert top["name"] == "spmm/grid" and top["phase"] == "spmm"
    assert top["ratio"] == pytest.approx(2.0)
    assert "spmm" in render_text(result).split("\n")[1]  # verdict names it


def test_counters_never_outrank_timed_rows():
    a, b = _obs_dump(), _obs_dump()
    # blow up a pure-count counter; timed rows are unchanged
    b["registries"][0]["metrics"][1]["value"] = 5000.0
    result = diff_artifacts(a, b)
    timed = [r for r in result["rows"] if r["excess"] is not None]
    counters = [r for r in result["rows"] if r["excess"] is None]
    assert counters and timed
    assert result["rows"].index(counters[0]) > result["rows"].index(timed[-1])
    assert result["culprit"] is None  # a counter is never the culprit


def test_seconds_counters_diff_as_time():
    result = diff_artifacts(_obs_dump(), _obs_dump(scale_serve=2.0))
    row = next(r for r in result["rows"] if r["name"].startswith("attr.compute_s"))
    assert row["unit"] == "ms" and row["excess"] == pytest.approx(10.0)


# --- safety / determinism ----------------------------------------------------


def test_mixed_kinds_are_rejected_and_unknown_payloads_raise():
    with pytest.raises(ValueError, match="cannot diff"):
        diff_artifacts(_obs_dump(), _bench())
    with pytest.raises(ValueError, match="unrecognized"):
        artifact_kind({"something": 1})


def test_diff_is_deterministic_and_na_safe_on_empty_dumps():
    empty = {"schema": 1, "registries": [], "spans": [], "requests": []}
    result = diff_artifacts(empty, empty)
    assert result["rows"] == [] and result["culprit"] is None
    text = render_text(result)
    assert "n/a" in text
    assert render_text(result) == text
    full = diff_artifacts(_obs_dump(), _obs_dump(scale_serve=2.0))
    assert render_markdown(full) == render_markdown(full)


def test_cli_writes_markdown_report(tmp_path, capsys):
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    a.write_text(json.dumps(_obs_dump()))
    b.write_text(json.dumps(_obs_dump(scale_serve=2.0)))
    out = tmp_path / "diff.md"
    assert diff_main([str(a), str(b), "--out", str(out)]) == 0
    assert "serve.flush" in capsys.readouterr().out
    md = out.read_text()
    assert md.startswith("# Performance diff")
    assert "serve.flush" in md and "2.00x" in md


# --- compare.py integration --------------------------------------------------


def _write(path, payload):
    path.write_text(json.dumps(payload))
    return str(path)


def test_compare_gate_failure_writes_culprit_report(tmp_path, capsys):
    base = _write(tmp_path / "base.json", _bench())
    cur = _write(tmp_path / "cur.json", _bench(scale_spmm=2.0))
    out = tmp_path / "BENCH_diff.md"
    rc = compare_main([cur, "--baseline", base, "--diff-out", str(out)])
    assert rc == 1
    md = out.read_text()
    assert "spmm/grid" in md  # the report names the regressed record...
    assert "| spmm |" in md  # ...and the regressed phase
    assert "verdict: worst regression is spmm/grid" in md


def test_compare_clean_gate_writes_no_report(tmp_path):
    base = _write(tmp_path / "base.json", _bench())
    cur = _write(tmp_path / "cur.json", _bench())
    out = tmp_path / "BENCH_diff.md"
    assert compare_main([cur, "--baseline", base, "--diff-out", str(out)]) == 0
    assert not out.exists()


def test_compare_diff_report_respects_prefix_gating(tmp_path):
    """Records outside the gated prefixes regressing must neither fail the
    gate nor appear in the culprit report."""
    base = _write(tmp_path / "base.json", _bench())
    cur_payload = _bench(scale_spmm=2.0)
    cur_payload["benches"][0]["min_us"] = 1000.0  # huge, but ungated below
    cur = _write(tmp_path / "cur.json", cur_payload)
    out = tmp_path / "BENCH_diff.md"
    rc = compare_main(
        [cur, "--baseline", base, "--prefix", "spmm", "--diff-out", str(out)]
    )
    assert rc == 1
    md = out.read_text()
    assert "spmm/grid" in md
    assert "preprocess/hash" not in md
