"""Graph workload subsystem: construction, aggregation monoids, GNN layers.

Acceptance (ISSUE 3): GCN/GraphSAGE forward on a 10k-node synthetic
power-law graph matches a dense-oracle reference — sum/mean to fp32
tolerance, max exactly — for feature dims 16 through 256, with k = 256
exercising the lane-tiled kernel path rather than a fallback.
"""
import numpy as np
import pytest

import jax

from repro.core import PartitionConfig, build_tiles
from repro.core.formats import CSRMatrix
from repro.graph import (
    add_self_loops,
    aggregate,
    degrees,
    gcn_forward,
    graph_from_edges,
    init_gcn,
    init_sage,
    make_aggregator,
    normalize_adjacency,
    plan_aggregator,
    power_law_graph,
    rmat_graph,
    sage_forward,
)


# --- numpy oracles (CSR-based: the 10k acceptance graph has no dense form) --


def _sum_oracle(csr: CSRMatrix, X: np.ndarray) -> np.ndarray:
    rows = np.repeat(np.arange(csr.n_rows), csr.row_nnz())
    out = np.zeros((csr.n_rows, X.shape[1]), np.float64)
    np.add.at(out, rows, csr.data[:, None] * X[csr.indices])
    return out


def _mean_oracle(csr: CSRMatrix, X: np.ndarray) -> np.ndarray:
    return _sum_oracle(csr, X) / np.maximum(csr.row_nnz(), 1)[:, None]


def _max_oracle(csr: CSRMatrix, X: np.ndarray) -> np.ndarray:
    rows = np.repeat(np.arange(csr.n_rows), csr.row_nnz())
    live = csr.data != 0
    out = np.full((csr.n_rows, X.shape[1]), -np.inf, np.float32)
    np.maximum.at(
        out, rows[live], (csr.data[live, None] * X[csr.indices[live]]).astype(np.float32)
    )
    out[np.isneginf(out).all(axis=1)] = 0.0
    out[np.isneginf(out)] = 0.0
    return out


# --- construction ----------------------------------------------------------


def test_graph_from_edges_directed_dedup():
    A = graph_from_edges([0, 1, 2, 2], [1, 2, 0, 0], n_nodes=4)
    D = A.to_dense()
    # row = destination, col = source; the repeated (2 -> 0) edge is one edge
    want = np.zeros((4, 4))
    want[1, 0] = want[2, 1] = want[0, 2] = 1
    np.testing.assert_array_equal(D, want)


def test_graph_from_edges_symmetric_and_self_loops():
    A = graph_from_edges([0, 1], [1, 2], n_nodes=3, symmetric=True, self_loops=True)
    D = A.to_dense()
    assert (D == D.T).all()
    np.testing.assert_array_equal(np.diagonal(D), np.ones(3))
    # idempotent self-loops: renormalizing never doubles the diagonal
    np.testing.assert_array_equal(add_self_loops(A).to_dense(), D)


def test_graph_from_edges_weighted_sums_duplicates():
    A = graph_from_edges([0, 0], [1, 1], n_nodes=2, weights=[2.0, 3.0])
    assert A.to_dense()[1, 0] == 5.0


def test_graph_from_edges_validation():
    with pytest.raises(ValueError, match="length mismatch"):
        graph_from_edges([0, 1], [1])
    with pytest.raises(ValueError, match="outside"):
        graph_from_edges([0], [5], n_nodes=3)


@pytest.mark.parametrize("kind", ["sym", "row"])
def test_normalize_adjacency_vs_dense(kind, rng):
    G = power_law_graph(300, 6.0, seed=1)
    D = G.to_dense()
    deg = D.sum(axis=1)
    with np.errstate(divide="ignore", invalid="ignore"):
        if kind == "sym":
            s = np.where(deg > 0, 1.0 / np.sqrt(deg), 0.0)
            want = s[:, None] * D * s[None, :]
        else:
            want = np.where(deg > 0, 1.0 / deg, 0.0)[:, None] * D
    np.testing.assert_allclose(normalize_adjacency(G, kind).to_dense(), want, atol=1e-6)
    # row-stochastic: every non-empty row sums to 1
    if kind == "row":
        rows = normalize_adjacency(G, kind).to_dense().sum(axis=1)
        np.testing.assert_allclose(rows[deg > 0], 1.0, atol=1e-5)


def test_normalize_none_and_unknown():
    G = rmat_graph(64, 4.0, seed=0)
    np.testing.assert_array_equal(normalize_adjacency(G, "none").to_dense(), G.to_dense())
    with pytest.raises(ValueError, match="normalization"):
        normalize_adjacency(G, "colwise")


def test_power_law_graph_exact_n_and_skew():
    G = power_law_graph(1000, 8.0, seed=3)
    assert G.shape == (1000, 1000)
    d = degrees(G)
    # power-law skew: the hub dwarfs the median — the load-imbalance
    # profile the nonlinear hash targets
    assert d.max() > 10 * max(np.median(d), 1)
    assert (G.to_dense() == G.to_dense().T).all()


def test_rmat_graph_is_binary():
    G = rmat_graph(128, 4.0, seed=1)
    assert set(np.unique(G.data)) <= {1.0}


# --- aggregation operators -------------------------------------------------


@pytest.mark.parametrize("op", ["sum", "mean", "max"])
@pytest.mark.parametrize("strategy", ["fused", "partials", "reference", "stable"])
def test_aggregate_matches_oracle_small(op, strategy, rng):
    G = power_law_graph(200, 5.0, seed=2)
    X = rng.standard_normal((200, 12)).astype(np.float32)
    tiles = build_tiles(G, PartitionConfig(row_block=64, col_block=64, group=8, lane=8))
    Y = np.asarray(
        aggregate(tiles, X, op=op, degree=degrees(G), strategy=strategy, interpret=True)
    )
    oracle = {"sum": _sum_oracle, "mean": _mean_oracle, "max": _max_oracle}[op](G, X)
    if op == "max":
        np.testing.assert_array_equal(Y, oracle)
    else:
        np.testing.assert_allclose(Y, oracle, rtol=1e-4, atol=1e-4)


def test_aggregate_isolated_nodes_are_zero(rng):
    """Nodes with no in-neighbors aggregate to 0 under every op — the max
    monoid's -inf identity must not leak (satellite acceptance)."""
    # nodes 3 and 7 have no incoming edges
    src = [0, 1, 2, 4, 5]
    dst = [1, 2, 0, 5, 6]
    G = graph_from_edges(src, dst, n_nodes=8)
    X = -1.0 - rng.random((8, 4)).astype(np.float32)  # strictly negative
    tiles = build_tiles(G, PartitionConfig(row_block=8, col_block=8, group=4, lane=4))
    iso = np.asarray(degrees(G) == 0)
    assert iso.sum() >= 2
    for op in ("sum", "mean", "max"):
        Y = np.asarray(aggregate(tiles, X, op=op, degree=degrees(G), interpret=True))
        assert np.isfinite(Y).all()
        assert (Y[iso] == 0).all(), op


def test_aggregate_validation(rng):
    G = rmat_graph(32, 2.0, seed=0)
    tiles = build_tiles(G, PartitionConfig(row_block=32, col_block=32, group=8, lane=8))
    X = rng.standard_normal((32, 2)).astype(np.float32)
    with pytest.raises(ValueError, match="unknown aggregation"):
        aggregate(tiles, X, op="median")
    with pytest.raises(ValueError, match="degree"):
        aggregate(tiles, X, op="mean")
    with pytest.raises(ValueError, match="degree"):
        make_aggregator(tiles, op="mean")


def test_make_aggregator_mean_accepts_device_degree(rng):
    """degree may arrive as a jax array (e.g. computed on device by a
    training loop) — no host np.asarray round-trip in the closure build."""
    import jax.numpy as jnp

    G = power_law_graph(120, 4.0, seed=9)
    X = rng.standard_normal((120, 6)).astype(np.float32)
    deg_dev = jnp.asarray(degrees(G))
    agg = make_aggregator(G, op="mean", degree=deg_dev)
    np.testing.assert_allclose(
        np.asarray(agg(X)), _mean_oracle(G, X), rtol=1e-4, atol=1e-4
    )
    tiles = build_tiles(G, PartitionConfig(row_block=64, col_block=64, group=8, lane=8))
    Y = aggregate(tiles, X, op="mean", degree=deg_dev, interpret=True)
    np.testing.assert_allclose(np.asarray(Y), _mean_oracle(G, X), rtol=1e-4, atol=1e-4)


def test_make_aggregator_closure_is_jittable(rng):
    G = power_law_graph(150, 4.0, seed=4)
    agg = make_aggregator(G, op="mean")
    X = rng.standard_normal((150, 8)).astype(np.float32)
    Y = np.asarray(jax.jit(agg)(X))
    np.testing.assert_allclose(Y, _mean_oracle(G, X), rtol=1e-4, atol=1e-4)


# --- serving-plan wiring ---------------------------------------------------


def test_plan_aggregator_through_registry(tmp_path, rng):
    from repro.serving import MatrixRegistry

    G = power_law_graph(250, 5.0, seed=6)
    reg = MatrixRegistry(cache_dir=tmp_path / "cache", search=False)
    plan = reg.admit(G, "graph")
    X = rng.standard_normal((250, 10)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(plan_aggregator(plan, op="sum")(X)), _sum_oracle(G, X),
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(plan_aggregator(plan, op="mean")(X)), _mean_oracle(G, X),
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_array_equal(
        np.asarray(plan_aggregator(plan, op="max")(X)), _max_oracle(G, X)
    )
    # re-admission of the same content reuses the resident plan
    assert reg.admit(G) is plan
    with pytest.raises(ValueError, match="unknown aggregation"):
        plan.aggregate(X, op="median")


def test_gcn_forward_over_plan_aggregator(tmp_path, rng):
    from repro.serving import MatrixRegistry

    G = power_law_graph(180, 5.0, seed=7)
    A_hat = normalize_adjacency(add_self_loops(G), "sym")
    reg = MatrixRegistry(cache_dir=tmp_path / "cache", search=False)
    plan = reg.admit(A_hat, "gcn-adj")
    X = rng.standard_normal((180, 16)).astype(np.float32)
    params = init_gcn(jax.random.PRNGKey(0), [16, 8, 3])
    out = np.asarray(gcn_forward(plan_aggregator(plan), params, X))
    D = A_hat.to_dense()
    h = np.maximum(D @ (X @ np.asarray(params[0].W)) + np.asarray(params[0].b), 0)
    want = D @ (h @ np.asarray(params[1].W)) + np.asarray(params[1].b)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


# --- acceptance: 10k-node power-law graph, k = 16 .. 256 -------------------


@pytest.fixture(scope="module")
def big_graph():
    return power_law_graph(10_000, 6.0, seed=42)


def _gcn_oracle(csr, params, X):
    h = X.astype(np.float64)
    for i, p in enumerate(params):
        h = _sum_oracle(csr, h @ np.asarray(p.W, np.float64)) + np.asarray(p.b)
        if i < len(params) - 1:
            h = np.maximum(h, 0)
    return h


def _sage_oracle(csr, params, X, op):
    agg = {"mean": _mean_oracle, "max": _max_oracle}[op]
    h = X.astype(np.float64)
    for i, p in enumerate(params):
        h = (
            h @ np.asarray(p.W_self, np.float64)
            + agg(csr, h) @ np.asarray(p.W_neigh, np.float64)
            + np.asarray(p.b)
        )
        if i < len(params) - 1:
            h = np.maximum(h, 0)
    return h


@pytest.mark.parametrize("k", [16, 64, 128, 256])
def test_gcn_forward_10k_power_law(big_graph, k, rng):
    from repro.kernels.ops import LANE_TILE, bucket_k

    if k == 256:  # the lane-tiled path, not a fallback: two full lane tiles
        assert k > LANE_TILE and bucket_k(k) == 256
    A_hat = normalize_adjacency(add_self_loops(big_graph), "sym")
    agg = make_aggregator(A_hat, op="sum")
    params = init_gcn(jax.random.PRNGKey(k), [k, 32, 8])
    X = rng.standard_normal((10_000, k)).astype(np.float32)
    out = np.asarray(gcn_forward(agg, params, X))
    want = _gcn_oracle(A_hat, params, X)
    scale = np.abs(want).max() + 1e-12
    np.testing.assert_allclose(out / scale, want / scale, atol=5e-6)


@pytest.mark.parametrize("k", [16, 64, 128, 256])
@pytest.mark.parametrize("op", ["mean", "max"])
def test_sage_forward_10k_power_law(big_graph, k, op, rng):
    """GraphSAGE aggregates at the RAW feature width: k = 256 drives the
    lane-tiled k loop through a full two-layer forward."""
    agg = make_aggregator(big_graph, op=op)
    params = init_sage(jax.random.PRNGKey(100 + k), [k, 32, 8])
    X = rng.standard_normal((10_000, k)).astype(np.float32)
    out = np.asarray(sage_forward(agg, params, X))
    want = _sage_oracle(big_graph, params, X, op)
    scale = np.abs(want).max() + 1e-12
    np.testing.assert_allclose(out / scale, want / scale, atol=5e-6)


@pytest.mark.parametrize("k", [16, 256])
def test_max_aggregation_10k_is_exact(big_graph, k, rng):
    """The monoid path is reassociation-free: raw max aggregation over the
    10k graph is bit-exact against the numpy oracle, including at the
    lane-tiled width."""
    agg = make_aggregator(big_graph, op="max")
    X = rng.standard_normal((10_000, k)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(agg(X)), _max_oracle(big_graph, X))