"""Faithful HBP format (Fig. 2, Algorithms 2/3) against the dense oracle."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import PartitionConfig, build_hbp, csr_from_dense, hbp_spmv_reference


@pytest.mark.parametrize("method", ["hash", "sort2d", "dp2d", "none"])
def test_hbp_spmv_matches_dense(method, rng):
    dense = rng.standard_normal((150, 200)) * (rng.random((150, 200)) < 0.12)
    dense[rng.integers(0, 150, 5)] = 0.0  # force zero rows
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=64, col_block=32, group=4, lane=8)
    hbp = build_hbp(csr, cfg, warp=8, method=method)
    x = rng.standard_normal(200)
    assert np.allclose(hbp_spmv_reference(hbp, x), dense @ x, atol=1e-10)


@given(st.integers(3, 80), st.integers(3, 90), st.floats(0.01, 0.5), st.integers(0, 6))
@settings(max_examples=25, deadline=None)
def test_hbp_hash_property(m, k, density, seed):
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal((m, k)) * (rng.random((m, k)) < density)
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=32, col_block=16, group=4, lane=4)
    hbp = build_hbp(csr, cfg, warp=4, method="hash")
    x = rng.standard_normal(k)
    assert np.allclose(hbp_spmv_reference(hbp, x), dense @ x, atol=1e-9)


def test_add_sign_terminates_rows(rng):
    """Every nonzero row's add_sign chain ends at -1 and visits exactly
    its nnz elements (Algorithm 3 invariant)."""
    dense = rng.standard_normal((64, 64)) * (rng.random((64, 64)) < 0.15)
    csr = csr_from_dense(dense)
    cfg = PartitionConfig(row_block=32, col_block=32, group=4, lane=8)
    hbp = build_hbp(csr, cfg, warp=8, method="hash")
    nbr, nbc = hbp.grid
    R, warp = cfg.row_block, hbp.warp
    for bi in range(nbr):
        for bj in range(nbc):
            zr = hbp.zero_row[bi, bj]
            perm = hbp.output_hash[bi, bj]
            for g in range(R // warp):
                for q in range(warp):
                    slot = g * warp + q
                    if zr[slot] < 0:
                        continue
                    j = hbp.group_ptr[bi, bj, g] + q - zr[slot]
                    count = 1
                    while hbp.add_sign[j] > 0:
                        j += hbp.add_sign[j]
                        count += 1
                    row = perm[slot] + bi * R
                    if row < 64:
                        expect = np.count_nonzero(
                            dense[row, bj * 32 : (bj + 1) * 32]
                        )
                        assert count == expect
