"""Mamba-2 SSD chunked scan vs the naive recurrence oracle."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def naive_ssd(x, dt, A, Bm, Cm, S0=None):
    B, S, nh, hp = x.shape
    n = Bm.shape[-1]
    y = np.zeros((B, S, nh, hp), np.float32)
    st_ = np.zeros((B, nh, n, hp), np.float32) if S0 is None else S0.copy()
    for t in range(S):
        decay = np.exp(dt[:, t] * A)
        st_ = st_ * decay[:, :, None, None] + np.einsum(
            "bn,bh,bhp->bhnp", Bm[:, t], dt[:, t], x[:, t]
        )
        y[:, t] = np.einsum("bn,bhnp->bhp", Cm[:, t], st_)
    return y, st_


def _random(seed, B=2, S=37, nh=3, hp=4, n=5):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((B, S, nh, hp)).astype(np.float32)
    dt = (np.abs(rng.standard_normal((B, S, nh))) * 0.5).astype(np.float32)
    A = -np.abs(rng.standard_normal(nh)).astype(np.float32)
    Bm = rng.standard_normal((B, S, n)).astype(np.float32)
    Cm = rng.standard_normal((B, S, n)).astype(np.float32)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [1, 4, 8, 16, 64])
def test_ssd_chunked_matches_recurrence(chunk):
    x, dt, A, Bm, Cm = _random(0)
    y_ref, st_ref = naive_ssd(x, dt, A, Bm, Cm)
    y, st_ = ssd_chunked(*map(jnp.asarray, (x, dt, A, Bm, Cm)), chunk)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_), st_ref, atol=2e-4)


@given(st.integers(1, 50), st.integers(1, 16), st.integers(0, 20))
@settings(max_examples=20, deadline=None)
def test_ssd_state_continuation(S, chunk, seed):
    x, dt, A, Bm, Cm = _random(seed, S=max(S, 2))
    S = max(S, 2)
    cut = S // 2
    y_ref, st_ref = naive_ssd(x, dt, A, Bm, Cm)
    y1, s1 = ssd_chunked(
        *map(jnp.asarray, (x[:, :cut], dt[:, :cut], A, Bm[:, :cut], Cm[:, :cut])), chunk
    )
    y2, s2 = ssd_chunked(
        *map(jnp.asarray, (x[:, cut:], dt[:, cut:], A, Bm[:, cut:], Cm[:, cut:])),
        chunk,
        init_state=s1,
    )
    y = np.concatenate([np.asarray(y1), np.asarray(y2)], axis=1)
    np.testing.assert_allclose(y, y_ref, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s2), st_ref, atol=3e-4)
