"""The OpenMetrics exporter: render, merge, validate, serve, snapshot.

The exposition contract is what an external Prometheus would hold us to:
counter samples carry ``_total``, histograms are cumulative with a
``+Inf`` bucket and per-bucket exemplars, families are typed exactly
once, and the text ends with ``# EOF``.  :func:`parse_openmetrics` is the
strict in-repo validator (no prometheus_client dependency), so these
tests also pin *it* against hand-built malformed inputs.
"""
import math
import urllib.error
import urllib.request

import pytest

from repro.obs.export import (
    CONTENT_TYPE,
    FileExporter,
    parse_openmetrics,
    render_openmetrics,
    serve,
    write_prom,
)
from repro.obs.metrics import MetricRegistry


def _populated_registry():
    reg = MetricRegistry(name="t-export")
    reg.counter("serving.requests", matrix="A").inc(7)
    reg.counter("serving.requests", matrix="B").inc(2)
    reg.gauge("slo.burn_rate", matrix="A", slo="deadline", window="60s").set(3.5)
    h = reg.histogram("serving.latency_s", buckets=[1e-3, 1e-2, 1e-1], matrix="A")
    h.observe(5e-3, exemplar="r9-1")
    h.observe(5e-2)
    h.observe(2.0, exemplar="r9-2")
    reg.series("solver.residual").extend([4.0, 1.0, 0.25])
    return reg


# --- rendering --------------------------------------------------------------


def test_render_round_trips_through_the_validator():
    text = render_openmetrics([_populated_registry()])
    assert text.endswith("# EOF\n")
    fam = parse_openmetrics(text)
    assert fam["serving_requests"]["type"] == "counter"
    by_matrix = {
        s["labels"]["matrix"]: s["value"]
        for s in fam["serving_requests"]["samples"]
    }
    assert by_matrix == {"A": 7, "B": 2}
    assert all(
        s["name"] == "serving_requests_total"
        for s in fam["serving_requests"]["samples"]
    )
    # the gauge keeps its full label set
    (g,) = fam["slo_burn_rate"]["samples"]
    assert g["value"] == 3.5 and g["labels"]["window"] == "60s"
    # series export their last value as a _last gauge
    (s,) = fam["solver_residual_last"]["samples"]
    assert s["value"] == 0.25


def test_render_histogram_cumulative_buckets_and_exemplars():
    text = render_openmetrics([_populated_registry()])
    fam = parse_openmetrics(text)
    hist = fam["serving_latency_s"]
    assert hist["type"] == "histogram"
    buckets = [s for s in hist["samples"] if s["name"].endswith("_bucket")]
    les = [float("inf") if s["labels"]["le"] == "+Inf" else float(s["labels"]["le"])
           for s in buckets]
    counts = [s["value"] for s in buckets]
    assert les == sorted(les) and math.isinf(les[-1])
    assert counts == sorted(counts) and counts[-1] == 3  # cumulative
    # exemplars sit on the buckets their observation landed in
    ex = {s["labels"]["le"]: s["exemplar"] for s in buckets if s["exemplar"]}
    assert ex["0.01"]["labels"]["trace_id"] == "r9-1"
    assert ex["0.01"]["value"] == 5e-3
    assert ex["+Inf"]["labels"]["trace_id"] == "r9-2"
    count = next(s for s in hist["samples"] if s["name"].endswith("_count"))
    total = next(s for s in hist["samples"] if s["name"].endswith("_sum"))
    assert count["value"] == 3
    assert total["value"] == pytest.approx(5e-3 + 5e-2 + 2.0)


def test_render_is_deterministic_and_sanitizes_names():
    reg = MetricRegistry(name="t-names")
    reg.counter("a.b-c/d", k="v").inc()
    text = render_openmetrics([reg])
    assert "a_b_c_d_total" in text
    assert render_openmetrics([reg]) == text  # byte-identical re-render
    # label values escape quotes/backslashes/newlines
    reg.gauge("g", path='ha"s\\new\nline').set(1)
    fam = parse_openmetrics(render_openmetrics([reg]))
    (s,) = fam["g"]["samples"]
    assert s["labels"]["path"] == 'ha"s\\new\nline'


def test_cross_registry_merge_semantics():
    a, b = MetricRegistry(name="m-a"), MetricRegistry(name="m-b")
    a.counter("req", matrix="A").inc(3)
    b.counter("req", matrix="A").inc(4)  # same series: counters sum
    a.gauge("depth").set(1.0)
    b.gauge("depth").set(9.0)  # gauges: last write (registry order) wins
    ha = a.histogram("lat", buckets=[0.1, 1.0])
    hb = b.histogram("lat", buckets=[0.1, 1.0])
    ha.observe(0.05, exemplar="r-a")
    hb.observe(0.5)
    hb.observe(0.05, exemplar="r-b")  # same bucket: later registry wins
    fam = parse_openmetrics(render_openmetrics([a, b]))
    (c,) = fam["req"]["samples"]
    assert c["value"] == 7
    (g,) = fam["depth"]["samples"]
    assert g["value"] == 9.0
    buckets = [s for s in fam["lat"]["samples"] if s["name"] == "lat_bucket"]
    assert buckets[-1]["value"] == 3  # counts merged
    ex = next(s["exemplar"] for s in buckets if s["exemplar"])
    assert ex["labels"]["trace_id"] == "r-b"


def test_merge_conflicts_are_dropped_and_counted():
    a, b = MetricRegistry(name="c-a"), MetricRegistry(name="c-b")
    a.histogram("lat", buckets=[0.1]).observe(0.05)
    b.histogram("lat", buckets=[0.2]).observe(0.05)  # bounds mismatch
    fam = parse_openmetrics(render_openmetrics([a, b]))
    (d,) = fam["repro_export_dropped"]["samples"]
    assert d["value"] == 1
    # the first registry's histogram survives intact
    count = next(s for s in fam["lat"]["samples"] if s["name"] == "lat_count")
    assert count["value"] == 1


def test_empty_registries_render_just_eof():
    assert render_openmetrics([]) == "# EOF\n"
    assert parse_openmetrics("# EOF\n") == {}


# --- the validator itself ---------------------------------------------------


def test_parser_rejects_structural_violations():
    with pytest.raises(ValueError, match="EOF"):
        parse_openmetrics("# TYPE a counter\na_total 1\n")
    with pytest.raises(ValueError, match="outside any TYPE"):
        parse_openmetrics("orphan 1\n# EOF")
    with pytest.raises(ValueError, match="does not belong"):
        parse_openmetrics("# TYPE a counter\na 1\n# EOF")  # missing _total
    with pytest.raises(ValueError, match="duplicate family"):
        parse_openmetrics("# TYPE a gauge\n# TYPE a gauge\n# EOF")
    with pytest.raises(ValueError, match="not cumulative"):
        parse_openmetrics(
            "# TYPE h histogram\n"
            'h_bucket{le="1"} 5\nh_bucket{le="+Inf"} 3\n'
            "h_count 5\nh_sum 1\n# EOF"
        )
    with pytest.raises(ValueError, match="missing le"):
        parse_openmetrics(
            "# TYPE h histogram\n"
            'h_bucket{le="1"} 5\nh_count 5\nh_sum 1\n# EOF'
        )


# --- egress: HTTP endpoint + file snapshots ---------------------------------


def test_http_endpoint_serves_live_openmetrics():
    reg = _populated_registry()
    with serve(port=0, registries=[reg]) as srv:
        assert srv.url.endswith("/metrics")
        with urllib.request.urlopen(srv.url, timeout=10) as resp:
            assert resp.headers["Content-Type"] == CONTENT_TYPE
            fam = parse_openmetrics(resp.read().decode("utf-8"))
        assert fam["serving_requests"]["type"] == "counter"
        # live: a scrape after more traffic sees the new value
        reg.counter("serving.requests", matrix="A").inc(10)
        with urllib.request.urlopen(srv.url, timeout=10) as resp:
            fam2 = parse_openmetrics(resp.read().decode("utf-8"))
        by_matrix = {
            s["labels"]["matrix"]: s["value"]
            for s in fam2["serving_requests"]["samples"]
        }
        assert by_matrix["A"] == 17
        # anything but / or /metrics is a 404
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(srv.url.replace("/metrics", "/nope"), timeout=10)
    # closed: the port no longer accepts scrapes
    with pytest.raises(urllib.error.URLError):
        urllib.request.urlopen(srv.url, timeout=0.5)


def test_write_prom_and_file_exporter(tmp_path):
    reg = _populated_registry()
    path = tmp_path / "metrics.prom"
    text = write_prom(path, [reg])
    assert path.read_text() == text
    assert parse_openmetrics(text)["serving_requests"]["type"] == "counter"
    assert not list(tmp_path.glob("*.tmp.*"))  # atomic: no droppings

    with FileExporter(tmp_path / "snap.prom", interval_s=60.0, registries=[reg]) as fx:
        # the first snapshot is written synchronously on start
        assert parse_openmetrics((tmp_path / "snap.prom").read_text())
        reg.counter("serving.requests", matrix="A").inc()
    # stop() wrote a final snapshot with the newer value
    assert fx.writes >= 2
    fam = parse_openmetrics((tmp_path / "snap.prom").read_text())
    by_matrix = {
        s["labels"]["matrix"]: s["value"]
        for s in fam["serving_requests"]["samples"]
    }
    assert by_matrix["A"] == 8
    fx.stop()  # idempotent
