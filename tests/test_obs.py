"""The observability layer: metrics core, span tracer, gating, exports.

Covers the contracts the instrumented subsystems rely on: histogram
percentiles agree with numpy (exact inside the sample window, bucket-
interpolated beyond), span nesting/ordering survives the Chrome-trace
export, counters hold up under concurrent bumps, and — the overhead
contract — disabled mode retains exactly nothing.

The always-on layers get their own sections: the flight recorder's ring
wraparound, trigger dumps and concurrency; the SLO engine's burn-rate
math and multi-window classification; and the bandwidth-attribution join
rendered by ``analysis/report.py --attribution``.
"""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs.attribution import attribution_rows, render_attribution
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import Histogram, MetricRegistry
from repro.obs.report import amortization_ledger, render
from repro.obs.slo import SLO, SLOEngine, worst_status
from repro.obs.trace import Tracer


@pytest.fixture()
def obs_on():
    """Enable obs for one test against clean global state."""
    obs.reset()
    obs.enable()
    yield
    obs.disable()
    obs.reset()


# --- histogram percentiles -------------------------------------------------


def test_histogram_percentiles_exact_within_window(rng):
    draws = rng.lognormal(mean=-6.0, sigma=2.0, size=1000)
    h = Histogram("t", {}, window=4096)
    for v in draws:
        h.observe(v)
    s = np.sort(draws)
    for q in (0.0, 0.5, 0.95, 0.99, 1.0):
        # the engine's historical convention: sorted[int(q * (n - 1))]
        assert h.percentile(q) == pytest.approx(s[int(q * (s.size - 1))])
    assert h.count == 1000
    assert h.mean == pytest.approx(draws.mean())


def test_histogram_percentiles_interpolated_beyond_window(rng):
    draws = rng.lognormal(mean=-6.0, sigma=2.0, size=5000)
    h = Histogram("t", {}, window=256)  # window evicts: bucket fallback
    for v in draws:
        h.observe(v)
    for q in (0.5, 0.95, 0.99):
        exact = float(np.sort(draws)[int(q * (draws.size - 1))])
        est = h.percentile(q)
        # default buckets are ~12% wide: interpolation stays within one
        assert est == pytest.approx(exact, rel=0.15)
    assert h.percentile(0.0) >= h.vmin
    assert h.percentile(1.0) <= h.vmax * (1 + 1e-12)


def test_histogram_empty_and_validation():
    h = Histogram("t", {})
    assert h.percentile(0.5) is None
    assert h.snapshot()["count"] == 0 and h.snapshot()["p99"] is None
    with pytest.raises(ValueError):
        h.percentile(1.5)
    with pytest.raises(ValueError):
        Histogram("bad", {}, buckets=[3.0, 1.0])


# --- counters / gauges / registry -----------------------------------------


def test_counter_monotone_and_thread_safe():
    reg = MetricRegistry()
    c = reg.counter("hits")
    threads = [
        threading.Thread(target=lambda: [c.inc() for _ in range(10_000)])
        for _ in range(8)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 80_000
    with pytest.raises(ValueError):
        c.inc(-1)


def test_histogram_thread_safe_observe():
    reg = MetricRegistry()
    h = reg.histogram("lat", window=0)  # bucket-only path under contention
    threads = [
        threading.Thread(target=lambda: [h.observe(1e-4) for _ in range(5_000)])
        for _ in range(8)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.count == 40_000
    assert int(h.bucket_counts.sum()) == 40_000


def test_registry_labels_and_type_conflicts():
    reg = MetricRegistry()
    a = reg.counter("req", matrix="A")
    b = reg.counter("req", matrix="B")
    assert a is not b
    assert reg.counter("req", matrix="A") is a  # get-or-create is stable
    a.inc(3)
    assert reg.value("req", matrix="A") == 3
    assert reg.value("req", matrix="C", default=-1) == -1
    assert sorted(reg.label_values("req", "matrix")) == ["A", "B"]
    with pytest.raises(TypeError):
        reg.gauge("req")  # same name, different type
    g = reg.gauge("depth")
    g.set(5)
    g.dec(2)
    assert g.value == 3


def test_series_is_iteration_indexed():
    reg = MetricRegistry()
    s = reg.series("resid", window=4)
    s.extend([4.0, 3.0, 2.0, 1.0, 0.5])
    assert s.count == 5
    assert s.points == [(1, 3.0), (2, 2.0), (3, 1.0), (4, 0.5)]  # window evicts
    snap = s.snapshot()
    assert snap["last"] == 0.5 and snap["min"] == 0.5


# --- span tracer -----------------------------------------------------------


def test_span_nesting_and_ordering_in_chrome_trace(tmp_path):
    tr = Tracer()
    with tr.span("outer", stage="admit"):
        with tr.span("inner_a"):
            pass
        with tr.span("inner_b") as sp:
            sp.annotate(found=3)
    trace = tr.chrome_trace()
    events = trace["traceEvents"]
    # children close before the parent: completion order, depth marks nesting
    assert [e["name"] for e in events] == ["inner_a", "inner_b", "outer"]
    by = {e["name"]: e for e in events}
    assert by["outer"]["depth"] == 0
    assert by["inner_a"]["depth"] == by["inner_b"]["depth"] == 1
    for child in ("inner_a", "inner_b"):
        assert by[child]["ts"] >= by["outer"]["ts"]
        assert by[child]["ts"] + by[child]["dur"] <= by["outer"]["ts"] + by["outer"]["dur"] + 1e-6
    assert by["inner_a"]["ts"] + by["inner_a"]["dur"] <= by["inner_b"]["ts"]
    assert by["inner_b"]["args"]["found"] == 3
    assert all(e["ph"] == "X" for e in events)
    # the export round-trips as the JSON object Perfetto loads
    path = tmp_path / "trace.json"
    tr.write_chrome(path)
    loaded = json.loads(path.read_text())
    assert loaded["traceEvents"] == json.loads(json.dumps(events))


def test_span_records_exceptions_and_rebalances_depth():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("fails"):
            raise RuntimeError("boom")
    (ev,) = tr.snapshot()
    assert ev["args"]["error"] == "RuntimeError"
    with tr.span("after"):  # depth recovered despite the exception
        pass
    assert tr.snapshot()[-1]["depth"] == 0


def test_tracer_bounds_events_and_counts_drops():
    tr = Tracer(max_events=3)
    for i in range(5):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.events) == 3 and tr.dropped == 2
    assert tr.chrome_trace()["otherData"]["dropped_events"] == 2
    tr.clear()
    assert tr.events == [] and tr.dropped == 0


def test_span_summary_aggregates_by_name():
    tr = Tracer()
    for _ in range(3):
        with tr.span("hot"):
            pass
    with tr.span("cold"):
        pass
    summary = {s["name"]: s for s in tr.summary()}
    assert summary["hot"]["count"] == 3 and summary["cold"]["count"] == 1
    assert summary["hot"]["total_ms"] >= summary["hot"]["mean_ms"]


# --- gating: disabled mode retains nothing ---------------------------------


def test_disabled_mode_retains_zero_events():
    obs.reset()
    assert not obs.enabled()
    with obs.span("never", matrix="A") as sp:
        sp.annotate(x=1)
        sp.sync(np.zeros(2))
    obs.counter("never").inc(100)
    obs.gauge("never").set(5)
    obs.histogram("never").observe(1.0)
    obs.series("never").append(1.0)
    assert obs.tracer().snapshot() == []
    assert obs.registry().metrics() == []
    snap = obs.collect()
    assert snap["enabled"] is False and snap["n_events"] == 0
    assert all(not r["metrics"] or r["registry"] != "global" for r in snap["registries"])


def test_enable_roundtrip_records_then_stops(obs_on):
    with obs.span("on"):
        obs.counter("hits").inc()
    assert len(obs.tracer().snapshot()) == 1
    assert obs.registry().value("hits") == 1
    obs.disable()
    with obs.span("off"):
        obs.counter("hits").inc()
    assert len(obs.tracer().snapshot()) == 1  # unchanged
    assert obs.registry().value("hits") == 1


# --- instrumented subsystems end to end ------------------------------------


def test_admission_emits_nested_spans_and_counters(obs_on):
    from repro.core import PartitionConfig, build_tiles
    from repro.core.matrices import circuit

    cfg = PartitionConfig(row_block=64, col_block=128, group=8, lane=16)
    build_tiles(circuit(200, seed=0), cfg)
    names = [e["name"] for e in obs.tracer().snapshot()]
    assert "admit.build_tiles" in names
    assert "admit.partition" in names and "admit.hash" in names
    by = {e["name"]: e for e in obs.tracer().snapshot()}
    assert by["admit.partition"]["depth"] > by["admit.build_tiles"]["depth"]
    assert obs.registry().value("admit.tile_builds") == 1
    assert obs.registry().value("admit.tiles_built") > 0


def test_kernel_launch_counters(obs_on):
    from repro.core import PartitionConfig, build_tiles, csr_from_dense
    from repro.kernels import ops

    rng = np.random.default_rng(5)
    dense = (rng.standard_normal((40, 50)) * (rng.random((40, 50)) < 0.2)).astype(
        np.float32
    )
    tiles = build_tiles(
        csr_from_dense(dense), PartitionConfig(row_block=64, col_block=128, lane=16)
    )
    ops.hbp_spmm(tiles, rng.standard_normal((50, 8)).astype(np.float32), strategy="stable")
    ops.hbp_spmv(tiles, rng.standard_normal(50).astype(np.float32), strategy="stable")
    reg = obs.registry()
    assert reg.value("kernels.launches", op="spmm", strategy="stable",
                     k_tiling="grid", combine="sum") == 1
    assert reg.value("kernels.launches", op="spmv", strategy="stable",
                     k_tiling="grid", combine="sum") == 1
    assert reg.value("kernels.traversals") == 2  # both k <= LANE_TILE: 1 pass each
    assert reg.value("kernels.bytes_modeled") > 0


def test_stream_passes_model():
    from repro.kernels.ops import LANE_TILE, stream_passes

    assert stream_passes(1, "stable", "grid") == 1
    assert stream_passes(LANE_TILE, "fused", "loop") == 1
    # one-pass geometries at wide k
    assert stream_passes(4 * LANE_TILE, "partials", "grid") == 1
    assert stream_passes(4 * LANE_TILE, "reference", "grid") == 1
    # chunked geometries pay one pass per lane tile
    assert stream_passes(4 * LANE_TILE, "partials", "loop") == 4
    assert stream_passes(4 * LANE_TILE, "stable", "grid") == 4
    assert stream_passes(3 * LANE_TILE + 1, "fused", "loop") == 4


def test_solver_history_streams_into_series(obs_on):
    from repro.solvers import cg

    rng = np.random.default_rng(0)
    n = 48
    R = rng.standard_normal((n, n)) * 0.05
    S = (np.eye(n) + R @ R.T).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    res = cg(S, b, tol=1e-6, maxiter=100)
    s = obs.registry().get("solver.cg.residual", run=1)
    assert s is not None
    assert len(s.points) == int(res.iterations) + 1
    np.testing.assert_allclose(
        s.values, np.asarray(res.history)[: int(res.iterations) + 1], rtol=1e-6
    )
    # a second run gets its own stream
    cg(S, b, tol=1e-6, maxiter=100)
    assert obs.registry().get("solver.cg.residual", run=2) is not None


# --- artifacts and the dashboard ------------------------------------------


def test_dump_report_and_ledger(obs_on, tmp_path):
    from repro.core.matrices import circuit
    from repro.serving import MatrixRegistry, ServingEngine

    reg = MatrixRegistry(cache_dir=tmp_path / "cache", search=False)
    A = circuit(150, seed=1)
    reg.admit(A, "A")
    reg.admit(A, "A")  # content hit
    eng = ServingEngine(reg, max_wait_s=1e9, max_batch=8)
    rng = np.random.default_rng(0)
    for _ in range(4):
        eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
    eng.flush()

    snap = obs.dump(tmp_path / "obs.json")
    assert json.loads((tmp_path / "obs.json").read_text())["schema"] == 1
    ledger = amortization_ledger(snap)
    (row,) = [r for r in ledger if r["matrix"] == "A"]
    assert row["requests"] == 4 and row["preprocess_s"] > 0
    assert row["amortized_preprocess_s"] == pytest.approx(row["preprocess_s"] / 4)

    text = render(snap)
    assert "registry.hits{matrix=A}" in text
    assert "serving.requests{matrix=A}" in text
    assert "amortization ledger" in text

    obs.write_trace(tmp_path / "trace.json")
    trace = json.loads((tmp_path / "trace.json").read_text())
    assert any(e["name"] == "serve.admit" for e in trace["traceEvents"])
    assert any(e["name"] == "serve.flush" for e in trace["traceEvents"])

    obs.write_events(tmp_path / "events.jsonl")
    lines = (tmp_path / "events.jsonl").read_text().splitlines()
    assert len(lines) == len(trace["traceEvents"])
    # complete spans plus request flow events (submit "s" → flush "f")
    assert all(json.loads(ln)["ph"] in ("X", "s", "f") for ln in lines)


def test_render_handles_empty_snapshot():
    out = render({"registries": [], "spans": []})
    assert "no metrics recorded" in out


# --- deterministic ordering (CI artifacts must diff cleanly) ----------------


def test_registry_collect_is_sorted_regardless_of_creation_order():
    reg = MetricRegistry()
    # scrambled creation order, mixed labels and types
    reg.counter("z.last", matrix="B").inc()
    reg.gauge("a.first", matrix="Z").set(1)
    reg.counter("m.mid", matrix="B").inc()
    reg.counter("m.mid", matrix="A").inc()
    reg.gauge("a.first", matrix="A").set(2)
    snap = reg.collect()
    keys = [
        (m["name"], tuple(sorted(m["labels"].items())), m["type"])
        for m in snap["metrics"]
    ]
    assert keys == sorted(keys)
    assert snap == reg.collect()  # stable across repeated collects


def test_render_rows_are_sorted(obs_on):
    obs.counter("zz.metric", matrix="B").inc()
    obs.counter("aa.metric", matrix="A").inc()
    obs.gauge("mm.gauge").set(1)
    text = render(obs.collect())
    assert text.index("aa.metric") < text.index("zz.metric")
    assert render(obs.collect()) == text


def test_span_summary_ties_break_by_name():
    tr = Tracer()
    # two zero-duration names: equal totals must still order deterministically
    tr.add_event("b_span", 0.0, 0.0, 0, {})
    tr.add_event("a_span", 0.0, 0.0, 0, {})
    names = [s["name"] for s in tr.summary()]
    assert names == ["a_span", "b_span"]


# --- flight recorder --------------------------------------------------------


def test_flight_ring_wraparound_keeps_newest():
    fl = FlightRecorder(capacity=8)
    for i in range(20):
        fl.record("ev", i=i)
    st = fl.stats()
    assert st["recorded_total"] == 20
    assert st["events"] == 8 and st["capacity"] == 8
    assert st["overwritten"] == 12
    kept = [e["args"]["i"] for e in fl.snapshot()]
    assert kept == list(range(12, 20))  # oldest overwritten, order preserved


def test_flight_span_records_duration_and_sampling():
    fl = FlightRecorder(capacity=16, seed=0)
    with fl.span("timed", matrix="A") as sp:
        sp.annotate(k=4)
    (ev,) = fl.snapshot()
    assert ev["name"] == "timed" and ev["ph"] == "X"
    assert ev["dur"] >= 0 and ev["args"] == {"matrix": "A", "k": 4}
    # sample=0.0 never records; the returned no-op still context-manages
    with fl.span("never", sample=0.0) as sp:
        sp.annotate(x=1)
    assert len(fl.snapshot()) == 1
    # errors inside a sampled span are annotated, not swallowed
    with pytest.raises(RuntimeError):
        with fl.span("fails"):
            raise RuntimeError("boom")
    assert fl.snapshot()[-1]["args"]["error"] == "RuntimeError"


def test_flight_trigger_writes_perfetto_loadable_dump(tmp_path):
    fl = FlightRecorder(capacity=32, dump_dir=tmp_path)
    fl.record("before", site="x")
    path = fl.trigger("unit_test", detail="why")
    assert path is not None
    loaded = json.loads((tmp_path / "flight_unit_test_0.json").read_text())
    names = [e["name"] for e in loaded["traceEvents"]]
    assert names == ["before", "flight.trigger"]  # trigger lands in the ring
    assert loaded["otherData"]["reason"] == "unit_test"
    assert loaded["otherData"]["context"]["detail"] == "why"
    # Chrome-trace invariants Perfetto relies on
    ts = [e["ts"] for e in loaded["traceEvents"]]
    assert ts == sorted(ts)
    for e in loaded["traceEvents"]:
        assert e["ph"] in ("X", "i")
        if e["ph"] == "X":
            assert e["dur"] >= 0
        else:
            assert e["s"] == "t"
    assert fl.stats()["dumps"] == [str(path)]


def test_flight_trigger_rate_limit_and_cap(tmp_path):
    fl = FlightRecorder(
        capacity=8, dump_dir=tmp_path, max_dumps=3, min_dump_interval_s=3600.0
    )
    assert fl.trigger("same") is not None
    assert fl.trigger("same") is None  # rate-limited per reason
    assert fl.trigger("other") is not None  # a different reason still dumps
    assert fl.trigger("third") is not None
    assert fl.trigger("fourth") is None  # global max_dumps cap
    st = fl.stats()
    assert len(st["dumps"]) == 3 and st["suppressed_triggers"] == 2


def test_flight_latency_anomaly_detector(tmp_path):
    fl = FlightRecorder(
        capacity=64,
        dump_dir=tmp_path,
        latency_window=128,
        latency_min_samples=16,
        latency_factor=4.0,
        latency_refresh=16,
    )
    # a stable baseline never triggers
    for _ in range(64):
        assert fl.observe_latency("site", 1e-3) is None
    # a 100x spike past the rolling threshold does
    path = fl.observe_latency("site", 0.1, matrix="A")
    assert path is not None
    loaded = json.load(open(path))
    assert loaded["otherData"]["reason"] == "latency_anomaly"
    assert loaded["otherData"]["context"]["site"] == "site"


def test_flight_queue_depth_detector(tmp_path):
    fl = FlightRecorder(capacity=8, dump_dir=tmp_path)
    assert fl.observe_queue_depth("q", 3, 8) is None
    assert fl.observe_queue_depth("q", 7, 8) is None
    path = fl.observe_queue_depth("q", 8, 8)
    assert path is not None
    assert json.load(open(path))["otherData"]["reason"] == (
        "queue_saturation"
    )
    assert fl.observe_queue_depth("q", 9, 0) is None  # limit 0 disables


def test_flight_concurrent_record_and_trigger(tmp_path):
    fl = FlightRecorder(capacity=64, dump_dir=tmp_path, min_dump_interval_s=0.0)
    n_threads, per_thread = 8, 500
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        barrier.wait()
        for i in range(per_thread):
            fl.record("ev", tid=tid, i=i)
            if i % 100 == 0:
                fl.trigger(f"t{tid}")

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = fl.stats()
    # every record landed exactly once (triggers add one ring event each)
    assert st["recorded_total"] >= n_threads * per_thread
    assert st["events"] == 64 and st["overwritten"] == st["recorded_total"] - 64
    snap = fl.snapshot()
    assert len(snap) == 64
    assert all(e is not None for e in snap)  # no torn slots under contention
    for p in st["dumps"]:  # every dump parses as a complete artifact
        assert "traceEvents" in json.load(open(p))


def test_flight_reset_and_global_accessor():
    fl = obs.flight()
    assert fl is obs.get_flight()
    fl.record("something")
    assert obs.collect()["flight"]["recorded_total"] >= 1
    obs.reset()
    assert obs.flight().stats()["recorded_total"] == 0


# --- SLO engine -------------------------------------------------------------


def test_slo_validation_and_budget():
    slo = SLO("deadline", "deadline_hit_ratio", 0.99)
    assert slo.budget == pytest.approx(0.01)
    assert slo.good(123.0, True) and not slo.good(0.0, False)
    lat = SLO("p99", "latency_p99", 0.005)
    assert lat.budget == pytest.approx(0.01)
    assert lat.good(0.004, False) and not lat.good(0.006, True)
    with pytest.raises(ValueError):
        SLO("bad", "nope", 0.5)
    with pytest.raises(ValueError):
        SLO("bad", "deadline_hit_ratio", 1.5)
    with pytest.raises(ValueError):
        SLO("bad", "latency_p99", 0.0)
    with pytest.raises(ValueError):
        SLO("bad", "deadline_hit_ratio", 0.99, windows=(60.0, 30.0))
    with pytest.raises(ValueError):
        SLOEngine([slo, SLO("deadline", "latency_p99", 1.0)])  # duplicate name


def test_slo_burn_rates_and_paging():
    clk = [1000.0]
    eng = SLOEngine(
        [SLO("deadline", "deadline_hit_ratio", 0.99, windows=(10.0, 60.0, 300.0))],
        clock=lambda: clk[0],
    )
    # 100 requests in the last 10s, half missing their deadline:
    # bad_ratio 0.5 / budget 0.01 = burn 50 >> fast_burn on both short windows
    for i in range(100):
        eng.record("A", latency_s=0.001, deadline_hit=(i % 2 == 0), now=1000.0 - i * 0.05)
    out = eng.evaluate("A", now=1000.0)["A"]["deadline"]
    assert out["status"] == "page"
    w10 = out["windows"]["10s"]
    assert w10["events"] == 100 and w10["bad"] == 50
    assert w10["burn_rate"] == pytest.approx(50.0)
    assert w10["attainment"] == pytest.approx(0.5)
    # the gauges refreshed into the engine's metric registry
    assert eng.metrics.value(
        "slo.burn_rate", matrix="A", slo="deadline", window="10s"
    ) == pytest.approx(50.0)


def test_slo_warn_on_longest_window_only():
    eng = SLOEngine(
        [SLO("deadline", "deadline_hit_ratio", 0.9, windows=(10.0, 60.0, 300.0))]
    )
    # misses concentrated 100s ago: short windows are clean, the long one burns
    for i in range(40):
        eng.record("A", latency_s=0.001, deadline_hit=False, now=900.0 - i * 0.1)
    for i in range(10):
        eng.record("A", latency_s=0.001, deadline_hit=True, now=1000.0 - i * 0.1)
    out = eng.evaluate("A", now=1000.0)["A"]["deadline"]
    assert out["windows"]["10s"]["bad"] == 0
    assert out["windows"]["300s"]["burn_rate"] >= 2.0
    assert out["status"] == "warn"


def test_slo_no_data_is_ok_not_outage():
    eng = SLOEngine()
    assert eng.evaluate() == {}
    eng.record("A", latency_s=0.001, deadline_hit=True, now=100.0)
    out = eng.evaluate("A", now=100.0 + 7200.0)["A"]["deadline"]
    assert all(w["events"] == 0 for w in out["windows"].values())
    assert all(w["burn_rate"] is None for w in out["windows"].values())
    assert out["status"] == "ok"


def test_slo_latency_objective_and_worst_status():
    eng = SLOEngine([SLO("p99", "latency_p99", 0.005, windows=(60.0, 300.0))])
    for i in range(50):
        eng.record("A", latency_s=0.5, deadline_hit=True, now=100.0 + i * 0.01)
    out = eng.evaluate("A", now=101.0)["A"]["p99"]
    assert out["status"] == "page"  # every request blows the latency bound
    assert worst_status(["ok", "warn"]) == "warn"
    assert worst_status(["warn", "page", "ok"]) == "page"
    assert worst_status([]) == "ok"


# --- bandwidth attribution --------------------------------------------------


def _attr_snapshot(bytes_modeled, measured_s):
    labels = {"matrix": "A", "strategy": "fused", "k_tiling": "grid"}
    return {
        "registries": [
            {
                "registry": "serving",
                "metrics": [
                    {"name": "attr.launches", "labels": labels, "type": "counter",
                     "value": 4},
                    {"name": "attr.bytes_modeled", "labels": labels,
                     "type": "counter", "value": bytes_modeled},
                    {"name": "attr.compute_s", "labels": labels, "type": "counter",
                     "value": measured_s},
                    {"name": "serving.requests", "labels": {"matrix": "A"},
                     "type": "counter", "value": 9},  # non-attr metrics ignored
                ],
            }
        ]
    }


def test_attribution_rows_join_and_flag():
    from repro.analysis.roofline import V5E

    # runs at exactly half the modeled roofline: 0.5 fraction, not flagged
    # at the default 0.5 threshold boundary? strictly-below flags, so equal
    # fraction stays unflagged
    snap = _attr_snapshot(bytes_modeled=V5E.hbm_bw, measured_s=2.0)
    (row,) = attribution_rows(snap)
    assert row["matrix"] == "A" and row["strategy"] == "fused"
    assert row["launches"] == 4
    assert row["achieved_gbps"] == pytest.approx(V5E.hbm_bw / 2 / 1e9)
    assert row["roofline_fraction"] == pytest.approx(0.5)
    assert not row["below_roofline"]
    # 10x slower than modeled: flagged
    (slow,) = attribution_rows(_attr_snapshot(V5E.hbm_bw, 10.0))
    assert slow["below_roofline"]
    text = render_attribution([slow])
    assert "BELOW-ROOFLINE" in text and "re-evaluate" in text
    assert "matrix" in text and "achieved_GB/s" in text


def test_attribution_handles_empty_and_zero_time():
    assert attribution_rows({"registries": []}) == []
    assert "no attribution counters" in render_attribution([])
    (row,) = attribution_rows(_attr_snapshot(1e9, 0.0))
    assert row["achieved_gbps"] is None and not row["below_roofline"]


def test_attribution_cli_mode(tmp_path, capsys, monkeypatch):
    from repro.analysis import report as analysis_report

    snap_path = tmp_path / "obs.json"
    snap_path.write_text(json.dumps(_attr_snapshot(1e9, 10.0)))
    monkeypatch.setattr(
        "sys.argv", ["report", "--attribution", str(snap_path)]
    )
    analysis_report.main()
    out = capsys.readouterr().out
    assert "bandwidth attribution" in out and "BELOW-ROOFLINE" in out


# --- serving integration: flight + SLO + gating -----------------------------


def _serve_matrix(tmp_path, **engine_kw):
    from repro.core.matrices import circuit
    from repro.serving import MatrixRegistry, ServingEngine

    reg = MatrixRegistry(cache_dir=tmp_path / "cache", search=False)
    A = circuit(150, seed=1)
    reg.admit(A, "A")
    vclock = [0.0]
    eng = ServingEngine(reg, clock=lambda: vclock[0], **engine_kw)
    return reg, A, eng, vclock


def test_induced_deadline_miss_dumps_flush_span(tmp_path):
    """Acceptance criterion: a deadline miss produces a Perfetto-loadable
    dump containing the offending serve.flush span."""
    fl = FlightRecorder(capacity=256, dump_dir=tmp_path / "dumps")
    reg, A, eng, vclock = _serve_matrix(
        tmp_path, max_wait_s=0.001, max_batch=8, flight=fl
    )
    rng = np.random.default_rng(0)
    for _ in range(4):
        eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
    vclock[0] = 1.0  # every pending request is now way past its deadline
    eng.flush()
    (dump_path,) = fl.stats()["dumps"]
    loaded = json.load(open(dump_path))
    assert loaded["otherData"]["reason"] == "deadline_miss"
    assert loaded["otherData"]["context"]["matrix"] == "A"
    flushes = [e for e in loaded["traceEvents"] if e["name"] == "serve.flush"]
    assert flushes, "the offending flush span must be in the dump"
    assert flushes[-1]["ph"] == "X" and flushes[-1]["dur"] > 0
    assert flushes[-1]["args"]["matrix"] == "A"
    # the SLO view pages on the same evidence
    assert eng.health(now=vclock[0])["matrices"]["A"]["status"] == "page"


def test_queue_saturation_triggers_dump(tmp_path):
    fl = FlightRecorder(capacity=64, dump_dir=tmp_path / "dumps")
    reg, A, eng, vclock = _serve_matrix(
        tmp_path, max_wait_s=1e9, max_batch=8, queue_limit=3, flight=fl
    )
    rng = np.random.default_rng(0)
    for _ in range(3):  # third submit hits the limit
        eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
    dumps = fl.stats()["dumps"]
    assert len(dumps) == 1
    assert json.load(open(dumps[0]))["otherData"]["reason"] == (
        "queue_saturation"
    )
    eng.flush()


def test_hot_loop_gating_is_consistent_when_disabled(tmp_path, monkeypatch):
    """Satellite: with obs disabled the engine must never touch the gated
    constructors — the disabled path allocates no label dicts and creates
    no global-registry metrics."""
    obs.reset()
    assert not obs.enabled()

    def boom(*a, **k):
        raise AssertionError("gated obs constructor called on disabled path")

    reg, A, eng, vclock = _serve_matrix(tmp_path, max_wait_s=1e9, max_batch=8)
    rng = np.random.default_rng(0)
    monkeypatch.setattr(obs, "counter", boom)
    monkeypatch.setattr(obs, "gauge", boom)
    monkeypatch.setattr(obs, "histogram", boom)
    for _ in range(4):
        eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
    eng.flush()
    assert obs.registry().metrics() == []  # nothing leaked into the registry
    # the always-live ledgers still worked
    assert eng.metrics.value("serving.requests", matrix="A") == 4
    assert eng.metrics.value("attr.launches", matrix="A", strategy=reg.strategy,
                             k_tiling="grid") > 0


def test_engine_attribution_counters_flow_to_report(tmp_path):
    reg, A, eng, vclock = _serve_matrix(tmp_path, max_wait_s=1e9, max_batch=8)
    rng = np.random.default_rng(0)
    for _ in range(4):
        eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
    eng.flush()
    snap = obs.collect()
    # rows group by (matrix, strategy, k_tiling) even across registries, so
    # a not-yet-collected registry from an earlier test can't split the row
    (row,) = [r for r in attribution_rows(snap) if r["matrix"] == "A"]
    assert row["launches"] >= 1
    assert row["bytes_modeled"] > 0 and row["measured_s"] > 0
    assert "bandwidth attribution" in render(snap)


# --- request-scoped tracing: contexts, exemplars, flows, waterfall ----------


def test_histogram_keeps_most_recent_exemplar_per_bucket():
    h = Histogram("lat", {}, buckets=[0.001, 0.01, 0.1])
    h.observe(0.005, exemplar="r1-a")
    h.observe(0.007, exemplar="r1-b")  # same bucket: replaces r1-a
    h.observe(0.5, exemplar="r1-c")  # overflow slot
    h.observe(0.05)  # no exemplar: bucket stays empty
    ex = h.exemplars()
    assert [(e["trace_id"], e["value"]) for e in ex] == [
        ("r1-b", 0.007),
        ("r1-c", 0.5),
    ]
    assert ex[0]["le"] == 0.01 and ex[1]["le"] == float("inf")
    # exemplars ride the snapshot (and therefore obs.dump())
    assert h.snapshot()["exemplars"] == ex
    # a histogram that never saw an exemplar allocates nothing and omits
    h2 = Histogram("lat2", {}, buckets=[0.001])
    h2.observe(0.5)
    assert h2.exemplars() == [] and "exemplars" not in h2.snapshot()


def test_noop_observe_accepts_exemplar_kwarg():
    assert not obs.enabled()
    # the disabled path must accept the full enabled-path signature
    obs.histogram("t").observe(0.5, exemplar="r-1")
    obs.flow("request", "r-1", "s")  # gated: no tracer event while disabled
    assert obs.tracer().snapshot() == []


def test_tracer_flow_events_shape_and_validation():
    tr = Tracer()
    tr.flow("request", "r3-1", "s", matrix="A")
    tr.flow("request", "r3-1", "f")
    s_ev, f_ev = tr.snapshot()
    assert s_ev["ph"] == "s" and f_ev["ph"] == "f"
    assert s_ev["id"] == f_ev["id"] == "r3-1"
    assert s_ev["cat"] == f_ev["cat"] == "request"
    assert f_ev["bp"] == "e"  # finish binds to the enclosing slice
    assert "bp" not in s_ev
    assert s_ev["args"] == {"matrix": "A"}
    with pytest.raises(ValueError, match="flow phase"):
        tr.flow("request", "r3-1", "x")
    # flow events carry no duration, so the span summary skips them
    assert tr.summary() == []


def test_engine_emits_flow_events_when_enabled(obs_on, tmp_path):
    reg, A, eng, vclock = _serve_matrix(tmp_path, max_wait_s=1e9, max_batch=4)
    rng = np.random.default_rng(0)
    tickets = [
        eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
        for _ in range(3)
    ]
    eng.flush()
    events = obs.tracer().snapshot()
    starts = {e["id"] for e in events if e["ph"] == "s"}
    finishes = {e["id"] for e in events if e["ph"] == "f"}
    ids = {t.trace_id for t in tickets}
    assert starts == finishes == ids
    # finish events land inside the serve.flush slice (bp="e" binding)
    flush = next(e for e in events if e["ph"] == "X" and e["name"] == "serve.flush")
    for e in events:
        if e["ph"] == "f":
            assert flush["ts"] <= e["ts"] <= flush["ts"] + flush["dur"]


def test_request_context_decomposition_on_virtual_clock(tmp_path):
    from repro.obs.requesttrace import RequestLog

    log = RequestLog()
    reg, A, eng, vclock = _serve_matrix(
        tmp_path, max_wait_s=0.5, max_batch=4, request_log=log
    )
    rng = np.random.default_rng(0)
    tickets = []
    for i in range(4):
        vclock[0] = 0.01 * i  # submits at t=0.00, 0.01, 0.02, 0.03
        tickets.append(
            eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
        )
    vclock[0] = 0.1
    eng.flush()
    assert log.count == 4
    ctxs = {c.trace_id: c for c in log.contexts()}
    assert set(ctxs) == {t.trace_id for t in tickets}
    for i, t in enumerate(tickets):
        c = ctxs[t.trace_id]
        assert c is t.context and c.done
        # stamps are in the virtual-clock domain: fully deterministic
        assert c.t_submit == pytest.approx(0.01 * i)
        assert c.queue_wait_s == pytest.approx(0.1 - 0.01 * i)
        assert c.latency_s == pytest.approx(0.1 - 0.01 * i)
        assert c.t_flush_start == c.t_dispatch == c.t_complete == 0.1
        assert c.batch_share == pytest.approx(0.25)
        assert c.batch_k == 4 and c.flush_reason == "drain"
        assert c.deadline_hit is (c.latency_s <= 0.5)
        # compute is wall time, attributed by share
        assert c.compute_s > 0
        assert c.compute_share_s == pytest.approx(c.compute_s * 0.25)
        d = c.to_dict()
        assert d["trace_id"] == c.trace_id and d["matrix"] == "A"
        assert d["queue_wait_s"] == pytest.approx(c.queue_wait_s)
    # the per-batch exemplar ends up on the latency histogram
    h = eng.metrics.get("serving.latency_s", matrix="A")
    assert {e["trace_id"] for e in h.exemplars()} <= set(ctxs)
    # and the engine defaulting to the process log feeds obs.collect()
    assert all(r["matrix"] == "A" for r in log.snapshot())


def test_collect_includes_process_request_log(tmp_path):
    obs.reset()
    reg, A, eng, vclock = _serve_matrix(tmp_path, max_wait_s=1e9, max_batch=2)
    rng = np.random.default_rng(0)
    t = eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
    eng.flush()
    snap = obs.collect()
    assert any(r["trace_id"] == t.trace_id for r in snap["requests"])
    obs.reset()  # reset() clears the request log too
    assert obs.collect()["requests"] == []


def test_deadline_miss_dump_names_late_requests(tmp_path):
    """Acceptance criterion: the deadline_miss trigger event carries the
    trace ids of the late requests, and the dump filename is greppable by
    the first of them."""
    from repro.obs.requesttrace import RequestLog

    fl = FlightRecorder(capacity=256, dump_dir=tmp_path / "dumps")
    log = RequestLog()
    reg, A, eng, vclock = _serve_matrix(
        tmp_path, max_wait_s=0.001, max_batch=8, flight=fl, request_log=log
    )
    rng = np.random.default_rng(0)
    for _ in range(4):
        eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
    vclock[0] = 1.0  # every pending request misses
    eng.flush()
    late = [c.trace_id for c in log.contexts() if c.deadline_hit is False]
    assert len(late) == 4
    (dump_path,) = fl.stats()["dumps"]
    loaded = json.load(open(dump_path))
    assert loaded["otherData"]["context"]["trace_ids"] == late
    (trig,) = [e for e in loaded["traceEvents"] if e["name"] == "flight.trigger"]
    assert trig["args"]["trace_ids"] == late
    # the filename names the first late request
    assert late[0] in dump_path
    # the flush ring event lists every coalesced request
    flush = next(e for e in loaded["traceEvents"] if e["name"] == "serve.flush")
    assert flush["args"]["trace_ids"] == late


def test_flight_reset_clears_rate_limiter_and_dump_seq(tmp_path):
    """Satellite: reset() must clear the per-reason rate limiter and the
    dump sequence counter, or post-reset triggers are silently suppressed
    and filenames collide across test runs."""
    fl = FlightRecorder(capacity=8, dump_dir=tmp_path, min_dump_interval_s=60.0)
    first = fl.trigger("deadline_miss", matrix="A")
    assert first is not None and "_0" in first
    assert fl.trigger("deadline_miss") is None  # rate-limited
    assert fl.stats()["suppressed_triggers"] == 1
    fl.reset()
    # post-reset: not suppressed, and the sequence restarts at 0
    again = fl.trigger("deadline_miss", matrix="A")
    assert again is not None
    assert json.load(open(again))["otherData"]["seq"] == 0
    st = fl.stats()
    assert st["suppressed_triggers"] == 0 and st["dumps"] == [str(again)]


def test_waterfall_renders_decomposition_and_handles_gaps():
    from repro.obs.requesttrace import waterfall

    rows = [
        {
            "trace_id": "r1-0", "matrix": "A", "latency_s": 0.10,
            "queue_wait_s": 0.08, "compute_share_s": 0.02,
            "batch_share": 0.25, "flush_reason": "size",
        },
        {
            "trace_id": "r1-1", "matrix": "B", "latency_s": 0.05,
            "queue_wait_s": None, "compute_share_s": None,
            "batch_share": None, "flush_reason": None,
        },
        {"trace_id": "r1-2", "matrix": "C", "latency_s": None},  # incomplete
    ]
    out = waterfall(rows, n=10, width=10)
    lines = out.splitlines()
    assert "slowest 2 requests" in lines[0]  # incomplete row dropped
    assert lines[2].startswith("r1-0")  # sorted by latency desc
    assert "░░░░░░░░██" in lines[2]  # 8/10 queue cells, 2/10 compute
    assert "1/4" in lines[2] and "size" in lines[2]
    # None fields render as n/a, never crash and never print "None"
    assert "n/a" in lines[3] and "None" not in out
    # n bounds the table; dict input reads snapshot["requests"]
    assert "slowest 1 requests" in waterfall({"requests": rows}, n=1)
    assert "no completed requests" in waterfall([])


def test_report_renders_requests_section_and_na(tmp_path):
    obs.reset()
    reg, A, eng, vclock = _serve_matrix(tmp_path, max_wait_s=1e9, max_batch=2)
    rng = np.random.default_rng(0)
    eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
    eng.flush()
    # an empty histogram's percentiles must render as n/a, not None
    eng.metrics.histogram("serving.empty_hist", matrix="A")
    text = render(obs.collect())
    assert "slowest 1 requests" in text
    assert "n/a" in text and "None" not in text
    obs.reset()


def test_analysis_report_cli_round_trips_dump(tmp_path, capsys, monkeypatch):
    """Satellite: --obs / --attribution / --requests must all re-render a
    real repro.obs.dump() snapshot file."""
    from repro.analysis import report as analysis_report

    obs.reset()
    reg, A, eng, vclock = _serve_matrix(tmp_path, max_wait_s=1e9, max_batch=4)
    rng = np.random.default_rng(0)
    tickets = [
        eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
        for _ in range(3)
    ]
    eng.flush()
    snap_path = tmp_path / "obs.json"
    obs.dump(snap_path)

    monkeypatch.setattr("sys.argv", ["report", "--obs", str(snap_path)])
    analysis_report.main()
    out = capsys.readouterr().out
    assert "repro.obs report" in out
    assert "serving.requests{matrix=A}" in out
    assert "slowest 3 requests" in out  # dump carries the request log

    monkeypatch.setattr("sys.argv", ["report", "--attribution", str(snap_path)])
    analysis_report.main()
    assert "bandwidth attribution" in capsys.readouterr().out

    monkeypatch.setattr(
        "sys.argv", ["report", "--requests", str(snap_path), "--top", "2"]
    )
    analysis_report.main()
    out = capsys.readouterr().out
    assert "slowest 2 requests" in out  # --top bounds the table
    assert any(t.trace_id in out for t in tickets)
    obs.reset()
