"""The observability layer: metrics core, span tracer, gating, exports.

Covers the contracts the instrumented subsystems rely on: histogram
percentiles agree with numpy (exact inside the sample window, bucket-
interpolated beyond), span nesting/ordering survives the Chrome-trace
export, counters hold up under concurrent bumps, and — the overhead
contract — disabled mode retains exactly nothing.
"""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import Histogram, MetricRegistry
from repro.obs.report import amortization_ledger, render
from repro.obs.trace import Tracer


@pytest.fixture()
def obs_on():
    """Enable obs for one test against clean global state."""
    obs.reset()
    obs.enable()
    yield
    obs.disable()
    obs.reset()


# --- histogram percentiles -------------------------------------------------


def test_histogram_percentiles_exact_within_window(rng):
    draws = rng.lognormal(mean=-6.0, sigma=2.0, size=1000)
    h = Histogram("t", {}, window=4096)
    for v in draws:
        h.observe(v)
    s = np.sort(draws)
    for q in (0.0, 0.5, 0.95, 0.99, 1.0):
        # the engine's historical convention: sorted[int(q * (n - 1))]
        assert h.percentile(q) == pytest.approx(s[int(q * (s.size - 1))])
    assert h.count == 1000
    assert h.mean == pytest.approx(draws.mean())


def test_histogram_percentiles_interpolated_beyond_window(rng):
    draws = rng.lognormal(mean=-6.0, sigma=2.0, size=5000)
    h = Histogram("t", {}, window=256)  # window evicts: bucket fallback
    for v in draws:
        h.observe(v)
    for q in (0.5, 0.95, 0.99):
        exact = float(np.sort(draws)[int(q * (draws.size - 1))])
        est = h.percentile(q)
        # default buckets are ~12% wide: interpolation stays within one
        assert est == pytest.approx(exact, rel=0.15)
    assert h.percentile(0.0) >= h.vmin
    assert h.percentile(1.0) <= h.vmax * (1 + 1e-12)


def test_histogram_empty_and_validation():
    h = Histogram("t", {})
    assert h.percentile(0.5) is None
    assert h.snapshot()["count"] == 0 and h.snapshot()["p99"] is None
    with pytest.raises(ValueError):
        h.percentile(1.5)
    with pytest.raises(ValueError):
        Histogram("bad", {}, buckets=[3.0, 1.0])


# --- counters / gauges / registry -----------------------------------------


def test_counter_monotone_and_thread_safe():
    reg = MetricRegistry()
    c = reg.counter("hits")
    threads = [
        threading.Thread(target=lambda: [c.inc() for _ in range(10_000)])
        for _ in range(8)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 80_000
    with pytest.raises(ValueError):
        c.inc(-1)


def test_histogram_thread_safe_observe():
    reg = MetricRegistry()
    h = reg.histogram("lat", window=0)  # bucket-only path under contention
    threads = [
        threading.Thread(target=lambda: [h.observe(1e-4) for _ in range(5_000)])
        for _ in range(8)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.count == 40_000
    assert int(h.bucket_counts.sum()) == 40_000


def test_registry_labels_and_type_conflicts():
    reg = MetricRegistry()
    a = reg.counter("req", matrix="A")
    b = reg.counter("req", matrix="B")
    assert a is not b
    assert reg.counter("req", matrix="A") is a  # get-or-create is stable
    a.inc(3)
    assert reg.value("req", matrix="A") == 3
    assert reg.value("req", matrix="C", default=-1) == -1
    assert sorted(reg.label_values("req", "matrix")) == ["A", "B"]
    with pytest.raises(TypeError):
        reg.gauge("req")  # same name, different type
    g = reg.gauge("depth")
    g.set(5)
    g.dec(2)
    assert g.value == 3


def test_series_is_iteration_indexed():
    reg = MetricRegistry()
    s = reg.series("resid", window=4)
    s.extend([4.0, 3.0, 2.0, 1.0, 0.5])
    assert s.count == 5
    assert s.points == [(1, 3.0), (2, 2.0), (3, 1.0), (4, 0.5)]  # window evicts
    snap = s.snapshot()
    assert snap["last"] == 0.5 and snap["min"] == 0.5


# --- span tracer -----------------------------------------------------------


def test_span_nesting_and_ordering_in_chrome_trace(tmp_path):
    tr = Tracer()
    with tr.span("outer", stage="admit"):
        with tr.span("inner_a"):
            pass
        with tr.span("inner_b") as sp:
            sp.annotate(found=3)
    trace = tr.chrome_trace()
    events = trace["traceEvents"]
    # children close before the parent: completion order, depth marks nesting
    assert [e["name"] for e in events] == ["inner_a", "inner_b", "outer"]
    by = {e["name"]: e for e in events}
    assert by["outer"]["depth"] == 0
    assert by["inner_a"]["depth"] == by["inner_b"]["depth"] == 1
    for child in ("inner_a", "inner_b"):
        assert by[child]["ts"] >= by["outer"]["ts"]
        assert by[child]["ts"] + by[child]["dur"] <= by["outer"]["ts"] + by["outer"]["dur"] + 1e-6
    assert by["inner_a"]["ts"] + by["inner_a"]["dur"] <= by["inner_b"]["ts"]
    assert by["inner_b"]["args"]["found"] == 3
    assert all(e["ph"] == "X" for e in events)
    # the export round-trips as the JSON object Perfetto loads
    path = tmp_path / "trace.json"
    tr.write_chrome(path)
    loaded = json.loads(path.read_text())
    assert loaded["traceEvents"] == json.loads(json.dumps(events))


def test_span_records_exceptions_and_rebalances_depth():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("fails"):
            raise RuntimeError("boom")
    (ev,) = tr.snapshot()
    assert ev["args"]["error"] == "RuntimeError"
    with tr.span("after"):  # depth recovered despite the exception
        pass
    assert tr.snapshot()[-1]["depth"] == 0


def test_tracer_bounds_events_and_counts_drops():
    tr = Tracer(max_events=3)
    for i in range(5):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.events) == 3 and tr.dropped == 2
    assert tr.chrome_trace()["otherData"]["dropped_events"] == 2
    tr.clear()
    assert tr.events == [] and tr.dropped == 0


def test_span_summary_aggregates_by_name():
    tr = Tracer()
    for _ in range(3):
        with tr.span("hot"):
            pass
    with tr.span("cold"):
        pass
    summary = {s["name"]: s for s in tr.summary()}
    assert summary["hot"]["count"] == 3 and summary["cold"]["count"] == 1
    assert summary["hot"]["total_ms"] >= summary["hot"]["mean_ms"]


# --- gating: disabled mode retains nothing ---------------------------------


def test_disabled_mode_retains_zero_events():
    obs.reset()
    assert not obs.enabled()
    with obs.span("never", matrix="A") as sp:
        sp.annotate(x=1)
        sp.sync(np.zeros(2))
    obs.counter("never").inc(100)
    obs.gauge("never").set(5)
    obs.histogram("never").observe(1.0)
    obs.series("never").append(1.0)
    assert obs.tracer().snapshot() == []
    assert obs.registry().metrics() == []
    snap = obs.collect()
    assert snap["enabled"] is False and snap["n_events"] == 0
    assert all(not r["metrics"] or r["registry"] != "global" for r in snap["registries"])


def test_enable_roundtrip_records_then_stops(obs_on):
    with obs.span("on"):
        obs.counter("hits").inc()
    assert len(obs.tracer().snapshot()) == 1
    assert obs.registry().value("hits") == 1
    obs.disable()
    with obs.span("off"):
        obs.counter("hits").inc()
    assert len(obs.tracer().snapshot()) == 1  # unchanged
    assert obs.registry().value("hits") == 1


# --- instrumented subsystems end to end ------------------------------------


def test_admission_emits_nested_spans_and_counters(obs_on):
    from repro.core import PartitionConfig, build_tiles
    from repro.core.matrices import circuit

    cfg = PartitionConfig(row_block=64, col_block=128, group=8, lane=16)
    build_tiles(circuit(200, seed=0), cfg)
    names = [e["name"] for e in obs.tracer().snapshot()]
    assert "admit.build_tiles" in names
    assert "admit.partition" in names and "admit.hash" in names
    by = {e["name"]: e for e in obs.tracer().snapshot()}
    assert by["admit.partition"]["depth"] > by["admit.build_tiles"]["depth"]
    assert obs.registry().value("admit.tile_builds") == 1
    assert obs.registry().value("admit.tiles_built") > 0


def test_kernel_launch_counters(obs_on):
    from repro.core import PartitionConfig, build_tiles, csr_from_dense
    from repro.kernels import ops

    rng = np.random.default_rng(5)
    dense = (rng.standard_normal((40, 50)) * (rng.random((40, 50)) < 0.2)).astype(
        np.float32
    )
    tiles = build_tiles(
        csr_from_dense(dense), PartitionConfig(row_block=64, col_block=128, lane=16)
    )
    ops.hbp_spmm(tiles, rng.standard_normal((50, 8)).astype(np.float32), strategy="stable")
    ops.hbp_spmv(tiles, rng.standard_normal(50).astype(np.float32), strategy="stable")
    reg = obs.registry()
    assert reg.value("kernels.launches", op="spmm", strategy="stable",
                     k_tiling="grid", combine="sum") == 1
    assert reg.value("kernels.launches", op="spmv", strategy="stable",
                     k_tiling="grid", combine="sum") == 1
    assert reg.value("kernels.traversals") == 2  # both k <= LANE_TILE: 1 pass each
    assert reg.value("kernels.bytes_modeled") > 0


def test_stream_passes_model():
    from repro.kernels.ops import LANE_TILE, stream_passes

    assert stream_passes(1, "stable", "grid") == 1
    assert stream_passes(LANE_TILE, "fused", "loop") == 1
    # one-pass geometries at wide k
    assert stream_passes(4 * LANE_TILE, "partials", "grid") == 1
    assert stream_passes(4 * LANE_TILE, "reference", "grid") == 1
    # chunked geometries pay one pass per lane tile
    assert stream_passes(4 * LANE_TILE, "partials", "loop") == 4
    assert stream_passes(4 * LANE_TILE, "stable", "grid") == 4
    assert stream_passes(3 * LANE_TILE + 1, "fused", "loop") == 4


def test_solver_history_streams_into_series(obs_on):
    from repro.solvers import cg

    rng = np.random.default_rng(0)
    n = 48
    R = rng.standard_normal((n, n)) * 0.05
    S = (np.eye(n) + R @ R.T).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    res = cg(S, b, tol=1e-6, maxiter=100)
    s = obs.registry().get("solver.cg.residual", run=1)
    assert s is not None
    assert len(s.points) == int(res.iterations) + 1
    np.testing.assert_allclose(
        s.values, np.asarray(res.history)[: int(res.iterations) + 1], rtol=1e-6
    )
    # a second run gets its own stream
    cg(S, b, tol=1e-6, maxiter=100)
    assert obs.registry().get("solver.cg.residual", run=2) is not None


# --- artifacts and the dashboard ------------------------------------------


def test_dump_report_and_ledger(obs_on, tmp_path):
    from repro.core.matrices import circuit
    from repro.serving import MatrixRegistry, ServingEngine

    reg = MatrixRegistry(cache_dir=tmp_path / "cache", search=False)
    A = circuit(150, seed=1)
    reg.admit(A, "A")
    reg.admit(A, "A")  # content hit
    eng = ServingEngine(reg, max_wait_s=1e9, max_batch=8)
    rng = np.random.default_rng(0)
    for _ in range(4):
        eng.submit("A", rng.standard_normal(A.shape[1]).astype(np.float32))
    eng.flush()

    snap = obs.dump(tmp_path / "obs.json")
    assert json.loads((tmp_path / "obs.json").read_text())["schema"] == 1
    ledger = amortization_ledger(snap)
    (row,) = [r for r in ledger if r["matrix"] == "A"]
    assert row["requests"] == 4 and row["preprocess_s"] > 0
    assert row["amortized_preprocess_s"] == pytest.approx(row["preprocess_s"] / 4)

    text = render(snap)
    assert "registry.hits{matrix=A}" in text
    assert "serving.requests{matrix=A}" in text
    assert "amortization ledger" in text

    obs.write_trace(tmp_path / "trace.json")
    trace = json.loads((tmp_path / "trace.json").read_text())
    assert any(e["name"] == "serve.admit" for e in trace["traceEvents"])
    assert any(e["name"] == "serve.flush" for e in trace["traceEvents"])

    obs.write_events(tmp_path / "events.jsonl")
    lines = (tmp_path / "events.jsonl").read_text().splitlines()
    assert len(lines) == len(trace["traceEvents"])
    assert all(json.loads(ln)["ph"] == "X" for ln in lines)


def test_render_handles_empty_snapshot():
    out = render({"registries": [], "spans": []})
    assert "no metrics recorded" in out
