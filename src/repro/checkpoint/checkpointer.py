"""Sharded checkpointing with async write, restart and elastic re-mesh.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json            tree structure, shapes, dtypes, step
        <leaf-key>.npy           one file per pytree leaf (host values)

Design points for large-scale runs:

* **process-local shards** — on a real multi-host cluster every process
  writes only its addressable shards (here: the single host writes all);
  the manifest keys are tree paths, not device ids, so restore is
  topology-independent;
* **async save** — the host copy is snapshotted synchronously (cheap), the
  file writes happen on a background thread so the train loop is not
  blocked (fault-tolerance without step-time cost);
* **elastic re-mesh** — ``restore`` takes the *target* sharding tree and
  uses ``jax.device_put`` per leaf, so a checkpoint taken on one mesh
  restores onto any other mesh shape (scale up/down after failures);
* **integrity** — writes go to ``step_xxx.tmp`` and are atomically renamed;
  a crash mid-save never corrupts the latest complete checkpoint.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np

import jax
import ml_dtypes

# numpy cannot natively serialise bfloat16 — store a uint16 view + the
# logical dtype in the manifest and view it back on restore.
_VIEW_DTYPES = {"bfloat16": np.uint16}

__all__ = ["Checkpointer"]


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


class Checkpointer:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Snapshot ``tree`` at ``step``; file IO runs on a worker thread."""
        self.wait()  # one in-flight save at a time
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        treedef = jax.tree.structure(tree)

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "leaves": {}}
            for i, (key, arr) in enumerate(host.items()):
                fname = f"leaf_{i:05d}.npy"
                logical = str(arr.dtype)
                if logical in _VIEW_DTYPES:
                    np.save(tmp / fname, arr.view(_VIEW_DTYPES[logical]))
                else:
                    np.save(tmp / fname, arr)
                manifest["leaves"][key] = {
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": logical,
                }
            manifest["treedef"] = str(treedef)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.dir.glob("step_*"))
        for old in steps[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = sorted(self.dir.glob("step_*"))
        steps = [s for s in steps if not s.name.endswith(".tmp")]
        if not steps:
            return None
        return int(steps[-1].name.split("_")[1])

    def restore(self, template, *, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``template``.

        ``shardings`` (same tree structure, NamedSharding leaves) re-shards
        onto the current mesh — elastic restore across topology changes.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        src = self.dir / f"step_{step:08d}"
        manifest = json.loads((src / "manifest.json").read_text())
        flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
        sh_leaves = None
        if shardings is not None:
            sh_leaves = [s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]]
        leaves = []
        for i, (path, tmpl) in enumerate(flat_template):
            key = jax.tree_util.keystr(path)
            meta = manifest["leaves"][key]
            arr = np.load(src / meta["file"])
            if meta["dtype"] in _VIEW_DTYPES:
                arr = arr.view(ml_dtypes.bfloat16)
            if list(arr.shape) != list(tmpl.shape):
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {tmpl.shape}")
            if sh_leaves is not None:
                leaves.append(jax.device_put(arr, sh_leaves[i]))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
        return jax.tree.unflatten(treedef, leaves), step
