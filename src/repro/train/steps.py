"""Training step factory: loss, microbatch gradient accumulation, AdamW.

``make_train_step`` builds the jit-able step used by the trainer, the
launcher and the dry-run.  Structure:

* next-token cross-entropy (f32 logits) + MoE load-balance aux loss;
* optional gradient accumulation: the global batch is split into
  ``n_microbatch`` slices and a ``lax.scan`` accumulates f32 grads — the
  activation-memory knob for the 340B/398B archs;
* remat (``jax.checkpoint``) on the layer-scan body via ``remat=True``;
* AdamW update with optional int8 moments (``optim.adamw``).

TrainState is a plain dict pytree so PartitionSpec trees mirror it 1:1.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state

__all__ = ["loss_fn", "make_train_step", "init_train_state"]

AUX_WEIGHT = 0.01


def loss_fn(model: Model, params, batch: Dict[str, jax.Array], *, remat: bool = False):
    """Mean next-token CE over the batch (+ MoE aux).

    The target log-prob is a masked sum over the vocab dim, NOT a
    ``take_along_axis`` gather: the vocab dim is sharded over "model", and
    a gather there makes GSPMD all-gather the full f32 logits (tens of GB
    at 1M-token batches).  ``where(iota == tgt) · logits`` stays sharded
    and reduces with a psum of scalars."""
    logits, _, aux = model.forward(params, batch, remat=remat)
    tokens = batch["tokens"]
    logits = logits[:, :-1].astype(jnp.float32)
    tgt = tokens[:, 1:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    tgt_logit = jnp.sum(jnp.where(iota == tgt[..., None], logits, 0.0), axis=-1)
    ce = jnp.mean(lse - tgt_logit)
    return ce + AUX_WEIGHT * aux, {"ce": ce, "aux": aux}


def init_train_state(model: Model, key: jax.Array, opt_cfg: AdamWConfig):
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params, opt_cfg)}


def _split_micro(batch: Dict[str, jax.Array], n: int) -> Dict[str, jax.Array]:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return {k: split(v) for k, v in batch.items()}


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    *,
    n_microbatch: int = 1,
    remat: bool = True,
    param_shardings=None,
    acc_dtype=jnp.float32,
):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``param_shardings`` (NamedSharding tree mirroring params) constrains
    gradients and the accumulation buffer to the parameter layout.  This is
    ZeRO gradient sharding: without it GSPMD leaves weight grads replicated
    over the data axis after the wgrad psum — measured 121 GiB of f32 grad
    buffers per device on nemotron-4-340b — and it also halves the wire
    bytes (the data-axis all-reduce becomes a reduce-scatter)."""

    def constrain(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, param_shardings)

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, remat=remat), has_aux=True
        )(params)
        return loss, parts, constrain(grads)

    def train_step(state, batch):
        params = state["params"]
        if n_microbatch == 1:
            loss, parts, grads = grads_of(params, batch)
        else:
            micro = _split_micro(batch, n_microbatch)

            def body(acc, mb):
                loss, parts, g = grads_of(params, mb)
                acc = constrain(jax.tree.map(
                    lambda a, gg: a + gg.astype(acc_dtype) / n_microbatch, acc, g
                ))
                return acc, (loss, parts["ce"])

            zeros = constrain(
                jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
            )
            grads, (losses, ces) = jax.lax.scan(body, zeros, micro)
            loss, parts = losses.mean(), {"ce": ces.mean(), "aux": jnp.zeros(())}
        new_params, new_opt, om = adamw_update(params, grads, state["opt"], opt_cfg)
        metrics = {"loss": loss, **parts, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
