from .steps import init_train_state, loss_fn, make_train_step
