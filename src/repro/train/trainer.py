"""Training loop with fault tolerance.

Responsibilities (the boring-but-essential production layer):

* jit-compiled train step with donated state (single in-flight buffer);
* deterministic data — batch k is a pure function of (seed, k), so
  restart replays the exact stream (``data.pipeline``);
* periodic async checkpoints + crash-safe restore (``checkpoint``);
* straggler/failure handling hook: on restore the state re-shards onto the
  *current* mesh (elastic — a pod lost to maintenance shrinks the mesh,
  training resumes from the last step);
* lightweight metrics log (JSONL) for the examples and integration tests.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    n_microbatch: int = 1
    remat: bool = False
    seed: int = 0


class Trainer:
    def __init__(
        self,
        model: Model,
        opt_cfg: AdamWConfig,
        data_cfg: DataConfig,
        cfg: TrainerConfig,
        *,
        batch_fn: Optional[Callable[[int], Dict]] = None,
    ):
        self.model = model
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.stream = SyntheticLM(data_cfg)
        self.batch_fn = batch_fn
        self.step_fn = jax.jit(
            make_train_step(model, opt_cfg, n_microbatch=cfg.n_microbatch, remat=cfg.remat),
            donate_argnums=(0,),
        )
        self.ckpt = (
            Checkpointer(cfg.checkpoint_dir) if cfg.checkpoint_dir else None
        )
        self.history: list = []

    def _batch(self, step: int) -> Dict:
        if self.batch_fn is not None:
            return self.batch_fn(step)
        return {k: jax.numpy.asarray(v) for k, v in self.stream.batch_at(step).items()}

    def run(self, state=None) -> Dict:
        """Train; resumes from the latest checkpoint if one exists."""
        start = 0
        if state is None:
            state = init_train_state(self.model, jax.random.key(self.cfg.seed), self.opt_cfg)
            if self.ckpt and self.ckpt.latest_step() is not None:
                state, start = self.ckpt.restore(state)
                start += 1
        t0 = time.time()
        for step in range(start, self.cfg.steps):
            state, metrics = self.step_fn(state, self._batch(step))
            if step % self.cfg.log_every == 0 or step == self.cfg.steps - 1:
                row = {
                    "step": step,
                    "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    "lr": float(metrics["lr"]),
                    "wall_s": round(time.time() - t0, 2),
                }
                self.history.append(row)
                print(json.dumps(row))
            if self.ckpt and step and step % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, state)
        if self.ckpt:
            self.ckpt.save(self.cfg.steps - 1, state, blocking=True)
        return state
