"""HBM-budgeted eviction over device-resident serving plans.

Admission stages a plan's tiles to the device once; with thousands of
resident matrices the staged bytes are the scarce resource, not the host
copies.  :class:`LRUEvictor` keeps the **device** footprint under a byte
budget: every admission (and every transparent re-stage) charges the
plan's device bytes, and when the budget overflows the least-recently-
*used* plans are unstaged — their device arrays dropped, their host tiles
and autotuned geometry kept, so a later request against an evicted plan
re-stages in one ``device_tiles`` call with zero re-preprocessing (the
partition config is still in the plan, and a full re-admission would hit
the ``.hbp_autotune/`` disk cache by content hash anyway).

Transpose pairs linked by ``admit_pair`` are evicted as a unit — a
forward plan without its backward partner would silently re-stage the
partner on the first training step, defeating the budget accounting.

The policy is pure bookkeeping (names and byte counts); the registry owns
the actual staging/unstaging side effects.
"""
from __future__ import annotations

from typing import Dict, List, Optional

__all__ = ["plan_device_bytes", "LRUEvictor"]


def plan_device_bytes(tiles) -> int:
    """Device bytes one plan's staged tiles occupy.

    Computed from the host :class:`~repro.core.tile.HBPTiles` mirror —
    the staged pytree holds the same arrays (data f32, cols/rowgroup/
    colblock/first i32, perm, plus the [n_rowgroups, 1] visited mask) at
    the dtypes ``device_tiles`` casts to.
    """
    return int(
        tiles.data.size * 4  # f32 payloads
        + tiles.cols.size * 4  # i32 local columns
        + (tiles.rowgroup.size + tiles.colblock.size + tiles.first.size) * 4
        + tiles.perm.size * 4  # staged as i32
        + tiles.n_rowgroups * 4  # visited mask f32[n_rowgroups, 1]
    )


class LRUEvictor:
    """Least-recently-used byte-budget policy over resident plan names.

    ``admit(name, nbytes)`` registers (or re-registers) a plan as the
    most recently used and returns the names that must be unstaged to get
    back under ``budget_bytes`` — oldest first, never the plan just
    admitted (a single plan larger than the whole budget stays resident
    and the evictor reports the overshoot via :meth:`over_budget`).
    ``touch(name)`` refreshes recency on every registry ``get``;
    ``drop(name)`` removes a plan the registry unstaged or fully evicted
    for its own reasons (pair partners, explicit evicts).
    """

    def __init__(self, budget_bytes: int):
        """Create a policy holding device residency under ``budget_bytes``."""
        if budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be > 0, got {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        # insertion order == recency order (Python dicts preserve it);
        # values are the charged device bytes
        self._resident: Dict[str, int] = {}
        self._pair: Dict[str, str] = {}

    # --- bookkeeping -------------------------------------------------------

    @property
    def resident_bytes(self) -> int:
        """Total device bytes currently charged."""
        return sum(self._resident.values())

    def resident(self) -> List[str]:
        """Resident plan names, least recently used first."""
        return list(self._resident)

    def over_budget(self) -> int:
        """Bytes past the budget, 0 when under.

        Positive only when a single resident unit exceeds the whole
        budget (such a unit stays resident rather than thrashing).
        """
        return max(0, self.resident_bytes - self.budget_bytes)

    def link(self, a: str, b: str) -> None:
        """Mark ``a`` and ``b`` as a transpose pair evicted as one unit."""
        if a != b:
            self._pair[a] = b
            self._pair[b] = a

    def touch(self, name: str) -> None:
        """Refresh ``name`` (and its pair partner) as most recently used."""
        for n in self._unit(name):
            nbytes = self._resident.pop(n, None)
            if nbytes is not None:
                self._resident[n] = nbytes

    def drop(self, name: str) -> None:
        """Forget ``name`` (registry unstaged or evicted it out of band)."""
        self._resident.pop(name, None)

    def unlink(self, name: str) -> None:
        """Dissolve ``name``'s pair link (full eviction of one side)."""
        partner = self._pair.pop(name, None)
        if partner is not None:
            self._pair.pop(partner, None)

    # --- the policy --------------------------------------------------------

    def admit(self, name: str, nbytes: int) -> List[str]:
        """Charge ``name`` at ``nbytes`` and return the victims to unstage.

        The admitted plan (and its pair partner, if resident) is pinned
        for this decision; victims come least recently used first, each
        expanded to its full pair unit, until the total fits the budget
        or nothing evictable remains.
        """
        self._resident.pop(name, None)
        self._resident[name] = int(nbytes)
        pinned = set(self._unit(name))
        victims: List[str] = []
        while self.resident_bytes > self.budget_bytes:
            candidate = next(
                (n for n in self._resident if n not in pinned), None
            )
            if candidate is None:
                break  # only the pinned unit remains: allow the overshoot
            for n in self._unit(candidate):
                if n in self._resident:
                    del self._resident[n]
                    victims.append(n)
        return victims

    def _unit(self, name: str) -> List[str]:
        """``name`` plus its pair partner — the unit evictions operate on."""
        partner: Optional[str] = self._pair.get(name)
        return [name] if partner is None else [name, partner]

    def snapshot(self) -> dict:
        """Bookkeeping view for stats/tests (bytes, order, budget)."""
        return {
            "budget_bytes": self.budget_bytes,
            "resident_bytes": self.resident_bytes,
            "resident": list(self._resident),
            "over_budget": self.over_budget(),
        }
