"""Micro-batching of concurrent SpMV requests into ``[n, k]`` SpMM blocks.

The HBP format's dominant per-multiply cost is streaming the tile arrays
from HBM; the SpMM kernel reads that stream once for all ``k`` RHS columns
(bench_solvers measures ~5x at k=8).  Serving traffic realises the same
win by coalescing: requests against the same matrix that arrive within a
small window are stacked column-wise and served by one kernel launch.

:class:`MicroBatcher` is the pure queueing policy — no kernels, no clocks
of its own, so it is exactly testable:

* one FIFO per matrix key (requests never migrate across matrices);
* a batch closes when it reaches ``max_batch`` columns (k-bucket ceiling)
  or when its oldest request has waited ``max_wait_s`` (deadline flush:
  bounded worst-case queueing latency under thin traffic);
* drained batches are stacked into ``[n, k]`` blocks whose k the engine
  pads to the serving buckets (:data:`repro.kernels.ops.K_BUCKETS`).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

__all__ = ["SpMVRequest", "MicroBatcher"]


@dataclasses.dataclass
class SpMVRequest:
    """One ``y = A @ x`` request as tracked by the batcher/engine."""

    key: str  # registry plan name
    x: np.ndarray  # f32[n_cols]
    req_id: int
    t_submit: float
    t_done: Optional[float] = None
    result: Optional[np.ndarray] = None
    # the request-scoped trace context (repro.obs.requesttrace.RequestContext);
    # typed loosely so the pure queueing module stays obs-import-free
    ctx: Optional[object] = None

    @property
    def done(self) -> bool:
        """Whether the request has completed (its result is assigned)."""
        return self.result is not None


class MicroBatcher:
    """Per-matrix FIFO queues with size- and deadline-triggered flushes.

    ``max_wait_s`` is the default batching window; :meth:`set_wait`
    overrides it per key so a tight-deadline QoS class flushes its
    batches earlier than the engine-wide default.
    """

    def __init__(self, *, max_batch: int = 16, max_wait_s: float = 0.002):
        """Create empty queues with the given size/deadline flush policy."""
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._queues: Dict[str, Deque[SpMVRequest]] = {}
        self._waits: Dict[str, float] = {}

    def add(self, req: SpMVRequest) -> None:
        """Enqueue one request on its matrix's FIFO."""
        self._queues.setdefault(req.key, deque()).append(req)

    def pending(self, key: Optional[str] = None) -> int:
        """Pending request count for ``key`` (or across all queues)."""
        if key is not None:
            return len(self._queues.get(key, ()))
        return sum(len(q) for q in self._queues.values())

    def set_wait(self, key: str, max_wait_s: Optional[float]) -> None:
        """Override ``key``'s batching window; ``None`` restores default."""
        if max_wait_s is None:
            self._waits.pop(key, None)
        else:
            self._waits[key] = max_wait_s

    def wait_for(self, key: str) -> float:
        """The batching window in effect for ``key``."""
        return self._waits.get(key, self.max_wait_s)

    def head_age(self, key: str, now: float) -> float:
        """Wait of ``key``'s oldest pending request, 0 on an empty queue."""
        q = self._queues.get(key)
        if not q:
            return 0.0
        return now - q[0].t_submit

    def due(self, now: float) -> List[str]:
        """Keys whose head batch must flush now: full, or deadline hit."""
        out = []
        for key, q in self._queues.items():
            if not q:
                continue
            if len(q) >= self.max_batch or now - q[0].t_submit >= self.wait_for(key):
                out.append(key)
        return out

    def take(self, key: str) -> List[SpMVRequest]:
        """Pop the next batch (up to ``max_batch`` oldest requests) for key."""
        q = self._queues.get(key)
        if not q:
            return []
        return [q.popleft() for _ in range(min(len(q), self.max_batch))]

    def keys_with_pending(self) -> List[str]:
        """Keys that currently hold at least one queued request."""
        return [k for k, q in self._queues.items() if q]

    @staticmethod
    def stack(batch: List[SpMVRequest]) -> np.ndarray:
        """Column-stack a batch into the ``[n, k]`` RHS block of one SpMM."""
        return np.stack([np.asarray(r.x, np.float32) for r in batch], axis=1)
