"""SpMV-traffic serving: admit a matrix once (content-hashed, autotuned,
device-resident), then coalesce concurrent ``y = A @ x`` requests into
``[n, k]`` micro-batches served by one SpMM tile-stream pass each.

Multi-tenant policy lives in :mod:`repro.serving.qos` (deadline classes,
typed backpressure, weighted-fair flush order) and
:mod:`repro.serving.eviction` (HBM-budgeted LRU residency).  Distinct
from ``repro.serve`` (the LLM token engine).
"""
from .autotune import (
    AutotuneCache,
    AutotuneResult,
    Probe,
    autotune_partition,
    cg_probe,
    matrix_hash,
    spmm_probe,
)
from .batcher import MicroBatcher, SpMVRequest
from .engine import ServingEngine, Ticket
from .eviction import LRUEvictor, plan_device_bytes
from .qos import (
    BEST_EFFORT,
    GOLD,
    STANDARD,
    BackpressureError,
    QoSClass,
    WeightedFairScheduler,
)
from .registry import MatrixPlan, MatrixRegistry

__all__ = [
    "AutotuneCache",
    "AutotuneResult",
    "Probe",
    "spmm_probe",
    "cg_probe",
    "autotune_partition",
    "matrix_hash",
    "MicroBatcher",
    "SpMVRequest",
    "ServingEngine",
    "Ticket",
    "MatrixPlan",
    "MatrixRegistry",
    "QoSClass",
    "BackpressureError",
    "WeightedFairScheduler",
    "GOLD",
    "STANDARD",
    "BEST_EFFORT",
    "LRUEvictor",
    "plan_device_bytes",
]
