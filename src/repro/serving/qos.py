"""Quality of service for multi-tenant SpMV serving.

One device, many tenants: every matrix resident in a
:class:`~repro.serving.registry.MatrixRegistry` is a tenant competing for
the same kernel-launch budget.  This module is the policy layer the
:class:`~repro.serving.engine.ServingEngine` consults on every submit and
every poll:

* :class:`QoSClass` — a named deadline class (per-request deadline,
  weighted-fair share, admission-control queue depth).  Deadline classes
  map directly onto the engine's per-matrix SLO accounting: the class
  deadline is what ``deadline_hit`` means for that tenant's requests, so
  the existing ``deadline_hit_ratio`` objectives and ``slo.*`` burn-rate
  gauges evaluate each tenant against its own class.
* :class:`BackpressureError` — the typed rejection admission control
  raises when a tenant's queue is saturated.  Shedding is never silent: a
  request is either enqueued (and will complete) or the caller gets this
  error with the depth/limit evidence and may retry or downgrade.
* :class:`WeightedFairScheduler` — the flush-order policy.  Tenants
  accumulate virtual work (served columns divided by their class weight),
  and due tenants are flushed lowest-virtual-work first, so a weight-4
  tenant sustains 4x the service share of a weight-1 tenant under
  contention.  Tenants whose SLO is paging are boosted ahead of the fair
  order (burn rates are the scheduler input, not just a dashboard), and
  head-of-line queue wait breaks ties so a starving queue cannot be
  shadowed by an equally-charged one.

Everything here is pure policy — no kernels, no clocks of its own — so
the scheduler is exactly testable the way the micro-batcher is.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Mapping, Optional

__all__ = [
    "QoSClass",
    "BackpressureError",
    "WeightedFairScheduler",
    "BEST_EFFORT",
    "STANDARD",
    "GOLD",
]

# severity order the scheduler boosts by: paging tenants flush first
_STATUS_RANK = {"page": 0, "warn": 1, "ok": 2}


@dataclasses.dataclass(frozen=True)
class QoSClass:
    """One deadline class: the service contract a tenant's requests get.

    ``deadline_s`` is the per-request latency deadline — a completed
    request is a deadline hit iff it waited at most this long, which is
    exactly the event the engine's ``deadline_hit_ratio`` SLOs and burn
    rates evaluate.  ``weight`` is the tenant's weighted-fair share of
    flush order under contention (relative to other tenants' weights).
    ``max_queue`` is the admission-control depth: a submit that finds the
    tenant's queue already holding this many requests is rejected with a
    :class:`BackpressureError` (``None`` disables shedding — the queue
    may grow without bound, as the pre-QoS engine allowed).
    ``max_wait_s`` optionally overrides the engine's batching window for
    this class: a tight-deadline class flushes its batches earlier.
    """

    name: str
    deadline_s: float
    weight: float = 1.0
    max_queue: Optional[int] = None
    max_wait_s: Optional[float] = None

    def __post_init__(self):
        """Validate the class invariants (positive deadline and weight)."""
        if self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.max_wait_s is not None and self.max_wait_s <= 0:
            raise ValueError(f"max_wait_s must be > 0, got {self.max_wait_s}")


# ready-made classes for the common three-tier setup; engines default to a
# per-engine "standard" class whose deadline is the batching window
GOLD = QoSClass("gold", deadline_s=0.005, weight=4.0, max_queue=None)
STANDARD = QoSClass("standard", deadline_s=0.02, weight=1.0, max_queue=None)
BEST_EFFORT = QoSClass(
    "best_effort", deadline_s=0.1, weight=0.25, max_queue=64
)


class BackpressureError(RuntimeError):
    """Typed admission-control rejection: the tenant's queue is saturated.

    Raised by :meth:`~repro.serving.engine.ServingEngine.submit` *before*
    the request is enqueued, so a shed request holds no queue slot and no
    ticket — the caller owns the retry/downgrade decision.  Carries the
    evidence: ``key`` (the tenant), ``qos`` (its class name), ``depth``
    (queue depth observed) and ``limit`` (the class ``max_queue``).
    """

    def __init__(self, key: str, qos: str, depth: int, limit: int):
        """Record the shed evidence and compose the message."""
        super().__init__(
            f"queue for {key!r} is saturated ({depth} >= max_queue={limit} "
            f"of QoS class {qos!r}); request shed — retry later or submit "
            "under a higher class"
        )
        self.key = key
        self.qos = qos
        self.depth = depth
        self.limit = limit


class WeightedFairScheduler:
    """Weighted-fair flush ordering over due tenants.

    Each tenant accumulates **virtual work**: served columns divided by
    its class weight (:meth:`charge`).  :meth:`order` sorts due tenants by
    (SLO status, virtual work, head-of-line wait, key) — paging tenants
    first, then least-served-relative-to-weight, oldest head request
    breaking ties, key last so the order is fully deterministic.

    A tenant first seen mid-run joins at the *minimum* live virtual work
    rather than zero, so a late joiner gets fair service from now on but
    no retroactive credit that would starve incumbents.
    """

    def __init__(self, weight_of: Callable[[str], float]):
        """Build a scheduler that reads per-key weights via ``weight_of``."""
        self.weight_of = weight_of
        self._vwork: Dict[str, float] = {}

    def vwork(self, key: str) -> float:
        """Virtual work accumulated by ``key`` (joins at the live minimum)."""
        v = self._vwork.get(key)
        if v is None:
            v = min(self._vwork.values(), default=0.0)
            self._vwork[key] = v
        return v

    def charge(self, key: str, columns: int) -> float:
        """Account one served batch of ``columns`` columns against ``key``.

        Returns the tenant's updated virtual work (columns / weight are
        the units — a weight-4 tenant is charged a quarter per column).
        """
        v = self.vwork(key) + columns / self.weight_of(key)
        self._vwork[key] = v
        return v

    def order(
        self,
        keys: Iterable[str],
        *,
        head_wait: Optional[Callable[[str], float]] = None,
        status: Optional[Mapping[str, str]] = None,
    ) -> List[str]:
        """Flush order for the due ``keys`` (see class docstring).

        ``head_wait`` maps a key to its oldest pending request's wait (a
        :class:`~repro.obs.requesttrace.RequestContext` submit stamp
        against now); ``status`` maps a key to its latest SLO
        classification (``ok``/``warn``/``page``) — both optional, both
        read-only inputs.
        """
        status = status or {}

        def rank(key: str):
            return (
                _STATUS_RANK.get(status.get(key, "ok"), 2),
                self.vwork(key),
                -(head_wait(key) if head_wait is not None else 0.0),
                key,
            )

        return sorted(keys, key=rank)

    def snapshot(self) -> Dict[str, float]:
        """Current per-key virtual work (for stats views and tests)."""
        return dict(self._vwork)
