"""Matrix admission: CSR in, device-resident autotuned HBP plan out.

A serving system's defining asymmetry is admit-once / multiply-many: the
HBP preprocessing pipeline (2D partition → nonlinear hash → tile packing)
runs once per matrix, and every subsequent request reuses the device-
resident tiles.  :class:`MatrixRegistry` owns that lifecycle:

* **content addressing** — matrices are keyed by a sha256 over shape +
  structure + values, so re-admitting an already-resident matrix returns
  the existing plan without touching the preprocessing pipeline;
* **autotuned geometry** — the partition config comes from
  :func:`repro.serving.autotune.autotune_partition` (measured search with a
  persistent on-disk cache), unless the caller pins an explicit config;
* **device residency** — tiles are staged to the device once at admission
  (:func:`repro.kernels.ops.device_tiles`); requests only launch kernels;
* **amortization bookkeeping** — the one-time preprocessing cost is
  recorded so :meth:`MatrixRegistry.stats` can report how far traffic has
  amortized it (the paper's Fig. 7 cost, divided by requests served).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro import obs
from repro.obs.flight import get_flight
from repro.obs.requesttrace import mint_trace_id
from repro.core.formats import CSRMatrix
from repro.core.partition import PartitionConfig
from repro.core.tile import HBPTiles, build_tiles
from repro.obs.metrics import MetricRegistry
from repro.obs import planview

from .autotune import AutotuneCache, autotune_partition, matrix_hash
from .eviction import LRUEvictor, plan_device_bytes

__all__ = ["MatrixPlan", "MatrixRegistry"]


@dataclasses.dataclass
class MatrixPlan:
    """Everything the serving path needs about one resident matrix."""

    name: str
    matrix_hash: str
    shape: tuple
    nnz: int
    cfg: PartitionConfig
    tiles: HBPTiles  # host copy (rebuilds, debugging)
    device: object  # DeviceTiles pytree, staged once
    diag: np.ndarray  # main diagonal, host-resident at tile-build time
    row_nnz: np.ndarray  # per-row stored-entry count (graph in-degree)
    preprocess_s: float  # autotune + tile build + device staging
    autotune_cache_hit: bool
    autotune_searched: bool
    strategy: str = "fused"
    interpret: Optional[bool] = None
    # launch geometry for RHS widths beyond one lane tile: "grid" = the
    # one-pass 2D k-tiled grid, "loop" = the legacy chunked launches
    # (an "auto" admission resolves to whichever measured faster)
    k_tiling: str = "grid"
    # admission-time introspection: static partition-quality metrics
    # (:func:`repro.obs.planview.partition_quality`) and the autotune
    # decision provenance — which geometry candidates were measured, what
    # each cost, and how the served k_tiling was chosen.  Deliberately NOT
    # part of ``_meta()``: these describe the plan, the kernels never see
    # them.
    quality: dict = dataclasses.field(default_factory=dict, repr=False, compare=False)
    provenance: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    # A <-> A^T link, set by MatrixRegistry.admit_pair: the transpose
    # plan's name plus a direct reference (a symmetric matrix links to
    # itself — one residency serves both directions for free)
    transpose_name: Optional[str] = None
    _transpose: object = dataclasses.field(default=None, repr=False, compare=False)
    # device-staged clamped in-degree [n, 1], built on first mean aggregate
    _mean_div: object = dataclasses.field(default=None, repr=False, compare=False)
    # the owning registry's shared MetricRegistry — single source of truth
    # for the admission counters this plan's views read
    _metrics: object = dataclasses.field(default=None, repr=False, compare=False)

    @property
    def admissions(self) -> int:
        """admit() calls that resolved to this plan — a *view* over the
        owning registry's shared metrics, not a second ledger."""
        if self._metrics is None:
            return 1
        return int(self._metrics.value("registry.admissions", 1, matrix=self.name))

    def _meta(self) -> dict:
        return dict(
            n_rowgroups=self.tiles.n_rowgroups,
            n_rows=self.shape[0],
            col_block=self.cfg.col_block,
            strategy=self.strategy,
            interpret=self.interpret,
            k_tiling=self.k_tiling,
        )

    def matvec(self, x) -> np.ndarray:
        """One-off ``A @ x`` against the resident plan (bypasses batching)."""
        from repro.kernels import ops

        return ops.hbp_spmv(self.device, x, **self._meta())

    def matmat(self, x, *, bucketed: bool = True, buckets=None, combine: str = "sum"):
        """``A (x) X`` for an ``[n, k]`` block; ``bucketed`` pads k to the
        serving buckets (``buckets`` overrides the default set) so the
        compile count stays bounded.  ``combine`` selects the reduction
        monoid ("sum" | "max") — feature widths beyond the top bucket
        lane-tile inside the kernel wrapper."""
        from repro.kernels import ops

        if not bucketed:
            return ops.hbp_spmm(self.device, x, combine=combine, **self._meta())
        if buckets is None:
            buckets = ops.K_BUCKETS
        return ops.hbp_spmm_bucketed(
            self.device, x, buckets=buckets, combine=combine, **self._meta()
        )

    def aggregate(self, x, *, op: str = "sum", bucketed: bool = True):
        """Neighborhood aggregation over the resident plan: the registered
        matrix read as a graph adjacency (rows aggregate their stored
        neighbors).  ``op`` is "sum", "mean" (sum / in-degree, captured at
        admission) or "max" (the max-monoid kernel path); repeated GNN
        layer calls all reuse the device tiles and autotuned geometry.
        """
        if op == "sum":
            return self.matmat(x, bucketed=bucketed)
        if op == "mean":
            if self._mean_div is None:  # staged once, like the tiles
                from repro.kernels.autodiff import mean_divisor

                self._mean_div = mean_divisor(self.row_nnz, self.shape[0])
            return self.matmat(x, bucketed=bucketed) / self._mean_div
        if op == "max":
            return self.matmat(x, bucketed=bucketed, combine="max")
        raise ValueError(f"unknown aggregation {op!r} (sum | mean | max)")

    def diff_aggregator(self, *, op: str = "sum", mode: str = "vjp"):
        """Differentiable aggregation closure over the resident plan.

        Backward for sum/mean launches the *linked transpose plan's*
        tiles (``x̄ = Aᵀ @ ȳ``), so the plan must have been admitted with
        :meth:`MatrixRegistry.admit_pair`; max routes cotangents through
        the argmax indices its forward saves and needs no transpose.
        Mean divides by the in-degree captured at admission.
        """
        from repro.kernels import autodiff

        needs_t = autodiff.needs_transpose(op, mode)
        if needs_t and self._transpose is None:
            raise ValueError(
                f"plan {self.name!r} has no linked transpose — admit the "
                "matrix with MatrixRegistry.admit_pair() for differentiable "
                "sum/mean aggregation"
            )
        plan_T = self._transpose
        return autodiff.device_diff_aggregator(
            self.device,
            plan_T.device if plan_T is not None else None,
            self._meta(),
            plan_T._meta() if plan_T is not None else None,
            op=op,
            degree=self.row_nnz if op == "mean" else None,
            mode=mode,
        )

    def operator(self):
        """The plan as a solver-ready :class:`LinearOperator`."""
        from repro.solvers.operator import LinearOperator

        return LinearOperator(self.shape, matvec=self.matvec, matmat=self.matmat)

    def jacobi(self):
        """Jacobi preconditioner built from the admission-time diagonal."""
        from repro.solvers.precond import jacobi

        return jacobi(self.diag)


class MatrixRegistry:
    """Admit CSR matrices once; hand out device-resident HBP plans.

    ``search=False`` replaces the measured autotune search with the
    ``tuned_partition_config`` heuristic (still cached); ``candidates``
    narrows the measured search space; ``strategy``/``interpret`` select
    the kernel path every plan's launches use.  The default strategy is
    backend-aware: the fused Pallas kernel on TPU, the batch-width-
    invariant ``"stable"`` jnp path elsewhere (off-TPU the kernels would
    run in interpret mode — slow, and ~1 ulp dependent on batch width,
    which would break the engine's coalescing-invariance guarantee).

    ``k_tiling`` selects the wide-k launch geometry every plan serves:
    ``"grid"`` (default) is the one-pass 2D k-tiled grid, ``"loop"`` the
    legacy chunked launches, and ``"auto"`` measures both per matrix at
    admission (:func:`repro.serving.autotune.pick_k_tiling`) so each
    autotuned plan picks the faster contract for its own geometry.

    ``metrics`` is the shared :class:`~repro.obs.metrics.MetricRegistry`
    backing this registry's admission counters *and* every
    :class:`~repro.serving.engine.ServingEngine` built over it — one
    ledger, two ``stats()`` views.  Each registry defaults to its own
    instance (test isolation); all live instances aggregate into
    ``repro.obs.dump()``/``report()``.

    ``hbm_budget_bytes`` caps the **device** footprint of staged tiles:
    when admissions (or re-stages) push past the budget, the least-
    recently-used plans are *unstaged* — device arrays dropped, host
    tiles and autotuned geometry kept — and the next :meth:`get` against
    an unstaged plan transparently re-stages it in one ``device_tiles``
    call (zero re-preprocessing; a full re-admission would hit the
    ``.hbp_autotune/`` disk cache by content hash anyway).  Transpose
    pairs are evicted and re-staged as a unit.  ``None`` (default)
    disables the budget — every admitted plan stays device-resident.
    """

    def __init__(
        self,
        *,
        cache_dir=None,
        search: bool = True,
        candidates=None,
        autotune_k: int = 8,
        strategy: Optional[str] = None,
        interpret: Optional[bool] = None,
        k_tiling: str = "grid",
        probe=None,
        metrics: Optional[MetricRegistry] = None,
        hbm_budget_bytes: Optional[int] = None,
    ):
        if strategy is None:
            import jax

            strategy = "fused" if jax.default_backend() == "tpu" else "stable"
        if k_tiling not in ("grid", "loop", "auto"):
            raise ValueError(
                f"unknown k_tiling {k_tiling!r} (expected grid, loop or auto)"
            )
        self.cache = AutotuneCache(cache_dir)
        self.search = search
        self.candidates = candidates
        self.autotune_k = autotune_k
        self.strategy = strategy
        self.interpret = interpret
        self.k_tiling = k_tiling
        self.probe = probe  # None: steady-state SpMM time (spmm_probe)
        self.metrics = metrics if metrics is not None else MetricRegistry(name="serving")
        self.evictor = (
            LRUEvictor(hbm_budget_bytes) if hbm_budget_bytes is not None else None
        )
        self._plans: Dict[str, MatrixPlan] = {}
        self._by_hash: Dict[str, str] = {}

    def admit(
        self,
        csr: CSRMatrix,
        name: Optional[str] = None,
        *,
        cfg: Optional[PartitionConfig] = None,
    ) -> MatrixPlan:
        """Admit ``csr`` and return its plan.

        Same content twice → the resident plan (no rebuild, no search).
        Fresh content with a warm on-disk cache → tile build only (the
        measured search is skipped).  ``cfg`` pins the geometry explicitly
        and bypasses autotuning altogether.
        """
        key = matrix_hash(csr)
        if key in self._by_hash:
            plan = self._plans[self._by_hash[key]]
            if cfg is not None and cfg != plan.cfg:
                raise ValueError(
                    f"matrix {key[:12]} is already resident as {plan.name!r} "
                    f"with config {plan.cfg}; re-admission pinned {cfg} — "
                    "evict the plan first to rebuild under a different geometry"
                )
            self.metrics.counter("registry.hits", matrix=plan.name).inc()
            self.metrics.counter("registry.admissions", matrix=plan.name).inc()
            self._ensure_staged(plan)
            return plan
        if name is not None and name in self._plans:
            raise ValueError(
                f"name {name!r} is already bound to matrix "
                f"{self._plans[name].matrix_hash[:12]}"
            )

        from repro.kernels import ops

        # admissions get trace ids too (kind "a"): the one-time preprocess
        # cost is attributable in dumps the same way requests are
        admit_id = mint_trace_id("a")
        with obs.span("serve.admit", matrix=name, nnz=csr.nnz, trace_id=admit_id) as sp:
            t0 = time.perf_counter()
            # the measured search ranks candidates under the served contract;
            # "auto" ranks under the default grid, then picks per matrix below
            served_tiling = self.k_tiling if self.k_tiling != "auto" else "grid"
            pinned = cfg is not None
            if pinned:
                tune_hit, tune_searched = False, False
                trials, evaluations, objective_us = (), 0, None
            else:
                tuned = autotune_partition(
                    csr,
                    key=key,
                    cache=self.cache,
                    search=self.search,
                    candidates=self.candidates,
                    k=self.autotune_k,
                    strategy=self.strategy,  # rank configs under the served path
                    k_tiling=served_tiling,
                    probe=self.probe,  # e.g. cg_probe: rank by time-to-tolerance
                )
                cfg = tuned.cfg
                tune_hit, tune_searched = tuned.cache_hit, tuned.searched
                trials = tuned.trials
                evaluations, objective_us = tuned.evaluations, tuned.objective_us
            k_tiling_us = None
            if self.k_tiling == "auto":
                from .autotune import measure_k_tilings

                k_tiling_us = measure_k_tilings(csr, cfg, strategy=self.strategy)
                if k_tiling_us:
                    served_tiling = min(k_tiling_us, key=k_tiling_us.get)
            tiles = build_tiles(csr, cfg)
            with obs.span("serve.stage_device", matrix=name):
                device = ops.device_tiles(tiles)
            diag = csr.diagonal()
            row_nnz = csr.row_nnz().astype(np.int64)
            preprocess_s = time.perf_counter() - t0
            name = name or f"m_{key[:12]}"
            sp.annotate(matrix=name, preprocess_s=round(preprocess_s, 6))
            # partition-quality introspection runs once per admission,
            # after the preprocess clock stops: it describes the plan, it
            # is not part of the amortizable build cost
            with obs.span("admit.plan_quality", matrix=name, tiles=tiles.n_tiles):
                quality = planview.partition_quality(tiles, csr)
        provenance = {
            "searched": tune_searched,
            "cache_hit": tune_hit,
            "pinned": pinned,
            "evaluations": evaluations,
            "objective_us": objective_us,
            "trials": [dict(t) for t in trials],
            "k_tiling": served_tiling,
            "k_tiling_mode": self.k_tiling,
            "k_tiling_us": k_tiling_us,
        }

        plan = MatrixPlan(
            name=name,
            matrix_hash=key,
            shape=csr.shape,
            nnz=csr.nnz,
            cfg=cfg,
            tiles=tiles,
            device=device,
            diag=diag,
            row_nnz=row_nnz,
            preprocess_s=preprocess_s,
            autotune_cache_hit=tune_hit,
            autotune_searched=tune_searched,
            strategy=self.strategy,
            interpret=self.interpret,
            k_tiling=served_tiling,
            quality=quality,
            provenance=provenance,
            _metrics=self.metrics,
        )
        self._plans[name] = plan
        self._by_hash[key] = name
        m = self.metrics
        planview.register_plan_metrics(m, name, quality, provenance)
        m.counter("registry.misses", matrix=name).inc()
        m.counter("registry.admissions", matrix=name).inc()
        m.counter("registry.preprocess_s", matrix=name).inc(preprocess_s)
        if tune_hit:
            m.counter("registry.autotune_cache_hits", matrix=name).inc()
        if tune_searched:
            m.counter("registry.autotune_searches", matrix=name).inc()
        m.gauge("registry.resident").set(len(self._plans))
        # admissions are rare and expensive — always worth a flight-ring
        # slot, so a post-mortem dump shows what was admitted and when
        get_flight().record(
            "serve.admit",
            matrix=name,
            nnz=csr.nnz,
            preprocess_s=round(preprocess_s, 6),
            k_tiling=served_tiling,
            trace_id=admit_id,
        )
        self._charge(plan)
        return plan

    def admit_pair(
        self,
        csr: CSRMatrix,
        name: Optional[str] = None,
        *,
        cfg: Optional[PartitionConfig] = None,
        cfg_T: Optional[PartitionConfig] = None,
    ) -> MatrixPlan:
        """Admit ``csr`` AND its transpose, linked for differentiable use.

        The pair is what training needs: the backward of ``A @ X`` is an
        SpMM against ``Aᵀ`` (:mod:`repro.kernels.autodiff`), so both
        directions become resident plans cross-linked via
        ``transpose_name``.  Content hashing makes every re-admission
        free, and a *symmetric* matrix (e.g. GCN's normalized adjacency)
        hashes identically to its transpose — one plan serves both
        directions, no second build.  Returns the forward plan; reach the
        transpose through the link (``plan.transpose_name`` /
        ``registry.transpose_of(plan)``).
        """
        plan = self.admit(csr, name, cfg=cfg)
        if plan._transpose is not None:  # pair already linked (re-admission)
            partner = plan._transpose
            if cfg_T is not None and cfg_T != partner.cfg:
                raise ValueError(
                    f"transpose of {plan.name!r} is already resident as "
                    f"{partner.name!r} with config {partner.cfg}; re-admission "
                    f"pinned {cfg_T} — evict the pair first to rebuild"
                )
            if partner is not plan:  # keep both sides' admission stats in step
                self.metrics.counter(
                    "registry.admissions", matrix=partner.name
                ).inc()
            return plan
        csr_T = csr.transpose()
        plan_T = self.admit(csr_T, f"{plan.name}::T", cfg=cfg_T)
        plan.transpose_name = plan_T.name
        plan._transpose = plan_T
        plan_T.transpose_name = plan.name
        plan_T._transpose = plan
        if self.evictor is not None and plan_T is not plan:
            # forward + backward are one residency unit: evicting one side
            # would silently re-stage the other on the next training step
            self.evictor.link(plan.name, plan_T.name)
        return plan

    def transpose_of(self, plan: MatrixPlan) -> MatrixPlan:
        """The linked Aᵀ plan (admit with :meth:`admit_pair` first)."""
        if plan._transpose is None:
            raise KeyError(f"plan {plan.name!r} has no linked transpose")
        return plan._transpose

    def get(self, name: str) -> MatrixPlan:
        """The resident plan for ``name`` (raises ``KeyError`` if absent).

        Under an HBM budget this is also the re-admission path: an
        unstaged plan is transparently re-staged to the device here (and
        its recency refreshed), so callers never observe eviction beyond
        the one-time ``device_tiles`` cost.
        """
        plan = self._plans[name]
        self._ensure_staged(plan)
        return plan

    def __contains__(self, name: str) -> bool:
        return name in self._plans

    def __len__(self) -> int:
        return len(self._plans)

    def names(self):
        """Names of every resident plan (staged or budget-unstaged)."""
        return list(self._plans)

    def evict(self, name: str) -> None:
        """Fully remove ``name``: plan, content-hash binding, pair link.

        Unlike budget-driven *unstaging* (device arrays only), this drops
        the host plan too — the next admit of the same content rebuilds
        tiles (the autotune disk cache still avoids the measured search).
        """
        plan = self._plans.pop(name)
        del self._by_hash[plan.matrix_hash]
        partner = plan._transpose
        if partner is not None and partner is not plan:
            partner.transpose_name = None
            partner._transpose = None
        if self.evictor is not None:
            self.evictor.drop(name)
            self.evictor.unlink(name)
        self.metrics.counter("registry.evictions", matrix=name).inc()
        self.metrics.gauge("registry.resident").set(len(self._plans))

    # --- HBM-budget residency ---------------------------------------------

    def _charge(self, plan: MatrixPlan) -> None:
        """Charge ``plan``'s device bytes to the budget; unstage victims."""
        if self.evictor is None:
            return
        victims = self.evictor.admit(plan.name, plan_device_bytes(plan.tiles))
        for victim in victims:
            self._unstage(victim)
        self.metrics.gauge("evict.resident_bytes").set(self.evictor.resident_bytes)

    def _unstage(self, name: str) -> None:
        """Drop ``name``'s device arrays (host tiles and geometry stay)."""
        plan = self._plans.get(name)
        if plan is None or plan.device is None:
            return
        plan.device = None
        plan._mean_div = None  # staged alongside the tiles; rebuilt on demand
        self.metrics.counter("evict.unstaged", matrix=name).inc()
        get_flight().record("evict.unstage", matrix=name)
        if obs.enabled():
            obs.counter("evict.unstaged", matrix=name).inc()

    def _ensure_staged(self, plan: MatrixPlan) -> None:
        """Refresh recency; re-stage the plan's unit if budget-evicted."""
        if self.evictor is None:
            return
        self.evictor.touch(plan.name)
        # the pair is one unit: restage both sides together so a training
        # step never finds half of its forward/backward residency missing
        unit = [plan]
        if plan._transpose is not None and plan._transpose is not plan:
            unit.append(plan._transpose)
        for p in unit:
            if p.device is not None:
                continue
            from repro.kernels import ops

            t0 = time.perf_counter()
            with obs.span("serve.restage", matrix=p.name):
                p.device = ops.device_tiles(p.tiles)
            restage_s = time.perf_counter() - t0
            m = self.metrics
            m.counter("evict.restages", matrix=p.name).inc()
            m.counter("evict.restage_s", matrix=p.name).inc(restage_s)
            get_flight().record(
                "evict.restage", matrix=p.name, restage_s=round(restage_s, 6)
            )
            self._charge(p)

    def stats(self) -> dict:
        """Per-matrix admission/preprocessing snapshot (engine adds traffic).

        A *view*: admission counts are read back from the shared
        :class:`~repro.obs.metrics.MetricRegistry` (``self.metrics``), the
        same store every engine over this registry reports traffic into.
        """
        return {
            name: {
                "matrix_hash": p.matrix_hash[:12],
                "shape": tuple(p.shape),
                "nnz": p.nnz,
                "config": dataclasses.asdict(p.cfg),
                "k_tiling": p.k_tiling,
                "admissions": p.admissions,
                "preprocess_s": p.preprocess_s,
                "autotune_cache_hit": p.autotune_cache_hit,
                "autotune_searched": p.autotune_searched,
                "quality": {
                    k: v for k, v in p.quality.items() if k != "occupancy_sample"
                },
                "provenance": p.provenance,
            }
            for name, p in self._plans.items()
        }
