"""Measured partition-config search with a persistent on-disk cache.

``tuned_partition_config`` (core/tile.py) picks a lane width from the nnz
profile — a heuristic.  A serving system can afford better: the matrix is
admitted once and then multiplied thousands of times, so a few measured
SpMM launches per candidate geometry are noise against the traffic they
optimise.  :func:`autotune_partition` times every candidate from the
:func:`repro.core.partition.enumerate_configs` search space and keeps the
fastest, caching the winner on disk keyed by the matrix's content hash so
the next admission — same process or next process — skips the search
entirely.

The objective is steady-state multiply time (one ``hbp_spmm`` launch at the
traffic's typical RHS width), not build time: preprocessing amortizes away
under serving traffic, the per-request multiply does not.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from pathlib import Path
from typing import Callable, Optional, Sequence

import numpy as np

from repro import obs
from repro.core.formats import CSRMatrix
from repro.core.partition import PartitionConfig, enumerate_configs
from repro.core.tile import build_tiles, tuned_partition_config

__all__ = [
    "matrix_hash",
    "AutotuneCache",
    "AutotuneResult",
    "Probe",
    "spmm_probe",
    "cg_probe",
    "measure_k_tilings",
    "pick_k_tiling",
    "autotune_partition",
    "DEFAULT_CACHE_DIR",
]

DEFAULT_CACHE_DIR = ".hbp_autotune"
_CACHE_VERSION = 1


def matrix_hash(csr: CSRMatrix) -> str:
    """Content hash of a CSR matrix: shape + structure + values.

    Two admissions of the same matrix — different objects, different
    processes — hash identically, which is what keys both the registry's
    resident-plan lookup and the on-disk autotune cache.
    """
    h = hashlib.sha256()
    h.update(np.asarray(csr.shape, np.int64).tobytes())
    h.update(np.ascontiguousarray(csr.indptr).tobytes())
    h.update(np.ascontiguousarray(csr.indices).tobytes())
    h.update(np.ascontiguousarray(csr.data, dtype=np.float64).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class AutotuneResult:
    """Outcome of one :func:`autotune_partition` call."""

    cfg: PartitionConfig
    cache_hit: bool  # config came from the on-disk cache; no search ran
    searched: bool  # a measured search ran this call
    evaluations: int  # candidate geometries actually timed
    objective_us: Optional[float]  # best measured SpMM time (None: heuristic)
    # decision provenance: every candidate measured, as
    # ``{"config": {...}, "objective_us": float}`` dicts sorted fastest
    # first — persisted into the cache entry, so a cache-hit admission can
    # still explain WHY its geometry won the original search
    trials: tuple = ()


class AutotuneCache:
    """On-disk partition-config cache: one JSON file per matrix hash.

    The directory (default ``.hbp_autotune/``, or ``$HBP_AUTOTUNE_DIR``) is
    safe to persist across runs and machines of the same matrix corpus —
    entries are keyed purely by matrix content.  Unreadable or
    version-mismatched entries are treated as misses, never errors.
    """

    def __init__(self, path: str | os.PathLike | None = None):
        if path is None:
            path = os.environ.get("HBP_AUTOTUNE_DIR", DEFAULT_CACHE_DIR)
        self.path = Path(path)

    def _entry(self, key: str) -> Path:
        return self.path / f"{key}.json"

    def get(self, key: str) -> Optional[dict]:
        try:
            entry = json.loads(self._entry(key).read_text())
        except (OSError, ValueError):
            return None
        if entry.get("version") != _CACHE_VERSION or "config" not in entry:
            return None
        return entry

    def get_config(self, key: str) -> Optional[PartitionConfig]:
        entry = self.get(key)
        if entry is None:
            return None
        try:
            return PartitionConfig(**entry["config"])
        except TypeError:
            return None

    def put(self, key: str, cfg: PartitionConfig, **extra) -> None:
        self.path.mkdir(parents=True, exist_ok=True)
        entry = {
            "version": _CACHE_VERSION,
            "config": dataclasses.asdict(cfg),
            **extra,
        }
        # per-process tmp name + atomic rename: concurrent admits of the
        # same matrix each install a complete entry, last writer wins
        tmp = self._entry(key).with_suffix(f".{os.getpid()}.tmp")
        tmp.write_text(json.dumps(entry, indent=2, sort_keys=True))
        os.replace(tmp, self._entry(key))


def _space_fingerprint(
    candidates: Sequence[PartitionConfig], k: int, strategy: str, probe: "Probe"
) -> str:
    """Content key of a measured search: candidate set plus the objective
    that ranked it.  Stored with searched cache entries so a search over a
    narrow space, a different kernel path, or a different objective (e.g.
    CG time-to-tolerance vs raw SpMM time) does not satisfy later
    admissions searching a different one.

    An SpMM probe is fingerprinted by ITS OWN (k, strategy) — not the
    ``autotune_partition`` call's — so e.g. a spmm_probe(k=128) search
    never satisfies a default k=8 admission; when the probe is the
    default one built from the call's arguments the two coincide, which
    keeps the historical ``(geoms, k, strategy)`` fingerprint and existing
    caches warm."""
    geoms = sorted((c.row_block, c.col_block, c.group, c.lane) for c in candidates)
    if probe.kind == "spmm" and len(probe.params) == 2:
        key = (geoms, *probe.params)
    else:
        key = (geoms, k, strategy, probe.kind, probe.params)
    return hashlib.sha256(repr(key).encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class Probe:
    """A measured-search objective: what one candidate geometry costs.

    ``measure(csr, cfg, repeats)`` returns the objective in microseconds
    (lower is better); ``kind`` names the objective and — together with
    ``params``, the objective's own parameters — enters the cache
    fingerprint, so entries tuned under one objective never satisfy
    admissions tuning under another.
    """

    kind: str
    measure: Callable[[CSRMatrix, PartitionConfig, int], float]
    params: tuple = ()

    def __call__(self, csr: CSRMatrix, cfg: PartitionConfig, repeats: int) -> float:
        return self.measure(csr, cfg, repeats)


def spmm_probe(k: int = 8, strategy: str = "stable", k_tiling: str = "grid") -> Probe:
    """The default serving objective: one steady-state k-wide SpMM launch.

    ``k_tiling`` selects the launch geometry the measurement runs under —
    ``"grid"`` (the one-pass 2D k-tiled grid the plans serve by default)
    or ``"loop"`` (the legacy chunked launches).  At k <= LANE_TILE the
    two geometries are the same launch, so the params tuple stays the
    historical two-element ``(k, strategy)`` and existing cache entries
    keep satisfying (they measured the identical computation); at wider
    k the geometries genuinely differ and ``k_tiling`` enters the
    fingerprint, so a loop-era entry never silently ranks a grid-served
    admission (or vice versa).
    """
    from repro.kernels.ops import LANE_TILE

    params = (k, strategy) if k <= LANE_TILE else (k, strategy, k_tiling)
    return Probe(
        kind="spmm",
        measure=lambda csr, cfg, repeats: _measure_spmm_us(
            csr, cfg, k, repeats, strategy, k_tiling=k_tiling
        ),
        params=params,
    )


def cg_probe(
    iters: int = 10, k: int = 1, strategy: str = "stable", seed: int = 0
) -> Probe:
    """Solver-objective probe: wall time of ``iters`` CG iterations.

    Ranks candidate geometries by what an iterative-solver workload
    actually pays — time to (a proxy for) tolerance rather than raw
    multiply time, folding in the per-iteration vector work and, for
    blocked RHS (``k > 1``), the SpMM amortization the solver sees.
    ``tol=0`` pins the iteration count so every candidate runs exactly
    ``iters`` steps of the same Krylov recurrence.
    """

    def measure(csr: CSRMatrix, cfg: PartitionConfig, repeats: int) -> float:
        from repro.solvers import cg
        from repro.solvers.operator import aslinearoperator

        tiles = build_tiles(csr, cfg)
        op = aslinearoperator(tiles, strategy=strategy)
        rng = np.random.default_rng(seed)
        shape = (csr.n_rows,) if k == 1 else (csr.n_rows, k)
        b = rng.standard_normal(shape).astype(np.float32)
        def jax_block(r):
            return r.x.block_until_ready()

        jax_block(cg(op, b, tol=0.0, maxiter=iters))  # compile outside the clock
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax_block(cg(op, b, tol=0.0, maxiter=iters))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts) * 1e6)

    return Probe(kind=f"cg{iters}x{k}_{strategy}", measure=measure)


def _measure_spmm_us(
    csr: CSRMatrix,
    cfg: PartitionConfig,
    k: int,
    repeats: int,
    strategy: str,
    k_tiling: str = "grid",
) -> float:
    """Median microseconds of one k-wide SpMM launch under ``cfg``.

    ``strategy`` (and ``k_tiling``) should be the path serving will
    actually run (the registry passes its own), so the search ranks
    configs under the cost model traffic pays — the jnp paths' k-scaling
    differs from the fused kernel's, and off-TPU the kernels execute in
    interpret mode whose timings are meaningless.
    """
    from repro.kernels import ops

    tiles = build_tiles(csr, cfg)
    dt = ops.device_tiles(tiles)
    meta = dict(
        n_rowgroups=tiles.n_rowgroups,
        n_rows=tiles.shape[0],
        col_block=cfg.col_block,
        strategy=strategy,
        k_tiling=k_tiling,
    )
    x = np.random.default_rng(0).standard_normal((csr.n_cols, k)).astype(np.float32)
    ops.hbp_spmm(dt, x, **meta).block_until_ready()  # compile outside the clock
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        ops.hbp_spmm(dt, x, **meta).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def measure_k_tilings(
    csr: CSRMatrix,
    cfg: PartitionConfig,
    *,
    k: int = 256,
    strategy: str = "stable",
    repeats: int = 3,
) -> Optional[dict]:
    """Measured microseconds per launch-geometry contract, or ``None``.

    Returns ``{"grid": us, "loop": us}`` at a RHS width where the two
    contracts genuinely differ.  At ``k <= LANE_TILE`` the contracts are
    the same launch, and under ``strategy="stable"`` they are the same
    chunked computation at EVERY width (bitwise invariance is that path's
    contract) — measuring would just rank noise, so both cases return
    ``None`` and the caller keeps the default.  The non-None dict is the
    provenance :func:`pick_k_tiling` decides from, recorded per plan so
    ``explain()`` can show why a geometry was served.
    """
    from repro.kernels import ops

    if k <= ops.LANE_TILE or strategy == "stable":
        return None  # the contracts are the same computation here
    return {
        kt: _measure_spmm_us(csr, cfg, k, repeats, strategy, k_tiling=kt)
        for kt in ops.K_TILINGS
    }


def pick_k_tiling(
    csr: CSRMatrix,
    cfg: PartitionConfig,
    *,
    k: int = 256,
    strategy: str = "stable",
    repeats: int = 3,
) -> str:
    """Measured per-matrix choice between the one-pass 2D k-tiled grid and
    the legacy chunk loop, at a wide RHS width where the two differ.

    Returns ``"grid"`` or ``"loop"``, whichever served the faster launch
    under this matrix's geometry (the registry's ``k_tiling="auto"`` calls
    this at admission); ``"grid"`` when :func:`measure_k_tilings`
    short-circuits because the contracts coincide.
    """
    times = measure_k_tilings(csr, cfg, k=k, strategy=strategy, repeats=repeats)
    if times is None:
        return "grid"
    return min(times, key=times.get)


def autotune_partition(
    csr: CSRMatrix,
    *,
    key: Optional[str] = None,
    cache: AutotuneCache | None = None,
    search: bool = True,
    candidates: Optional[Sequence[PartitionConfig]] = None,
    k: int = 8,
    repeats: int = 3,
    strategy: str = "stable",
    k_tiling: str = "grid",
    probe: Optional[Probe] = None,
) -> AutotuneResult:
    """Pick a :class:`PartitionConfig` for ``csr``, cheapest source first.

    1. on-disk cache hit for the matrix's content hash → no search;
    2. ``search=True`` → time every candidate (``enumerate_configs`` by
       default) and keep the fastest;
    3. ``search=False`` → the ``tuned_partition_config`` nnz-profile
       heuristic.

    Either way the chosen config is written back to the cache, so the next
    admission of the same matrix is a pure read.  Cached entries remember
    *how* they were produced: a heuristic entry satisfies only
    ``search=False`` callers, and a searched entry satisfies ``search=True``
    callers only when it covered the same candidate space (and probe
    width) — so neither a heuristic admission nor a narrow example-sized
    search can permanently pin a matrix that a full-space admission would
    have tuned better; the mismatched admission simply re-searches and
    overwrites.

    ``probe`` swaps the search objective: the default ranks candidates by
    one steady-state ``k``-wide SpMM launch under ``strategy``
    (:func:`spmm_probe`); a solver workload can rank by time-to-tolerance
    instead (:func:`cg_probe`, ``iters`` fixed CG steps).  The probe kind
    is part of the cache fingerprint, so entries tuned under different
    objectives never satisfy each other.
    """
    cache = cache or AutotuneCache()
    key = key or matrix_hash(csr)
    if probe is None:
        probe = spmm_probe(k=k, strategy=strategy, k_tiling=k_tiling)
    if search:
        # materialize once: generators must survive both the fingerprint
        # and the measurement loop
        candidates = (
            enumerate_configs(csr.shape) if candidates is None else list(candidates)
        )
    space = _space_fingerprint(candidates, k, strategy, probe) if search else None
    entry = cache.get(key)
    if entry is not None:
        satisfied = (
            (entry.get("searched") and entry.get("space") == space)
            if search
            else True
        )
        cached = cache.get_config(key)
        if satisfied and cached is not None:
            return AutotuneResult(
                cfg=cached, cache_hit=True, searched=False, evaluations=0,
                objective_us=entry.get("objective_us"),
                trials=tuple(entry.get("trials") or ()),
            )

    if not search:
        cfg = tuned_partition_config(csr)
        cache.put(key, cfg, searched=False, objective_us=None)
        return AutotuneResult(
            cfg=cfg, cache_hit=False, searched=False, evaluations=0, objective_us=None
        )

    best_cfg, best_us = None, float("inf")
    trials = []
    with obs.span(
        "serve.autotune", probe=probe.kind, candidates=len(candidates)
    ) as search_sp:
        for cand in candidates:
            with obs.span(
                "serve.autotune_trial",
                row_block=cand.row_block,
                col_block=cand.col_block,
                lane=cand.lane,
            ) as sp:
                us = probe(csr, cand, repeats)
                sp.annotate(objective_us=round(us, 1))
            trials.append(
                {"config": dataclasses.asdict(cand), "objective_us": round(us, 1)}
            )
            if us < best_us:
                best_cfg, best_us = cand, us
        search_sp.annotate(best_us=round(best_us, 1))
    trials.sort(key=lambda t: (t["objective_us"], sorted(t["config"].items())))
    if best_cfg is not None:
        # searches are rare + expensive: a flight-ring record of the winner
        # makes a later post-mortem show which geometry this plan serves
        from repro.obs.flight import get_flight

        get_flight().record(
            "serve.autotune",
            probe=probe.kind,
            candidates=len(candidates),
            best_us=round(best_us, 1),
        )
    if best_cfg is None:  # empty candidate list: fall back to the heuristic
        return autotune_partition(csr, key=key, cache=cache, search=False)
    cache.put(
        key, best_cfg, searched=True, objective_us=best_us, space=space,
        probe=probe.kind, trials=trials,
    )
    return AutotuneResult(
        cfg=best_cfg,
        cache_hit=False,
        searched=True,
        evaluations=len(candidates),
        objective_us=best_us,
        trials=tuple(trials),
    )
