"""The SpMV traffic engine: submit → coalesce → one SpMM launch → slice.

Distinct from :mod:`repro.serve` (the LLM token engine): requests here are
``y = A @ x`` against matrices resident in a :class:`MatrixRegistry`.

The engine is event-driven and single-threaded by design — `submit` never
computes, it enqueues and returns a :class:`Ticket`; work happens in
`poll` (flushes batches whose size or deadline policy fired) and `flush`
(drains unconditionally, e.g. at shutdown or when a ticket's result is
demanded).  A caller that wants wall-clock-driven service calls `poll`
from its own loop; tests and benchmarks inject a virtual ``clock`` and get
fully deterministic flush decisions.

Instrumentation is part of the contract: per matrix the engine counts
requests, batches, k-bucket occupancy and padding, p50/p99 request
latency, per-batch compute seconds, and the admission cost still
unamortized — :meth:`ServingEngine.stats` snapshots all of it.  The
backing store is the registry's shared
:class:`~repro.obs.metrics.MetricRegistry` (one ledger for admission and
traffic; ``stats()`` is a view over it), and with ``repro.obs`` enabled
the hot loop additionally emits flush spans, flush-reason counters,
queue-depth gauges and deadline-miss counts.

Three always-on layers ride the same loop regardless of the obs flag:

* every flush lands in the process **flight recorder** ring, and a
  deadline miss / latency anomaly / queue saturation triggers a
  Perfetto-loadable post-mortem dump (:mod:`repro.obs.flight`);
* per-flush **attribution counters** (``attr.launches`` /
  ``attr.bytes_modeled`` / ``attr.compute_s``, labeled by matrix,
  strategy and k_tiling) feed the achieved-vs-modeled bandwidth report
  (:mod:`repro.obs.attribution`);
* every completed request feeds the **SLO engine**, and
  :meth:`ServingEngine.health` classifies per-matrix burn rates for the
  QoS layer (:mod:`repro.obs.slo`).
"""
from __future__ import annotations

import time
from typing import Iterable, Optional

import numpy as np

from repro import obs
from repro.kernels.ops import K_BUCKETS, bucket_k, modeled_launch_bytes
from repro.obs.flight import FlightRecorder, get_flight
from repro.obs.requesttrace import RequestContext, RequestLog, get_request_log, new_context
from repro.obs.slo import SLO, SLOEngine, worst_status

from .batcher import MicroBatcher, SpMVRequest
from .registry import MatrixRegistry

__all__ = ["Ticket", "ServingEngine"]


class Ticket:
    """Handle to one submitted request; ``result()`` forces completion."""

    __slots__ = ("_engine", "_req")

    def __init__(self, engine: "ServingEngine", req: SpMVRequest):
        self._engine = engine
        self._req = req

    @property
    def req_id(self) -> int:
        return self._req.req_id

    @property
    def trace_id(self) -> Optional[str]:
        """The request's trace id — the join key into exemplars, flight
        dumps and flow events."""
        ctx = self._req.ctx
        return ctx.trace_id if ctx is not None else None

    @property
    def context(self) -> Optional[RequestContext]:
        """The live request context (stamps fill in as the request moves)."""
        return self._req.ctx

    def done(self) -> bool:
        return self._req.done

    def result(self) -> np.ndarray:
        """The request's ``y``; drains its matrix's queue if still pending."""
        if not self._req.done:
            self._engine.flush(self._req.key)
        assert self._req.result is not None
        return self._req.result

    def latency_s(self) -> float:
        if self._req.t_done is None:
            raise RuntimeError("request not completed yet")
        return self._req.t_done - self._req.t_submit


# latency percentiles are computed over a sliding window of this many most
# recent requests — a long-lived engine must not grow per-request state
_LATENCY_WINDOW = 4096

# burn-rate gauges are refreshed every this many flushed batches (health()
# and evaluate() always compute fresh — this only paces the passive gauges)
_SLO_EVAL_EVERY = 32


class ServingEngine:
    """Micro-batching SpMV server over a :class:`MatrixRegistry`.

    ``max_batch`` is clamped to the top k-bucket so a drained batch always
    fits one bucketed SpMM launch; ``clock`` supplies "now" for deadlines
    and latency accounting (inject a virtual clock for determinism —
    compute seconds are always wall time regardless).

    ``slos`` declares the objectives :meth:`health` evaluates (default: a
    99% deadline-hit-ratio SLO); ``queue_limit`` is the per-matrix pending
    depth past which the flight recorder snapshots a ``queue_saturation``
    dump (default ``4 * max_batch``); ``flight`` overrides the process
    flight recorder (tests inject their own to isolate dump artifacts).
    """

    def __init__(
        self,
        registry: MatrixRegistry,
        *,
        max_batch: int = K_BUCKETS[-1],
        max_wait_s: float = 0.002,
        buckets: tuple = K_BUCKETS,
        clock=time.perf_counter,
        slos: Optional[Iterable[SLO]] = None,
        queue_limit: Optional[int] = None,
        flight: Optional[FlightRecorder] = None,
        request_log: Optional[RequestLog] = None,
    ):
        if max_batch > buckets[-1]:
            raise ValueError(
                f"max_batch={max_batch} exceeds the top k-bucket {buckets[-1]}"
            )
        self.registry = registry
        self.batcher = MicroBatcher(max_batch=max_batch, max_wait_s=max_wait_s)
        self.buckets = tuple(buckets)
        self.clock = clock
        # one ledger with the registry: admission and traffic counters live
        # side by side, and both stats() views read the same store
        self.metrics = registry.metrics
        self.flight = flight if flight is not None else get_flight()
        # completed RequestContexts land here; the process-global log by
        # default so dump()/--requests see every engine's traffic
        self.request_log = request_log if request_log is not None else get_request_log()
        self.queue_limit = (
            queue_limit if queue_limit is not None else 4 * self.batcher.max_batch
        )
        # slo.* gauges ride the shared ledger so dump()/report() see them
        self.slo = SLOEngine(slos, metrics=self.metrics, clock=clock)
        self._next_id = 0
        self._batches = 0

    def submit(self, key: str, x) -> Ticket:
        """Enqueue ``y = A_key @ x``; returns immediately with a ticket."""
        plan = self.registry.get(key)
        x = np.asarray(x, np.float32)
        if x.shape != (plan.shape[1],):
            raise ValueError(
                f"x has shape {x.shape}, matrix {key!r} expects ({plan.shape[1]},)"
            )
        t_submit = self.clock()
        # the context is the single per-request allocation this path makes;
        # every later lifecycle stamp is a plain attribute write on it
        req = SpMVRequest(
            key=key,
            x=x,
            req_id=self._next_id,
            t_submit=t_submit,
            ctx=new_context(key, t_submit),
        )
        self._next_id += 1
        self.batcher.add(req)
        req.ctx.t_enqueue = self.clock()
        depth = self.batcher.pending(key)
        if obs.enabled():
            obs.gauge("serving.queue_depth", matrix=key).set(depth)
            # flow start: the submit end of the Perfetto submit→flush arrow
            obs.flow("request", req.ctx.trace_id, "s", matrix=key)
        # always-on saturation watch: an int compare until the queue blows
        # past the limit, then a flight-recorder post-mortem dump
        self.flight.observe_queue_depth(key, depth, self.queue_limit)
        return Ticket(self, req)

    def poll(self, now: Optional[float] = None) -> int:
        """Flush every batch whose policy fired; returns requests completed."""
        now = self.clock() if now is None else now
        served = 0
        for key in self.batcher.due(now):
            # a key can owe several full batches after a burst
            while self.batcher.pending(key) >= self.batcher.max_batch:
                served += self._run_batch(key, reason="size")
            if key in self.batcher.due(now):  # deadline still unmet
                served += self._run_batch(key, reason="deadline")
        return served

    def flush(self, key: Optional[str] = None) -> int:
        """Drain everything pending (for ``key``, or all matrices)."""
        keys = [key] if key is not None else self.batcher.keys_with_pending()
        served = 0
        for k in keys:
            while self.batcher.pending(k):
                served += self._run_batch(k, reason="drain")
        return served

    def _run_batch(self, key: str, *, reason: str = "drain") -> int:
        batch = self.batcher.take(key)
        if not batch:
            return 0
        plan = self.registry.get(key)
        t_flush = self.clock()
        for req in batch:
            if req.ctx is not None:
                req.ctx.t_flush_start = t_flush
                req.ctx.flush_reason = reason
        X = MicroBatcher.stack(batch)  # [n, k]
        k = X.shape[1]
        with obs.span("serve.flush", matrix=key, reason=reason, k=k):
            t_dispatch = self.clock()
            t0 = time.perf_counter()
            Y = np.asarray(plan.matmat(X, bucketed=True, buckets=self.buckets))
            compute_s = time.perf_counter() - t0
            if obs.enabled():
                # flow finish inside the span so bp="e" binds the arrow to
                # this flush slice — one arrow per coalesced request
                for req in batch:
                    if req.ctx is not None:
                        obs.flow("request", req.ctx.trace_id, "f", matrix=key)
        done = self.clock()
        trace_ids = [r.ctx.trace_id for r in batch if r.ctx is not None]
        # the flush lands in the always-on flight ring *before* any trigger
        # below fires, so a post-mortem dump contains the offending span
        self.flight.record(
            "serve.flush",
            t0=t0,
            dur_s=compute_s,
            matrix=key,
            reason=reason,
            k=k,
            trace_ids=trace_ids,
        )
        launched_k = bucket_k(k, self.buckets)
        m = self.metrics
        m.counter("serving.requests", matrix=key).inc(len(batch))
        m.counter("serving.batches", matrix=key).inc()
        m.counter("serving.columns", matrix=key).inc(k)
        m.counter("serving.padded_columns", matrix=key).inc(launched_k - k)
        m.counter("serving.compute_s", matrix=key).inc(compute_s)
        # bandwidth attribution: modeled bytes of the launch actually issued
        # (at the padded bucket width) joined with the measured seconds —
        # always live, labeled so attribution_rows() can group the join
        attr_labels = dict(
            matrix=key, strategy=plan.strategy, k_tiling=plan.k_tiling
        )
        m.counter("attr.launches", **attr_labels).inc()
        m.counter("attr.bytes_modeled", **attr_labels).inc(
            modeled_launch_bytes(plan.device, launched_k, plan.strategy, plan.k_tiling)
        )
        m.counter("attr.compute_s", **attr_labels).inc(compute_s)
        lat = m.histogram("serving.latency_s", window=_LATENCY_WINDOW, matrix=key)
        share = 1.0 / len(batch)
        misses = 0
        late = []  # trace ids of the requests that burned the deadline
        for j, req in enumerate(batch):
            req.result = Y[:, j]
            req.t_done = done
            wait = done - req.t_submit
            hit = wait <= self.batcher.max_wait_s
            if not hit:
                misses += 1
            ctx = req.ctx
            if ctx is not None:
                ctx.t_dispatch = t_dispatch
                ctx.t_complete = done
                ctx.compute_s = compute_s
                ctx.batch_share = share
                ctx.batch_k = k
                ctx.deadline_hit = hit
                # the trace id rides the latency histogram as the bucket
                # exemplar: a p99 outlier bucket names its request
                lat.observe(wait, exemplar=ctx.trace_id)
                self.request_log.complete(ctx)
                if not hit:
                    late.append(ctx.trace_id)
            else:
                lat.observe(wait)
            self.slo.record(key, latency_s=wait, deadline_hit=hit, now=done)
            self.flight.observe_latency(key, wait)
        if misses:
            self.flight.trigger(
                "deadline_miss",
                matrix=key,
                misses=misses,
                flush_reason=reason,
                k=k,
                trace_ids=late,
            )
        self._batches += 1
        if self._batches % _SLO_EVAL_EVERY == 0:
            self.slo.evaluate(now=done)  # refresh the passive slo.* gauges
        if obs.enabled():
            obs.counter("serving.flush", matrix=key, reason=reason).inc()
            obs.histogram("serving.batch_k", matrix=key).observe(k)
            obs.gauge("serving.queue_depth", matrix=key).set(
                self.batcher.pending(key)
            )
            if misses:
                obs.counter("serving.deadline_miss", matrix=key).inc(misses)
        return len(batch)

    def stats(self) -> dict:
        """Per-matrix traffic snapshot, joined with registry admission data.

        ``occupancy`` is real columns per batch relative to ``max_batch``
        (how full the coalescing window runs); ``pad_fraction`` is the share
        of launched bucket slots that carried padding; latency percentiles
        cover the most recent ``_LATENCY_WINDOW`` requests; ``amortized_
        preprocess_s`` is the one-time admission cost divided by requests
        served so far — the number that justifies the HBP preprocessing
        under serving traffic.

        Pure view: every number is read back from the shared
        ``MetricRegistry`` — the engine holds no counter state of its own,
        so this report and :meth:`MatrixRegistry.stats` cannot disagree.
        """
        reg = self.registry.stats()
        m = self.metrics
        out = {}
        for key in {*reg, *m.label_values("serving.requests", "matrix")}:
            requests = int(m.value("serving.requests", matrix=key))
            batches = int(m.value("serving.batches", matrix=key))
            columns = int(m.value("serving.columns", matrix=key))
            padded = int(m.value("serving.padded_columns", matrix=key))
            lat = m.get("serving.latency_s", matrix=key)
            launched = columns + padded
            out[key] = {
                **reg.get(key, {}),
                "requests": requests,
                "batches": batches,
                "mean_batch_k": columns / max(batches, 1),
                "occupancy": columns / max(batches * self.batcher.max_batch, 1),
                "pad_fraction": padded / max(launched, 1),
                "compute_s": m.value("serving.compute_s", matrix=key),
                "latency_p50_s": lat.percentile(0.50) if lat is not None else None,
                "latency_p99_s": lat.percentile(0.99) if lat is not None else None,
                "amortized_preprocess_s": (
                    reg[key]["preprocess_s"] / requests
                    if key in reg and requests
                    else None
                ),
                "pending": self.batcher.pending(key),
            }
        return out

    def health(self, now: Optional[float] = None) -> dict:
        """SLO-based health view — the signal the QoS front-end consumes.

        Per matrix: the multi-window burn-rate evaluation of every declared
        :class:`~repro.obs.slo.SLO` plus the current queue depth; overall
        ``status`` is the worst per-matrix classification (``ok`` <
        ``warn`` < ``page``).  Always fresh — this evaluates now, it does
        not read the passively-refreshed gauges.
        """
        now = self.clock() if now is None else now
        evaluation = self.slo.evaluate(now=now)
        matrices = {}
        for key in sorted(evaluation):
            slos = evaluation[key]
            matrices[key] = {
                "status": worst_status(s["status"] for s in slos.values()),
                "slos": slos,
                "queue_depth": self.batcher.pending(key),
            }
        return {
            "status": worst_status(m["status"] for m in matrices.values()),
            "matrices": matrices,
        }
