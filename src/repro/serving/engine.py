"""The SpMV traffic engine: submit → coalesce → one SpMM launch → slice.

Distinct from :mod:`repro.serve` (the LLM token engine): requests here are
``y = A @ x`` against matrices resident in a :class:`MatrixRegistry`.

The engine is event-driven and single-threaded by design — `submit` never
computes, it enqueues and returns a :class:`Ticket`; work happens in
`poll` (flushes batches whose size or deadline policy fired) and `flush`
(drains unconditionally, e.g. at shutdown or when a ticket's result is
demanded).  A caller that wants wall-clock-driven service calls `poll`
from its own loop; tests and benchmarks inject a virtual ``clock`` and get
fully deterministic flush decisions.

Two dispatch modes share that loop:

* **synchronous** (default, ``overlap=False``) — ``_run_batch`` blocks on
  the device result before completing the batch, exactly the historical
  behavior: ``poll()`` returns with every fired batch fully served.
* **overlapped** (``overlap=True``) — ``_run_batch`` only *dispatches*:
  JAX async dispatch queues the SpMM and returns immediately, the batch
  parks on an in-flight list, and the host goes straight back to
  coalescing the next bucket while the device computes this one.
  ``poll`` harvests batches whose device arrays report ready without
  blocking; :meth:`Ticket.result` (via :meth:`flush`) is the only place
  that blocks on a device array.

Multi-tenant policy rides the same loop: each matrix key maps to a
:class:`~repro.serving.qos.QoSClass` (deadline, weighted-fair share,
admission-control depth).  Submit sheds with a typed
:class:`~repro.serving.qos.BackpressureError` when a tenant's queue is
saturated, and poll flushes due tenants in weighted-fair order — the
scheduler reads the SLO burn-rate classifications and head-of-line queue
waits, so a paging tenant is boosted and a starving queue breaks ties.

Instrumentation is part of the contract: per matrix the engine counts
requests, batches, k-bucket occupancy and padding, p50/p99 request
latency, per-batch compute seconds, and the admission cost still
unamortized — :meth:`ServingEngine.stats` snapshots all of it.  The
backing store is the registry's shared
:class:`~repro.obs.metrics.MetricRegistry` (one ledger for admission and
traffic; ``stats()`` is a view over it — including the new ``qos.*``
shed/virtual-work state), and with ``repro.obs`` enabled the hot loop
additionally emits flush spans, flush-reason counters, queue-depth gauges
and deadline-miss counts.

Three always-on layers ride the same loop regardless of the obs flag:

* every flush lands in the process **flight recorder** ring, and a
  deadline miss / latency anomaly / queue saturation / load shed triggers
  a Perfetto-loadable post-mortem dump (:mod:`repro.obs.flight`);
* per-flush **attribution counters** (``attr.launches`` /
  ``attr.bytes_modeled`` / ``attr.compute_s``, labeled by matrix,
  strategy and k_tiling) feed the achieved-vs-modeled bandwidth report
  (:mod:`repro.obs.attribution`);
* every completed request feeds the **SLO engine**, and
  :meth:`ServingEngine.health` classifies per-matrix burn rates — the
  same classifications the weighted-fair scheduler consumes
  (:mod:`repro.obs.slo`).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro import obs
from repro.kernels.ops import K_BUCKETS, bucket_k, modeled_launch_bytes
from repro.obs.flight import FlightRecorder, get_flight
from repro.obs.requesttrace import RequestContext, RequestLog, get_request_log, new_context
from repro.obs.slo import SLO, SLOEngine, worst_status

from .batcher import MicroBatcher, SpMVRequest
from .qos import BackpressureError, QoSClass, WeightedFairScheduler
from .registry import MatrixRegistry

__all__ = ["Ticket", "ServingEngine"]


class Ticket:
    """Handle to one submitted request; ``result()`` forces completion."""

    __slots__ = ("_engine", "_req")

    def __init__(self, engine: "ServingEngine", req: SpMVRequest):
        """Bind the ticket to its engine and tracked request."""
        self._engine = engine
        self._req = req

    @property
    def req_id(self) -> int:
        """The engine-scoped monotonically increasing request id."""
        return self._req.req_id

    @property
    def trace_id(self) -> Optional[str]:
        """The request's trace id — the join key into exemplars, flight
        dumps and flow events."""
        ctx = self._req.ctx
        return ctx.trace_id if ctx is not None else None

    @property
    def context(self) -> Optional[RequestContext]:
        """The live request context (stamps fill in as the request moves)."""
        return self._req.ctx

    def done(self) -> bool:
        """Whether the request has completed (non-blocking)."""
        return self._req.done

    def result(self) -> np.ndarray:
        """The request's ``y``; drains its matrix's queue if still pending.

        This is the ONE engine call that blocks on device arrays: pending
        submissions for the matrix are dispatched and every in-flight
        batch of the matrix is harvested to completion.
        """
        if not self._req.done:
            self._engine.flush(self._req.key)
        assert self._req.result is not None
        return self._req.result

    def latency_s(self) -> float:
        """Submit-to-complete wall time (raises until completed)."""
        if self._req.t_done is None:
            raise RuntimeError("request not completed yet")
        return self._req.t_done - self._req.t_submit


# latency percentiles are computed over a sliding window of this many most
# recent requests — a long-lived engine must not grow per-request state
_LATENCY_WINDOW = 4096

# burn-rate gauges are refreshed every this many flushed batches (health()
# and evaluate() always compute fresh — this only paces the passive gauges)
_SLO_EVAL_EVERY = 32


class _InFlight:
    """One dispatched-but-unharvested batch (overlap mode)."""

    __slots__ = ("key", "batch", "Y", "k", "reason", "t_dispatch", "t0_wall")

    def __init__(self, key, batch, Y, k, reason, t_dispatch, t0_wall):
        """Record the dispatched batch and its launch stamps."""
        self.key = key
        self.batch = batch
        self.Y = Y  # device array, NOT materialized
        self.k = k
        self.reason = reason
        self.t_dispatch = t_dispatch  # engine clock domain
        self.t0_wall = t0_wall  # wall clock, for compute attribution


def _device_ready(y) -> bool:
    """Whether a dispatched array can be harvested without blocking."""
    is_ready = getattr(y, "is_ready", None)
    return bool(is_ready()) if callable(is_ready) else True


class ServingEngine:
    """Micro-batching SpMV server over a :class:`MatrixRegistry`.

    ``max_batch`` is clamped to the top k-bucket so a drained batch always
    fits one bucketed SpMM launch; ``clock`` supplies "now" for deadlines
    and latency accounting (inject a virtual clock for determinism —
    compute seconds are always wall time regardless).

    ``qos`` maps matrix keys to :class:`~repro.serving.qos.QoSClass`
    deadline classes; unmapped keys get ``default_qos`` (which defaults to
    a per-engine "standard" class whose deadline is ``max_wait_s`` — the
    historical deadline-hit semantics).  The class drives three things:
    the per-request deadline the SLOs account against, the weighted-fair
    flush share under contention, and the admission-control ``max_queue``
    past which :meth:`submit` sheds with a typed
    :class:`~repro.serving.qos.BackpressureError`.

    ``overlap=True`` enables asynchronous dispatch: fired batches are
    queued on the device and harvested when ready instead of blocking the
    poll loop (see the module docstring for the contract).

    ``slos`` declares the objectives :meth:`health` evaluates (default: a
    99% deadline-hit-ratio SLO); ``queue_limit`` is the per-matrix pending
    depth past which the flight recorder snapshots a ``queue_saturation``
    dump (default ``4 * max_batch``); ``flight`` overrides the process
    flight recorder (tests inject their own to isolate dump artifacts).
    """

    def __init__(
        self,
        registry: MatrixRegistry,
        *,
        max_batch: int = K_BUCKETS[-1],
        max_wait_s: float = 0.002,
        buckets: tuple = K_BUCKETS,
        clock=time.perf_counter,
        qos: Optional[Dict[str, QoSClass]] = None,
        default_qos: Optional[QoSClass] = None,
        overlap: bool = False,
        slos: Optional[Iterable[SLO]] = None,
        queue_limit: Optional[int] = None,
        flight: Optional[FlightRecorder] = None,
        request_log: Optional[RequestLog] = None,
    ):
        """Wire the engine over ``registry`` (see class docstring)."""
        if max_batch > buckets[-1]:
            raise ValueError(
                f"max_batch={max_batch} exceeds the top k-bucket {buckets[-1]}"
            )
        self.registry = registry
        self.batcher = MicroBatcher(max_batch=max_batch, max_wait_s=max_wait_s)
        self.buckets = tuple(buckets)
        self.clock = clock
        self.overlap = overlap
        # one ledger with the registry: admission and traffic counters live
        # side by side, and both stats() views read the same store
        self.metrics = registry.metrics
        self.flight = flight if flight is not None else get_flight()
        # completed RequestContexts land here; the process-global log by
        # default so dump()/--requests see every engine's traffic
        self.request_log = request_log if request_log is not None else get_request_log()
        self.queue_limit = (
            queue_limit if queue_limit is not None else 4 * self.batcher.max_batch
        )
        self.qos_map: Dict[str, QoSClass] = dict(qos or {})
        # a zero batching window (flush-immediately engines) still needs a
        # valid positive deadline; 1us preserves the historical semantics
        # under a virtual clock (zero wait is a hit either way)
        self.default_qos = (
            default_qos
            if default_qos is not None
            else QoSClass("standard", deadline_s=max(max_wait_s, 1e-6))
        )
        self.scheduler = WeightedFairScheduler(lambda key: self.qos_of(key).weight)
        # per-key SLO classification from the most recent evaluation —
        # the scheduler's boost input (refreshed every _SLO_EVAL_EVERY
        # batches and on every health() call)
        self._status: Dict[str, str] = {}
        # slo.* gauges ride the shared ledger so dump()/report() see them
        self.slo = SLOEngine(slos, metrics=self.metrics, clock=clock)
        self._inflight: deque = deque()
        self._next_id = 0
        self._batches = 0

    # --- QoS ---------------------------------------------------------------

    def qos_of(self, key: str) -> QoSClass:
        """The deadline class serving ``key`` (``default_qos`` if unmapped)."""
        return self.qos_map.get(key, self.default_qos)

    def set_qos(self, key: str, qos: QoSClass) -> None:
        """Map ``key`` to ``qos`` (takes effect on the next submit/poll)."""
        self.qos_map[key] = qos
        self.batcher.set_wait(key, qos.max_wait_s)

    # --- the serving loop --------------------------------------------------

    def submit(self, key: str, x) -> Ticket:
        """Enqueue ``y = A_key @ x``; returns immediately with a ticket.

        Raises :class:`~repro.serving.qos.BackpressureError` when the
        key's QoS class declares ``max_queue`` and the queue is already
        that deep — the request is shed *before* it holds a queue slot,
        never silently dropped after.
        """
        plan = self.registry.get(key)
        x = np.asarray(x, np.float32)
        if x.shape != (plan.shape[1],):
            raise ValueError(
                f"x has shape {x.shape}, matrix {key!r} expects ({plan.shape[1]},)"
            )
        q = self.qos_of(key)
        depth = self.batcher.pending(key)
        if q.max_queue is not None and depth >= q.max_queue:
            # typed shedding: counted on the always-live ledger, flight-
            # dumped (rate-limited, so the first shed of an overload burst
            # leaves a post-mortem), then surfaced to the caller
            self.metrics.counter("qos.shed", matrix=key, qos=q.name).inc()
            self.flight.trigger(
                "load_shed", matrix=key, qos=q.name, depth=depth, limit=q.max_queue
            )
            raise BackpressureError(key, q.name, depth, q.max_queue)
        if q.max_wait_s is not None:
            self.batcher.set_wait(key, q.max_wait_s)
        t_submit = self.clock()
        # the context is the single per-request allocation this path makes;
        # every later lifecycle stamp is a plain attribute write on it
        req = SpMVRequest(
            key=key,
            x=x,
            req_id=self._next_id,
            t_submit=t_submit,
            ctx=new_context(key, t_submit),
        )
        self._next_id += 1
        self.batcher.add(req)
        req.ctx.t_enqueue = self.clock()
        depth = self.batcher.pending(key)
        if obs.enabled():
            obs.gauge("serving.queue_depth", matrix=key).set(depth)
            # flow start: the submit end of the Perfetto submit→flush arrow
            obs.flow("request", req.ctx.trace_id, "s", matrix=key)
        # always-on saturation watch: an int compare until the queue blows
        # past the limit, then a flight-recorder post-mortem dump
        self.flight.observe_queue_depth(key, depth, self.queue_limit)
        return Ticket(self, req)

    def poll(self, now: Optional[float] = None) -> int:
        """Serve every batch whose policy fired; returns requests completed.

        Due keys flush in weighted-fair order (paging tenants boosted,
        least-served-per-weight first, head-of-line wait breaking ties).
        In overlap mode this call never blocks: batches are dispatched,
        and whatever the device has finished — from this call or earlier
        ones — is harvested and counted.
        """
        now = self.clock() if now is None else now
        served = self._harvest() if self._inflight else 0
        due = self.batcher.due(now)
        for key in self.scheduler.order(
            due,
            head_wait=lambda k: self.batcher.head_age(k, now),
            status=self._status,
        ):
            # a key can owe several full batches after a burst
            while self.batcher.pending(key) >= self.batcher.max_batch:
                served += self._run_batch(key, reason="size")
            if key in self.batcher.due(now):  # deadline still unmet
                served += self._run_batch(key, reason="deadline")
        if self._inflight:
            served += self._harvest()
        return served

    def flush(self, key: Optional[str] = None) -> int:
        """Drain everything pending (for ``key``, or all matrices).

        Blocks until the drained batches (and any earlier in-flight ones
        for the same scope) have completed — this is the blocking edge
        :meth:`Ticket.result` relies on.
        """
        keys = [key] if key is not None else self.batcher.keys_with_pending()
        served = 0
        for k in keys:
            while self.batcher.pending(k):
                served += self._run_batch(k, reason="drain")
        served += self._harvest(block=True, key=key)
        return served

    def _run_batch(self, key: str, *, reason: str = "drain") -> int:
        """Dispatch one batch for ``key``; returns requests completed now.

        Synchronous mode blocks on the device result and completes the
        batch inline (return value = batch size); overlap mode queues the
        launch, parks the batch in flight and returns 0 — completion
        happens at harvest.
        """
        batch = self.batcher.take(key)
        if not batch:
            return 0
        plan = self.registry.get(key)
        t_flush = self.clock()
        for req in batch:
            if req.ctx is not None:
                req.ctx.t_flush_start = t_flush
                req.ctx.flush_reason = reason
        X = MicroBatcher.stack(batch)  # [n, k]
        k = X.shape[1]
        sync_compute_s = None
        with obs.span("serve.flush", matrix=key, reason=reason, k=k):
            t_dispatch = self.clock()
            t0 = time.perf_counter()
            # JAX async dispatch: this enqueues the SpMM and returns; only
            # materializing the array blocks on the device
            Y = plan.matmat(X, bucketed=True, buckets=self.buckets)
            if not self.overlap:
                Y = np.asarray(Y)  # block inside the span, as before
                sync_compute_s = time.perf_counter() - t0
            if obs.enabled():
                # flow finish inside the span so bp="e" binds the arrow to
                # this flush slice — one arrow per coalesced request
                for req in batch:
                    if req.ctx is not None:
                        obs.flow("request", req.ctx.trace_id, "f", matrix=key)
        # weighted-fair accounting happens at dispatch: the device time is
        # committed now, whether or not the host has harvested it yet
        self.metrics.gauge("qos.vwork", matrix=key).set(
            self.scheduler.charge(key, k)
        )
        infl = _InFlight(key, batch, Y, k, reason, t_dispatch, t0)
        if not self.overlap:
            return self._complete(infl, compute_s=sync_compute_s)
        self._inflight.append(infl)
        self.metrics.gauge("serving.inflight").set(len(self._inflight))
        return 0

    def _harvest(
        self, *, block: bool = False, key: Optional[str] = None
    ) -> int:
        """Complete in-flight batches: all ready ones, or (``block=True``)
        every one in scope (``key=None`` means all keys).

        Returns requests completed.  The non-blocking path asks each
        device array whether it is ready (``jax.Array.is_ready``) — the
        only poll-loop interaction with in-flight results.
        """
        if not self._inflight:
            return 0
        served = 0
        keep: deque = deque()
        for infl in self._inflight:
            in_scope = key is None or infl.key == key
            if in_scope and (block or _device_ready(infl.Y)):
                served += self._complete(infl)
            else:
                keep.append(infl)
        self._inflight = keep
        self.metrics.gauge("serving.inflight").set(len(self._inflight))
        return served

    def _complete(self, infl: _InFlight, *, compute_s: Optional[float] = None) -> int:
        """Materialize one batch's results and run the completion accounting.

        ``compute_s`` is the measured blocking time in synchronous mode;
        in overlap mode it is derived here as dispatch-to-harvest wall
        time (an upper bound on device time — the host may harvest late).
        """
        Y = np.asarray(infl.Y)  # blocks iff not ready yet
        if compute_s is None:
            compute_s = time.perf_counter() - infl.t0_wall
        key, batch, reason, k = infl.key, infl.batch, infl.reason, infl.k
        plan = self.registry.get(key)
        done = self.clock()
        trace_ids = [r.ctx.trace_id for r in batch if r.ctx is not None]
        # the flush lands in the always-on flight ring *before* any trigger
        # below fires, so a post-mortem dump contains the offending span
        self.flight.record(
            "serve.flush",
            t0=infl.t0_wall,
            dur_s=compute_s,
            matrix=key,
            reason=reason,
            k=k,
            trace_ids=trace_ids,
        )
        launched_k = bucket_k(k, self.buckets)
        m = self.metrics
        m.counter("serving.requests", matrix=key).inc(len(batch))
        m.counter("serving.batches", matrix=key).inc()
        m.counter("serving.columns", matrix=key).inc(k)
        m.counter("serving.padded_columns", matrix=key).inc(launched_k - k)
        m.counter("serving.compute_s", matrix=key).inc(compute_s)
        # bandwidth attribution: modeled bytes of the launch actually issued
        # (at the padded bucket width) joined with the measured seconds —
        # always live, labeled so attribution_rows() can group the join
        attr_labels = dict(
            matrix=key, strategy=plan.strategy, k_tiling=plan.k_tiling
        )
        m.counter("attr.launches", **attr_labels).inc()
        m.counter("attr.bytes_modeled", **attr_labels).inc(
            modeled_launch_bytes(plan.device, launched_k, plan.strategy, plan.k_tiling)
        )
        m.counter("attr.compute_s", **attr_labels).inc(compute_s)
        lat = m.histogram("serving.latency_s", window=_LATENCY_WINDOW, matrix=key)
        share = 1.0 / len(batch)
        deadline_s = self.qos_of(key).deadline_s
        misses = 0
        late = []  # trace ids of the requests that burned the deadline
        for j, req in enumerate(batch):
            req.result = Y[:, j]
            req.t_done = done
            wait = done - req.t_submit
            hit = wait <= deadline_s
            if not hit:
                misses += 1
            ctx = req.ctx
            if ctx is not None:
                ctx.t_dispatch = infl.t_dispatch
                ctx.t_complete = done
                ctx.compute_s = compute_s
                ctx.batch_share = share
                ctx.batch_k = k
                ctx.deadline_hit = hit
                # the trace id rides the latency histogram as the bucket
                # exemplar: a p99 outlier bucket names its request
                lat.observe(wait, exemplar=ctx.trace_id)
                self.request_log.complete(ctx)
                if not hit:
                    late.append(ctx.trace_id)
            else:
                lat.observe(wait)
            self.slo.record(key, latency_s=wait, deadline_hit=hit, now=done)
            self.flight.observe_latency(key, wait)
        if misses:
            self.flight.trigger(
                "deadline_miss",
                matrix=key,
                misses=misses,
                flush_reason=reason,
                k=k,
                trace_ids=late,
            )
        self._batches += 1
        if self._batches % _SLO_EVAL_EVERY == 0:
            self._refresh_status(self.slo.evaluate(now=done))
        if obs.enabled():
            obs.counter("serving.flush", matrix=key, reason=reason).inc()
            obs.histogram("serving.batch_k", matrix=key).observe(k)
            obs.gauge("serving.queue_depth", matrix=key).set(
                self.batcher.pending(key)
            )
            if misses:
                obs.counter("serving.deadline_miss", matrix=key).inc(misses)
        return len(batch)

    def _refresh_status(self, evaluation: dict) -> None:
        """Fold an SLO evaluation into the scheduler's per-key status map."""
        self._status = {
            key: worst_status(s["status"] for s in slos.values())
            for key, slos in evaluation.items()
        }

    # --- views -------------------------------------------------------------

    def stats(self) -> dict:
        """Per-matrix traffic snapshot, joined with registry admission data.

        ``occupancy`` is real columns per batch relative to ``max_batch``
        (how full the coalescing window runs); ``pad_fraction`` is the share
        of launched bucket slots that carried padding; latency percentiles
        cover the most recent ``_LATENCY_WINDOW`` requests; ``amortized_
        preprocess_s`` is the one-time admission cost divided by requests
        served so far — the number that justifies the HBP preprocessing
        under serving traffic.  ``qos``/``shed`` report the key's deadline
        class and its admission-control rejections.

        Pure view: every number is read back from the shared
        ``MetricRegistry`` — the engine holds no counter state of its own,
        so this report and :meth:`MatrixRegistry.stats` cannot disagree.
        """
        reg = self.registry.stats()
        m = self.metrics
        out = {}
        for key in {*reg, *m.label_values("serving.requests", "matrix")}:
            requests = int(m.value("serving.requests", matrix=key))
            batches = int(m.value("serving.batches", matrix=key))
            columns = int(m.value("serving.columns", matrix=key))
            padded = int(m.value("serving.padded_columns", matrix=key))
            lat = m.get("serving.latency_s", matrix=key)
            launched = columns + padded
            q = self.qos_of(key)
            out[key] = {
                **reg.get(key, {}),
                "requests": requests,
                "batches": batches,
                "mean_batch_k": columns / max(batches, 1),
                "occupancy": columns / max(batches * self.batcher.max_batch, 1),
                "pad_fraction": padded / max(launched, 1),
                "compute_s": m.value("serving.compute_s", matrix=key),
                "latency_p50_s": lat.percentile(0.50) if lat is not None else None,
                "latency_p99_s": lat.percentile(0.99) if lat is not None else None,
                "amortized_preprocess_s": (
                    reg[key]["preprocess_s"] / requests
                    if key in reg and requests
                    else None
                ),
                "pending": self.batcher.pending(key),
                "qos": q.name,
                "deadline_s": q.deadline_s,
                "shed": int(m.value("qos.shed", matrix=key, qos=q.name)),
            }
        return out

    def inflight(self) -> int:
        """Dispatched-but-unharvested batches (always 0 in sync mode)."""
        return len(self._inflight)

    def health(self, now: Optional[float] = None) -> dict:
        """SLO-based health view — the signal the QoS scheduler consumes.

        Per matrix: the multi-window burn-rate evaluation of every declared
        :class:`~repro.obs.slo.SLO` plus the current queue depth and
        deadline class; overall ``status`` is the worst per-matrix
        classification (``ok`` < ``warn`` < ``page``).  Always fresh —
        this evaluates now, it does not read the passively-refreshed
        gauges — and the refreshed classifications feed the next poll's
        weighted-fair boost.
        """
        now = self.clock() if now is None else now
        evaluation = self.slo.evaluate(now=now)
        self._refresh_status(evaluation)
        matrices = {}
        for key in sorted(evaluation):
            slos = evaluation[key]
            matrices[key] = {
                "status": worst_status(s["status"] for s in slos.values()),
                "slos": slos,
                "queue_depth": self.batcher.pending(key),
                "qos": self.qos_of(key).name,
            }
        return {
            "status": worst_status(m["status"] for m in matrices.values()),
            "matrices": matrices,
        }


# typing helper referenced in docstrings; kept importable for callers
# that annotate scheduler inputs
InFlightList = List[_InFlight]
