"""The SpMV traffic engine: submit → coalesce → one SpMM launch → slice.

Distinct from :mod:`repro.serve` (the LLM token engine): requests here are
``y = A @ x`` against matrices resident in a :class:`MatrixRegistry`.

The engine is event-driven and single-threaded by design — `submit` never
computes, it enqueues and returns a :class:`Ticket`; work happens in
`poll` (flushes batches whose size or deadline policy fired) and `flush`
(drains unconditionally, e.g. at shutdown or when a ticket's result is
demanded).  A caller that wants wall-clock-driven service calls `poll`
from its own loop; tests and benchmarks inject a virtual ``clock`` and get
fully deterministic flush decisions.

Instrumentation is part of the contract: per matrix the engine counts
requests, batches, k-bucket occupancy and padding, p50/p99 request
latency, per-batch compute seconds, and the admission cost still
unamortized — :meth:`ServingEngine.stats` snapshots all of it.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, Optional

import numpy as np

from repro.kernels.ops import K_BUCKETS, bucket_k

from .batcher import MicroBatcher, SpMVRequest
from .registry import MatrixRegistry

__all__ = ["Ticket", "ServingEngine"]


class Ticket:
    """Handle to one submitted request; ``result()`` forces completion."""

    __slots__ = ("_engine", "_req")

    def __init__(self, engine: "ServingEngine", req: SpMVRequest):
        self._engine = engine
        self._req = req

    @property
    def req_id(self) -> int:
        return self._req.req_id

    def done(self) -> bool:
        return self._req.done

    def result(self) -> np.ndarray:
        """The request's ``y``; drains its matrix's queue if still pending."""
        if not self._req.done:
            self._engine.flush(self._req.key)
        assert self._req.result is not None
        return self._req.result

    def latency_s(self) -> float:
        if self._req.t_done is None:
            raise RuntimeError("request not completed yet")
        return self._req.t_done - self._req.t_submit


# latency percentiles are computed over a sliding window of this many most
# recent requests — a long-lived engine must not grow per-request state
_LATENCY_WINDOW = 4096


class _MatrixCounters:
    def __init__(self) -> None:
        self.requests = 0
        self.batches = 0
        self.columns = 0  # real RHS columns served
        self.padded_columns = 0  # bucket slots beyond the real columns
        self.compute_s = 0.0
        self.latencies: deque = deque(maxlen=_LATENCY_WINDOW)


class ServingEngine:
    """Micro-batching SpMV server over a :class:`MatrixRegistry`.

    ``max_batch`` is clamped to the top k-bucket so a drained batch always
    fits one bucketed SpMM launch; ``clock`` supplies "now" for deadlines
    and latency accounting (inject a virtual clock for determinism —
    compute seconds are always wall time regardless).
    """

    def __init__(
        self,
        registry: MatrixRegistry,
        *,
        max_batch: int = K_BUCKETS[-1],
        max_wait_s: float = 0.002,
        buckets: tuple = K_BUCKETS,
        clock=time.perf_counter,
    ):
        if max_batch > buckets[-1]:
            raise ValueError(
                f"max_batch={max_batch} exceeds the top k-bucket {buckets[-1]}"
            )
        self.registry = registry
        self.batcher = MicroBatcher(max_batch=max_batch, max_wait_s=max_wait_s)
        self.buckets = tuple(buckets)
        self.clock = clock
        self._counters: Dict[str, _MatrixCounters] = {}
        self._next_id = 0

    def submit(self, key: str, x) -> Ticket:
        """Enqueue ``y = A_key @ x``; returns immediately with a ticket."""
        plan = self.registry.get(key)
        x = np.asarray(x, np.float32)
        if x.shape != (plan.shape[1],):
            raise ValueError(
                f"x has shape {x.shape}, matrix {key!r} expects ({plan.shape[1]},)"
            )
        req = SpMVRequest(key=key, x=x, req_id=self._next_id, t_submit=self.clock())
        self._next_id += 1
        self.batcher.add(req)
        return Ticket(self, req)

    def poll(self, now: Optional[float] = None) -> int:
        """Flush every batch whose policy fired; returns requests completed."""
        now = self.clock() if now is None else now
        served = 0
        for key in self.batcher.due(now):
            # a key can owe several full batches after a burst
            while self.batcher.pending(key) >= self.batcher.max_batch:
                served += self._run_batch(key)
            if key in self.batcher.due(now):  # deadline still unmet
                served += self._run_batch(key)
        return served

    def flush(self, key: Optional[str] = None) -> int:
        """Drain everything pending (for ``key``, or all matrices)."""
        keys = [key] if key is not None else self.batcher.keys_with_pending()
        served = 0
        for k in keys:
            while self.batcher.pending(k):
                served += self._run_batch(k)
        return served

    def _run_batch(self, key: str) -> int:
        batch = self.batcher.take(key)
        if not batch:
            return 0
        plan = self.registry.get(key)
        X = MicroBatcher.stack(batch)  # [n, k]
        k = X.shape[1]
        t0 = time.perf_counter()
        Y = np.asarray(plan.matmat(X, bucketed=True, buckets=self.buckets))
        compute_s = time.perf_counter() - t0
        done = self.clock()
        ctr = self._counters.setdefault(key, _MatrixCounters())
        ctr.requests += len(batch)
        ctr.batches += 1
        ctr.columns += k
        ctr.padded_columns += bucket_k(k, self.buckets) - k
        ctr.compute_s += compute_s
        for j, req in enumerate(batch):
            req.result = Y[:, j]
            req.t_done = done
            ctr.latencies.append(done - req.t_submit)
        return len(batch)

    def stats(self) -> dict:
        """Per-matrix traffic snapshot, joined with registry admission data.

        ``occupancy`` is real columns per batch relative to ``max_batch``
        (how full the coalescing window runs); ``pad_fraction`` is the share
        of launched bucket slots that carried padding; latency percentiles
        cover the most recent ``_LATENCY_WINDOW`` requests; ``amortized_
        preprocess_s`` is the one-time admission cost divided by requests
        served so far — the number that justifies the HBP preprocessing
        under serving traffic.
        """
        reg = self.registry.stats()
        out = {}
        empty = _MatrixCounters()  # uniform schema for zero-traffic matrices
        for key in {*reg, *self._counters}:
            ctr = self._counters.get(key, empty)
            lat = np.sort(np.asarray(ctr.latencies, np.float64))
            launched = ctr.columns + ctr.padded_columns
            out[key] = {
                **reg.get(key, {}),
                "requests": ctr.requests,
                "batches": ctr.batches,
                "mean_batch_k": ctr.columns / max(ctr.batches, 1),
                "occupancy": ctr.columns
                / max(ctr.batches * self.batcher.max_batch, 1),
                "pad_fraction": ctr.padded_columns / max(launched, 1),
                "compute_s": ctr.compute_s,
                "latency_p50_s": float(lat[int(0.50 * (lat.size - 1))]) if lat.size else None,
                "latency_p99_s": float(lat[int(0.99 * (lat.size - 1))]) if lat.size else None,
                "amortized_preprocess_s": (
                    reg[key]["preprocess_s"] / ctr.requests
                    if key in reg and ctr.requests
                    else None
                ),
                "pending": self.batcher.pending(key),
            }
        return out
