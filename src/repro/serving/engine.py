"""The SpMV traffic engine: submit → coalesce → one SpMM launch → slice.

Distinct from :mod:`repro.serve` (the LLM token engine): requests here are
``y = A @ x`` against matrices resident in a :class:`MatrixRegistry`.

The engine is event-driven and single-threaded by design — `submit` never
computes, it enqueues and returns a :class:`Ticket`; work happens in
`poll` (flushes batches whose size or deadline policy fired) and `flush`
(drains unconditionally, e.g. at shutdown or when a ticket's result is
demanded).  A caller that wants wall-clock-driven service calls `poll`
from its own loop; tests and benchmarks inject a virtual ``clock`` and get
fully deterministic flush decisions.

Instrumentation is part of the contract: per matrix the engine counts
requests, batches, k-bucket occupancy and padding, p50/p99 request
latency, per-batch compute seconds, and the admission cost still
unamortized — :meth:`ServingEngine.stats` snapshots all of it.  The
backing store is the registry's shared
:class:`~repro.obs.metrics.MetricRegistry` (one ledger for admission and
traffic; ``stats()`` is a view over it), and with ``repro.obs`` enabled
the hot loop additionally emits flush spans, flush-reason counters,
queue-depth gauges and deadline-miss counts.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro import obs
from repro.kernels.ops import K_BUCKETS, bucket_k

from .batcher import MicroBatcher, SpMVRequest
from .registry import MatrixRegistry

__all__ = ["Ticket", "ServingEngine"]


class Ticket:
    """Handle to one submitted request; ``result()`` forces completion."""

    __slots__ = ("_engine", "_req")

    def __init__(self, engine: "ServingEngine", req: SpMVRequest):
        self._engine = engine
        self._req = req

    @property
    def req_id(self) -> int:
        return self._req.req_id

    def done(self) -> bool:
        return self._req.done

    def result(self) -> np.ndarray:
        """The request's ``y``; drains its matrix's queue if still pending."""
        if not self._req.done:
            self._engine.flush(self._req.key)
        assert self._req.result is not None
        return self._req.result

    def latency_s(self) -> float:
        if self._req.t_done is None:
            raise RuntimeError("request not completed yet")
        return self._req.t_done - self._req.t_submit


# latency percentiles are computed over a sliding window of this many most
# recent requests — a long-lived engine must not grow per-request state
_LATENCY_WINDOW = 4096


class ServingEngine:
    """Micro-batching SpMV server over a :class:`MatrixRegistry`.

    ``max_batch`` is clamped to the top k-bucket so a drained batch always
    fits one bucketed SpMM launch; ``clock`` supplies "now" for deadlines
    and latency accounting (inject a virtual clock for determinism —
    compute seconds are always wall time regardless).
    """

    def __init__(
        self,
        registry: MatrixRegistry,
        *,
        max_batch: int = K_BUCKETS[-1],
        max_wait_s: float = 0.002,
        buckets: tuple = K_BUCKETS,
        clock=time.perf_counter,
    ):
        if max_batch > buckets[-1]:
            raise ValueError(
                f"max_batch={max_batch} exceeds the top k-bucket {buckets[-1]}"
            )
        self.registry = registry
        self.batcher = MicroBatcher(max_batch=max_batch, max_wait_s=max_wait_s)
        self.buckets = tuple(buckets)
        self.clock = clock
        # one ledger with the registry: admission and traffic counters live
        # side by side, and both stats() views read the same store
        self.metrics = registry.metrics
        self._next_id = 0

    def submit(self, key: str, x) -> Ticket:
        """Enqueue ``y = A_key @ x``; returns immediately with a ticket."""
        plan = self.registry.get(key)
        x = np.asarray(x, np.float32)
        if x.shape != (plan.shape[1],):
            raise ValueError(
                f"x has shape {x.shape}, matrix {key!r} expects ({plan.shape[1]},)"
            )
        req = SpMVRequest(key=key, x=x, req_id=self._next_id, t_submit=self.clock())
        self._next_id += 1
        self.batcher.add(req)
        obs.gauge("serving.queue_depth", matrix=key).set(self.batcher.pending(key))
        return Ticket(self, req)

    def poll(self, now: Optional[float] = None) -> int:
        """Flush every batch whose policy fired; returns requests completed."""
        now = self.clock() if now is None else now
        served = 0
        for key in self.batcher.due(now):
            # a key can owe several full batches after a burst
            while self.batcher.pending(key) >= self.batcher.max_batch:
                served += self._run_batch(key, reason="size")
            if key in self.batcher.due(now):  # deadline still unmet
                served += self._run_batch(key, reason="deadline")
        return served

    def flush(self, key: Optional[str] = None) -> int:
        """Drain everything pending (for ``key``, or all matrices)."""
        keys = [key] if key is not None else self.batcher.keys_with_pending()
        served = 0
        for k in keys:
            while self.batcher.pending(k):
                served += self._run_batch(k, reason="drain")
        return served

    def _run_batch(self, key: str, *, reason: str = "drain") -> int:
        batch = self.batcher.take(key)
        if not batch:
            return 0
        plan = self.registry.get(key)
        X = MicroBatcher.stack(batch)  # [n, k]
        k = X.shape[1]
        with obs.span("serve.flush", matrix=key, reason=reason, k=k):
            t0 = time.perf_counter()
            Y = np.asarray(plan.matmat(X, bucketed=True, buckets=self.buckets))
            compute_s = time.perf_counter() - t0
        done = self.clock()
        m = self.metrics
        m.counter("serving.requests", matrix=key).inc(len(batch))
        m.counter("serving.batches", matrix=key).inc()
        m.counter("serving.columns", matrix=key).inc(k)
        m.counter("serving.padded_columns", matrix=key).inc(
            bucket_k(k, self.buckets) - k
        )
        m.counter("serving.compute_s", matrix=key).inc(compute_s)
        lat = m.histogram("serving.latency_s", window=_LATENCY_WINDOW, matrix=key)
        misses = 0
        for j, req in enumerate(batch):
            req.result = Y[:, j]
            req.t_done = done
            wait = done - req.t_submit
            lat.observe(wait)
            if wait > self.batcher.max_wait_s:
                misses += 1
        if obs.enabled():
            obs.counter("serving.flush", matrix=key, reason=reason).inc()
            obs.histogram("serving.batch_k", matrix=key).observe(k)
            obs.gauge("serving.queue_depth", matrix=key).set(
                self.batcher.pending(key)
            )
            if misses:
                obs.counter("serving.deadline_miss", matrix=key).inc(misses)
        return len(batch)

    def stats(self) -> dict:
        """Per-matrix traffic snapshot, joined with registry admission data.

        ``occupancy`` is real columns per batch relative to ``max_batch``
        (how full the coalescing window runs); ``pad_fraction`` is the share
        of launched bucket slots that carried padding; latency percentiles
        cover the most recent ``_LATENCY_WINDOW`` requests; ``amortized_
        preprocess_s`` is the one-time admission cost divided by requests
        served so far — the number that justifies the HBP preprocessing
        under serving traffic.

        Pure view: every number is read back from the shared
        ``MetricRegistry`` — the engine holds no counter state of its own,
        so this report and :meth:`MatrixRegistry.stats` cannot disagree.
        """
        reg = self.registry.stats()
        m = self.metrics
        out = {}
        for key in {*reg, *m.label_values("serving.requests", "matrix")}:
            requests = int(m.value("serving.requests", matrix=key))
            batches = int(m.value("serving.batches", matrix=key))
            columns = int(m.value("serving.columns", matrix=key))
            padded = int(m.value("serving.padded_columns", matrix=key))
            lat = m.get("serving.latency_s", matrix=key)
            launched = columns + padded
            out[key] = {
                **reg.get(key, {}),
                "requests": requests,
                "batches": batches,
                "mean_batch_k": columns / max(batches, 1),
                "occupancy": columns / max(batches * self.batcher.max_batch, 1),
                "pad_fraction": padded / max(launched, 1),
                "compute_s": m.value("serving.compute_s", matrix=key),
                "latency_p50_s": lat.percentile(0.50) if lat is not None else None,
                "latency_p99_s": lat.percentile(0.99) if lat is not None else None,
                "amortized_preprocess_s": (
                    reg[key]["preprocess_s"] / requests
                    if key in reg and requests
                    else None
                ),
                "pending": self.batcher.pending(key),
            }
        return out
