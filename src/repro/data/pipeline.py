"""Deterministic synthetic token pipeline.

Production framing without external datasets: batches are generated from a
counter-based RNG keyed by ``(seed, step)``, so the stream is

* **restart-exact** — resuming from a checkpoint at step k regenerates
  exactly the batches a crashed run would have seen (fault tolerance);
* **host-shardable** — each host materialises only its slice of the global
  batch (``host_slice``), matching multi-host jax.Array construction;
* **structured** — a Zipf unigram marginal plus a first-order mixing
  process, so cross-entropy has learnable structure (loss decreases) and
  examples/tests can assert real training progress.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

import jax

__all__ = ["DataConfig", "SyntheticLM", "make_global_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    # markov mixing: p(next ~ f(prev)) vs fresh zipf draw
    mix: float = 0.7


class SyntheticLM:
    """Deterministic, seekable synthetic LM token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()

    def batch_at(self, step: int, *, lo: int = 0, hi: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Global batch rows [lo, hi) for ``step`` (host slice support)."""
        cfg = self.cfg
        hi = cfg.global_batch if hi is None else hi
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, lo, hi])
        )
        n = hi - lo
        fresh = rng.choice(cfg.vocab, size=(n, cfg.seq_len), p=self._probs)
        toks = fresh.copy()
        # first-order structure: next token correlated with prev
        keep = rng.random((n, cfg.seq_len)) < cfg.mix
        shifted = (toks[:, :-1] * 31 + 7) % cfg.vocab
        toks[:, 1:] = np.where(keep[:, 1:], shifted, fresh[:, 1:])
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_global_batch(stream: SyntheticLM, step: int, mesh=None, sharding=None):
    """Materialise step's batch as (possibly sharded) jax Arrays."""
    host = stream.batch_at(step)
    if sharding is None:
        return {k: jax.numpy.asarray(v) for k, v in host.items()}
    return {
        k: jax.make_array_from_process_local_data(sharding[k], v)
        for k, v in host.items()
    }
