"""Granite-3.0 1B-A400M — fine-grained MoE, 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,  # per-expert FFN width
    vocab=49155,
    act="silu",
    rope_theta=1e4,
    moe_experts=32,
    moe_top_k=8,
    tie_embeddings=True,
    notes="32 experts top-8, d_ff=512 per expert",
))
