"""Mamba2-370M — attention-free SSM with state-space duality (SSD).
[arXiv:2405.21060; unverified]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,  # Mamba-2 blocks carry their own 2x expansion
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    notes="SSD chunked scan; O(1)-state decode -> runs long_500k",
))
