"""Nemotron-4 340B — dense GQA with squared-ReLU MLP.
[arXiv:2402.16819; unverified]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    act="relu2",  # squared ReLU, non-gated MLP
    rope_theta=1e4,
    notes="GQA kv=8, squared-ReLU; the largest dense arch in the pool",
))
