"""Mistral-NeMo 12B — dense GQA, 128k context.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,  # explicit: 5120/32=160 but NeMo pins head_dim=128
    d_ff=14336,
    vocab=131072,
    act="silu",
    rope_theta=1e6,
    notes="GQA kv=8, 128k ctx, Tekken 131k vocab",
))
