"""OLMo-1B — dense, non-parametric LayerNorm. [arXiv:2402.00838; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    act="silu",
    norm="nonparam_ln",  # OLMo: LayerNorm without learnable scale/bias
    rope_theta=1e4,
    tie_embeddings=True,
    notes="MHA (kv=16), non-parametric LN",
))
