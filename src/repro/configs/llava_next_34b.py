"""LLaVA-NeXT 34B — VLM: anyres tiling, Hermes-Yi-34B backbone.
[hf:llava-hf/llava-v1.6-mistral-7b-hf family; unverified]
The vision tower is a STUB: ``input_specs`` provides precomputed patch
embeddings (anyres tiles -> up to ``frontend_tokens`` patches) that the
model scatters at the start of the sequence."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    act="silu",
    rope_theta=5e6,
    frontend="vision",
    frontend_tokens=2880,  # anyres: 5 tiles x 576 CLIP patches
    notes="GQA kv=8; vision frontend stubbed with patch embeddings",
))
