"""SeamlessM4T-Large v2 — encoder-decoder multimodal backbone.
[arXiv:2308.11596; hf]

Per the assignment sheet the modality frontend is a STUB: ``input_specs``
provides precomputed speech-frame embeddings as the encoder input; the
listed 24L/1024d/16H/8192ff backbone is instantiated as a 24-layer encoder
plus 24-layer decoder with cross-attention."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,           # decoder depth
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    act="silu",
    rope_theta=1e4,
    frontend="audio",
    frontend_tokens=0,     # encoder consumes frame embeddings directly
    notes="enc-dec; audio frontend stubbed with frame embeddings",
))
