"""Model configuration system.

One :class:`ModelConfig` describes every architecture in the assigned pool
(dense / MoE / MLA / SSM / hybrid / enc-dec / VLM / audio).  Each
``src/repro/configs/<arch>.py`` exports ``CONFIG`` (the exact published
configuration) and the registry maps ``--arch <id>`` to it.  ``smoke()``
derives the reduced same-family configuration used by the per-arch CPU
smoke tests; the full configs are exercised only through the AOT dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "register", "get_config", "ARCHS"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default: d_model // n_heads
    act: str = "silu"  # silu (SwiGLU) | gelu | relu2 (squared ReLU, non-gated)
    norm: str = "rmsnorm"  # rmsnorm | nonparam_ln (OLMo)
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0  # shared (always-on) experts, DeepSeek-style
    moe_every: int = 1  # MoE replaces dense FFN in every k-th layer
    moe_first_dense: int = 0  # first k layers keep dense FFN (DeepSeek-V2)
    first_dense_ff: int = 0  # FFN width of those first dense layers (0 = d_ff)
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek-V2) ---
    mla_kv_lora: int = 0
    mla_rope_dim: int = 64
    # --- SSM (Mamba-2 SSD; also used by hybrid layers) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    attn_every: int = 0  # hybrid: attention replaces SSM in every k-th layer
    attn_offset: int = 0  # position of the attention layer inside the period
    # --- encoder-decoder ---
    encoder_layers: int = 0  # >0 => enc-dec; n_layers is the decoder depth
    # --- modality frontend (STUB: input_specs provide embeddings) ---
    frontend: str = "none"  # none | vision | audio
    frontend_tokens: int = 0  # patch/frame embeddings prepended to the sequence
    # --- numerics / compilation ---
    dtype: str = "bfloat16"
    scan_layers: bool = True
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def padded_heads(self) -> int:
        """Query heads padded to divide a 16-wide model axis (and stay a
        multiple of the KV-head count).  llava-next's published 56 heads
        cannot shard 16 ways — GSPMD replicates all attention activations
        (measured 63 GiB/device, memory-bound).  Padding to 64 follows
        standard Megatron practice; a converted checkpoint zero-pads
        wq/wo.  Recorded in DESIGN.md §Hardware-adaptation."""
        h, kv = self.n_heads, max(self.n_kv_heads, 1)
        if h == 0:
            return 0
        step = 16
        while step % kv:
            step += 16
        if h % 16 == 0 and h % kv == 0:
            return h
        return -(-h // step) * step

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim always
        shards over the model axis.  Unpadded odd vocabs (granite 49155,
        mamba2 50280, seamless 256206) silently fall back to REPLICATED
        logits — measured 4x 12 GiB f32 buffers on the granite train cell.
        Padded logit columns are masked to -1e30 in ``logits_apply``."""
        return -(-self.vocab // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if the arch supports O(1)-state long-context decode
        (SSM / hybrid); pure full-attention archs skip ``long_500k``."""
        return self.family in ("ssm", "hybrid")

    @property
    def layer_period(self) -> int:
        """Smallest repeating layer pattern — the scan group size."""
        period = 1
        if self.moe_experts and self.moe_every > 1:
            period = _lcm(period, self.moe_every)
        if self.attn_every > 1:
            period = _lcm(period, self.attn_every)
        return period

    def layer_kind(self, l: int) -> Tuple[str, str]:
        """(mixer, ffn) of layer ``l``.

        mixer: "attn" | "mamba";  ffn: "dense" | "moe" | "none".
        """
        if self.family == "ssm":
            mixer = "mamba"
        elif self.attn_every > 1:
            mixer = "attn" if l % self.attn_every == self.attn_offset else "mamba"
        else:
            mixer = "attn"
        if self.family == "ssm":
            ffn = "none"  # Mamba-2 blocks carry their own expansion
        elif self.moe_experts and l >= self.moe_first_dense and l % self.moe_every == (self.moe_every - 1 if self.moe_every > 1 else 0):
            ffn = "moe"
        else:
            ffn = "dense"
        return mixer, ffn

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6·N·D in §Roofline)."""
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: shared + top-k experts)."""
        return _count_params(self, active_only=True)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        period = self.layer_period
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, 2 * period),
            encoder_layers=2 if self.is_encdec else 0,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=256,
            moe_experts=min(self.moe_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            moe_shared=min(self.moe_shared, 1),
            mla_kv_lora=32 if self.mla_kv_lora else 0,
            mla_rope_dim=8 if self.mla_kv_lora else 64,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            capacity_factor=4.0,  # avoid routing drops in tiny smoke batches
            frontend_tokens=8 if self.frontend != "none" else 0,
            dtype="float32",
        )


def _lcm(a: int, b: int) -> int:
    from math import gcd

    return a * b // gcd(a, b)


def _count_params(cfg: ModelConfig, *, active_only: bool) -> int:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    hd = cfg.resolved_head_dim
    n = v * d  # embedding
    if not cfg.tie_embeddings:
        n += v * d  # output head

    def attn_params() -> int:
        if cfg.mla_kv_lora:
            r, rd = cfg.mla_kv_lora, cfg.mla_rope_dim
            p = d * cfg.n_heads * (hd + rd)  # q (nope + rope)
            p += d * (r + rd)  # kv down-projection + k rope
            p += r * cfg.n_heads * (hd + hd)  # k/v up-projections
            p += cfg.n_heads * hd * d  # out
            return p
        p = d * cfg.n_heads * hd  # q
        p += 2 * d * cfg.n_kv_heads * hd  # k, v
        p += cfg.n_heads * hd * d  # out
        return p

    def mamba_params() -> int:
        di = cfg.ssm_expand * d
        nh = di // cfg.ssm_head_dim
        p = d * (2 * di + 2 * cfg.ssm_state + nh)  # in_proj: x, z, B, C, dt
        p += di * cfg.ssm_conv  # depthwise conv
        p += nh * 2  # A_log, D
        p += di  # gate norm
        p += di * d  # out_proj
        return p

    def ffn_params(kind: str, layer: int = 10**9) -> int:
        gated = cfg.act != "relu2"
        width = f
        if kind == "dense" and cfg.first_dense_ff and layer < cfg.moe_first_dense:
            width = cfg.first_dense_ff
        per_ffn = d * width * (3 if gated else 2)
        if kind == "dense":
            return per_ffn
        total_experts = cfg.moe_experts + cfg.moe_shared
        active_experts = cfg.moe_top_k + cfg.moe_shared
        router = d * cfg.moe_experts
        if active_only:
            return router + active_experts * per_ffn
        return router + total_experts * per_ffn

    layers = 0
    for l in range(cfg.n_layers):
        mixer, ffn = cfg.layer_kind(l)
        layers += attn_params() if mixer == "attn" else mamba_params()
        if ffn != "none":
            layers += ffn_params(ffn, l)
        layers += 2 * d if cfg.norm == "rmsnorm" else 0
    if cfg.is_encdec:
        enc = cfg.encoder_layers * (attn_params() + ffn_params("dense") + 2 * d)
        cross = cfg.n_layers * attn_params()  # decoder cross-attention
        layers += enc + cross
    return n + layers


# ---------------------------------------------------------------------------
# Input shapes (assigned to every architecture in the pool)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


ARCHS: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import the configs package so every <arch>.py registers itself
    from repro import configs as _  # noqa: F401

    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
