"""DeepSeek-V2-Lite 16B — MLA (kv_lora=512) + MoE 64 routed top-6 + 2 shared.
[arXiv:2405.04434; hf]

Note: the assignment sheet lists "2 shared+160 routed top-6"; 160 routed is
the *full* V2 configuration — V2-Lite (16B, as assigned) has 64 routed
experts.  We implement the Lite configuration and record the discrepancy in
DESIGN.md."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,  # per-expert width (dense layer-0 FFN is 10944 -> see notes)
    vocab=102400,
    act="silu",
    rope_theta=1e4,
    moe_experts=64,
    moe_top_k=6,
    moe_shared=2,
    moe_first_dense=1,  # layer 0 keeps a dense FFN
    first_dense_ff=10944,
    mla_kv_lora=512,
    mla_rope_dim=64,
    notes="MLA kv_lora=512; MoE 64e top-6 + 2 shared; layer0 dense",
))
