"""Jamba-1.5-Large 398B — hybrid Mamba+attention (1:7) with MoE 16e top-2.
[arXiv:2403.19887; hf]

Layer pattern (period 8): attention at offset 4 inside every 8-layer block
(1 attention : 7 mamba), MoE replaces the dense FFN in every 2nd layer."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    act="silu",
    rope_theta=1e6,
    moe_experts=16,
    moe_top_k=2,
    moe_every=2,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=128,
    ssm_chunk=256,
    attn_every=8,
    attn_offset=4,
    notes="Mamba+attn 1:7 interleave, MoE every 2nd layer; runs long_500k",
))
