# One <arch>.py per assigned architecture. Importing this package registers
# every config in repro.configs.base.ARCHS (used by --arch lookups).
from . import (  # noqa: F401
    deepseek_v2_lite,
    granite_moe_1b,
    internlm2_20b,
    jamba_1_5_large,
    llava_next_34b,
    mamba2_370m,
    mistral_nemo_12b,
    nemotron_4_340b,
    olmo_1b,
    seamless_m4t_large,
)
from .base import ARCHS, SHAPES, ModelConfig, ShapeConfig, get_config  # noqa: F401
