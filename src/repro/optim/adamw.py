"""AdamW with optional 8-bit quantized moments.

At 340B+ parameters the f32 Adam moments (8 bytes/param) dominate HBM; the
block-quantized int8 variant (1 byte/param + one f32 scale per block of
256) brings the optimizer to 2 bytes/param — the distributed-optimization
trick that lets nemotron-4-340b and jamba-1.5-large train on a single
16 GB/chip pod.  Quantization is stochastic-rounding-free absmax per block
(m) and per block (v, with a strictly positive floor), re-quantized every
step; parameters stay bf16 with f32 update math.

The moment trees inherit the parameter PartitionSpecs, so optimizer state
is ZeRO-sharded exactly like the FSDP weights.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "opt_state_specs"]

_BLOCK = 256  # retained for reference; quantization is per-row (below)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 200
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # float32 | int8


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps, 1), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr_peak * jnp.minimum(warm, cos)


# --- int8 quantization -----------------------------------------------------
# Moments keep the PARAMETER SHAPE (int8) with one f32 absmax scale per
# trailing row.  Shape preservation is the point: the q tensor inherits the
# parameter PartitionSpec verbatim, so quantize/dequantize never reshards
# (a flattened block layout forces GSPMD into a full replicate-repartition
# of every 341B-parameter moment tensor — measured 2.5 TB of temps).


class QTensor(NamedTuple):
    q: jax.Array  # int8, parameter shape
    scale: jax.Array  # f32, shape[:-1] + (1,)


def _quantize(x: jax.Array) -> QTensor:
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale.astype(jnp.float32))


def _dequantize(t: QTensor) -> jax.Array:
    return t.q.astype(jnp.float32) * t.scale


def _wrap(x: jax.Array, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    return x.astype(jnp.float32)


def _unwrap(m, shape) -> jax.Array:
    if isinstance(m, QTensor):
        return _dequantize(m)
    return m


def init_opt_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: _wrap(jnp.zeros(p.shape, jnp.float32), cfg.state_dtype), params)
    zeros2 = jax.tree.map(lambda p: _wrap(jnp.zeros(p.shape, jnp.float32), cfg.state_dtype), params)
    return {"m": zeros, "v": zeros2, "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(params_abs, param_specs, cfg: AdamWConfig, mesh=None):
    """Moment specs.  f32 moments mirror the parameter specs (ZeRO follows
    FSDP).  int8 moments keep the parameter shape, so ``q`` reuses the
    parameter spec directly and the per-row ``scale`` drops the last dim."""
    from jax.sharding import PartitionSpec as P

    def mom(p, spec):
        if cfg.state_dtype != "int8":
            return spec
        entries = tuple(spec) + (None,) * (len(p.shape) - len(tuple(spec)))
        return QTensor(P(*entries), P(*entries[:-1], None))

    is_leaf = lambda x: isinstance(x, (P, jax.ShapeDtypeStruct))
    return {
        "m": jax.tree.map(mom, params_abs, param_specs, is_leaf=is_leaf),
        "v": jax.tree.map(mom, params_abs, param_specs, is_leaf=is_leaf),
        "step": P(),
    }


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    is_q = lambda x: isinstance(x, QTensor)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])

    def update_leaf(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m32 = _unwrap(m, p.shape) * b1 + (1 - b1) * g32
        v32 = _unwrap(v, p.shape) * b2 + (1 - b2) * g32 * g32
        upd = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        p32 = p.astype(jnp.float32) * (1.0 - lr * cfg.weight_decay) - lr * upd
        return p32.astype(p.dtype), _wrap(m32, cfg.state_dtype), _wrap(v32, cfg.state_dtype)

    # Layer-stacked giants (e.g. a 522 GB f32 view of a 96-layer MLP stack)
    # are updated with a lax.map over the stacking axis so the f32
    # update-chain transients stay per-layer sized — the optimizer would
    # otherwise dominate peak HBM at 340B+ parameters.
    _SCAN_LIMIT = 1 << 27  # elements

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        if p.ndim >= 2 and p.size > _SCAN_LIMIT:
            np_, nm, nv = jax.lax.map(lambda a: update_leaf(*a), (p, g, m, v))
        else:
            np_, nm, nv = update_leaf(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)

    metrics = {"lr": lr, "grad_norm": gnorm, "step": step}
    return (
        jax.tree.unflatten(treedef, new_p),
        {
            "m": jax.tree.unflatten(treedef, new_m),
            "v": jax.tree.unflatten(treedef, new_v),
            "step": step,
        },
        metrics,
    )
