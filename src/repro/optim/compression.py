"""Top-k gradient compression with error feedback (inter-pod link saver).

The multi-pod mesh crosses pods over DCN-class links an order of magnitude
slower than intra-pod ICI; the pod axis carries exactly one gradient
all-reduce per step.  Top-k sparsification with local error feedback
(Stich et al.; Lin et al. "Deep Gradient Compression") cuts those bytes by
``1/ratio`` while provably preserving convergence: dropped coordinates are
remembered in a residual and re-applied next step.

Usage (wraps any grad tree before the optimizer):

    comp = TopKCompressor(ratio=0.01)
    state = comp.init(params)
    grads, state = comp.round_trip(grads, state)   # compress + decompress

``round_trip`` is what a real deployment all-reduces in compressed form;
here it returns the decompressed gradients so the train step stays
mesh-agnostic (the wire-format helpers are exposed for the pod-axis
collective).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["TopKCompressor"]


class TopKCompressor:
    def __init__(self, ratio: float = 0.01, min_k: int = 16):
        if not 0 < ratio <= 1:
            raise ValueError(ratio)
        self.ratio = ratio
        self.min_k = min_k

    def init(self, params) -> Dict:
        """Error-feedback residual, one per parameter leaf."""
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def _k(self, n: int) -> int:
        return max(self.min_k, int(n * self.ratio))

    def compress(self, g: jax.Array, residual: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Returns (values, flat indices, new residual) for one leaf."""
        acc = g.astype(jnp.float32) + residual
        flat = acc.reshape(-1)
        k = self._k(flat.size)
        if k >= flat.size:
            return flat, jnp.arange(flat.size, dtype=jnp.int32), jnp.zeros_like(residual)
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        sel = flat[idx]
        new_res = flat.at[idx].set(0.0).reshape(residual.shape)
        return sel, idx.astype(jnp.int32), new_res

    def decompress(self, vals: jax.Array, idx: jax.Array, shape) -> jax.Array:
        import math

        n = math.prod(shape)
        return jnp.zeros((n,), jnp.float32).at[idx].set(vals).reshape(shape)

    def round_trip(self, grads, state):
        """Compress + decompress every leaf, carrying error feedback."""
        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = treedef.flatten_up_to(state)
        out_g, out_r = [], []
        for g, r in zip(flat_g, flat_r):
            vals, idx, new_r = self.compress(g, r)
            out_g.append(self.decompress(vals, idx, g.shape).astype(g.dtype))
            out_r.append(new_r)
        return jax.tree.unflatten(treedef, out_g), jax.tree.unflatten(treedef, out_r)

    def wire_bytes(self, grads) -> Tuple[int, int]:
        """(uncompressed bf16 bytes, compressed val+idx bytes) per step."""
        full = sum(2 * g.size for g in jax.tree.leaves(grads))
        comp = sum(
            (4 + 4) * self._k(g.size) for g in jax.tree.leaves(grads)
        )
        return full, comp
