"""Render EXPERIMENTS.md §Roofline/§Dry-run tables from the sweep JSONs.

    PYTHONPATH=src python -m repro.analysis.report [--dir experiments/dryrun]

``--obs PATH`` switches modes: re-render the text dashboard from a
``repro.obs.dump()`` snapshot instead (counters, histograms, span
aggregates, amortized-preprocess ledger)::

    PYTHONPATH=src python -m repro.analysis.report --obs obs.json

``--attribution PATH`` renders only the bandwidth-attribution join from a
snapshot: achieved vs modeled bytes per (matrix, strategy, k_tiling),
flagging plans below the modeled roofline
(:mod:`repro.obs.attribution`)::

    REPRO_OBS_DUMP=obs.json python benchmarks/bench_obs.py
    PYTHONPATH=src python -m repro.analysis.report --attribution obs.json

``--requests PATH`` renders the slowest-N request waterfall from a
snapshot's request log: per-request queue-wait vs compute-share
decomposition, trace ids included so rows join to flight dumps and
histogram exemplars (``--top`` bounds N)::

    PYTHONPATH=src python -m repro.analysis.report --requests obs.json --top 10

``--explain MATRIX`` renders the per-matrix explain report — partition
quality (tile occupancy, competitive ratio, hash-group cohesion),
autotune decision provenance, modeled-vs-measured bandwidth and the
imbalance verdict — from the ``--obs`` snapshot (default
``serve_obs.json``, the artifact ``examples/serve_spmv.py`` leaves)::

    PYTHONPATH=src python -m repro.analysis.report --explain circuit --obs serve_obs.json

``--diff A B`` compares two obs dumps (or two ``benchmarks.run --json``
artifacts) and prints the ranked culprit table
(:mod:`repro.analysis.diff`)::

    PYTHONPATH=src python -m repro.analysis.report --diff before.json after.json
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ARCH_ORDER = [
    "llava-next-34b", "olmo-1b", "mistral-nemo-12b", "internlm2-20b",
    "nemotron-4-340b", "granite-moe-1b-a400m", "deepseek-v2-lite-16b",
    "mamba2-370m", "jamba-1.5-large-398b", "seamless-m4t-large-v2",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath: Path):
    recs = {}
    for p in dirpath.glob("*.json"):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bound | t_bound | useful/HLO | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, "single"))
            if r is None:
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | MISSING |")
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | skip (full-attn @500k) |")
                continue
            if r["status"] != "ok" or "roofline" not in r:
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | {r['status']} |")
                continue
            rf = r["roofline"]
            tb = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
            ratio = r.get("model_flops_ratio") or 0
            lines.append(
                f"| {arch} | {shape} | {fmt_s(rf['t_compute_s'])} | "
                f"{fmt_s(rf['t_memory_s'])} | {fmt_s(rf['t_collective_s'])} | "
                f"{rf['bottleneck']} | {fmt_s(tb)} | {ratio:.2f} | "
                f"{'✓' if r['fits_hbm'] else 'OVER'} |"
            )
    return "\n".join(lines)


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | status | peak GiB | fits | compile s | n_micro |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("single", "multipod"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | MISSING | | | | |")
                    continue
                if r["status"] != "ok":
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | {r['status']} | | | | |"
                    )
                    continue
                m = r["memory"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | "
                    f"{m['peak_estimate_bytes']/2**30:.2f} | "
                    f"{'✓' if r['fits_hbm'] else 'OVER'} | {r['compile_s']} | "
                    f"{r.get('n_microbatch', '')} |"
                )
    return "\n".join(lines)


def fleet_stats(recs) -> str:
    ok = [r for r in recs.values() if r["status"] == "ok"]
    skipped = [r for r in recs.values() if r["status"] == "skipped"]
    err = [r for r in recs.values() if r["status"] == "error"]
    fits = [r for r in ok if r.get("fits_hbm")]
    return (
        f"cells: {len(recs)} recorded — {len(ok)} compiled ok "
        f"({len(fits)} fit in 16 GB/chip), {len(skipped)} skipped per "
        f"assignment sheet, {len(err)} errors"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument(
        "--obs",
        default=None,
        metavar="PATH",
        help="render the dashboard from a repro.obs.dump() snapshot instead",
    )
    ap.add_argument(
        "--attribution",
        default=None,
        metavar="PATH",
        help="render achieved-vs-modeled bandwidth per (matrix, strategy, "
        "k_tiling) from a repro.obs.dump() snapshot",
    )
    ap.add_argument(
        "--requests",
        default=None,
        metavar="PATH",
        help="render the slowest-N request waterfall (queue wait vs compute "
        "share, trace ids) from a repro.obs.dump() snapshot",
    )
    ap.add_argument(
        "--top",
        type=int,
        default=20,
        help="how many requests the --requests waterfall shows (default 20)",
    )
    ap.add_argument(
        "--explain",
        default=None,
        metavar="MATRIX",
        help="render the per-matrix explain report (partition quality, "
        "autotune provenance, modeled-vs-measured bandwidth, imbalance "
        "verdict) from the --obs snapshot (default serve_obs.json)",
    )
    ap.add_argument(
        "--diff",
        nargs=2,
        default=None,
        metavar=("A", "B"),
        help="differential comparison of two obs dumps or two "
        "benchmarks.run --json artifacts (ranked culprit table)",
    )
    args = ap.parse_args()
    if args.diff:
        from repro.analysis.diff import diff_artifacts, load_artifact, render_text

        a, b = args.diff
        print(
            render_text(diff_artifacts(load_artifact(a), load_artifact(b)), top=args.top),
            end="",
        )
        return
    if args.explain:
        from repro.obs.planview import explain_report

        snapshot = json.loads(Path(args.obs or "serve_obs.json").read_text())
        print(explain_report(snapshot, args.explain), end="")
        return
    if args.requests:
        from repro.obs.requesttrace import waterfall

        snapshot = json.loads(Path(args.requests).read_text())
        print(waterfall(snapshot, n=args.top))
        return
    if args.attribution:
        from repro.obs.attribution import attribution_rows, render_attribution

        snapshot = json.loads(Path(args.attribution).read_text())
        print(render_attribution(attribution_rows(snapshot)))
        return
    if args.obs:
        from repro.obs.report import render

        print(render(json.loads(Path(args.obs).read_text())))
        return
    recs = load(Path(args.dir))
    print("## §Roofline (single-pod 16×16, per-device per-step seconds)\n")
    print(roofline_table(recs))
    print("\n## §Dry-run matrix\n")
    print(fleet_stats(recs) + "\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
