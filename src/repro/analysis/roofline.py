"""Roofline terms from compiled dry-run artifacts (TPU v5e target).

Terms (per device, per step, seconds):

* compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
* memory     = HLO_bytes / HBM_bw                (819 GB/s)
* collective = wire_bytes / link_bw              (50 GB/s/link ICI)

``cost_analysis`` on this JAX build is per-device and counts every
``while`` (scan) body ONCE.  Two corrections are used and cross-checked:

1. **Unrolled extrapolation** (primary): lower the same step with 1 and 2
   unrolled layer groups; ``per_group = c(2) - c(1)``,
   ``base = c(1) - per_group``, ``total = base + n_groups·per_group``.
2. **Trip-count attribution** (cross-check + collectives): parse the
   optimized HLO text, attribute each collective to its computation, and
   weight computations by the product of enclosing whiles'
   ``known_trip_count``s.

Collective wire bytes use ring-algorithm factors on the participating
group size g: all-reduce 2·(g−1)/g·result, all-gather (g−1)/g·result,
reduce-scatter (g−1)·result (result is the scattered shard),
all-to-all (g−1)/g·result, collective-permute 1·result.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = [
    "V5E",
    "HardwareSpec",
    "parse_collective_bytes",
    "RooflineTerms",
    "roofline_from_costs",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float  # FLOP/s (bf16)
    hbm_bw: float  # B/s
    link_bw: float  # B/s per ICI link
    hbm_bytes: float


V5E = HardwareSpec("tpu_v5e", 197e12, 819e9, 50e9, 16e9)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(shape_str: str) -> float:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0.0
    dt, dims = m.groups()
    size = _DTYPE_BYTES.get(dt, 4)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return float(size * n)


def _group_size(line: str, world: int) -> int:
    # explicit groups: replica_groups={{0,1,2},{...}}
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    # iota format: replica_groups=[32,16]<=[512] -> group size = dims[-1]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return world


def _wire_bytes(kind: str, result_bytes: float, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    if kind == "collective-permute":
        return result_bytes
    return 0.0


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text, from optimized HLO."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", line)
        if m:
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def _while_edges(hlo: str) -> List[Tuple[str, Optional[int]]]:
    """(body computation name, known trip count or None) per while op."""
    out = []
    for line in hlo.splitlines():
        if " while(" not in line:
            continue
        mb = re.search(r"body=%?([\w\.\-]+)", line)
        if not mb:
            continue
        mt = re.search(r'known_trip_count[\'"]?\s*:\s*\{\s*[\'"]n[\'"]\s*:\s*[\'"](\d+)[\'"]', line)
        out.append((mb.group(1), int(mt.group(1)) if mt else None))
    return out


def parse_collective_bytes(
    hlo: str, *, world: int, default_trip: int = 1
) -> Tuple[float, Dict[str, float]]:
    """Total per-device collective wire bytes (+ per-kind breakdown).

    Collectives inside scan bodies are weighted by the enclosing whiles'
    ``known_trip_count`` (falling back to ``default_trip``); nesting
    composes multiplicatively.
    """
    comps = _split_computations(hlo)
    # weight per computation: entry-reachable while bodies get trip factors
    weights: Dict[str, float] = {name: 1.0 for name in comps}
    # build parent -> (body, trips) and propagate breadth-first
    edges: Dict[str, List[Tuple[str, int]]] = {name: [] for name in comps}
    for name, body in comps.items():
        for line in body.splitlines():
            if " while(" in line:
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                mt = re.search(
                    r'known_trip_count[\'"]?\s*:\s*\{\s*[\'"]n[\'"]\s*:\s*[\'"](\d+)[\'"]', line
                )
                trips = int(mt.group(1)) if mt else default_trip
                if mb:
                    edges[name].append((mb.group(1), trips))
                if mc:
                    edges[name].append((mc.group(1), 1))
    # propagate weights topologically (HLO call graph is acyclic)
    changed = True
    it = 0
    while changed and it < 64:
        changed = False
        it += 1
        for parent, childs in edges.items():
            for child, trips in childs:
                w = weights.get(parent, 1.0) * trips
                if child in weights and abs(weights[child] - w) > 1e-9 and w > weights[child]:
                    weights[child] = w
                    changed = True

    total = 0.0
    by_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for name, body in comps.items():
        w = weights.get(name, 1.0)
        for line in body.splitlines():
            for kind in _COLLECTIVES:
                # result-typed op: "%x = TYPE[shape] kind(" or fused start
                m = re.search(r"=\s*(?:\()?(\w+\[[\d,]*\])[^=]*\s" + kind + r"(?:-start|-done)?\(", line)
                if m and f" {kind}" in line:
                    rb = _shape_bytes(m.group(1))
                    # tuple results: sum every typed buffer in the tuple
                    tup = re.search(r"=\s*\(([^)]*)\)\s*" + kind, line)
                    if tup:
                        rb = sum(_shape_bytes(s) for s in re.findall(r"\w+\[[\d,]*\]", tup.group(1)))
                    g = _group_size(line, world)
                    wb = _wire_bytes(kind, rb, g) * w
                    total += wb
                    by_kind[kind] += wb
                    break
    return total, by_kind


@dataclasses.dataclass
class RooflineTerms:
    flops: float  # per device
    bytes: float  # per device HBM traffic
    coll_bytes: float  # per device wire bytes
    hw: HardwareSpec = V5E

    @property
    def t_compute(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.hw.link_bw

    @property
    def bottleneck(self) -> str:
        ts = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def roofline_from_costs(
    c1: Dict[str, float],
    c2: Dict[str, float],
    n_groups: int,
    coll_bytes: float,
    hw: HardwareSpec = V5E,
) -> RooflineTerms:
    """Linear extrapolation from 1-group and 2-group unrolled lowerings."""

    def extrap(key: str) -> float:
        per = c2.get(key, 0.0) - c1.get(key, 0.0)
        base = c1.get(key, 0.0) - per
        return max(base + n_groups * per, 0.0)

    return RooflineTerms(extrap("flops"), extrap("bytes accessed"), coll_bytes, hw)
