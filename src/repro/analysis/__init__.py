from .roofline import V5E, RooflineTerms, parse_collective_bytes, roofline_from_costs
