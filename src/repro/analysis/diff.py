"""Differential performance attribution between two observability artifacts.

    PYTHONPATH=src python -m repro.analysis.diff A.json B.json [--out DIFF.md]

Both arguments must be the *same kind* of artifact, either:

* two ``repro.obs.dump()`` snapshots — the diff decomposes the change
  per phase (``admit.*`` vs ``kernels.*`` vs ``serve.*``), per span name,
  per request-log aggregate (queue wait vs compute share), and per
  ``attr.*`` (matrix, strategy, k_tiling) attribution counter; or
* two ``benchmarks.run --json`` artifacts — the diff is per bench record
  (gate metric: ``min_us``, falling back to ``median_us``) with the same
  phase rollup over the ``suite/name`` prefixes.

The output is a **ranked culprit table**: time-like rows ordered by the
absolute time they added (``excess``), so "what regressed" is the first
line, not a needle in a wall of ratios.  Counter rows (launches, bytes)
never carry time units and rank below every timed row — they explain a
culprit, they are not one.  ``benchmarks/compare.py --diff-out`` uses the
markdown renderer to leave ``BENCH_diff.md`` next to a failed CI gate so
the artifact names the regressed phase without a local repro.

Everything is n/a-safe (missing sections diff to empty, zero baselines
report ``new``) and deterministically ordered.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

__all__ = [
    "load_artifact",
    "artifact_kind",
    "diff_artifacts",
    "diff_bench_records",
    "diff_obs",
    "render_text",
    "render_markdown",
    "main",
]


def load_artifact(path) -> dict:
    return json.loads(Path(path).read_text())


def artifact_kind(payload: dict) -> str:
    """``"bench"`` (benchmarks.run --json) or ``"obs"`` (obs.dump())."""
    if isinstance(payload, dict) and "benches" in payload:
        return "bench"
    if isinstance(payload, dict) and "registries" in payload:
        return "obs"
    raise ValueError(
        "unrecognized artifact: expected a benchmarks.run --json payload "
        '(has "benches") or a repro.obs.dump() snapshot (has "registries")'
    )


def _phase(name: str) -> str:
    """Phase prefix of a row name: ``admit.schedule`` -> ``admit``,
    ``preprocess/hash_group`` -> ``preprocess``."""
    for sep in ("/", "."):
        if sep in name:
            return name.split(sep, 1)[0]
    return name


def _row(name: str, a, b, unit: str, *, timed: bool) -> dict:
    """One comparison row; ``excess`` (time added, in ``unit``) only for
    timed rows — counters explain culprits, they never rank as one."""
    ratio = (b / a) if a else None
    return {
        "name": name,
        "phase": _phase(name),
        "a": a,
        "b": b,
        "unit": unit,
        "ratio": ratio,
        "excess": (b - a) if timed else None,
    }


def _rank(rows: List[dict]) -> List[dict]:
    """Ranked culprit order: timed rows by time added desc, then counters
    by ratio desc; name breaks every tie (deterministic output)."""
    return sorted(
        rows,
        key=lambda r: (
            r["excess"] is None,
            -(r["excess"] or 0.0),
            -(r["ratio"] or 0.0),
            r["name"],
        ),
    )


# --- bench artifacts ---------------------------------------------------------


def _bench_records(payload: dict) -> Dict[str, dict]:
    out: Dict[str, dict] = {}
    for rec in payload.get("benches", []):
        out[rec["name"]] = rec
    return out


def diff_bench_records(a: Dict[str, dict], b: Dict[str, dict]) -> List[dict]:
    """Per-record rows over two ``{name: record}`` maps (the shape
    ``benchmarks.compare.load_records`` produces)."""
    rows = []
    for name in sorted(set(a) & set(b)):
        ra, rb = a[name], b[name]
        metric = "min_us" if ("min_us" in ra and "min_us" in rb) else "median_us"
        va, vb = ra.get(metric), rb.get(metric)
        if va is None or vb is None:
            continue
        rows.append(_row(name, float(va), float(vb), "us", timed=True))
    return rows


# --- obs snapshots -----------------------------------------------------------


def _span_rows(a: dict, b: dict) -> List[dict]:
    sa = {s["name"]: s for s in a.get("spans") or []}
    sb = {s["name"]: s for s in b.get("spans") or []}
    return [
        _row(
            name,
            float(sa[name].get("total_ms") or 0.0),
            float(sb[name].get("total_ms") or 0.0),
            "ms",
            timed=True,
        )
        for name in sorted(set(sa) & set(sb))
    ]


def _request_rows(a: dict, b: dict) -> List[dict]:
    """Queue-wait vs compute decomposition of the request logs: mean
    seconds per completed request, as ms rows under phase ``requests``."""

    def agg(snapshot) -> Dict[str, float]:
        reqs = snapshot.get("requests") or []
        out = {}
        for field in ("queue_wait_s", "compute_share_s", "latency_s"):
            vals = [r[field] for r in reqs if r.get(field) is not None]
            if vals:
                out[field] = 1e3 * sum(vals) / len(vals)
        return out

    ra, rb = agg(a), agg(b)
    return [
        _row(f"requests.{f[: -2]}_mean", ra[f], rb[f], "ms", timed=True)
        for f in sorted(set(ra) & set(rb))
    ]


def _counter_values(snapshot: dict) -> Dict[str, float]:
    """Every counter in every registry, keyed ``name{k=v,...}`` (labels
    sorted) and summed across registries (live dumps can hold one family
    in several registries)."""
    out: Dict[str, float] = {}
    for reg in snapshot.get("registries") or []:
        for m in reg.get("metrics") or []:
            if m.get("type") != "counter" or "value" not in m:
                continue
            labels = m.get("labels") or {}
            tag = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
            key = f"{m['name']}{{{tag}}}" if tag else m["name"]
            out[key] = out.get(key, 0.0) + float(m["value"])
    return out


def _counter_rows(a: dict, b: dict) -> List[dict]:
    ca, cb = _counter_values(a), _counter_values(b)
    rows = []
    for key in sorted(set(ca) & set(cb)):
        base = key.split("{", 1)[0]
        if base.endswith("_s"):
            # seconds-valued counters (attr.compute_s, attr.modeled_s,
            # registry.preprocess_s ...) are time — they rank as culprits
            rows.append(_row(key, 1e3 * ca[key], 1e3 * cb[key], "ms", timed=True))
        else:
            rows.append(_row(key, ca[key], cb[key], "", timed=False))
    return rows


def diff_obs(a: dict, b: dict) -> List[dict]:
    return _span_rows(a, b) + _request_rows(a, b) + _counter_rows(a, b)


# --- the joined result -------------------------------------------------------


def _phase_table(rows: List[dict]) -> List[dict]:
    """Per-phase rollup of the *timed* rows (total time per phase side)."""
    agg: Dict[str, List[float]] = {}
    for r in rows:
        if r["excess"] is None:
            continue
        pa, pb = agg.setdefault(r["phase"], [0.0, 0.0])
        agg[r["phase"]] = [pa + r["a"], pb + r["b"]]
    out = []
    for phase in sorted(agg):
        pa, pb = agg[phase]
        out.append(
            {
                "phase": phase,
                "a": pa,
                "b": pb,
                "ratio": (pb / pa) if pa else None,
                "excess": pb - pa,
            }
        )
    out.sort(key=lambda r: (-(r["excess"] or 0.0), r["phase"]))
    return out


def diff_artifacts(a: dict, b: dict) -> dict:
    """Compare two same-kind artifacts; see the module docstring.

    Returns ``{"kind", "unit", "rows", "phases", "culprit"}`` with rows in
    ranked culprit order and ``culprit`` the worst *regressed* timed row
    (``None`` when nothing got slower).
    """
    ka, kb = artifact_kind(a), artifact_kind(b)
    if ka != kb:
        raise ValueError(f"cannot diff a {ka} artifact against a {kb} artifact")
    rows = (
        diff_bench_records(_bench_records(a), _bench_records(b))
        if ka == "bench"
        else diff_obs(a, b)
    )
    rows = _rank(rows)
    culprit = next(
        (r for r in rows if r["excess"] is not None and r["excess"] > 0 and r["a"]),
        None,
    )
    return {
        "kind": ka,
        "unit": "us" if ka == "bench" else "ms",
        "rows": rows,
        "phases": _phase_table(rows),
        "culprit": culprit,
    }


# --- rendering ---------------------------------------------------------------


def _fmt_ratio(r: Optional[float]) -> str:
    return "new" if r is None else f"{r:.2f}x"


def _verdict_line(result: dict) -> str:
    c = result["culprit"]
    if c is None:
        return "verdict: no timed row regressed (B <= A everywhere measured)"
    return (
        f"verdict: worst regression is {c['name']} (phase {c['phase']}): "
        f"{c['a']:.1f}{c['unit']} -> {c['b']:.1f}{c['unit']} "
        f"({_fmt_ratio(c['ratio'])}, +{c['excess']:.1f}{c['unit']})"
    )


def render_text(result: dict, *, top: int = 20) -> str:
    lines = [f"== diff ({result['kind']} artifacts) ==", _verdict_line(result)]
    if result["phases"]:
        lines.append("-- per-phase (timed rows, total) --")
        for p in result["phases"]:
            lines.append(
                f"  {p['phase']:<12} {p['a']:>12.1f} -> {p['b']:>12.1f} "
                f"{result['unit']}  ({_fmt_ratio(p['ratio'])})"
            )
    shown = result["rows"][:top]
    if shown:
        lines.append(f"-- ranked culprits (top {len(shown)} of {len(result['rows'])}) --")
        for i, r in enumerate(shown, 1):
            unit = r["unit"]
            excess = "" if r["excess"] is None else f"  +{r['excess']:.1f}{unit}"
            lines.append(
                f"  {i:>3}. {r['name']:<44} {r['a']:.1f}{unit} -> "
                f"{r['b']:.1f}{unit} ({_fmt_ratio(r['ratio'])}){excess}"
            )
    else:
        lines.append("  n/a — no comparable rows shared by the two artifacts")
    return "\n".join(lines) + "\n"


def render_markdown(result: dict, *, top: int = 20, title: str = "Performance diff") -> str:
    lines = [f"# {title}", "", _verdict_line(result), ""]
    if result["phases"]:
        lines += [
            f"## Per-phase ({result['unit']}, timed rows)",
            "",
            "| phase | A | B | ratio |",
            "|---|---|---|---|",
        ]
        for p in result["phases"]:
            lines.append(
                f"| {p['phase']} | {p['a']:.1f} | {p['b']:.1f} "
                f"| {_fmt_ratio(p['ratio'])} |"
            )
        lines.append("")
    shown = result["rows"][:top]
    if shown:
        lines += [
            f"## Ranked culprits (top {len(shown)} of {len(result['rows'])})",
            "",
            "| rank | name | phase | A | B | ratio | excess |",
            "|---|---|---|---|---|---|---|",
        ]
        for i, r in enumerate(shown, 1):
            unit = r["unit"]
            excess = "" if r["excess"] is None else f"+{r['excess']:.1f}{unit}"
            lines.append(
                f"| {i} | `{r['name']}` | {r['phase']} | {r['a']:.1f}{unit} "
                f"| {r['b']:.1f}{unit} | {_fmt_ratio(r['ratio'])} | {excess} |"
            )
    else:
        lines.append("No comparable rows shared by the two artifacts.")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("a", help="baseline artifact (obs dump or bench JSON)")
    ap.add_argument("b", help="candidate artifact of the same kind")
    ap.add_argument(
        "--out",
        default=None,
        metavar="PATH",
        help="also write the markdown culprit report here",
    )
    ap.add_argument("--top", type=int, default=20, help="rows shown (default 20)")
    args = ap.parse_args(argv)
    result = diff_artifacts(load_artifact(args.a), load_artifact(args.b))
    print(render_text(result, top=args.top), end="")
    if args.out:
        Path(args.out).write_text(
            render_markdown(
                result,
                top=args.top,
                title=f"Performance diff: {Path(args.a).name} vs {Path(args.b).name}",
            )
        )
        print(f"markdown report written to {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
