"""Bandwidth attribution: achieved vs modeled bytes per served plan.

The Gao et al. SpMV survey (PAPERS.md) identifies memory bandwidth — not
FLOPs — as the binding constraint, and the kernel layer already models
every launch's HBM traffic (:func:`repro.kernels.ops.modeled_launch_bytes`
on the stream-pass model).  This module closes the loop: the serving
engine records, per ``(matrix, strategy, k_tiling)``, the **modeled bytes**
of each flush alongside its **measured compute seconds** (the
``attr.bytes_modeled`` / ``attr.compute_s`` / ``attr.launches``
always-live counters), and :func:`attribution_rows` joins them into

    achieved B/s  =  bytes_modeled / measured_s

compared against a :class:`~repro.analysis.roofline.HardwareSpec`'s HBM
bandwidth.  A plan running far below its modeled roofline fraction is
flagged — the signal that autotune's admission-time pick no longer matches
the traffic actually served (wrong probe width, cold cache, interpret
mode, a neighbor stealing the device), and the row ``analysis/report.py
--attribution`` renders for the re-tune decision.
"""
from __future__ import annotations

from typing import List, Optional

from repro.analysis.roofline import V5E, HardwareSpec

__all__ = ["attribution_rows", "render_attribution", "report"]

# counters the serving engine records per (matrix, strategy, k_tiling)
_ATTR_COUNTERS = ("attr.launches", "attr.bytes_modeled", "attr.compute_s")


def attribution_rows(
    snapshot: dict, *, hw: HardwareSpec = V5E, flag_below: float = 0.5
) -> List[dict]:
    """Join the attr.* counters of a ``repro.obs.dump()`` snapshot into
    per-(matrix, strategy, k_tiling) achieved-vs-modeled bandwidth rows.

    ``achieved_gbps`` divides modeled bytes by measured wall seconds (so
    it is the *effective* bandwidth the modeled traffic would imply);
    ``roofline_fraction`` compares that against ``hw.hbm_bw``, and rows
    under ``flag_below`` are marked ``below_roofline`` — the autotune
    re-evaluation candidates.  Rows are sorted by key for deterministic
    artifacts.
    """
    acc: dict = {}
    for reg in snapshot.get("registries", []):
        for m in reg.get("metrics", []):
            if m.get("name") not in _ATTR_COUNTERS:
                continue
            lab = m.get("labels") or {}
            key = (
                lab.get("matrix", "?"),
                lab.get("strategy", "?"),
                lab.get("k_tiling", "?"),
            )
            d = acc.setdefault(
                key, {"launches": 0, "bytes_modeled": 0.0, "measured_s": 0.0}
            )
            if m["name"] == "attr.launches":
                d["launches"] += int(m["value"])
            elif m["name"] == "attr.bytes_modeled":
                d["bytes_modeled"] += float(m["value"])
            else:
                d["measured_s"] += float(m["value"])
    rows = []
    for (matrix, strategy, k_tiling) in sorted(acc):
        d = acc[(matrix, strategy, k_tiling)]
        sec, byts = d["measured_s"], d["bytes_modeled"]
        achieved = (byts / sec) if sec > 0 else None  # B/s
        frac = (achieved / hw.hbm_bw) if achieved is not None else None
        rows.append(
            {
                "matrix": matrix,
                "strategy": strategy,
                "k_tiling": k_tiling,
                "launches": d["launches"],
                "bytes_modeled": byts,
                "measured_s": sec,
                "modeled_s": byts / hw.hbm_bw,
                "achieved_gbps": achieved / 1e9 if achieved is not None else None,
                "roofline_fraction": frac,
                "below_roofline": (frac is not None and frac < flag_below),
            }
        )
    return rows


def render_attribution(rows: List[dict], *, hw: HardwareSpec = V5E) -> str:
    """Text table over :func:`attribution_rows` output."""
    if not rows:
        return "(no attribution counters recorded — serve traffic first)\n"
    header = [
        "matrix", "strategy", "k_tiling", "launches", "MB_modeled",
        "measured_ms", "achieved_GB/s", "roofline%", "flag",
    ]
    table = []
    for r in rows:
        table.append(
            [
                r["matrix"],
                r["strategy"],
                r["k_tiling"],
                str(r["launches"]),
                f"{r['bytes_modeled'] / 1e6:.2f}",
                f"{r['measured_s'] * 1e3:.2f}",
                "-" if r["achieved_gbps"] is None else f"{r['achieved_gbps']:.3f}",
                "-"
                if r["roofline_fraction"] is None
                else f"{100 * r['roofline_fraction']:.1f}",
                "BELOW-ROOFLINE" if r["below_roofline"] else "",
            ]
        )
    widths = [max(len(h), *(len(row[i]) for row in table)) for i, h in enumerate(header)]
    lines = [f"== bandwidth attribution (vs {hw.name} @ {hw.hbm_bw / 1e9:.0f} GB/s) =="]
    lines.append("  ".join(h.ljust(w) for h, w in zip(header, widths)).rstrip())
    for row in table:
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip())
    flagged = [r for r in rows if r["below_roofline"]]
    if flagged:
        lines.append(
            f"!! {len(flagged)} plan(s) below the modeled-roofline threshold — "
            "re-evaluate their autotuned configs"
        )
    return "\n".join(lines) + "\n"


def report(snapshot: Optional[dict] = None, *, hw: HardwareSpec = V5E) -> str:
    """Live convenience: render attribution over the current process state
    (or a provided snapshot)."""
    if snapshot is None:
        from repro import obs

        snapshot = obs.collect()
    return render_attribution(attribution_rows(snapshot, hw=hw), hw=hw)
