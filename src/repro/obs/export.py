"""Telemetry egress: OpenMetrics exposition + scrape endpoint + snapshots.

Everything the obs stack records was, until now, reachable only as JSON
files on disk — no scrape-based monitoring stack (Prometheus, Grafana
agent, OpenTelemetry collectors) could consume it.  This module renders
any set of :class:`~repro.obs.metrics.MetricRegistry` instances to
`OpenMetrics <https://openmetrics.io>`_ text:

* **counters** become ``<name>_total`` samples, **gauges** plain samples,
  **histograms** cumulative ``_bucket{le=...}`` series plus ``_count`` /
  ``_sum`` — with per-bucket **exemplars** (``# {trace_id="..."} v``)
  linking outlier buckets straight to request traces; **series** export
  their last value as a ``<name>_last`` gauge (iteration streams have no
  OpenMetrics type);
* registries are **merged**: the same (name, labels) series appearing in
  several live registries (e.g. two serving ``MatrixRegistry`` ledgers)
  sums counters/histograms and last-write-wins gauges, so the exposition
  never emits duplicate series — the aggregate matches what
  ``repro.obs.dump()`` reports;
* metric/label names are sanitized to the OpenMetrics grammar
  (``serving.latency_s`` → ``serving_latency_s``), label values escaped.

Egress paths:

* :func:`serve` — a stdlib ``http.server`` scrape endpoint
  (``repro.obs.export.serve(port)``; ``GET /metrics`` renders live state
  per scrape);
* :func:`write_prom` / :class:`FileExporter` — one-shot and periodic
  atomic file snapshots for air-gapped runs (point a node-exporter
  textfile collector at the output);
* :func:`parse_openmetrics` — a strict-enough parser used by tests and
  the CI scrape smoke to validate that the exposition actually parses.
"""
from __future__ import annotations

import math
import os
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    Series,
    all_registries,
)

__all__ = [
    "CONTENT_TYPE",
    "render_openmetrics",
    "write_prom",
    "parse_openmetrics",
    "serve",
    "MetricsServer",
    "FileExporter",
]

# the content type Prometheus negotiates for OpenMetrics 1.0
CONTENT_TYPE = "application/openmetrics-text; version=1.0.0; charset=utf-8"

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_LABEL_RE = re.compile(r"[^a-zA-Z0-9_]")


def _family_name(name: str) -> str:
    out = _NAME_RE.sub("_", name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _label_name(name: str) -> str:
    out = _LABEL_RE.sub("_", name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_val(v: float) -> str:
    v = float(v)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def _labels_str(labels: Dict[str, str], extra: Optional[List[Tuple[str, str]]] = None) -> str:
    pairs = [(_label_name(k), str(v)) for k, v in sorted(labels.items())]
    if extra:
        pairs += extra
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{_escape(v)}"' for k, v in pairs) + "}"


# --- collection: merge live registries into exposition families --------------


class _HistState:
    """Mergeable histogram accumulator (bounds must agree to merge)."""

    __slots__ = ("bounds", "counts", "count", "total", "exemplars")

    def __init__(self, h: Histogram):
        with h._lock:
            self.counts = h.bucket_counts.copy()
            self.count = h.count
            self.total = h.total
        self.bounds = h.bounds
        self.exemplars = {e["le"]: e for e in h.exemplars()}

    def merge(self, h: Histogram) -> bool:
        if not np.array_equal(self.bounds, h.bounds):
            return False
        with h._lock:
            self.counts = self.counts + h.bucket_counts
            self.count += h.count
            self.total += h.total
        for e in h.exemplars():  # later registries win per bucket
            self.exemplars[e["le"]] = e
        return True


def _collect_families(registries: Iterable[MetricRegistry]) -> Tuple[dict, int]:
    """Merge every metric into ``{family: {"type", "samples"}}``.

    ``samples`` maps a sorted-label key to the merged sample state;
    returns the family dict plus a count of metrics dropped because they
    could not merge (type conflict across registries, histogram bucket
    bounds mismatch) — surfaced as ``repro_export_dropped`` in the
    exposition so silent loss is visible to the scraper.
    """
    families: Dict[str, dict] = {}
    dropped = 0
    for reg in registries:
        for m in reg.metrics():
            if isinstance(m, Counter):
                kind = "counter"
            elif isinstance(m, Gauge):
                kind = "gauge"
            elif isinstance(m, Histogram):
                kind = "histogram"
            elif isinstance(m, Series):
                kind = "gauge"
            else:  # pragma: no cover - no other metric types exist
                continue
            fam = _family_name(m.name + ("_last" if isinstance(m, Series) else ""))
            f = families.setdefault(fam, {"type": kind, "samples": {}})
            if f["type"] != kind:
                dropped += 1
                continue
            lk = tuple(sorted((str(k), str(v)) for k, v in m.labels.items()))
            samples = f["samples"]
            if isinstance(m, Counter):
                samples[lk] = samples.get(lk, 0.0) + m.value
            elif isinstance(m, Gauge):
                samples[lk] = m.value
            elif isinstance(m, Series):
                pts = m.points
                if pts:
                    samples[lk] = pts[-1][1]
            else:
                st = samples.get(lk)
                if st is None:
                    samples[lk] = _HistState(m)
                elif not st.merge(m):
                    dropped += 1
    return families, dropped


def render_openmetrics(registries: Optional[Iterable[MetricRegistry]] = None) -> str:
    """Render ``registries`` (default: every live one) as OpenMetrics text.

    Deterministic: families sorted by name, samples by label key — two
    renders of the same state are byte-identical, so CI artifacts diff
    cleanly.
    """
    regs = all_registries() if registries is None else list(registries)
    families, dropped = _collect_families(regs)
    if dropped:
        families.setdefault(
            "repro_export_dropped", {"type": "gauge", "samples": {(): float(dropped)}}
        )
    lines: List[str] = []
    for fam in sorted(families):
        f = families[fam]
        samples = f["samples"]
        if not samples:
            continue
        lines.append(f"# TYPE {fam} {f['type']}")
        for lk in sorted(samples):
            labels = dict(lk)
            st = samples[lk]
            if f["type"] == "counter":
                lines.append(f"{fam}_total{_labels_str(labels)} {_fmt_val(st)}")
            elif f["type"] == "gauge":
                lines.append(f"{fam}{_labels_str(labels)} {_fmt_val(st)}")
            else:  # histogram
                cum = 0
                n_bounds = st.bounds.size
                for i in range(n_bounds + 1):
                    c = int(st.counts[i])
                    cum += c
                    le = float(st.bounds[i]) if i < n_bounds else math.inf
                    ex = st.exemplars.get(le)
                    last = i == n_bounds
                    # sparse exposition: only buckets where the cumulative
                    # count moves, plus exemplar carriers and +Inf (legal —
                    # le values are an arbitrary ascending subset)
                    if c == 0 and ex is None and not last:
                        continue
                    le_str = "+Inf" if last else _fmt_val(le)
                    line = (
                        f"{fam}_bucket"
                        f"{_labels_str(labels, extra=[('le', le_str)])} {cum}"
                    )
                    if ex is not None:
                        line += (
                            f' # {{trace_id="{_escape(ex["trace_id"])}"}}'
                            f" {_fmt_val(ex['value'])}"
                        )
                    lines.append(line)
                lines.append(f"{fam}_count{_labels_str(labels)} {st.count}")
                lines.append(f"{fam}_sum{_labels_str(labels)} {_fmt_val(st.total)}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def write_prom(path, registries: Optional[Iterable[MetricRegistry]] = None) -> str:
    """Atomically write the exposition to ``path``; returns the text.

    Write-then-rename so a scraper of the file (node-exporter textfile
    collector) never reads a torn snapshot.
    """
    text = render_openmetrics(registries)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return text


# --- the scrape endpoint -----------------------------------------------------


class MetricsServer:
    """Stdlib HTTP scrape endpoint serving live OpenMetrics text.

    ``GET /metrics`` (or ``/``) renders the registries at scrape time —
    every scrape sees current state, no background sampling thread.  The
    server runs on a daemon thread; :meth:`close` shuts it down.  Usable
    as a context manager.
    """

    def __init__(
        self,
        port: int = 0,
        addr: str = "127.0.0.1",
        registries: Optional[Iterable[MetricRegistry]] = None,
    ):
        regs = None if registries is None else list(registries)

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (stdlib handler contract)
                if self.path.split("?")[0] not in ("/", "/metrics"):
                    self.send_error(404, "scrape /metrics")
                    return
                body = render_openmetrics(regs).encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # silence per-scrape stderr noise
                pass

        self._httpd = ThreadingHTTPServer((addr, port), _Handler)
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-metrics", daemon=True
        )
        self._thread.start()

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False


def serve(
    port: int = 0,
    addr: str = "127.0.0.1",
    registries: Optional[Iterable[MetricRegistry]] = None,
) -> MetricsServer:
    """Start the scrape endpoint; returns the running :class:`MetricsServer`.

    ``port=0`` binds an ephemeral port (read it back from ``.port``) —
    the test/CI-friendly default; a deployment passes its scrape port.
    """
    return MetricsServer(port=port, addr=addr, registries=registries)


# --- periodic file snapshots (air-gapped mode) -------------------------------


class FileExporter:
    """Write the exposition to a file every ``interval_s`` seconds.

    The air-gapped complement to :func:`serve`: no listener, just an
    atomically-replaced ``metrics.prom`` a sidecar can ship.  Writes once
    immediately on start; :meth:`stop` writes a final snapshot and joins
    the thread.
    """

    def __init__(
        self,
        path,
        interval_s: float = 30.0,
        registries: Optional[Iterable[MetricRegistry]] = None,
    ):
        self.path = path
        self.interval_s = interval_s
        self._registries = None if registries is None else list(registries)
        self._stop = threading.Event()
        write_prom(path, self._registries)
        self.writes = 1
        self._thread = threading.Thread(
            target=self._loop, name="repro-metrics-file", daemon=True
        )
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            write_prom(self.path, self._registries)
            self.writes += 1

    def stop(self) -> None:
        """Final snapshot + shutdown (idempotent)."""
        if not self._stop.is_set():
            self._stop.set()
            self._thread.join(timeout=5.0)
            write_prom(self.path, self._registries)
            self.writes += 1

    def __enter__(self) -> "FileExporter":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop()
        return False


# --- validation parser -------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^}]*\})?"
    r"\s+(?P<value>\S+)"
    r"(?:\s+(?P<ts>[0-9.eE+-]+))?$"
)
_LABEL_PAIR_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
_SUFFIXES = {
    "counter": ("_total",),
    "gauge": ("",),
    "histogram": ("_bucket", "_count", "_sum"),
}


_UNESCAPE_RE = re.compile(r'\\(.)')
_UNESCAPE_MAP = {'"': '"', "\\": "\\", "n": "\n"}


def _unescape(s: str) -> str:
    # single pass: sequential str.replace would re-interpret the 'n' after
    # an escaped backslash ("\\n" in the text is backslash + literal n)
    return _UNESCAPE_RE.sub(lambda m: _UNESCAPE_MAP.get(m.group(1), m.group(1)), s)


def _parse_labels(block: Optional[str]) -> Dict[str, str]:
    if not block:
        return {}
    return {k: _unescape(v) for k, v in _LABEL_PAIR_RE.findall(block)}


def _parse_value(s: str) -> float:
    if s == "+Inf":
        return math.inf
    if s == "-Inf":
        return -math.inf
    return float(s)  # raises ValueError on garbage — that's the validation


def parse_openmetrics(text: str) -> Dict[str, dict]:
    """Parse (and thereby validate) OpenMetrics text.

    Returns ``{family: {"type": t, "samples": [{"name", "labels",
    "value", "exemplar"}]}}``.  Raises :class:`ValueError` on structural
    violations: missing ``# EOF``, samples outside a ``# TYPE`` family,
    suffixes illegal for the type, non-monotone histogram buckets, or a
    histogram without a ``+Inf`` bucket.  Deliberately strict — this is
    the CI gate that the exposition a real Prometheus would scrape
    actually parses.
    """
    lines = text.splitlines()
    if not lines or lines[-1] != "# EOF":
        raise ValueError("exposition must end with '# EOF'")
    families: Dict[str, dict] = {}
    current: Optional[str] = None
    for ln, raw in enumerate(lines[:-1], start=1):
        if not raw.strip():
            raise ValueError(f"line {ln}: blank lines are not allowed")
        if raw.startswith("#"):
            parts = raw.split()
            if len(parts) >= 2 and parts[1] in ("TYPE", "HELP", "UNIT"):
                if parts[1] == "TYPE":
                    if len(parts) != 4:
                        raise ValueError(f"line {ln}: malformed TYPE: {raw!r}")
                    _, _, fam, kind = parts
                    if kind not in _SUFFIXES:
                        raise ValueError(f"line {ln}: unknown type {kind!r}")
                    if fam in families:
                        raise ValueError(f"line {ln}: duplicate family {fam!r}")
                    families[fam] = {"type": kind, "samples": []}
                    current = fam
                continue
            raise ValueError(f"line {ln}: stray comment: {raw!r}")
        sample, exemplar = raw, None
        if " # " in raw:
            sample, ex_part = raw.split(" # ", 1)
            m = re.match(r"^(\{[^}]*\})\s+(\S+)(?:\s+(\S+))?$", ex_part)
            if m is None:
                raise ValueError(f"line {ln}: malformed exemplar: {ex_part!r}")
            exemplar = {
                "labels": _parse_labels(m.group(1)),
                "value": _parse_value(m.group(2)),
            }
        m = _SAMPLE_RE.match(sample.rstrip())
        if m is None:
            raise ValueError(f"line {ln}: malformed sample: {raw!r}")
        name = m.group("name")
        if current is None:
            raise ValueError(f"line {ln}: sample {name!r} outside any TYPE family")
        fam, kind = current, families[current]["type"]
        suffixes = _SUFFIXES[kind]
        if not any(name == fam + s for s in suffixes):
            raise ValueError(
                f"line {ln}: sample {name!r} does not belong to family "
                f"{fam!r} (type {kind})"
            )
        if exemplar is not None and not (
            kind == "histogram" and name == fam + "_bucket"
        ):
            raise ValueError(f"line {ln}: exemplar on a non-bucket sample")
        families[fam]["samples"].append(
            {
                "name": name,
                "labels": _parse_labels(m.group("labels")),
                "value": _parse_value(m.group("value")),
                "exemplar": exemplar,
            }
        )
    for fam, f in families.items():
        if f["type"] != "histogram":
            continue
        series: Dict[tuple, list] = {}
        for s in f["samples"]:
            if s["name"] != fam + "_bucket":
                continue
            lk = tuple(sorted((k, v) for k, v in s["labels"].items() if k != "le"))
            series.setdefault(lk, []).append(s)
        for lk, buckets in series.items():
            les = [_parse_value(s["labels"]["le"]) for s in buckets]
            counts = [s["value"] for s in buckets]
            if les != sorted(les):
                raise ValueError(f"{fam}{dict(lk)}: bucket le values not ascending")
            if counts != sorted(counts):
                raise ValueError(f"{fam}{dict(lk)}: bucket counts not cumulative")
            if not les or not math.isinf(les[-1]):
                raise ValueError(f"{fam}{dict(lk)}: missing le=\"+Inf\" bucket")
    return families
