"""Unified observability: spans, metrics, kernel-traffic counters.

The subsystem the rest of the library reports into — admission stages,
the serving hot loop, kernel launches, solver iterations and training
steps all emit through this one surface, and the paper's amortization
ledger (preprocessing cost vs traffic served) falls out of its counters.

**Off by default.**  ``enable()`` (or ``REPRO_OBS=1`` in the environment)
turns it on; while disabled, :func:`span`, :func:`counter`,
:func:`gauge`, :func:`histogram` and :func:`series` all return one shared
no-op object whose methods do nothing — a hot call site pays a module
attribute read and a falsy check, nothing allocates, nothing locks.
Call sites that want even that gone guard with ``if obs.enabled():``.

Two kinds of state:

* **gated instrumentation** — spans and the convenience metric
  constructors here write to the process-global tracer/registry only
  while enabled (kernel launch counters, admission stage timings, solver
  residual streams);
* **always-live metrics** — subsystems that *own* bookkeeping (the
  serving :class:`~repro.serving.registry.MatrixRegistry` and engines
  backing their ``stats()`` views) hold :class:`MetricRegistry` instances
  directly; those count regardless of the enable flag, exactly as their
  pre-obs dict counters did, and aggregate into :func:`dump` /
  :func:`report` through :func:`repro.obs.metrics.all_registries`.

Artifacts: :func:`write_trace` emits Chrome-trace JSON (load it at
https://ui.perfetto.dev), :func:`write_events` the same events as JSONL,
:func:`dump` the full metrics+span snapshot, :func:`report` the text
dashboard.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from .metrics import (  # noqa: F401  (re-exported surface)
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    Series,
    all_registries,
    default_buckets,
    get_registry,
)
from .flight import FlightRecorder, get_flight  # noqa: F401
from .slo import SLO, SLOEngine, worst_status  # noqa: F401
from .trace import Span, Tracer, get_tracer  # noqa: F401
from .requesttrace import (  # noqa: F401
    RequestContext,
    RequestLog,
    get_request_log,
    mint_trace_id,
    new_context,
    waterfall,
)
from . import export  # noqa: F401  (repro.obs.export.serve(port) is the API)

__all__ = [
    "enabled",
    "enable",
    "disable",
    "span",
    "flow",
    "counter",
    "gauge",
    "histogram",
    "series",
    "registry",
    "tracer",
    "flight",
    "request_log",
    "collect",
    "report",
    "dump",
    "write_trace",
    "write_events",
    "reset",
    "NOOP",
    "Counter",
    "Gauge",
    "Histogram",
    "Series",
    "MetricRegistry",
    "Span",
    "Tracer",
    "FlightRecorder",
    "RequestContext",
    "RequestLog",
    "SLO",
    "SLOEngine",
    "worst_status",
    "get_registry",
    "get_flight",
    "get_request_log",
    "mint_trace_id",
    "new_context",
    "waterfall",
    "all_registries",
    "default_buckets",
    "export",
]


class _Noop:
    """The disabled path: one shared instance, every method a no-op.

    Duck-types every metric and the span context manager, so call sites
    never branch on the enable flag themselves.
    """

    __slots__ = ()

    def inc(self, n=1.0):
        pass

    def dec(self, n=1.0):
        pass

    def set(self, v):
        pass

    def observe(self, v, exemplar=None):
        pass

    def append(self, value, index=None):
        pass

    def extend(self, values):
        pass

    def annotate(self, **kw):
        return self

    def sync(self, value):
        return value

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def __bool__(self):
        return False


NOOP = _Noop()

_enabled = False


def enabled() -> bool:
    """Whether gated instrumentation is recording."""
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


# --- gated constructors (no-op while disabled) ------------------------------


def span(name: str, **args):
    """Timed scope context manager (no-op while disabled)::

        with obs.span("admit.build_tiles", matrix=name) as sp:
            tiles = build(...)
            sp.annotate(tiles=tiles.n_tiles)
    """
    return get_tracer().span(name, **args) if _enabled else NOOP


def flow(name: str, fid: str, phase: str = "s", **args) -> None:
    """Emit one Chrome-trace flow event (no-op while disabled).

    ``phase`` is ``"s"`` (start), ``"t"`` (step) or ``"f"`` (finish,
    binding to the enclosing slice); ``fid`` — the request trace id —
    joins both ends of the Perfetto arrow.
    """
    if _enabled:
        get_tracer().flow(name, fid, phase, **args)


def counter(name: str, **labels):
    return get_registry().counter(name, **labels) if _enabled else NOOP


def gauge(name: str, **labels):
    return get_registry().gauge(name, **labels) if _enabled else NOOP


def histogram(name: str, **labels):
    return get_registry().histogram(name, **labels) if _enabled else NOOP


def series(name: str, **labels):
    return get_registry().series(name, **labels) if _enabled else NOOP


# --- aggregation / artifacts ------------------------------------------------


def registry() -> MetricRegistry:
    """The process-global metric registry (live even while disabled)."""
    return get_registry()


def tracer() -> Tracer:
    """The process-global span tracer."""
    return get_tracer()


def flight() -> FlightRecorder:
    """The process-global flight recorder (always on, bounded ring)."""
    return get_flight()


def request_log() -> RequestLog:
    """The process-global request log (always on, bounded window)."""
    return get_request_log()


def collect() -> dict:
    """One snapshot of everything: all live registries + span summary."""
    t = get_tracer()
    return {
        "schema": 1,
        "enabled": _enabled,
        "registries": [r.collect() for r in all_registries()],
        "spans": t.summary(),
        "n_events": len(t.events),
        "dropped_events": t.dropped,
        "flight": get_flight().stats(),
        "requests": get_request_log().snapshot(),
    }


def report() -> str:
    """The text dashboard over the live process state."""
    from .report import render

    return render(collect())


def dump(path) -> dict:
    """Write the full metrics+span snapshot as JSON; returns the snapshot.

    This is the artifact ``python -m repro.analysis.report --obs PATH``
    re-renders — counters (registry hits/misses, kernel traffic), bucket
    occupancy histograms, solver/training series, span aggregates, and
    the per-matrix amortized-preprocess ledger derived from them.
    """
    snap = collect()
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True, default=str)
    return snap


def write_trace(path) -> None:
    """Write the Chrome-trace JSON (opens in Perfetto / chrome://tracing)."""
    get_tracer().write_chrome(path)


def write_events(path) -> None:
    """Write the span events as JSONL (one event object per line)."""
    get_tracer().write_jsonl(path)


def reset() -> None:
    """Clear the global registry, tracer, flight ring and request log
    (test isolation)."""
    get_registry().reset()
    get_tracer().clear()
    get_flight().reset()
    get_request_log().clear()


def _env_truthy(v: Optional[str]) -> bool:
    return v is not None and v.strip().lower() not in ("", "0", "false", "no", "off")


if _env_truthy(os.environ.get("REPRO_OBS")):
    enable()
