"""Request-scoped tracing: one context per submitted request, always on.

The aggregate telemetry (histograms, burn rates, flight rings) answers
"how is the fleet doing?"; this module answers the per-request question a
QoS front-end has to ask: *for this specific request, how much of its
latency was queue wait vs device compute, and which requests burned the
SLO?*  Every :meth:`~repro.serving.engine.ServingEngine.submit` mints a
:class:`RequestContext` — a trace id plus monotonic stamps at submit →
enqueue → flush-start → dispatch → complete — that rides the request's
:class:`~repro.serving.batcher.SpMVRequest` through the batcher and the
flush, and is pushed into the bounded process :class:`RequestLog` on
completion.

Cost contract: this path is always live, so it must stay at
flight-recorder overhead — the context object (one ``__slots__``
instance + one short trace-id string) is the *only* per-request
allocation; stamps are plain float attribute writes, and completion is a
single bounded-deque append.  Everything derived (queue/compute
decomposition, dict rendering) happens at snapshot time, not on the hot
path.

The trace id is the join key across the whole stack: it lands as the
**exemplar** on the ``serving.latency_s`` histogram buckets
(:meth:`repro.obs.metrics.Histogram.observe`), in the flight-recorder
ring events and ``deadline_miss`` trigger context
(:mod:`repro.obs.flight`), and as Chrome-trace **flow events** in the
gated tracer (:meth:`repro.obs.trace.Tracer.flow`) so Perfetto draws the
submit→flush arrow.  ``python -m repro.analysis.report --requests DUMP``
renders the slowest-N waterfall from a ``repro.obs.dump()`` snapshot.
"""
from __future__ import annotations

import itertools
import os
import threading
from collections import deque
from typing import List, Optional, Union

__all__ = [
    "RequestContext",
    "RequestLog",
    "mint_trace_id",
    "new_context",
    "get_request_log",
    "waterfall",
]

# one process-wide monotone sequence; itertools.count() increments under
# the GIL so minting needs no lock of its own
_SEQ = itertools.count()
_PID_TOKEN = f"{os.getpid():x}"


def mint_trace_id(kind: str = "r") -> str:
    """A short process-unique trace id, e.g. ``r3f91-1a``.

    ``kind`` prefixes the id class: ``r`` for serving requests, ``a`` for
    admissions.  The pid token keeps ids from concurrent processes (a
    serving fleet writing dumps into one directory) distinct.
    """
    return f"{kind}{_PID_TOKEN}-{next(_SEQ):x}"


class RequestContext:
    """Per-request causality record: trace id + lifecycle stamps.

    Stamps are in the *engine clock* domain (injectable, virtual in
    tests) so the queue/latency decomposition is deterministic wherever
    latency accounting is; ``compute_s`` is the flushed batch's measured
    wall compute, attributed to this request via ``batch_share``.
    """

    __slots__ = (
        "trace_id",
        "key",
        "t_submit",
        "t_enqueue",
        "t_flush_start",
        "t_dispatch",
        "t_complete",
        "compute_s",
        "batch_share",
        "batch_k",
        "flush_reason",
        "deadline_hit",
    )

    def __init__(self, key: str, t_submit: float):
        self.trace_id = mint_trace_id("r")
        self.key = key
        self.t_submit = t_submit
        self.t_enqueue: Optional[float] = None
        self.t_flush_start: Optional[float] = None
        self.t_dispatch: Optional[float] = None
        self.t_complete: Optional[float] = None
        self.compute_s: Optional[float] = None
        self.batch_share: Optional[float] = None
        self.batch_k: Optional[int] = None
        self.flush_reason: Optional[str] = None
        self.deadline_hit: Optional[bool] = None

    # --- derived decomposition (computed at read time, never stored) -------

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Submit → flush-start: time spent coalescing in the batcher."""
        if self.t_flush_start is None:
            return None
        return self.t_flush_start - self.t_submit

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_complete is None:
            return None
        return self.t_complete - self.t_submit

    @property
    def compute_share_s(self) -> Optional[float]:
        """This request's share of its batch's measured compute seconds."""
        if self.compute_s is None or self.batch_share is None:
            return None
        return self.compute_s * self.batch_share

    @property
    def done(self) -> bool:
        return self.t_complete is not None

    def to_dict(self) -> dict:
        """JSON-ready record for dumps and the ``--requests`` waterfall."""
        return {
            "trace_id": self.trace_id,
            "matrix": self.key,
            "t_submit": self.t_submit,
            "t_enqueue": self.t_enqueue,
            "t_flush_start": self.t_flush_start,
            "t_dispatch": self.t_dispatch,
            "t_complete": self.t_complete,
            "queue_wait_s": self.queue_wait_s,
            "compute_s": self.compute_s,
            "compute_share_s": self.compute_share_s,
            "batch_share": self.batch_share,
            "batch_k": self.batch_k,
            "flush_reason": self.flush_reason,
            "deadline_hit": self.deadline_hit,
            "latency_s": self.latency_s,
        }

    def __repr__(self) -> str:  # debugging aid, never on the hot path
        return (
            f"RequestContext({self.trace_id}, key={self.key!r}, "
            f"latency_s={self.latency_s}, queue_wait_s={self.queue_wait_s})"
        )


def new_context(key: str, t_submit: float) -> RequestContext:
    """Mint the context for one submitted request."""
    return RequestContext(key, t_submit)


class RequestLog:
    """Bounded ring of completed :class:`RequestContext` objects.

    The engine appends the context object itself (no dict per request);
    :meth:`snapshot` renders dicts only when a dump/report asks.  Like
    the flight ring, memory is bounded regardless of traffic volume.
    """

    def __init__(self, *, window: int = 1024):
        self._ctxs: deque = deque(maxlen=window)
        self._count = 0
        self._lock = threading.Lock()

    def complete(self, ctx: RequestContext) -> None:
        """Record one completed request (hot path: a deque append)."""
        with self._lock:
            self._ctxs.append(ctx)
            self._count += 1

    @property
    def count(self) -> int:
        """Total requests ever completed (the window holds the newest)."""
        return self._count

    def contexts(self) -> List[RequestContext]:
        with self._lock:
            return list(self._ctxs)

    def snapshot(self) -> List[dict]:
        """The retained window as JSON-ready dicts, oldest first."""
        return [c.to_dict() for c in self.contexts()]

    def clear(self) -> None:
        with self._lock:
            self._ctxs.clear()
            self._count = 0


_LOG: Optional[RequestLog] = None
_LOG_LOCK = threading.Lock()


def get_request_log() -> RequestLog:
    """The process-global request log (created on first use, always on)."""
    global _LOG
    with _LOG_LOCK:
        if _LOG is None:
            _LOG = RequestLog()
        return _LOG


# --- the slowest-N waterfall -------------------------------------------------


def _ms(v: Optional[float]) -> str:
    return "n/a" if v is None else f"{1e3 * v:.3f}"


def waterfall(
    snapshot_or_rows: Union[dict, List[dict]], *, n: int = 20, width: int = 32
) -> str:
    """Render the slowest-``n`` request waterfall as a text table.

    Accepts either a ``repro.obs.dump()``/``collect()`` snapshot (reads
    its ``"requests"`` list) or the request-dict list directly.  Each row
    shows the queue-vs-compute decomposition numerically and as a bar —
    ``░`` is queue wait, ``█`` the request's compute share — scaled so
    the slowest request spans ``width`` cells.
    """
    rows = (
        snapshot_or_rows.get("requests", [])
        if isinstance(snapshot_or_rows, dict)
        else list(snapshot_or_rows)
    )
    rows = [r for r in rows if r.get("latency_s") is not None]
    if not rows:
        return (
            "(no completed requests in snapshot — serve traffic through a "
            "ServingEngine first)\n"
        )
    rows.sort(key=lambda r: (-r["latency_s"], r.get("trace_id", "")))
    rows = rows[:n]
    scale = max(r["latency_s"] for r in rows)
    header = [
        "trace_id", "matrix", "latency_ms", "queue_ms", "compute_ms",
        "share", "reason", "queue░ compute█",
    ]
    table = []
    for r in rows:
        lat = r["latency_s"]
        queue = r.get("queue_wait_s")
        comp = r.get("compute_share_s")
        q_cells = int(round(width * (queue or 0.0) / scale)) if scale > 0 else 0
        c_cells = int(round(width * (comp or 0.0) / scale)) if scale > 0 else 0
        q_cells = min(q_cells, width)
        c_cells = min(c_cells, width - q_cells)
        share = r.get("batch_share")
        table.append(
            [
                str(r.get("trace_id", "?")),
                str(r.get("matrix", "?")),
                _ms(lat),
                _ms(queue),
                _ms(comp),
                "n/a" if share is None else f"1/{round(1 / share)}" if share else "0",
                str(r.get("flush_reason") or "n/a"),
                "░" * q_cells + "█" * c_cells,
            ]
        )
    widths = [max(len(h), *(len(row[i]) for row in table)) for i, h in enumerate(header)]
    lines = [f"== slowest {len(rows)} requests (queue wait vs compute share) =="]
    lines.append("  ".join(h.ljust(w) for h, w in zip(header, widths)).rstrip())
    for row in table:
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip())
    return "\n".join(lines) + "\n"
