"""Always-on flight recorder: a bounded ring of events + anomaly dumps.

The gated tracer (:mod:`repro.obs.trace`) answers "what happened?" only if
observability was enabled *before* the interesting thing happened.
Production serving needs the opposite: a recorder that is **always on**,
costs a bounded ring slot per event, and can explain — after the fact —
why a request missed its deadline.  :class:`FlightRecorder` is that
recorder:

* **fixed ring capacity** — events land in a preallocated ring buffer;
  once full, the oldest events are overwritten (never an allocation-
  per-event growth path, never unbounded memory);
* **lock-cheap recording** — one small critical section per event (a slot
  write and an index bump); hot call sites that cannot afford even a dict
  per call use :meth:`sampled` to record probabilistically;
* **anomaly triggers** — :meth:`trigger` snapshots the ring to a
  Perfetto-loadable artifact ``flight_<reason>_<seq>.json``.  The serving
  engine fires it on deadline misses; :meth:`observe_latency` fires it
  when an observation exceeds a rolling-quantile threshold, and
  :meth:`observe_queue_depth` when a queue saturates.  Dumps are
  rate-limited per reason and capped per process so a pathological
  workload cannot flood the disk.

The artifact is the same Chrome-trace JSON shape the tracer exports
(``{"traceEvents": [...]}``): drop it on https://ui.perfetto.dev and the
ring replays as spans (``ph: "X"``) and instants (``ph: "i"``), with the
trigger context under ``otherData``.
"""
from __future__ import annotations

import json
import os
import random
import re
import threading
import time
from collections import deque
from pathlib import Path
from typing import Dict, List, Optional

__all__ = ["FlightRecorder", "get_flight", "DEFAULT_DUMP_DIR"]

# where trigger dumps land when neither the recorder's ``dump_dir`` nor
# ``$REPRO_FLIGHT_DIR`` is set — a dedicated (gitignored) subdirectory, so
# the default can never pollute a repository checkout's root
DEFAULT_DUMP_DIR = ".flight_dumps"


def _safe_token(s: str, maxlen: int = 40) -> str:
    """Filesystem-safe slice of a trace id for dump filenames."""
    return re.sub(r"[^A-Za-z0-9_.-]", "", s)[:maxlen]


class _NoopSpan:
    """Shared no-op for unsampled spans — enter/exit/annotate do nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def annotate(self, **kw):
        return self


_NOOP_SPAN = _NoopSpan()


class _Rolling:
    """Per-site rolling latency window with a cached anomaly threshold.

    The threshold (``factor`` × the window's p99) is recomputed every
    ``refresh`` observations, not per observation — the hot path pays a
    float compare and a deque append.
    """

    __slots__ = ("window", "threshold", "since_refresh")

    def __init__(self, maxlen: int):
        self.window: deque = deque(maxlen=maxlen)
        self.threshold = float("inf")
        self.since_refresh = 0


class _FlightSpan:
    """Timed scope that records into the ring on exit."""

    __slots__ = ("recorder", "name", "args", "t0")

    def __init__(self, recorder: "FlightRecorder", name: str, args: dict):
        self.recorder = recorder
        self.name = name
        self.args = args

    def __enter__(self) -> "_FlightSpan":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self.recorder.record(self.name, t0=self.t0, dur_s=t1 - self.t0, **self.args)
        return False

    def annotate(self, **kw) -> "_FlightSpan":
        self.args.update(kw)
        return self


class FlightRecorder:
    """Bounded always-on event ring with triggerable post-mortem dumps."""

    def __init__(
        self,
        *,
        capacity: int = 4096,
        dump_dir: Optional[os.PathLike] = None,
        max_dumps: int = 64,
        min_dump_interval_s: float = 1.0,
        seed: Optional[int] = None,
        latency_window: int = 512,
        latency_min_samples: int = 32,
        latency_factor: float = 4.0,
        latency_refresh: int = 64,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        # None: $REPRO_FLIGHT_DIR at dump time, else ./.flight_dumps/ — never
        # the bare cwd, so an example run can't litter a repo checkout with
        # flight_*.json artifacts (they are post-mortems, not source)
        self.dump_dir = dump_dir
        self.max_dumps = max_dumps
        self.min_dump_interval_s = min_dump_interval_s
        self.latency_window = latency_window
        self.latency_min_samples = latency_min_samples
        self.latency_factor = latency_factor
        self.latency_refresh = latency_refresh
        self.epoch = time.perf_counter()
        self._ring: List[Optional[dict]] = [None] * capacity
        self._n = 0  # total events ever recorded
        self._seq = 0  # dump sequence number
        self._last_dump: Dict[str, float] = {}
        self._suppressed = 0  # triggers rate-limited away (still counted)
        self.dumps: List[str] = []
        self._lat: Dict[str, _Rolling] = {}
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    # --- recording ---------------------------------------------------------

    def record(
        self, name: str, *, t0: Optional[float] = None, dur_s: float = 0.0, **args
    ) -> None:
        """Append one event to the ring (span if ``dur_s`` > 0, else instant).

        ``t0`` is the ``time.perf_counter`` start of the event (defaults to
        now); overwrites the oldest slot once the ring is full.
        """
        t0 = time.perf_counter() if t0 is None else t0
        ev = {
            "name": name,
            "ph": "X" if dur_s > 0 else "i",
            "ts": (t0 - self.epoch) * 1e6,  # Chrome trace wants microseconds
            "pid": os.getpid(),
            "tid": threading.get_ident(),
        }
        if dur_s > 0:
            ev["dur"] = dur_s * 1e6
        else:
            ev["s"] = "t"  # Perfetto instant scope: thread
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        with self._lock:
            self._ring[self._n % self.capacity] = ev
            self._n += 1

    def span(self, name: str, *, sample: float = 1.0, **args):
        """Timed scope recorded on exit; ``sample`` < 1 records that
        fraction of entries (the unsampled rest cost one RNG draw)."""
        if sample < 1.0 and not self.sampled(sample):
            return _NOOP_SPAN
        return _FlightSpan(self, name, args)

    def sampled(self, rate: float) -> bool:
        """One probabilistic sampling decision (true ~``rate`` of calls)."""
        return self._rng.random() < rate

    # --- anomaly detectors -------------------------------------------------

    def observe_latency(self, site: str, value_s: float, **context) -> Optional[str]:
        """Feed one latency observation; trigger a dump when it exceeds the
        site's rolling-quantile threshold (``latency_factor`` × rolling p99
        over the last ``latency_window`` observations).  Returns the dump
        path when one was written."""
        with self._lock:
            r = self._lat.get(site)
            if r is None:
                r = self._lat[site] = _Rolling(self.latency_window)
            anomalous = (
                len(r.window) >= self.latency_min_samples and value_s > r.threshold
            )
            threshold = r.threshold
            r.window.append(value_s)
            r.since_refresh += 1
            if r.since_refresh >= self.latency_refresh or (
                threshold == float("inf")
                and len(r.window) >= self.latency_min_samples
            ):
                lat = sorted(r.window)
                r.threshold = self.latency_factor * lat[int(0.99 * (len(lat) - 1))]
                r.since_refresh = 0
        if not anomalous:
            return None
        return self.trigger(
            "latency_anomaly",
            site=site,
            value_s=value_s,
            threshold_s=threshold,
            **context,
        )

    def observe_queue_depth(
        self, site: str, depth: int, limit: int, **context
    ) -> Optional[str]:
        """Trigger a dump when ``depth`` saturates ``limit`` (an int compare
        on the non-saturated path — cheap enough for submit loops)."""
        if limit <= 0 or depth < limit:
            return None
        return self.trigger(
            "queue_saturation", site=site, depth=depth, limit=limit, **context
        )

    # --- triggers / dumps --------------------------------------------------

    def trigger(self, reason: str, **context) -> Optional[str]:
        """Snapshot the ring to ``flight_<reason>_<seq>[_<trace>].json``.

        Rate-limited: at most one dump per ``reason`` per
        ``min_dump_interval_s`` and ``max_dumps`` total per process
        (suppressed triggers are counted, not lost silently).  The trigger
        itself lands in the ring first, so the artifact records why it
        exists.  A ``trace_ids=[...]`` context entry names the offending
        requests: it rides the trigger event and the dump's context, and
        the first id is appended to the filename so an on-disk post-mortem
        directory can be grepped by request.  Returns the path written,
        or None when suppressed.
        """
        now = time.time()
        with self._lock:
            last = self._last_dump.get(reason)
            if self._seq >= self.max_dumps or (
                last is not None and now - last < self.min_dump_interval_s
            ):
                self._suppressed += 1
                return None
            self._last_dump[reason] = now
            seq = self._seq
            self._seq += 1
        self.record("flight.trigger", reason=reason, **context)
        directory = Path(
            self.dump_dir
            if self.dump_dir is not None
            else os.environ.get("REPRO_FLIGHT_DIR", DEFAULT_DUMP_DIR)
        )
        directory.mkdir(parents=True, exist_ok=True)
        stem = f"flight_{reason}_{seq}"
        trace_ids = context.get("trace_ids")
        if trace_ids:
            tok = _safe_token(str(trace_ids[0]))
            if tok:
                stem = f"{stem}_{tok}"
        path = directory / f"{stem}.json"
        payload = {
            "traceEvents": self.snapshot(),
            "displayTimeUnit": "ms",
            "otherData": {
                "reason": reason,
                "seq": seq,
                "context": {k: _jsonable(v) for k, v in sorted(context.items())},
                "recorded_total": self._n,
                "capacity": self.capacity,
            },
        }
        with open(path, "w") as f:
            json.dump(payload, f, sort_keys=True)
        with self._lock:
            self.dumps.append(str(path))
        return str(path)

    # --- introspection -----------------------------------------------------

    def snapshot(self) -> List[dict]:
        """Ring contents oldest-first (sorted by timestamp for stability
        under concurrent recorders)."""
        with self._lock:
            if self._n <= self.capacity:
                events = [e for e in self._ring[: self._n]]
            else:
                head = self._n % self.capacity
                events = self._ring[head:] + self._ring[:head]
        return sorted(events, key=lambda e: (e["ts"], e["name"]))

    def stats(self) -> dict:
        with self._lock:
            return {
                "recorded_total": self._n,
                "events": min(self._n, self.capacity),
                "capacity": self.capacity,
                "overwritten": max(0, self._n - self.capacity),
                "dumps": list(self.dumps),
                "suppressed_triggers": self._suppressed,
            }

    def reset(self) -> None:
        """Clear the ring, detectors and dump bookkeeping (test isolation)."""
        with self._lock:
            self._ring = [None] * self.capacity
            self._n = 0
            self._seq = 0
            self._last_dump.clear()
            self._suppressed = 0
            self.dumps = []
            self._lat.clear()


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        # bounded: ring slots must stay small even if a caller passes a
        # large batch's trace-id list by mistake
        return [_jsonable(x) for x in v[:64]]
    try:
        return float(v)
    except (TypeError, ValueError):
        return repr(v)


_FLIGHT: Optional[FlightRecorder] = None
_FLIGHT_LOCK = threading.Lock()


def get_flight() -> FlightRecorder:
    """The process-global flight recorder (created on first use, always on)."""
    global _FLIGHT
    with _FLIGHT_LOCK:
        if _FLIGHT is None:
            _FLIGHT = FlightRecorder()
        return _FLIGHT
