"""Metrics core: counters, gauges, histograms, series, and their registry.

The paper's whole argument is a cost ledger — preprocessing time against
SpMV speedup — and this module is where the ledger lives at runtime.
Metrics are plain thread-safe objects that always work when held directly
(the serving registry/engine use them as the backing store for their
``stats()`` views, enabled or not); the *gated* convenience constructors in
:mod:`repro.obs` return a shared no-op when observability is disabled, so
hot-path instrumentation costs one global read and nothing else.

Types:

* :class:`Counter` — monotone float/int accumulator (``inc``);
* :class:`Gauge` — last-write-wins level (``set``/``inc``/``dec``);
* :class:`Histogram` — fixed log-spaced buckets plus an optional sliding
  window of raw samples: percentiles are *exact* over the window while it
  covers every observation (the serving latency contract inherited from
  the pre-obs engine) and bucket-interpolated beyond it;
* :class:`Series` — an append-only (index, value) stream for quantities
  that are ordered but not timestamped, e.g. per-iteration solver
  residuals recorded post-hoc from a ``lax.while_loop`` carry.

Every :class:`MetricRegistry` self-registers in a process-global weak set
so ``repro.obs.dump()``/``report()`` can aggregate over all live
registries — including the per-``MatrixRegistry`` instances that keep
test runs isolated from each other.
"""
from __future__ import annotations

import math
import threading
import weakref
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Series",
    "MetricRegistry",
    "get_registry",
    "all_registries",
    "default_buckets",
]

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def default_buckets() -> np.ndarray:
    """Log-spaced bucket bounds covering 100 ns .. 100 s at ~12% width.

    20 buckets per decade over 9 decades: wide enough for admission times
    (seconds) and kernel launches (tens of microseconds) in one histogram,
    fine enough that an interpolated percentile lands within ~6% of the
    true value (half a bucket).
    """
    return np.logspace(-7, 2, 181)


class _Metric:
    """Shared identity: ``name`` plus a frozen label set."""

    __slots__ = ("name", "labels", "_lock")

    def __init__(self, name: str, labels: Dict[str, object]):
        self.name = name
        self.labels = dict(labels)
        self._lock = threading.Lock()

    def _ident(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels)}


class Counter(_Metric):
    """Monotone accumulator.  ``inc`` is thread-safe (guarded, not GIL-lucky)."""

    __slots__ = ("_value",)

    def __init__(self, name: str, labels: Dict[str, object]):
        super().__init__(name, labels)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease (inc {n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {**self._ident(), "type": "counter", "value": self._value}


class Gauge(_Metric):
    """Last-write-wins level (queue depths, occupancies, config choices)."""

    __slots__ = ("_value",)

    def __init__(self, name: str, labels: Dict[str, object]):
        super().__init__(name, labels)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {**self._ident(), "type": "gauge", "value": self._value}


class Histogram(_Metric):
    """Fixed-bucket histogram with an optional exact sliding window.

    ``buckets`` are the ascending bucket *bounds*; observation ``v`` lands
    in the bucket whose bound is the first one ``>= v`` (underflow goes to
    bucket 0, overflow to the extra last slot).  ``window`` raw samples are
    retained (default 4096, the engine's historical latency window):
    :meth:`percentile` is numpy-exact while the window still holds every
    observation, and falls back to linear interpolation inside the bucket
    bounds once observations have been evicted — bounded error, bounded
    memory, regardless of traffic volume.

    ``observe(v, exemplar=trace_id)`` additionally keeps the **most
    recent exemplar per bucket** (one ``(trace_id, value)`` slot, lazily
    allocated on the first exemplar ever seen), so a p99 outlier bucket
    links straight to the trace that landed in it — the OpenMetrics
    exporter renders them as ``# {trace_id="..."} v`` bucket exemplars.
    """

    __slots__ = (
        "bounds", "bucket_counts", "count", "total", "vmin", "vmax",
        "_window", "_exemplars",
    )

    def __init__(
        self,
        name: str,
        labels: Dict[str, object],
        *,
        buckets: Optional[Iterable[float]] = None,
        window: int = 4096,
    ):
        super().__init__(name, labels)
        self.bounds = np.asarray(
            default_buckets() if buckets is None else list(buckets), np.float64
        )
        if self.bounds.size < 1 or np.any(np.diff(self.bounds) <= 0):
            raise ValueError("histogram buckets must be ascending and non-empty")
        self.bucket_counts = np.zeros(self.bounds.size + 1, np.int64)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._window = deque(maxlen=window) if window > 0 else None
        self._exemplars: Optional[list] = None  # per-bucket (trace_id, value)

    def observe(self, v: float, exemplar: Optional[str] = None) -> None:
        v = float(v)
        idx = int(np.searchsorted(self.bounds, v, side="left"))
        with self._lock:
            self.bucket_counts[idx] += 1
            self.count += 1
            self.total += v
            self.vmin = min(self.vmin, v)
            self.vmax = max(self.vmax, v)
            if self._window is not None:
                self._window.append(v)
            if exemplar is not None:
                if self._exemplars is None:
                    self._exemplars = [None] * (self.bounds.size + 1)
                self._exemplars[idx] = (str(exemplar), v)

    def exemplars(self) -> List[dict]:
        """The retained per-bucket exemplars, ascending by bucket.

        Each entry carries the bucket's upper bound (``math.inf`` for the
        overflow slot), the exemplar's observed value, and its trace id —
        the join key back to flight dumps and flow events.
        """
        with self._lock:
            if self._exemplars is None:
                return []
            kept = list(enumerate(self._exemplars))
        out = []
        for i, ex in kept:
            if ex is None:
                continue
            le = float(self.bounds[i]) if i < self.bounds.size else math.inf
            out.append({"le": le, "trace_id": ex[0], "value": ex[1]})
        return out

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def percentile(self, q: float) -> Optional[float]:
        """Quantile ``q`` in [0, 1]: exact over the sample window while it
        holds every observation, bucket-interpolated otherwise."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        with self._lock:
            if self.count == 0:
                return None
            if self._window is not None and len(self._window) == self.count:
                # the exact path reproduces the pre-obs engine convention:
                # sorted[int(q * (n - 1))], no interpolation between samples
                lat = np.sort(np.asarray(self._window, np.float64))
                return float(lat[int(q * (lat.size - 1))])
            counts = self.bucket_counts.copy()
            vmin, vmax, count = self.vmin, self.vmax, self.count
        rank = q * (count - 1)
        cum = 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c > rank:
                lo = vmin if i == 0 else float(self.bounds[i - 1])
                hi = vmax if i >= self.bounds.size else float(self.bounds[i])
                lo = max(lo, vmin)
                hi = min(hi, vmax)
                frac = (rank - cum) / c
                return float(lo + frac * (hi - lo))
            cum += c
        return float(vmax)

    def snapshot(self) -> dict:
        with self._lock:
            count, total = self.count, self.total
            vmin, vmax = self.vmin, self.vmax
        snap = {
            **self._ident(),
            "type": "histogram",
            "count": count,
            "sum": total,
            "min": vmin if count else None,
            "max": vmax if count else None,
        }
        for q in (0.50, 0.95, 0.99):
            snap[f"p{int(q * 100)}"] = self.percentile(q)
        ex = self.exemplars()
        if ex:
            snap["exemplars"] = ex
        return snap


class Series(_Metric):
    """Ordered (index, value) stream — iteration-indexed, not timestamped.

    Solver residual histories and training loss curves are produced on
    device inside ``lax.while_loop`` carries and recorded *post-hoc*; the
    index is the iteration number, which is the honest x-axis (inventing
    wall-clock timestamps after the fact would corrupt the trace
    timeline).  The window bounds memory on long runs.
    """

    __slots__ = ("_points", "count")

    def __init__(self, name: str, labels: Dict[str, object], *, window: int = 4096):
        super().__init__(name, labels)
        self._points: deque = deque(maxlen=window)
        self.count = 0

    def append(self, value: float, index: Optional[int] = None) -> None:
        with self._lock:
            idx = self.count if index is None else int(index)
            self._points.append((idx, float(value)))
            self.count += 1

    def extend(self, values: Iterable[float]) -> None:
        for v in values:
            self.append(v)

    @property
    def points(self) -> List[Tuple[int, float]]:
        with self._lock:
            return list(self._points)

    @property
    def values(self) -> List[float]:
        return [v for _, v in self.points]

    def snapshot(self) -> dict:
        pts = self.points
        vals = [v for _, v in pts]
        return {
            **self._ident(),
            "type": "series",
            "count": self.count,
            "first": vals[0] if vals else None,
            "last": vals[-1] if vals else None,
            "min": min(vals) if vals else None,
            "max": max(vals) if vals else None,
            "points": pts,
        }


_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram, "series": Series}

# weak set of every live registry, aggregated by repro.obs.dump()/report()
_ALL: "weakref.WeakSet[MetricRegistry]" = weakref.WeakSet()
_ALL_LOCK = threading.Lock()
_UNNAMED = [0]


class MetricRegistry:
    """Get-or-create home for metrics, keyed by (type, name, labels).

    One process-global instance (:func:`get_registry`) backs the gated
    ``repro.obs`` constructors; subsystems that need isolated bookkeeping
    (each serving ``MatrixRegistry`` shares one with its engines) create
    their own — all live instances are visible to :func:`all_registries`.
    """

    def __init__(self, name: Optional[str] = None):
        if name is None:
            with _ALL_LOCK:
                _UNNAMED[0] += 1
                name = f"registry-{_UNNAMED[0]}"
        self.name = name
        self._metrics: Dict[Tuple[str, str, LabelKey], _Metric] = {}
        self._lock = threading.RLock()
        with _ALL_LOCK:
            _ALL.add(self)

    def _get_or_create(self, cls_name: str, name: str, labels: dict, **kwargs):
        key = (cls_name, name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                conflict = [k for k in self._metrics if k[1] == name and k[0] != cls_name]
                if conflict:
                    raise TypeError(
                        f"metric {name!r} already registered as {conflict[0][0]}, "
                        f"requested {cls_name}"
                    )
                m = _TYPES[cls_name](name, labels, **kwargs)
                self._metrics[key] = m
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_create("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get_or_create("gauge", name, labels)

    def histogram(
        self,
        name: str,
        *,
        buckets: Optional[Iterable[float]] = None,
        window: int = 4096,
        **labels,
    ) -> Histogram:
        return self._get_or_create(
            "histogram", name, labels, buckets=buckets, window=window
        )

    def series(self, name: str, *, window: int = 4096, **labels) -> Series:
        return self._get_or_create("series", name, labels, window=window)

    def get(self, name: str, **labels) -> Optional[_Metric]:
        """The already-registered metric for (name, labels), else None."""
        lk = _label_key(labels)
        with self._lock:
            for (_, n, k), m in self._metrics.items():
                if n == name and k == lk:
                    return m
        return None

    def value(self, name: str, default: float = 0.0, **labels) -> float:
        """Counter/gauge value for (name, labels); ``default`` if absent."""
        m = self.get(name, **labels)
        return m.value if m is not None and hasattr(m, "value") else default

    def find(self, name: str) -> List[_Metric]:
        """Every metric registered under ``name``, across label sets."""
        with self._lock:
            return [m for (_, n, _), m in self._metrics.items() if n == name]

    def label_values(self, name: str, label: str) -> List[str]:
        """Distinct values of one label across a metric name (e.g. every
        ``matrix=`` a serving counter has seen)."""
        out = []
        for m in self.find(name):
            v = m.labels.get(label)
            if v is not None and v not in out:
                out.append(v)
        return out

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def collect(self) -> dict:
        """Snapshot of every metric, ready for JSON.

        Sorted by (name, labels, type) so dumps and dashboards are
        deterministic run-to-run — CI artifacts diff cleanly regardless of
        metric creation order.
        """
        snaps = [m.snapshot() for m in self.metrics()]
        snaps.sort(
            key=lambda s: (s["name"], _label_key(s.get("labels") or {}), s["type"])
        )
        return {"registry": self.name, "metrics": snaps}

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


_GLOBAL = MetricRegistry(name="global")


def get_registry() -> MetricRegistry:
    """The process-global registry the gated ``repro.obs`` helpers use."""
    return _GLOBAL


def all_registries() -> List[MetricRegistry]:
    """Every live registry (global first), for aggregation in dump/report."""
    with _ALL_LOCK:
        live = list(_ALL)
    live.sort(key=lambda r: (r is not _GLOBAL, r.name))
    return live
