"""Span tracer: nested timing scopes exported as Chrome-trace / JSONL.

``span("admit.build_tiles")`` opens a timed scope; on exit one *complete*
event (Chrome ``"ph": "X"``) is appended to the process-global
:class:`Tracer`.  Nesting needs no bookkeeping in the export — the Chrome
trace viewer and Perfetto nest same-thread events by time containment —
but each event also carries an explicit ``depth`` (the thread's open-span
count at entry) so tests and the JSONL log can assert ordering without a
trace viewer.

Device work is asynchronous under JAX: a span that closes right after a
kernel launch times the *dispatch*, not the compute.  ``Span.sync(value)``
wraps ``jax.block_until_ready`` so the caller decides, per span, whether
the device is drained inside the measurement::

    with obs.span("serve.flush", matrix=key) as sp:
        y = sp.sync(plan.matmat(X))   # compute lands inside the span

The event buffer is bounded (default 1M events); past the cap events are
dropped and counted, never silently lost.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional

__all__ = ["Span", "Tracer", "get_tracer"]


class Tracer:
    """Bounded event buffer with Chrome-trace and JSONL exporters."""

    def __init__(self, *, max_events: int = 1_000_000):
        self.max_events = max_events
        self.epoch = time.perf_counter()
        self.events: List[dict] = []
        self.dropped = 0
        self._lock = threading.Lock()
        self._tls = threading.local()

    # --- span lifecycle ----------------------------------------------------

    def span(self, name: str, **args) -> "Span":
        return Span(self, name, args)

    def _depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    def _enter(self) -> int:
        d = self._depth()
        self._tls.depth = d + 1
        return d

    def _exit(self) -> None:
        self._tls.depth = max(0, self._depth() - 1)

    def add_event(
        self, name: str, t0: float, t1: float, depth: int, args: Dict[str, object]
    ) -> None:
        ev = {
            "name": name,
            "ph": "X",
            "ts": (t0 - self.epoch) * 1e6,  # Chrome trace wants microseconds
            "dur": (t1 - t0) * 1e6,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "depth": depth,
        }
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        self._append(ev)

    def flow(
        self, name: str, fid: str, phase: str = "s", t: Optional[float] = None, **args
    ) -> None:
        """Append one Chrome-trace **flow event** (``ph`` s/t/f).

        Flow events draw arrows between slices in Perfetto: a ``"s"``
        (start) at submit time and an ``"f"`` (finish, binding to the
        enclosing slice) inside the flush span connect a request's
        submission to the batch that served it.  ``fid`` is the flow id —
        the request's trace id — shared by both ends of the arrow.
        """
        if phase not in ("s", "t", "f"):
            raise ValueError(f"flow phase must be s, t or f, got {phase!r}")
        t = time.perf_counter() if t is None else t
        ev = {
            "name": name,
            "ph": phase,
            "cat": "request",
            "id": str(fid),
            "ts": (t - self.epoch) * 1e6,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
        }
        if phase == "f":
            ev["bp"] = "e"  # bind to the enclosing slice, not the next one
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        self._append(ev)

    def _append(self, ev: dict) -> None:
        with self._lock:
            if len(self.events) >= self.max_events:
                self.dropped += 1
            else:
                self.events.append(ev)

    # --- introspection / export --------------------------------------------

    def snapshot(self) -> List[dict]:
        with self._lock:
            return list(self.events)

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self.dropped = 0

    def summary(self) -> List[dict]:
        """Per-span-name aggregate: count, total/mean/max duration (ms).

        Flow events carry no duration and are skipped — they annotate
        causality, not time spent."""
        agg: Dict[str, List[float]] = {}
        for ev in self.snapshot():
            if "dur" not in ev:
                continue
            agg.setdefault(ev["name"], []).append(ev["dur"])
        out = []
        for name in sorted(agg, key=lambda n: (-sum(agg[n]), n)):
            durs = agg[name]
            out.append(
                {
                    "name": name,
                    "count": len(durs),
                    "total_ms": sum(durs) / 1e3,
                    "mean_ms": sum(durs) / len(durs) / 1e3,
                    "max_ms": max(durs) / 1e3,
                }
            )
        return out

    def chrome_trace(self) -> dict:
        """The ``{"traceEvents": [...]}`` object Perfetto / chrome://tracing
        load directly."""
        return {
            "traceEvents": self.snapshot(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def write_chrome(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def write_jsonl(self, path) -> None:
        """One event object per line — greppable, streamable, diffable."""
        with open(path, "w") as f:
            for ev in self.snapshot():
                f.write(json.dumps(ev, sort_keys=True) + "\n")


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    try:
        return float(v)
    except (TypeError, ValueError):
        return repr(v)


class Span:
    """One timed scope.  Use as a context manager; re-entrant per instance
    is not supported (make a new span instead)."""

    __slots__ = ("tracer", "name", "args", "t0", "depth")

    def __init__(self, tracer: Tracer, name: str, args: Dict[str, object]):
        self.tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self) -> "Span":
        self.depth = self.tracer._enter()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        self.tracer._exit()
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self.tracer.add_event(self.name, self.t0, t1, self.depth, self.args)
        return False

    def annotate(self, **kw) -> "Span":
        """Attach args discovered mid-span (tile counts, chosen configs)."""
        self.args.update(kw)
        return self

    def sync(self, value):
        """Block until ``value``'s device work is done; returns ``value``.
        Use inside the span so asynchronous dispatch lands in the timing."""
        import jax

        return jax.block_until_ready(value)


_TRACER: Optional[Tracer] = None
_TRACER_LOCK = threading.Lock()


def get_tracer() -> Tracer:
    """The process-global tracer (created on first use)."""
    global _TRACER
    with _TRACER_LOCK:
        if _TRACER is None:
            _TRACER = Tracer()
        return _TRACER
