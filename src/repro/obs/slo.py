"""SLO engine: declarative objectives evaluated as multi-window burn rates.

A serving deployment does not want raw latency histograms at decision
time — it wants "are we spending our error budget too fast?".  That is a
**burn rate**: the fraction of requests violating the objective over a
trailing window, divided by the budget the objective allows.  A burn rate
of 1.0 spends the budget exactly at the sustainable pace; 14x on a short
window is the classic page-now signal, ~2x on a long window the slow leak
worth a ticket.

:class:`SLO` declares one objective:

* ``objective="deadline_hit_ratio"`` — ``target`` is the required hit
  ratio (e.g. 0.99: at most 1% of requests may miss their deadline);
* ``objective="latency_p99"`` — ``target`` is a latency bound in seconds
  that 99% of requests must meet (budget fixed at 1%).

Both reduce to the same good-events accounting, so one engine evaluates
any mix of objectives per matrix/tenant key.  :class:`SLOEngine.record`
is the hot-path call (a deque append); :meth:`SLOEngine.evaluate` scans
the trailing events once per window set, refreshes the always-live
``slo.burn_rate`` / ``slo.attainment`` gauges, and classifies each
(key, slo) as ``ok`` / ``warn`` / ``page`` — the view
:meth:`repro.serving.engine.ServingEngine.health` hands the QoS layer.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, Iterable, Optional, Tuple

from .metrics import MetricRegistry

__all__ = ["SLO", "SLOEngine", "DEFAULT_WINDOWS", "worst_status"]

# trailing evaluation windows in seconds, shortest first (1m / 5m / 1h)
DEFAULT_WINDOWS: Tuple[float, ...] = (60.0, 300.0, 3600.0)

_OBJECTIVES = ("deadline_hit_ratio", "latency_p99")


@dataclasses.dataclass(frozen=True)
class SLO:
    """One declarative objective (see module docstring for semantics)."""

    name: str
    objective: str
    target: float
    windows: Tuple[float, ...] = DEFAULT_WINDOWS
    fast_burn: float = 14.0  # page: budget burning this fast on short windows
    slow_burn: float = 2.0  # warn: sustained burn on the longest window

    def __post_init__(self):
        if self.objective not in _OBJECTIVES:
            raise ValueError(
                f"unknown objective {self.objective!r} (expected one of {_OBJECTIVES})"
            )
        if self.objective == "deadline_hit_ratio" and not 0.0 < self.target < 1.0:
            raise ValueError(
                f"deadline_hit_ratio target must be in (0, 1), got {self.target}"
            )
        if self.objective == "latency_p99" and self.target <= 0:
            raise ValueError(f"latency_p99 target must be > 0 s, got {self.target}")
        if not self.windows or any(
            b <= a for a, b in zip(self.windows, self.windows[1:])
        ):
            raise ValueError(f"windows must be ascending and non-empty: {self.windows}")

    @property
    def budget(self) -> float:
        """Allowed bad-event fraction (the error budget)."""
        if self.objective == "deadline_hit_ratio":
            return 1.0 - self.target
        return 0.01  # latency_p99: 1% of requests may exceed the bound

    def good(self, latency_s: float, deadline_hit: bool) -> bool:
        if self.objective == "deadline_hit_ratio":
            return deadline_hit
        return latency_s <= self.target


class SLOEngine:
    """Evaluate a set of :class:`SLO` objectives per matrix/tenant key.

    ``metrics`` is where the ``slo.*`` gauges live — pass the serving
    registry's shared :class:`MetricRegistry` so burn rates ride the same
    always-live ledger as the traffic counters (and surface in
    ``repro.obs.dump()`` / the dashboard); defaults to a private one.
    ``max_events`` bounds per-key memory regardless of traffic volume.
    """

    def __init__(
        self,
        slos: Optional[Iterable[SLO]] = None,
        *,
        metrics: Optional[MetricRegistry] = None,
        clock=time.perf_counter,
        max_events: int = 65536,
    ):
        self.slos = tuple(slos) if slos is not None else (
            SLO("deadline", "deadline_hit_ratio", 0.99),
        )
        names = [s.name for s in self.slos]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO names: {names}")
        self.metrics = metrics if metrics is not None else MetricRegistry(name="slo")
        self.clock = clock
        self.max_events = max_events
        # one event stream per key, shared by every objective:
        # (t_done, latency_s, deadline_hit)
        self._events: Dict[str, deque] = {}

    @property
    def max_window(self) -> float:
        return max(w for s in self.slos for w in s.windows)

    def record(
        self,
        key: str,
        *,
        latency_s: float,
        deadline_hit: bool,
        now: Optional[float] = None,
    ) -> None:
        """One completed request (hot path: an append and a bounded prune)."""
        now = self.clock() if now is None else now
        q = self._events.get(key)
        if q is None:
            q = self._events[key] = deque(maxlen=self.max_events)
        q.append((now, float(latency_s), bool(deadline_hit)))
        horizon = now - self.max_window
        while q and q[0][0] < horizon:
            q.popleft()

    def keys(self):
        return list(self._events)

    def evaluate(
        self, key: Optional[str] = None, now: Optional[float] = None
    ) -> dict:
        """Burn rates + status per (key, slo); refreshes the slo.* gauges.

        Returns ``{key: {slo_name: {"status", "budget", "windows": {label:
        {"events", "bad", "attainment", "burn_rate"}}}}}``.  Windows with
        no events report ``attainment``/``burn_rate`` of None and never
        page (no data is not an outage — queue-depth triggers cover the
        nothing-completes failure mode).
        """
        now = self.clock() if now is None else now
        keys = self.keys() if key is None else [key]
        out = {}
        for k in keys:
            events = list(self._events.get(k, ()))
            out[k] = {slo.name: self._eval_one(k, slo, events, now) for slo in self.slos}
        return out

    def _eval_one(self, key: str, slo: SLO, events, now: float) -> dict:
        windows = slo.windows
        totals = [0] * len(windows)
        bads = [0] * len(windows)
        for t, latency_s, hit in reversed(events):
            age = now - t
            if age > windows[-1]:
                break
            bad = not slo.good(latency_s, hit)
            for i, w in enumerate(windows):
                if age <= w:
                    totals[i] += 1
                    if bad:
                        bads[i] += 1
        burns, report = [], {}
        for i, w in enumerate(windows):
            n, b = totals[i], bads[i]
            ratio = (b / n) if n else None
            burn = (ratio / slo.budget) if ratio is not None else None
            attainment = (1.0 - ratio) if ratio is not None else None
            burns.append(burn)
            label = _window_label(w)
            report[label] = {
                "events": n,
                "bad": b,
                "attainment": attainment,
                "burn_rate": burn,
            }
            self.metrics.gauge(
                "slo.burn_rate", matrix=key, slo=slo.name, window=label
            ).set(burn if burn is not None else 0.0)
            self.metrics.gauge(
                "slo.attainment", matrix=key, slo=slo.name, window=label
            ).set(attainment if attainment is not None else 1.0)
        status = _classify(burns, slo)
        return {"status": status, "budget": slo.budget, "windows": report}


def _classify(burns, slo: SLO) -> str:
    """Multi-window classification: ``page`` needs the two shortest windows
    both burning past ``fast_burn`` (a lone short-window spike of a few
    requests should not page); ``warn`` is a sustained burn on the longest
    window past ``slow_burn``."""
    fast = [b for b in burns[:2] if b is not None]
    if fast and all(b >= slo.fast_burn for b in fast):
        return "page"
    if burns[-1] is not None and burns[-1] >= slo.slow_burn:
        return "warn"
    return "ok"


def _window_label(w: float) -> str:
    return f"{int(w)}s" if float(w).is_integer() else f"{w}s"


def worst_status(statuses: Iterable[str]) -> str:
    """The most severe of a set of SLO statuses (ok < warn < page)."""
    rank = {"ok": 0, "warn": 1, "page": 2}
    worst = "ok"
    for s in statuses:
        if rank.get(s, 0) > rank[worst]:
            worst = s
    return worst
