"""Plan introspection: partition-quality metrics + the ``explain()`` report.

The paper's two load-bearing claims — the nonlinear hash groups *similar*
rows together, and the competitive allocation balances load across blocks
— were, until this module, completely unobserved at runtime: telemetry
could show that a flush was slow, but not whether the partition itself was
bad.  The reordering-effectiveness literature (PAPERS.md) says partition
quality is matrix-dependent and often the whole story, so this module
computes it per plan, at admission, from quantities the tile build already
produced:

* **per-tile occupancy** — each tile streams ``group × lane`` slots from
  HBM whether useful or not; its nnz / slots ratio is the exact fraction
  of that traffic that was not padding (distribution summarized + a
  bounded-sample histogram);
* **row-group cost distribution** — tiles per output row group; the
  ``max/mean`` imbalance is the quantity a skewed matrix (one dense row
  block) blows up and a uniform one keeps near 1;
* **hash-group cohesion** — within-group row-pattern similarity: rows
  sharing a group ideally touch the same column blocks (their tiles pack
  densely); the same statistic under a seeded *random* grouping is the
  baseline, and the ratio is the measured value of the hash reordering;
* **competitive ratio** — the LPT replay of the paper's competitive
  allocation over per-block tile costs: modeled makespan / ideal balanced
  makespan.  Pinned near 1.0 the placement is fine; well above 1.0 a
  single block dominates and *no* schedule can recover it.

Everything is registered as **always-live labelled gauges** on the serving
registry's shared :class:`~repro.obs.metrics.MetricRegistry` (so they
scrape through the OpenMetrics exporter and land in every ``obs.dump()``),
alongside the autotune **decision provenance** (which candidates were
measured, what each cost, why the winner won, how ``k_tiling`` was
picked).  :func:`explain_report` joins the static picture with the
*measured* ``attr.*`` bandwidth-attribution counters into the per-matrix
"why is this fast or slow" report ``python -m repro.analysis.report
--explain MATRIX`` renders.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

__all__ = [
    "partition_quality",
    "register_plan_metrics",
    "plan_metrics_from_snapshot",
    "explain_report",
    "explain",
]

# quality keys that become always-live ``plan.<key>`` gauges per matrix
_GAUGE_KEYS = (
    "tiles",
    "nnz_utilization",
    "occupancy_mean",
    "occupancy_min",
    "occupancy_p10",
    "occupancy_p50",
    "occupancy_p90",
    "rowgroups",
    "rowgroup_imbalance",
    "competitive_ratio",
    "cohesion",
    "cohesion_random",
    "cohesion_score",
)

# bounded sample fed to the plan.tile_occupancy histogram: enough for
# stable percentiles, cheap enough for the per-admission budget
_OCCUPANCY_SAMPLE = 256

# at most this many autotune trials become labelled gauges (trials arrive
# sorted fastest-first, so the winner and its nearest rivals always land;
# the full list still lives in the plan provenance / cache entry)
_MAX_TRIAL_GAUGES = 8

# imbalance verdict thresholds on the competitive ratio
_BALANCED_BELOW = 1.15
_MILD_BELOW = 1.5


def _pooled_cohesion(footprint, rows, gids, n_groups, nbc) -> Optional[float]:
    """Pooled within-group column-footprint cohesion of one grouping.

    ``footprint`` is the boolean [n_rows, n_col_blocks] row-pattern matrix
    (row r touches column block j); ``rows``/``gids`` are the surviving
    (non-padded, non-empty) member rows and their group ids.  Per group:
    ``touches / (union_blocks * member_rows)`` — exactly 1.0 when every
    member row touches the identical block set, approaching 1/members when
    each row touches its own disjoint blocks.  Groups are pooled weighted
    by membership; ``None`` when nothing touches anything.
    """
    if rows.size == 0:
        return None
    # scatter-add via one flat bincount over the nonzero footprint entries
    # (np.add.at is an order of magnitude slower at admission scale)
    ii, jj = np.nonzero(footprint[rows])
    touch = np.bincount(
        gids[ii] * nbc + jj, minlength=n_groups * nbc
    ).reshape(n_groups, nbc)
    union = (touch > 0).sum(axis=1)
    members = np.bincount(gids, minlength=n_groups)
    live = union > 0
    denom = float((union[live] * members[live]).sum())
    return float(touch[live].sum() / denom) if denom > 0 else None


def partition_quality(
    tiles,
    csr=None,
    *,
    n_workers: int = 2,
    seed: int = 0,
) -> dict:
    """Static quality metrics of one built plan (see module docstring).

    ``tiles`` is the plan's :class:`~repro.core.tile.HBPTiles`; ``csr``
    (the admitted matrix) unlocks the cohesion scores — without it they
    are ``None``.  ``n_workers`` sizes the LPT competitive-ratio model
    (default 2: the megacore slots of one chip); ``seed`` fixes the
    random-grouping cohesion baseline so the gauges are deterministic.
    Everything is vectorised numpy over arrays the tile build already
    holds — cheap enough to run on every admission (``bench_obs`` pins
    the budget).
    """
    from repro.core.schedule import lpt_schedule

    occ = tiles.tile_occupancy()
    rg = tiles.rowgroup_costs().astype(np.float64)
    block = tiles.block_costs().astype(np.float64)

    out: dict = {
        "tiles": float(tiles.n_tiles),
        "nnz_utilization": tiles.nnz_utilization(),
        "rowgroups": float(tiles.n_rowgroups),
        "schedule_workers": float(n_workers),
    }
    if occ.size:
        p10, p50, p90 = np.percentile(occ, (10, 50, 90))
        out.update(
            occupancy_mean=float(occ.mean()),
            occupancy_min=float(occ.min()),
            occupancy_p10=float(p10),
            occupancy_p50=float(p50),
            occupancy_p90=float(p90),
        )
    else:
        out.update(
            occupancy_mean=None,
            occupancy_min=None,
            occupancy_p10=None,
            occupancy_p50=None,
            occupancy_p90=None,
        )
    out["rowgroup_imbalance"] = (
        float(rg.max() / rg.mean()) if rg.size and rg.mean() > 0 else 1.0
    )
    if block.sum() > 0:
        sched = lpt_schedule(block, n_workers)
        out["competitive_ratio"] = sched.competitive_ratio
    else:
        out["competitive_ratio"] = 1.0

    cohesion = cohesion_random = score = None
    if csr is not None and csr.nnz:
        from repro.core.partition import count_block_nnz

        footprint = count_block_nnz(csr, tiles.cfg) > 0
        n_rows = csr.shape[0]
        G, R = tiles.cfg.group, tiles.cfg.row_block
        cohesion = _grouping_cohesion(footprint, tiles.perm, G, n_rows)
        # baseline: the same rows grouped at random WITHIN each row block
        # (the hash only ever permutes inside a block, so that is the
        # fair counterfactual)
        rng = np.random.default_rng(seed)
        rand_perm = np.empty_like(tiles.perm)
        for bi in range(tiles.perm.size // R):
            rand_perm[bi * R : (bi + 1) * R] = rng.permutation(R) + bi * R
        cohesion_random = _grouping_cohesion(footprint, rand_perm, G, n_rows)
        if cohesion is not None and cohesion_random:
            score = cohesion / cohesion_random
    out.update(
        cohesion=cohesion, cohesion_random=cohesion_random, cohesion_score=score
    )
    out["occupancy_sample"] = occ[
        :: max(1, occ.size // _OCCUPANCY_SAMPLE)
    ].tolist()
    return out


def _grouping_cohesion(footprint, perm, group, n_rows) -> Optional[float]:
    """Cohesion of the grouping ``perm`` induces (see :func:`_pooled_cohesion`)."""
    n_pos = perm.size
    gids_all = np.arange(n_pos) // group
    valid = perm < n_rows
    rows = perm[valid]
    gids = gids_all[valid]
    nonempty = footprint[rows].any(axis=1)
    return _pooled_cohesion(
        footprint, rows[nonempty], gids[nonempty], n_pos // group, footprint.shape[1]
    )


def register_plan_metrics(
    metrics, name: str, quality: dict, provenance: Optional[dict] = None
) -> None:
    """Publish one plan's quality + provenance as always-live metrics.

    ``metrics`` is the serving registry's shared
    :class:`~repro.obs.metrics.MetricRegistry`; gauges are labelled
    ``matrix=name`` so they join the ``attr.*`` / ``serving.*`` families
    in dumps and OpenMetrics scrapes.  Numeric quality keys become
    ``plan.<key>`` gauges; the bounded occupancy sample feeds the
    ``plan.tile_occupancy`` histogram; autotune provenance lands as
    ``plan.autotune_*`` gauges (per-trial objective times labelled by the
    candidate geometry) plus ``plan.k_tiling_us`` per measured contract.
    """
    for key in _GAUGE_KEYS:
        v = quality.get(key)
        if v is not None:
            metrics.gauge(f"plan.{key}", matrix=name).set(float(v))
    sample = quality.get("occupancy_sample") or ()
    if sample:
        h = metrics.histogram(
            "plan.tile_occupancy",
            buckets=[round(0.1 * i, 1) for i in range(1, 11)],
            window=_OCCUPANCY_SAMPLE,
            matrix=name,
        )
        for v in sample:
            h.observe(float(v))
    if not provenance:
        return
    m = metrics
    m.gauge("plan.autotune_searched", matrix=name).set(
        1.0 if provenance.get("searched") else 0.0
    )
    m.gauge("plan.autotune_cache_hit", matrix=name).set(
        1.0 if provenance.get("cache_hit") else 0.0
    )
    m.gauge("plan.autotune_evaluations", matrix=name).set(
        float(provenance.get("evaluations") or 0)
    )
    if provenance.get("objective_us") is not None:
        m.gauge("plan.autotune_objective_us", matrix=name).set(
            float(provenance["objective_us"])
        )
    for trial in list(provenance.get("trials") or ())[:_MAX_TRIAL_GAUGES]:
        cfg = trial.get("config") or {}
        label = _config_label(cfg)
        m.gauge("plan.autotune_trial_us", matrix=name, config=label).set(
            float(trial["objective_us"])
        )
    for kt, us in sorted((provenance.get("k_tiling_us") or {}).items()):
        m.gauge("plan.k_tiling_us", matrix=name, k_tiling=kt).set(float(us))
    kt = provenance.get("k_tiling")
    if kt:
        m.gauge("plan.k_tiling_choice", matrix=name, k_tiling=kt).set(1.0)


def _config_label(cfg: dict) -> str:
    return (
        f"r{cfg.get('row_block', '?')}.c{cfg.get('col_block', '?')}"
        f".g{cfg.get('group', '?')}.l{cfg.get('lane', '?')}"
    )


# --- snapshot joins (the explain() data plane) ------------------------------


def plan_metrics_from_snapshot(snapshot: dict, matrix: str) -> dict:
    """Every ``plan.*`` metric for ``matrix`` out of an ``obs.dump()``
    snapshot: plain gauges as ``{short_name: value}``, the per-trial and
    per-contract families as sorted ``(label, value)`` lists under
    ``autotune_trials`` / ``k_tiling_us`` / ``k_tiling_choice``."""
    out: dict = {"autotune_trials": [], "k_tiling_us": [], "k_tiling_choice": []}
    for reg in snapshot.get("registries", []):
        for m in reg.get("metrics", []):
            name = m.get("name", "")
            lab = m.get("labels") or {}
            if lab.get("matrix") != matrix or not name.startswith("plan."):
                continue
            short = name[len("plan.") :]
            if name == "plan.autotune_trial_us":
                out["autotune_trials"].append((lab.get("config", "?"), m["value"]))
            elif name == "plan.k_tiling_us":
                out["k_tiling_us"].append((lab.get("k_tiling", "?"), m["value"]))
            elif name == "plan.k_tiling_choice":
                out["k_tiling_choice"].append(lab.get("k_tiling", "?"))
            elif "value" in m:
                out[short] = m["value"]
    out["autotune_trials"].sort(key=lambda t: (t[1], t[0]))
    out["k_tiling_us"].sort()
    out["k_tiling_choice"].sort()
    return out


def _fmt(v, digits: int = 3) -> str:
    if v is None:
        return "n/a"
    return f"{v:.{digits}f}"


def _verdict(pm: dict) -> List[str]:
    """The imbalance/cohesion verdict lines, n/a-safe."""
    lines = []
    cr = pm.get("competitive_ratio")
    if cr is None:
        lines.append("verdict: n/a — no partition-quality gauges in this dump")
        return lines
    if cr <= _BALANCED_BELOW:
        lines.append(
            f"verdict: balanced (competitive ratio {cr:.3f} <= "
            f"{_BALANCED_BELOW}) — the partition is not the bottleneck"
        )
    elif cr <= _MILD_BELOW:
        lines.append(
            f"verdict: mildly imbalanced (competitive ratio {cr:.3f}) — "
            "placement can still help; watch the dominant row groups"
        )
    else:
        lines.append(
            f"verdict: IMBALANCED (competitive ratio {cr:.3f} > {_MILD_BELOW}) "
            "— a few blocks dominate; no schedule can recover this, "
            "re-partition (smaller row_block / narrower lane) instead"
        )
    score = pm.get("cohesion_score")
    if score is not None:
        if score >= 1.2:
            lines.append(
                f"hash grouping is earning its keep: cohesion {score:.2f}x "
                "the random-grouping baseline"
            )
        elif score <= 1.05:
            lines.append(
                f"hash grouping adds little here (cohesion {score:.2f}x "
                "random) — rows are homogeneous or patterns are scattered"
            )
    return lines


def explain_report(snapshot: dict, matrix: str, *, hw=None) -> str:
    """The per-matrix "why is this fast or slow" report.

    Joins three planes of one ``obs.dump()`` snapshot: the static
    partition-quality gauges, the autotune decision provenance, and the
    measured ``attr.*`` bandwidth attribution vs the modeled roofline.
    Every section renders "n/a" on missing data (a dump taken before any
    traffic, or from a registry without plan introspection) and all rows
    are deterministically ordered.
    """
    from repro.analysis.roofline import V5E
    from repro.obs.attribution import attribution_rows

    hw = hw or V5E
    pm = plan_metrics_from_snapshot(snapshot, matrix)
    lines = [f"== explain: {matrix} =="]

    # --- partition quality -------------------------------------------------
    lines.append("-- partition quality --")
    if pm.get("tiles") is None:
        lines.append(
            "  n/a — no plan.* gauges for this matrix in the dump (admit it "
            "through a MatrixRegistry, then obs.dump() again)"
        )
    else:
        lines.append(
            f"  tiles={int(pm['tiles'])}  rowgroups={int(pm.get('rowgroups', 0))}  "
            f"nnz_utilization={_fmt(pm.get('nnz_utilization'))}"
        )
        lines.append(
            "  tile occupancy: "
            f"p10={_fmt(pm.get('occupancy_p10'))} "
            f"p50={_fmt(pm.get('occupancy_p50'))} "
            f"p90={_fmt(pm.get('occupancy_p90'))} "
            f"(mean {_fmt(pm.get('occupancy_mean'))}, "
            f"min {_fmt(pm.get('occupancy_min'))})"
        )
        lines.append(
            f"  rowgroup imbalance (max/mean cost): "
            f"{_fmt(pm.get('rowgroup_imbalance'))}"
        )
        lines.append(
            f"  competitive ratio (LPT makespan / ideal): "
            f"{_fmt(pm.get('competitive_ratio'))}"
        )
        lines.append(
            f"  hash-group cohesion: {_fmt(pm.get('cohesion'))} "
            f"vs random {_fmt(pm.get('cohesion_random'))} "
            f"(score {_fmt(pm.get('cohesion_score'), 2)}x)"
        )

    # --- autotune provenance ----------------------------------------------
    lines.append("-- autotune provenance --")
    searched = pm.get("autotune_searched")
    if searched is None:
        lines.append("  n/a — no autotune gauges for this matrix")
    else:
        if searched:
            src = "measured search"
        elif pm.get("autotune_cache_hit"):
            src = "on-disk cache hit"
        else:
            src = "heuristic/pinned config"
        evals = int(pm.get("autotune_evaluations") or 0)
        obj = pm.get("autotune_objective_us")
        lines.append(
            f"  decision: {src}, {evals} candidate(s) measured"
            + (f", winner objective {obj:.1f}us" if obj is not None else "")
        )
        trials = pm["autotune_trials"]
        if trials:
            best = trials[0][1]
            for i, (label, us) in enumerate(trials):
                delta = "winner" if i == 0 else f"+{100 * (us / best - 1):.1f}%"
                lines.append(f"    {label:<24} {us:>10.1f}us  {delta}")
        choice = pm["k_tiling_choice"]
        kt_us = dict(pm["k_tiling_us"])
        if kt_us:
            measured = "  ".join(f"{kt}={us:.1f}us" for kt, us in sorted(kt_us.items()))
            lines.append(
                f"  k_tiling: {', '.join(choice) or '?'} (measured: {measured})"
            )
        elif choice:
            lines.append(
                f"  k_tiling: {', '.join(choice)} "
                "(contracts coincide at the served width — no measurement needed)"
            )

    # --- measured traffic vs model ----------------------------------------
    lines.append("-- measured traffic (modeled vs measured bandwidth) --")
    rows = [r for r in attribution_rows(snapshot, hw=hw) if r["matrix"] == matrix]
    if not rows:
        lines.append("  n/a — no attr.* counters for this matrix (serve traffic first)")
    for r in rows:
        ach = r["achieved_gbps"]
        frac = r["roofline_fraction"]
        lines.append(
            f"  strategy={r['strategy']} k_tiling={r['k_tiling']}: "
            f"launches={r['launches']} "
            f"modeled={1e3 * r['modeled_s']:.3f}ms measured={1e3 * r['measured_s']:.3f}ms "
            f"achieved={'n/a' if ach is None else f'{ach:.3f}'} GB/s"
            + (
                ""
                if frac is None
                else f" = {100 * frac:.1f}% of {hw.name} HBM"
            )
            + ("  [BELOW-ROOFLINE]" if r["below_roofline"] else "")
        )

    # --- verdict -----------------------------------------------------------
    lines.extend(_verdict(pm))
    return "\n".join(lines) + "\n"


def explain(matrix: str, snapshot: Optional[dict] = None, *, hw=None) -> str:
    """Live convenience: explain ``matrix`` from the current process state
    (or a provided ``obs.dump()`` snapshot)."""
    if snapshot is None:
        from repro import obs

        snapshot = obs.collect()
    return explain_report(snapshot, matrix, hw=hw)
