"""Text dashboard + artifact writer over the metrics/trace snapshots.

:func:`render` turns a collected snapshot (the dict :func:`repro.obs.dump`
writes) into the terminal dashboard; :func:`repro.obs.report` renders the
live process state through the same path, and ``python -m
repro.analysis.report --obs DUMP.json`` re-renders a dumped artifact —
one formatter for live and post-mortem views.
"""
from __future__ import annotations

from typing import List

__all__ = ["render", "amortization_ledger"]


def _fmt(v, unit: str = "") -> str:
    # empty-window percentiles and unset fields arrive as None — render a
    # readable placeholder, never crash and never print a bare "None"
    if v is None:
        return "n/a"
    if isinstance(v, float):
        if v != 0 and (abs(v) < 1e-3 or abs(v) >= 1e6):
            return f"{v:.3e}{unit}"
        return f"{v:,.6g}{unit}"
    return f"{v}{unit}"


def _labels(m: dict) -> str:
    lab = m.get("labels") or {}
    if not lab:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(lab.items())) + "}"


def _rows(title: str, header: List[str], rows: List[List[str]]) -> List[str]:
    if not rows:
        return []
    widths = [max(len(h), *(len(r[i]) for r in rows)) for i, h in enumerate(header)]
    out = [f"-- {title} --"]
    out.append("  ".join(h.ljust(w) for h, w in zip(header, widths)).rstrip())
    for r in rows:
        out.append("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())
    out.append("")
    return out


def amortization_ledger(snapshot: dict) -> List[dict]:
    """The paper's cost ledger, per matrix: one-time preprocessing seconds
    vs requests served, and the amortized cost per request.

    Derived purely from the shared serving counters
    (``registry.preprocess_s`` / ``serving.requests``), so the engine's
    and registry's ``stats()`` views and this ledger can never disagree.
    """
    pre: dict = {}
    req: dict = {}
    for reg in snapshot.get("registries", []):
        for m in reg["metrics"]:
            key = (m.get("labels") or {}).get("matrix")
            if key is None:
                continue
            if m["name"] == "registry.preprocess_s":
                pre[key] = pre.get(key, 0.0) + m["value"]
            elif m["name"] == "serving.requests":
                req[key] = req.get(key, 0.0) + m["value"]
    ledger = []
    for key in sorted(set(pre) | set(req)):
        n = int(req.get(key, 0))
        p = pre.get(key, 0.0)
        ledger.append(
            {
                "matrix": key,
                "preprocess_s": p,
                "requests": n,
                "amortized_preprocess_s": (p / n) if n else None,
            }
        )
    return ledger


def render(snapshot: dict) -> str:
    """The obs dashboard: counters, gauges, histograms, series, spans."""
    counters, gauges, hists, series = [], [], [], []
    for reg in snapshot.get("registries", []):
        rname = reg.get("registry", "")
        for m in reg["metrics"]:
            tag = f"{m['name']}{_labels(m)}"
            if len(snapshot.get("registries", [])) > 1 and rname != "global":
                tag = f"[{rname}] {tag}"
            if m["type"] == "counter":
                counters.append([tag, _fmt(m["value"])])
            elif m["type"] == "gauge":
                gauges.append([tag, _fmt(m["value"])])
            elif m["type"] == "histogram":
                hists.append(
                    [
                        tag,
                        str(m["count"]),
                        _fmt(m.get("p50")),
                        _fmt(m.get("p95")),
                        _fmt(m.get("p99")),
                        _fmt(m.get("max")),
                    ]
                )
            elif m["type"] == "series":
                series.append(
                    [
                        tag,
                        str(m["count"]),
                        _fmt(m.get("first")),
                        _fmt(m.get("last")),
                        _fmt(m.get("min")),
                    ]
                )

    # deterministic dashboards: rows sorted by tag regardless of the order
    # metrics were created in (CI artifacts diff cleanly run-to-run)
    for table in (counters, gauges, hists, series):
        table.sort(key=lambda r: r[0])

    lines: List[str] = ["== repro.obs report =="]
    lines.append("")
    lines += _rows("counters", ["name", "value"], counters)
    lines += _rows("gauges", ["name", "value"], gauges)
    lines += _rows(
        "histograms", ["name", "count", "p50", "p95", "p99", "max"], hists
    )
    lines += _rows("series", ["name", "count", "first", "last", "min"], series)

    ledger = amortization_ledger(snapshot)
    lines += _rows(
        "amortization ledger (preprocess vs traffic)",
        ["matrix", "preprocess_s", "requests", "amortized_s/req"],
        [
            [
                row["matrix"],
                _fmt(row["preprocess_s"]),
                str(row["requests"]),
                _fmt(row["amortized_preprocess_s"]),
            ]
            for row in ledger
        ],
    )

    spans = snapshot.get("spans", [])
    lines += _rows(
        "spans (by total time)",
        ["name", "count", "total_ms", "mean_ms", "max_ms"],
        [
            [
                s["name"],
                str(s["count"]),
                _fmt(s["total_ms"]),
                _fmt(s["mean_ms"]),
                _fmt(s["max_ms"]),
            ]
            for s in spans
        ],
    )
    # bandwidth attribution, when the serving engine recorded attr.* counters
    from .attribution import attribution_rows, render_attribution

    attr = attribution_rows(snapshot)
    if attr:
        lines.append(render_attribution(attr).rstrip())
        lines.append("")

    # per-request decomposition, when the snapshot carries a request log
    if snapshot.get("requests"):
        from .requesttrace import waterfall

        lines.append(waterfall(snapshot, n=5).rstrip())
        lines.append("")

    fl = snapshot.get("flight")
    if fl and fl.get("recorded_total"):
        lines.append(
            f"-- flight recorder: {fl['events']}/{fl['capacity']} events "
            f"({fl['recorded_total']} recorded, {fl['overwritten']} overwritten, "
            f"{len(fl.get('dumps', []))} dumps, "
            f"{fl.get('suppressed_triggers', 0)} suppressed triggers) --"
        )
        lines.append("")

    dropped = snapshot.get("dropped_events", 0)
    if dropped:
        lines.append(f"!! {dropped} trace events dropped (buffer full)")
    if len(lines) == 2:
        lines.append("(no metrics recorded)")
    return "\n".join(lines).rstrip() + "\n"
