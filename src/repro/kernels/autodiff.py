"""Differentiable HBP aggregation: custom VJPs that stay on the kernel path.

``jax.grad`` through a plain aggregation closure would trace *into* the
SpMM implementation and transpose whatever it finds there — a thicket of
gathers and segment sums, or a Pallas kernel it cannot differentiate at
all.  But the backward pass of sparse aggregation has a closed form that
is itself an HBP SpMM:

* ``sum``:  ``y = A @ x``            → ``x̄ = Aᵀ @ ȳ``
* ``mean``: ``y = (A @ x) / d``      → ``x̄ = Aᵀ @ (ȳ / d)``
* ``max``:  ``y[i,c] = max_j a_ij x[j,c]`` → route ``ȳ[i,c]`` to the
  winning neighbor: ``x̄[j*,c] += a_{i,j*} ȳ[i,c]``

so training reuses the paper's admit-once/multiply-many economics: A and
Aᵀ are each one cheap hash-based preprocessing pass (:func:`hbp_transpose`
builds the pair together), and every backward step is one more launch of
the same kernels the forward uses.

Two wrapper flavors are exposed via ``mode``:

* ``"vjp"`` (default, the training path) — a dual :func:`jax.custom_vjp`
  pair: the backward of ``A @ x`` *is* the ``Aᵀ`` SpMM launch, and the
  backward of that backward is the ``A`` launch again, so reverse-mode
  works to any order.  Forward-mode (``jax.jvp``) is not supported on
  ``custom_vjp`` functions by JAX itself.
* ``"jvp"`` — a :func:`jax.custom_jvp` wrapper whose tangent is a second
  ``A`` SpMM launch (exact, since the op is linear).  Forward-mode is
  first-class; reverse-mode is derived by transposing that tangent
  launch's trace — correct, but the cotangent program is the transposed
  gather/segment graph rather than the resident ``Aᵀ`` tile stream.

``max`` uses :func:`jax.custom_jvp` with argmax routing under both modes
(its forward saves the winning-neighbor indices via the one-pass
paired-payload argmax SpMM of :func:`repro.kernels.ops.hbp_spmm_argmax` —
value, index and coefficient advance together through a single
tile-stream traversal; JAX transposes the tangent's gather into exactly
the argmax-routed cotangent scatter), so it supports forward and reverse
mode alike.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.formats import CSRMatrix
from repro.core.tile import HBPTiles, build_tiles, tuned_partition_config

from . import ops

__all__ = [
    "PairedTiles",
    "hbp_transpose",
    "linear_spmm_vjp",
    "linear_spmm_jvp",
    "argmax_spmm_diff",
    "diff_aggregator",
    "device_diff_aggregator",
    "mean_divisor",
    "needs_transpose",
]

DIFF_MODES = ("vjp", "jvp")


class PairedTiles(NamedTuple):
    """HBP tile formats of a matrix and its transpose, built together.

    The pair is what the differentiable ops consume: ``tiles`` serves the
    forward launches, ``tiles_T`` the cotangent launches.  Geometry is
    tuned per side — A's row-nnz profile and Aᵀ's (A's column profile)
    generally differ, so each side gets its own partition config.
    ``tiles_T`` may be ``None`` for ops that never launch Aᵀ (see
    :func:`needs_transpose`).
    """

    tiles: HBPTiles
    tiles_T: Optional[HBPTiles]


def hbp_transpose(
    csr: CSRMatrix,
    cfg=None,
    cfg_T=None,
    *,
    method: str = "hash",
) -> PairedTiles:
    """Host-side CSR transpose + paired tile build: ``(tiles, tiles_T)``.

    One preprocessing pass per side — the transpose itself is a stable
    counting sort (:meth:`~repro.core.formats.CSRMatrix.transpose`), and
    each side's tile geometry is tuned from its own nnz profile unless
    pinned by ``cfg``/``cfg_T``.  For serving-registry residency (content
    hashing links A ↔ Aᵀ so re-admission of either is free) use
    :meth:`repro.serving.registry.MatrixRegistry.admit_pair` instead.
    """
    csr_T = csr.transpose()
    tiles = build_tiles(csr, cfg or tuned_partition_config(csr), method=method)
    tiles_T = build_tiles(csr_T, cfg_T or tuned_partition_config(csr_T), method=method)
    return PairedTiles(tiles, tiles_T)


def linear_spmm_vjp(
    apply_A: Callable[[jax.Array], jax.Array],
    apply_AT: Callable[[jax.Array], jax.Array],
) -> Callable[[jax.Array], jax.Array]:
    """Wrap a linear map and its transpose as a dual ``custom_vjp`` pair.

    ``grad`` of the result launches ``apply_AT`` on the cotangent, and
    ``grad`` of *that* launches ``apply_A`` again — reverse-mode composes
    to any order without ever tracing inside either implementation.
    """

    @jax.custom_vjp
    def f(x):
        return apply_A(x)

    @jax.custom_vjp
    def fT(g):
        return apply_AT(g)

    f.defvjp(lambda x: (apply_A(x), None), lambda _, g: (fT(g),))
    fT.defvjp(lambda g: (apply_AT(g), None), lambda _, v: (f(v),))
    return f


def linear_spmm_jvp(
    apply_A: Callable[[jax.Array], jax.Array],
) -> Callable[[jax.Array], jax.Array]:
    """Wrap a linear map as a ``custom_jvp``: tangent = a second launch.

    Forward-mode is exact and never differentiates the implementation;
    reverse-mode transposes the tangent launch's trace (correct, but not
    the resident-Aᵀ path — prefer :func:`linear_spmm_vjp` for training).
    """

    @jax.custom_jvp
    def f(x):
        return apply_A(x)

    @f.defjvp
    def _jvp(primals, tangents):
        (x,), (t,) = primals, tangents
        return apply_A(x), apply_A(t)

    return f


def argmax_spmm_diff(
    dt: ops.DeviceTiles,
    *,
    n_rowgroups: int,
    n_rows: int,
    col_block: int,
    passes: int = 1,
) -> Callable[[jax.Array], jax.Array]:
    """Differentiable max-aggregation over staged tiles.

    Forward runs the argmax SpMM — by default the one-pass paired-payload
    kernel (max value + winning-neighbor index + winning coefficient
    carried through a single tile-stream traversal; ``passes=3`` keeps
    the legacy three-monoid-pass recovery); the tangent gathers
    ``coeff * t[idx]`` and JAX's transpose of that gather is the
    argmax-routed cotangent scatter.  Ties route to the lowest winning
    column; rows with no live entry get zero output and pass no gradient
    — identical conventions under either pass count.
    """
    meta = dict(
        n_rowgroups=n_rowgroups, n_rows=n_rows, col_block=col_block, passes=passes
    )

    @jax.custom_jvp
    def f(x):
        y, _, _ = ops.hbp_spmm_argmax(dt, x, **meta)
        return y

    @f.defjvp
    def _jvp(primals, tangents):
        (x,), (t,) = primals, tangents
        y, idx, coeff = ops.hbp_spmm_argmax(dt, x, **meta)
        picked = jnp.take_along_axis(t, jnp.maximum(idx, 0), axis=0)
        return y, jnp.where(idx >= 0, coeff * picked, 0.0)

    return f


def mean_divisor(degree, n_rows: int) -> jax.Array:
    """[n, 1] clamped in-degree: mean over an empty neighborhood is 0.

    The single home of the clamp convention — the graph aggregators and
    the serving registry delegate here, so mean forward and mean backward
    can never disagree about empty rows.  Accepts numpy or jax input
    without a device -> host round trip.
    """
    d = jnp.asarray(degree, jnp.float32).reshape(n_rows, 1)
    return jnp.maximum(d, 1.0)


def device_diff_aggregator(
    dt: ops.DeviceTiles,
    dt_T: Optional[ops.DeviceTiles],
    meta: dict,
    meta_T: Optional[dict],
    *,
    op: str = "sum",
    degree=None,
    mode: str = "vjp",
) -> Callable[[jax.Array], jax.Array]:
    """Differentiable aggregation closure over already-staged tiles.

    ``meta``/``meta_T`` are the keyword dicts :func:`repro.kernels.ops.
    hbp_spmm` needs beyond the tiles (``n_rowgroups``, ``n_rows``,
    ``col_block``, ``strategy``, ``interpret``, optionally ``k_tiling``).  ``dt_T`` may be ``None``
    for ``op="max"`` (its backward is a scatter, not a transpose SpMM)
    and for ``mode="jvp"``.  This is the layer
    :meth:`~repro.serving.registry.MatrixPlan.diff_aggregator` and
    :func:`repro.graph.aggregate.make_diff_aggregator` both sit on.
    """
    if mode not in DIFF_MODES:
        raise ValueError(f"unknown mode {mode!r} (expected one of {DIFF_MODES})")
    if op == "max":
        return argmax_spmm_diff(
            dt,
            n_rowgroups=meta["n_rowgroups"],
            n_rows=meta["n_rows"],
            col_block=meta["col_block"],
        )
    if op not in ("sum", "mean"):
        raise ValueError(f"unknown aggregation {op!r} (expected sum, mean or max)")

    div = None
    if op == "mean":
        if degree is None:
            raise ValueError("op='mean' needs the degree vector (degrees(adj))")
        div = mean_divisor(degree, meta["n_rows"])

    def apply_A(x):
        y = ops.hbp_spmm(dt, x, **meta)
        return y / div if div is not None else y

    if mode == "jvp":
        return linear_spmm_jvp(apply_A)
    if dt_T is None or meta_T is None:
        raise ValueError("mode='vjp' needs the transpose tiles (build with hbp_transpose)")

    def apply_AT(g):
        g = g / div if div is not None else g
        return ops.hbp_spmm(dt_T, g, **meta_T)

    return linear_spmm_vjp(apply_A, apply_AT)


def needs_transpose(op: str, mode: str) -> bool:
    """Whether the differentiable op launches the Aᵀ tiles at all: only
    the linear ops' ``"vjp"`` backward does — max routes a scatter and
    the ``"jvp"`` flavor re-launches A, so neither pays for a transpose
    build or residency."""
    return mode == "vjp" and op in ("sum", "mean")


def diff_aggregator(
    pair: PairedTiles,
    *,
    op: str = "sum",
    degree=None,
    strategy: str = "stable",
    interpret: bool | None = None,
    mode: str = "vjp",
) -> Callable[[jax.Array], jax.Array]:
    """Stage a :class:`PairedTiles` and return a differentiable aggregator.

    The graph-level entry with CSR handling and degree defaulting is
    :func:`repro.graph.aggregate.make_diff_aggregator`; this layer works
    directly on the tile pair (e.g. prebuilt by :func:`hbp_transpose`).
    When the op never launches Aᵀ (see :func:`needs_transpose`) the
    transpose side is not staged — ``pair.tiles_T`` may then be ``None``.
    """
    tiles, tiles_T = pair

    def _meta(t: HBPTiles) -> dict:
        return dict(
            n_rowgroups=t.n_rowgroups,
            n_rows=t.shape[0],
            col_block=t.cfg.col_block,
            strategy=strategy,
            interpret=interpret,
        )

    stage_t = needs_transpose(op, mode) and tiles_T is not None
    return device_diff_aggregator(
        ops.device_tiles(tiles),
        ops.device_tiles(tiles_T) if stage_t else None,
        _meta(tiles),
        _meta(tiles_T) if stage_t else None,
        op=op,
        degree=degree,
        mode=mode,
    )
