"""Pallas TPU kernels for HBP SpMV.

Two kernel strategies, both consuming the tile format of
:mod:`repro.core.tile`:

* :func:`hbp_spmv_fused` — **fused combine** (beyond-paper, TPU-enabled).
  The grid walks tiles sorted by (row-group, col-block); consecutive tiles
  of the same row group accumulate into the same output ref, so the
  "combine part" of Fig. 1 disappears into the SpMV pass.  On the GPU the
  paper tried this fusion and found atomics too expensive (Discussion
  section); the TPU's sequential grid gives it for free.

* :func:`hbp_spmv_partials` — **faithful two-phase**: each tile writes its
  own partial vector; the combine is a separate segment-sum (see
  ``ops.hbp_spmv(..., strategy="partials")``).  This mirrors the paper's
  SpMV-part/combine-part split and is kept as the paper-faithful baseline
  the fused kernel is measured against (EXPERIMENTS.md §Perf).

VMEM budget per grid step (defaults: group=8, lane=128, col_block=4096):
data tile 8×128×4 B = 4 KiB, col tile 4 KiB, x segment 16 KiB, y block
32 B — trivially double-buffered in ~128 MiB of VMEM.  The x segment is
fetched only when ``colblock[t]`` changes (Pallas skips the copy when the
index map returns the same block), which the (row-group, col-block) sort
keeps infrequent; this is the VMEM analogue of the paper's shared-memory
vector-segment reuse.

The gather ``jnp.take(seg, cols)`` maps to Mosaic's dynamic-gather on the
lane dimension (int32 indices into VMEM).  Kernels are validated against
``ref.py`` in ``interpret=True`` mode on CPU; TPU is the deployment target.

Both strategies also come in **multi-RHS SpMM** form
(:func:`hbp_spmm_fused` / :func:`hbp_spmm_partials`): ``X: [n, k]`` is
staged as ``[n_col_blocks, col_block, k]`` segments with the RHS columns in
the lane dimension, so one launch reads the tile stream once for all ``k``
right-hand sides — the workload shape of blocked Krylov solvers and
multi-personalization PageRank (see ``repro.solvers``).

**2D k-tiled grid.**  One VREG holds :data:`LANE_TILE` = 128 lanes, so a
single grid step carries at most 128 RHS columns.  Wider feature blocks
(``k`` a multiple of 128, padded by the caller) run on a **2D grid**
instead of the legacy host-side loop of ceil(k/128) separate launches
(``ops.hbp_spmm(..., k_tiling="loop")`` keeps that geometry for
comparison).  The two kernel families tile k differently, because Pallas
TPU only preserves an output block across *consecutive* grid steps:

* **partials** — grid ``(T, k // LANE_TILE)``, tile-major.  Every step
  writes its own output block ``(t, j)``, so no revisit is needed; the
  (data, cols) block maps depend only on ``t`` and Pallas fetches each
  tile ONCE, revisited across k-tiles — the stream is read once total.
* **fused** — grid ``(k // LANE_TILE, T)``, k-tile-major (outer).  The
  fused combine *accumulates* into output block ``(rg[t], j)``, which is
  only well-defined while revisits are consecutive — so the reduction
  dimension ``t`` must be innermost.  For each k-tile the t sweep re-reads
  the stream (same bytes as the legacy loop), but the whole width is one
  launch: no per-chunk host round-trips, and the grid pipeline overlaps
  the k-tiles' transfers.

Each in-flight block spans ≤128 lanes, so no step spills the VPU's lane
dimension, and interpret-mode results are bitwise-identical to the
legacy loop chunking (same per-(rg, j) accumulation order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "LANE_TILE",
    "hbp_spmv_fused",
    "hbp_spmv_partials",
    "hbp_spmm_fused",
    "hbp_spmm_partials",
    "hbp_spmm_fused_max",
    "hbp_spmm_partials_max",
]

# Widest RHS block one grid step carries: k sits in the lane dimension of
# the x segment and the output tile, and one VREG holds 128 lanes.  Wider
# k runs the 2D k-tiled grid (k-tile inner, tile stream fetched once).
LANE_TILE = 128


def _k_grid(k: int):
    """(k_tile, n_k_tiles) of the 2D launch; k > LANE_TILE must be padded
    to a LANE_TILE multiple by the caller (``ops._hbp_spmm_device`` does)."""
    if k <= LANE_TILE:
        return k, 1
    if k % LANE_TILE:
        raise ValueError(
            f"k = {k} exceeds one lane tile ({LANE_TILE}) and is not a "
            "multiple of it — pad the RHS block before launching"
        )
    return LANE_TILE, k // LANE_TILE


def _fused_kernel(rowgroup_ref, colblock_ref, first_ref, data_ref, cols_ref, x_ref, y_ref):
    """One grid step = one tile: y[rowgroup[t]] += (data * x_seg[cols]).sum(lanes)."""
    t = pl.program_id(0)

    @pl.when(first_ref[t] == 1)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    seg = x_ref[0]  # [col_block] vector segment, VMEM resident
    gathered = jnp.take(seg, cols_ref[0], axis=0)  # [group, lane]
    y_ref[0, :] += jnp.sum(data_ref[0] * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("n_rowgroups", "interpret"))
def hbp_spmv_fused(
    rowgroup: jax.Array,  # i32[T]
    colblock: jax.Array,  # i32[T]
    first: jax.Array,  # i32[T]
    data: jax.Array,  # f32[T, group, lane]
    cols: jax.Array,  # i32[T, group, lane]
    x_blocked: jax.Array,  # f32[n_col_blocks, col_block]
    *,
    n_rowgroups: int,
    interpret: bool = False,
) -> jax.Array:
    """Fused-combine HBP SpMV.  Returns y in hashed row order,
    shape [n_rowgroups, group]."""
    T, group, lane = data.shape
    col_block = x_blocked.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, group, lane), lambda t, rg, cb, fs: (t, 0, 0)),
            pl.BlockSpec((1, group, lane), lambda t, rg, cb, fs: (t, 0, 0)),
            pl.BlockSpec((1, col_block), lambda t, rg, cb, fs: (cb[t], 0)),
        ],
        out_specs=pl.BlockSpec((1, group), lambda t, rg, cb, fs: (rg[t], 0)),
    )
    return pl.pallas_call(
        _fused_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rowgroups, group), jnp.float32),
        interpret=interpret,
    )(rowgroup, colblock, first, data, cols, x_blocked)


def _fused_spmm_kernel(rowgroup_ref, colblock_ref, first_ref, data_ref, cols_ref, x_ref, y_ref):
    """Multi-RHS variant: y[rowgroup[t]] += einsum('gl,glk->gk', data, x_seg[cols]).

    The tile index t is the LAST grid dimension (k-tile-major 2D grid):
    the accumulation revisits output block (rg[t], j), and Pallas TPU
    preserves an output block only across consecutive grid steps — so the
    reduction dim t must be innermost."""
    t = pl.program_id(1)

    @pl.when(first_ref[t] == 1)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    seg = x_ref[0]  # [col_block, k]: RHS columns live in the lane dimension
    gathered = jnp.take(seg, cols_ref[0], axis=0)  # [group, lane, k]
    y_ref[0] += jnp.sum(data_ref[0][..., None] * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("n_rowgroups", "interpret"))
def hbp_spmm_fused(
    rowgroup: jax.Array,  # i32[T]
    colblock: jax.Array,  # i32[T]
    first: jax.Array,  # i32[T]
    data: jax.Array,  # f32[T, group, lane]
    cols: jax.Array,  # i32[T, group, lane]
    x_blocked: jax.Array,  # f32[n_col_blocks, col_block, k]
    *,
    n_rowgroups: int,
    interpret: bool = False,
) -> jax.Array:
    """Fused-combine HBP SpMM (multi-RHS): ``Y = A @ X`` with ``X: [n, k]``.

    One kernel launch serves all ``k`` right-hand sides: the tile stream
    (data + cols, the dominant HBM traffic) is read ONCE instead of ``k``
    times, so blocked iterative solvers and multi-personalization PageRank
    amortize the format bytes across RHS columns.  ``k`` sits in the lane
    dimension (the x segment is ``[col_block, k]``), keeping the gather on
    the sublane axis exactly as in the SpMV kernel; beyond one lane tile
    the grid grows a k-tile dimension — OUTER, because the fused combine's
    output revisits must stay consecutive in t (module docstring).
    Returns y in hashed row order, shape [n_rowgroups, group, k].
    """
    T, group, lane = data.shape
    col_block, k = x_blocked.shape[1], x_blocked.shape[2]
    kt, n_kt = _k_grid(k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_kt, T),
        in_specs=[
            pl.BlockSpec((1, group, lane), lambda j, t, rg, cb, fs: (t, 0, 0)),
            pl.BlockSpec((1, group, lane), lambda j, t, rg, cb, fs: (t, 0, 0)),
            pl.BlockSpec((1, col_block, kt), lambda j, t, rg, cb, fs: (cb[t], 0, j)),
        ],
        out_specs=pl.BlockSpec((1, group, kt), lambda j, t, rg, cb, fs: (rg[t], 0, j)),
    )
    return pl.pallas_call(
        _fused_spmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rowgroups, group, k), jnp.float32),
        interpret=interpret,
    )(rowgroup, colblock, first, data, cols, x_blocked)


def _fused_spmm_max_kernel(rowgroup_ref, colblock_ref, first_ref, data_ref, cols_ref, x_ref, y_ref):
    """Max-monoid fused combine: y[rowgroup[t]] = max(y, tile's lane max).

    Padded slots (stored value 0) are masked to -inf — the max identity —
    instead of contributing 0; empty output rows therefore come back -inf
    for the host wrapper to zero (``ops._hbp_spmm_device``).  Like the sum
    variant, t is the last (innermost) grid dim: the maximum accumulation
    revisits its output block and revisits must be consecutive."""
    t = pl.program_id(1)

    @pl.when(first_ref[t] == 1)
    def _init():
        y_ref[...] = jnp.full_like(y_ref, -jnp.inf)

    seg = x_ref[0]  # [col_block, k]
    gathered = jnp.take(seg, cols_ref[0], axis=0)  # [group, lane, k]
    d = data_ref[0][..., None]  # [group, lane, 1]
    masked = jnp.where(d != 0, d * gathered, -jnp.inf)
    y_ref[0] = jnp.maximum(y_ref[0], jnp.max(masked, axis=1))


@functools.partial(jax.jit, static_argnames=("n_rowgroups", "interpret"))
def hbp_spmm_fused_max(
    rowgroup: jax.Array,  # i32[T]
    colblock: jax.Array,  # i32[T]
    first: jax.Array,  # i32[T]
    data: jax.Array,  # f32[T, group, lane]
    cols: jax.Array,  # i32[T, group, lane]
    x_blocked: jax.Array,  # f32[n_col_blocks, col_block, k]
    *,
    n_rowgroups: int,
    interpret: bool = False,
) -> jax.Array:
    """Fused-combine HBP SpMM under the max monoid (GNN max-aggregation).

    Identical tile stream and revisit pattern to :func:`hbp_spmm_fused`
    (including the k-tile-OUTER 2D grid beyond one lane tile); the
    accumulation is ``maximum`` with identity ``-inf`` instead of ``+``
    with identity 0.  Returns hashed-order [n_rowgroups, group, k] with
    ``-inf`` in rows that saw no live entry.
    """
    T, group, lane = data.shape
    col_block, k = x_blocked.shape[1], x_blocked.shape[2]
    kt, n_kt = _k_grid(k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_kt, T),
        in_specs=[
            pl.BlockSpec((1, group, lane), lambda j, t, rg, cb, fs: (t, 0, 0)),
            pl.BlockSpec((1, group, lane), lambda j, t, rg, cb, fs: (t, 0, 0)),
            pl.BlockSpec((1, col_block, kt), lambda j, t, rg, cb, fs: (cb[t], 0, j)),
        ],
        out_specs=pl.BlockSpec((1, group, kt), lambda j, t, rg, cb, fs: (rg[t], 0, j)),
    )
    return pl.pallas_call(
        _fused_spmm_max_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rowgroups, group, k), jnp.float32),
        interpret=interpret,
    )(rowgroup, colblock, first, data, cols, x_blocked)


def _partials_spmm_max_kernel(colblock_ref, data_ref, cols_ref, x_ref, y_ref):
    """Max-monoid partials: one tile emits its masked [group, k] lane max."""
    seg = x_ref[0]
    gathered = jnp.take(seg, cols_ref[0], axis=0)  # [group, lane, k]
    d = data_ref[0][..., None]
    masked = jnp.where(d != 0, d * gathered, -jnp.inf)
    y_ref[0] = jnp.max(masked, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hbp_spmm_partials_max(
    colblock: jax.Array,  # i32[T]
    data: jax.Array,  # f32[T, group, lane]
    cols: jax.Array,  # i32[T, group, lane]
    x_blocked: jax.Array,  # f32[n_col_blocks, col_block, k]
    *,
    interpret: bool = False,
) -> jax.Array:
    """SpMM part only under the max monoid: per-tile partial blocks
    [T, group, k]; the combine part reduces them with ``segment_max``.
    Wide k runs the 2D k-tiled grid like the sum variant."""
    T, group, lane = data.shape
    col_block, k = x_blocked.shape[1], x_blocked.shape[2]
    kt, n_kt = _k_grid(k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T, n_kt),
        in_specs=[
            pl.BlockSpec((1, group, lane), lambda t, j, cb: (t, 0, 0)),
            pl.BlockSpec((1, group, lane), lambda t, j, cb: (t, 0, 0)),
            pl.BlockSpec((1, col_block, kt), lambda t, j, cb: (cb[t], 0, j)),
        ],
        out_specs=pl.BlockSpec((1, group, kt), lambda t, j, cb: (t, 0, j)),
    )
    return pl.pallas_call(
        _partials_spmm_max_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, group, k), jnp.float32),
        interpret=interpret,
    )(colblock, data, cols, x_blocked)


def _partials_kernel(colblock_ref, data_ref, cols_ref, x_ref, y_ref):
    """One grid step = one tile: emit the tile's own partial result."""
    seg = x_ref[0]
    gathered = jnp.take(seg, cols_ref[0], axis=0)
    y_ref[0, :] = jnp.sum(data_ref[0] * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hbp_spmv_partials(
    colblock: jax.Array,  # i32[T]
    data: jax.Array,  # f32[T, group, lane]
    cols: jax.Array,  # i32[T, group, lane]
    x_blocked: jax.Array,  # f32[n_col_blocks, col_block]
    *,
    interpret: bool = False,
) -> jax.Array:
    """SpMV part only (paper-faithful): per-tile partial vectors
    [T, group]; the combine part reduces them by row group."""
    T, group, lane = data.shape
    col_block = x_blocked.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, group, lane), lambda t, cb: (t, 0, 0)),
            pl.BlockSpec((1, group, lane), lambda t, cb: (t, 0, 0)),
            pl.BlockSpec((1, col_block), lambda t, cb: (cb[t], 0)),
        ],
        out_specs=pl.BlockSpec((1, group), lambda t, cb: (t, 0)),
    )
    return pl.pallas_call(
        _partials_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, group), jnp.float32),
        interpret=interpret,
    )(colblock, data, cols, x_blocked)


def _partials_spmm_kernel(colblock_ref, data_ref, cols_ref, x_ref, y_ref):
    """Multi-RHS partials: one tile emits its [group, k] partial block."""
    seg = x_ref[0]  # [col_block, k]
    gathered = jnp.take(seg, cols_ref[0], axis=0)  # [group, lane, k]
    y_ref[0] = jnp.sum(data_ref[0][..., None] * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hbp_spmm_partials(
    colblock: jax.Array,  # i32[T]
    data: jax.Array,  # f32[T, group, lane]
    cols: jax.Array,  # i32[T, group, lane]
    x_blocked: jax.Array,  # f32[n_col_blocks, col_block, k]
    *,
    interpret: bool = False,
) -> jax.Array:
    """SpMM part only (two-phase multi-RHS): per-tile partial blocks
    [T, group, k]; the combine part reduces them by row group.  Wide k
    runs the 2D k-tiled grid — the (data, cols) blocks depend only on
    ``t``, so the stream is fetched once per tile, not once per k chunk."""
    T, group, lane = data.shape
    col_block, k = x_blocked.shape[1], x_blocked.shape[2]
    kt, n_kt = _k_grid(k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T, n_kt),
        in_specs=[
            pl.BlockSpec((1, group, lane), lambda t, j, cb: (t, 0, 0)),
            pl.BlockSpec((1, group, lane), lambda t, j, cb: (t, 0, 0)),
            pl.BlockSpec((1, col_block, kt), lambda t, j, cb: (cb[t], 0, j)),
        ],
        out_specs=pl.BlockSpec((1, group, kt), lambda t, j, cb: (t, 0, j)),
    )
    return pl.pallas_call(
        _partials_spmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, group, k), jnp.float32),
        interpret=interpret,
    )(colblock, data, cols, x_blocked)
