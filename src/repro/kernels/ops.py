"""Jitted public wrappers around the Pallas SpMV kernels.

``hbp_spmv`` is the production entry point: it stages the host-side tile
format to the device once (:func:`device_tiles`), pads the dense vector
into column-block segments, launches the requested kernel strategy and
undoes the hash permutation.
"""
from __future__ import annotations

import functools
from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.tile import HBPTiles

from . import hbp_spmv as _k
from . import ref as _ref

__all__ = [
    "DeviceTiles",
    "device_tiles",
    "hbp_spmv",
    "hbp_spmm",
    "hbp_spmm_argmax",
    "hbp_spmm_bucketed",
    "bucket_k",
    "K_BUCKETS",
    "K_TILINGS",
    "LANE_TILE",
    "blocked_vector",
    "blocked_matrix",
]

# RHS-width buckets of the k-padded SpMM entry.  ``_hbp_spmm_device`` is
# jitted with k baked into the trace, so an unconstrained request mix would
# compile one kernel per distinct k; padding to the next bucket bounds the
# compile count at len(K_BUCKETS) per matrix geometry.  The top bucket is
# one full lane tile (128): beyond it ``bucket_k`` rounds up to multiples
# of 128, each served as one k-tile of the 2D-grid launch — so GNN feature
# widths (256, 512, ...) add at most one partially padded k-tile, never an
# unbounded compile set.
K_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128)

# Widest RHS block one kernel grid step carries (defined with the kernels;
# re-exported here for the serving/bucketing layers).  Wider k runs the 2D
# k-tiled grid — or, under the legacy ``k_tiling="loop"`` contract, a
# host-side loop of sequential <=128-wide launches.
LANE_TILE = _k.LANE_TILE

# Launch contracts for k wider than one lane tile: "grid" (default) reads
# the tile stream once — Pallas strategies via the 2D (tile, k-tile) grid,
# jnp strategies via a single full-width lane chain; "loop" is the legacy
# host-side chunk loop (one launch per 128-wide chunk, the tile stream
# re-read by each), kept as the equivalence/benchmark baseline.
K_TILINGS = ("grid", "loop")


class DeviceTiles(NamedTuple):
    """Device-resident HBP tile format (a pytree of jnp arrays)."""

    rowgroup: jax.Array  # i32[T]
    colblock: jax.Array  # i32[T]
    first: jax.Array  # i32[T]
    data: jax.Array  # f32[T, group, lane]
    cols: jax.Array  # i32[T, group, lane]
    perm: jax.Array  # i32[padded_rows]
    visited: jax.Array  # f32[n_rowgroups, 1]: 0 for all-zero row groups
    # (the hash clusters empty rows, so whole groups can have no tiles;
    # Pallas leaves never-visited output blocks undefined — mask them)


def device_tiles(tiles: HBPTiles) -> DeviceTiles:
    import numpy as np

    visited = np.zeros((tiles.n_rowgroups, 1), np.float32)
    visited[tiles.rowgroup] = 1.0
    return DeviceTiles(
        rowgroup=jnp.asarray(tiles.rowgroup, jnp.int32),
        colblock=jnp.asarray(tiles.colblock, jnp.int32),
        first=jnp.asarray(tiles.first, jnp.int32),
        data=jnp.asarray(tiles.data, jnp.float32),
        cols=jnp.asarray(tiles.cols, jnp.int32),
        perm=jnp.asarray(tiles.perm, jnp.int32),
        visited=jnp.asarray(visited),
    )


def blocked_vector(x: jax.Array, col_block: int) -> jax.Array:
    """Pad x to a multiple of ``col_block`` and reshape into segments."""
    n = x.shape[0]
    n_blocks = -(-n // col_block)
    pad = n_blocks * col_block - n
    return jnp.pad(x, (0, pad)).reshape(n_blocks, col_block)


def blocked_matrix(x: jax.Array, col_block: int) -> jax.Array:
    """Pad an [n, k] RHS block to a multiple of ``col_block`` rows and
    reshape into [n_blocks, col_block, k] segments (k in the lane dim)."""
    n, k = x.shape
    n_blocks = -(-n // col_block)
    pad = n_blocks * col_block - n
    return jnp.pad(x, ((0, pad), (0, 0))).reshape(n_blocks, col_block, k)


def _default_interpret() -> bool:
    # Pallas TPU kernels execute natively on TPU; everywhere else we run the
    # kernel body in interpret mode (bit-accurate, Python-evaluated).
    return jax.default_backend() != "tpu"


def stream_passes(k: int, strategy: str, k_tiling: str) -> int:
    """How many times one launch walks the packed tile stream.

    The structural quantity behind the HBM traffic model (and what
    ``ref.count_traversals`` counts on the jnp references): at
    ``k <= LANE_TILE`` every contract is a single traversal; wider k reads
    the stream once under the one-pass geometries (``"grid"`` partials —
    block maps depend only on the tile index — and the references' single
    full-width trace) and once per 128-wide k-tile everywhere else (the
    fused k-tile-major grid's revisits, the legacy chunk loop, and the
    ``"stable"`` path's chunked lane chains under both tilings).
    """
    if k <= LANE_TILE:
        return 1
    if k_tiling == "grid" and strategy in ("partials", "reference"):
        return 1
    return -(-k // LANE_TILE)


def modeled_launch_bytes(
    dt: DeviceTiles, k: int, strategy: str, k_tiling: str
) -> int:
    """Modeled HBM bytes one SpMM launch moves (the bandwidth ledger).

    Tile stream (data f32 + cols i32) and the gathered x values are paid
    once per stream pass; the output block is written once.  A *model*,
    not a measurement: it assumes no cache reuse across passes (the
    pessimistic bound ``bench_memtraffic`` compares against) — useful for
    attributing relative traffic across strategies and k-tilings, which
    is exactly what Gao et al. identify as the binding constraint.
    """
    passes = stream_passes(k, strategy, k_tiling)
    stream = dt.data.nbytes + dt.cols.nbytes  # the packed tile arrays
    gathers = dt.data.size * 4  # one f32 x gather per tile slot
    n_rowgroups, group = dt.visited.shape[0], dt.data.shape[1] if dt.data.ndim == 3 else 8
    out = n_rowgroups * group * max(k, 1) * 4
    return int(passes * (stream + gathers) + out)


def _record_launch(
    dt: DeviceTiles, k: int, *, op: str, strategy: str, k_tiling: str,
    combine: str = "sum", passes: int | None = None,
) -> None:
    """Gated kernel-traffic accounting: one bump per *Python-level* launch.

    Calls traced inside an outer ``jit`` (e.g. the solver ``while_loop``
    body) are counted once per trace, not once per device execution — the
    counters see what Python dispatches, which is the honest observable
    from this layer.
    """
    if not obs.enabled():
        return
    obs.counter(
        "kernels.launches", op=op, strategy=strategy, k_tiling=k_tiling,
        combine=combine,
    ).inc()
    n_passes = stream_passes(k, strategy, k_tiling) if passes is None else passes
    obs.counter("kernels.traversals").inc(n_passes)
    obs.counter("kernels.bytes_modeled").inc(
        modeled_launch_bytes(dt, k, strategy, k_tiling)
    )
    obs.counter("kernels.k_tiling", choice=k_tiling).inc()
    obs.histogram("kernels.launch_k").observe(k)


@functools.partial(
    jax.jit, static_argnames=("n_rowgroups", "n_rows", "strategy", "interpret")
)
def _hbp_spmv_device(
    dt: DeviceTiles,
    x_blocked: jax.Array,
    *,
    n_rowgroups: int,
    n_rows: int,
    strategy: str,
    interpret: bool,
) -> jax.Array:
    if dt.data.shape[0] == 0:  # empty matrix: no tiles, y == 0
        return jnp.zeros((n_rows,), jnp.float32)
    if strategy == "fused":
        y_hashed = _k.hbp_spmv_fused(
            dt.rowgroup, dt.colblock, dt.first, dt.data, dt.cols, x_blocked,
            n_rowgroups=n_rowgroups, interpret=interpret,
        )
        y_hashed = jnp.where(dt.visited > 0, y_hashed, 0.0)
    elif strategy == "partials":
        # paper-faithful split: SpMV part (kernel) + combine part (XLA)
        contrib = _k.hbp_spmv_partials(
            dt.colblock, dt.data, dt.cols, x_blocked, interpret=interpret
        )
        y_hashed = jax.ops.segment_sum(contrib, dt.rowgroup, num_segments=n_rowgroups)
    elif strategy == "reference":
        y_hashed = _ref.hbp_spmv_hashed_ref(
            dt.rowgroup, dt.colblock, dt.data, dt.cols, x_blocked,
            n_rowgroups=n_rowgroups,
        )
    elif strategy == "stable":
        # the k=1 column of the batch-width-invariant SpMM, so a vector
        # served alone gets the same bits as any batched launch of it
        y_hashed = _ref.hbp_spmm_hashed_stable(
            dt.rowgroup, dt.colblock, dt.data, dt.cols, x_blocked[..., None],
            n_rowgroups=n_rowgroups,
        )[..., 0]
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return _ref.unpermute(y_hashed, dt.perm, n_rows)


def _spmm_hashed_chunk(
    dt: DeviceTiles,
    x_blocked: jax.Array,  # f32[n_blocks, col_block, k<=LANE_TILE]
    *,
    n_rowgroups: int,
    strategy: str,
    combine: str,
    interpret: bool,
) -> jax.Array:
    """One SpMM launch on the selected strategy, output in hashed row order.

    The jnp strategies take any k; the Pallas strategies take k <= LANE_TILE
    (one grid column) or a LANE_TILE multiple (the 2D k-tiled grid) — the
    caller (``_hbp_spmm_device``) pads accordingly.  Under ``combine="max"``
    empty rows carry the monoid identity ``-inf`` here; the caller maps it
    to 0 once, after assembly."""
    if combine == "max":
        if strategy == "fused":
            y = _k.hbp_spmm_fused_max(
                dt.rowgroup, dt.colblock, dt.first, dt.data, dt.cols, x_blocked,
                n_rowgroups=n_rowgroups, interpret=interpret,
            )
            # never-visited output blocks are undefined memory, not -inf
            return jnp.where(dt.visited[..., None] > 0, y, -jnp.inf)
        if strategy == "partials":
            contrib = _k.hbp_spmm_partials_max(
                dt.colblock, dt.data, dt.cols, x_blocked, interpret=interpret
            )
            return jax.ops.segment_max(contrib, dt.rowgroup, num_segments=n_rowgroups)
        if strategy in ("reference", "stable"):
            # maximum is exactly associative/commutative: the unrolled lane
            # chain is reference, stable and batch-width-invariant at once
            return _ref.hbp_spmm_hashed_max(
                dt.rowgroup, dt.colblock, dt.data, dt.cols, x_blocked,
                n_rowgroups=n_rowgroups,
            )
        raise ValueError(f"unknown strategy {strategy!r}")
    if combine != "sum":
        raise ValueError(f"unknown combine {combine!r} (expected 'sum' or 'max')")
    if strategy == "fused":
        y = _k.hbp_spmm_fused(
            dt.rowgroup, dt.colblock, dt.first, dt.data, dt.cols, x_blocked,
            n_rowgroups=n_rowgroups, interpret=interpret,
        )
        return jnp.where(dt.visited[..., None] > 0, y, 0.0)
    if strategy == "partials":
        contrib = _k.hbp_spmm_partials(
            dt.colblock, dt.data, dt.cols, x_blocked, interpret=interpret
        )
        return jax.ops.segment_sum(contrib, dt.rowgroup, num_segments=n_rowgroups)
    if strategy == "reference":
        return _ref.hbp_spmm_hashed_ref(
            dt.rowgroup, dt.colblock, dt.data, dt.cols, x_blocked,
            n_rowgroups=n_rowgroups,
        )
    if strategy == "stable":
        return _ref.hbp_spmm_hashed_stable(
            dt.rowgroup, dt.colblock, dt.data, dt.cols, x_blocked,
            n_rowgroups=n_rowgroups,
        )
    raise ValueError(f"unknown strategy {strategy!r}")


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_rowgroups", "n_rows", "strategy", "interpret", "combine", "k_tiling",
    ),
)
def _hbp_spmm_device(
    dt: DeviceTiles,
    x_blocked: jax.Array,  # f32[n_blocks, col_block, k]
    *,
    n_rowgroups: int,
    n_rows: int,
    strategy: str,
    interpret: bool,
    combine: str = "sum",
    k_tiling: str = "grid",
) -> jax.Array:
    """Hashed SpMM + unpermute, k-tiling the RHS width.

    ``k`` lives in the lane dimension of the kernels, so one grid step
    carries at most :data:`LANE_TILE` RHS columns.  Wider feature blocks
    (GNN aggregation at k = 256, 512, ...) are served under one of two
    launch contracts:

    * ``k_tiling="grid"`` (default, one-pass) — the Pallas strategies pad
      k to a LANE_TILE multiple and run the kernels' **2D k-tiled grid**
      as ONE launch.  ``"partials"`` is tile-major: its (data, cols)
      block maps depend only on the tile index, so the stream is fetched
      once and revisited across k-tiles — one read total.  ``"fused"`` is
      k-tile-major (its in-kernel accumulation revisits output blocks,
      which Pallas TPU only preserves across consecutive steps, pinning
      the tile index innermost): same stream bytes as the loop, but no
      per-chunk host round-trips and the grid pipeline overlaps k-tiles.
      ``"reference"`` runs its einsum oracle over the full width in a
      single traversal.  ``"stable"``
      keeps the chunked <=LANE_TILE lane chains under BOTH tilings: its
      contract is bitwise batch-width invariance, which XLA only upholds
      across launch widths that share codegen — a single wide trace
      changes the tail columns' contraction by ~1 ulp (pinned by
      tests/test_onepass.py), so for stable the two tilings are the same
      computation and bits never move.
    * ``k_tiling="loop"`` (legacy) — a host-side loop of sequential
      <=LANE_TILE-wide launches, the tile stream re-read once per chunk:
      ceil(k / 128) passes.  Kept as the equivalence baseline and for the
      bench regression gate's before/after comparison.

    The contract never changes results: each strategy's lane reduction is
    per-column (elementwise across k), so a column's value — and for
    ``"stable"`` its exact bit pattern — is independent of launch width,
    chunking, and k_tiling (tests/test_onepass.py pins this at every
    k-bucket boundary).
    """
    k = x_blocked.shape[-1]
    if dt.data.shape[0] == 0:  # empty matrix: no tiles, Y == identity-mapped 0
        return jnp.zeros((n_rows, k), jnp.float32)
    if k_tiling not in K_TILINGS:
        raise ValueError(f"unknown k_tiling {k_tiling!r} (expected one of {K_TILINGS})")
    if k <= LANE_TILE:
        y_hashed = _spmm_hashed_chunk(
            dt, x_blocked, n_rowgroups=n_rowgroups, strategy=strategy,
            combine=combine, interpret=interpret,
        )
    elif k_tiling == "grid" and strategy != "stable":
        xw = x_blocked
        if strategy in ("fused", "partials") and k % LANE_TILE:
            # the 2D grid tiles k in whole lane tiles; padded columns are
            # zero, contribute nothing, and are sliced back off below
            xw = jnp.pad(x_blocked, ((0, 0), (0, 0), (0, -k % LANE_TILE)))
        y_hashed = _spmm_hashed_chunk(
            dt, xw, n_rowgroups=n_rowgroups, strategy=strategy,
            combine=combine, interpret=interpret,
        )[..., :k]
    else:
        chunks = [
            _spmm_hashed_chunk(
                dt, x_blocked[..., lo : lo + LANE_TILE], n_rowgroups=n_rowgroups,
                strategy=strategy, combine=combine, interpret=interpret,
            )
            for lo in range(0, k, LANE_TILE)
        ]
        y_hashed = jnp.concatenate(chunks, axis=-1)
    if combine == "max":
        # rows with no live entry hold the monoid identity; outputs are 0
        # there (the aggregation convention for isolated graph nodes)
        y_hashed = jnp.where(jnp.isneginf(y_hashed), 0.0, y_hashed)
    return _ref.unpermute(y_hashed, dt.perm, n_rows)


def _resolve(tiles, x, n_rowgroups, n_rows, col_block):
    if isinstance(tiles, HBPTiles):
        if x.shape[0] != tiles.shape[1]:
            # jnp gathers clamp out-of-range block ids, so a wrong-sized x
            # would silently return garbage instead of erroring
            raise ValueError(
                f"x has {x.shape[0]} rows but the matrix has {tiles.shape[1]} columns"
            )
        return device_tiles(tiles), (tiles.n_rowgroups, tiles.shape[0], tiles.cfg.col_block)
    if None in (n_rowgroups, n_rows, col_block):
        raise ValueError("DeviceTiles input requires explicit metadata")
    return tiles, (n_rowgroups, n_rows, col_block)


def hbp_spmv(
    tiles: HBPTiles | DeviceTiles,
    x: jax.Array,
    *,
    strategy: Literal["fused", "partials", "reference", "stable"] = "fused",
    interpret: bool | None = None,
    n_rowgroups: int | None = None,
    n_rows: int | None = None,
    col_block: int | None = None,
    k_tiling: Literal["grid", "loop"] = "grid",
) -> jax.Array:
    """HBP SpMV: ``y = A @ x`` with A in HBP tile format.

    ``k_tiling`` is accepted for meta-dict uniformity with
    :func:`hbp_spmm` (a serving plan passes one keyword set to both);
    a single vector never spans more than one lane tile, so both
    contracts are the same launch here.
    """
    if k_tiling not in K_TILINGS:
        raise ValueError(f"unknown k_tiling {k_tiling!r} (expected one of {K_TILINGS})")
    x = jnp.asarray(x, jnp.float32)
    dt, (n_rowgroups, n_rows, col_block) = _resolve(tiles, x, n_rowgroups, n_rows, col_block)
    if interpret is None:
        interpret = _default_interpret()
    _record_launch(dt, 1, op="spmv", strategy=strategy, k_tiling=k_tiling)
    x_blocked = blocked_vector(x, col_block)
    return _hbp_spmv_device(
        dt,
        x_blocked,
        n_rowgroups=n_rowgroups,
        n_rows=n_rows,
        strategy=strategy,
        interpret=interpret,
    )


def bucket_k(k: int, buckets: tuple = K_BUCKETS) -> int:
    """Smallest bucket width >= k; beyond the top bucket, the next
    *multiple* of it.

    A request is never clamped down to the top bucket: k = 300 over the
    default buckets pads up to 384 (three 128-wide lane tiles), and
    ``hbp_spmm_bucketed`` slices the real columns back out — the 2D k-tiled
    grid in ``_hbp_spmm_device`` serves every 128-wide k-tile in one
    tile-stream pass.  Rounding to top-bucket multiples keeps the compile count
    bounded (one trace per multiple actually seen) while supporting
    arbitrary feature widths.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if not buckets:
        raise ValueError("buckets must be non-empty")
    for b in buckets:
        if k <= b:
            return int(b)
    top = buckets[-1]
    return -(-k // top) * top


def hbp_spmm_bucketed(
    tiles: HBPTiles | DeviceTiles,
    x: jax.Array,  # [n_cols, k]
    *,
    buckets: tuple = K_BUCKETS,
    **kwargs,
) -> jax.Array:
    """k-padded SpMM: pad the RHS block to the next bucket width, launch
    :func:`hbp_spmm`, slice the real columns back out.

    The padded columns are zero, contribute nothing, and are dropped
    before returning.  Under ``strategy="stable"`` the surviving columns
    are bitwise identical to the unpadded launch (the lane reduction is
    launch-width-invariant); the other strategies agree numerically but
    may differ by ~1 ulp when the bucket changes the launch width.  This
    is the entry the serving micro-batcher routes coalesced request
    blocks through.

    Zero-padding is also safe under ``combine="max"``: padded columns are
    sliced off before returning, and a padded *column* cannot influence a
    real one (the lane reduction never mixes k slots).
    """
    x = jnp.asarray(x, jnp.float32)
    k = x.shape[1]
    kb = bucket_k(k, buckets)
    if kb != k:
        x = jnp.pad(x, ((0, 0), (0, kb - k)))
    return hbp_spmm(tiles, x, **kwargs)[:, :k]


@functools.partial(jax.jit, static_argnames=("n_rowgroups", "n_rows", "passes"))
def _hbp_spmm_argmax_device(
    dt: DeviceTiles,
    x_blocked: jax.Array,  # f32[n_blocks, col_block, k]
    *,
    n_rowgroups: int,
    n_rows: int,
    passes: int = 1,
):
    k = x_blocked.shape[-1]
    if dt.data.shape[0] == 0:  # no tiles: every row is empty
        return (
            jnp.zeros((n_rows, k), jnp.float32),
            jnp.full((n_rows, k), -1, jnp.int32),
            jnp.zeros((n_rows, k), jnp.float32),
        )
    hashed = (
        _ref.hbp_spmm_hashed_argmax_onepass
        if passes == 1
        else _ref.hbp_spmm_hashed_argmax
    )
    y_h, idx_h, coeff_h = hashed(
        dt.rowgroup, dt.colblock, dt.data, dt.cols, x_blocked,
        n_rowgroups=n_rowgroups,
    )
    y_h = jnp.where(jnp.isneginf(y_h), 0.0, y_h)  # empty rows aggregate to 0
    return (
        _ref.unpermute(y_h, dt.perm, n_rows),
        _ref.unpermute(idx_h, dt.perm, n_rows),
        _ref.unpermute(coeff_h, dt.perm, n_rows),
    )


def hbp_spmm_argmax(
    tiles: HBPTiles | DeviceTiles,
    x: jax.Array,  # [n_cols, k]
    *,
    n_rowgroups: int | None = None,
    n_rows: int | None = None,
    col_block: int | None = None,
    passes: Literal[1, 3] = 1,
):
    """Max-monoid SpMM with winner tracking: ``(y, idx, coeff)``.

    ``y`` matches ``hbp_spmm(..., combine="max")`` exactly; ``idx[i, c]``
    is the global source column whose stored entry attained the max (ties
    to the lowest column, ``-1`` for rows with no live entry) and
    ``coeff[i, c]`` that entry's value.  This is the forward pass of the
    differentiable max-aggregation (:mod:`repro.kernels.autodiff`): the
    VJP scatters ``coeff * cotangent`` back to row ``idx`` of the input.
    The reduction runs on the monoid-exact jnp path (the same lane chain
    as ``strategy="stable"``), so values are bitwise identical across
    batch widths and strategies.

    ``passes=1`` (default) carries a paired (value, index, coefficient)
    payload through a single tile-stream traversal
    (:func:`repro.kernels.ref.hbp_spmm_hashed_argmax_onepass`);
    ``passes=3`` runs the legacy three-monoid-pass recovery, kept as the
    equivalence oracle.  Both return identical triples.
    """
    if passes not in (1, 3):
        raise ValueError(f"passes must be 1 or 3, got {passes!r}")
    x = jnp.asarray(x, jnp.float32)
    dt, (n_rowgroups, n_rows, col_block) = _resolve(tiles, x, n_rowgroups, n_rows, col_block)
    _record_launch(
        dt, x.shape[1], op="spmm_argmax", strategy="stable", k_tiling="grid",
        combine="max", passes=passes,
    )
    x_blocked = blocked_matrix(x, col_block)
    return _hbp_spmm_argmax_device(
        dt, x_blocked, n_rowgroups=n_rowgroups, n_rows=n_rows, passes=passes
    )


def hbp_spmm(
    tiles: HBPTiles | DeviceTiles,
    x: jax.Array,  # [n_cols, k]
    *,
    strategy: Literal["fused", "partials", "reference", "stable"] = "fused",
    combine: Literal["sum", "max"] = "sum",
    interpret: bool | None = None,
    n_rowgroups: int | None = None,
    n_rows: int | None = None,
    col_block: int | None = None,
    k_tiling: Literal["grid", "loop"] = "grid",
) -> jax.Array:
    """HBP multi-RHS SpMM: ``Y = A (x) X`` with A in HBP tile format.

    One grid step serves up to :data:`LANE_TILE` columns of X; wider
    blocks run the one-pass geometry (``k_tiling="grid"``, default): one
    2D k-tiled kernel launch — tile-major for ``"partials"`` (the tile
    stream is read ONCE for all k) and k-tile-major for ``"fused"``
    (consecutive-revisit accumulation) — or the ``"reference"`` jnp
    path's single full-width traversal; versus the ceil(k/128) separate
    launches of the legacy host-side chunk loop (``k_tiling="loop"``) or
    the k reads of SpMV-per-column.

    ``combine`` selects the reduction monoid: ``"sum"`` is the standard
    SpMM; ``"max"`` computes ``Y[i, c] = max_j A[i, j] * X[j, c]`` over
    A's *stored* entries (rows with none yield 0) — the max-aggregation
    semiring of GNN message passing (:mod:`repro.graph`).
    """
    x = jnp.asarray(x, jnp.float32)
    dt, (n_rowgroups, n_rows, col_block) = _resolve(tiles, x, n_rowgroups, n_rows, col_block)
    if interpret is None:
        interpret = _default_interpret()
    _record_launch(
        dt, x.shape[1], op="spmm", strategy=strategy, k_tiling=k_tiling,
        combine=combine,
    )
    x_blocked = blocked_matrix(x, col_block)
    return _hbp_spmm_device(
        dt,
        x_blocked,
        n_rowgroups=n_rowgroups,
        n_rows=n_rows,
        strategy=strategy,
        interpret=interpret,
        combine=combine,
        k_tiling=k_tiling,
    )
