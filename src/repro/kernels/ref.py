"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against these references with
``numpy.testing.assert_allclose`` across shape/dtype/sparsity sweeps
(tests/test_kernels.py); the references themselves are validated against
the dense matmul and the faithful GPU-semantics implementation
(:func:`repro.core.hbp.hbp_spmv_reference`).
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

__all__ = [
    "tile_contrib_ref",
    "hbp_spmv_hashed_ref",
    "tile_contrib_spmm_ref",
    "hbp_spmm_hashed_ref",
    "tile_contrib_spmm_stable",
    "hbp_spmm_hashed_stable",
    "tile_contrib_spmm_max",
    "hbp_spmm_hashed_max",
    "hbp_spmm_hashed_argmax",
    "hbp_spmm_hashed_argmax_onepass",
    "count_traversals",
    "unpermute",
]

# Tile-stream traversal accounting.  A "traversal" is one walk over the
# packed (data, cols) stream with its x gathers — the dominant HBM traffic
# of every kernel in this package, and the quantity the one-pass argmax
# exists to cut from 3 to 1.  Each lane-loop body below bumps the counter
# once per *trace*, so callers measuring it must invoke these references
# directly (eagerly or via a fresh trace), not through a cached jit.
_TRAVERSALS = [0]


def _traverse() -> None:
    _TRAVERSALS[0] += 1


@contextlib.contextmanager
def count_traversals():
    """Context manager yielding a 1-element list that, on exit, holds the
    number of tile-stream traversals traced inside the block."""
    start = _TRAVERSALS[0]
    box = [0]
    try:
        yield box
    finally:
        box[0] = _TRAVERSALS[0] - start


def tile_contrib_ref(
    colblock: jax.Array,  # i32[T]
    data: jax.Array,  # f32[T, group, lane]
    cols: jax.Array,  # i32[T, group, lane]
    x_blocked: jax.Array,  # f32[n_col_blocks, col_block]
) -> jax.Array:
    """Per-tile partial results [T, group] — oracle of the SpMV part."""
    _traverse()
    segs = x_blocked[colblock]  # [T, col_block]
    T, group, lane = data.shape
    gathered = jnp.take_along_axis(
        segs[:, None, :], cols.reshape(T, 1, group * lane), axis=2
    ).reshape(T, group, lane)
    return jnp.sum(data * gathered, axis=2)


def hbp_spmv_hashed_ref(
    rowgroup: jax.Array,
    colblock: jax.Array,
    data: jax.Array,
    cols: jax.Array,
    x_blocked: jax.Array,
    *,
    n_rowgroups: int,
) -> jax.Array:
    """Full SpMV + combine oracle, output in hashed row order
    [n_rowgroups, group]."""
    contrib = tile_contrib_ref(colblock, data, cols, x_blocked)
    return jax.ops.segment_sum(contrib, rowgroup, num_segments=n_rowgroups)


def tile_contrib_spmm_ref(
    colblock: jax.Array,  # i32[T]
    data: jax.Array,  # f32[T, group, lane]
    cols: jax.Array,  # i32[T, group, lane]
    x_blocked: jax.Array,  # f32[n_col_blocks, col_block, k]
) -> jax.Array:
    """Per-tile partial blocks [T, group, k] — oracle of the SpMM part."""
    _traverse()
    segs = x_blocked[colblock]  # [T, col_block, k]
    gathered = jax.vmap(lambda s, c: s[c])(segs, cols)  # [T, group, lane, k]
    return jnp.einsum("tgl,tglk->tgk", data, gathered)


def hbp_spmm_hashed_ref(
    rowgroup: jax.Array,
    colblock: jax.Array,
    data: jax.Array,
    cols: jax.Array,
    x_blocked: jax.Array,
    *,
    n_rowgroups: int,
) -> jax.Array:
    """Full multi-RHS SpMM + combine oracle, output in hashed row order
    [n_rowgroups, group, k]."""
    contrib = tile_contrib_spmm_ref(colblock, data, cols, x_blocked)
    return jax.ops.segment_sum(contrib, rowgroup, num_segments=n_rowgroups)


def tile_contrib_spmm_stable(
    colblock: jax.Array,  # i32[T]
    data: jax.Array,  # f32[T, group, lane]
    cols: jax.Array,  # i32[T, group, lane]
    x_blocked: jax.Array,  # f32[n_col_blocks, col_block, k]
) -> jax.Array:
    """Batch-width-invariant SpMM contributions [T, group, k].

    Numerically equivalent to :func:`tile_contrib_spmm_ref`, but the lane
    reduction is an explicitly ordered chain of elementwise adds (unrolled
    over the static lane dimension) instead of a fused contraction.  XLA
    cannot reassociate elementwise adds, so a column's bit pattern is
    independent of how many RHS columns share the launch — the guarantee
    the serving engine's k-bucketed micro-batching relies on: coalescing a
    request with arbitrary co-traffic, or padding its bucket with zero
    columns, never changes its result.  (The einsum oracle and the
    interpret-mode kernels are ~1 ulp width-dependent at small k.)

    The gather is flat and per lane: each step touches only the [T, group]
    slots it multiplies, never a [T, col_block, k] segment expansion nor a
    [T, group, lane, k] product — the largest temporary is [T, group, k],
    which is what keeps this path's k-scaling near the ideal tile-stream
    amortization (the einsum oracle loses it to the blown-up intermediates).
    """
    _traverse()
    n_cb, col_block, k = x_blocked.shape
    x_flat = x_blocked.reshape(n_cb * col_block, k)
    base = colblock[:, None] * col_block  # [T, 1] offset of each tile's segment
    acc = data[:, :, 0, None] * x_flat[base + cols[:, :, 0]]
    for lane in range(1, data.shape[2]):
        acc = acc + data[:, :, lane, None] * x_flat[base + cols[:, :, lane]]
    return acc


def hbp_spmm_hashed_stable(
    rowgroup: jax.Array,
    colblock: jax.Array,
    data: jax.Array,
    cols: jax.Array,
    x_blocked: jax.Array,
    *,
    n_rowgroups: int,
) -> jax.Array:
    """Full batch-width-invariant SpMM + combine, hashed row order
    [n_rowgroups, group, k]."""
    contrib = tile_contrib_spmm_stable(colblock, data, cols, x_blocked)
    return jax.ops.segment_sum(contrib, rowgroup, num_segments=n_rowgroups)


def tile_contrib_spmm_max(
    colblock: jax.Array,  # i32[T]
    data: jax.Array,  # f32[T, group, lane]
    cols: jax.Array,  # i32[T, group, lane]
    x_blocked: jax.Array,  # f32[n_col_blocks, col_block, k]
) -> jax.Array:
    """Max-monoid SpMM contributions [T, group, k]: per-tile
    ``max_j(a_ij * x_jk)`` over the tile's lanes.

    The max semiring backs GNN max-aggregation (``repro.graph``): the
    combine is ``maximum`` instead of ``+``, whose identity is ``-inf`` —
    so padded tile slots must be *masked out*, not multiplied through
    (``0 * x = 0`` would beat every all-negative row).  A slot is live iff
    its stored value is nonzero; explicitly stored zeros are treated as
    absent entries, consistent with sparse semantics where only the stored
    pattern participates.  Rows with no live slots come out ``-inf`` here;
    the caller maps the identity back to 0 *after* the row-group combine
    (see ``ops._hbp_spmm_device``) so it never leaks into outputs.

    Like the stable sum path, the lane reduction is an unrolled chain —
    ``maximum`` is exactly associative and commutative on floats, so this
    one form serves as reference, stable, and oracle at once (bit-exact
    under any batch width by construction).
    """
    _traverse()
    n_cb, col_block, k = x_blocked.shape
    x_flat = x_blocked.reshape(n_cb * col_block, k)
    base = colblock[:, None] * col_block  # [T, 1] offset of each tile's segment
    neg = jnp.float32(-jnp.inf)

    def lane_term(lane):
        d = data[:, :, lane, None]  # [T, group, 1]
        prod = d * x_flat[base + cols[:, :, lane]]
        return jnp.where(d != 0, prod, neg)

    acc = lane_term(0)
    for lane in range(1, data.shape[2]):
        acc = jnp.maximum(acc, lane_term(lane))
    return acc


def hbp_spmm_hashed_max(
    rowgroup: jax.Array,
    colblock: jax.Array,
    data: jax.Array,
    cols: jax.Array,
    x_blocked: jax.Array,
    *,
    n_rowgroups: int,
) -> jax.Array:
    """Max-monoid SpMM + combine, hashed row order [n_rowgroups, group, k].

    Row groups with no tiles (and all-padding slots) are ``-inf`` — the
    monoid identity, for the caller to mask."""
    contrib = tile_contrib_spmm_max(colblock, data, cols, x_blocked)
    return jax.ops.segment_max(contrib, rowgroup, num_segments=n_rowgroups)


def hbp_spmm_hashed_argmax(
    rowgroup: jax.Array,
    colblock: jax.Array,
    data: jax.Array,
    cols: jax.Array,
    x_blocked: jax.Array,
    *,
    n_rowgroups: int,
):
    """Max-monoid SpMM that also reports *which* stored entry won.

    Returns ``(y, idx, coeff)`` in hashed row order, each
    ``[n_rowgroups, group, k]``:

    * ``y`` — the max-SpMM values (``-inf`` identity for rows with no live
      entry, exactly :func:`hbp_spmm_hashed_max`);
    * ``idx`` — the *global column id* of the winning stored entry
      (``-1`` where the row has none), ties broken to the lowest column;
    * ``coeff`` — the winning entry's stored value ``a_{i, idx}``
      (0 where the row has none).

    This is the forward of max-aggregation's VJP: the backward routes the
    cotangent to the winning neighbor, scaled by ``coeff``.  The index is
    recovered by a **parallel index-SpMM under the same max monoid** — a
    second pass over the tile stream that reduces ``-col`` (so the max
    picks the lowest column) over the slots whose product attained ``y``,
    and a third pass that reads the winner's coefficient.  Three passes
    keep every reduction inside the monoid the kernels already implement.
    Kept as the equivalence oracle of the production
    :func:`hbp_spmm_hashed_argmax_onepass`, which carries (value, index,
    coefficient) as a paired payload through a single traversal.
    """
    n_cb, col_block, k = x_blocked.shape
    x_flat = x_blocked.reshape(n_cb * col_block, k)
    base = colblock[:, None] * col_block  # [T, 1]
    y = hbp_spmm_hashed_max(
        rowgroup, colblock, data, cols, x_blocked, n_rowgroups=n_rowgroups
    )
    y_t = y[rowgroup]  # [T, group, k] each tile's target row values
    int_min = jnp.iinfo(jnp.int32).min

    def lane_parts(lane):
        d = data[:, :, lane, None]  # [T, group, 1]
        gcol = (base + cols[:, :, lane])[..., None]  # [T, group, 1] global col
        prod = d * x_flat[base + cols[:, :, lane]]  # [T, group, k]
        win = (d != 0) & (prod == y_t)
        return d, gcol, win

    # pass 2: lowest winning global column, as a max of the negated id
    _traverse()
    acc = None
    for lane in range(data.shape[2]):
        d, gcol, win = lane_parts(lane)
        term = jnp.where(win, -gcol.astype(jnp.int32), int_min)
        acc = term if acc is None else jnp.maximum(acc, term)
    neg_idx = jax.ops.segment_max(acc, rowgroup, num_segments=n_rowgroups)
    live = neg_idx > int_min  # also False for never-visited row groups
    idx = jnp.where(live, -neg_idx, -1)

    # pass 3: the winner's stored coefficient (unique per (row, col) pair)
    _traverse()
    idx_t = idx[rowgroup]
    acc_c = None
    for lane in range(data.shape[2]):
        d = data[:, :, lane, None]
        gcol = (base + cols[:, :, lane])[..., None].astype(jnp.int32)
        hit = (d != 0) & (gcol == idx_t)
        term = jnp.where(hit, jnp.broadcast_to(d, idx_t.shape), -jnp.inf)
        acc_c = term if acc_c is None else jnp.maximum(acc_c, term)
    coeff = jax.ops.segment_max(acc_c, rowgroup, num_segments=n_rowgroups)
    coeff = jnp.where(live, coeff, 0.0)
    return y, idx, coeff


def hbp_spmm_hashed_argmax_onepass(
    rowgroup: jax.Array,
    colblock: jax.Array,
    data: jax.Array,
    cols: jax.Array,
    x_blocked: jax.Array,
    *,
    n_rowgroups: int,
):
    """One-pass argmax SpMM: the paired-payload form of
    :func:`hbp_spmm_hashed_argmax`.

    Returns the same ``(y, idx, coeff)`` triple — bitwise-identical values
    (the value chain is the exact ``maximum`` sequence of
    :func:`tile_contrib_spmm_max`), identical tie-breaking (lowest global
    column) and empty-row conventions (``idx = -1``, ``coeff = 0``) — but
    walks the tile stream ONCE: each lane step advances a paired
    ``(value, index, coefficient)`` payload through the max combine, where
    a lane term displaces the accumulator iff its value is strictly
    greater or equal-with-lower-column.  The per-tile payloads are then
    reduced across each row group with segment ops over the already-
    materialized ``[T, group, k]`` contributions — no further x gathers or
    data reads, so tile-stream traffic is 1/3 of the three-pass oracle's.
    """
    _traverse()
    n_cb, col_block, k = x_blocked.shape
    x_flat = x_blocked.reshape(n_cb * col_block, k)
    base = colblock[:, None] * col_block  # [T, 1]
    int_max = jnp.iinfo(jnp.int32).max

    def lane_term(lane):
        d = data[:, :, lane, None]  # [T, group, 1]
        gcol = (base + cols[:, :, lane])[..., None].astype(jnp.int32)
        prod = d * x_flat[base + cols[:, :, lane]]  # [T, group, k]
        live = d != 0
        v = jnp.where(live, prod, -jnp.inf)
        # dead slots carry the int32 max sentinel so the lowest-column
        # tie-break can never select them
        i = jnp.broadcast_to(jnp.where(live, gcol, int_max), v.shape)
        c = jnp.broadcast_to(jnp.where(live, d, 0.0), v.shape)
        return v, i, c

    acc_v, acc_i, acc_c = lane_term(0)
    for lane in range(1, data.shape[2]):
        v, i, c = lane_term(lane)
        take = (v > acc_v) | ((v == acc_v) & (i < acc_i))
        # the value chain stays the literal maximum() sequence of the
        # max-monoid path, so y is bitwise-identical to hashed_max
        acc_v = jnp.maximum(acc_v, v)
        acc_i = jnp.where(take, i, acc_i)
        acc_c = jnp.where(take, c, acc_c)

    # row-group combine of the per-tile payloads (contribution arrays
    # only — the tile stream is not touched again)
    y = jax.ops.segment_max(acc_v, rowgroup, num_segments=n_rowgroups)
    attain = acc_v == y[rowgroup]  # a tile's winner attains the row max
    idx_min = jax.ops.segment_min(
        jnp.where(attain, acc_i, int_max), rowgroup, num_segments=n_rowgroups
    )
    live = idx_min < int_max  # also False for never-visited row groups
    idx = jnp.where(live, idx_min, -1)
    hit = attain & (acc_i == idx[rowgroup])
    coeff = jax.ops.segment_max(
        jnp.where(hit, acc_c, -jnp.inf), rowgroup, num_segments=n_rowgroups
    )
    coeff = jnp.where(live, coeff, 0.0)
    return y, idx, coeff


def unpermute(y_hashed: jax.Array, perm: jax.Array, n_rows: int) -> jax.Array:
    """Undo the hash reordering: slot s computed original row ``perm[s]``.

    ``y_hashed`` is [n_rowgroups, group] (SpMV) or [n_rowgroups, group, k]
    (SpMM); ``perm`` maps slots (flattened hashed order) to original row
    ids over the padded row space.  Trailing RHS dims ride along.
    """
    flat = y_hashed.reshape((-1,) + y_hashed.shape[2:])
    padded = jnp.zeros((perm.shape[0],) + flat.shape[1:], dtype=y_hashed.dtype)
    # perm is a genuine permutation: declaring uniqueness lets XLA drop the
    # collision handling and makes the scatter linearly transposable (the
    # jvp-mode autodiff wrappers rely on this)
    padded = padded.at[perm].set(flat, unique_indices=True)
    return padded[:n_rows]
