# Pallas TPU kernels for the paper's compute hot-spot: HBP SpMV.
# <name>.py holds the pl.pallas_call + BlockSpec kernels, ops.py the jitted
# public wrappers, ref.py the pure-jnp oracles they are validated against,
# autodiff.py the custom-VJP layer (backward = the transpose-tiles SpMM).
from . import autodiff, ops, ref
from .autodiff import PairedTiles, diff_aggregator, hbp_transpose
from .ops import (
    K_BUCKETS,
    LANE_TILE,
    DeviceTiles,
    bucket_k,
    device_tiles,
    hbp_spmm,
    hbp_spmm_argmax,
    hbp_spmm_bucketed,
    hbp_spmv,
)

__all__ = [
    "ops",
    "ref",
    "autodiff",
    "DeviceTiles",
    "device_tiles",
    "hbp_spmv",
    "hbp_spmm",
    "hbp_spmm_argmax",
    "hbp_spmm_bucketed",
    "bucket_k",
    "K_BUCKETS",
    "LANE_TILE",
    "PairedTiles",
    "hbp_transpose",
    "diff_aggregator",
]
