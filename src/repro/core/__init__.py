# The paper's primary contribution: the Hash-Based Partition (HBP) SpMV
# pipeline — 2D partitioning, nonlinear hash reordering, tile construction,
# mixed-execution scheduling — plus the baselines it is evaluated against.
from .formats import COOMatrix, CSRMatrix, csr_from_coo, csr_from_dense
from .hash import HashParams, hash_reorder, hash_slot, sample_params
from .hbp import HBPMatrix, build_hbp, hbp_spmv_reference
from .partition import Partition2D, PartitionConfig, enumerate_configs
from .reorder import REORDER_METHODS, group_stddev, padding_waste
from .schedule import Schedule, contiguous_schedule, lpt_schedule, mixed_schedule
from .spmv import csr_spmm_jnp, csr_spmv_jnp, spmm, spmv
from .tile import HBPTiles, build_tiles, tuned_partition_config

__all__ = [
    "COOMatrix",
    "CSRMatrix",
    "csr_from_coo",
    "csr_from_dense",
    "HashParams",
    "hash_reorder",
    "hash_slot",
    "sample_params",
    "HBPMatrix",
    "build_hbp",
    "hbp_spmv_reference",
    "Partition2D",
    "PartitionConfig",
    "enumerate_configs",
    "REORDER_METHODS",
    "group_stddev",
    "padding_waste",
    "Schedule",
    "contiguous_schedule",
    "lpt_schedule",
    "mixed_schedule",
    "csr_spmv_jnp",
    "csr_spmm_jnp",
    "spmv",
    "spmm",
    "HBPTiles",
    "build_tiles",
    "tuned_partition_config",
]
