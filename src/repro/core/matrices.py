"""Synthetic sparse-matrix generators modelled on the paper's test suite.

The paper evaluates on 14 matrices from the University of Florida (SuiteSparse)
collection (Table I).  The collection is not available offline, so this module
provides generators that reproduce the *structural* characteristics of each
matrix family used in the paper:

* ``kron_g500-lognNN``  — Kronecker/R-MAT power-law graphs (Graph500 spec),
  extreme row-imbalance, scattered column access.  (m4-m7)
* ``ASIC_*``, ``rajat*`` — circuit-simulation matrices: strong diagonal,
  a few dense rows/columns (power rails), mostly short rows.  (m1, m2, m11-m14)
* ``ohne2``, ``barrier2-3``, ``nxp1`` — semiconductor-device FEM matrices:
  banded with regular medium-length rows.  (m3, m9, m10)
* ``mip1`` — optimisation matrix: dense blocks and long rows.  (m8)

Every generator is deterministic given ``seed`` and returns a
:class:`repro.core.formats.CSRMatrix`.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from .formats import COOMatrix, CSRMatrix, csr_from_coo

__all__ = [
    "rmat",
    "circuit",
    "banded_fem",
    "dense_block",
    "uniform_random",
    "paper_suite",
    "SUITE_SPECS",
]


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def rmat(
    n: int,
    nnz: int,
    *,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    symmetric: bool = True,
) -> CSRMatrix:
    """R-MAT / Kronecker power-law graph (Graph500 parameters by default).

    Mirrors the ``kron_g500-lognNN`` matrices: heavy-tailed row degree
    distribution, the worst case for per-warp load balance.
    """
    rng = _rng(seed)
    scale = int(np.ceil(np.log2(n)))
    n = 1 << scale
    m = nnz if not symmetric else max(1, nnz // 2)
    rows = np.zeros(m, dtype=np.int64)
    cols = np.zeros(m, dtype=np.int64)
    for level in range(scale):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        go_down = r >= a + b  # rows bit set
        go_right = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        rows |= go_down.astype(np.int64) << level
        cols |= go_right.astype(np.int64) << level
    data = rng.standard_normal(m)
    if symmetric:
        rows, cols = np.concatenate([rows, cols]), np.concatenate([cols, rows])
        data = np.concatenate([data, data])
    return csr_from_coo(COOMatrix(rows, cols, data, (n, n)))


def circuit(
    n: int,
    *,
    seed: int = 0,
    avg_offdiag: float = 4.0,
    n_dense_rows: int = 8,
    dense_row_frac: float = 0.02,
) -> CSRMatrix:
    """Circuit-simulation matrix (ASIC_*/rajat* family).

    Full diagonal, geometric number of local off-diagonal entries per row and
    a handful of nearly-dense rows/columns (supply rails) that dominate the
    load-imbalance profile.
    """
    rng = _rng(seed)
    rows = [np.arange(n)]
    cols = [np.arange(n)]
    # local couplings: geometric count, near-diagonal columns
    cnt = rng.geometric(1.0 / (1.0 + avg_offdiag), size=n) - 1
    r = np.repeat(np.arange(n), cnt)
    spread = rng.integers(-2000, 2000, size=r.size)
    c = np.clip(r + spread, 0, n - 1)
    rows.append(r)
    cols.append(c)
    # dense rows and matching dense columns (rails)
    rail_len = max(1, int(n * dense_row_frac))
    for k in range(n_dense_rows):
        rail = rng.integers(0, n)
        touched = rng.choice(n, size=rail_len, replace=False)
        rows.append(np.full(rail_len, rail))
        cols.append(touched)
        rows.append(touched)
        cols.append(np.full(rail_len, rail))
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    data = rng.standard_normal(rows.size)
    return csr_from_coo(COOMatrix(rows, cols, data, (n, n)))


def banded_fem(
    n: int,
    *,
    seed: int = 0,
    band: int = 24,
    fill: float = 0.75,
) -> CSRMatrix:
    """Banded FEM/device-simulation matrix (ohne2/barrier2-3/nxp1 family)."""
    rng = _rng(seed)
    offsets = np.arange(-band, band + 1)
    rows = []
    cols = []
    for off in offsets:
        keep = rng.random(n) < fill
        r = np.nonzero(keep)[0]
        c = r + off
        ok = (c >= 0) & (c < n)
        rows.append(r[ok])
        cols.append(c[ok])
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    data = rng.standard_normal(rows.size)
    return csr_from_coo(COOMatrix(rows, cols, data, (n, n)))


def dense_block(
    n: int,
    *,
    seed: int = 0,
    block: int = 512,
    n_blocks: int = 12,
    background: float = 8.0,
) -> CSRMatrix:
    """Matrix with a few dense blocks plus sparse background (mip1 family)."""
    rng = _rng(seed)
    rows = []
    cols = []
    for _ in range(n_blocks):
        r0 = rng.integers(0, max(1, n - block))
        c0 = rng.integers(0, max(1, n - block))
        density = 0.35
        cnt = int(block * block * density)
        rows.append(r0 + rng.integers(0, block, size=cnt))
        cols.append(c0 + rng.integers(0, block, size=cnt))
    bg = int(n * background)
    rows.append(rng.integers(0, n, size=bg))
    cols.append(rng.integers(0, n, size=bg))
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    data = rng.standard_normal(rows.size)
    return csr_from_coo(COOMatrix(rows, cols, data, (n, n)))


def uniform_random(n: int, density: float, *, seed: int = 0) -> CSRMatrix:
    rng = _rng(seed)
    nnz = int(n * n * density)
    rows = rng.integers(0, n, size=nnz)
    cols = rng.integers(0, n, size=nnz)
    data = rng.standard_normal(nnz)
    return csr_from_coo(COOMatrix(rows, cols, data, (n, n)))


# ---------------------------------------------------------------------------
# The benchmark suite: scaled-down analogues of the paper's Table I.
# Sizes are reduced ~8-32x so the full benchmark sweep runs on a single CPU
# host; the structural characteristics (degree distributions, banding,
# rails) match the originals.  ``scale`` in benchmarks can raise them.
# ---------------------------------------------------------------------------

SUITE_SPECS: Dict[str, Callable[[int], CSRMatrix]] = {
    # circuit family (ASIC_320k / ASIC_680k / rajat21/24/29/30)
    "m1_asic320k": lambda s: circuit(40_000, seed=1 + s, avg_offdiag=4.9),
    "m2_asic680k": lambda s: circuit(85_000, seed=2 + s, avg_offdiag=4.6),
    "m3_barrier2": lambda s: banded_fem(14_000, seed=3 + s, band=9, fill=0.95),
    # kron_g500 family (power-law)
    "m4_kron16": lambda s: rmat(1 << 16, 5_200_000, seed=4 + s),
    "m5_kron17": lambda s: rmat(1 << 17, 10_800_000, seed=5 + s),
    "m8_mip1": lambda s: dense_block(8_000, seed=8 + s, block=384, n_blocks=10),
    "m9_nxp1": lambda s: banded_fem(52_000, seed=9 + s, band=3, fill=0.9),
    "m10_ohne2": lambda s: banded_fem(22_000, seed=10 + s, band=19, fill=0.95),
    "m11_rajat21": lambda s: circuit(51_000, seed=11 + s, avg_offdiag=3.4),
    "m14_rajat30": lambda s: circuit(80_000, seed=14 + s, avg_offdiag=8.7),
}


def paper_suite(seed: int = 0) -> Dict[str, CSRMatrix]:
    """Generate the full scaled Table-I analogue suite."""
    return {name: gen(seed) for name, gen in SUITE_SPECS.items()}
