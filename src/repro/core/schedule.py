"""Mixed execution allocation (paper §III-C), adapted to TPU scheduling.

The paper balances load *between* matrix blocks by splitting the block set
into a **fixed part** — statically assigned, one warp per block, preferring
same-column blocks per warp so the shared-memory vector segment is reused —
and a **competitive part** — blocks grabbed at runtime (ticket lock) by
warps that finished their fixed quota ("those who are capable work harder").

TPU adaptation (DESIGN.md §Hardware-adaptation): a TPU program is statically
scheduled — there is no runtime work stealing between cores.  But the
*reason* the GPU needs runtime competition is that execution time is
unpredictable (cache misses, divergence).  On TPU, per-block execution time
is a deterministic function of the tile count, so the competitive phase can
be *played out at schedule time*: we simulate "whoever is free takes the
next block", which is exactly the greedy LPT (longest-processing-time)
policy.  The fixed/competitive split therefore becomes:

* fixed part      — ``fixed_fraction`` of total work assigned round-robin in
  column-major order (locality: consecutive blocks of a worker share the
  same x segment, the VMEM analogue of the paper's shared-memory reuse);
* competitive part — the remaining blocks, sorted by descending cost, each
  assigned to the currently least-loaded worker (deterministic ticket-lock
  replay).

Workers are devices (distributed SpMV) or the two megacore slots of one
chip.  The returned schedule is dense: per-worker block lists padded to
equal length with null blocks, so every worker runs the same program.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List

import numpy as np

__all__ = ["Schedule", "mixed_schedule", "lpt_schedule", "contiguous_schedule"]


@dataclasses.dataclass
class Schedule:
    """Assignment of blocks to workers.

    ``assignment[w]`` lists block ids for worker ``w`` in execution order;
    ``loads[w]`` is the summed cost.  ``makespan_ratio`` = max load / mean
    load: 1.0 is a perfect balance (the metric of the Fig. 5 discussion).
    """

    assignment: List[List[int]]
    loads: np.ndarray
    fixed_counts: np.ndarray  # how many of each worker's blocks were fixed

    @property
    def makespan_ratio(self) -> float:
        mean = self.loads.mean()
        return float(self.loads.max() / mean) if mean > 0 else 1.0

    @property
    def competitive_ratio(self) -> float:
        """Modeled makespan over the ideal balanced makespan.

        ``ideal = total / n_workers`` (every worker slot counted, loaded or
        not), so this equals :attr:`makespan_ratio` but carries the paper's
        framing: how far the competitive allocation lands from a perfectly
        balanced split.  1.0 is ideal; a value pinned well above 1 means a
        single block dominates and NO schedule can balance the work — the
        partition itself is the bottleneck, not the placement.
        """
        return self.makespan_ratio

    def padded(self, null_block: int = -1) -> np.ndarray:
        """Dense [workers, max_len] block-id matrix padded with null blocks."""
        n = max((len(a) for a in self.assignment), default=0)
        out = np.full((len(self.assignment), n), null_block, dtype=np.int64)
        for w, blocks in enumerate(self.assignment):
            out[w, : len(blocks)] = blocks
        return out


def contiguous_schedule(costs: np.ndarray, n_workers: int) -> Schedule:
    """Naive static split: equal *count* of blocks per worker (the baseline
    the paper's fixed/competitive split improves on)."""
    n = costs.size
    ids = np.arange(n)
    chunks = np.array_split(ids, n_workers)
    loads = np.array([costs[c].sum() for c in chunks], dtype=np.float64)
    return Schedule([list(c) for c in chunks], loads, np.array([len(c) for c in chunks]))


def lpt_schedule(costs: np.ndarray, n_workers: int) -> Schedule:
    """Pure greedy LPT: every block competitive (no locality)."""
    order = np.argsort(-costs, kind="stable")
    heap = [(0.0, w) for w in range(n_workers)]
    heapq.heapify(heap)
    assignment: List[List[int]] = [[] for _ in range(n_workers)]
    for b in order:
        load, w = heapq.heappop(heap)
        assignment[w].append(int(b))
        heapq.heappush(heap, (load + float(costs[b]), w))
    loads = np.array([costs[a].sum() if a else 0.0 for a in assignment])
    return Schedule(assignment, loads, np.zeros(n_workers, dtype=np.int64))


def mixed_schedule(
    costs: np.ndarray,
    n_workers: int,
    *,
    n_cols: int | None = None,
    fixed_fraction: float = 0.7,
) -> Schedule:
    """The paper's fixed + competitive allocation, replayed statically.

    ``costs`` is per-block work (tile count / nnz), flattened row-major over
    the (row-block, col-block) grid; ``n_cols`` is the number of column
    blocks — needed to group same-column blocks in the fixed phase.
    """
    from repro import obs

    with obs.span(
        "admit.schedule", blocks=int(costs.size), workers=n_workers
    ) as sp:
        sched = _mixed_schedule_impl(
            costs, n_workers, n_cols=n_cols, fixed_fraction=fixed_fraction
        )
        sp.annotate(makespan_ratio=round(sched.makespan_ratio, 4))
    if obs.enabled():
        obs.gauge("schedule.makespan_ratio").set(sched.makespan_ratio)
        obs.counter("schedule.builds").inc()
    return sched


def _mixed_schedule_impl(
    costs: np.ndarray,
    n_workers: int,
    *,
    n_cols: int | None = None,
    fixed_fraction: float = 0.7,
) -> Schedule:
    n = costs.size
    ids = np.arange(n)
    if n_cols:
        # column-major visit order: same-column blocks land on the same
        # worker consecutively (vector-segment reuse).
        cols = ids % n_cols
        visit = ids[np.argsort(cols, kind="stable")]
    else:
        visit = ids
    total = float(costs.sum())
    fixed_budget = fixed_fraction * total

    assignment: List[List[int]] = [[] for _ in range(n_workers)]
    loads = np.zeros(n_workers, dtype=np.float64)
    fixed_counts = np.zeros(n_workers, dtype=np.int64)

    # --- fixed part: round-robin contiguous runs of the column-major order
    assigned = np.zeros(n, dtype=bool)
    spent = 0.0
    w = 0
    per_worker_quota = fixed_budget / n_workers if n_workers else 0.0
    for b in visit:
        if spent >= fixed_budget:
            break
        if loads[w] >= (fixed_counts[w] + 1) * 0 + per_worker_quota and w < n_workers - 1:
            w += 1
        assignment[w].append(int(b))
        loads[w] += float(costs[b])
        fixed_counts[w] += 1
        assigned[b] = True
        spent += float(costs[b])

    # --- competitive part: deterministic ticket-lock replay == greedy LPT
    rest = ids[~assigned]
    order = rest[np.argsort(-costs[rest], kind="stable")]
    heap = [(loads[ww], ww) for ww in range(n_workers)]
    heapq.heapify(heap)
    for b in order:
        load, ww = heapq.heappop(heap)
        assignment[ww].append(int(b))
        load += float(costs[b])
        loads[ww] = load
        heapq.heappush(heap, (load, ww))

    return Schedule(assignment, loads, fixed_counts)
