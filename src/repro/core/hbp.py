"""The Hash-Based Partition (HBP) format — faithful construction (Fig. 2).

This module reproduces the paper's storage format with its exact GPU
semantics and serves as the reference the TPU tile format
(:mod:`repro.core.tile`) and the Pallas kernels are validated against.

Components (paper §III-A):

* ``col`` / ``data``       — nonzeros of each block stored adjacently, in
  jagged column-major order over each warp's rows (no zero padding).
* ``add_sign``             — distance from a nonzero to the next nonzero of
  the *same row* inside the block; ``-1`` marks the last one.
* ``zero_row``             — ``-1`` for all-zero rows, else the number of
  zero rows preceding it inside its warp (so thread ``q`` can locate its
  first element without padding).
* ``begin_nnz``            — offset of each block's first nonzero (the
  role CSR's ``ptr`` plays, but per block).
* ``group_ptr``            — offset of each (block, warp-group)'s storage
  (the ``begin_ptr`` of Algorithm 3).
* ``output_hash``          — ``output_hash[slot] = original row``; the table
  index *is* the execution order, writes go to the pre-hash position.

Note on Algorithm 3: as printed, ``while add_sign[j] > 0`` would skip the
final element of every row (its ``add_sign`` is ``-1``).  We implement the
evidently intended do-while semantics — process the element, then follow
``add_sign`` if positive — and record the pseudocode off-by-one in
DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from .formats import CSRMatrix
from .hash import HashParams, sample_params
from .partition import Partition2D, PartitionConfig
from .reorder import REORDER_METHODS

__all__ = ["HBPMatrix", "build_hbp", "hbp_spmv_reference"]


@dataclasses.dataclass
class HBPMatrix:
    """Faithful HBP container (host-side arrays, GPU layout semantics)."""

    col: np.ndarray        # int64[nnz]  global column ids, jagged col-major
    data: np.ndarray       # float[nnz]
    add_sign: np.ndarray   # int64[nnz]  step to next element of same row, -1 at end
    zero_row: np.ndarray   # int64[nbr, nbc, row_block]
    begin_nnz: np.ndarray  # int64[nbr*nbc + 1]
    group_ptr: np.ndarray  # int64[nbr, nbc, groups_per_block] storage offsets
    output_hash: np.ndarray  # int64[nbr, nbc, row_block]  slot -> original local row
    group_nnz_rows: np.ndarray  # int64[nbr, nbc, groups_per_block] nonzero rows per group
    shape: tuple
    cfg: PartitionConfig
    warp: int
    hash_params: Dict[int, HashParams]  # per row-block sampled (a, c)

    @property
    def nnz(self) -> int:
        return int(self.data.size)

    @property
    def grid(self):
        return self.cfg.grid(self.shape)


def _jagged_order(row_pos: np.ndarray, k: np.ndarray, group: np.ndarray) -> np.ndarray:
    """Stable order by (group, k, row position): jagged column-major."""
    return np.lexsort((row_pos, k, group))


def build_hbp(
    csr: CSRMatrix,
    cfg: PartitionConfig | None = None,
    *,
    warp: int = 32,
    method: str = "hash",
) -> HBPMatrix:
    """Convert CSR → HBP (paper §III-B "format conversion").

    ``method`` selects the reordering: "hash" (the paper), "sort2d", "dp2d"
    or "none" — the same format built on a different permutation, which is
    how the preprocessing benchmark compares strategies like-for-like.
    """
    from repro import obs

    cfg = cfg or PartitionConfig()
    with obs.span("admit.build_hbp", method=method, nnz=csr.nnz, warp=warp):
        return _build_hbp_impl(csr, cfg, warp, method)


def _build_hbp_impl(
    csr: CSRMatrix, cfg: PartitionConfig, warp: int, method: str
) -> HBPMatrix:
    part = Partition2D.build(csr, cfg)
    nbr, nbc = part.grid
    R = cfg.row_block
    gpb = R // warp  # warp groups per block

    reorder = REORDER_METHODS[method]

    col_out = np.empty(csr.nnz, dtype=np.int64)
    data_out = np.empty(csr.nnz, dtype=csr.data.dtype)
    add_out = np.empty(csr.nnz, dtype=np.int64)
    zero_row = np.full((nbr, nbc, R), -1, dtype=np.int64)
    group_ptr = np.zeros((nbr, nbc, gpb), dtype=np.int64)
    out_hash = np.zeros((nbr, nbc, R), dtype=np.int64)
    group_nzr = np.zeros((nbr, nbc, gpb), dtype=np.int64)
    hash_params: Dict[int, HashParams] = {}

    for bi in range(nbr):
        lo = bi * R
        hi = min(lo + R, csr.n_rows)
        n_local = hi - lo
        # per-row nnz inside each column block of this row block
        counts = np.zeros((R, nbc), dtype=np.int64)
        counts[:n_local] = part.counts[lo:hi]
        if method == "hash":
            params = sample_params(counts[counts > 0], table_size=R)
            hash_params[bi] = params
        for bj in range(nbc):
            base = part.begin_nnz[bi * nbc + bj]
            nnz_rows = counts[:, bj]
            if method == "hash":
                perm = REORDER_METHODS["hash"](nnz_rows, hash_params[bi])
            else:
                perm = reorder(nnz_rows)
            out_hash[bi, bj] = perm
            nnz_hashed = nnz_rows[perm]

            # zero_row: -1 for empty rows, else #zero rows before it in warp
            z = (nnz_hashed == 0).reshape(gpb, warp)
            zcum = np.cumsum(z, axis=1) - z  # exclusive prefix count
            zr = np.where(z, -1, zcum).reshape(-1)
            zero_row[bi, bj] = zr
            group_nzr[bi, bj] = (~z).sum(axis=1)

            blk_nnz = int(nnz_hashed.sum())
            if blk_nnz == 0:
                group_ptr[bi, bj] = base
                continue

            # entries of this block in block-row-major order, then reorder
            # rows by the permutation and emit jagged column-major.
            rows, cols, vals = part.block_entries(bi, bj)
            inv = np.empty(R, dtype=np.int64)
            inv[perm] = np.arange(R)
            row_pos = inv[rows]  # position of each entry's row in hashed order
            order_rm = np.lexsort((cols, row_pos))  # hashed-row major
            row_pos = row_pos[order_rm]
            cols = cols[order_rm] + bj * cfg.col_block  # store GLOBAL col
            vals = vals[order_rm]
            # k = index of entry within its row
            starts = np.zeros(R + 1, dtype=np.int64)
            np.cumsum(nnz_hashed, out=starts[1:])
            k = np.arange(blk_nnz) - starts[row_pos]
            grp = row_pos // warp
            jperm = _jagged_order(row_pos, k, grp)
            jpos = np.empty(blk_nnz, dtype=np.int64)
            jpos[jperm] = np.arange(blk_nnz)
            # add_sign: jagged distance to the next entry of the same row
            add = np.full(blk_nnz, -1, dtype=np.int64)
            same_row = row_pos[:-1] == row_pos[1:]
            add[:-1][same_row] = jpos[1:][same_row] - jpos[:-1][same_row]
            sl = slice(base, base + blk_nnz)
            col_out[sl] = cols[jperm]
            data_out[sl] = vals[jperm]
            add_out[sl] = add[jperm]
            # group storage offsets: cumsum of per-group nnz
            gsz = np.bincount(grp, weights=None, minlength=gpb)
            goff = np.zeros(gpb, dtype=np.int64)
            np.cumsum(gsz[:-1], out=goff[1:])
            group_ptr[bi, bj] = base + goff

    return HBPMatrix(
        col=col_out,
        data=data_out,
        add_sign=add_out,
        zero_row=zero_row,
        begin_nnz=part.begin_nnz,
        group_ptr=group_ptr,
        output_hash=out_hash,
        group_nnz_rows=group_nzr,
        shape=csr.shape,
        cfg=cfg,
        warp=warp,
        hash_params=hash_params,
    )


def hbp_spmv_reference(hbp: HBPMatrix, x: np.ndarray) -> np.ndarray:
    """Reference SpMV over the HBP format (Algorithm 3 semantics).

    Emulates the GPU execution: one warp per block, one thread per row slot,
    ``add_sign`` chases the jagged column-major storage.  Vectorised across
    the threads of a warp; the while-loop over ``add_sign`` is iterated to
    the longest row.  Partial vectors of blocks in the same block-row are
    summed — the "combine part" of Fig. 1.
    """
    nbr, nbc = hbp.grid
    R = hbp.cfg.row_block
    warp = hbp.warp
    gpb = R // warp
    y = np.zeros(hbp.shape[0], dtype=np.result_type(hbp.data, x))

    for bi in range(nbr):
        row_lo = bi * R
        n_local = min(R, hbp.shape[0] - row_lo)
        for bj in range(nbc):
            acc = np.zeros(R, dtype=y.dtype)  # per-slot partial results
            zr = hbp.zero_row[bi, bj]
            for g in range(gpb):
                q = np.arange(warp)
                zrg = zr[g * warp : (g + 1) * warp]
                active = zrg >= 0
                if not active.any():
                    continue
                # thread q's first element: group base + (q - #zero rows before)
                j = hbp.group_ptr[bi, bj, g] + (q - zrg)
                j = np.where(active, j, 0)
                sums = np.zeros(warp, dtype=y.dtype)
                alive = active.copy()
                while alive.any():
                    jj = j[alive]
                    sums[alive] += hbp.data[jj] * x[hbp.col[jj]]
                    step = hbp.add_sign[jj]
                    cont = step > 0
                    nxt = np.where(cont, j[alive] + step, j[alive])
                    j[alive] = nxt
                    alive[np.nonzero(alive)[0][~cont]] = False
                acc[g * warp : (g + 1) * warp] = sums
            # combine: write back through output_hash (pre-hash positions)
            perm = hbp.output_hash[bi, bj]
            contrib = np.zeros(R, dtype=y.dtype)
            contrib[perm] = acc  # slot s computed row perm[s]
            y[row_lo : row_lo + n_local] += contrib[:n_local]
    return y
