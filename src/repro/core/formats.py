"""Sparse-matrix containers used throughout the library.

Host-side (numpy) containers hold the matrix during preprocessing — format
construction, 2D partitioning, hash reordering — mirroring how production
frameworks (cuSPARSE, MaxText input pipelines) keep format conversion on the
host.  Device-side containers (see :mod:`repro.core.tile`) are pytrees of
``jnp`` arrays consumed by the Pallas kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["COOMatrix", "CSRMatrix", "csr_from_dense", "csr_from_coo"]


@dataclasses.dataclass
class COOMatrix:
    """Coordinate format: explicit (row, col, value) triples."""

    row: np.ndarray  # int32[nnz]
    col: np.ndarray  # int32[nnz]
    data: np.ndarray  # float[nnz]
    shape: Tuple[int, int]

    def __post_init__(self) -> None:
        self.row = np.asarray(self.row, dtype=np.int64)
        self.col = np.asarray(self.col, dtype=np.int64)
        self.data = np.asarray(self.data)
        if not (self.row.shape == self.col.shape == self.data.shape):
            raise ValueError("row/col/data must have identical shapes")

    @property
    def nnz(self) -> int:
        return int(self.data.size)

    def to_csr(self) -> "CSRMatrix":
        return csr_from_coo(self)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.data.dtype)
        np.add.at(out, (self.row, self.col), self.data)
        return out


@dataclasses.dataclass
class CSRMatrix:
    """Compressed sparse row.  ``indices`` are sorted within each row.

    This is the input format of every preprocessing routine in this library,
    exactly as in the paper (Algorithm 2 consumes ``csr_ptr``/``csr_col``).
    """

    indptr: np.ndarray  # int64[n_rows + 1]
    indices: np.ndarray  # int64[nnz], column ids, sorted per row
    data: np.ndarray  # float[nnz]
    shape: Tuple[int, int]

    def __post_init__(self) -> None:
        self.indptr = np.asarray(self.indptr, dtype=np.int64)
        self.indices = np.asarray(self.indices, dtype=np.int64)
        self.data = np.asarray(self.data)
        n_rows, _ = self.shape
        if self.indptr.shape != (n_rows + 1,):
            raise ValueError(
                f"indptr has shape {self.indptr.shape}, expected {(n_rows + 1,)}"
            )
        if self.indptr[-1] != self.indices.size:
            raise ValueError("indptr[-1] must equal nnz")

    @property
    def nnz(self) -> int:
        return int(self.data.size)

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    def row_nnz(self) -> np.ndarray:
        """Number of nonzeros per row — the input of the nonlinear hash."""
        return np.diff(self.indptr)

    def row_slice(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = self.indptr[i], self.indptr[i + 1]
        return self.indices[lo:hi], self.data[lo:hi]

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.data.dtype)
        rows = np.repeat(np.arange(self.n_rows), self.row_nnz())
        out[rows, self.indices] = self.data
        return out

    def to_coo(self) -> COOMatrix:
        rows = np.repeat(np.arange(self.n_rows), self.row_nnz())
        return COOMatrix(rows, self.indices.copy(), self.data.copy(), self.shape)

    def diagonal(self) -> np.ndarray:
        """Main diagonal as a dense ``[min(shape)]`` vector (absent = 0).

        Host-resident by construction — this is what the Jacobi
        preconditioner and the serving registry capture at tile-build time.
        """
        n = min(self.shape)
        rows = np.repeat(np.arange(self.n_rows), self.row_nnz())
        mask = (rows == self.indices) & (rows < n)
        out = np.zeros(n, dtype=self.data.dtype)
        # accumulate: duplicate entries sum, matching matvec's semantics
        np.add.at(out, rows[mask], self.data[mask])
        return out

    def transpose(self) -> "CSRMatrix":
        """Aᵀ as a fresh CSR (the CSC view of A, re-read as rows).

        Index-sorted and round-trip exact: the conversion is a stable
        counting sort over (col, row), so ``A.transpose().transpose()``
        reproduces ``indptr``/``indices``/``data`` bit for bit — no
        duplicate merging, no value reordering within ties.  This is the
        host-side half of the differentiable aggregation path (the VJP of
        ``A @ X`` is ``Aᵀ @ Ḡ``), but it stands alone as format API.
        """
        n_rows, n_cols = self.shape
        rows = np.repeat(np.arange(n_rows), self.row_nnz())
        # stable sort by column, then row: Aᵀ's rows come out in order with
        # sorted inner indices (the rows of A, ascending per column)
        order = np.lexsort((rows, self.indices))
        indptr = np.zeros(n_cols + 1, dtype=np.int64)
        np.add.at(indptr, self.indices + 1, 1)
        indptr = np.cumsum(indptr)
        return CSRMatrix(indptr, rows[order], self.data[order], (n_cols, n_rows))

    def matvec(self, x: np.ndarray) -> np.ndarray:
        """Reference CSR SpMV (Algorithm 1 of the paper), vectorised."""
        prod = self.data * x[self.indices]
        out = np.zeros(self.n_rows, dtype=np.result_type(self.data, x))
        np.add.at(out, np.repeat(np.arange(self.n_rows), self.row_nnz()), prod)
        return out


def csr_from_coo(coo: COOMatrix, *, sum_duplicates: bool = True) -> CSRMatrix:
    """Convert COO → CSR with per-row sorted column indices."""
    n_rows, n_cols = coo.shape
    order = np.lexsort((coo.col, coo.row))
    row, col, data = coo.row[order], coo.col[order], coo.data[order]
    if sum_duplicates and row.size:
        key_change = np.empty(row.size, dtype=bool)
        key_change[0] = True
        key_change[1:] = (row[1:] != row[:-1]) | (col[1:] != col[:-1])
        group = np.cumsum(key_change) - 1
        row = row[key_change]
        col = col[key_change]
        data = np.bincount(group, weights=data).astype(data.dtype)
    indptr = np.zeros(n_rows + 1, dtype=np.int64)
    np.add.at(indptr, row + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRMatrix(indptr, col, data, coo.shape)


def csr_from_dense(dense: np.ndarray, *, tol: float = 0.0) -> CSRMatrix:
    mask = np.abs(dense) > tol
    row, col = np.nonzero(mask)
    coo = COOMatrix(row, col, dense[mask], dense.shape)
    return csr_from_coo(coo, sum_duplicates=False)
