"""2D partitioning of sparse matrices (paper §III-A).

The matrix is split into ``row_block × col_block`` tiles.  Column
partitioning bounds the vector segment a block touches so it fits fast
memory (GPU shared memory in the paper, VMEM on TPU); row partitioning
bounds the scope of the hash reordering.

The paper sets ``col_block = 4096`` (a vector segment of 4K doubles fits a
warp's shared-memory budget) and ``row_block = 512``.  On TPU v5e a core has
~128 MiB of VMEM, so a 4096-element f32 segment (16 KiB) is comfortably
double-buffered; we keep the paper's defaults and expose them as knobs.

:func:`count_block_nnz` is the vectorised equivalent of the per-thread
counting loop in Algorithm 2: for every row it locates the column-block
boundaries inside the row's sorted column indices with a ``searchsorted``,
which yields the per-(row, col-block) nonzero counts in one shot.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .formats import CSRMatrix

__all__ = [
    "PartitionConfig",
    "count_block_nnz",
    "block_entry_order",
    "Partition2D",
    "enumerate_configs",
]


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    row_block: int = 512  # paper: N = 512 (reorder scope)
    col_block: int = 4096  # paper: M = 4096 (vector-segment length)
    # TPU tile geometry (see kernels/hbp_spmv.py): rows per group = sublanes,
    # tile width = lanes of one VREG.
    group: int = 8
    lane: int = 128

    def grid(self, shape: Tuple[int, int]) -> Tuple[int, int]:
        n_rows, n_cols = shape
        return (
            -(-n_rows // self.row_block),
            -(-n_cols // self.col_block),
        )


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def enumerate_configs(
    shape: Tuple[int, int],
    *,
    row_blocks: Tuple[int, ...] = (256, 512),
    col_blocks: Tuple[int, ...] = (1024, 4096),
    groups: Tuple[int, ...] = (8,),
    lanes: Tuple[int, ...] = (8, 32, 128),
) -> list:
    """Candidate tile geometries for a measured autotune search.

    This is the search-space hook the serving autotuner
    (:mod:`repro.serving.autotune`) enumerates and times.  Candidates are
    clipped to the matrix: a row/column block larger than the (power-of-two
    padded) dimension only adds padding, so oversized values collapse onto
    the clipped one and duplicates are dropped, keeping the measured search
    proportional to the matrix, not to the nominal grid.  ``group`` must
    divide ``row_block`` (tile rows per group sit in the sublane dimension);
    invalid combinations are skipped.
    """
    n_rows, n_cols = shape
    row_cap = max(_next_pow2(n_rows), min(groups))
    col_cap = max(_next_pow2(n_cols), min(lanes))
    seen = set()
    out = []
    for rb in row_blocks:
        rb = min(rb, row_cap)
        for cb in col_blocks:
            cb = min(cb, col_cap)
            for g in groups:
                if rb % g:
                    continue
                for lane in lanes:
                    key = (rb, cb, g, lane)
                    if key in seen:
                        continue
                    seen.add(key)
                    out.append(
                        PartitionConfig(row_block=rb, col_block=cb, group=g, lane=lane)
                    )
    return out


def count_block_nnz(csr: CSRMatrix, cfg: PartitionConfig) -> np.ndarray:
    """Per-(row, col-block) nonzero counts — vectorised Algorithm 2.

    Returns ``counts`` of shape ``[n_rows, n_col_blocks]``.  This is the
    input of the nonlinear hash: ``counts[r, bj]`` is the nnz of row ``r``
    restricted to column block ``bj``.
    """
    n_rows, _ = csr.shape
    _, nbc = cfg.grid(csr.shape)
    if csr.nnz == 0:
        return np.zeros((n_rows, nbc), dtype=np.int64)
    # For every nonzero, its column block; then a 2D histogram over
    # (row, col_block).  Equivalent to the searchsorted loop but one pass.
    col_blk = csr.indices // cfg.col_block
    rows = np.repeat(np.arange(n_rows), csr.row_nnz())
    flat = rows * nbc + col_blk
    counts = np.bincount(flat, minlength=n_rows * nbc)
    return counts.reshape(n_rows, nbc)


def block_entry_order(csr: CSRMatrix, cfg: PartitionConfig) -> np.ndarray:
    """Stable order of nonzero entries grouped by (row_block, col_block).

    Returns a permutation ``perm`` over ``[0, nnz)`` such that
    ``indices[perm]`` enumerates entries block by block (row-block major,
    then column block), preserving row-major / column-sorted order within
    each block.  CSR entries are already sorted by (row, col), so a stable
    sort on the block id suffices — no comparison sort over full keys.
    """
    col_blk = csr.indices // cfg.col_block
    rows = np.repeat(np.arange(csr.n_rows), csr.row_nnz())
    row_blk = rows // cfg.row_block
    _, nbc = cfg.grid(csr.shape)
    block_id = row_blk * nbc + col_blk
    return np.argsort(block_id, kind="stable")


@dataclasses.dataclass
class Partition2D:
    """A 2D-partitioned view of a CSR matrix.

    * ``counts[r, bj]`` — nnz of row r in column block bj (hash input).
    * ``begin_nnz[bi, bj]`` — offset of block (bi, bj)'s first entry in the
      block-ordered entry arrays (the paper's ``begin_nnz``; plays the role
      CSR's ``ptr`` plays, but per block).
    * ``entry_perm`` — permutation taking CSR entry order to block order.
    """

    csr: CSRMatrix
    cfg: PartitionConfig
    counts: np.ndarray  # int64[n_rows, nbc]
    begin_nnz: np.ndarray  # int64[nbr * nbc + 1]
    entry_perm: np.ndarray  # int64[nnz]

    @classmethod
    def build(cls, csr: CSRMatrix, cfg: PartitionConfig | None = None) -> "Partition2D":
        from repro import obs

        cfg = cfg or PartitionConfig()
        with obs.span(
            "admit.partition",
            row_block=cfg.row_block,
            col_block=cfg.col_block,
            nnz=csr.nnz,
        ):
            counts = count_block_nnz(csr, cfg)
            nbr, nbc = cfg.grid(csr.shape)
            # per-block totals: sum counts over the rows of each row block
            n_rows = csr.n_rows
            pad_rows = nbr * cfg.row_block - n_rows
            padded = np.pad(counts, ((0, pad_rows), (0, 0)))
            block_tot = padded.reshape(nbr, cfg.row_block, nbc).sum(axis=1)
            begin = np.zeros(nbr * nbc + 1, dtype=np.int64)
            np.cumsum(block_tot.reshape(-1), out=begin[1:])
            perm = block_entry_order(csr, cfg)
        return cls(csr, cfg, counts, begin, perm)

    @property
    def grid(self) -> Tuple[int, int]:
        return self.cfg.grid(self.csr.shape)

    def block_nnz(self) -> np.ndarray:
        """nnz per block, shape [nbr, nbc] — the scheduler's cost signal."""
        nbr, nbc = self.grid
        return np.diff(self.begin_nnz).reshape(nbr, nbc)

    def block_rows(self, bi: int) -> Tuple[int, int]:
        lo = bi * self.cfg.row_block
        return lo, min(lo + self.cfg.row_block, self.csr.n_rows)

    def block_entries(self, bi: int, bj: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(rows, local_cols, data) of block (bi, bj), row-major within block."""
        nbr, nbc = self.grid
        lo, hi = self.begin_nnz[bi * nbc + bj], self.begin_nnz[bi * nbc + bj + 1]
        idx = self.entry_perm[lo:hi]
        all_rows = np.repeat(np.arange(self.csr.n_rows), self.csr.row_nnz())
        rows = all_rows[idx] - bi * self.cfg.row_block
        cols = self.csr.indices[idx] - bj * self.cfg.col_block
        return rows, cols, self.csr.data[idx]
