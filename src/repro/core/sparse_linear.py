"""HBP-backed sparse linear layers — the paper's technique inside the LM.

At decode, a pruned linear layer's matmul is a batch of SpMVs: the weight
matrix is magnitude-sparsified offline, converted once to the HBP tile
format (2D partition + nonlinear hash reordering), and applied per token
with the Pallas kernel.  This is the integration point the assignment's
"first-class feature" requirement refers to: ``examples/serve_pruned.py``
serves a model whose FFN weights run through this layer.

``SparseLinear.apply`` consumes ``x [tokens, in]`` and returns
``[tokens, out]`` by running one SpMV per token-row (vmapped over the
batch; the kernel itself is the per-vector path the paper optimizes).
For CPU validation the jnp oracle backend is used; on TPU the Pallas
kernel takes over unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

import jax
import jax.numpy as jnp

from .formats import csr_from_dense
from .partition import PartitionConfig
from .tile import HBPTiles, build_tiles

__all__ = ["SparseLinear", "magnitude_prune"]


def magnitude_prune(w: np.ndarray, sparsity: float) -> np.ndarray:
    """Zero the smallest-|w| entries to the requested sparsity."""
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(sparsity)
    k = int(w.size * sparsity)
    if k == 0:
        return w.copy()
    thresh = np.partition(np.abs(w).reshape(-1), k)[k]
    out = w.copy()
    out[np.abs(out) < thresh] = 0.0
    return out


@dataclasses.dataclass
class SparseLinear:
    """y = W_sparse @ x with W in HBP tile format (W: [out, in])."""

    tiles: HBPTiles
    out_features: int
    in_features: int
    backend: Literal["pallas", "jnp"] = "jnp"

    @classmethod
    def from_dense(
        cls,
        w: np.ndarray,  # [out, in]
        *,
        sparsity: float = 0.9,
        cfg: PartitionConfig | None = None,
        backend: Literal["pallas", "jnp"] = "jnp",
    ) -> "SparseLinear":
        cfg = cfg or PartitionConfig(row_block=256, col_block=512)
        pruned = magnitude_prune(np.asarray(w, np.float32), sparsity)
        csr = csr_from_dense(pruned)
        tiles = build_tiles(csr, cfg, method="hash")
        return cls(tiles, w.shape[0], w.shape[1], backend)

    def apply(self, x: jax.Array) -> jax.Array:
        """x: [..., in_features] -> [..., out_features]."""
        from repro.kernels import device_tiles, hbp_spmv

        dt = device_tiles(self.tiles)
        lead = x.shape[:-1]
        flat = x.reshape(-1, self.in_features)
        strategy = "reference" if self.backend == "jnp" else "fused"

        def one(v):
            return hbp_spmv(
                dt,
                v,
                strategy=strategy,
                n_rowgroups=self.tiles.n_rowgroups,
                n_rows=self.out_features,
                col_block=self.tiles.cfg.col_block,
            )

        y = jax.vmap(one)(flat)
        return y.reshape(*lead, self.out_features)

    def density(self) -> float:
        return float(np.count_nonzero(self.tiles.data)) / (
            self.out_features * self.in_features
        )
