"""TPU-native HBP tile format (the hardware adaptation of Fig. 2).

On a GPU the HBP format removes warp divergence: the hash groups rows of
similar nnz so that the 32 threads of a warp finish together, and the
jagged ``add_sign`` storage avoids zero padding entirely.

A TPU core has no divergent threads to protect — its vector unit consumes
dense (8 sublanes × 128 lanes) registers and its grid is executed
*sequentially* by a scalar pipeline.  The paper's insight transfers as
follows (DESIGN.md §Hardware-adaptation):

* warp of 32 threads           → group of 8 rows (sublane dimension);
* divergence inside a warp     → zero padding inside an 8×``lane`` tile:
  each group is stored densely, padded to the group's max nnz.  The hash
  makes groups homogeneous, so padding (the TPU cost) is small — the same
  objective, a different cost model;
* ``add_sign`` pointer chasing → dense gather: a tile of column ids indexes
  the block's vector segment resident in VMEM;
* shared-memory vector segment → VMEM block, staged by ``BlockSpec``;
* the "combine part"           → revisited output blocks: the sequential
  grid lets consecutive tiles accumulate into the same output ref, fusing
  SpMV and combine (the fusion the paper wanted but atomics made too
  expensive on GPU — Discussion section).

The tile arrays produced here feed ``kernels/hbp_spmv.py`` directly.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro import obs

from .formats import CSRMatrix
from .hash import sample_params
from .partition import Partition2D, PartitionConfig
from .reorder import REORDER_METHODS

__all__ = ["HBPTiles", "build_tiles", "tuned_partition_config"]


@dataclasses.dataclass
class HBPTiles:
    """Packed 8×lane tiles, grid-ordered for the Pallas kernel.

    Tiles are sorted by (row_group, col_block, k) so that all tiles
    contributing to one output row group are consecutive — the kernel
    accumulates them into the output ref and writes it back once
    (fused combine).  ``first`` flags the first tile of each run.
    """

    data: np.ndarray  # f32[T, group, lane]
    cols: np.ndarray  # i32[T, group, lane]  LOCAL col within the col block
    rowgroup: np.ndarray  # i32[T]  global output row-group id (hashed order)
    colblock: np.ndarray  # i32[T]  column block id (selects the x segment)
    first: np.ndarray  # i32[T]  1 = first tile of its output row group
    perm: np.ndarray  # i64[padded_rows]  hashed position -> original row
    shape: Tuple[int, int]
    cfg: PartitionConfig
    n_rowgroups: int

    @property
    def n_tiles(self) -> int:
        return int(self.data.shape[0])

    def padded_rows(self) -> int:
        return self.n_rowgroups * self.cfg.group

    def nnz_utilization(self) -> float:
        """Useful fraction of tile slots (1 - padding waste)."""
        total = self.data.size
        return float(np.count_nonzero(self.data) / total) if total else 1.0

    # --- per-tile cost vectors (the plan-introspection inputs) -------------

    def tile_nnz(self) -> np.ndarray:
        """Stored entries per tile, ``i64[T]`` — each tile's useful payload.

        The kernel streams every tile at full ``group × lane`` width
        regardless, so ``tile_nnz / (group * lane)`` is the per-tile
        occupancy: the exact fraction of that tile's HBM traffic that was
        not padding.
        """
        if self.n_tiles == 0:
            return np.zeros(0, dtype=np.int64)
        return np.count_nonzero(
            self.data.reshape(self.n_tiles, -1), axis=1
        ).astype(np.int64)

    def tile_occupancy(self) -> np.ndarray:
        """Per-tile useful fraction of slots, ``f64[T]`` in (0, 1]."""
        slots = self.cfg.group * self.cfg.lane
        return self.tile_nnz() / float(slots)

    def rowgroup_costs(self) -> np.ndarray:
        """Tiles per output row group, ``i64[n_rowgroups]``.

        On the sequentially-executed TPU grid a row group's service time is
        proportional to the tiles it owns — this is the cost vector the
        imbalance gauges and the LPT competitive-ratio model consume.
        """
        return np.bincount(self.rowgroup, minlength=self.n_rowgroups).astype(
            np.int64
        )

    def block_costs(self) -> np.ndarray:
        """Tiles per (row-block, col-block) grid cell, flattened row-major.

        The schedule layer's unit of placement (paper §III-C): feeding this
        to :func:`repro.core.schedule.lpt_schedule` replays the competitive
        allocation and yields the modeled-vs-ideal makespan ratio.
        """
        gpb = self.cfg.row_block // self.cfg.group
        nbr = -(-self.n_rowgroups // gpb)
        nbc = -(-self.shape[1] // self.cfg.col_block)
        if self.n_tiles == 0:
            return np.zeros(nbr * nbc, dtype=np.int64)
        block_id = (self.rowgroup.astype(np.int64) // gpb) * nbc + self.colblock
        return np.bincount(block_id, minlength=nbr * nbc).astype(np.int64)


def build_tiles(
    csr: CSRMatrix,
    cfg: PartitionConfig | None = None,
    *,
    method: str = "hash",
) -> HBPTiles:
    """CSR → TPU tile format.

    Per (row-block, col-block): reorder rows with ``method`` (the paper's
    hash by default, "none" reproduces the plain 2D-partitioning baseline),
    cut the reordered rows into groups of ``cfg.group``, pad each group to
    ``ceil(max_nnz / lane)`` tiles of ``group × lane``, gather column ids
    local to the column block.  Padded slots carry ``col=0, data=0`` so the
    kernel's gather-multiply contributes nothing.
    """
    cfg = cfg or PartitionConfig()
    with obs.span(
        "admit.build_tiles", method=method, n_rows=csr.shape[0], nnz=csr.nnz
    ) as sp:
        tiles = _build_tiles_impl(csr, cfg, method)
        sp.annotate(
            tiles=tiles.n_tiles, nnz_utilization=round(tiles.nnz_utilization(), 4)
        )
    if obs.enabled():
        obs.counter("admit.tile_builds").inc()
        obs.counter("admit.tiles_built").inc(tiles.n_tiles)
        obs.histogram("admit.nnz_utilization").observe(tiles.nnz_utilization())
        # padding is the TPU adaptation's cost model: zero slots streamed
        # from HBM for nothing — the quantity the hash exists to minimize
        obs.counter("admit.padded_slots").inc(
            tiles.data.size - int(np.count_nonzero(tiles.data))
        )
    return tiles


def _build_tiles_impl(csr: CSRMatrix, cfg: PartitionConfig, method: str) -> HBPTiles:
    part = Partition2D.build(csr, cfg)
    nbr, nbc = part.grid
    R, G, LANE = cfg.row_block, cfg.group, cfg.lane
    gpb = R // G  # row groups per row block

    reorder = REORDER_METHODS[method]

    tiles_data: list = []
    tiles_cols: list = []
    t_rowgroup: list = []
    t_colblock: list = []
    perm_global = np.empty(nbr * R, dtype=np.int64)

    for bi in range(nbr):
        lo = bi * R
        hi = min(lo + R, csr.n_rows)
        counts = np.zeros((R, nbc), dtype=np.int64)
        counts[: hi - lo] = part.counts[lo:hi]
        row_tot = counts.sum(axis=1)
        # One permutation per ROW BLOCK (not per column block): the output
        # row order must be consistent across the column blocks that
        # accumulate into it.  The hash input is the row's total nnz in the
        # block row — the same quantity Algorithm 2 accumulates.
        with obs.span("admit.hash", row_block=bi, method=method):
            if method == "hash":
                params = sample_params(row_tot, table_size=R)
                perm = REORDER_METHODS["hash"](row_tot, params)
            else:
                perm = reorder(row_tot)
        perm_global[bi * R : (bi + 1) * R] = perm + lo
        nnz_hashed = counts[perm]  # [R, nbc]

        with obs.span("admit.pack_tiles", row_block=bi):
            for bj in range(nbc):
                if part.block_nnz()[bi, bj] == 0:
                    continue
                rows, cols, vals = part.block_entries(bi, bj)
                inv = np.empty(R, dtype=np.int64)
                inv[perm] = np.arange(R)
                row_pos = inv[rows]
                order = np.lexsort((cols, row_pos))
                row_pos, cols, vals = row_pos[order], cols[order], vals[order]
                nnzb = nnz_hashed[:, bj]
                starts = np.zeros(R + 1, dtype=np.int64)
                np.cumsum(nnzb, out=starts[1:])
                k = np.arange(vals.size) - starts[row_pos]
                grp = row_pos // G
                sub = row_pos % G
                # tiles per group: ceil(group max nnz / LANE)
                gmax = np.zeros(gpb, dtype=np.int64)
                np.maximum.at(gmax, grp, nnzb[row_pos])
                ntile = -(-gmax // LANE)  # 0 for empty groups
                tile_base = np.zeros(gpb + 1, dtype=np.int64)
                np.cumsum(ntile, out=tile_base[1:])
                total = int(tile_base[-1])
                if total == 0:
                    continue
                dblk = np.zeros((total, G, LANE), dtype=np.float32)
                cblk = np.zeros((total, G, LANE), dtype=np.int32)
                t_idx = tile_base[grp] + k // LANE
                dblk[t_idx, sub, k % LANE] = vals.astype(np.float32)
                cblk[t_idx, sub, k % LANE] = cols.astype(np.int32)
                tiles_data.append(dblk)
                tiles_cols.append(cblk)
                g_of_tile = np.repeat(np.arange(gpb), ntile)
                t_rowgroup.append(bi * gpb + g_of_tile)
                t_colblock.append(np.full(total, bj, dtype=np.int64))

    if tiles_data:
        data = np.concatenate(tiles_data)
        cols = np.concatenate(tiles_cols)
        rowgroup = np.concatenate(t_rowgroup)
        colblock = np.concatenate(t_colblock)
    else:
        data = np.zeros((0, G, LANE), dtype=np.float32)
        cols = np.zeros((0, G, LANE), dtype=np.int32)
        rowgroup = np.zeros(0, dtype=np.int64)
        colblock = np.zeros(0, dtype=np.int64)

    # Grid order: by (rowgroup, colblock) so output runs are consecutive.
    order = np.lexsort((colblock, rowgroup))
    data, cols = data[order], cols[order]
    rowgroup, colblock = rowgroup[order], colblock[order]
    first = np.ones(rowgroup.size, dtype=np.int32)
    first[1:] = (rowgroup[1:] != rowgroup[:-1]).astype(np.int32)

    return HBPTiles(
        data=data,
        cols=cols.astype(np.int32),
        rowgroup=rowgroup.astype(np.int32),
        colblock=colblock.astype(np.int32),
        first=first,
        perm=perm_global,
        shape=csr.shape,
        cfg=cfg,
        n_rowgroups=nbr * gpb,
    )


def tuned_partition_config(
    csr: CSRMatrix,
    *,
    row_block: int = 512,
    col_block: int = 4096,
    quantile: float = 0.75,
    tile_elems: int = 1024,
) -> PartitionConfig:
    """Beyond-paper: pick the tile geometry from the matrix's nnz profile.

    The paper's warp is fixed at 32 threads; our default tile is 8 rows ×
    128 lanes.  For ultra-sparse matrices (circuit/power-law rows with
    ~4-8 nnz) a 128-wide tile is ≥94% padding — the format's HBM traffic,
    the controlling quantity of a bandwidth-bound SpMV, balloons ~30×.

    Since the nonlinear hash groups rows of similar nnz anyway, narrow
    tiles lose nothing on long rows (they simply span several consecutive
    tiles, still streamed contiguously).  We choose::

        lane  = clip(next_pow2(quantile_0.75 of per-(row, col-block) nnz), 8, 128)
        group = tile_elems // lane      (tile stays 8x128-sized in VMEM)

    Narrow lanes trade VPU lane padding (compute, which SpMV has to spare)
    for HBM bytes (which it does not).  EXPERIMENTS.md §Perf quantifies
    the utilization/traffic win per suite matrix.
    """
    from .partition import count_block_nnz

    probe = PartitionConfig(row_block=row_block, col_block=col_block)
    counts = count_block_nnz(csr, probe)
    nz = counts[counts > 0]
    q = float(np.quantile(nz, quantile)) if nz.size else 1.0
    lane = 8
    while lane < 128 and lane < q:
        lane *= 2
    # group stays 8: wider groups would mix hash buckets and pad every row
    # to a more heterogeneous group max — measured to cancel the gain.
    return PartitionConfig(
        row_block=row_block, col_block=col_block, group=8, lane=lane
    )
