"""High-level SpMV API: format construction + dispatch to backends.

``spmv`` dispatches on the container type:

* :class:`~repro.core.formats.CSRMatrix`  — CSR baselines (numpy reference
  or the jnp segment-sum device path, Algorithm 1).
* :class:`~repro.core.hbp.HBPMatrix`      — faithful GPU-semantics
  reference (Algorithm 3).
* :class:`~repro.core.tile.HBPTiles`      — the production path: Pallas
  TPU kernel (``backend="pallas"``), its jnp oracle (``backend="jnp"``).
"""
from __future__ import annotations

from typing import Literal

import jax.numpy as jnp
import numpy as np

from .formats import CSRMatrix
from .hbp import HBPMatrix, build_hbp, hbp_spmv_reference
from .partition import PartitionConfig
from .tile import HBPTiles, build_tiles

__all__ = [
    "spmv",
    "csr_spmv_jnp",
    "build_hbp",
    "build_tiles",
    "PartitionConfig",
]


def csr_spmv_jnp(
    indptr: jnp.ndarray, indices: jnp.ndarray, data: jnp.ndarray, x: jnp.ndarray, n_rows: int
) -> jnp.ndarray:
    """Device CSR SpMV (Algorithm 1) via segment-sum — the CSR baseline of
    Figs. 8/10 expressed in XLA-native ops."""
    rows = jnp.cumsum(jnp.zeros(data.shape[0], jnp.int32).at[indptr[1:-1]].add(1))
    prod = data * x[indices]
    import jax

    return jax.ops.segment_sum(prod, rows, num_segments=n_rows)


def spmv(
    A,
    x,
    *,
    backend: Literal["auto", "pallas", "jnp", "reference"] = "auto",
    interpret: bool | None = None,
):
    """Sparse matrix–vector product ``A @ x``."""
    if isinstance(A, CSRMatrix):
        if backend in ("auto", "reference"):
            return A.matvec(np.asarray(x))
        return csr_spmv_jnp(
            jnp.asarray(A.indptr), jnp.asarray(A.indices), jnp.asarray(A.data), jnp.asarray(x), A.n_rows
        )
    if isinstance(A, HBPMatrix):
        return hbp_spmv_reference(A, np.asarray(x))
    if isinstance(A, HBPTiles):
        from repro.kernels import ops

        if backend in ("auto", "pallas"):
            return ops.hbp_spmv(A, jnp.asarray(x, jnp.float32), interpret=interpret)
        if backend == "jnp":
            return ops.hbp_spmv(A, jnp.asarray(x, jnp.float32), strategy="reference")
        raise ValueError(f"unsupported backend {backend!r} for HBPTiles")
    raise TypeError(f"unsupported matrix type {type(A)!r}")
