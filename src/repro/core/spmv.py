"""High-level SpMV API: format construction + dispatch to backends.

``spmv`` dispatches on the container type:

* :class:`~repro.core.formats.CSRMatrix`  — CSR baselines (numpy reference
  or the jnp segment-sum device path, Algorithm 1).
* :class:`~repro.core.hbp.HBPMatrix`      — faithful GPU-semantics
  reference (Algorithm 3).
* :class:`~repro.core.tile.HBPTiles`      — the production path: Pallas
  TPU kernel (``backend="pallas"``), its jnp oracle (``backend="jnp"``).
"""
from __future__ import annotations

from typing import Literal

import jax.numpy as jnp
import numpy as np

from .formats import CSRMatrix
from .hbp import HBPMatrix, build_hbp, hbp_spmv_reference
from .partition import PartitionConfig
from .tile import HBPTiles, build_tiles

__all__ = [
    "spmv",
    "spmm",
    "csr_spmv_jnp",
    "csr_spmm_jnp",
    "build_hbp",
    "build_tiles",
    "PartitionConfig",
]


def _csr_row_ids(indptr: jnp.ndarray, nnz: int) -> jnp.ndarray:
    """Row id of every nonzero, reconstructed from ``indptr`` on device."""
    return jnp.cumsum(jnp.zeros(nnz, jnp.int32).at[indptr[1:-1]].add(1))


def csr_spmv_jnp(
    indptr: jnp.ndarray, indices: jnp.ndarray, data: jnp.ndarray, x: jnp.ndarray, n_rows: int
) -> jnp.ndarray:
    """Device CSR SpMV (Algorithm 1) via segment-sum — the CSR baseline of
    Figs. 8/10 expressed in XLA-native ops."""
    import jax

    prod = data * x[indices]
    return jax.ops.segment_sum(prod, _csr_row_ids(indptr, data.shape[0]), num_segments=n_rows)


def csr_spmm_jnp(
    indptr: jnp.ndarray, indices: jnp.ndarray, data: jnp.ndarray, x: jnp.ndarray, n_rows: int
) -> jnp.ndarray:
    """Device CSR multi-RHS SpMM (``x: [n_cols, k]``) via segment-sum —
    the CSR baseline of the SpMM kernel."""
    import jax

    prod = data[:, None] * x[indices]  # [nnz, k]
    return jax.ops.segment_sum(prod, _csr_row_ids(indptr, data.shape[0]), num_segments=n_rows)


def spmv(
    A,
    x,
    *,
    backend: Literal["auto", "pallas", "jnp", "reference"] = "auto",
    interpret: bool | None = None,
):
    """Sparse matrix–vector product ``A @ x``.

    A 2-D ``x`` (an ``[n, k]`` block of right-hand sides) routes to
    :func:`spmm`, which serves all ``k`` columns from one kernel launch.
    A ``[n, 1]`` column vector is a 2-D input: it takes the SpMM path and
    comes back as ``[n, 1]``, never silently squeezed to ``[n]``.
    """
    # np.ndim (not getattr) so nested-list inputs dispatch by their true
    # rank instead of falling through to the 1-D path.
    if np.ndim(x) == 2:
        return spmm(A, x, backend=backend, interpret=interpret)
    if np.ndim(x) != 1:
        raise ValueError(f"spmv expects a 1-D or 2-D x, got ndim={np.ndim(x)}")
    if isinstance(A, CSRMatrix):
        if backend in ("auto", "reference"):
            return A.matvec(np.asarray(x))
        return csr_spmv_jnp(
            jnp.asarray(A.indptr), jnp.asarray(A.indices), jnp.asarray(A.data), jnp.asarray(x), A.n_rows
        )
    if isinstance(A, HBPMatrix):
        return hbp_spmv_reference(A, np.asarray(x))
    if isinstance(A, HBPTiles):
        from repro.kernels import ops

        if backend in ("auto", "pallas"):
            return ops.hbp_spmv(A, jnp.asarray(x, jnp.float32), interpret=interpret)
        if backend == "jnp":
            return ops.hbp_spmv(A, jnp.asarray(x, jnp.float32), strategy="reference")
        raise ValueError(f"unsupported backend {backend!r} for HBPTiles")
    raise TypeError(f"unsupported matrix type {type(A)!r}")


def spmm(
    A,
    x,
    *,
    backend: Literal["auto", "pallas", "jnp", "reference"] = "auto",
    interpret: bool | None = None,
):
    """Sparse matrix–matrix product ``Y = A @ X`` with ``X: [n_cols, k]``.

    Dispatches like :func:`spmv`; on :class:`HBPTiles` it launches the
    multi-RHS SpMM kernel (one tile-stream pass for all ``k`` columns).
    ``k = 1`` is a valid block width: the result keeps its ``[n, 1]`` shape.
    """
    if np.ndim(x) != 2:
        raise ValueError(
            f"spmm expects x of shape [n_cols, k], got ndim={np.ndim(x)}; "
            "use spmv for 1-D right-hand sides"
        )
    if isinstance(A, CSRMatrix):
        if backend in ("auto", "reference"):
            xs = np.asarray(x)
            return np.stack([A.matvec(xs[:, j]) for j in range(xs.shape[1])], axis=1)
        return csr_spmm_jnp(
            jnp.asarray(A.indptr), jnp.asarray(A.indices), jnp.asarray(A.data), jnp.asarray(x), A.n_rows
        )
    if isinstance(A, HBPMatrix):
        xs = np.asarray(x)
        return np.stack(
            [hbp_spmv_reference(A, xs[:, j]) for j in range(xs.shape[1])], axis=1
        )
    if isinstance(A, HBPTiles):
        from repro.kernels import ops

        if backend in ("auto", "pallas"):
            return ops.hbp_spmm(A, jnp.asarray(x, jnp.float32), interpret=interpret)
        if backend == "jnp":
            return ops.hbp_spmm(A, jnp.asarray(x, jnp.float32), strategy="reference")
        raise ValueError(f"unsupported backend {backend!r} for HBPTiles")
    raise TypeError(f"unsupported matrix type {type(A)!r}")
