"""The nonlinear hash at the heart of the HBP format (paper §III-B, Fig. 3).

The hash takes the number of nonzero elements of a row as input and produces
the row's slot in a per-block hash table whose index order *is* the execution
order.  It decomposes into three stages:

* **Aggregation** — a nonlinear map that sends rows with *similar* nnz to the
  same bucket.  The paper uses a cheap bit shift ``nnz >> a`` and artificially
  clips the bucket range to ``[0, n_buckets)`` (= 9 in the paper, "0 to 8");
  rows that overflow are treated as bucket ``n_buckets - 1``.
* **Dispersion** — spreads each bucket to a disjoint region of the hash
  table: bucket ``k`` owns slots ``[k*c, (k+1)*c)``.  ``c`` is sampled from
  the input matrix together with ``a``.
* **Linear mapping** — a fine adjustment *within* the region to reduce
  collisions; the paper exemplifies it with a modulo.  Residual collisions
  are resolved by linear probing (atomic CAS on the GPU; here a sequential
  reference and a vectorised rank-based equivalent).

Parameters ``a`` and ``c`` are sampled from the matrix at runtime;
``b`` (table size = row-partition size) and ``d`` (linear-map modulus) are
fixed before the run — exactly the split described in the paper.

Two implementations are provided:

* :func:`hash_insert_probe` — the faithful GPU semantics: slots are claimed
  in thread order with linear probing.  Used as the reference oracle.
* :func:`hash_insert_ranked` — a vectorised, order-equivalent variant: rows
  are placed at ``slot0 + rank`` where ``rank`` is the row's position among
  all rows hashing to the same initial slot.  This is the data-parallel
  formulation used on TPU (no atomics on the vector unit), and produces the
  same *grouping* (bucket-contiguous execution order) as probing.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "HashParams",
    "sample_params",
    "hash_slot",
    "hash_insert_probe",
    "hash_insert_ranked",
    "hash_reorder",
]

N_BUCKETS = 9  # the paper maps "most numbers of nonzero elements" to 0..8


@dataclasses.dataclass(frozen=True)
class HashParams:
    """Parameters of the nonlinear hash h(nnz) (Fig. 3).

    ``a``/``c`` are *sampled* per matrix; ``b``/``d`` are fixed pre-run.
    """

    a: int  # aggregation shift: bucket = min(nnz >> a, n_buckets - 1)
    c: int  # dispersion stride: bucket k owns table slots [k*c, (k+1)*c)
    b: int  # table size == row-partition size (paper: 512)
    d: int  # linear-map modulus for the in-region fine adjustment
    n_buckets: int = N_BUCKETS


def sample_params(
    row_nnz: np.ndarray,
    table_size: int,
    *,
    quantile: float = 0.99,
    n_buckets: int = N_BUCKETS,
) -> HashParams:
    """Sample ``a`` and ``c`` from the input (paper: "a and c are dynamically
    determined based on the input matrix and sampled during program
    execution").

    ``a`` is chosen as the smallest shift such that the ``quantile`` heaviest
    row still lands inside the bucket range — "we allowed the existence of a
    small number of rows that exceed 8 after mapping".
    """
    nz = row_nnz[row_nnz > 0]
    if nz.size == 0:
        hi = 1.0
    else:
        hi = float(np.quantile(nz, quantile))
    a = 0
    while (int(hi) >> a) >= n_buckets:
        a += 1
    c = max(1, table_size // n_buckets)
    d = c  # fixed pre-run from the row-partition size, like b
    return HashParams(a=a, c=c, b=table_size, d=d, n_buckets=n_buckets)


def hash_slot(nnz: np.ndarray, p: HashParams) -> np.ndarray:
    """h(nnz): initial table slot before collision resolution.

    aggregation → dispersion → linear mapping, all O(1) per row and
    independent across rows (this is what makes the preprocessing parallel).
    """
    nnz = np.asarray(nnz)
    bucket = np.minimum(nnz >> p.a, p.n_buckets - 1)  # aggregation (clipped)
    base = bucket * p.c  # dispersion
    fine = (nnz % p.d) % p.c  # linear mapping within the region
    return np.minimum(base + fine, p.b - 1)


def hash_insert_probe(slot0: np.ndarray, table_size: int) -> np.ndarray:
    """Faithful linear-probing insertion (GPU atomic-CAS semantics).

    Rows are inserted in index order; each probes ``slot0, slot0+1, ...``
    (mod table) until a free slot is found.  Returns ``slots[i]`` = final
    table slot of row ``i``.  O(rows · probe-length) reference — the oracle
    the vectorised variant is validated against.
    """
    taken = np.zeros(table_size, dtype=bool)
    slots = np.empty(slot0.size, dtype=np.int64)
    for i, s in enumerate(slot0):
        s = int(s)
        while taken[s]:
            s = (s + 1) % table_size
        taken[s] = True
        slots[i] = s
    return slots


def hash_insert_ranked(slot0: np.ndarray, table_size: int) -> np.ndarray:
    """Vectorised collision resolution: row i goes to position
    ``rank`` among rows sorted by (slot0, i).

    Equivalent to probing in the dense limit (every slot eventually filled)
    and produces the same bucket-contiguous ordering; fully data-parallel
    (one stable counting-sort-by-key, no atomics), which is the TPU-native
    formulation of the paper's hash+probe.
    """
    if slot0.size > table_size:
        raise ValueError("more rows than table slots")
    order = np.argsort(slot0, kind="stable")  # counting sort by initial slot
    slots = np.empty(slot0.size, dtype=np.int64)
    slots[order] = np.arange(slot0.size)
    return slots


def hash_reorder(
    row_nnz: np.ndarray,
    params: HashParams | None = None,
    *,
    method: str = "ranked",
) -> np.ndarray:
    """Full hash-based reordering of one row block.

    Returns ``perm`` with ``perm[slot] = original_row`` — the paper's
    ``output_hash`` read the other way around: position in ``perm`` is the
    execution order, the value is the row computed at that position.
    """
    row_nnz = np.asarray(row_nnz)
    if params is None:
        params = sample_params(row_nnz, table_size=row_nnz.size)
    slot0 = hash_slot(row_nnz, params)
    if method == "probe":
        slots = hash_insert_probe(slot0, params.b)
    elif method == "ranked":
        slots = hash_insert_ranked(slot0, min(params.b, slot0.size) if slot0.size else params.b)
    else:
        raise ValueError(f"unknown method {method!r}")
    if method == "probe":
        # compress occupied slots to a dense execution order
        order = np.argsort(slots, kind="stable")
        return order
    perm = np.empty(slot0.size, dtype=np.int64)
    perm[slots] = np.arange(slot0.size)
    return perm
