"""Row-reordering strategies and load-balance metrics (paper §III-B, §IV-A/B).

All strategies consume the per-row nonzero counts of one (row-block,
col-block) tile and return a permutation ``perm`` with ``perm[slot] =
original_row`` (the paper's ``output_hash``).  Rows executed by the same
warp (GPU) / packed into the same sublane group (TPU) are consecutive slots.

Strategies:

* :func:`hash_reorder_block` — the paper's nonlinear hash (O(rows), parallel).
* :func:`sort_reorder` — ``sort2D`` baseline: full comparison sort by nnz.
* :func:`dp_reorder` — ``DP2D`` baseline: the Regu2D dynamic-programming
  grouping (sort + O(n·G) DP choosing group boundaries that minimise padded
  work).  Its mandatory sort is the bottleneck the paper removes.
* :func:`identity_reorder` — no reordering (the plain 2D-partitioning
  baseline of Figs. 8/10).

Metrics:

* :func:`group_stddev` — Fig. 6's metric: std-dev of per-row nnz within each
  execution group (warp on GPU, sublane group on TPU).
* :func:`padding_waste` — the TPU-relevant cost: fraction of padded slots
  when each group is stored as a dense tile of width = group max.
"""
from __future__ import annotations

import numpy as np

from .hash import HashParams, hash_reorder

__all__ = [
    "identity_reorder",
    "hash_reorder_block",
    "sort_reorder",
    "dp_reorder",
    "group_stddev",
    "padding_waste",
    "REORDER_METHODS",
]


def identity_reorder(row_nnz: np.ndarray) -> np.ndarray:
    return np.arange(row_nnz.size, dtype=np.int64)


def hash_reorder_block(
    row_nnz: np.ndarray, params: HashParams | None = None
) -> np.ndarray:
    """The paper's method — see :mod:`repro.core.hash`."""
    return hash_reorder(row_nnz, params)


def sort_reorder(row_nnz: np.ndarray) -> np.ndarray:
    """sort2D baseline: comparison sort on the row nnz."""
    return np.argsort(row_nnz, kind="stable")


def dp_reorder(row_nnz: np.ndarray, *, group: int = 32, max_group: int | None = None) -> np.ndarray:
    """DP2D baseline (Regu2D): sort, then dynamic programming over group
    boundaries minimising the zero-padded storage cost.

    After sorting ascending, rows are split into contiguous groups of size at
    most ``max_group`` (default ``2*group``); a group of rows ``[i, j)`` costs
    ``(j - i_pad) * nnz[j-1]`` where every row is padded to the group max
    (``nnz[j-1]``, the largest since sorted).  DP finds the boundary set with
    minimum total padded cost.  The output permutation is the sorted order —
    the DP's value is the grouping, its *cost* is the sort + O(n·G) table,
    which is what the preprocessing benchmark measures.
    """
    order = np.argsort(row_nnz, kind="stable")
    nnz = np.asarray(row_nnz)[order]
    n = nnz.size
    max_group = max_group or 2 * group
    INF = np.inf
    best = np.full(n + 1, INF)
    best[0] = 0.0
    choice = np.zeros(n + 1, dtype=np.int64)
    for j in range(1, n + 1):
        lo = max(0, j - max_group)
        # group [i, j) padded to nnz[j-1]
        for i in range(lo, j):
            c = best[i] + (j - i) * nnz[j - 1]
            if c < best[j]:
                best[j] = c
                choice[j] = i
    # boundaries are implicit in the sorted order; the permutation is the
    # sorted order itself (groups are contiguous runs of it).
    return order


REORDER_METHODS = {
    "none": identity_reorder,
    "hash": hash_reorder_block,
    "sort2d": sort_reorder,
    "dp2d": dp_reorder,
}


def group_stddev(row_nnz: np.ndarray, perm: np.ndarray, *, group: int = 32) -> np.ndarray:
    """Per-group std-dev of nnz after reordering (Fig. 6's ordinate).

    ``group`` is the number of rows executed together: the warp width (32)
    on GPU; on TPU we also report it for the 8-row sublane groups.
    """
    nnz = np.asarray(row_nnz)[perm].astype(np.float64)
    pad = (-nnz.size) % group
    if pad:
        nnz = np.pad(nnz, (0, pad))
    return nnz.reshape(-1, group).std(axis=1)


def padding_waste(row_nnz: np.ndarray, perm: np.ndarray, *, group: int = 8) -> float:
    """Fraction of wasted (padded) slots when each ``group`` consecutive rows
    are stored as a dense tile of width ``max(nnz in group)``.

    This is the TPU analogue of warp divergence: on the GPU wasted work is
    idle lanes inside a warp; on the TPU it is zero-padded MAC slots inside
    an 8×128 tile.  Lower is better; 0 means perfectly homogeneous groups.
    """
    nnz = np.asarray(row_nnz)[perm].astype(np.int64)
    pad = (-nnz.size) % group
    if pad:
        nnz = np.pad(nnz, (0, pad))
    g = nnz.reshape(-1, group)
    padded = (g.max(axis=1) * group).sum()
    useful = g.sum()
    if padded == 0:
        return 0.0
    return float(1.0 - useful / padded)
