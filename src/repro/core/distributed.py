"""Distributed SpMV: the paper's block scheduling at cluster scale.

The 2D block grid maps onto the device mesh; the combine part becomes a
collective.  Two placements mirror the paper's fixed/competitive split:

* ``grid``     — locality-first (the *fixed* part writ large): row blocks
  shard over "data", column blocks over "model".  Each device owns the x
  segments of its column shard, so SpMV needs **no communication at all**;
  the combine is one ``psum_scatter`` over "model".
* ``balanced`` — the *competitive* part: blocks are LPT-assigned to
  devices by tile count regardless of position (deterministic replay of
  the paper's ticket-lock), x is fully replicated, partials reduce with a
  single ``psum``.  Better makespan on power-law matrices, more bytes on
  the wire — exactly the trade the paper navigates on-chip.

Implementation: ``shard_map`` over the mesh; per-device tile lists are
padded to equal length with null tiles (rowgroup -1 → accumulated into a
scratch row), so every device runs the same program — the SPMD analogue of
the paper's equal-length fixed quota.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .formats import CSRMatrix
from .partition import PartitionConfig
from .schedule import lpt_schedule
from .tile import HBPTiles, build_tiles

__all__ = ["ShardedSpmv", "build_sharded_spmv"]


def _pad_tiles(arrs, n_pad, rowgroup_fill=-1):
    data, cols, rowgroup, colblock = arrs
    G, LANE = data.shape[1], data.shape[2]
    return (
        np.concatenate([data, np.zeros((n_pad, G, LANE), data.dtype)]),
        np.concatenate([cols, np.zeros((n_pad, G, LANE), cols.dtype)]),
        np.concatenate([rowgroup, np.full(n_pad, rowgroup_fill, rowgroup.dtype)]),
        np.concatenate([colblock, np.zeros(n_pad, colblock.dtype)]),
    )


@dataclasses.dataclass
class ShardedSpmv:
    """Device-placed tile shards + the jitted sharded matvec."""

    mesh: Mesh
    mode: str
    tiles: HBPTiles
    # stacked per-device tiles [n_dev, T_max, ...]
    data: jax.Array
    cols: jax.Array
    rowgroup: jax.Array
    colblock: jax.Array
    perm: jax.Array
    n_rows: int
    loads: np.ndarray

    def matvec(self, x: jax.Array) -> jax.Array:
        from jax.experimental.shard_map import shard_map

        cfg = self.tiles.cfg
        nrg = self.tiles.n_rowgroups
        n_cb = -(-self.tiles.shape[1] // cfg.col_block)
        axis = "data"  # worker axis
        xb_len = n_cb * cfg.col_block

        def local(data, cols, rowgroup, colblock, xb):
            # data: [1, T, G, L] local shard; xb: [n_cb, col_block] replicated
            segs = xb[colblock[0]]  # [T, col_block]
            T, G, L = data.shape[1:]
            gathered = jnp.take_along_axis(
                segs[:, None, :], cols[0].reshape(T, 1, G * L), axis=2
            ).reshape(T, G, L)
            contrib = jnp.sum(data[0] * gathered, axis=2)  # [T, G]
            seg_ids = jnp.where(rowgroup[0] < 0, nrg, rowgroup[0])
            y_part = jax.ops.segment_sum(contrib, seg_ids, num_segments=nrg + 1)
            y_part = y_part[:nrg]  # drop the null-tile scratch row
            # combine part: one collective over the worker axis
            return jax.lax.psum(y_part, axis)[None]

        n_workers = self.data.shape[0]
        fn = shard_map(
            local,
            mesh=self.mesh,
            in_specs=(P(axis), P(axis), P(axis), P(axis), P()),
            out_specs=P(axis),
            check_rep=False,
        )
        xb = jnp.pad(x, (0, xb_len - x.shape[0])).reshape(n_cb, cfg.col_block)
        y_hashed = fn(self.data, self.cols, self.rowgroup, self.colblock, xb)[0]
        flat = y_hashed.reshape(-1)
        out = jnp.zeros(self.perm.shape[0], flat.dtype).at[self.perm].set(flat)
        return out[: self.n_rows]


def build_sharded_spmv(
    csr: CSRMatrix,
    mesh: Mesh,
    *,
    cfg: PartitionConfig | None = None,
    mode: Literal["grid", "balanced"] = "balanced",
    axis: str = "data",
) -> ShardedSpmv:
    cfg = cfg or PartitionConfig()
    tiles = build_tiles(csr, cfg, method="hash")
    n_workers = mesh.shape[axis]

    if mode == "balanced":
        # competitive placement: LPT over per-rowgroup tile runs so each
        # worker's output rows stay disjoint *per tile*, balance by count
        costs = np.ones(tiles.n_tiles)
        sched = lpt_schedule(costs, n_workers)
        assign = sched.assignment
    else:
        # locality placement: tiles follow their column block (x reuse)
        assign = [[] for _ in range(n_workers)]
        for t in range(tiles.n_tiles):
            assign[int(tiles.colblock[t]) % n_workers].append(t)

    t_max = max((len(a) for a in assign), default=1)
    per_dev = []
    loads = np.zeros(n_workers)
    for w in range(n_workers):
        ids = np.asarray(assign[w], dtype=np.int64)
        loads[w] = ids.size
        arrs = (
            tiles.data[ids],
            tiles.cols[ids],
            tiles.rowgroup[ids],
            tiles.colblock[ids],
        )
        per_dev.append(_pad_tiles(arrs, t_max - ids.size))
    stacked = [np.stack([d[i] for d in per_dev]) for i in range(4)]

    return ShardedSpmv(
        mesh=mesh,
        mode=mode,
        tiles=tiles,
        data=jnp.asarray(stacked[0]),
        cols=jnp.asarray(stacked[1]),
        rowgroup=jnp.asarray(stacked[2]),
        colblock=jnp.asarray(stacked[3]),
        perm=jnp.asarray(tiles.perm),
        n_rows=csr.n_rows,
        loads=loads,
    )
