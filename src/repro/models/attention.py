"""Attention: GQA (dense + blockwise online-softmax) and MLA (DeepSeek-V2).

Prefill/training uses a double-chunked blockwise attention (online softmax,
``lax.scan`` over query and key chunks) above a size threshold, keeping the
scores working set at ``B·Cq·H·Ckv`` — the jnp-native equivalent of flash
attention and the reason ``prefill_32k`` fits.  Decode attends densely over
the KV cache (one query row; memory-bound by design).

MLA implements the *absorbed* decode path: the cache stores only the latent
``c_kv`` (+ rope key), queries are projected into the latent space, and the
value up-projection happens after the softmax — the 576 B/token cache that
is MLA's reason to exist.

No Pallas kernel here on purpose: the paper's hot-spot is SpMV; attention
stays XLA-native (DESIGN.md §Kernels).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import rope
from .params import ParamDef

__all__ = ["attention_defs", "attention_apply", "init_attn_cache"]

_DENSE_LIMIT = 1 << 22  # Sq*Skv above this -> blockwise path
_NEG = -1e30


def attention_defs(cfg: ModelConfig, *, cross: bool = False) -> Dict[str, ParamDef]:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H = cfg.padded_heads  # llava: 56 -> 64 so heads shard (DESIGN.md §2)
    if cfg.mla_kv_lora and not cross:
        r, rd = cfg.mla_kv_lora, cfg.mla_rope_dim
        return {
            "wq": ParamDef((d, H, hd + rd), ("embed", "heads", None)),
            "wkv_a": ParamDef((d, r + rd), ("embed", None)),
            "wk_b": ParamDef((r, H, hd), (None, "heads", None)),
            "wv_b": ParamDef((r, H, hd), (None, "heads", None)),
            "wo": ParamDef((H, hd, d), ("heads", None, "embed")),
        }
    return {
        "wq": ParamDef((d, H, hd), ("embed", "heads", None)),
        "wk": ParamDef((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", None)),
        "wv": ParamDef((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", None)),
        "wo": ParamDef((H, hd, d), ("heads", None, "embed")),
    }


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Zeroed decode cache + logical axis names (for spec derivation)."""
    hd = cfg.resolved_head_dim
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.mla_kv_lora:
        return {
            "ckv": jnp.zeros((batch, max_len, cfg.mla_kv_lora), dt),
            "kpe": jnp.zeros((batch, max_len, cfg.mla_rope_dim), dt),
        }
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
    }


ATTN_CACHE_LOGICAL = {
    "ckv": ("cache_batch", None, "kv_embed"),
    "kpe": ("cache_batch", None, None),
    "k": ("cache_batch", None, "kv_heads", "head_dim"),
    "v": ("cache_batch", None, "kv_heads", "head_dim"),
}


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _dense_attend(q, k, v, q_pos, k_pos, causal: bool, k_valid=None):
    """Flat-head attention.  q: [B,Sq,H,hd]; k: [B,Skv,H,hdk]; v: [B,Skv,H,hdv].

    Heads stay one flat dim end to end: a (KH, G) grouped reshape defeats
    GSPMD head sharding whenever KH or G does not divide the model axis
    (nemotron: 96 -> (8,12) on a 16-wide axis replicated all heads).  GQA
    expands K/V to H heads with a cheap repeat instead (the repeat's
    backward reduces grads back to the KV heads automatically)."""
    scale = 1.0 / float(q.shape[-1]) ** 0.5
    s = jnp.einsum("bqhd,bthd->bhqt", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]
    if k_valid is not None:
        mask = mask & k_valid[None, :]
    s = jnp.where(mask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqt,bthd->bqhd", p.astype(v.dtype), v)


def _blockwise_attend(q, k, v, q_pos, k_pos, causal: bool, q_chunk=512, kv_chunk=1024):
    """Online-softmax double-chunked attention (flash-style, flat heads)."""
    B, Sq, H, hd = q.shape
    Skv, hdv = k.shape[1], v.shape[-1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0, (Sq, q_chunk, Skv, kv_chunk)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / float(hd) ** 0.5

    qs = jnp.moveaxis(q.reshape(B, nq, q_chunk, H, hd), 1, 0)
    qp = q_pos.reshape(nq, q_chunk)
    ks = jnp.moveaxis(k.reshape(B, nk, kv_chunk, H, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, kv_chunk, H, hdv), 1, 0)
    kp = k_pos.reshape(nk, kv_chunk)

    def q_body(_, qc_in):
        qc, qpc = qc_in  # [B,Cq,H,hd], [Cq]

        def kv_body(carry, kc_in):
            m, l, acc = carry
            kc, vc, kpc = kc_in
            s = jnp.einsum(
                "bqhd,bthd->bhqt", qc, kc, preferred_element_type=jnp.float32
            ) * scale
            if causal:
                s = jnp.where(qpc[:, None] >= kpc[None, :], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqt,bthd->bhqd", p.astype(vc.dtype), vc)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, hdv), v.dtype)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (ks, vs, kp))
        out = acc / jnp.maximum(l, 1e-20)[..., None].astype(acc.dtype)
        return None, jnp.einsum("bhqd->bqhd", out)

    _, outs = jax.lax.scan(q_body, None, (qs, qp))
    # outs: [nq, B, Cq, H, hdv] -> [B, Sq, H, hdv]
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hdv)


def _attend(q, k, v, q_pos, k_pos, causal, k_valid=None):
    Sq, Skv = q.shape[1], k.shape[1]
    if Sq * Skv <= _DENSE_LIMIT or Sq == 1:
        return _dense_attend(q, k, v, q_pos, k_pos, causal, k_valid)
    return _blockwise_attend(q, k, v, q_pos, k_pos, causal)


def _expand_kv(k: jax.Array, groups: int) -> jax.Array:
    """[B,S,KH,hd] -> [B,S,KH*G,hd] (GQA expansion, flat heads)."""
    if groups == 1:
        return k
    B, S, KH, hd = k.shape
    return jnp.repeat(k, groups, axis=2)


# ---------------------------------------------------------------------------
# GQA / MLA apply
# ---------------------------------------------------------------------------


def _gqa(p, x, cfg: ModelConfig, pos0, cache, kv_x, causal, is_cross=False):
    B, S, _ = x.shape
    KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    H = p["wq"].shape[1]  # padded head count (from the weights)
    G = H // KH
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_pos = pos0 + jnp.arange(S)
    is_cross = is_cross or kv_x is not None

    if is_cross and cache is not None and S == 1:
        # cross-attention decode: cache holds the encoder K/V, read-only
        k, v, k_valid = cache["k"], cache["v"], None
        k_pos = jnp.arange(k.shape[1])
        new_cache = cache
    elif is_cross and cache is not None:
        # cross-attention prefill: compute encoder K/V once, store them
        k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
        pad = cache["k"].shape[1] - k.shape[1]
        new_cache = {
            "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["k"].dtype),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["v"].dtype),
        }
        k_pos = jnp.arange(k.shape[1])
        k_valid = None
    else:
        src = kv_x if is_cross else x
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
        if not is_cross:
            q = rope(q, q_pos, cfg.rope_theta)
            k = rope(k, q_pos, cfg.rope_theta)
        k_valid = None
        if cache is None:
            k_pos = q_pos
            new_cache = None
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos0, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos0, 0, 0)
            )
            new_cache = {"k": ck, "v": cv}
            if S == 1:  # decode: attend over the whole cache, mask invalid
                k, v = ck, cv
                k_pos = jnp.arange(k.shape[1])
                k_valid = k_pos <= pos0
            else:  # prefill: attend over the fresh keys only
                k_pos = q_pos

    kf = _expand_kv(k.astype(q.dtype), G)
    vf = _expand_kv(v.astype(q.dtype), G)
    out = _attend(q, kf, vf, q_pos, k_pos, causal and not is_cross, k_valid)
    y = jnp.einsum("bqhd,hdo->bqo", out, p["wo"])
    return y, new_cache


def _mla(p, x, cfg: ModelConfig, pos0, cache, causal):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H = p["wq"].shape[1]
    r, rd = cfg.mla_kv_lora, cfg.mla_rope_dim
    q_pos = pos0 + jnp.arange(S)

    qfull = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_pe = qfull[..., :hd], rope(qfull[..., hd:], q_pos, cfg.rope_theta, head_axes=1)
    ckv_full = x @ p["wkv_a"]
    c_kv, k_pe = ckv_full[..., :r], rope(ckv_full[..., r:], q_pos, cfg.rope_theta, head_axes=0)

    if cache is not None:
        n_ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, pos0, 0)
        )
        n_kpe = jax.lax.dynamic_update_slice(
            cache["kpe"], k_pe.astype(cache["kpe"].dtype), (0, pos0, 0)
        )
        new_cache = {"ckv": n_ckv, "kpe": n_kpe}
    else:
        new_cache = None

    if cache is not None and S == 1:
        # absorbed decode: stay in the latent space, cache is 576 B/token
        ckv_t, kpe_t = new_cache["ckv"], new_cache["kpe"]
        Skv = ckv_t.shape[1]
        scale = 1.0 / float(hd + rd) ** 0.5
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, p["wk_b"])
        s = (
            jnp.einsum("bqhr,btr->bhqt", q_lat, ckv_t, preferred_element_type=jnp.float32)
            + jnp.einsum("bqhp,btp->bhqt", q_pe, kpe_t, preferred_element_type=jnp.float32)
        ) * scale
        valid = jnp.arange(Skv) <= pos0
        s = jnp.where(valid[None, None, None, :], s, _NEG)
        attn = jax.nn.softmax(s, axis=-1)
        lat = jnp.einsum("bhqt,btr->bqhr", attn.astype(ckv_t.dtype), ckv_t)
        heads = jnp.einsum("bqhr,rhd->bqhd", lat, p["wv_b"])
    else:
        # train/prefill: expand per-head keys/values from the latent
        k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, p["wk_b"])
        vv = jnp.einsum("bsr,rhd->bshd", c_kv, p["wv_b"])
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, rd))], -1)
        q = jnp.concatenate([q_nope, q_pe], -1)  # [B,S,H,hd+rd]
        heads = _attend(q, k.astype(q.dtype), vv.astype(q.dtype), q_pos, q_pos, causal)
    y = jnp.einsum("bqhd,hdo->bqo", heads, p["wo"])
    return y, new_cache


def attention_apply(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    pos0: jax.Array | int = 0,
    cache: Optional[Dict] = None,
    kv_x: Optional[jax.Array] = None,
    causal: bool = True,
    cross: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Self- or cross-attention with optional decode cache.

    ``cross=True`` (or a ``kv_x``) switches to cross-attention: K/V come
    from the encoder output at prefill and from the read-only cache at
    decode (when ``kv_x`` is no longer available).
    """
    if cfg.mla_kv_lora and not cross and kv_x is None:
        return _mla(p, x, cfg, pos0, cache, causal)
    return _gqa(p, x, cfg, pos0, cache, kv_x, causal, is_cross=cross)
