"""Shared building blocks: norms, rotary embeddings, MLPs, embeddings.

All layers are pure functions ``apply(params, x, cfg, ...)`` over ParamDef
trees — no module framework, so the same code paths serve real arrays and
``ShapeDtypeStruct`` tracing in the AOT dry-run.  Math in bf16 params /
f32 accumulation throughout.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .params import ParamDef, shard

__all__ = [
    "grad_dtype_guard",
    "rmsnorm",
    "nonparam_layernorm",
    "norm_defs",
    "apply_norm",
    "rope",
    "mlp_defs",
    "mlp_apply",
    "embed_defs",
    "embed_apply",
    "logits_apply",
]


@jax.custom_vjp
def grad_dtype_guard(x: jax.Array) -> jax.Array:
    """Identity forward; casts the COTANGENT back to x's dtype in backward.

    Attention/score einsums use ``preferred_element_type=f32``; their
    transpose rules emit f32 cotangents, which then propagate f32 through
    the whole backward residual stream (2x activation-grad memory and wire
    bytes — measured as f32 copies of every remat boundary on nemotron).
    Clamping the residual-stream cotangent at each block boundary keeps
    the backward in bf16 while the softmax math stays f32."""
    return x


def _guard_fwd(x):
    return x, jnp.zeros((0,), x.dtype)  # dtype carrier (residuals must be jax types)


def _guard_bwd(res, g):
    return (g.astype(res.dtype),)


grad_dtype_guard.defvjp(_guard_fwd, _guard_bwd)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * rms) * w.astype(jnp.float32)).astype(x.dtype)


def nonparam_layernorm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo's non-parametric LayerNorm: no learnable scale or bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def norm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    if cfg.norm == "nonparam_ln":
        return {}
    return {"w": ParamDef((cfg.d_model,), ("embed",), init="ones")}


def apply_norm(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.norm == "nonparam_ln":
        return nonparam_layernorm(x)
    return rmsnorm(x, p["w"])


def rope(x: jax.Array, positions: jax.Array, theta: float, head_axes: int = 1) -> jax.Array:
    """Rotary embedding over the last dim.

    ``positions`` ([S] or [B, S]) aligns with x's sequence dim;
    ``head_axes`` is the number of head dims between sequence and head_dim
    (1 for [B,S,H,hd], 0 for the headless MLA rope key [B,S,rd])."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # pos.shape + [half]
    ang = ang.reshape(ang.shape[:-1] + (1,) * head_axes + (half,))
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------


def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "relu2":  # non-gated (Nemotron-4 squared ReLU)
        return {
            "w1": ParamDef((d, f), ("embed", "mlp")),
            "w2": ParamDef((f, d), ("mlp", "embed")),
        }
    return {
        "wg": ParamDef((d, f), ("embed", "mlp")),
        "w1": ParamDef((d, f), ("embed", "mlp")),
        "w2": ParamDef((f, d), ("mlp", "embed")),
    }


def _activate(h: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(h)
    if act == "gelu":
        return jax.nn.gelu(h)
    if act == "relu2":
        r = jnp.maximum(h, 0.0)
        return r * r
    raise ValueError(f"unknown activation {act!r}")


def mlp_apply(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.act == "relu2":
        return _activate(x @ p["w1"], "relu2") @ p["w2"]
    return (_activate(x @ p["wg"], cfg.act) * (x @ p["w1"])) @ p["w2"]


# ---------------------------------------------------------------------------
# Embeddings / logits
# ---------------------------------------------------------------------------


def embed_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    v = cfg.padded_vocab
    defs = {"tok": ParamDef((v, cfg.d_model), ("vocab", "embed"), scale=0.02)}
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((cfg.d_model, v), ("embed", "vocab"), scale=0.02)
    return defs


def embed_apply(p: Dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    if tokens.shape[-1] == 1:
        # decode: one token, no gradient — a gather is optimal
        x = jnp.take(p["tok"], tokens, axis=0)
    else:
        # train/prefill: one-hot contraction instead of gather.  The gather
        # backward is a scatter-add into the full [vocab, d] table, which
        # GSPMD materialises REPLICATED (17.6 GiB/device f32 on nemotron);
        # the einsum wgrad is an ordinary sharded matmul.  The extra fwd
        # FLOPs are ~3% of one MLP layer.
        onehot = jax.nn.one_hot(tokens, cfg.padded_vocab, dtype=p["tok"].dtype)
        x = jnp.einsum("bsv,vd->bsd", onehot, p["tok"])
    return shard(x, "batch", "seq", "act_embed")


def logits_apply(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = (x @ w).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        # mask pad columns: no effect on CE's logsumexp, never sampled
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(iota < cfg.vocab, logits, -1e30)
    return shard(logits, "batch", "seq", "vocab")
