"""Layer assembly: blocks, scanned stacks, caches.

Layers are grouped into the smallest repeating pattern
(``cfg.layer_period``: 1 for uniform stacks, 8 for Jamba's 1:7
mamba/attention interleave) and the stack is a ``lax.scan`` over groups
with stacked parameters — HLO size and compile time are independent of
depth, which is what makes the 96-layer Nemotron dry-run compile in
seconds.  ``moe_first_dense`` layers (DeepSeek-V2) are unrolled as a
prologue before the scanned stack.

Decode caches mirror the stack structure: per-layer cache dicts, stacked
along a leading group dimension for the scanned part, so the same scan
carries (params, cache) pairs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .attention import ATTN_CACHE_LOGICAL, attention_apply, attention_defs, init_attn_cache
from .layers import apply_norm, grad_dtype_guard, mlp_apply, mlp_defs, norm_defs
from .moe import moe_apply, moe_defs
from .params import constrain_defs, shard, stack_defs
from .ssm import MAMBA_CACHE_LOGICAL, init_mamba_cache, mamba_apply, mamba_defs

__all__ = [
    "LogicalAxes",
    "block_defs",
    "block_apply",
    "stack_defs_for",
    "stack_apply",
    "init_stack_cache",
    "stack_cache_logical",
]


@dataclasses.dataclass(frozen=True)
class LogicalAxes:
    """Leaf marker carrying logical axis names for non-param arrays
    (decode caches); deliberately NOT a pytree so tree_map treats it
    as a leaf."""

    axes: Tuple[Optional[str], ...]


def block_defs(cfg: ModelConfig, kind: Tuple[str, str], *, cross: bool = False) -> Dict:
    mixer, ffn = kind
    defs: Dict[str, Any] = {"norm1": norm_defs(cfg)}
    if mixer == "attn":
        defs["attn"] = attention_defs(cfg)
    else:
        defs["mamba"] = mamba_defs(cfg)
    if cross:
        defs["norm_cross"] = norm_defs(cfg)
        defs["cross"] = attention_defs(cfg, cross=True)
    if ffn == "dense":
        defs["norm2"] = norm_defs(cfg)
        ff = cfg.first_dense_ff if (ffn == "dense" and cfg.moe_experts and cfg.first_dense_ff) else None
        defs["ffn"] = mlp_defs(cfg, d_ff=ff)
    elif ffn == "moe":
        defs["norm2"] = norm_defs(cfg)
        defs["moe"] = moe_defs(cfg)
    return defs


def block_apply(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    kind: Tuple[str, str],
    *,
    pos0: jax.Array | int = 0,
    cache: Optional[Dict] = None,
    enc_out: Optional[jax.Array] = None,
    causal: bool = True,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Pre-norm residual block.  Returns (x, new_cache, aux_loss)."""
    mixer, ffn = kind
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}

    h = apply_norm(p["norm1"], x, cfg)
    if mixer == "attn":
        mx, c = attention_apply(
            p["attn"], h, cfg, pos0=pos0,
            cache=None if cache is None else cache.get("attn"), causal=causal,
        )
        if c is not None:
            new_cache["attn"] = c
    else:
        mx, c = mamba_apply(
            p["mamba"], h, cfg, cache=None if cache is None else cache.get("mamba")
        )
        if c is not None:
            new_cache["mamba"] = c
    x = x + mx

    if enc_out is not None or (cache is not None and "cross" in cache):
        h = apply_norm(p["norm_cross"], x, cfg)
        cx, c = attention_apply(
            p["cross"], h, cfg, pos0=pos0, kv_x=enc_out, cross=True,
            cache=None if cache is None else cache.get("cross"), causal=False,
        )
        if c is not None:
            new_cache["cross"] = c
        x = x + cx

    if ffn != "none":
        h = apply_norm(p["norm2"], x, cfg)
        if ffn == "dense":
            f = mlp_apply(p["ffn"], h, cfg)
        else:
            f, aux = moe_apply(p["moe"], h, cfg)
        x = x + f
    x = grad_dtype_guard(shard(x, "batch", "seq", "act_embed"))
    return x, (new_cache if cache is not None else None), aux


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------


def _sqrt_factor(n: int) -> int:
    """Largest divisor of n not exceeding sqrt(n) (two-level remat split)."""
    best = 1
    d = 1
    while d * d <= n:
        if n % d == 0:
            best = d
        d += 1
    return best


def _pattern(cfg: ModelConfig, n_layers: int, offset: int = 0):
    """(prologue kinds, period kinds, n_groups) for a decoder stack."""
    prologue = cfg.moe_first_dense if cfg.moe_experts else 0
    period = cfg.layer_period
    body = n_layers - prologue
    assert body % period == 0, (n_layers, prologue, period)
    prologue_kinds = [cfg.layer_kind(l) for l in range(prologue)]
    period_kinds = [cfg.layer_kind(prologue + j) for j in range(period)]
    return prologue_kinds, period_kinds, body // period


def stack_defs_for(cfg: ModelConfig, *, n_layers: int, cross: bool = False) -> Dict:
    prologue_kinds, period_kinds, n_groups = _pattern(cfg, n_layers)
    defs: Dict[str, Any] = {}
    for i, kind in enumerate(prologue_kinds):
        defs[f"pro{i}"] = block_defs(cfg, kind, cross=cross)
    group = {f"l{j}": block_defs(cfg, kind, cross=cross) for j, kind in enumerate(period_kinds)}
    if cfg.scan_layers:
        defs["stack"] = stack_defs(group, n_groups)
    else:
        for g in range(n_groups):
            defs[f"g{g}"] = group  # shared structure, distinct leaves on init
    return defs


def stack_apply(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    n_layers: int,
    pos0: jax.Array | int = 0,
    cache: Optional[Dict] = None,
    enc_out: Optional[jax.Array] = None,
    causal: bool = True,
    remat: bool = False,
    cross: bool = False,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    prologue_kinds, period_kinds, n_groups = _pattern(cfg, n_layers)
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}

    for i, kind in enumerate(prologue_kinds):
        x, c, aux = block_apply(
            params[f"pro{i}"], x, cfg, kind, pos0=pos0,
            cache=None if cache is None else cache[f"pro{i}"],
            enc_out=enc_out, causal=causal,
        )
        if c is not None:
            new_cache[f"pro{i}"] = c
        aux_total = aux_total + aux

    def group_apply(gp, x, gcache):
        gaux = jnp.zeros((), jnp.float32)
        newc: Dict[str, Any] = {}
        for j, kind in enumerate(period_kinds):
            x, c, aux = block_apply(
                gp[f"l{j}"], x, cfg, kind, pos0=pos0,
                cache=None if gcache is None else gcache[f"l{j}"],
                enc_out=enc_out, causal=causal,
            )
            if c is not None:
                newc[f"l{j}"] = c
            gaux = gaux + aux
        return x, (newc if gcache is not None else None), gaux

    if cfg.scan_layers:
        group_defs = {
            f"l{j}": block_defs(cfg, kind, cross=cross)
            for j, kind in enumerate(period_kinds)
        }

        def body(carry, xs):
            x = carry
            gp = xs[0] if cache is not None else xs
            gcache = xs[1] if cache is not None else None
            gp = constrain_defs(gp, group_defs)
            x, newc, gaux = group_apply(gp, x, gcache)
            return x, (newc, gaux) if cache is not None else (None, gaux)

        if remat:
            body = jax.checkpoint(body, prevent_cse=True)
        xs = (params["stack"], cache["stack"]) if cache is not None else params["stack"]
        n_inner = _sqrt_factor(n_groups) if (remat and cache is None) else 1
        if n_inner > 1:
            # two-level (sqrt) remat: the outer scan stores only
            # n_groups/n_inner boundary activations; the inner scan's
            # residuals are recomputed in the backward pass.  This is what
            # bounds stored activations for 96-layer/18k-wide stacks
            # (14.5 GB -> ~2 GB per device on nemotron-4-340b).
            n_outer = n_groups // n_inner
            xs2 = jax.tree.map(
                lambda a: a.reshape((n_outer, n_inner) + a.shape[1:]), xs
            )

            def outer_body(carry, outer_xs):
                y, (_, gaux) = jax.lax.scan(body, carry, outer_xs)
                return y, gaux

            outer_body = jax.checkpoint(outer_body, prevent_cse=True)
            x, gauxs = jax.lax.scan(outer_body, x, xs2)
            aux_total = aux_total + gauxs.sum()
        else:
            x, (stack_cache, gauxs) = jax.lax.scan(body, x, xs)
            if cache is not None:
                new_cache["stack"] = stack_cache
            aux_total = aux_total + gauxs.sum()
    else:
        for g in range(n_groups):
            x, newc, gaux = group_apply(
                params[f"g{g}"], x, None if cache is None else cache[f"g{g}"]
            )
            if newc is not None:
                new_cache[f"g{g}"] = newc
            aux_total = aux_total + gaux
    return x, (new_cache if cache is not None else None), aux_total


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def _block_cache(cfg, kind, batch, max_len, *, cross_len: int = 0):
    mixer, _ = kind
    c: Dict[str, Any] = {}
    if mixer == "attn":
        c["attn"] = init_attn_cache(cfg, batch, max_len)
    else:
        c["mamba"] = init_mamba_cache(cfg, batch)
    if cross_len:
        hd = cfg.resolved_head_dim
        cdt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        c["cross"] = {
            "k": jnp.zeros((batch, cross_len, cfg.n_kv_heads, hd), cdt),
            "v": jnp.zeros((batch, cross_len, cfg.n_kv_heads, hd), cdt),
        }
    return c


def _block_cache_logical(cfg, kind, *, cross: bool = False):
    mixer, _ = kind
    c: Dict[str, Any] = {}
    if mixer == "attn":
        keys = ("ckv", "kpe") if cfg.mla_kv_lora else ("k", "v")
        c["attn"] = {k: LogicalAxes(ATTN_CACHE_LOGICAL[k]) for k in keys}
    else:
        c["mamba"] = {k: LogicalAxes(MAMBA_CACHE_LOGICAL[k]) for k in ("conv", "state")}
    if cross:
        c["cross"] = {k: LogicalAxes(ATTN_CACHE_LOGICAL[k]) for k in ("k", "v")}
    return c


def init_stack_cache(cfg: ModelConfig, *, n_layers: int, batch: int, max_len: int, cross_len: int = 0):
    """Zeroed decode cache for a stack (use under jax.eval_shape for AOT)."""
    prologue_kinds, period_kinds, n_groups = _pattern(cfg, n_layers)
    cache: Dict[str, Any] = {}
    for i, kind in enumerate(prologue_kinds):
        cache[f"pro{i}"] = _block_cache(cfg, kind, batch, max_len, cross_len=cross_len)
    group = {
        f"l{j}": _block_cache(cfg, kind, batch, max_len, cross_len=cross_len)
        for j, kind in enumerate(period_kinds)
    }
    if cfg.scan_layers:
        cache["stack"] = jax.tree.map(
            lambda z: jnp.broadcast_to(z[None], (n_groups,) + z.shape), group
        )
    else:
        for g in range(n_groups):
            cache[f"g{g}"] = jax.tree.map(lambda z: z, group)
    return cache


def stack_cache_logical(cfg: ModelConfig, *, n_layers: int, cross: bool = False):
    """Same structure as init_stack_cache, LogicalAxes leaves (for specs)."""
    prologue_kinds, period_kinds, n_groups = _pattern(cfg, n_layers)
    is_leaf = lambda v: isinstance(v, LogicalAxes)
    tree: Dict[str, Any] = {}
    for i, kind in enumerate(prologue_kinds):
        tree[f"pro{i}"] = _block_cache_logical(cfg, kind, cross=cross)
    group = {
        f"l{j}": _block_cache_logical(cfg, kind, cross=cross)
        for j, kind in enumerate(period_kinds)
    }
    if cfg.scan_layers:
        tree["stack"] = jax.tree.map(
            lambda l: LogicalAxes(("layers",) + l.axes), group, is_leaf=is_leaf
        )
    else:
        for g in range(n_groups):
            tree[f"g{g}"] = group
    return tree
