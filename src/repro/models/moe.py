"""Mixture-of-Experts FFN with sort-based, scatter-free capacity dispatch.

Dispatch is a *permutation*, not an einsum: naive GShard one-hot dispatch
tensors ``[tokens, E, C]`` cost ``2·T·E·C·D`` garbage FLOPs — at the
train_4k cell (1M tokens) ~3000× the useful expert FLOPs.  And it is
*scatter-free*: every data movement is a batch-positional
``take_along_axis`` gather.  Scatters with explicit batch-index arrays
(``x.at[bi, idx].add``) make GSPMD replicate the operand (measured
100+ GiB/device on the deepseek cells); gathers along axis 1 keep the
batch dim sharded.

Pipeline per sequence (batch dim untouched end to end):

1. route: top-k experts per token, f32 router, Switch aux loss;
2. sort (token, slot) pairs by expert id (stable per-sequence argsort);
3. *gather* expert buffers: slot (e, c) of the ``[B, E, C, D]`` buffer
   reads sorted position ``starts[e] + c`` (beyond-count slots read the
   zero pad row) — the inverse of the scatter a GPU implementation does;
4. expert einsum with weights sharded over "model" (EP) — GSPMD
   materialises the token movement as the canonical MoE all-to-all;
5. combine: the inverse gathers, then fold the K slots per token.

Every index map is injective (pad-extended), so each step runs through the
``_permute`` custom-vjp whose BACKWARD is also a gather — jax's default
gather transpose is a scatter-add, which GSPMD replicates across the mesh
(the §Perf log quantifies the win on the granite/deepseek train cells).

Capacity is per sequence: ``C = ceil(S·K/E · capacity_factor)``; overflow
tokens pass through on the residual only.  Decode (S=1) routes exactly.
DeepSeek-style shared experts are dense FFNs added to the routed output.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import _activate, mlp_apply, mlp_defs
from .params import ParamDef, shard

__all__ = ["moe_defs", "moe_apply"]


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    gated = cfg.act != "relu2"
    defs: Dict[str, ParamDef] = {
        "router": ParamDef((d, e), ("embed", None), scale=0.02),
        "w1": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "w2": ParamDef((e, f, d), ("experts", "mlp", "embed")),
    }
    if gated:
        defs["wg"] = ParamDef((e, d, f), ("experts", "embed", "mlp"))
    for s in range(cfg.moe_shared):
        defs[f"shared_{s}"] = mlp_defs(cfg)
    return defs


def _take1(x: jax.Array, idx: jax.Array) -> jax.Array:
    """Batch-positional gather along axis 1: x [B,N,D], idx [B,M] -> [B,M,D]."""
    return jnp.take_along_axis(x, idx[..., None], axis=1)


@jax.custom_vjp
def _permute(x: jax.Array, fwd_idx: jax.Array, bwd_idx: jax.Array, m: int) -> jax.Array:
    """Injective padded permutation: out[b, i] = x[b, fwd_idx[i]] (index
    N = x.shape[1] reads the zero pad row).  ``bwd_idx`` must be the
    inverse mapping (index M = out length = pad).  Because the mapping is injective on valid entries, the VJP is
    itself a gather — jax's default transpose of a gather is a scatter-add,
    which GSPMD replicates across the mesh (measured 25-50 GiB/device on
    the MoE train cells); this keeps the backward scatter-free."""
    B, N, D = x.shape
    padded = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    return _take1(padded, fwd_idx)


def _permute_fwd(x, fwd_idx, bwd_idx, m):
    return _permute(x, fwd_idx, bwd_idx, m), (fwd_idx, bwd_idx, x.shape[1])


def _permute_bwd(res, g):
    fwd_idx, bwd_idx, n = res
    B, M, D = g.shape
    padded = jnp.concatenate([g, jnp.zeros((B, 1, D), g.dtype)], axis=1)
    dx = _take1(padded, bwd_idx)
    return (dx, None, None, None)


_permute.defvjp(_permute_fwd, _permute_bwd)


def moe_apply(
    p: Dict, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,D], aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    T = S * K  # routing slots per sequence

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)  # [B,S,K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing loss (fraction routed vs mean prob)
    me = probs.mean(axis=(0, 1))  # [E]
    ce = jnp.sum(
        jax.nn.one_hot(idx.reshape(B, T), E, dtype=jnp.float32), axis=(0, 1)
    ) / (B * T)
    aux = E * jnp.sum(me * ce)

    if S == 1:
        capacity = 1  # decode: exact routing (top-k experts are distinct)
    else:
        capacity = min(S, max(4, int(S * K / E * cfg.capacity_factor)))
    C = capacity

    # ---- sort slots by expert (per sequence; batch dim stays positional)
    e_flat = idx.reshape(B, T)
    order = jnp.argsort(e_flat, axis=-1, stable=True)  # [B, T]
    inv_order = jnp.argsort(order, axis=-1, stable=True)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=-1)
    counts = jnp.sum(jax.nn.one_hot(e_flat, E, dtype=jnp.int32), axis=1)  # [B,E]
    starts = jnp.cumsum(counts, axis=-1) - counts  # exclusive per-expert start
    rank = jnp.arange(T)[None, :] - jnp.take_along_axis(starts, e_sorted, axis=-1)
    keep = rank < C  # beyond-capacity slots are dropped

    # ---- dispatch: all index maps below are injective (pad-extended), so
    # both directions run through the scatter-free _permute gathers.
    # token -> slot expansion (K slots per token; backward = reshape-sum)
    x_slots = jnp.repeat(x, K, axis=1)  # [B, T, D]
    # sorted-slot <- slot (bijection: order / inv_order)
    xs = _permute(x_slots, order, inv_order, T)  # [B, T, D]
    # expert buffer slot (e, c) <- sorted slot (injective; invalid -> pad)
    src = starts[:, :, None] + jnp.arange(C)[None, None, :]  # [B, E, C]
    valid = jnp.arange(C)[None, None, :] < counts[:, :, None]
    src = jnp.where(valid, src, T).reshape(B, E * C)
    slot_dest = jnp.where(keep, e_sorted * C + rank, E * C)  # inverse map
    expert_in = _permute(xs, src, slot_dest, E * C).reshape(B, E, C, D)
    expert_in = shard(expert_in, "batch", "act_experts", None, None)

    gated = cfg.act != "relu2"
    if gated:
        h = _activate(
            jnp.einsum("becd,edf->becf", expert_in, p["wg"]), cfg.act
        ) * jnp.einsum("becd,edf->becf", expert_in, p["w1"])
    else:
        h = _activate(jnp.einsum("becd,edf->becf", expert_in, p["w1"]), cfg.act)
    eout = jnp.einsum("becf,efd->becd", h, p["w2"]).reshape(B, E * C, D)

    # ---- combine: sorted slot <- expert buffer slot (inverse of dispatch)
    contrib = _permute(eout, slot_dest, src, T)  # [B, T, D]; dropped -> 0
    gate_sorted = jnp.take_along_axis(gates.reshape(B, T), order, axis=-1)
    contrib = contrib * gate_sorted[..., None].astype(contrib.dtype)
    # slot <- sorted slot (bijection), then fold the K slots per token
    contrib = _permute(contrib, inv_order, order, T)
    out = contrib.reshape(B, S, K, D).sum(axis=2)

    for s in range(cfg.moe_shared):
        out = out + mlp_apply(p[f"shared_{s}"], x, cfg)
    return out.astype(x.dtype), aux
