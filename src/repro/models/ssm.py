"""Mamba-2 blocks via the SSD (state-space duality) chunked algorithm.

Training/prefill uses the chunked-quadratic SSD form: within chunks of
``cfg.ssm_chunk`` tokens the recurrence is computed as a masked-decay
matmul (MXU-friendly); across chunks a ``lax.scan`` carries the
``[heads, state, head_dim]`` recurrent state.  Decode is the O(1)
recurrent step — the reason SSM/hybrid archs run the ``long_500k`` shape.

Layer structure follows Mamba-2: fused input projection into
(x, z, B, C, dt), a short causal depthwise conv over [x;B;C], SSD, gated
RMSNorm, output projection.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import rmsnorm
from .params import ParamDef

__all__ = ["mamba_defs", "mamba_apply", "init_mamba_cache", "MAMBA_CACHE_LOGICAL"]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    hp = cfg.ssm_head_dim
    nh = di // hp
    return d, di, n, hp, nh


def mamba_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, di, n, hp, nh = _dims(cfg)
    ch = di + 2 * n  # conv runs over [x; B; C]
    return {
        "wx": ParamDef((d, di), ("embed", "ssm_inner")),
        "wz": ParamDef((d, di), ("embed", "ssm_inner")),
        "wB": ParamDef((d, n), ("embed", None)),
        "wC": ParamDef((d, n), ("embed", None)),
        "wdt": ParamDef((d, nh), ("embed", "ssm_heads")),
        "dt_bias": ParamDef((nh,), ("ssm_heads",), init="const:-4.6"),  # softplus^-1(0.01)
        "A_log": ParamDef((nh,), ("ssm_heads",), init="a_log"),
        "D": ParamDef((nh,), ("ssm_heads",), init="ones"),
        "conv_w": ParamDef((cfg.ssm_conv, ch), (None, "ssm_conv_ch"), scale=0.5),
        "conv_b": ParamDef((ch,), ("ssm_conv_ch",), init="zeros"),
        "norm_w": ParamDef((di,), ("ssm_inner",), init="ones"),
        "wout": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int):
    d, di, n, hp, nh = _dims(cfg)
    ch = di + 2 * n
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, ch), dt),
        "state": jnp.zeros((batch, nh, n, hp), jnp.float32),
    }


MAMBA_CACHE_LOGICAL = {
    "conv": ("cache_batch", None, "ssm_conv_ch"),
    "state": ("cache_batch", "ssm_heads", None, None),
}


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array, history: Optional[jax.Array]):
    """Depthwise causal conv, kernel K small (4): sum of shifted slices.

    ``history`` is the last K-1 inputs from a previous segment (decode/
    prefill continuation) or None (zero history)."""
    B, S, CH = xBC.shape
    K = w.shape[0]
    if history is None:
        history = jnp.zeros((B, K - 1, CH), xBC.dtype)
    padded = jnp.concatenate([history.astype(xBC.dtype), xBC], axis=1)
    out = sum(
        padded[:, k : k + S, :].astype(jnp.float32) * w[k].astype(jnp.float32)
        for k in range(K)
    ) + b.astype(jnp.float32)
    new_history = padded[:, -(K - 1) :, :] if K > 1 else history
    return jax.nn.silu(out).astype(xBC.dtype), new_history


def ssd_chunked(
    x: jax.Array,  # [B, S, nh, hp]
    dt: jax.Array,  # [B, S, nh]  (post-softplus, > 0)
    A: jax.Array,  # [nh]  (< 0)
    Bm: jax.Array,  # [B, S, n]
    Cm: jax.Array,  # [B, S, n]
    chunk: int,
    init_state: Optional[jax.Array] = None,  # [B, nh, n, hp]
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y [B,S,nh,hp], final_state)."""
    B, S, nh, hp = x.shape
    n = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    xc = x.reshape(B, nc, Q, nh, hp).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, nh).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, n).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, n).astype(jnp.float32)

    a = dtc * A  # [B,nc,Q,nh], negative log-decay increments
    a_cs = jnp.cumsum(a, axis=2)

    # --- intra-chunk (quadratic within Q, MXU matmuls)
    diff = a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]  # [B,nc,Q,Q,nh]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    G = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    M = G[..., None] * L
    y_diag = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", M, dtc, xc)

    # --- chunk boundary states
    a_sum = a_cs[:, :, -1, :]  # [B,nc,nh]
    decay_out = jnp.exp(a_sum[:, :, None, :] - a_cs)  # [B,nc,Q,nh]
    S_c = jnp.einsum("bckn,bckh,bckhp->bchnp", Bc, decay_out * dtc, xc)

    # --- inter-chunk recurrence
    S0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((B, nh, n, hp), jnp.float32)
    )

    def step(S_prev, inp):
        S_cur, decay = inp  # [B,nh,n,hp], [B,nh]
        S_new = S_prev * jnp.exp(decay)[:, :, None, None] + S_cur
        return S_new, S_prev

    xs = (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(a_sum, 1, 0))
    S_last, S_prevs = jax.lax.scan(step, S0, xs)
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)  # [B,nc,nh,n,hp]

    y_off = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", Cc, S_prevs, jnp.exp(a_cs))
    y = (y_diag + y_off).reshape(B, Sp, nh, hp)[:, :S]
    return y, S_last


def mamba_apply(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Mamba-2 block.  [B,S,D] -> [B,S,D]; decode when S == 1 and cache."""
    B, S, _ = x.shape
    d, di, n, hp, nh = _dims(cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [nh]

    xi = x @ p["wx"]
    z = x @ p["wz"]
    Bm = x @ p["wB"]
    Cm = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    xBC = jnp.concatenate([xi, Bm.astype(xi.dtype), Cm.astype(xi.dtype)], axis=-1)

    history = cache["conv"] if cache is not None else None
    conv_out, new_history = _causal_conv(xBC, p["conv_w"], p["conv_b"], history)
    xc, Bc, Cc = conv_out[..., :di], conv_out[..., di : di + n], conv_out[..., di + n :]
    xh = xc.reshape(B, S, nh, hp)

    if cache is not None and S == 1:
        # O(1) recurrent decode step
        st = cache["state"]  # [B,nh,n,hp] f32
        dt1 = dt[:, 0]  # [B,nh]
        decay = jnp.exp(dt1 * A)  # [B,nh]
        upd = jnp.einsum(
            "bn,bh,bhp->bhnp",
            Bc[:, 0].astype(jnp.float32),
            dt1,
            xh[:, 0].astype(jnp.float32),
        )
        st = st * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(jnp.float32), st)
        y = y + p["D"].astype(jnp.float32)[:, None] * xh[:, 0].astype(jnp.float32)
        y = y[:, None]  # [B,1,nh,hp]
        new_cache = {"conv": new_history, "state": st}
    else:
        init_state = cache["state"] if cache is not None else None
        y, S_last = ssd_chunked(xh, dt, A, Bc, Cc, cfg.ssm_chunk, init_state)
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
        new_cache = {"conv": new_history, "state": S_last} if cache is not None else None

    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["wout"], new_cache
