"""Parameter definition trees with logical-axis sharding.

Every layer contributes a nested dict of :class:`ParamDef` leaves.  A
ParamDef names each array dimension with a *logical* axis ("embed",
"heads", "mlp", "experts", ...).  Sharding rules map logical axes to mesh
axes ("pod", "data", "model"); the mapping is divisibility-checked per
tensor, so an axis that does not divide (e.g. 56 query heads on a 16-wide
model axis, or 8 KV heads) silently falls back to replication instead of
producing an invalid PartitionSpec.  Rule sets are the primary §Perf knob:
swapping rules re-shards the whole model without touching layer code.

Three materialisations of a def tree:

* :func:`init_params`   — real arrays (smoke tests, examples, training);
* :func:`abstract_params` — ``ShapeDtypeStruct``s (AOT dry-run, no alloc);
* :func:`param_specs`   — ``PartitionSpec`` tree for in/out_shardings.
"""
from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ParamDef",
    "init_params",
    "abstract_params",
    "param_specs",
    "stack_defs",
    "Rules",
    "TRAIN_RULES",
    "TRAIN_RULES_SP",
    "DECODE_RULES",
    "sharding_ctx",
    "shard",
    "logical_spec",
]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones
    scale: Optional[float] = None  # stddev override for "normal"

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.logical):
            raise ValueError(f"shape {self.shape} vs logical {self.logical}")


def _is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _map_defs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=_is_def)


def stack_defs(tree, n: int, axis_name: str = "layers"):
    """Prepend a stacking dimension (for scan-over-layers parameter stacks)."""
    return _map_defs(
        lambda d: dataclasses.replace(
            d, shape=(n,) + d.shape, logical=(axis_name,) + d.logical
        ),
        tree,
    )


def init_params(tree, key: jax.Array, dtype=jnp.float32):
    """Materialise a def tree into arrays.  Deterministic: every leaf's key
    is folded from its path, independent of dict ordering."""
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_def)

    def make(path, d: ParamDef):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        if d.init == "a_log":  # Mamba A init: A = -exp(A_log) in [-16, -1]
            row = jnp.log(jnp.linspace(1.0, 16.0, d.shape[-1]))
            return jnp.broadcast_to(row, d.shape).astype(dtype)
        if d.init.startswith("const:"):
            return jnp.full(d.shape, float(d.init.split(":")[1]), dtype)
        # stddev: explicit scale, else 1/sqrt(fan_in) over the last-but-one dim
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale if d.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        seed = hash(jax.tree_util.keystr(path)) % (2**31 - 1)
        k = jax.random.fold_in(key, seed)
        return (jax.random.truncated_normal(k, -2.0, 2.0, d.shape, jnp.float32) * std).astype(dtype)

    leaves = [make(p, d) for p, d in leaves_with_paths]
    return jax.tree.unflatten(treedef, leaves)


def abstract_params(tree, dtype=jnp.bfloat16):
    return _map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), tree)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical-axis → mesh-axis mapping.  ``name`` keys EXPERIMENTS.md rows."""

    name: str
    table: Dict[str, Any]  # logical -> mesh axis (str | tuple | None)

    def get(self, logical: Optional[str]):
        if logical is None:
            return None
        return self.table.get(logical)


# Baseline training rules: TP over "model" (heads / mlp / experts / vocab),
# FSDP-style weight sharding over "data" on the embed dim, pure DP over
# "pod".  Gradient reduction over (pod, data) is induced by pjit.
TRAIN_RULES = Rules(
    "fsdp_tp",
    {
        "vocab": "model",
        "embed": ("pod", "data"),
        "heads": "model",
        "kv_heads": "model",  # divisibility-checked; kv=8 falls back to None
        "mlp": "model",
        "experts": "model",  # expert parallelism
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_conv_ch": "model",
        "batch": ("pod", "data"),
        "act_embed": None,
        "act_heads": "model",
        "act_mlp": "model",
        "act_experts": "model",
        "seq": None,  # flip to "model" for sequence parallelism (§Perf)
        "kv_embed": "model",
        "cache_batch": ("pod", "data"),
        "head_dim": None,
    },
)

# Sequence-parallel training rules: activations (and therefore the remat
# boundaries the layer scan stores) are additionally sharded over "model"
# on the sequence dim.  Used when d_model·layers makes the stored
# boundaries exceed the HBM budget (nemotron-4-340b).
TRAIN_RULES_SP = Rules(
    "fsdp_tp_sp",
    dict(TRAIN_RULES.table, seq="model"),
)

# Serving/decode rules: weights fully sharded over (data, model) — decode is
# weight- and cache-bandwidth-bound, so every byte is sharded; the KV cache
# shards batch over "data" and head_dim/latent over "model".
DECODE_RULES = Rules(
    "decode_fullshard",
    {
        "vocab": "model",
        "embed": "data",
        "heads": "model",
        "kv_heads": None,
        "mlp": "model",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_conv_ch": "model",
        "batch": ("pod", "data"),
        "act_embed": None,
        "act_heads": "model",
        "act_mlp": "model",
        "act_experts": "model",
        "seq": None,
        "kv_embed": "model",
        "cache_batch": ("pod", "data"),
        "head_dim": "model",
    },
)


def logical_spec(
    shape: Tuple[int, ...],
    logical: Tuple[Optional[str], ...],
    rules: Rules,
    mesh: Mesh,
) -> P:
    """Build a PartitionSpec, skipping axes that don't divide or repeat."""
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        axis = rules.get(name)
        if axis is None:
            out.append(None)
            continue
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        # keep the subset of axes that exist in this mesh and are unused
        # (e.g. ("pod", "data") degrades to ("data",) on the single-pod mesh)
        avail = tuple(a for a in axes if a in mesh.shape and a not in used)
        size = 1
        for a in avail:
            size *= mesh.shape[a]
        if not avail or dim % size != 0:
            out.append(None)
            continue
        used.update(avail)
        out.append(avail if len(avail) > 1 else avail[0])
    return P(*out)


def param_specs(tree, rules: Rules, mesh: Mesh):
    return _map_defs(lambda d: logical_spec(d.shape, d.logical, rules, mesh), tree)


# ---------------------------------------------------------------------------
# Activation sharding constraints
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextmanager
def sharding_ctx(mesh: Mesh, rules: Rules):
    """Activate activation sharding constraints inside model code."""
    prev = getattr(_CTX, "v", None)
    _CTX.v = (mesh, rules)
    try:
        yield
    finally:
        _CTX.v = prev


def get_sharding_ctx():
    return getattr(_CTX, "v", None)


def constrain_defs(tree, defs_tree):
    """Constrain arrays to the sharding their ParamDefs imply (no-op outside
    a sharding_ctx).  Placed INSIDE a scan body, the constraint's transpose
    pins the per-layer weight-gradient cotangents to the parameter layout —
    i.e. the wgrad reduce-scatter happens per layer inside the scan
    backward instead of accumulating a model-sharded-only stacked buffer
    (15.2 GiB vs 0.95 GiB on nemotron's MLP stack)."""
    ctx = get_sharding_ctx()
    if ctx is None:
        return tree
    mesh, rules = ctx

    def one(arr, d):
        spec = logical_spec(d.shape, d.logical, rules, mesh)
        return jax.lax.with_sharding_constraint(arr, NamedSharding(mesh, spec))

    return jax.tree.map(one, tree, defs_tree, is_leaf=lambda v: isinstance(v, ParamDef))


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the active rules; no-op outside a sharding_ctx
    (smoke tests, single-device examples)."""
    ctx = getattr(_CTX, "v", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_spec(x.shape, tuple(logical), rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
