"""Top-level model API: build, forward, caches, input specs.

``build_model(cfg)`` returns a :class:`Model` holding the ParamDef tree;
``Model.forward`` covers all four execution modes used by the launchers:

* train / eval        — full sequence, no cache;
* prefill             — full sequence, writes the decode cache;
* decode              — one token against the cache (``tokens [B, 1]``);
* encoder-decoder     — frames → encoder, tokens → decoder w/ cross-attn.

``input_specs`` produces ``ShapeDtypeStruct`` stand-ins for every model
input of an (arch × shape) cell — the dry-run lowers against these, so no
host allocation ever happens for the full-size configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

from .layers import embed_apply, embed_defs, logits_apply, apply_norm, norm_defs
from .params import (
    Rules,
    abstract_params,
    init_params,
    logical_spec,
    param_specs,
)
from .transformer import (
    LogicalAxes,
    init_stack_cache,
    stack_apply,
    stack_cache_logical,
    stack_defs_for,
)

__all__ = ["Model", "build_model", "input_specs"]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    defs: Dict[str, Any]

    # ------------------------------------------------------------------ build
    def init(self, key: jax.Array):
        return init_params(self.defs, key, dtype=_dtype(self.cfg))

    def abstract(self):
        return abstract_params(self.defs, dtype=_dtype(self.cfg))

    def specs(self, rules: Rules, mesh):
        return param_specs(self.defs, rules, mesh)

    # ------------------------------------------------------------------ cache
    def init_cache(self, batch: int, max_len: int, cross_len: int | None = None):
        """Decode cache.  ``cross_len`` must equal the exact encoder output
        length for enc-dec models (padded cross keys would otherwise leak
        into the softmax); defaults to ``max_len``."""
        cfg = self.cfg
        cross = (cross_len if cross_len is not None else max_len) if cfg.is_encdec else 0
        cache = {
            "dec": init_stack_cache(
                cfg, n_layers=cfg.n_layers, batch=batch, max_len=max_len,
                cross_len=cross,
            )
        }
        return cache

    def abstract_cache(self, batch: int, max_len: int, cross_len: int | None = None):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len, cross_len))

    def cache_specs(self, rules: Rules, mesh, batch: int, max_len: int):
        shapes = self.abstract_cache(batch, max_len)
        logical = {
            "dec": stack_cache_logical(
                self.cfg, n_layers=self.cfg.n_layers, cross=self.cfg.is_encdec
            )
        }
        is_leaf = lambda v: isinstance(v, LogicalAxes)
        return jax.tree.map(
            lambda s, l: logical_spec(s.shape, l.axes, rules, mesh),
            shapes,
            logical,
            is_leaf=lambda v: isinstance(v, (LogicalAxes, jax.ShapeDtypeStruct)),
        )

    # ---------------------------------------------------------------- forward
    def encode(self, params, frames: jax.Array, remat: bool = False) -> jax.Array:
        """Encoder stack over stub frame embeddings [B, S_enc, D]."""
        cfg = self.cfg
        x = frames.astype(_dtype(cfg))
        x, _, _ = stack_apply(
            params["enc"], x, cfg, n_layers=cfg.encoder_layers,
            causal=False, remat=remat,
        )
        return apply_norm(params["enc_norm"], x, cfg)

    def forward(
        self,
        params,
        batch: Dict[str, jax.Array],
        *,
        cache: Optional[Dict] = None,
        pos0: jax.Array | int = 0,
        remat: bool = False,
    ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
        """Returns (logits f32 [B,S,V], new_cache, aux_loss)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed_apply(params["embed"], tokens, cfg)

        if cfg.frontend == "vision" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(x.dtype)
            x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))

        enc_out = None
        if cfg.is_encdec:
            if "enc_out" in batch:
                enc_out = batch["enc_out"]
            elif "frames" in batch:
                enc_out = self.encode(params, batch["frames"], remat=remat)
            # decode steps read cross-K/V from the cache; enc_out may be None

        x, new_cache, aux = stack_apply(
            params["dec"], x, cfg, n_layers=cfg.n_layers, pos0=pos0,
            cache=None if cache is None else cache["dec"],
            enc_out=enc_out, causal=True, remat=remat, cross=cfg.is_encdec,
        )
        x = apply_norm(params["final_norm"], x, cfg)
        logits = logits_apply(params["embed"], x, cfg)
        return logits, ({"dec": new_cache} if cache is not None else None), aux


def build_model(cfg: ModelConfig) -> Model:
    defs: Dict[str, Any] = {
        "embed": embed_defs(cfg),
        "final_norm": norm_defs(cfg),
        "dec": stack_defs_for(cfg, n_layers=cfg.n_layers, cross=cfg.is_encdec),
    }
    if cfg.is_encdec:
        enc_cfg = dataclasses.replace(
            cfg, moe_experts=0, attn_every=0, ssm_state=0, family="dense"
        )
        defs["enc"] = stack_defs_for(enc_cfg, n_layers=cfg.encoder_layers)
        defs["enc_norm"] = norm_defs(cfg)
    return Model(cfg, defs)


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    * train   — tokens are both inputs and (shifted) labels;
    * prefill — the full prompt;
    * decode  — one new token (the KV/state cache is built separately via
      ``Model.abstract_cache`` and passed alongside).
    Modality frontends are stubs: precomputed patch/frame embeddings
    appear as explicit inputs.
    """
    B, S = shape.global_batch, shape.seq_len
    dt = _dtype(cfg)
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.frontend == "vision" and shape.kind != "decode":
        P = min(cfg.frontend_tokens, S)
        specs["patch_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model), dt)
    if cfg.is_encdec and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
    return specs
