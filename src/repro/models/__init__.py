# Model zoo substrate: parameter/sharding system and the layer families
# (GQA/MLA attention, MoE, Mamba-2 SSD, hybrid stacks, enc-dec).
from .model import Model, build_model, input_specs
from .params import (
    DECODE_RULES,
    TRAIN_RULES,
    ParamDef,
    Rules,
    abstract_params,
    init_params,
    param_specs,
    shard,
    sharding_ctx,
)

__all__ = [
    "Model",
    "build_model",
    "input_specs",
    "ParamDef",
    "Rules",
    "TRAIN_RULES",
    "DECODE_RULES",
    "abstract_params",
    "init_params",
    "param_specs",
    "shard",
    "sharding_ctx",
]
