"""Power iteration and PageRank on the HBP operator.

PageRank is the canonical "SpMV in a loop" workload (the SpMV surveys
benchmark formats inside exactly this kernel): every iteration is one
product with the column-stochastic transition matrix.  With ``k``
personalization vectors the iteration state is an ``[n, k]`` block and
each step is ONE multi-RHS SpMM launch — the tile stream is read once for
all ``k`` rankings, which is where the HBP format's preprocessing cost
amortizes fastest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import COOMatrix, CSRMatrix, csr_from_coo

from .base import EigResult, SolveResult, history_init, l2norm
from .operator import aslinearoperator

__all__ = ["power_iteration", "transition_matrix", "pagerank"]


def power_iteration(
    A,
    *,
    v0: jax.Array | None = None,
    tol: float = 1e-6,
    maxiter: int = 1000,
    seed: int = 0,
) -> EigResult:
    """Dominant eigenpair of ``A`` by the power method.

    Converges when ``||A v - lambda v|| <= tol * |lambda|``.  ``v0``
    defaults to a deterministic random unit vector (``seed``).
    """
    op = aslinearoperator(A)
    n = op.shape[0]
    if v0 is None:
        v0 = np.random.default_rng(seed).standard_normal(n)
    v = jnp.asarray(v0, jnp.float32)
    v = v / jnp.maximum(l2norm(v), jnp.finfo(jnp.float32).tiny)

    w = op(v)
    lam = jnp.sum(v * w, axis=0)
    resid = l2norm(w - lam * v)
    hist = history_init(maxiter, lam)

    def cond(state):
        k, _, lam, resid, _ = state
        return (k < maxiter) & (resid > tol * jnp.abs(lam))

    def body(state):
        k, v, lam, _, hist = state
        w = op(v)
        v = w / jnp.maximum(l2norm(w), jnp.finfo(jnp.float32).tiny)
        w = op(v)
        lam = jnp.sum(v * w, axis=0)  # Rayleigh quotient of the unit iterate
        resid = l2norm(w - lam * v)
        hist = hist.at[k + 1].set(lam)
        return k + 1, v, lam, resid, hist

    k, v, lam, resid, hist = jax.lax.while_loop(cond, body, (0, v, lam, resid, hist))
    return EigResult(
        eigenvalue=lam,
        eigenvector=v,
        converged=resid <= tol * jnp.abs(lam),
        iterations=k,
        residual=resid,
        history=hist,
    )


def transition_matrix(adj: CSRMatrix) -> tuple[CSRMatrix, np.ndarray]:
    """Column-stochastic PageRank matrix from an adjacency matrix.

    Edge weights are ``|a_ij|`` normalised by out-weight, then transposed
    so that ``p_new = M @ p`` propagates rank along edges.  Returns
    ``(M, dangling)`` where ``dangling`` is the float indicator of rows
    with no out-edges (their mass is redistributed by :func:`pagerank`).
    Host-side preprocessing, like the HBP format build it feeds.
    """
    n = adj.n_rows
    if adj.shape[0] != adj.shape[1]:
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    w = np.abs(adj.data)
    out_weight = np.zeros(n)
    rows = np.repeat(np.arange(n), adj.row_nnz())
    np.add.at(out_weight, rows, w)
    dangling = (out_weight == 0).astype(np.float32)
    norm = w / np.where(out_weight > 0, out_weight, 1.0)[rows]
    # transpose by swapping the roles of row and column in COO
    M = csr_from_coo(
        COOMatrix(adj.indices.copy(), rows, norm, (n, n)), sum_duplicates=True
    )
    return M, dangling


def pagerank(
    M,
    *,
    damping: float = 0.85,
    personalization: jax.Array | None = None,
    dangling: np.ndarray | None = None,
    tol: float = 1e-8,
    maxiter: int = 200,
) -> SolveResult:
    """PageRank by power iteration on the column-stochastic ``M``.

    ``M`` is anything :func:`aslinearoperator` accepts — build it with
    :func:`transition_matrix` and convert to :class:`HBPTiles` for the
    Pallas path.  ``personalization`` may be a single ``[n]`` vector or an
    ``[n, k]`` block (k personalized rankings per launch, via the SpMM
    kernel); it is normalised to sum 1 per column.  Dangling mass is
    redistributed according to the personalization, as in NetworkX.
    Converges on the per-column L1 change ``||p' - p||_1 <= tol * n``.
    """
    op = aslinearoperator(M)
    n = op.shape[0]
    if personalization is None:
        v = jnp.full((n,), 1.0 / n, jnp.float32)
    else:
        v = jnp.asarray(personalization, jnp.float32)
        v = v / jnp.sum(v, axis=0)
    dang = (
        jnp.zeros((n,), jnp.float32) if dangling is None else jnp.asarray(dangling, jnp.float32)
    )

    p = v
    # slot 0 is the pre-iteration error carry (inf, like the loop init), so
    # the finite-prefix history convention matches the linear solvers
    hist = history_init(maxiter, jnp.full(v.shape[1:], jnp.inf, jnp.float32))
    thresh = tol * n

    def cond(state):
        k, _, err, _ = state
        return (k < maxiter) & jnp.any(err > thresh)

    def body(state):
        k, p, _, hist = state
        spread = op(p)  # one SpMV/SpMM launch
        p_new = damping * (spread + (dang @ p) * v) + (1.0 - damping) * v
        err = jnp.sum(jnp.abs(p_new - p), axis=0)
        hist = hist.at[k + 1].set(err)
        return k + 1, p_new, err, hist

    k, p, err, hist = jax.lax.while_loop(
        cond, body, (0, p, jnp.full(v.shape[1:], jnp.inf), hist)
    )
    return SolveResult(
        x=p,
        converged=jnp.all(err <= thresh),
        iterations=k,
        residual=err,
        history=hist,
    )
