"""The operator abstraction every solver dispatches through.

A :class:`LinearOperator` is a *traceable* ``y = A @ x``: its ``matvec`` /
``matmat`` closures hold only jnp arrays (device-resident tile formats,
CSR arrays, dense matrices), so a solver loop built on it stays inside one
``jax.lax.while_loop`` — no host round-trips per iteration.

:func:`aslinearoperator` adapts every container in the library:

* :class:`~repro.core.tile.HBPTiles` — the production path: the Pallas HBP
  kernels (SpMV for single vectors, the multi-RHS SpMM kernel for ``[n, k]``
  blocks).  The host tiles are staged to the device ONCE at operator
  construction; solver iterations touch only :class:`DeviceTiles`.
* :class:`~repro.core.formats.CSRMatrix` — the segment-sum CSR baseline
  (Algorithm 1) for apples-to-apples workload benchmarks.
* dense ``np.ndarray`` / ``jax.Array`` — ``jnp.dot``, the oracle solvers
  are validated against.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import CSRMatrix
from repro.core.spmv import csr_spmm_jnp, csr_spmv_jnp
from repro.core.tile import HBPTiles

__all__ = ["LinearOperator", "aslinearoperator"]


class LinearOperator:
    """Matrix-free ``A``: a shape plus traceable matvec/matmat closures.

    ``matmat`` defaults to column-at-a-time matvec; format-aware adapters
    (HBP tiles) override it with the one-launch SpMM kernel.
    """

    def __init__(
        self,
        shape: Tuple[int, int],
        matvec: Callable[[jax.Array], jax.Array],
        matmat: Callable[[jax.Array], jax.Array] | None = None,
        dtype=jnp.float32,
    ):
        self.shape = tuple(shape)
        self.dtype = dtype
        self._matvec = matvec
        self._matmat = matmat

    def matvec(self, x: jax.Array) -> jax.Array:
        """``A @ x`` for a single vector ``x: [n]``."""
        return self._matvec(x)

    def matmat(self, x: jax.Array) -> jax.Array:
        """``A @ X`` for a block of right-hand sides ``X: [n, k]``."""
        if self._matmat is not None:
            return self._matmat(x)
        return jnp.stack([self._matvec(x[:, j]) for j in range(x.shape[1])], axis=1)

    def __call__(self, x: jax.Array) -> jax.Array:
        """Shape-polymorphic apply: [n] -> matvec, [n, k] -> matmat."""
        return self.matvec(x) if x.ndim == 1 else self.matmat(x)

    def __matmul__(self, x):
        return self(x)


def _from_hbp_tiles(
    tiles: HBPTiles, *, strategy: str = "fused", interpret: bool | None = None
) -> LinearOperator:
    from repro.kernels import ops

    dt = ops.device_tiles(tiles)  # staged once; iterations reuse it
    meta = dict(
        n_rowgroups=tiles.n_rowgroups,
        n_rows=tiles.shape[0],
        col_block=tiles.cfg.col_block,
        strategy=strategy,
        interpret=interpret,
    )
    return LinearOperator(
        tiles.shape,
        matvec=lambda x: ops.hbp_spmv(dt, x, **meta),
        matmat=lambda x: ops.hbp_spmm(dt, x, **meta),
    )


def _from_csr(csr: CSRMatrix) -> LinearOperator:
    indptr = jnp.asarray(csr.indptr)
    indices = jnp.asarray(csr.indices)
    data = jnp.asarray(csr.data, jnp.float32)
    n_rows = csr.n_rows
    return LinearOperator(
        csr.shape,
        matvec=lambda x: csr_spmv_jnp(indptr, indices, data, x, n_rows),
        matmat=lambda x: csr_spmm_jnp(indptr, indices, data, x, n_rows),
    )


def _from_dense(a) -> LinearOperator:
    aj = jnp.asarray(a, jnp.float32)
    return LinearOperator(aj.shape, matvec=lambda x: aj @ x, matmat=lambda x: aj @ x)


def aslinearoperator(
    A, *, strategy: str = "fused", interpret: bool | None = None
) -> LinearOperator:
    """Adapt any supported container to a :class:`LinearOperator`.

    ``strategy`` / ``interpret`` configure the Pallas kernels and apply
    only to :class:`HBPTiles` inputs.
    """
    if isinstance(A, LinearOperator):
        return A
    if isinstance(A, HBPTiles):
        return _from_hbp_tiles(A, strategy=strategy, interpret=interpret)
    if isinstance(A, CSRMatrix):
        return _from_csr(A)
    if isinstance(A, (np.ndarray, jax.Array)):
        if A.ndim != 2:
            raise ValueError(f"dense operator must be 2-D, got shape {A.shape}")
        return _from_dense(A)
    raise TypeError(f"cannot build a LinearOperator from {type(A)!r}")
