"""Conjugate gradients on the HBP operator (SPD systems).

Textbook CG (Hestenes–Stiefel) with two twists that matter here:

* the matrix product is whatever :class:`~repro.solvers.operator.LinearOperator`
  supplies — for :class:`HBPTiles` one Pallas kernel launch per iteration;
* ``b`` may be an ``[n, k]`` block of right-hand sides.  The iteration is
  then the *vectorised* CG (independent step lengths per column, one
  shared SpMM launch), so the tile stream is read once per iteration for
  all ``k`` systems instead of ``k`` times.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import SolveResult, history_init, l2norm, safe_div
from .operator import aslinearoperator

__all__ = ["cg"]


def cg(
    A,
    b: jax.Array,
    *,
    x0: jax.Array | None = None,
    tol: float = 1e-6,
    maxiter: int = 200,
) -> SolveResult:
    """Solve ``A x = b`` for SPD ``A``; ``b`` is ``[n]`` or ``[n, k]``.

    Converges when every column satisfies ``||r|| <= tol * ||b||``.
    The loop is a ``lax.while_loop`` — jit-compatible end to end.
    """
    op = aslinearoperator(A)
    b = jnp.asarray(b, jnp.float32)
    x = jnp.zeros_like(b) if x0 is None else jnp.asarray(x0, jnp.float32)
    bnorm = jnp.maximum(l2norm(b), jnp.finfo(jnp.float32).tiny)

    r = b - op(x)
    p = r
    rs = jnp.sum(r * r, axis=0)
    hist = history_init(maxiter, jnp.sqrt(rs))

    def cond(state):
        k, _, _, _, rs, _ = state
        return (k < maxiter) & jnp.any(jnp.sqrt(rs) > tol * bnorm)

    def body(state):
        k, x, r, p, rs, hist = state
        Ap = op(p)
        alpha = safe_div(rs, jnp.sum(p * Ap, axis=0))
        x = x + alpha * p
        r = r - alpha * Ap
        rs_new = jnp.sum(r * r, axis=0)
        beta = safe_div(rs_new, rs)
        p = r + beta * p
        hist = hist.at[k + 1].set(jnp.sqrt(rs_new))
        return k + 1, x, r, p, rs_new, hist

    k, x, r, p, rs, hist = jax.lax.while_loop(cond, body, (0, x, r, p, rs, hist))
    res = jnp.sqrt(rs)
    return SolveResult(
        x=x,
        converged=jnp.all(res <= tol * bnorm),
        iterations=k,
        residual=res,
        history=hist,
    )
