"""Conjugate gradients on the HBP operator (SPD systems).

Textbook (preconditioned) CG with two twists that matter here:

* the matrix product is whatever :class:`~repro.solvers.operator.LinearOperator`
  supplies — for :class:`HBPTiles` one Pallas kernel launch per iteration;
* ``b`` may be an ``[n, k]`` block of right-hand sides.  The iteration is
  then the *vectorised* CG (independent step lengths per column, one
  shared SpMM launch), so the tile stream is read once per iteration for
  all ``k`` systems instead of ``k`` times.

``M`` is an optional preconditioner ``M ~= A^{-1}`` (e.g.
:func:`~repro.solvers.precond.jacobi`), applied as one extra operator
product per iteration; convergence is still tested on the true residual.
With ``M=None`` the update algebra reduces exactly to plain CG.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import SolveResult, emit_history, history_init, l2norm, safe_div
from .operator import aslinearoperator

__all__ = ["cg"]


def cg(
    A,
    b: jax.Array,
    *,
    x0: jax.Array | None = None,
    tol: float = 1e-6,
    maxiter: int = 200,
    M=None,
    record_history: bool = True,
) -> SolveResult:
    """Solve ``A x = b`` for SPD ``A``; ``b`` is ``[n]`` or ``[n, k]``.

    ``M`` (optional) preconditions the iteration: for SPD ``M ~= A^{-1}``
    this is standard PCG, minimising the same ``A``-norm error over the
    preconditioned Krylov space — badly scaled diagonals (circuit
    matrices) converge in far fewer iterations under :func:`jacobi`.
    Converges when every column satisfies ``||r|| <= tol * ||b||``.
    The loop is a ``lax.while_loop`` — jit-compatible end to end.

    ``record_history=True`` (default) carries per-iteration residual
    norms in the loop state (``result.history``, NaN-padded) and — with
    ``repro.obs`` enabled — streams them as a ``solver.cg.residual``
    series after the loop exits; ``False`` carries a single slot instead
    (memory-free long runs, ``history`` holds only the initial norm).
    """
    op = aslinearoperator(A)
    apply_M = aslinearoperator(M) if M is not None else (lambda v: v)
    b = jnp.asarray(b, jnp.float32)
    x = jnp.zeros_like(b) if x0 is None else jnp.asarray(x0, jnp.float32)
    bnorm = jnp.maximum(l2norm(b), jnp.finfo(jnp.float32).tiny)

    r = b - op(x)
    z = apply_M(r)
    p = z
    rz = jnp.sum(r * z, axis=0)
    hist = history_init(maxiter if record_history else 0, l2norm(r))

    def cond(state):
        k, _, r, _, _, _ = state
        return (k < maxiter) & jnp.any(l2norm(r) > tol * bnorm)

    def body(state):
        k, x, r, p, rz, hist = state
        Ap = op(p)
        alpha = safe_div(rz, jnp.sum(p * Ap, axis=0))
        x = x + alpha * p
        r = r - alpha * Ap
        z = apply_M(r)
        rz_new = jnp.sum(r * z, axis=0)
        beta = safe_div(rz_new, rz)
        p = z + beta * p
        hist = hist.at[k + 1].set(l2norm(r))
        return k + 1, x, r, p, rz_new, hist

    k, x, r, p, rz, hist = jax.lax.while_loop(cond, body, (0, x, r, p, rz, hist))
    res = l2norm(r)
    emit_history("cg", hist)
    return SolveResult(
        x=x,
        converged=jnp.all(res <= tol * bnorm),
        iterations=k,
        residual=res,
        history=hist,
    )
