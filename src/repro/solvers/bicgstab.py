"""BiCGSTAB (van der Vorst) — the nonsymmetric workhorse.

The paper's matrix families (circuit simulation, semiconductor FEM) are
nonsymmetric, so CG does not apply to them directly; BiCGSTAB is the
standard Krylov method production circuit solvers run on exactly these
matrices.  Two operator applications per iteration; like :func:`cg` it is
vectorised over an ``[n, k]`` RHS block (per-column scalars, shared SpMM
launches).

``M`` right-preconditions the iteration (``A M`` Krylov space, update
directions mapped through ``M`` before entering ``x``): the residual keeps
its plain meaning ``b - A x``, so the convergence test is unchanged, and
``M=None`` reduces exactly to the unpreconditioned update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import SolveResult, emit_history, history_init, l2norm, safe_div
from .operator import aslinearoperator

__all__ = ["bicgstab"]


def bicgstab(
    A,
    b: jax.Array,
    *,
    x0: jax.Array | None = None,
    tol: float = 1e-6,
    maxiter: int = 400,
    M=None,
    record_history: bool = True,
) -> SolveResult:
    """Solve ``A x = b`` for general (nonsymmetric) ``A``.

    ``M`` (optional) is a right preconditioner ``M ~= A^{-1}``, e.g.
    :func:`~repro.solvers.precond.jacobi` — one extra operator product per
    operator application.  On Krylov breakdown (``rho`` or ``omega``
    hitting exactly zero — residual already at machine floor) the guarded
    divisions freeze the iterate instead of producing NaNs, and the loop
    exits on the residual test or ``maxiter``.

    ``record_history`` as in :func:`~repro.solvers.cg.cg`: ``True``
    carries per-iteration residual norms (and streams them to
    ``repro.obs`` post-loop), ``False`` carries one slot.
    """
    op = aslinearoperator(A)
    apply_M = aslinearoperator(M) if M is not None else (lambda v: v)
    b = jnp.asarray(b, jnp.float32)
    x = jnp.zeros_like(b) if x0 is None else jnp.asarray(x0, jnp.float32)
    bnorm = jnp.maximum(l2norm(b), jnp.finfo(jnp.float32).tiny)

    r = b - op(x)
    rhat = r  # shadow residual, fixed
    ones = jnp.ones(r.shape[1:], jnp.float32)
    rho = ones
    alpha = ones
    omega = ones
    v = jnp.zeros_like(r)
    p = jnp.zeros_like(r)
    hist = history_init(maxiter if record_history else 0, l2norm(r))

    def cond(state):
        k, _, r, *_ = state
        return (k < maxiter) & jnp.any(l2norm(r) > tol * bnorm)

    def body(state):
        k, x, r, p, v, rho, alpha, omega, hist = state
        rho_new = jnp.sum(rhat * r, axis=0)
        beta = safe_div(rho_new * alpha, rho * omega)
        p = r + beta * (p - omega * v)
        phat = apply_M(p)
        v = op(phat)
        alpha = safe_div(rho_new, jnp.sum(rhat * v, axis=0))
        s = r - alpha * v
        shat = apply_M(s)
        t = op(shat)
        omega = safe_div(jnp.sum(t * s, axis=0), jnp.sum(t * t, axis=0))
        x = x + alpha * phat + omega * shat
        r = s - omega * t
        hist = hist.at[k + 1].set(l2norm(r))
        return k + 1, x, r, p, v, rho_new, alpha, omega, hist

    state = (0, x, r, p, v, rho, alpha, omega, hist)
    k, x, r, p, v, rho, alpha, omega, hist = jax.lax.while_loop(cond, body, state)
    res = l2norm(r)
    emit_history("bicgstab", hist)
    return SolveResult(
        x=x,
        converged=jnp.all(res <= tol * bnorm),
        iterations=k,
        residual=res,
        history=hist,
    )
