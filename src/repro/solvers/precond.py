"""Preconditioners as :class:`LinearOperator` compositions.

A preconditioner ``M ~= A^{-1}`` enters the Krylov loops (``cg``,
``bicgstab``) as just another operator application, so it composes with
every matrix container the solvers accept — and it stays inside the
``lax.while_loop`` like the SpMV itself.

:func:`jacobi` is the diagonal (point-Jacobi) preconditioner.  Its input
is deliberately flexible: the diagonal is host-resident anyway at
tile-build time (the CSR matrix is on the host while the HBP tiles are
constructed; the serving registry snapshots it into the plan), so there is
never a reason to recover it from the device format.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.formats import CSRMatrix

from .operator import LinearOperator

__all__ = ["jacobi"]


def jacobi(A) -> LinearOperator:
    """Jacobi preconditioner ``M = diag(A)^{-1}`` as a LinearOperator.

    ``A`` may be a :class:`CSRMatrix` (diagonal extracted on the host), a
    dense 2-D array, or the diagonal itself as a 1-D vector — e.g. the
    one a serving :class:`~repro.serving.registry.MatrixPlan` captured at
    admission.  Zero diagonal entries fall back to the identity (scale 1)
    so the operator is always well defined.
    """
    if isinstance(A, CSRMatrix):
        diag = A.diagonal()
    else:
        arr = np.asarray(A)
        if arr.ndim == 2:
            diag = np.diagonal(arr)
        elif arr.ndim == 1:
            diag = arr
        else:
            raise ValueError(
                f"jacobi expects a matrix or a 1-D diagonal, got ndim={arr.ndim}"
            )
    inv = jnp.asarray(
        np.where(diag != 0, 1.0 / np.where(diag != 0, diag, 1.0), 1.0), jnp.float32
    )
    n = inv.shape[0]
    return LinearOperator(
        (n, n),
        matvec=lambda x: inv * x,
        matmat=lambda x: inv[:, None] * x,
    )
