"""Preconditioners as :class:`LinearOperator` compositions.

A preconditioner ``M ~= A^{-1}`` enters the Krylov loops (``cg``,
``bicgstab``) as just another operator application, so it composes with
every matrix container the solvers accept — and it stays inside the
``lax.while_loop`` like the SpMV itself.

:func:`jacobi` is the diagonal (point-Jacobi) preconditioner.  Its input
is deliberately flexible: the diagonal is host-resident anyway at
tile-build time (the CSR matrix is on the host while the HBP tiles are
constructed; the serving registry snapshots it into the plan), so there is
never a reason to recover it from the device format.

:func:`block_jacobi` is the block variant: invert dense diagonal blocks
``A[idx, idx]`` over a partition of the index set and apply them batched.
Any disjoint partition is valid — contiguous ``block_size`` runs are the
classic choice, and :func:`hash_group_blocks` derives the partition from
the HBP tile format itself (one block per hash group, the ``[group,
group]`` granularity the kernels already reduce over).  Off-block
couplings are simply dropped, so the better the partition matches the
matrix's strong couplings, the closer M is to A^{-1}.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.formats import CSRMatrix
from repro.core.tile import HBPTiles

from .operator import LinearOperator

__all__ = ["jacobi", "block_jacobi", "hash_group_blocks"]


def jacobi(A) -> LinearOperator:
    """Jacobi preconditioner ``M = diag(A)^{-1}`` as a LinearOperator.

    ``A`` may be a :class:`CSRMatrix` (diagonal extracted on the host), a
    dense 2-D array, or the diagonal itself as a 1-D vector — e.g. the
    one a serving :class:`~repro.serving.registry.MatrixPlan` captured at
    admission.  Zero diagonal entries fall back to the identity (scale 1)
    so the operator is always well defined.
    """
    if isinstance(A, CSRMatrix):
        diag = A.diagonal()
    else:
        arr = np.asarray(A)
        if arr.ndim == 2:
            diag = np.diagonal(arr)
        elif arr.ndim == 1:
            diag = arr
        else:
            raise ValueError(
                f"jacobi expects a matrix or a 1-D diagonal, got ndim={arr.ndim}"
            )
    inv = jnp.asarray(
        np.where(diag != 0, 1.0 / np.where(diag != 0, diag, 1.0), 1.0), jnp.float32
    )
    n = inv.shape[0]
    return LinearOperator(
        (n, n),
        matvec=lambda x: inv * x,
        matmat=lambda x: inv[:, None] * x,
    )


def hash_group_blocks(tiles: HBPTiles) -> list:
    """Index partition induced by the HBP hash: one block per row group.

    ``tiles.perm`` maps hashed slots to original rows over the padded row
    space; consecutive runs of ``cfg.group`` slots are exactly the row
    groups the kernels reduce over.  Padding rows are dropped, empty
    groups skipped.  Because the nonlinear hash clusters rows of similar
    nnz, these blocks capture the "rows that behave alike" structure the
    format was built around — the natural granularity for a tile-format
    block preconditioner.
    """
    n_rows = tiles.shape[0]
    G = tiles.cfg.group
    slots = np.asarray(tiles.perm).reshape(-1, G)
    blocks = []
    for grp in slots:
        idx = np.sort(grp[grp < n_rows])
        if idx.size:
            blocks.append(idx.astype(np.int64))
    return blocks


def _dense_blocks_from_csr(
    csr: CSRMatrix, blocks: Sequence[np.ndarray], bmax: int
) -> np.ndarray:
    """Gather A[idx, idx] for every block in one pass over the nnz."""
    n = csr.shape[0]
    bid = np.full(n, -1, dtype=np.int64)  # block id per row, -1 = unassigned
    lpos = np.zeros(n, dtype=np.int64)  # local position within the block
    for b, idx in enumerate(blocks):
        bid[idx] = b
        lpos[idx] = np.arange(idx.size)
    rows = np.repeat(np.arange(n), csr.row_nnz())
    cols = csr.indices
    mask = (bid[rows] >= 0) & (bid[rows] == bid[cols])
    dense = np.zeros((len(blocks), bmax, bmax), dtype=np.float64)
    np.add.at(
        dense, (bid[rows[mask]], lpos[rows[mask]], lpos[cols[mask]]), csr.data[mask]
    )
    return dense


def block_jacobi(
    A,
    *,
    block_size: Optional[int] = None,
    blocks: Optional[Sequence[np.ndarray]] = None,
) -> LinearOperator:
    """Block-Jacobi preconditioner ``M = blockdiag(A[idx, idx])^{-1}``.

    ``A`` is a :class:`CSRMatrix` or a dense 2-D array (the tile format
    holds permuted values only — for a tile-derived partition pass the CSR
    as ``A`` with ``blocks=hash_group_blocks(tiles)``).  The partition
    comes from ``blocks`` (disjoint index arrays; rows left out fall back
    to point Jacobi on their diagonal) or ``block_size`` (contiguous runs,
    default 8).

    Each block is inverted densely on the host at build time —
    ``[group, group]`` solves are trivial next to tile construction — and
    applied batched on device: gather to ``[n_blocks, bmax]``, one
    ``einsum`` against the padded inverse stack, scatter back.  Singular
    blocks fall back to the pseudo-inverse.
    """
    if isinstance(A, HBPTiles):
        raise TypeError(
            "block_jacobi needs the host CSR matrix; derive the partition "
            "with blocks=hash_group_blocks(tiles) and pass the CSR as A"
        )
    if isinstance(A, CSRMatrix):
        csr = A
    else:
        arr = np.asarray(A)
        if arr.ndim != 2 or arr.shape[0] != arr.shape[1]:
            raise ValueError(f"block_jacobi expects a square matrix, got {arr.shape}")
        from repro.core.formats import csr_from_dense

        csr = csr_from_dense(arr)
    n = csr.shape[0]
    if csr.shape[0] != csr.shape[1]:
        raise ValueError(f"block_jacobi expects a square matrix, got {csr.shape}")

    if blocks is None:
        bs = block_size or 8
        blocks = [np.arange(lo, min(lo + bs, n)) for lo in range(0, n, bs)]
    else:
        blocks = [np.asarray(b, dtype=np.int64) for b in blocks if len(b)]
        flat = np.concatenate(blocks) if blocks else np.zeros(0, np.int64)
        if flat.size != np.unique(flat).size:
            raise ValueError("blocks must be disjoint")
        if flat.size and (flat.min() < 0 or flat.max() >= n):
            raise ValueError(f"block indices outside [0, {n})")
    if not blocks:
        return jacobi(csr)

    bmax = max(len(b) for b in blocks)
    dense = _dense_blocks_from_csr(csr, blocks, bmax)

    # pad unused local slots (short blocks) with identity so inversion is
    # well posed and padded slots pass values through unchanged
    inv = np.zeros_like(dense)
    for b, idx in enumerate(blocks):
        s = idx.size
        blk = dense[b, :s, :s]
        # zero diagonal entries would make even the 1x1 case singular;
        # match jacobi()'s identity fallback at the scalar level
        dzero = np.diagonal(blk) == 0
        if dzero.any():
            blk = blk + np.diag(np.where(dzero, 1.0, 0.0))
        try:
            inv_blk = np.linalg.inv(blk)
        except np.linalg.LinAlgError:
            inv_blk = np.linalg.pinv(blk)
        inv[b, :s, :s] = inv_blk

    # device-side application: gather -> batched matmul -> scatter
    idx_pad = np.zeros((len(blocks), bmax), dtype=np.int64)
    mask = np.zeros((len(blocks), bmax), dtype=np.float32)
    for b, idx in enumerate(blocks):
        idx_pad[b, : idx.size] = idx
        mask[b, : idx.size] = 1.0
    covered = np.zeros(n, dtype=bool)
    covered[np.concatenate(blocks)] = True
    # rows no block claims: point Jacobi on their diagonal (identity if 0)
    diag = csr.diagonal()
    rest = np.where(
        covered, 0.0, np.where(diag != 0, 1.0 / np.where(diag != 0, diag, 1.0), 1.0)
    )

    inv_j = jnp.asarray(inv, jnp.float32)
    idx_j = jnp.asarray(idx_pad)
    mask_j = jnp.asarray(mask)
    rest_j = jnp.asarray(rest, jnp.float32)

    def matmat(x: jnp.ndarray) -> jnp.ndarray:
        xg = x[idx_j] * mask_j[..., None]  # [nb, bmax, k]
        yg = jnp.einsum("bij,bjk->bik", inv_j, xg) * mask_j[..., None]
        y = jnp.zeros_like(x).at[idx_j.reshape(-1)].add(
            yg.reshape(-1, x.shape[-1])
        )
        return y + rest_j[:, None] * x

    return LinearOperator(
        (n, n),
        matvec=lambda x: matmat(x[:, None])[:, 0],
        matmat=matmat,
    )
