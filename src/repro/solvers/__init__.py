# Iterative workloads on top of the HBP SpMV/SpMM kernels: the algorithms
# whose inner loop IS a sparse matrix product, so the format's preprocessing
# cost (paper Fig. 7) amortizes across iterations.  Every solver dispatches
# through the LinearOperator abstraction (operator.py) and runs its loop in
# a jax.lax.while_loop — the whole iteration stays on device.
from .base import EigResult, SolveResult
from .bicgstab import bicgstab
from .cg import cg
from .chebyshev import chebyshev, estimate_spectrum
from .operator import LinearOperator, aslinearoperator
from .power import pagerank, power_iteration, transition_matrix
from .precond import block_jacobi, hash_group_blocks, jacobi

__all__ = [
    "SolveResult",
    "EigResult",
    "LinearOperator",
    "aslinearoperator",
    "cg",
    "bicgstab",
    "chebyshev",
    "estimate_spectrum",
    "power_iteration",
    "pagerank",
    "transition_matrix",
    "jacobi",
    "block_jacobi",
    "hash_group_blocks",
]
