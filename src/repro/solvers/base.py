"""Shared solver plumbing: results, histories, safe arithmetic.

Every solver loop is a ``jax.lax.while_loop`` whose carry includes a
fixed-length residual history (``maxiter + 1`` slots, NaN beyond the last
iteration actually run), so the whole iteration — SpMV/SpMM launches,
vector updates, convergence test — stays on device and jit-compiles.
``record_history=False`` shrinks the carried history to a single slot
(the in-loop scatter then drops out of bounds — JAX's documented update
semantics — so the loop body is unchanged); :func:`emit_history` streams
a recorded history into ``repro.obs`` *after* the loop returns, never
from inside it, so observability adds zero host syncs per iteration.
"""
from __future__ import annotations

import math
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SolveResult",
    "EigResult",
    "l2norm",
    "safe_div",
    "history_init",
    "emit_history",
]


class SolveResult(NamedTuple):
    """Outcome of an iterative linear solve.

    ``x`` has the shape of ``b`` ([n] or [n, k]); ``residual`` and the
    per-iteration ``history`` rows are scalars for a single RHS and
    ``[k]`` vectors for blocked RHS.
    """

    x: jax.Array
    converged: jax.Array  # bool[] — all RHS columns under tolerance
    iterations: jax.Array  # i32[]
    residual: jax.Array  # final ||b - A x|| (2-norm), per RHS column
    history: jax.Array  # f32[maxiter + 1, ...] residual norms, NaN-padded


class EigResult(NamedTuple):
    """Outcome of an eigenvalue iteration (power method)."""

    eigenvalue: jax.Array  # f32[] Rayleigh quotient at exit
    eigenvector: jax.Array  # f32[n], unit norm
    converged: jax.Array  # bool[]
    iterations: jax.Array  # i32[]
    residual: jax.Array  # ||A v - lambda v|| at exit
    history: jax.Array  # f32[maxiter + 1] eigenvalue estimates, NaN-padded


def l2norm(v: jax.Array) -> jax.Array:
    """Column-wise 2-norm: scalar for [n], [k] for [n, k]."""
    return jnp.sqrt(jnp.sum(v * v, axis=0))


def safe_div(num: jax.Array, den: jax.Array) -> jax.Array:
    """num / den with 0 where den == 0 (Krylov breakdown guard: a zero
    denominator only occurs once the residual is exactly zero)."""
    return jnp.where(den != 0, num / jnp.where(den != 0, den, 1.0), 0.0)


def history_init(maxiter: int, first_row: jax.Array) -> jax.Array:
    """[maxiter + 1, ...] NaN history with slot 0 filled.

    ``maxiter=0`` is the ``record_history=False`` form: one slot, and the
    solvers' in-loop ``hist.at[k + 1].set(...)`` scatters land out of
    bounds and are dropped — same loop body, no carried history memory.
    """
    hist = jnp.full((maxiter + 1,) + first_row.shape, jnp.nan, jnp.float32)
    return hist.at[0].set(first_row)


def emit_history(solver: str, hist: jax.Array) -> None:
    """Stream a residual history into ``repro.obs`` as a per-run series.

    Called by the solvers after their ``lax.while_loop`` returns — never
    inside it, so instrumentation costs no per-iteration host syncs.  A
    no-op when the solver is itself under a ``jit`` trace (``hist`` is an
    abstract tracer — no values exist yet) or when the history holds a
    single slot (``record_history=False``); otherwise one summary instant
    always lands in the flight ring, and the full residual series is
    streamed only while obs is enabled.  Blocked
    RHS histories record the worst column per iteration (the convergence
    test is on the max).  Each call gets its own ``run=N``-labelled
    series, indexed by iteration.
    """
    from repro import obs

    if isinstance(hist, jax.core.Tracer):
        return
    vals = np.asarray(hist)
    if vals.shape[0] <= 1:  # record_history=False: nothing to stream
        return
    if vals.ndim > 1:
        # unfilled iterations are all-NaN rows; silence nanmax's warning
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            vals = np.nanmax(vals.reshape(vals.shape[0], -1), axis=1)
    # the always-on flight ring gets one instant per solve regardless of
    # the obs flag — a post-mortem can show what converged around an anomaly
    n = int(np.sum(~np.isnan(vals)))
    obs.get_flight().record(
        "solver.run",
        solver=solver,
        iters=max(n - 1, 0),
        final_residual=float(vals[n - 1]) if n else None,
    )
    if not obs.enabled():
        return
    runs = obs.counter("solver.runs", solver=solver)
    runs.inc()
    series = obs.series(f"solver.{solver}.residual", run=int(runs.value))
    for i, v in enumerate(vals):
        if math.isnan(v):
            break
        series.append(float(v), index=i)
