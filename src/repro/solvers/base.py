"""Shared solver plumbing: results, histories, safe arithmetic.

Every solver loop is a ``jax.lax.while_loop`` whose carry includes a
fixed-length residual history (``maxiter + 1`` slots, NaN beyond the last
iteration actually run), so the whole iteration — SpMV/SpMM launches,
vector updates, convergence test — stays on device and jit-compiles.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["SolveResult", "EigResult", "l2norm", "safe_div", "history_init"]


class SolveResult(NamedTuple):
    """Outcome of an iterative linear solve.

    ``x`` has the shape of ``b`` ([n] or [n, k]); ``residual`` and the
    per-iteration ``history`` rows are scalars for a single RHS and
    ``[k]`` vectors for blocked RHS.
    """

    x: jax.Array
    converged: jax.Array  # bool[] — all RHS columns under tolerance
    iterations: jax.Array  # i32[]
    residual: jax.Array  # final ||b - A x|| (2-norm), per RHS column
    history: jax.Array  # f32[maxiter + 1, ...] residual norms, NaN-padded


class EigResult(NamedTuple):
    """Outcome of an eigenvalue iteration (power method)."""

    eigenvalue: jax.Array  # f32[] Rayleigh quotient at exit
    eigenvector: jax.Array  # f32[n], unit norm
    converged: jax.Array  # bool[]
    iterations: jax.Array  # i32[]
    residual: jax.Array  # ||A v - lambda v|| at exit
    history: jax.Array  # f32[maxiter + 1] eigenvalue estimates, NaN-padded


def l2norm(v: jax.Array) -> jax.Array:
    """Column-wise 2-norm: scalar for [n], [k] for [n, k]."""
    return jnp.sqrt(jnp.sum(v * v, axis=0))


def safe_div(num: jax.Array, den: jax.Array) -> jax.Array:
    """num / den with 0 where den == 0 (Krylov breakdown guard: a zero
    denominator only occurs once the residual is exactly zero)."""
    return jnp.where(den != 0, num / jnp.where(den != 0, den, 1.0), 0.0)


def history_init(maxiter: int, first_row: jax.Array) -> jax.Array:
    """[maxiter + 1, ...] NaN history with slot 0 filled."""
    hist = jnp.full((maxiter + 1,) + first_row.shape, jnp.nan, jnp.float32)
    return hist.at[0].set(first_row)
