"""Chebyshev iteration / polynomial smoothing on the HBP operator.

Given spectrum bounds ``0 < lam_min <= lam(A) <= lam_max`` for SPD ``A``,
Chebyshev iteration reaches CG-like convergence WITHOUT inner products —
every iteration is exactly one operator application plus AXPYs.  That
makes it the multigrid smoother of choice and, for this library, the
purest "SpMV is the whole workload" solver: no reductions compete with
the kernel launch in the profile.  Vectorised over ``[n, k]`` RHS blocks
like :func:`~repro.solvers.cg.cg` (the scalars are spectral, shared by
every column).

:func:`estimate_spectrum` bootstraps the bounds with a short power
iteration (``lam_max`` slightly inflated for safety, ``lam_min`` as a
fixed fraction — the standard smoothing convention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import SolveResult, emit_history, history_init, l2norm
from .operator import aslinearoperator

__all__ = ["chebyshev", "estimate_spectrum"]


def estimate_spectrum(
    A, *, maxiter: int = 50, lower_frac: float = 0.1, safety: float = 1.05
) -> tuple[float, float]:
    """(lam_min, lam_max) bounds for :func:`chebyshev` via power iteration."""
    from .power import power_iteration

    res = power_iteration(A, maxiter=maxiter, tol=1e-4)
    lam_max = float(res.eigenvalue) * safety
    return lower_frac * lam_max, lam_max


def chebyshev(
    A,
    b: jax.Array,
    *,
    lam_min: float,
    lam_max: float,
    x0: jax.Array | None = None,
    tol: float = 1e-6,
    maxiter: int = 200,
    record_history: bool = True,
) -> SolveResult:
    """Solve / smooth ``A x = b`` with Chebyshev acceleration.

    With ``tol=0`` it runs exactly ``maxiter`` iterations — the fixed
    polynomial degree of a multigrid smoothing pass.

    ``record_history`` as in :func:`~repro.solvers.cg.cg`: ``True``
    carries per-iteration residual norms (and streams them to
    ``repro.obs`` post-loop), ``False`` carries one slot.
    """
    if not 0 < lam_min < lam_max:
        raise ValueError(f"need 0 < lam_min < lam_max, got [{lam_min}, {lam_max}]")
    op = aslinearoperator(A)
    b = jnp.asarray(b, jnp.float32)
    x = jnp.zeros_like(b) if x0 is None else jnp.asarray(x0, jnp.float32)
    bnorm = jnp.maximum(l2norm(b), jnp.finfo(jnp.float32).tiny)

    theta = 0.5 * (lam_max + lam_min)  # spectrum centre
    delta = 0.5 * (lam_max - lam_min)  # spectrum half-width
    sigma = theta / delta

    r = b - op(x)
    d = r / theta
    hist = history_init(maxiter if record_history else 0, l2norm(r))

    def cond(state):
        k, _, r, _, _, _ = state
        return (k < maxiter) & jnp.any(l2norm(r) > tol * bnorm)

    def body(state):
        k, x, r, d, rho, hist = state
        x = x + d
        r = r - op(d)
        rho_new = 1.0 / (2.0 * sigma - rho)
        d = rho_new * rho * d + (2.0 * rho_new / delta) * r
        hist = hist.at[k + 1].set(l2norm(r))
        return k + 1, x, r, d, rho_new, hist

    state = (0, x, r, d, 1.0 / sigma, hist)
    k, x, r, d, rho, hist = jax.lax.while_loop(cond, body, state)
    res = l2norm(r)
    emit_history("chebyshev", hist)
    return SolveResult(
        x=x,
        converged=jnp.all(res <= tol * bnorm),
        iterations=k,
        residual=res,
        history=hist,
    )
