"""Serving launcher CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --requests 8 --max-new 16 [--sparsity 0.9]

``--sparsity`` additionally builds HBP SparseLinear versions of every FFN
projection (the paper's technique as a serving feature) and reports the
achieved density; decode itself runs the dense path so the comparison is
apples-to-apples on CPU.
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import Engine, EngineConfig, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--sparsity", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    if args.sparsity > 0:
        from repro.core.sparse_linear import SparseLinear

        stack = params["dec"]["stack"]
        dens = []
        for key, sub in stack.items():
            if "ffn" not in sub:
                continue
            w = np.asarray(sub["ffn"]["w2"][0])
            dens.append(SparseLinear.from_dense(w.T, sparsity=args.sparsity).density())
        print(f"HBP sparse FFNs: target sparsity {args.sparsity}, density {np.mean(dens):.3f}")

    engine = Engine(model, params, EngineConfig(batch=args.batch, max_len=256))
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                max_new=args.max_new)
        for _ in range(args.requests)
    ]
    import time

    t0 = time.time()
    engine.generate(reqs)
    dt = time.time() - t0
    total = sum(r.max_new for r in reqs)
    print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on host CPU)")
    for i, r in enumerate(reqs[:3]):
        print(f"req{i}: {r.out[:10].tolist()}")


if __name__ == "__main__":
    main()
