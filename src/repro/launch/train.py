"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50 \
        --smoke --batch 8 --seq 128

``--smoke`` runs the reduced same-family config on the host (CPU-friendly);
without it the full config is built and the step is jit-compiled against
the production mesh (requires the corresponding device count).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", help="reduced config on host")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    n = sum(int(v.size) for v in jax.tree.leaves(model.abstract()))
    print(f"arch={cfg.name} params={n/1e6:.1f}M")

    trainer = Trainer(
        model,
        AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 10, 1), decay_steps=args.steps),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch),
        TrainerConfig(
            steps=args.steps,
            log_every=max(args.steps // 10, 1),
            checkpoint_every=max(args.steps // 2, 1),
            checkpoint_dir=args.ckpt,
            n_microbatch=args.microbatch,
        ),
    )
    trainer.run()


if __name__ == "__main__":
    main()
