"""Production mesh construction.

A *function*, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).

Topology: TPU v5e, 16×16 = 256 chips per pod; the multi-pod mesh stacks a
"pod" data-parallel axis across 2 pods (512 chips).  When the process holds
more devices than a single-pod mesh needs (the 512-device dry-run), the
single-pod mesh is built on the first 256 devices.
"""
from __future__ import annotations

import numpy as np

import jax

__all__ = ["make_production_mesh", "mesh_devices"]


def mesh_devices(n: int):
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh needs {n} devices, found {len(devs)} — the dry-run must "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import"
        )
    return np.array(devs[:n])


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=mesh_devices(n))


def make_host_mesh():
    """Degenerate 1×1 mesh for smoke tests / single-host examples."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=mesh_devices(1))
