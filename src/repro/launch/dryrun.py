import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod AOT dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
initialisation, and the production meshes need 512 placeholder CPU
devices.  Nothing here allocates device memory for the full configs — all
inputs are ShapeDtypeStructs and the compile is ahead-of-time.

Per cell this driver records (experiments/dryrun/<arch>__<shape>__<mesh>.json):
  * memory_analysis  — per-device argument/output/temp bytes (fit proof);
  * cost_analysis    — per-device FLOPs / bytes accessed;
  * collective wire bytes parsed from the optimized HLO (scan-body trip
    counts composed multiplicatively);
  * roofline terms from 1-group/2-group unrolled extrapolation (§Roofline).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.roofline import V5E, parse_collective_bytes, roofline_from_costs
from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import DECODE_RULES, TRAIN_RULES, build_model, input_specs, sharding_ctx
from repro.models.params import logical_spec
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.serve.steps import make_decode_step, make_prefill_step
from repro.train.steps import make_train_step
from repro.launch.mesh import make_production_mesh

# gradient-accumulation microbatch override per arch for the train_4k cell
# (auto-sized otherwise — the activation-memory knob, EXPERIMENTS.md notes).
MICROBATCH: dict = {}

# target activation volume per microbatch per device (token·dims); sized so
# a layer's transient working set stays well under the 16 GB/chip budget.
_MICRO_TARGET = 16384 * 4096


def default_microbatch(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    if shape.kind != "train":
        return 1
    if cfg.name in MICROBATCH:
        return MICROBATCH[cfg.name]
    data_shards = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    tok_per_dev = shape.global_batch * shape.seq_len / data_shards
    d = max(cfg.d_model, cfg.ssm_expand * cfg.d_model if cfg.ssm_state else 0)
    if cfg.moe_experts:
        # MoE dispatch expands every token into top_k slots — the dominant
        # transient is the [B, S·K, D] permuted activation, not [B, S, D]
        d = max(d, cfg.d_model * max(cfg.moe_top_k // 2, 1))
    layers = cfg.n_layers + cfg.encoder_layers
    # (1) per-microbatch transient working set; (2) remat boundary budget:
    # the layer scan stores one bf16 [tokens, d_model] carry per layer.
    # A microbatch must keep at least one sequence per data shard — smaller
    # slices stop sharding the batch dim and replicate activations.  The
    # two-level remat scan stores ~sqrt(layers) boundaries, reflected here.
    import math

    stored_layers = 2 * math.isqrt(layers) + 2
    n1 = tok_per_dev * d / _MICRO_TARGET
    n2 = tok_per_dev * cfg.d_model * 2 * stored_layers / 4e9
    # (3) f32 logits transient: tokens × padded_vocab/16 × 4 B (the CE
    # masked-sum keeps it sharded over "model", but several copies live
    # through the backward) — dominates for 256k-vocab models
    vocab_shards = mesh.shape.get("model", 1) if cfg.padded_vocab % mesh.shape.get("model", 1) == 0 else 1
    n3 = tok_per_dev * cfg.padded_vocab * 4 / vocab_shards / 2e9
    n = max(1, int(max(n1, n2, n3)))
    n = 1 << (n - 1).bit_length()  # next power of two (divides the batch)
    return min(n, max(1, shape.global_batch // data_shards))


def needs_sp(cfg: ModelConfig, shape: ShapeConfig, mesh) -> bool:
    """Sequence parallelism when the remat boundaries of the largest legal
    microbatch would not fit (the 340B-class cells)."""
    if shape.kind != "train":
        return False
    data_shards = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    tok_micro_dev = shape.seq_len  # one sequence per device, the floor
    boundaries = tok_micro_dev * cfg.d_model * 2 * (cfg.n_layers + cfg.encoder_layers)
    return boundaries > 6e9


def opt_config(cfg: ModelConfig) -> AdamWConfig:
    # int8 Adam moments above 100B params (16 GB/chip budget, DESIGN.md §5)
    state_dtype = "int8" if cfg.param_count() > 1e11 else "float32"
    return AdamWConfig(state_dtype=state_dtype)


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    """None if runnable, else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return (
            "long_500k needs sub-quadratic decode state; "
            f"{cfg.name} is pure full-attention (skip per assignment sheet)"
        )
    return None


def named(tree, mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *, n_micro: int | None = None):
    """Returns (step_fn, abstract_args, in_shardings, out_shardings, rules)."""
    model = build_model(cfg)
    batch_specs = input_specs(cfg, shape)
    B = shape.global_batch

    def batch_sharding():
        out = {}
        for k, v in batch_specs.items():
            if k == "tokens":
                logical = ("batch", None)
            else:  # patch_embeds / frames
                logical = ("batch", None, None)
            out[k] = logical_spec(v.shape, logical, rules, mesh)
        return out

    if shape.kind == "train":
        rules = TRAIN_RULES
        ocfg = opt_config(cfg)
        micro = n_micro if n_micro is not None else default_microbatch(cfg, shape, mesh)
        params_abs = model.abstract()
        opt_abs = jax.eval_shape(lambda p: init_opt_state(p, ocfg), params_abs)
        state_abs = {"params": params_abs, "opt": opt_abs}
        pspecs = model.specs(rules, mesh)
        acc_dtype = jnp.bfloat16 if cfg.param_count() > 1e11 else jnp.float32
        step = make_train_step(
            model, ocfg, n_microbatch=micro, remat=True,
            param_shardings=named(pspecs, mesh), acc_dtype=acc_dtype,
        )
        from repro.optim.adamw import opt_state_specs

        state_specs = {"params": pspecs, "opt": opt_state_specs(params_abs, pspecs, ocfg, mesh)}
        args = (state_abs, batch_specs)
        in_sh = (named(state_specs, mesh), named(batch_sharding(), mesh))
        out_sh = (in_sh[0], None)
        extra = {"n_microbatch": micro, "opt_state": ocfg.state_dtype,
                 "acc_dtype": str(jnp.dtype(acc_dtype)), "rules": rules.name,
                 "donate": (0,)}
        return step, args, in_sh, out_sh, rules, extra

    rules = DECODE_RULES
    model_abs = model.abstract()
    pspecs = model.specs(rules, mesh)
    cache_abs = model.abstract_cache(B, shape.seq_len)
    cache_specs = model.cache_specs(rules, mesh, B, shape.seq_len)

    if shape.kind == "prefill":
        step = make_prefill_step(model)
        args = (model_abs, batch_specs, cache_abs)
        in_sh = (named(pspecs, mesh), named(batch_sharding(), mesh), named(cache_specs, mesh))
        out_sh = (in_sh[2], None)
        return step, args, in_sh, out_sh, rules, {"donate": (2,)}

    # decode: one token against a full cache
    step = make_decode_step(model)
    tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    tok_spec = logical_spec((B, 1), ("batch", None), rules, mesh)
    args = (model_abs, cache_abs, tok_abs, pos_abs)
    in_sh = (
        named(pspecs, mesh),
        named(cache_specs, mesh),
        NamedSharding(mesh, tok_spec),
        NamedSharding(mesh, P()),
    )
    out_sh = (in_sh[1], NamedSharding(mesh, tok_spec), None)
    return step, args, in_sh, out_sh, rules, {"donate": (1,)}


def lower_compile(step, args, in_sh, out_sh, mesh, rules, donate=()):
    t0 = time.time()
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate)
    with mesh, sharding_ctx(mesh, rules):
        lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return lowered, compiled, t_lower, t_compile


def unrolled_cfg(cfg: ModelConfig, k: int) -> ModelConfig:
    """k layer groups, unrolled (for per-layer cost extrapolation)."""
    prologue = cfg.moe_first_dense if cfg.moe_experts else 0
    return dataclasses.replace(
        cfg,
        n_layers=prologue + k * cfg.layer_period,
        encoder_layers=k if cfg.is_encdec else 0,
        scan_layers=False,
    )


def cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax < 0.5 wraps it per-computation
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: Path, *, roofline: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}

    skip = cell_applicable(cfg, shape)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: SKIP ({skip})")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    world = mesh.devices.size
    try:
        step, args, in_sh, out_sh, rules, extra = build_cell(cfg, shape, mesh)
        donate = extra.pop("donate", ())
        lowered, compiled, t_lower, t_compile = lower_compile(
            step, args, in_sh, out_sh, mesh, rules, donate=donate
        )
        ma = compiled.memory_analysis()
        rec.update(extra)
        rec["status"] = "ok"
        rec["lower_s"] = round(t_lower, 2)
        rec["compile_s"] = round(t_compile, 2)
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate_bytes": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes
            ),
            "hbm_bytes": int(V5E.hbm_bytes),
        }
        rec["fits_hbm"] = rec["memory"]["peak_estimate_bytes"] <= V5E.hbm_bytes
        rec["cost_analysis"] = cost_dict(compiled)

        hlo = compiled.as_text()
        coll, by_kind = parse_collective_bytes(hlo, world=world)
        rec["collectives"] = {"wire_bytes_per_device": coll, "by_kind": by_kind}

        if roofline:
            prologue = cfg.moe_first_dense if cfg.moe_experts else 0
            n_groups = (cfg.n_layers - prologue) // cfg.layer_period
            costs = []
            for k in (1, 2):
                cfg_k = unrolled_cfg(cfg, k)
                step_k, args_k, in_k, out_k, rules_k, extra_k = build_cell(
                    cfg_k, shape, mesh, n_micro=1
                )
                _, comp_k, _, tc = lower_compile(
                    step_k, args_k, in_k, out_k, mesh, rules_k,
                    donate=extra_k.get("donate", ()),
                )
                costs.append(cost_dict(comp_k))
                rec[f"unrolled_{k}_compile_s"] = round(tc, 2)
            terms = roofline_from_costs(costs[0], costs[1], n_groups, coll)
            rec["roofline"] = terms.as_dict()
            rec["unrolled_costs"] = costs
            n_active = cfg.active_param_count()
            tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
            mult = 6 if shape.kind == "train" else 2
            rec["model_flops_global"] = float(mult * n_active * tokens)
            hlo_global = terms.flops * world
            rec["model_flops_ratio"] = (
                rec["model_flops_global"] / hlo_global if hlo_global else None
            )
        print(
            f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
            f"compile={t_compile:.1f}s "
            f"peak={rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB "
            f"fits={rec['fits_hbm']}"
        )
    except Exception as e:  # record the failure; the sweep continues
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: ERROR {type(e).__name__}: {e}")
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (see configs/)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multipod", "both"])
    ap.add_argument("--all", action="store_true", help="run every (arch × shape)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--skip-done", action="store_true", help="skip cells with an ok JSON")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                out_path = out_dir / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_done and out_path.exists():
                    try:
                        if json.loads(out_path.read_text()).get("status") in ("ok", "skipped"):
                            continue
                    except Exception:
                        pass
                run_cell(
                    arch, shape, mesh_name, out_dir,
                    roofline=(not args.no_roofline) and mesh_name == "single",
                )


if __name__ == "__main__":
    main()
