"""Serving step factories: prefill and decode.

* ``prefill_step(params, batch, cache) -> (cache, last_logits)`` — runs the
  prompt through the model, filling the KV/state cache (the
  ``prefill_32k`` dry-run cell).
* ``decode_step(params, cache, tokens, pos) -> (cache, next_token,
  logits)`` — one token against the cache (the ``decode_32k`` /
  ``long_500k`` cells).  Greedy argmax keeps the step deterministic; the
  engine layer samples if asked.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.model import Model

__all__ = ["make_prefill_step", "make_decode_step"]


def make_prefill_step(model: Model):
    def prefill_step(params, batch: Dict[str, jax.Array], cache):
        logits, cache, _ = model.forward(params, batch, cache=cache, pos0=0)
        return cache, logits[:, -1].astype(jnp.float32)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens: jax.Array, pos: jax.Array):
        """tokens: [B, 1] current token; pos: scalar position index."""
        logits, cache, _ = model.forward(
            params, {"tokens": tokens}, cache=cache, pos0=pos
        )
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return cache, nxt[:, None], logits[:, -1]

    return decode_step
