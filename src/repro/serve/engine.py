"""Batched serving engine: continuous prefill + greedy/sampled decode.

A deliberately compact production shape: fixed-size decode batch, one
jit-compiled prefill step (padded to a bucket length) and one decode step
(cache donated, so decode runs in-place at one buffer).  Requests join the
batch at slot granularity; finished slots are recycled.

This is the layer ``examples/serve_pruned.py`` drives; the big-model
decode cells of the dry-run lower exactly the same ``decode_step``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.serve.steps import make_decode_step, make_prefill_step

__all__ = ["EngineConfig", "Engine", "Request"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # int32[prompt_len]
    max_new: int = 32
    out: Optional[np.ndarray] = None


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    batch: int = 4
    max_len: int = 512
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


class Engine:
    def __init__(self, model: Model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(make_prefill_step(model))
        self._decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests in fixed-size batches."""
        cfg = self.cfg
        for i in range(0, len(requests), cfg.batch):
            self._run_batch(requests[i : i + cfg.batch])
        return requests

    def _run_batch(self, reqs: List[Request]) -> None:
        cfg = self.cfg
        B = cfg.batch
        plen = max(int(r.prompt.size) for r in reqs)
        max_new = max(r.max_new for r in reqs)
        total = plen + max_new
        assert total <= cfg.max_len, (total, cfg.max_len)

        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - r.prompt.size :] = r.prompt  # left-pad
        cache = self.model.init_cache(B, cfg.max_len, cross_len=plen)
        batch = {"tokens": jnp.asarray(toks)}
        if self.model.cfg.is_encdec:
            batch["frames"] = jnp.zeros((B, plen, self.model.cfg.d_model), jnp.float32)
        cache, last_logits = self._prefill(self.params, batch, cache)

        outs = [list() for _ in reqs]
        cur = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
        key = jax.random.key(cfg.seed)
        for step in range(max_new):
            for i in range(len(reqs)):
                outs[i].append(int(cur[i, 0]))
            cache, nxt, logits = self._decode(
                self.params, cache, cur, jnp.asarray(plen + step, jnp.int32)
            )
            if cfg.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits / cfg.temperature, axis=-1
                ).astype(jnp.int32)[:, None]
            cur = nxt
        for i, r in enumerate(reqs):
            r.out = np.asarray(outs[i][: r.max_new], np.int32)
