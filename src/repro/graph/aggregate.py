"""Neighborhood aggregation operators on the HBP tile format.

The message-passing primitive ``agg_{u in N(v)} x_u`` for a whole feature
block X: [n, k] is one HBP SpMM launch —

* ``sum``  — ``A @ X`` under the standard (+) combine;
* ``mean`` — ``A @ X`` divided by the in-degree (or serve a row-stochastic
  adjacency and "sum" IS "mean", see :func:`~repro.graph.graph.
  normalize_adjacency`);
* ``max``  — ``A @ X`` under the max monoid (``combine="max"`` in
  :mod:`repro.kernels.ops`): per output row the max of ``a_vu * x_u`` over
  stored neighbors, 0 for isolated nodes.

Feature widths beyond 128 tile over lanes inside the kernel wrapper (the
lane-tiled k loop), so k = 256/512 GNN features stay on the fast path.

:func:`make_aggregator` stages the tiles to the device once and returns a
traceable closure — the form the GNN layers (:mod:`repro.graph.layers_gnn`)
compose and ``jax.jit`` end to end.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

from repro.core.formats import CSRMatrix
from repro.core.tile import HBPTiles, build_tiles, tuned_partition_config

from .graph import degrees

__all__ = [
    "AGGREGATIONS",
    "aggregate",
    "make_aggregator",
    "make_diff_aggregator",
    "plan_aggregator",
    "plan_diff_aggregator",
]

AGGREGATIONS = ("sum", "mean", "max")


def _mean_divisor(degree, n_rows: int) -> jax.Array:
    """[n, 1] clamped in-degree: mean over an empty neighborhood is 0.

    Delegates to the single clamp-convention home in the kernel layer so
    the differentiable mean backward can never disagree with the forward."""
    from repro.kernels.autodiff import mean_divisor

    return mean_divisor(degree, n_rows)


def aggregate(
    tiles: HBPTiles,
    x: jax.Array,  # [n, k] node features
    *,
    op: str = "sum",
    degree=None,
    strategy: str = "stable",
    interpret: bool | None = None,
) -> jax.Array:
    """One-shot neighborhood aggregation ``[n, k] -> [n, k]``.

    ``degree`` (required for ``op="mean"``) is the per-node in-neighbor
    count, e.g. :func:`repro.graph.graph.degrees` of the same adjacency.
    For repeated calls over a resident graph prefer :func:`make_aggregator`
    (or a serving :class:`~repro.serving.registry.MatrixPlan`), which
    stage the tiles once.
    """
    from repro.kernels import ops

    if op not in AGGREGATIONS:
        raise ValueError(f"unknown aggregation {op!r} (expected one of {AGGREGATIONS})")
    combine = "max" if op == "max" else "sum"
    y = ops.hbp_spmm(tiles, x, strategy=strategy, combine=combine, interpret=interpret)
    if op == "mean":
        if degree is None:
            raise ValueError("op='mean' needs the degree vector (degrees(adj))")
        y = y / _mean_divisor(degree, tiles.shape[0])
    return y


def make_aggregator(
    adj: CSRMatrix | HBPTiles,
    *,
    op: str = "sum",
    degree=None,
    cfg=None,
    strategy: str = "stable",
    interpret: bool | None = None,
) -> Callable[[jax.Array], jax.Array]:
    """Build a traceable aggregation closure over a device-resident graph.

    ``adj`` may be the CSR adjacency (tiles are built here, with the
    nnz-profile-tuned geometry unless ``cfg`` pins one) or prebuilt
    :class:`HBPTiles`.  For ``op="mean"`` the degree vector defaults to
    the structural in-degree of the CSR input (must be passed explicitly
    for tiles).  The returned closure holds only jnp arrays — safe to
    close over in a jitted GNN forward.
    """
    from repro.kernels import ops

    if op not in AGGREGATIONS:
        raise ValueError(f"unknown aggregation {op!r} (expected one of {AGGREGATIONS})")
    if isinstance(adj, CSRMatrix):
        if op == "mean" and degree is None:
            degree = degrees(adj)
        tiles = build_tiles(adj, cfg or tuned_partition_config(adj))
    else:
        tiles = adj
        if op == "mean" and degree is None:
            raise ValueError("op='mean' over prebuilt tiles needs degree=")
    dt = ops.device_tiles(tiles)  # staged once; every call reuses it
    meta = dict(
        n_rowgroups=tiles.n_rowgroups,
        n_rows=tiles.shape[0],
        col_block=tiles.cfg.col_block,
        strategy=strategy,
        interpret=interpret,
        combine="max" if op == "max" else "sum",
    )
    # degree may be a numpy or jax array alike: _mean_divisor stages it
    # directly, with no host round-trip for device-resident degrees
    div: Optional[jax.Array] = (
        _mean_divisor(degree, tiles.shape[0]) if op == "mean" else None
    )

    def agg(x: jax.Array) -> jax.Array:
        y = ops.hbp_spmm(dt, x, **meta)
        return y / div if div is not None else y

    return agg


def make_diff_aggregator(
    adj,  # CSRMatrix | kernels.autodiff.PairedTiles
    *,
    op: str = "sum",
    degree=None,
    cfg=None,
    cfg_T=None,
    strategy: str = "stable",
    interpret: bool | None = None,
    mode: str = "vjp",
) -> Callable[[jax.Array], jax.Array]:
    """Differentiable twin of :func:`make_aggregator`.

    The returned closure supports ``jax.grad`` without tracing into the
    kernels: sum/mean backward is one HBP SpMM against the transpose
    adjacency (built here as a paired tile set, see
    :func:`repro.kernels.autodiff.hbp_transpose`), max backward routes
    cotangents to the argmax neighbor saved during the forward.  ``adj``
    is the CSR adjacency or a prebuilt
    :class:`~repro.kernels.autodiff.PairedTiles`; for ``op="mean"`` the
    degree defaults to the structural in-degree of the CSR input (pass
    ``degree=`` explicitly — numpy or jax — for prebuilt pairs).  For
    served graphs prefer :func:`plan_diff_aggregator` over a registry
    plan pair, which shares residency and the autotune cache.
    """
    from repro.kernels import autodiff

    if op not in AGGREGATIONS:
        raise ValueError(f"unknown aggregation {op!r} (expected one of {AGGREGATIONS})")
    if isinstance(adj, CSRMatrix):
        if op == "mean" and degree is None:
            degree = degrees(adj)
        if autodiff.needs_transpose(op, mode):
            pair = autodiff.hbp_transpose(adj, cfg, cfg_T)
        else:  # max / jvp never launch the transpose: skip its build
            tiles = build_tiles(adj, cfg or tuned_partition_config(adj))
            pair = autodiff.PairedTiles(tiles, None)
    else:
        pair = autodiff.PairedTiles(*adj)
        if op == "mean" and degree is None:
            raise ValueError("op='mean' over prebuilt tiles needs degree=")
    return autodiff.diff_aggregator(
        pair, op=op, degree=degree, strategy=strategy, interpret=interpret, mode=mode
    )


def plan_aggregator(plan, *, op: str = "sum", bucketed: bool = True) -> Callable:
    """Aggregator over a serving :class:`~repro.serving.registry.MatrixPlan`.

    The served path for resident graphs: admit the (normalized) adjacency
    to a :class:`~repro.serving.registry.MatrixRegistry` once — content
    hashing and the autotune cache make re-admission free — and every GNN
    layer call reuses its device tiles and autotuned geometry.  ``op``
    follows :data:`AGGREGATIONS`; mean uses the in-degree the plan
    captured at admission.
    """
    if op not in AGGREGATIONS:
        raise ValueError(f"unknown aggregation {op!r} (expected one of {AGGREGATIONS})")
    return lambda x: plan.aggregate(x, op=op, bucketed=bucketed)


def plan_diff_aggregator(plan, *, op: str = "sum", mode: str = "vjp") -> Callable:
    """Differentiable aggregator over a registry-resident plan pair.

    The training-side sibling of :func:`plan_aggregator`: admit the
    adjacency with :meth:`~repro.serving.registry.MatrixRegistry.
    admit_pair` (A and Aᵀ built together, linked by content hash) and the
    closure's backward launches the linked transpose plan's tiles.  Mean
    uses the in-degree the plan captured at admission.
    """
    if op not in AGGREGATIONS:
        raise ValueError(f"unknown aggregation {op!r} (expected one of {AGGREGATIONS})")
    return plan.diff_aggregator(op=op, mode=mode)
