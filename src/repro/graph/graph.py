"""Graph construction for HBP-backed message passing.

A graph enters the library as its adjacency matrix: neighborhood
aggregation — the inner loop of every message-passing GNN — is exactly
``A @ X`` with a feature-matrix right-hand side, i.e. the multi-RHS SpMM
the HBP tile format already serves.  This module owns the host-side
construction: edge lists (or the R-MAT generator the paper's kron_g500
suite uses) become a :class:`~repro.core.formats.CSRMatrix` adjacency with
optional self-loops and the degree-based normalizations GNN layers expect.

Conventions (row = destination): ``A[v, u] != 0`` means an edge u -> v, so
``(A @ X)[v]`` aggregates over v's in-neighbors — the message direction of
GCN/GraphSAGE.  For undirected graphs build with ``symmetric=True`` and
the distinction disappears.
"""
from __future__ import annotations

import numpy as np

from repro.core.formats import COOMatrix, CSRMatrix, csr_from_coo
from repro.core.matrices import rmat

__all__ = [
    "graph_from_edges",
    "add_self_loops",
    "degrees",
    "normalize_adjacency",
    "rmat_graph",
    "power_law_graph",
]


def graph_from_edges(
    src,
    dst,
    *,
    n_nodes: int | None = None,
    weights=None,
    symmetric: bool = False,
    self_loops: bool = False,
    dedup: bool = True,
) -> CSRMatrix:
    """Edge list -> CSR adjacency (row = destination, col = source).

    ``weights=None`` builds a binary adjacency; with ``dedup`` repeated
    edges collapse to a single 1 (weighted duplicates always sum, the COO
    convention).  ``symmetric`` mirrors every edge; ``self_loops`` adds
    the diagonal afterwards (weight 1).
    """
    src = np.asarray(src, dtype=np.int64).ravel()
    dst = np.asarray(dst, dtype=np.int64).ravel()
    if src.shape != dst.shape:
        raise ValueError(f"src/dst length mismatch: {src.size} vs {dst.size}")
    if n_nodes is None:
        n_nodes = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    if src.size and (min(src.min(), dst.min()) < 0 or max(src.max(), dst.max()) >= n_nodes):
        raise ValueError(f"edge endpoints outside [0, {n_nodes})")
    if weights is None:
        data = np.ones(src.size, dtype=np.float32)
    else:
        data = np.asarray(weights, dtype=np.float32).ravel()
        if data.shape != src.shape:
            raise ValueError("weights must match the edge count")
    row, col = dst, src  # aggregate INTO the destination row
    if symmetric:
        row, col = np.concatenate([row, col]), np.concatenate([col, row])
        data = np.concatenate([data, data])
    csr = csr_from_coo(COOMatrix(row, col, data, (n_nodes, n_nodes)))
    if weights is None and dedup:
        # binary graph: repeated (and mirrored-duplicate) edges are still one edge
        csr.data = np.minimum(csr.data, 1.0).astype(np.float32)
    if self_loops:
        csr = add_self_loops(csr)
    return csr


def add_self_loops(csr: CSRMatrix, weight: float = 1.0) -> CSRMatrix:
    """A + weight * I, replacing any existing diagonal (GCN's A-tilde).

    Replacing (not accumulating) keeps the call idempotent — renormalizing
    a graph that already carries self-loops does not double them."""
    n = csr.shape[0]
    if csr.shape[0] != csr.shape[1]:
        raise ValueError(f"adjacency must be square, got {csr.shape}")
    coo = csr.to_coo()
    off = coo.row != coo.col
    row = np.concatenate([coo.row[off], np.arange(n)])
    col = np.concatenate([coo.col[off], np.arange(n)])
    data = np.concatenate(
        [coo.data[off], np.full(n, weight, dtype=coo.data.dtype)]
    )
    return csr_from_coo(COOMatrix(row, col, data, csr.shape))


def degrees(csr: CSRMatrix, *, weighted: bool = False) -> np.ndarray:
    """Per-row degree: in-neighbor count (or weighted row sum).

    The structural count is what mean-aggregation divides by; the weighted
    sum is the D of the GCN normalization."""
    if weighted:
        out = np.zeros(csr.n_rows, dtype=np.float64)
        np.add.at(out, np.repeat(np.arange(csr.n_rows), csr.row_nnz()), csr.data)
        return out
    return csr.row_nnz().astype(np.int64)


def normalize_adjacency(csr: CSRMatrix, kind: str = "sym") -> CSRMatrix:
    """Degree-normalize an adjacency matrix.

    * ``"sym"`` — ``D^{-1/2} A D^{-1/2}`` (GCN's symmetric normalization;
      D = weighted row sums, isolated nodes keep 0 rows);
    * ``"row"`` — ``D^{-1} A`` (row-stochastic: sum-aggregation over the
      result IS mean aggregation);
    * ``"none"`` — a copy, for API uniformity.
    """
    if kind == "none":
        return CSRMatrix(csr.indptr.copy(), csr.indices.copy(), csr.data.copy(), csr.shape)
    if kind not in ("sym", "row"):
        raise ValueError(f"unknown normalization {kind!r} (sym | row | none)")
    if csr.shape[0] != csr.shape[1]:
        raise ValueError(f"adjacency must be square, got {csr.shape}")
    d = degrees(csr, weighted=True)
    with np.errstate(divide="ignore"):
        d_inv = np.where(d != 0, 1.0 / d, 0.0)
        d_inv_sqrt = np.sqrt(np.where(d > 0, d_inv, 0.0))
    rows = np.repeat(np.arange(csr.n_rows), csr.row_nnz())
    if kind == "row":
        data = csr.data * d_inv[rows]
    else:
        data = csr.data * d_inv_sqrt[rows] * d_inv_sqrt[csr.indices]
    return CSRMatrix(csr.indptr.copy(), csr.indices.copy(), data.astype(np.float32), csr.shape)


def rmat_graph(
    n: int,
    avg_degree: float = 16.0,
    *,
    seed: int = 0,
    symmetric: bool = True,
    self_loops: bool = False,
) -> CSRMatrix:
    """Binary R-MAT (kron_g500-family) graph: power-law degrees, the
    skewed-row workload the nonlinear hash was built for.

    ``n`` rounds up to the next power of two (the R-MAT recursion depth).
    """
    g = rmat(n, int(n * avg_degree), seed=seed, symmetric=symmetric)
    g = CSRMatrix(g.indptr, g.indices, np.ones(g.nnz, dtype=np.float32), g.shape)
    if self_loops:
        g = add_self_loops(g)
    return g


def power_law_graph(
    n: int,
    avg_degree: float = 8.0,
    *,
    seed: int = 0,
    exponent: float = 1.2,
    symmetric: bool = True,
    self_loops: bool = False,
) -> CSRMatrix:
    """Power-law graph at an *exact* node count (R-MAT rounds to 2^k).

    Endpoints are sampled with Zipf-like popularity ``p(v) ∝ rank^-exponent``
    under a random rank assignment — a preferential-attachment-shaped
    degree profile on precisely ``n`` nodes, which is what the GNN
    acceptance tests pin (e.g. the 10k-node Cora-like graph).
    """
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree) // (2 if symmetric else 1)
    p = (1.0 + np.arange(n)) ** -exponent
    p /= p.sum()
    popularity = rng.permutation(n)  # which node gets which rank
    src = popularity[rng.choice(n, size=m, p=p)]
    dst = popularity[rng.choice(n, size=m, p=p)]
    keep = src != dst  # self-loops only by request, below
    return graph_from_edges(
        src[keep], dst[keep], n_nodes=n, symmetric=symmetric, self_loops=self_loops
    )
