"""GCN and GraphSAGE forward passes over HBP aggregation.

Layers are pure functions of (aggregator, params, features): the
aggregator is any ``[n, k] -> [n, k]`` closure from
:func:`repro.graph.aggregate.make_aggregator` (or a serving plan's
``aggregate``), params are plain pytrees of jnp arrays, and the whole
forward jit-compiles end to end — the sparse aggregation launches and the
dense feature transforms fuse into one traced program.

* **GCN** (Kipf & Welling): ``H' = act(Â (H W) + b)`` with
  Â = D^-1/2 (A + I) D^-1/2 — build the aggregator over
  ``normalize_adjacency(add_self_loops(A), "sym")`` with ``op="sum"``.
  The dense transform runs *before* the sparse aggregation, so the SpMM
  runs at the layer's output width (usually the narrower side).

* **GraphSAGE** (Hamilton et al.): ``h' = act(x W_self + agg(x) W_neigh
  + b)`` with a mean or max neighbor aggregator over the *raw* (no
  self-loop) adjacency — max exercises the kernel's max-monoid combine.
"""
from __future__ import annotations

from typing import Callable, List, NamedTuple, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "DenseParams",
    "SageParams",
    "init_gcn",
    "init_sage",
    "gcn_layer",
    "gcn_forward",
    "sage_layer",
    "sage_forward",
]

Aggregator = Callable[[jax.Array], jax.Array]


class DenseParams(NamedTuple):
    """One GCN layer: feature transform W [in, out] and bias b [out]."""

    W: jax.Array
    b: jax.Array


class SageParams(NamedTuple):
    """One GraphSAGE layer: self and neighbor transforms plus bias."""

    W_self: jax.Array  # [in, out]
    W_neigh: jax.Array  # [in, out]
    b: jax.Array  # [out]


def _glorot(key, fan_in: int, fan_out: int) -> jax.Array:
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(key, (fan_in, fan_out), jnp.float32)


def init_gcn(key, dims: Sequence[int]) -> List[DenseParams]:
    """Glorot-initialized GCN stack: dims = [in, hidden..., out]."""
    keys = jax.random.split(key, len(dims) - 1)
    return [
        DenseParams(W=_glorot(k, d_in, d_out), b=jnp.zeros((d_out,), jnp.float32))
        for k, d_in, d_out in zip(keys, dims[:-1], dims[1:])
    ]


def init_sage(key, dims: Sequence[int]) -> List[SageParams]:
    """Glorot-initialized GraphSAGE stack: dims = [in, hidden..., out]."""
    keys = jax.random.split(key, 2 * (len(dims) - 1))
    return [
        SageParams(
            W_self=_glorot(keys[2 * i], d_in, d_out),
            W_neigh=_glorot(keys[2 * i + 1], d_in, d_out),
            b=jnp.zeros((d_out,), jnp.float32),
        )
        for i, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:]))
    ]


def gcn_layer(
    agg: Aggregator, p: DenseParams, x: jax.Array, activation=jax.nn.relu
) -> jax.Array:
    """act(Â (x W) + b); pass ``activation=None`` for the logits layer."""
    h = agg(x @ p.W) + p.b
    return activation(h) if activation is not None else h


def gcn_forward(
    agg: Aggregator,
    params: Sequence[DenseParams],
    x: jax.Array,
    *,
    activation=jax.nn.relu,
) -> jax.Array:
    """Full GCN forward: activation between layers, raw logits out."""
    for p in params[:-1]:
        x = gcn_layer(agg, p, x, activation)
    return gcn_layer(agg, params[-1], x, activation=None)


def sage_layer(
    agg: Aggregator, p: SageParams, x: jax.Array, activation=jax.nn.relu
) -> jax.Array:
    """act(x W_self + agg(x) W_neigh + b).

    ``agg`` supplies the aggregation semantics (mean or max, with the
    kernel's monoid underneath); the layer itself is aggregation-agnostic.
    """
    h = x @ p.W_self + agg(x) @ p.W_neigh + p.b
    return activation(h) if activation is not None else h


def sage_forward(
    agg: Aggregator,
    params: Sequence[SageParams],
    x: jax.Array,
    *,
    activation=jax.nn.relu,
) -> jax.Array:
    """Full GraphSAGE forward: activation between layers, raw logits out."""
    for p in params[:-1]:
        x = sage_layer(agg, p, x, activation)
    return sage_layer(agg, params[-1], x, activation=None)
