"""Node-classification objectives over GNN logits.

Masked means throughout: mini-batch training supervises the *seed* rows
only (the sampled context exists to feed their aggregation), and
full-graph training may hold out validation/test node sets — both are the
same masked cross-entropy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["softmax_cross_entropy", "accuracy"]


def _masked_mean(values: jax.Array, mask) -> jax.Array:
    if mask is None:
        return values.mean()
    m = jnp.asarray(mask, values.dtype).reshape(values.shape)
    return (values * m).sum() / jnp.maximum(m.sum(), 1.0)


def softmax_cross_entropy(logits: jax.Array, labels, mask=None) -> jax.Array:
    """Mean cross-entropy of ``logits`` [n, C] vs integer ``labels`` [n].

    ``mask`` (optional, [n], nonzero = supervised) restricts the mean to
    the supervised rows; an all-zero mask yields 0 rather than NaN.
    """
    labels = jnp.asarray(labels, jnp.int32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return _masked_mean(nll, mask)


def accuracy(logits: jax.Array, labels, mask=None) -> jax.Array:
    """Fraction of (masked) rows whose argmax matches the label."""
    labels = jnp.asarray(labels, jnp.int32)
    hit = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    return _masked_mean(hit, mask)
