"""Neighbor-sampled mini-batching: subgraph extraction + GraphSAGE fan-out.

Full-graph training keeps one resident adjacency and aggregates every node
each step; GraphSAGE's original regime instead trains on *mini-batches*:
pick seed nodes, sample a bounded fan-out of neighbors per hop, and run
the forward/backward on the induced subgraph only.  Both halves live on
the host format:

* :func:`subgraph` — induced-subgraph extraction on :class:`CSRMatrix`
  (vectorised gather + relabel, no Python per-edge loop), preserving edge
  weights exactly;
* :func:`sample_neighbors` — the fan-out sampler: per hop, each frontier
  node draws at most ``fanout`` in-neighbors without replacement, the
  union becomes the batch's node set (seeds first), and the batch carries
  the induced adjacency over that set.

Determinism is the point of the ``seed`` parameter: the same
``(seeds, fanouts, seed)`` triple reproduces the same subgraph bit for
bit, so its content hash matches and re-admission to a serving
:class:`~repro.serving.registry.MatrixRegistry` is free — epochs after
the first pay zero preprocessing (the admit-once/multiply-many asymmetry,
per batch).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.formats import COOMatrix, CSRMatrix, csr_from_coo

__all__ = ["subgraph", "sample_neighbors", "SampledSubgraph"]


def _row_entries(csr: CSRMatrix, rows: np.ndarray):
    """All stored entries of ``rows``: (local_row, col, val), vectorised."""
    counts = csr.row_nnz()[rows]
    total = int(counts.sum())
    if total == 0:
        e = np.zeros(0, dtype=np.int64)
        return e, e, np.zeros(0, dtype=csr.data.dtype)
    local = np.repeat(np.arange(rows.size), counts)
    base = np.repeat(csr.indptr[rows], counts)
    within = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
    flat = base + within
    return local, csr.indices[flat], csr.data[flat]


def subgraph(csr: CSRMatrix, nodes) -> CSRMatrix:
    """Induced subgraph of a square adjacency on ``nodes``.

    ``nodes`` (global ids, duplicates dropped keeping first occurrence)
    become local ids 0..m-1 in the given order; the result keeps exactly
    the stored entries whose row AND column are both in the set, with
    their weights bit-identical to the parent — so local degrees equal
    the count of in-set parent neighbors, and repeated node sets produce
    content-hash-identical subgraphs.
    """
    if csr.shape[0] != csr.shape[1]:
        raise ValueError(f"adjacency must be square, got {csr.shape}")
    nodes = np.asarray(nodes, dtype=np.int64).ravel()
    if nodes.size and (nodes.min() < 0 or nodes.max() >= csr.shape[0]):
        raise ValueError(f"node ids outside [0, {csr.shape[0]})")
    _, first = np.unique(nodes, return_index=True)
    nodes = nodes[np.sort(first)]
    m = nodes.size
    lookup = np.full(csr.shape[1], -1, dtype=np.int64)
    lookup[nodes] = np.arange(m)
    row_l, col_g, vals = _row_entries(csr, nodes)
    keep = lookup[col_g] >= 0
    return csr_from_coo(
        COOMatrix(row_l[keep], lookup[col_g[keep]], vals[keep], (m, m)),
        sum_duplicates=False,
    )


@dataclasses.dataclass
class SampledSubgraph:
    """One mini-batch: node set (seeds first) + induced adjacency."""

    nodes: np.ndarray  # int64[m] global ids; nodes[:n_seeds] are the seeds
    n_seeds: int
    adj: CSRMatrix  # [m, m] induced adjacency in local ids

    def seed_mask(self) -> np.ndarray:
        """f32[m] indicator of the seed rows — the loss mask: supervision
        applies to seeds only, the sampled context is support."""
        mask = np.zeros(self.nodes.size, dtype=np.float32)
        mask[: self.n_seeds] = 1.0
        return mask


def sample_neighbors(
    csr: CSRMatrix,
    seeds,
    fanouts,
    *,
    seed: int = 0,
) -> SampledSubgraph:
    """GraphSAGE fan-out sampling: seeds + ≤``fanouts[h]`` in-neighbors/hop.

    Hop ``h`` expands the current frontier: every frontier node draws at
    most ``fanouts[h]`` of its stored in-neighbors (without replacement,
    uniformly over the stored pattern), newly-seen nodes join the node
    set and form the next frontier.  The batch adjacency is the *induced*
    subgraph over the final node set — a superset of the sampled edge
    tree, so aggregation sees every in-set edge (one SpMM, no per-hop
    masking).  Node count is bounded by
    ``len(seeds) * prod(1 + fanouts)``; identical ``(seeds, fanouts,
    seed)`` reproduce the identical batch.
    """
    nodes = np.asarray(seeds, dtype=np.int64).ravel()
    _, first = np.unique(nodes, return_index=True)
    nodes = nodes[np.sort(first)]
    n_seeds = nodes.size
    if n_seeds == 0:
        raise ValueError("need at least one seed node")
    rng = np.random.default_rng(seed)
    seen = set(nodes.tolist())
    frontier = nodes
    order = [nodes]
    for fanout in fanouts:
        if fanout < 1 or frontier.size == 0:
            break
        picked = []
        for u in frontier:
            nbrs, _ = csr.row_slice(int(u))
            if nbrs.size == 0:
                continue
            if nbrs.size > fanout:
                nbrs = rng.choice(nbrs, size=fanout, replace=False)
            picked.extend(int(v) for v in nbrs)
        fresh = [v for v in dict.fromkeys(picked) if v not in seen]
        seen.update(fresh)
        frontier = np.asarray(fresh, dtype=np.int64)
        if fresh:
            order.append(frontier)
    nodes = np.concatenate(order)
    return SampledSubgraph(nodes=nodes, n_seeds=n_seeds, adj=subgraph(csr, nodes))
