# GNN training on the HBP path: neighbor sampling (host-side subgraph
# extraction + GraphSAGE fan-out), masked node-classification objectives,
# and a trainer that backpropagates through the differentiable aggregators
# (sum/mean backward = the transpose-adjacency SpMM, max = argmax routing).
from .loss import accuracy, softmax_cross_entropy
from .sampling import SampledSubgraph, sample_neighbors, subgraph
from .trainer import NodeClassifierTrainer, TrainState

__all__ = [
    "subgraph",
    "sample_neighbors",
    "SampledSubgraph",
    "softmax_cross_entropy",
    "accuracy",
    "NodeClassifierTrainer",
    "TrainState",
]
