"""Node-classification training loop over differentiable HBP aggregation.

The trainer composes the pieces the rest of the library already owns:

* forward — :mod:`repro.graph.layers_gnn` GCN/GraphSAGE stacks over a
  differentiable aggregator (:mod:`repro.kernels.autodiff`), so
  ``jax.grad`` of the loss launches the transpose-adjacency SpMM for the
  backward instead of tracing into the kernels;
* optimizer — :func:`repro.optim.adamw.adamw_update` (warmup + cosine
  schedule, global-norm clipping);
* residency — an optional serving :class:`~repro.serving.registry.
  MatrixRegistry`: adjacencies are admitted as linked (A, Aᵀ) pairs, and
  in mini-batch mode each sampled subgraph is content-hashed, so epochs
  after the first re-admit every batch for free.

Two regimes: :meth:`NodeClassifierTrainer.fit` trains full-graph (one
resident adjacency, every step aggregates all nodes);
:meth:`~NodeClassifierTrainer.fit_sampled` trains GraphSAGE-style
neighbor-sampled mini-batches (:mod:`repro.graph.train.sampling`), with
supervision restricted to each batch's seed nodes.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.formats import CSRMatrix
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state

from ..aggregate import make_diff_aggregator, plan_diff_aggregator
from ..graph import add_self_loops, normalize_adjacency
from ..layers_gnn import gcn_forward, init_gcn, init_sage, sage_forward
from .loss import accuracy, softmax_cross_entropy
from .sampling import sample_neighbors

__all__ = ["TrainState", "NodeClassifierTrainer"]

MODELS = ("gcn", "sage")


class TrainState(NamedTuple):
    """Parameters + optimizer state; advance with ``trainer.step``."""

    params: Any
    opt_state: Dict[str, Any]


class NodeClassifierTrainer:
    """Cross-entropy node classification with GCN or GraphSAGE.

    ``dims`` is the layer stack ``[n_features, hidden..., n_classes]``.
    ``model`` picks the forward and the adjacency convention: ``"gcn"``
    sum-aggregates over the symmetric-normalized self-loop adjacency,
    ``"sage"`` mean/max-aggregates over the raw adjacency (``op``
    defaults accordingly and must be "sum" | "mean" | "max").  Pass a
    ``registry`` to serve aggregation from resident, content-hashed
    (A, Aᵀ) plan pairs — required for mini-batch cache reuse to pay off.
    """

    def __init__(
        self,
        dims: Sequence[int],
        *,
        model: str = "gcn",
        op: Optional[str] = None,
        adamw: Optional[AdamWConfig] = None,
        registry=None,
        strategy: Optional[str] = None,
        interpret: Optional[bool] = None,
        mode: str = "vjp",
    ):
        if model not in MODELS:
            raise ValueError(f"unknown model {model!r} (expected one of {MODELS})")
        if len(dims) < 2:
            raise ValueError("dims needs at least [n_features, n_classes]")
        self.dims = list(dims)
        self.model = model
        self.op = op or ("sum" if model == "gcn" else "mean")
        self.adamw = adamw or AdamWConfig(
            lr_peak=2e-2, warmup_steps=5, decay_steps=500, weight_decay=0.0
        )
        self.registry = registry
        if strategy is None:
            strategy = "fused" if jax.default_backend() == "tpu" else "stable"
        self.strategy = strategy
        self.interpret = interpret
        self.mode = mode

    # --- setup -------------------------------------------------------------

    def init(self, key) -> TrainState:
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        init = init_gcn if self.model == "gcn" else init_sage
        params = init(key, self.dims)
        return TrainState(params=params, opt_state=init_opt_state(params, self.adamw))

    def prepare_adjacency(self, adj: CSRMatrix) -> CSRMatrix:
        """The model's adjacency convention: Â for GCN, raw for SAGE."""
        if self.model == "gcn":
            return normalize_adjacency(add_self_loops(adj), "sym")
        return adj

    def aggregator(self, adj: CSRMatrix) -> Callable[[jax.Array], jax.Array]:
        """Differentiable aggregator over a *prepared* adjacency.

        With a registry the adjacency is admitted as a linked (A, Aᵀ)
        pair — re-admitting the same content (the resident full graph, or
        a repeated sampled batch) is free; without one, tiles are built
        directly per call.  Ops whose backward never launches Aᵀ (max,
        or the jvp mode) admit only the forward direction.
        """
        if self.registry is not None:
            from repro.kernels.autodiff import needs_transpose

            if needs_transpose(self.op, self.mode):
                plan = self.registry.admit_pair(adj)
            else:
                plan = self.registry.admit(adj)
            return plan_diff_aggregator(plan, op=self.op, mode=self.mode)
        return make_diff_aggregator(
            adj,
            op=self.op,
            strategy=self.strategy,
            interpret=self.interpret,
            mode=self.mode,
        )

    # --- one step ----------------------------------------------------------

    def _forward(self, agg, params, x: jax.Array) -> jax.Array:
        fwd = gcn_forward if self.model == "gcn" else sage_forward
        return fwd(agg, params, x)

    def step(
        self,
        state: TrainState,
        agg: Callable[[jax.Array], jax.Array],
        x: jax.Array,
        labels,
        mask=None,
    ) -> Tuple[TrainState, Dict[str, float]]:
        """One train step: loss + grads (VJP = transpose SpMM) + AdamW."""

        def loss_fn(params):
            logits = self._forward(agg, params, x)
            return softmax_cross_entropy(logits, labels, mask), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        params, opt_state, metrics = adamw_update(
            state.params, grads, state.opt_state, self.adamw
        )
        out = {
            "loss": float(loss),
            "accuracy": float(accuracy(logits, labels, mask)),
            "grad_norm": float(metrics["grad_norm"]),
            "lr": float(metrics["lr"]),
            "step": int(metrics["step"]),
        }
        # one always-on flight instant per step (values are already host
        # floats — no extra syncs); a post-mortem shows training progress
        # around whatever anomaly triggered the dump
        obs.get_flight().record(
            "train.step",
            model=self.model,
            step=out["step"],
            loss=out["loss"],
            grad_norm=out["grad_norm"],
        )
        if obs.enabled():
            # the step dict already forced these to host floats, so the
            # streams cost no extra syncs; indexed by optimizer step
            i = out["step"]
            obs.series("train.loss", model=self.model).append(out["loss"], index=i)
            obs.series("train.grad_norm", model=self.model).append(
                out["grad_norm"], index=i
            )
            obs.series("train.accuracy", model=self.model).append(
                out["accuracy"], index=i
            )
            obs.counter("train.steps", model=self.model).inc()
        return TrainState(params, opt_state), out

    def evaluate(self, state: TrainState, agg, x, labels, mask=None) -> Dict[str, float]:
        logits = self._forward(agg, state.params, x)
        return {
            "loss": float(softmax_cross_entropy(logits, labels, mask)),
            "accuracy": float(accuracy(logits, labels, mask)),
        }

    # --- training regimes --------------------------------------------------

    def fit(
        self,
        adj: CSRMatrix,
        x,
        labels,
        *,
        steps: int,
        state: Optional[TrainState] = None,
        key: int = 0,
        mask=None,
    ) -> Tuple[TrainState, List[Dict[str, float]]]:
        """Full-graph training: one resident adjacency, ``steps`` updates."""
        state = state or self.init(key)
        agg = self.aggregator(self.prepare_adjacency(adj))
        x = jnp.asarray(x, jnp.float32)
        history = []
        for _ in range(steps):
            state, metrics = self.step(state, agg, x, labels, mask)
            history.append(metrics)
        return state, history

    def fit_sampled(
        self,
        adj: CSRMatrix,
        x,
        labels,
        *,
        steps: int,
        batch_size: int,
        fanouts: Sequence[int] = (10, 5),
        state: Optional[TrainState] = None,
        key: int = 0,
        seed: int = 0,
        train_nodes=None,
    ) -> Tuple[TrainState, List[Dict[str, float]]]:
        """Neighbor-sampled mini-batch training (GraphSAGE's regime).

        One epoch is a fixed partition of ``train_nodes`` (default: all)
        into ``batch_size`` seed groups; epochs cycle the same batches
        with the same per-batch sampler seeds, so every subgraph after
        the first epoch is a registry content-hash hit (when a registry
        is attached) — per-batch preprocessing is paid once per run.
        Supervision applies to each batch's seed rows only.
        """
        state = state or self.init(key)
        n = adj.shape[0]
        train_nodes = (
            np.arange(n, dtype=np.int64)
            if train_nodes is None
            else np.asarray(train_nodes, dtype=np.int64)
        )
        if train_nodes.size == 0:
            raise ValueError("train_nodes selected no nodes to supervise")
        rng = np.random.default_rng(seed)
        perm = rng.permutation(train_nodes)
        batches = [perm[i : i + batch_size] for i in range(0, perm.size, batch_size)]
        x = np.asarray(x, np.float32)
        labels = np.asarray(labels)
        history = []
        for s in range(steps):
            b = s % len(batches)
            batch = sample_neighbors(adj, batches[b], fanouts, seed=seed + b)
            agg = self.aggregator(self.prepare_adjacency(batch.adj))
            state, metrics = self.step(
                state,
                agg,
                jnp.asarray(x[batch.nodes]),
                labels[batch.nodes],
                jnp.asarray(batch.seed_mask()),
            )
            metrics["batch_nodes"] = int(batch.nodes.size)
            history.append(metrics)
        return state, history
