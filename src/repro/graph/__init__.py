# Graph workloads on the HBP path: GNN neighborhood aggregation is SpMM
# with a feature-matrix RHS, so the paper's kernel serves message passing
# directly.  graph.py builds/normalizes adjacencies (host side), aggregate.py
# wraps the SpMM combine monoids (sum/mean/max) as traceable operators, and
# layers_gnn.py composes them into jit-able GCN / GraphSAGE forwards.
from .aggregate import (
    AGGREGATIONS,
    aggregate,
    make_aggregator,
    make_diff_aggregator,
    plan_aggregator,
    plan_diff_aggregator,
)
from .graph import (
    add_self_loops,
    degrees,
    graph_from_edges,
    normalize_adjacency,
    power_law_graph,
    rmat_graph,
)
from .layers_gnn import (
    DenseParams,
    SageParams,
    gcn_forward,
    gcn_layer,
    init_gcn,
    init_sage,
    sage_forward,
    sage_layer,
)

__all__ = [
    "AGGREGATIONS",
    "aggregate",
    "make_aggregator",
    "make_diff_aggregator",
    "plan_aggregator",
    "plan_diff_aggregator",
    "graph_from_edges",
    "add_self_loops",
    "degrees",
    "normalize_adjacency",
    "rmat_graph",
    "power_law_graph",
    "DenseParams",
    "SageParams",
    "init_gcn",
    "init_sage",
    "gcn_layer",
    "gcn_forward",
    "sage_layer",
    "sage_forward",
]
